"""Compiler-in-the-loop demo: ONE deployed multi-target cost model drives
fusion, unroll, and recompile decisions (the paper's §1 motivation) —
served through the async micro-batching CostModelServer, the way a
multi-threaded compiler would reach it.

Every advisor shares the same gateway: a single encoder forward pass per
candidate graph yields register pressure, vALU utilization, and latency
together; requests from concurrent compile threads coalesce into shared
batched forward passes; and the LRU cache behind the server is shared
across advisors — a graph costed during fusion search is free during
unroll search.

The finale is the full ``repro.opt`` engine the advisors are thin
wrappers over: beam search across the whole rewrite registry (fusion,
CSE, DCE, recompute, bf16 narrowing, unroll), one batched predict_all
per frontier expansion, judged against the analyzer oracle.

    PYTHONPATH=src python examples/compiler_advisors.py
"""
import numpy as np

from repro.configs.costmodel import CostModelConfig
from repro.core import augment as AUG
from repro.core import models as CM
from repro.core import trainer as TR
from repro.core.server import CostModelServer
from repro.core.service import (CostModelService, FusionAdvisor,
                                RecompileAdvisor, UnrollAdvisor)
from repro.ir import analyzers, dataset as DS
from repro.ir import samplers
from repro.opt import evaluate as OE
from repro.opt import search as OPT


def main(n_graphs=900, train_steps=300, seed=0):
    cfg = CostModelConfig(name="advisors", vocab_size=4096, max_seq=160,
                          embed_dim=64, conv_channels=(64,) * 6,
                          fc_dims=(256, 64))
    # rewrite_factor puts fused/bf16 IR text in the corpus (and vocab),
    # so the model can rank the optimizer's candidates
    ds = DS.build_dataset(n_graphs, mode="ops", max_seq=160,
                          vocab_size=4096, augment_factor=1,
                          rewrite_factor=1, seed=seed)
    tr, te = ds.split(0.1)
    print(f"training one model for all targets: {list(CM.DEFAULT_HEADS)}")
    res = TR.TrainEngine("conv1d", cfg, CM.DEFAULT_HEADS,
                         steps=train_steps, batch_size=128, lr=2e-3,
                         seed=seed).fit(tr)
    for t, m in TR.evaluate("conv1d", cfg, res, te).items():
        print(f"  eval[{t}]: rmse_rel={m['rmse_rel_pct']:.1f}% "
              f"mape={m['mape_pct']:.1f}%")

    svc = CostModelService("conv1d", cfg, res.params, ds.vocab,
                           res.norm_stats, mode="ops", max_seq=160)
    with CostModelServer(svc, max_batch=32, flush_us=2000) as server:
        fusion = FusionAdvisor(server)
        unroll = UnrollAdvisor(server, register_budget=64)
        recompile = RecompileAdvisor(server)

        rng = np.random.default_rng(seed + 1)
        g = samplers.sample_graph(rng, "resnet")
        costs = server.predict_all([g])
        print("one forward pass, all characteristics:",
              {t: round(float(v[0]), 2) for t, v in costs.items()})

        do_fuse, c0, c1 = fusion.advise(g)
        print(f"fusion advisor: fuse={do_fuse} "
              f"(unfused={c0:.1f}us fused={c1:.1f}us)")
        adv = unroll.advise(g)
        per_iter = {k: round(v, 1)
                    for k, v in adv['per_iter_latency'].items()}
        print(f"unroll advisor: best_factor={adv['best_factor']} "
              f"per-iter latency={per_iter}")
        g2 = AUG.jitter_shapes(g, rng)
        dec = recompile.advise(g, g2)
        print(f"recompile advisor: recompile={dec['recompile']} "
              f"shift={dec['shift']:.1%}")

        # the full engine: beam search over the whole rewrite registry
        gb = samplers.sample_graph(rng, "bert")
        res = OPT.beam_search(server, gb, beam_width=3, max_steps=4)
        final = OE.replay(res)
        print(f"beam search [{gb.name}]: {res.describe()}")
        print(f"  predicted latency {res.root_preds['latency_us']:.1f}us "
              f"-> {res.best_preds['latency_us']:.1f}us in "
              f"{res.expansions} expansions "
              f"({res.evaluated} candidates, "
              f"{res.predict_calls} batched predict_all calls)")
        print(f"  oracle latency    {analyzers.latency_us(gb):.1f}us "
              f"-> {analyzers.latency_us(final):.1f}us")
        m = server.metrics.snapshot()
        print(f"server session: {m['requests']} requests, "
              f"{m['batches']} batched forward passes "
              f"(occupancy {m['batch_occupancy']:.1f}), "
              f"cache_hit_rate={m['cache_hit_rate']:.1%}")
    print(f"cache after session: {svc.cache_stats()['size']} entries "
          f"(bound {svc.cache_size})")


if __name__ == "__main__":
    main()
