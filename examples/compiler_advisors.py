"""Compiler-in-the-loop demo: the deployed cost model drives fusion,
unroll, and recompile decisions (the paper's §1 motivation).

    PYTHONPATH=src python examples/compiler_advisors.py
"""
import subprocess
import sys

# The serve driver is the real implementation; this example runs a short
# end-to-end session through it.
sys.exit(subprocess.call(
    [sys.executable, "-m", "repro.launch.serve",
     "--requests", "300", "--train-steps", "300", "--n-graphs", "900"]))
