"""Train a reduced assigned-architecture LM end-to-end on synthetic data —
exercises the zoo + optimizer + pipeline + checkpointing together.

    PYTHONPATH=src python examples/train_lm_smoke.py \
        --arch qwen3-0.6b --steps 30
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.data import pipeline as PIPE
from repro.models import model as MODEL, steps as STEPS
from repro.optim import adamw
from repro.checkpoint import ckpt

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen3-0.6b")
ap.add_argument("--steps", type=int, default=30)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=64)
ap.add_argument("--ckpt-dir", default="checkpoints/lm_smoke")
args = ap.parse_args()

cfg = get_arch(args.arch).reduced()
params = MODEL.init_params(jax.random.PRNGKey(0), cfg)
opt_cfg = adamw.AdamWConfig(lr=1e-3, total_steps=args.steps, warmup_steps=5)
train_step = jax.jit(STEPS.make_train_step(cfg, opt_cfg))
opt_state = adamw.init_state(params)
data = PIPE.synthetic_lm_batches(cfg.vocab, args.batch, args.seq)

print(f"training reduced {args.arch} for {args.steps} steps ...")
t0 = time.time()
for step in range(1, args.steps + 1):
    b = next(data)
    extra = {}
    if cfg.frontend == "vision":
        extra["patch_embeds"] = jnp.zeros(
            (args.batch, cfg.vision_patches, cfg.d_model), jnp.bfloat16)
    if cfg.frontend == "audio":
        extra["frame_embeds"] = jnp.zeros(
            (args.batch, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    batch = {"tokens": jnp.asarray(b["tokens"]),
             "labels": jnp.asarray(b["labels"]), **extra}
    params, opt_state, m = train_step(params, opt_state, batch)
    if step % 10 == 0 or step == 1:
        print(f"  step {step}: loss={float(m['loss']):.4f} "
              f"grad_norm={float(m['grad_norm']):.3f}")
ckpt.save(args.ckpt_dir, args.steps, params)
print(f"done in {time.time()-t0:.1f}s; checkpoint saved to {args.ckpt_dir}")
