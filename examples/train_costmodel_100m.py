"""End-to-end driver: train the ~100M-parameter cost model for a few
hundred steps with the full production substrate (sharded data pipeline,
AdamW, int8 error-feedback grad compression, atomic checkpoints + resume).

    # demo scale (runs in minutes on CPU):
    PYTHONPATH=src python examples/train_costmodel_100m.py --steps 300

    # the actual 100M config (use on real hardware):
    PYTHONPATH=src python examples/train_costmodel_100m.py \
        --preset 100m --steps 200
"""
import sys
import subprocess

args = sys.argv[1:]
if not any(a.startswith("--preset") for a in args):
    args = ["--preset", "base"] + args
if not any(a.startswith("--steps") for a in args):
    args += ["--steps", "300"]
sys.exit(subprocess.call(
    [sys.executable, "-m", "repro.launch.train", "--compress-grads",
     "--target", "register_pressure"] + args))
