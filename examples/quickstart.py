"""Quickstart: build an MLIR corpus, train the paper's Conv1D cost model,
predict hardware characteristics for an unseen graph.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs.costmodel import CostModelConfig
from repro.core import trainer as TR
from repro.core.service import CostModelService
from repro.ir import dataset as DS, printer, samplers, analyzers

cfg = CostModelConfig(name="quickstart", vocab_size=2048, max_seq=128,
                      embed_dim=64, conv_channels=(64,) * 6,
                      fc_dims=(256, 64))

print("1) sampling 1200 dataflow graphs (resnet/bert/unet/ssd/yolo) ...")
ds = DS.build_dataset(1200, mode="ops", max_seq=128, vocab_size=2048,
                      augment_factor=2, seed=0)
train, test = ds.split(0.1)

print("2) training the Conv1D+MaxPool+FC regressor on register pressure ...")
engine = TR.TrainEngine("conv1d", cfg, "register_pressure",
                        steps=500, batch_size=128, lr=2e-3, verbose=True,
                        log_every=100)
res = engine.fit(train)
print(f"   {res.stats['steps_per_s']:.1f} steps/s (bucketed batches)")
metrics = TR.evaluate("conv1d", cfg, res, test, "register_pressure")
print("   test metrics:", {k: round(v, 2) for k, v in metrics.items()})

print("3) predicting an unseen graph ...")
rng = np.random.default_rng(123)
g = samplers.sample_graph(rng, "bert")
print(printer.to_mlir(g).splitlines()[0], "...")
svc = CostModelService("conv1d", cfg, res.params, ds.vocab,
                       res.norm_stats, mode="ops", max_seq=128)
pred = svc.predict(g)
true = analyzers.register_pressure(g)
print(f"   predicted register pressure: {pred:.1f}  (ground truth: {true})")
