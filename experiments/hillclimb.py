"""Perf hillclimb driver: run named variants of the three chosen cells and
record roofline terms per iteration (EXPERIMENTS.md §Perf reads these).

    PYTHONPATH=src python experiments/hillclimb.py --cell llava_prefill
    PYTHONPATH=src python experiments/hillclimb.py --all
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import dryrun as DR

OUT = os.path.join(os.path.dirname(__file__), "hillclimb")

# Each variant: (tag, kwargs for run_cell). Baselines ran with the v0 code;
# the "it1-*" rows re-measure after global code changes (context parallelism
# + SP residual sharding), later rows apply per-cell overrides.
CELLS = {
    "llava_prefill": [
        ("it1-context-parallel", dict(
            arch_name="llava-next-34b", shape_name="prefill_32k")),
        ("it2-noSP-residual", dict(
            arch_name="llava-next-34b", shape_name="prefill_32k",
            rules_overrides={"embed": None, "qseq": ("model",)})),
        ("it3-pad-heads-tp", dict(
            arch_name="llava-next-34b", shape_name="prefill_32k",
            pad_heads=True)),
        ("it4-pad-heads-batch2d", dict(
            arch_name="llava-next-34b", shape_name="prefill_32k",
            pad_heads=True,
            rules_overrides={"batch": ("data",), "qseq": ()})),
    ],
    "jamba_train": [
        ("it1-global-sp", dict(
            arch_name="jamba-v0.1-52b", shape_name="train_4k")),
        ("it2-seq-sharded-residual", dict(
            arch_name="jamba-v0.1-52b", shape_name="train_4k",
            rules_overrides={"seq": ("model",)})),
        ("it3-batch-over-model-too", dict(
            arch_name="jamba-v0.1-52b", shape_name="train_4k",
            rules_overrides={"batch": ("pod", "data", "model")})),
        ("it4-2d-param-sharding", dict(
            arch_name="jamba-v0.1-52b", shape_name="train_4k")),
        ("it5-2d-params-dp-batch", dict(
            arch_name="jamba-v0.1-52b", shape_name="train_4k",
            rules_overrides={"batch": ("pod", "data", "model")})),
    ],
    "qwen3_train": [
        ("it1-global-sp", dict(
            arch_name="qwen3-0.6b", shape_name="train_4k")),
        ("it2-pure-dp", dict(
            arch_name="qwen3-0.6b", shape_name="train_4k",
            rules_overrides={"batch": ("pod", "data", "model")})),
        ("it3-pure-dp-no-remat", dict(
            arch_name="qwen3-0.6b", shape_name="train_4k", remat=False,
            rules_overrides={"batch": ("pod", "data", "model")})),
        ("it4-dp-replicated-params", dict(
            arch_name="qwen3-0.6b", shape_name="train_4k", remat=False,
            rules_overrides={"batch": ("pod", "data", "model"),
                             "ffn": None, "vocab": None, "heads": None,
                             "kv_heads": None})),
        ("it5-dp-repl-bf16-grads", dict(
            arch_name="qwen3-0.6b", shape_name="train_4k", remat=False,
            grad_bf16=True,
            rules_overrides={"batch": ("pod", "data", "model"),
                             "ffn": None, "vocab": None, "heads": None,
                             "kv_heads": None})),
    ],
}


def run(cell):
    os.makedirs(OUT, exist_ok=True)
    for tag, kw in CELLS[cell]:
        path = os.path.join(OUT, f"{cell}__{tag}.json")
        if os.path.exists(path):
            with open(path) as f:
                rec = json.load(f)
            if rec.get("status") == "ok":
                r = rec["roofline"]
                print(f"[cached] {cell}/{tag}: "
                      f"t=({r['t_compute']*1e3:.0f},{r['t_memory']*1e3:.0f},"
                      f"{r['t_collective']*1e3:.0f})ms "
                      f"frac={r['roofline_fraction']:.2%}")
                continue
        print(f"=== {cell} / {tag} ===")
        try:
            rec = DR.run_cell(verbose=True, **kw)
        except Exception as e:
            import traceback
            rec = {"status": "failed", "error": str(e),
                   "traceback": traceback.format_exc()[-1500:]}
            print(f"FAILED: {str(e)[:300]}")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=sorted(CELLS), default=None)
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()
    for c in ([args.cell] if args.cell else sorted(CELLS)):
        if c:
            run(c)
