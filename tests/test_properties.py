"""Property-based tests (hypothesis) on system invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st, HealthCheck

from repro.core import augment as AUG
from repro.core import tokenizer as TOK
from repro.ir import analyzers, samplers
from repro.launch import hlo_cost as HC

SETTINGS = dict(max_examples=25, deadline=None,
                suppress_health_check=[HealthCheck.too_slow])


@st.composite
def graphs(draw):
    seed = draw(st.integers(0, 2**31 - 1))
    fam = draw(st.sampled_from(sorted(samplers.SAMPLERS)))
    return samplers.sample_graph(np.random.default_rng(seed), fam)


@given(graphs())
@settings(**SETTINGS)
def test_sampled_graphs_always_valid(g):
    g.validate()
    assert len(g.values) == g.n_args + len(g.ops)


@given(graphs())
@settings(**SETTINGS)
def test_analyzer_targets_positive_and_finite(g):
    res = analyzers.analyze(g)
    for k, v in res.items():
        assert np.isfinite(v) and v >= 0, k


@given(graphs(), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_reorder_is_semantic_invariant(g, seed):
    """Topological reorder: same ops, same flops-derived targets; register
    pressure may change (schedule-dependent) but stays within bounds."""
    rng = np.random.default_rng(seed)
    g2 = AUG.reorder_ops(g, rng)
    g2.validate()
    assert sorted(o.opcode for o in g2.ops) == \
        sorted(o.opcode for o in g.ops)
    assert analyzers.valu_utilization(g2) == analyzers.valu_utilization(g)
    assert analyzers.latency_us(g2) == pytest.approx(analyzers.latency_us(g))
    # pressure bounded by sum of all value units (trivial upper bound)
    ub = sum(analyzers._vreg_units(t) for t in g.values)
    assert 0 < analyzers.register_pressure(g2) <= ub


@given(graphs())
@settings(**SETTINGS)
def test_tokenizer_ops_subset_of_operands_mode(g):
    ops = TOK.graph_tokens(g, "ops")
    opnd = TOK.graph_tokens(g, "ops_operands")
    # every opcode token appears in both modes, in the same order
    o1 = [t for t in ops if t.startswith("xpu.")]
    o2 = [t for t in opnd if t.startswith("xpu.")]
    assert o1 == o2
    assert len(opnd) >= len(ops)


@given(graphs(), st.integers(4, 64))
@settings(**SETTINGS)
def test_encode_pads_and_truncates(g, max_len):
    toks = TOK.graph_tokens(g, "ops")
    v = TOK.fit_vocab([toks], max_size=4096)
    ids = v.encode(toks, max_len)
    assert ids.shape == (max_len,)
    assert (ids[min(len(toks), max_len):] == v.token_to_id[TOK.PAD]).all()


@given(st.lists(st.sampled_from(["a", "b", "c", "dd", "ee"]),
                min_size=1, max_size=50))
@settings(**SETTINGS)
def test_vocab_fit_encode_no_oov_on_train_corpus(tokens):
    v = TOK.fit_vocab([tokens], max_size=4096)
    assert v.oov_rate(tokens) == 0.0


@given(st.integers(1, 8), st.integers(1, 8), st.integers(1, 64))
@settings(**SETTINGS)
def test_hlo_shape_bytes(a, b, c):
    total, shapes = HC._shape_info(f"f32[{a},{b},{c}]{{2,1,0}}")
    assert total == a * b * c * 4
    total2, _ = HC._shape_info(f"(f32[{a}], bf16[{b},{c}])")
    assert total2 == a * 4 + b * c * 2


@given(graphs(), st.lists(st.integers(0, 2**31 - 1),
                          min_size=1, max_size=6))
@settings(**SETTINGS)
def test_incremental_struct_key_matches_from_scratch(g, seeds):
    """Incremental hashing invariant: after ANY legal rewrite sequence
    (random rule + site per step, across all registered families —
    fusion, CSE, DCE, recompute, dtype_narrow, unroll), the child's
    memoized/inherited struct_key equals the from-scratch Merkle walk,
    and a bare structural clone (no memos at all) agrees."""
    from repro.ir.graph import Graph
    from repro.opt import rewrites as RW
    rules = RW.default_rules()
    out = g
    for s in seeds:
        rng = np.random.default_rng(s)
        firing = [(r, site) for r in rules for site in r.applicable(out)]
        if not firing:
            break
        r, site = firing[int(rng.integers(0, len(firing)))]
        try:
            out = r.apply(out, site)
        except AssertionError:
            continue                      # illegal here: try next step
        assert out.struct_key() == out.struct_key_fresh()
    clone = Graph(values=list(out.values), n_args=out.n_args,
                  ops=list(out.ops), outputs=list(out.outputs))
    assert clone.struct_key() == out.struct_key()


@given(graphs(), st.integers(4, 64))
@settings(**SETTINGS)
def test_encode_many_matches_encode(g, max_len):
    """Vectorized batch encode is row-identical to per-sequence encode,
    including truncation, PAD fill, and <unk> for OOV tokens."""
    toks = TOK.graph_tokens(g, "ops")
    v = TOK.fit_vocab([toks[: max(len(toks) // 2, 1)]], max_size=4096)
    seqs = [toks, toks[:3], ["never-seen"] * 5, []]
    batch = v.encode_many(seqs, max_len)
    assert batch.shape == (len(seqs), max_len)
    for row, s in zip(batch, seqs):
        np.testing.assert_array_equal(row, v.encode(s, max_len))


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_fusion_advisor_cost_ordering(seed):
    """fuse_elementwise never increases op count; latency oracle agrees
    fused <= unfused (fewer HBM round-trips in the analyzer's model)."""
    from repro.core.service import fuse_elementwise
    rng = np.random.default_rng(seed)
    g = samplers.sample_graph(rng, "resnet")
    f = fuse_elementwise(g)
    f.validate()
    assert len(f.ops) <= len(g.ops)
