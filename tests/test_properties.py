"""Property-based tests (hypothesis) on system invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st, HealthCheck

from repro.core import augment as AUG
from repro.core import tokenizer as TOK
from repro.ir import analyzers, samplers
from repro.launch import hlo_cost as HC

SETTINGS = dict(max_examples=25, deadline=None,
                suppress_health_check=[HealthCheck.too_slow])


@st.composite
def graphs(draw):
    seed = draw(st.integers(0, 2**31 - 1))
    fam = draw(st.sampled_from(sorted(samplers.SAMPLERS)))
    return samplers.sample_graph(np.random.default_rng(seed), fam)


@given(graphs())
@settings(**SETTINGS)
def test_sampled_graphs_always_valid(g):
    g.validate()
    assert len(g.values) == g.n_args + len(g.ops)


@given(graphs())
@settings(**SETTINGS)
def test_analyzer_targets_positive_and_finite(g):
    res = analyzers.analyze(g)
    for k, v in res.items():
        assert np.isfinite(v) and v >= 0, k


@given(graphs(), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_reorder_is_semantic_invariant(g, seed):
    """Topological reorder: same ops, same flops-derived targets; register
    pressure may change (schedule-dependent) but stays within bounds."""
    rng = np.random.default_rng(seed)
    g2 = AUG.reorder_ops(g, rng)
    g2.validate()
    assert sorted(o.opcode for o in g2.ops) == \
        sorted(o.opcode for o in g.ops)
    assert analyzers.valu_utilization(g2) == analyzers.valu_utilization(g)
    assert analyzers.latency_us(g2) == pytest.approx(analyzers.latency_us(g))
    # pressure bounded by sum of all value units (trivial upper bound)
    ub = sum(analyzers._vreg_units(t) for t in g.values)
    assert 0 < analyzers.register_pressure(g2) <= ub


@given(graphs())
@settings(**SETTINGS)
def test_tokenizer_ops_subset_of_operands_mode(g):
    ops = TOK.graph_tokens(g, "ops")
    opnd = TOK.graph_tokens(g, "ops_operands")
    # every opcode token appears in both modes, in the same order
    o1 = [t for t in ops if t.startswith("xpu.")]
    o2 = [t for t in opnd if t.startswith("xpu.")]
    assert o1 == o2
    assert len(opnd) >= len(ops)


@given(graphs(), st.integers(4, 64))
@settings(**SETTINGS)
def test_encode_pads_and_truncates(g, max_len):
    toks = TOK.graph_tokens(g, "ops")
    v = TOK.fit_vocab([toks], max_size=4096)
    ids = v.encode(toks, max_len)
    assert ids.shape == (max_len,)
    assert (ids[min(len(toks), max_len):] == v.token_to_id[TOK.PAD]).all()


@given(st.lists(st.sampled_from(["a", "b", "c", "dd", "ee"]),
                min_size=1, max_size=50))
@settings(**SETTINGS)
def test_vocab_fit_encode_no_oov_on_train_corpus(tokens):
    v = TOK.fit_vocab([tokens], max_size=4096)
    assert v.oov_rate(tokens) == 0.0


@given(st.integers(1, 8), st.integers(1, 8), st.integers(1, 64))
@settings(**SETTINGS)
def test_hlo_shape_bytes(a, b, c):
    total, shapes = HC._shape_info(f"f32[{a},{b},{c}]{{2,1,0}}")
    assert total == a * b * c * 4
    total2, _ = HC._shape_info(f"(f32[{a}], bf16[{b},{c}])")
    assert total2 == a * 4 + b * c * 2


@given(graphs(), st.lists(st.integers(0, 2**31 - 1),
                          min_size=1, max_size=6))
@settings(**SETTINGS)
def test_incremental_struct_key_matches_from_scratch(g, seeds):
    """Incremental hashing invariant: after ANY legal rewrite sequence
    (random rule + site per step, across all registered families —
    fusion, CSE, DCE, recompute, dtype_narrow, unroll), the child's
    memoized/inherited struct_key equals the from-scratch Merkle walk,
    and a bare structural clone (no memos at all) agrees."""
    from repro.ir.graph import Graph
    from repro.opt import rewrites as RW
    rules = RW.default_rules()
    out = g
    for s in seeds:
        rng = np.random.default_rng(s)
        firing = [(r, site) for r in rules for site in r.applicable(out)]
        if not firing:
            break
        r, site = firing[int(rng.integers(0, len(firing)))]
        try:
            out = r.apply(out, site)
        except AssertionError:
            continue                      # illegal here: try next step
        assert out.struct_key() == out.struct_key_fresh()
    clone = Graph(values=list(out.values), n_args=out.n_args,
                  ops=list(out.ops), outputs=list(out.outputs))
    assert clone.struct_key() == out.struct_key()


@given(graphs(), st.integers(4, 64))
@settings(**SETTINGS)
def test_encode_many_matches_encode(g, max_len):
    """Vectorized batch encode is row-identical to per-sequence encode,
    including truncation, PAD fill, and <unk> for OOV tokens."""
    toks = TOK.graph_tokens(g, "ops")
    v = TOK.fit_vocab([toks[: max(len(toks) // 2, 1)]], max_size=4096)
    seqs = [toks, toks[:3], ["never-seen"] * 5, []]
    batch = v.encode_many(seqs, max_len)
    assert batch.shape == (len(seqs), max_len)
    for row, s in zip(batch, seqs):
        np.testing.assert_array_equal(row, v.encode(s, max_len))


@given(st.integers(0, 2**31 - 1), st.integers(1, 12),
       st.sampled_from([(2, 2, 2), (3, 5), (1,)]),
       st.booleans(), st.booleans())
@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_fused_conv_forward_matches_oracle(seed, B, fs_list, all_pad,
                                           bf16):
    """Property parity of the ids-in/predictions-out Pallas kernel vs
    the kernels/ref.py oracle: random ragged masks, optional all-PAD
    rows, batch sizes straddling the bblk tile, every filter mix, both
    dtypes (bf16 at quantization tolerance)."""
    import jax
    import jax.numpy as jnp
    from repro.configs.costmodel import CostModelConfig
    from repro.core import models as CM
    from repro.kernels import ops as KOPS
    from repro.kernels import ref as REF

    cfg = CostModelConfig(
        name="prop", vocab_size=64, max_seq=16, embed_dim=8,
        conv_filters=fs_list, conv_channels=(8,) * len(fs_list),
        fc_dims=(16, 8))
    params = CM.conv_init(jax.random.PRNGKey(seed % 997), cfg,
                          heads=CM.DEFAULT_HEADS)
    rng = np.random.default_rng(seed)
    ids = rng.integers(1, cfg.vocab_size, (B, cfg.max_seq))
    lens = rng.integers(1, cfg.max_seq + 1, (B,))
    ids[np.arange(cfg.max_seq)[None, :] >= lens[:, None]] = 0
    if all_pad:
        ids[rng.integers(0, B)] = 0
    ids = np.asarray(ids, np.int32)
    want = REF.conv_forward_ref(params, ids)
    if bf16:
        params = jax.tree.map(
            lambda a: a.astype(jnp.bfloat16)
            if jnp.issubdtype(a.dtype, jnp.floating) else a, params)
    got = KOPS.conv_forward_apply(params, ids, interpret=True)
    tol = 5e-2 if bf16 else 2e-4
    for t in CM.DEFAULT_HEADS:
        np.testing.assert_allclose(np.asarray(got[t]),
                                   np.asarray(want[t]),
                                   rtol=tol, atol=tol)


@given(st.integers(0, 2**31 - 1), st.integers(1, 10),
       st.integers(2, 24))
@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_lstm_scan_kernel_matches_oracle(seed, B, S):
    """Property parity of the Pallas LSTM recurrence vs the jnp oracle
    over random gate inputs and ragged masks (all-pad rows keep a zero
    carry)."""
    import jax.numpy as jnp
    from repro.kernels import ref as REF
    from repro.kernels.lstm_scan import lstm_scan_fused

    H = 8
    rng = np.random.default_rng(seed)
    xw = jnp.asarray(rng.normal(size=(B, S, 4 * H)) * 0.5, jnp.float32)
    mask = jnp.asarray(rng.random((B, S)) < 0.7, jnp.float32)
    wh = jnp.asarray(rng.normal(size=(H, 4 * H)) * 0.3, jnp.float32)
    got = lstm_scan_fused(xw, mask, wh, bblk=4, interpret=True)
    want = REF.lstm_scan_ref(xw, mask, wh)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    dead = np.asarray(mask).sum(1) == 0
    assert (np.abs(np.asarray(got)[dead]).max(initial=0.0)) == 0.0


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_fusion_advisor_cost_ordering(seed):
    """fuse_elementwise never increases op count; latency oracle agrees
    fused <= unfused (fewer HBM round-trips in the analyzer's model)."""
    from repro.core.service import fuse_elementwise
    rng = np.random.default_rng(seed)
    g = samplers.sample_graph(rng, "resnet")
    f = fuse_elementwise(g)
    f.validate()
    assert len(f.ops) <= len(g.ops)
