"""TrainEngine: the unified bucketed, fault-tolerant step loop.

Covers the refactor's contract: bucketed (batch_max) training reaches the
same eval metrics as max_seq-padded training on the same seed; the id
storage layout (dense vs bucket-grouped) does not change training at all;
kill-and-resume mid-run reproduces the uninterrupted run's final params
(checkpoint + loader cursor); TrainResult.stats is populated; and the
train_model compatibility wrapper still drives the engine.
"""
import jax
import numpy as np
import pytest

from repro.configs import COSTMODEL_SMALL
from repro.core import trainer as TR
from repro.core.service import pad_slack
from repro.data import pipeline as PIPE
from repro.ir import dataset as DS


@pytest.fixture(scope="module")
def small_dataset():
    return DS.build_dataset(300, mode="ops", max_seq=96, vocab_size=512,
                            augment_factor=2, seed=1)


@pytest.fixture(scope="module")
def split(small_dataset):
    return small_dataset.split(0.1)


def _param_diff(a, b) -> float:
    return max(float(np.abs(np.asarray(x) - np.asarray(y)).max())
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# -------------------------------------------------------------- bucketing
@pytest.mark.parametrize("kind", ["conv1d", "fc"])
def test_bucketed_training_parity(kind, split):
    """Engine default (batch_max bucketing) must reach eval metrics within
    tolerance of max_seq padding on the same seed — for conv1d (whose
    bucket widths include the pad-slack rule) and a masking family.

    Per-step gradients are width-invariant to ~1e-10 (the same pad-slack
    argument serving relies on); over a few hundred Adam steps that
    amplifies into small param drift, so we compare eval metrics, not
    params."""
    tr, te = split
    res_b = TR.TrainEngine(kind, COSTMODEL_SMALL, "register_pressure",
                           steps=120, batch_size=64, seed=0,
                           bucketed=True).fit(tr)
    res_p = TR.TrainEngine(kind, COSTMODEL_SMALL, "register_pressure",
                           steps=120, batch_size=64, seed=0,
                           bucketed=False).fit(tr)
    mb = TR.evaluate(kind, COSTMODEL_SMALL, res_b, te, "register_pressure")
    mp = TR.evaluate(kind, COSTMODEL_SMALL, res_p, te, "register_pressure")
    assert abs(mb["rmse_norm"] - mp["rmse_norm"]) <= \
        0.10 * mp["rmse_norm"] + 0.02, (mb["rmse_norm"], mp["rmse_norm"])


def test_batch_max_width_contract(split):
    """batch_max mode: identical batch composition to unbucketed loading,
    with each batch's ids at exactly the largest member's bucket (never
    the global max_seq unless a member needs it)."""
    tr, _ = split
    eng = TR.TrainEngine("conv1d", COSTMODEL_SMALL, "register_pressure",
                         batch_size=32, seed=0)
    bucket_by = eng.bucket_assignments(tr)
    assert len(np.unique(bucket_by)) > 1, "corpus has one bucket only"
    y, _ = DS.normalize_targets(tr.targets["register_pressure"])
    loader = eng.make_loader(tr, y.astype(np.float32))
    plain = PIPE.Loader(PIPE.ArraySource(ids=tr.ids, y=y,
                                         row=np.arange(tr.n)), 32, seed=0)
    it, it_ref = iter(loader), iter(plain)
    for _ in range(loader.steps_per_epoch()):
        b, ref = next(it), next(it_ref)
        np.testing.assert_array_equal(b["y"], ref["y"])  # same composition
        want = int(bucket_by[ref["row"]].max())
        assert b["ids"].shape[1] == want, (b["ids"].shape, want)
        np.testing.assert_array_equal(
            b["ids"], ref["ids"][:, :b["ids"].shape[1]])


def test_homogeneous_mode_single_bucket_batches(split):
    tr, _ = split
    slack = pad_slack("conv1d", COSTMODEL_SMALL)
    buckets = DS.default_buckets(tr.max_seq)
    bucket_by = DS.bucket_lengths(tr.get_seq_lens(), buckets, slack)
    src = PIPE.ArraySource(ids=tr.ids, y=np.arange(tr.n, dtype=np.int64))
    ld = PIPE.Loader(src, 32, seed=0, bucket_by=bucket_by,
                     bucket_mode="homogeneous", drop_remainder=False)
    it = iter(ld)
    seen = []
    for _ in range(ld.steps_per_epoch()):
        b = next(it)
        rows = b["y"]
        width = b["ids"].shape[1]
        # one planned bucket per batch; small buckets merge upward, so
        # every member's own bucket fits under the batch width
        assert width in set(bucket_by.tolist())
        assert bucket_by[rows].max() <= width
        seen.extend(rows.tolist())
    assert sorted(seen) == list(range(tr.n))   # full coverage, no dupes


def test_dataset_layout_does_not_change_training(split):
    """Bucket-grouped id storage is an exact drop-in for dense storage."""
    tr, _ = split
    dsb = DS.build_dataset(300, mode="ops", max_seq=96, vocab_size=512,
                           augment_factor=2, seed=1, layout="bucketed")
    trb, _ = dsb.split(0.1)
    np.testing.assert_array_equal(tr.ids, trb.dense_ids())
    a = TR.TrainEngine("conv1d", COSTMODEL_SMALL, "register_pressure",
                       steps=40, batch_size=64, seed=0).fit(tr)
    b = TR.TrainEngine("conv1d", COSTMODEL_SMALL, "register_pressure",
                       steps=40, batch_size=64, seed=0).fit(trb)
    assert _param_diff(a.params, b.params) == 0.0


# ---------------------------------------------------------- fault tolerance
def test_engine_kill_and_resume_reproduces_run(split, tmp_path):
    """Kill mid-run; a fresh engine restores the last committed checkpoint
    (params + optimizer + loader cursor) and must land on the
    uninterrupted run's final params."""
    tr, _ = split
    kw = dict(steps=40, batch_size=32, seed=3)
    full = TR.TrainEngine("conv1d", COSTMODEL_SMALL, "valu_utilization",
                          **kw).fit(tr)

    class Kill(Exception):
        pass

    def killer(step, dt):
        if step == 17:
            raise Kill()

    d = str(tmp_path / "ck")
    with pytest.raises(Kill):
        TR.TrainEngine("conv1d", COSTMODEL_SMALL, "valu_utilization",
                       ckpt_dir=d, save_every=10, **kw).fit(
                           tr, on_step=killer)
    resumed = TR.TrainEngine("conv1d", COSTMODEL_SMALL, "valu_utilization",
                             ckpt_dir=d, save_every=10, **kw).fit(tr)
    assert resumed.stats["steps"] == 30.0   # resumed from step 10
    for a, b in zip(jax.tree.leaves(full.params),
                    jax.tree.leaves(resumed.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)


def test_engine_multihead_with_compression_and_ckpt(split, tmp_path):
    """The full substrate in one run: multi-head joint training, int8
    error-feedback grad compression, checkpointing — through the one
    engine loop."""
    tr, te = split
    heads = ("register_pressure", "latency_us")
    res = TR.TrainEngine("fc", COSTMODEL_SMALL, heads, steps=60,
                         batch_size=64, seed=0, compress_grads=True,
                         ckpt_dir=str(tmp_path / "ck")).fit(tr)
    assert res.heads == heads
    m = TR.evaluate("fc", COSTMODEL_SMALL, res, te)
    assert set(m) == set(heads)
    for t in heads:
        assert np.isfinite(m[t]["rmse_norm"])


# ----------------------------------------------------------------- results
def test_train_result_stats_populated(split):
    tr, _ = split
    res = TR.train_model("fc", COSTMODEL_SMALL, tr, "latency_us",
                         steps=30, batch_size=64, log_every=10)
    for k in ["final_loss", "steps", "wall_time_s", "steps_per_s"]:
        assert k in res.stats, res.stats
    assert res.stats["steps"] == 30.0
    assert res.stats["steps_per_s"] > 0
    assert np.isfinite(res.stats["final_loss"])
    assert res.history and res.history[-1][0] == 30
