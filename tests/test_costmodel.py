"""Cost-model core tests: tokenizer, analyzers, dataset, training conv."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import COSTMODEL_SMALL
from repro.core import augment as AUG
from repro.core import models as CM
from repro.core import tokenizer as TOK
from repro.core import trainer as TR
from repro.ir import analyzers, dataset as DS, printer, samplers
from repro.ir.graph import Graph, Tensor


@pytest.fixture(scope="module")
def small_dataset():
    return DS.build_dataset(300, mode="ops", max_seq=96, vocab_size=512,
                            augment_factor=2, seed=1)


def test_samplers_produce_valid_graphs(rng):
    for fam in samplers.SAMPLERS:
        for _ in range(5):
            g = samplers.sample_graph(rng, fam)
            g.validate()
            assert g.ops and g.outputs


def test_printer_emits_mlir(rng):
    g = samplers.sample_graph(rng, "bert")
    text = printer.to_mlir(g)
    assert text.startswith("func.func @")
    assert '"xpu.matmul"' in text
    assert "tensor<" in text and "return" in text


def test_analyzers_deterministic_and_positive(rng):
    g = samplers.sample_graph(rng, "resnet")
    a1, a2 = analyzers.analyze(g), analyzers.analyze(g)
    assert a1 == a2
    assert a1["register_pressure"] > 0
    assert a1["valu_utilization"] > 0
    assert a1["latency_us"] > 0


def test_register_pressure_liveness():
    """Hand-built graph: chain vs fan-out have different pressure."""
    t = Tensor((8, 1024))  # 1 vreg unit... 8*1024/1024 = 8 units
    chain = Graph()
    a = chain.add_arg(t)
    x = chain.add_op("relu", [a], t)
    x = chain.add_op("relu", [x], t)
    chain.outputs = [x]
    fan = Graph()
    a = fan.add_arg(t)
    x1 = fan.add_op("relu", [a], t)
    x2 = fan.add_op("relu", [a], t)
    x3 = fan.add_op("add", [x1, x2], t)
    fan.outputs = [x3]
    assert analyzers.register_pressure(fan) > \
        analyzers.register_pressure(chain)


def test_tokenizer_modes(rng):
    g = samplers.sample_graph(rng, "unet")
    ops = TOK.graph_tokens(g, "ops")
    opnd = TOK.graph_tokens(g, "ops_operands")
    assert len(opnd) > len(ops)  # paper: ~4x longer
    assert any(t.startswith("xpu.") for t in ops)
    assert not any(t.startswith("%") for t in ops)   # operands dropped
    assert any(t.startswith("%") for t in opnd)


def test_vocab_encode_oov():
    v = TOK.fit_vocab([["xpu.add", "8x8xf32"]], max_size=16)
    ids = v.encode(["xpu.add", "UNSEEN_TOKEN", "8x8xf32"], max_len=8)
    assert ids[1] == v.token_to_id[TOK.UNK]
    assert ids[0] == v.token_to_id["xpu.add"]
    assert v.oov_rate(["xpu.add", "zzz"]) == 0.5


def test_tokenize_raw_mlir_text():
    txt = ('%3 = "stablehlo.dot_general"(%1, %2) : '
           '(tensor<8x64xf32>, tensor<64x32xf32>) -> tensor<8x32xf32>')
    toks = TOK.tokenize_text(txt)
    assert "stablehlo.dot_general" in toks
    assert "8x64xf32" in toks


def test_augment_reorder_preserves_semantics(rng):
    g = samplers.sample_graph(rng, "ssd")
    g2 = AUG.reorder_ops(g, rng)
    g2.validate()
    assert len(g2.ops) == len(g.ops)
    # vALU utilization is schedule-invariant
    assert analyzers.valu_utilization(g) == analyzers.valu_utilization(g2)
    assert analyzers.latency_us(g) == pytest.approx(analyzers.latency_us(g2))


def test_dataset_roundtrip(tmp_path, small_dataset):
    p = str(tmp_path / "ds.npz")
    small_dataset.save(p)
    ds2 = DS.CostDataset.load(p)
    np.testing.assert_array_equal(ds2.ids, small_dataset.ids)
    assert ds2.vocab.size == small_dataset.vocab.size
    for k in small_dataset.targets:
        np.testing.assert_allclose(ds2.targets[k],
                                   small_dataset.targets[k])


def test_models_forward_shapes(small_dataset):
    ids = jnp.asarray(small_dataset.ids[:4, :COSTMODEL_SMALL.max_seq])
    for kind in CM.MODELS:
        init_fn, apply_fn, _ = CM.get_model(kind)
        params = init_fn(jax.random.PRNGKey(0), COSTMODEL_SMALL)
        out = apply_fn(params, ids)
        assert out.shape == (4,)
        assert bool(jnp.isfinite(out).all())


def test_training_reduces_loss(small_dataset):
    tr, _ = small_dataset.split(0.1)
    res = TR.train_model("conv1d", COSTMODEL_SMALL, tr,
                         "valu_utilization", steps=120, batch_size=64,
                         log_every=20)
    losses = [v for _, v in res.history]
    assert losses[-1] < losses[0]


def test_normalization_roundtrip():
    y = np.abs(np.random.default_rng(0).normal(size=100) * 50) + 1
    n, stats = DS.normalize_targets(y)
    back = DS.denormalize(n, stats)
    np.testing.assert_allclose(back, y, rtol=1e-4)
