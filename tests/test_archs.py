"""Per-architecture smoke tests: reduced config, one forward + train step +
decode step on CPU; asserts output shapes and no NaNs. (Full configs are
exercised only via the dry-run, per the brief.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.models import model as MODEL
from repro.models import steps as STEPS
from repro.optim import adamw

B, S = 2, 16


def _batch(cfg, with_labels=True):
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(1, cfg.vocab, size=(B, S)), jnp.int32)}
    if with_labels:
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab, size=(B, S)), jnp.int32)
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.vision_patches, cfg.d_model)) * 0.02,
            jnp.bfloat16)
    if cfg.frontend == "audio":
        batch["frame_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)) * 0.02,
            jnp.bfloat16)
    return batch


@pytest.fixture(scope="module")
def reduced_state():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = get_arch(name).reduced()
            params = MODEL.init_params(jax.random.PRNGKey(0), cfg)
            cache[name] = (cfg, params)
        return cache[name]
    return get


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_forward_shapes_and_finite(name, reduced_state):
    cfg, params = reduced_state(name)
    logits, aux = MODEL.forward(params, cfg, _batch(cfg))
    vpad = MODEL.padded_vocab(cfg)
    assert logits.shape == (B, S, vpad)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_train_step_decreases_loss_and_finite(name, reduced_state):
    cfg, params = reduced_state(name)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, total_steps=5, warmup_steps=0)
    step = jax.jit(STEPS.make_train_step(cfg, opt_cfg))
    state = adamw.init_state(params)
    batch = _batch(cfg)
    p, state, m1 = step(params, state, batch)
    p, state, m2 = step(p, state, batch)
    p, state, m3 = step(p, state, batch)
    assert np.isfinite(float(m1["loss"]))
    assert float(m3["loss"]) < float(m1["loss"])  # same batch: must improve
    for leaf in jax.tree.leaves(p):
        assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all())


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_decode_step_runs_and_is_finite(name, reduced_state):
    cfg, params = reduced_state(name)
    cache = MODEL.init_cache(cfg, B, 32)
    step = jax.jit(STEPS.make_decode_step(cfg))
    tok = jnp.ones((B, 1), jnp.int32)
    for i in range(3):
        tok, cache = step(params, cache, tok, jnp.int32(i))
    assert tok.shape == (B, 1)
    assert int(tok.min()) >= 0 and int(tok.max()) < cfg.vocab


@pytest.mark.parametrize("name", ["qwen3-0.6b", "starcoder2-3b",
                                  "granite-moe-1b-a400m"])
def test_prefill_decode_consistency(name, reduced_state):
    """Greedy next token from prefill logits == decode path next token.

    MoE archs: capacity dropping is chunk-size dependent (prefill chunks
    vs per-token decode), so use a dropless capacity factor here."""
    import dataclasses
    cfg, params = reduced_state(name)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    batch = _batch(cfg, with_labels=False)
    logits, _ = MODEL.forward(params, cfg, batch)
    vpad = MODEL.padded_vocab(cfg)
    col = jnp.arange(vpad)
    masked = jnp.where(col[None, None] < cfg.vocab,
                       logits.astype(jnp.float32), -1e30)
    want = jnp.argmax(masked[:, -1], axis=-1)

    cache = MODEL.init_cache(cfg, B, S + 4, kv_dtype=jnp.float32)
    step = jax.jit(STEPS.make_decode_step(cfg))
    toks = batch["tokens"]
    for i in range(S):
        tok, cache = step(params, cache, toks[:, i:i + 1], jnp.int32(i))
    np.testing.assert_array_equal(np.asarray(tok[:, 0]), np.asarray(want))


def test_param_counts_match_init():
    """Analytic param_count ~= actual init sizes (within vocab padding)."""
    for name in ["qwen3-0.6b", "qwen3-1.7b", "starcoder2-3b"]:
        cfg = get_arch(name)
        abs_p = STEPS.abstract_params(cfg)
        actual = sum(int(np.prod(x.shape))
                     for x in jax.tree.leaves(abs_p))
        expected = cfg.param_count()
        assert abs(actual - expected) / expected < 0.02, \
            f"{name}: init {actual} vs analytic {expected}"


def test_full_configs_are_exact():
    """Spot-check the published numbers are preserved."""
    q = get_arch("qwen1.5-32b")
    assert (q.n_layers, q.d_model, q.n_heads, q.n_kv_heads, q.d_ff,
            q.vocab) == (64, 5120, 40, 40, 27392, 152064)
    assert q.qkv_bias
    p = get_arch("phi3.5-moe-42b-a6.6b")
    assert p.moe.n_experts == 16 and p.moe.top_k == 2
    g = get_arch("granite-moe-1b-a400m")
    assert g.moe.n_experts == 32 and g.moe.top_k == 8
    j = get_arch("jamba-v0.1-52b")
    assert j.hybrid.period == 8 and j.moe.moe_every == 2
    x = get_arch("xlstm-125m")
    assert x.d_ff == 0 and x.n_heads == 4


class _StubRules:
    """Pretends to be a 16-way-model ShardingRules: identity constraints,
    triggers the head-padding path in attention."""
    axis_sizes = {"data": 16, "model": 16}
    pad_attention_heads = True

    def constrain(self, x, *axes):
        return x

    def divisible(self, dim, axis):
        n = self.axis_sizes.get(axis, 1)
        return n > 1 and dim % n == 0


def test_head_padding_is_identity():
    """Padded-head attention (56->64 style) must equal unpadded attention."""
    import jax
    import jax.numpy as jnp
    from repro.models import layers as L
    from repro.configs import get_arch
    import dataclasses
    cfg = dataclasses.replace(get_arch("llava-next-34b").reduced(),
                              n_heads=6, n_kv_heads=2, head_dim=8,
                              d_model=48)
    p = L.attention_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 48))
    pos = jnp.broadcast_to(jnp.arange(16), (2, 16))
    out_ref, _ = L.attention_apply(p, x, cfg, positions=pos, rules=None,
                                   cdt=jnp.float32)
    out_pad, _ = L.attention_apply(p, x, cfg, positions=pos,
                                   rules=_StubRules(), cdt=jnp.float32)
    np.testing.assert_allclose(np.asarray(out_pad), np.asarray(out_ref),
                               rtol=1e-5, atol=1e-5)
