"""Multi-target encoder/head architecture: models, training, serving.

Covers the refactor's contract: every family exposes a shared encode +
per-target heads; joint training is competitive with single-head; the
unified service is cache-consistent, LRU-bounded, and bucket-invariant;
multi-head params roundtrip through the checkpoint layer.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs import COSTMODEL_SMALL
from repro.core import models as CM
from repro.core import trainer as TR
from repro.core.service import CostModelService, default_buckets
from repro.ir import dataset as DS, samplers
from repro.runtime.sharding import ShardingRules, tree_shardings

HEADS = CM.DEFAULT_HEADS


@pytest.fixture(scope="module")
def small_dataset():
    return DS.build_dataset(300, mode="ops", max_seq=96, vocab_size=512,
                            augment_factor=2, seed=1)


# ----------------------------------------------------------------- models
def test_multihead_forward_shapes(small_dataset):
    ids = jnp.asarray(small_dataset.ids[:4, :COSTMODEL_SMALL.max_seq])
    for kind in CM.MODELS:
        init_fn, apply_fn, _ = CM.get_model(kind)
        params = init_fn(jax.random.PRNGKey(0), COSTMODEL_SMALL, heads=HEADS)
        assert CM.model_heads(params) == HEADS
        out = apply_fn(params, ids)
        assert set(out) == set(HEADS)
        for t in HEADS:
            assert out[t].shape == (4,)
            assert bool(jnp.isfinite(out[t]).all())


def test_encode_is_shared_across_heads(small_dataset):
    """Heads are linear readouts of the same features: encode() + head
    weights reproduces apply() exactly."""
    ids = jnp.asarray(small_dataset.ids[:4, :COSTMODEL_SMALL.max_seq])
    for kind in CM.MODELS:
        init_fn, apply_fn, _ = CM.get_model(kind)
        params = init_fn(jax.random.PRNGKey(1), COSTMODEL_SMALL, heads=HEADS)
        feats = CM.get_encoder(kind)(params, ids)
        out = apply_fn(params, ids)
        for t in HEADS:
            manual = (feats @ params["heads"][t]["w"]
                      + params["heads"][t]["b"])[..., 0]
            np.testing.assert_allclose(np.asarray(out[t]),
                                       np.asarray(manual), rtol=1e-6)


def test_multihead_axes_match_params():
    """*_axes(heads=...) must stay zip-compatible with the param tree for
    the sharded 100M driver (tree_shardings asserts rank per leaf)."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = ShardingRules(mesh)
    for kind in CM.MODELS:
        init_fn, _, axes_fn = CM.get_model(kind)
        for heads in (None, HEADS):
            kw = {"heads": heads} if heads else {}
            params = init_fn(jax.random.PRNGKey(0), COSTMODEL_SMALL, **kw)
            axes = axes_fn(COSTMODEL_SMALL, heads=heads) if heads \
                else axes_fn(COSTMODEL_SMALL)
            shapes = jax.tree.map(lambda x: x.shape, params)
            shardings = tree_shardings(rules, axes, shapes)
            assert jax.tree.structure(params) == \
                jax.tree.structure(shardings)


def test_single_head_path_unchanged(small_dataset):
    """No heads kwarg -> legacy scalar-output layout."""
    ids = jnp.asarray(small_dataset.ids[:4, :COSTMODEL_SMALL.max_seq])
    for kind in CM.MODELS:
        init_fn, apply_fn, _ = CM.get_model(kind)
        params = init_fn(jax.random.PRNGKey(0), COSTMODEL_SMALL)
        assert CM.model_heads(params) is None
        out = apply_fn(params, ids)
        assert out.shape == (4,)


# --------------------------------------------------------------- training
def test_joint_training_comparable_to_single_head(small_dataset):
    """Joint multi-target training reaches per-target accuracy in the same
    ballpark as dedicated single-head models on a small dataset."""
    tr, te = small_dataset.split(0.1)
    # the joint model learns three tasks: give it a larger step budget
    # (still < 3x the single-task budget — the encoder is shared)
    multi = TR.train_model("conv1d", COSTMODEL_SMALL, tr, HEADS,
                           steps=400, batch_size=64, lr=2e-3, seed=0)
    assert multi.heads == HEADS
    assert set(multi.norm_stats) == set(HEADS)
    multi_metrics = TR.evaluate("conv1d", COSTMODEL_SMALL, multi, te)
    for target in HEADS:
        single = TR.train_model("conv1d", COSTMODEL_SMALL, tr, target,
                                steps=220, batch_size=64, lr=2e-3, seed=0)
        sm = TR.evaluate("conv1d", COSTMODEL_SMALL, single, te, target)
        mm = multi_metrics[target]
        # comparable = within 2x normalized RMSE + small absolute slack
        assert mm["rmse_norm"] <= 2.0 * sm["rmse_norm"] + 0.25, \
            (target, mm["rmse_norm"], sm["rmse_norm"])
    # joint loss decreased over training
    losses = [v for _, v in multi.history]
    assert losses[-1] < losses[0]


def test_evaluate_single_target_view_of_multihead(small_dataset):
    tr, te = small_dataset.split(0.1)
    res = TR.train_model("fc", COSTMODEL_SMALL, tr, HEADS,
                         steps=60, batch_size=64)
    per = TR.evaluate("fc", COSTMODEL_SMALL, res, te)
    one = TR.evaluate("fc", COSTMODEL_SMALL, res, te, "latency_us")
    assert one == per["latency_us"]


# ------------------------------------------------------------- checkpoint
def test_multihead_checkpoint_roundtrip(tmp_path):
    params = CM.conv_init(jax.random.PRNGKey(0), COSTMODEL_SMALL,
                          heads=HEADS)
    stats = {t: {"mu": float(i), "sigma": 1.0 + i}
             for i, t in enumerate(HEADS)}
    ckpt.save(str(tmp_path), 7, params,
              extra={"norm_stats": stats, "heads": list(HEADS)})
    like = CM.conv_init(jax.random.PRNGKey(1), COSTMODEL_SMALL, heads=HEADS)
    restored, step, extra = ckpt.restore(str(tmp_path), like, verify=True)
    assert step == 7
    assert extra["norm_stats"] == stats and tuple(extra["heads"]) == HEADS
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_checkpoint_rejects_head_layout_drift(tmp_path):
    single = CM.conv_init(jax.random.PRNGKey(0), COSTMODEL_SMALL)
    ckpt.save(str(tmp_path), 1, single)
    multi_like = CM.conv_init(jax.random.PRNGKey(0), COSTMODEL_SMALL,
                              heads=HEADS)
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), multi_like)


# ---------------------------------------------------------------- serving
@pytest.fixture(scope="module")
def unified_service(small_dataset):
    tr, _ = small_dataset.split(0.1)
    res = TR.train_model("conv1d", COSTMODEL_SMALL, tr, HEADS,
                         steps=80, batch_size=64)
    return CostModelService(
        "conv1d", COSTMODEL_SMALL, res.params, small_dataset.vocab,
        res.norm_stats, mode="ops", max_seq=96)


def test_service_cached_vs_fresh_identical(unified_service, small_dataset):
    svc = unified_service
    rng = np.random.default_rng(7)
    gs = [samplers.sample_graph(rng) for _ in range(6)]
    first = svc.predict_all(gs)          # fills cache
    second = svc.predict_all(gs)         # served from cache
    fresh = CostModelService(
        "conv1d", COSTMODEL_SMALL, svc.params, small_dataset.vocab,
        svc.norm_stats, mode="ops", max_seq=96)
    uncached = fresh.predict_all(gs)
    for t in HEADS:
        np.testing.assert_array_equal(first[t], second[t])
        np.testing.assert_array_equal(first[t], uncached[t])


def test_lru_eviction_bounds_cache(unified_service, small_dataset):
    svc = CostModelService(
        "conv1d", COSTMODEL_SMALL, unified_service.params,
        small_dataset.vocab, unified_service.norm_stats,
        mode="ops", max_seq=96, cache_size=8)
    rng = np.random.default_rng(8)
    gs = [samplers.sample_graph(rng) for _ in range(30)]
    n_unique = len({svc._encode(g).tobytes() for g in gs})
    svc.predict_all(gs)
    assert len(svc._cache) == min(8, n_unique)
    svc.predict_all(gs[-4:])             # refresh recency for these four
    keys = set(svc._cache)
    svc.predict_all(gs[-4:])             # pure hits: no eviction, no growth
    assert set(svc._cache) == keys
    assert len(svc._cache) <= 8


def test_bucketed_matches_unbucketed(unified_service, small_dataset):
    """Padding to the bucket instead of max_seq must not change
    predictions — every family masks padding."""
    rng = np.random.default_rng(9)
    gs = [samplers.sample_graph(rng) for _ in range(8)]
    for kind in CM.MODELS:
        init_fn, _, _ = CM.get_model(kind)
        params = init_fn(jax.random.PRNGKey(2), COSTMODEL_SMALL, heads=HEADS)
        stats = {t: {"mu": 0.0, "sigma": 1.0} for t in HEADS}
        # max_seq = cfg.max_seq: the xformer's pos table bounds seq length
        def mk(buckets):
            return CostModelService(
                kind, COSTMODEL_SMALL, params, small_dataset.vocab, stats,
                mode="ops", max_seq=COSTMODEL_SMALL.max_seq,
                buckets=buckets)
        bucketed, unbucketed = mk(None), mk((COSTMODEL_SMALL.max_seq,))
        assert len(bucketed.buckets) > 1
        pb = bucketed.predict_all(gs)
        pu = unbucketed.predict_all(gs)
        for t in HEADS:
            np.testing.assert_allclose(pb[t], pu[t], rtol=1e-5, atol=1e-6,
                                       err_msg=f"{kind}/{t}")


def test_predict_all_empty_batch(unified_service):
    out = unified_service.predict_all([])
    assert set(out) == set(HEADS)
    for v in out.values():
        assert v.shape == (0,)


def test_named_single_head_rejects_mismatched_target(small_dataset):
    """A service that KNOWS it predicts latency must not answer a
    register-pressure request with latency numbers."""
    params = CM.conv_init(jax.random.PRNGKey(0), COSTMODEL_SMALL)
    svc = CostModelService(
        "conv1d", COSTMODEL_SMALL, params, small_dataset.vocab,
        {"mu": 0.0, "sigma": 1.0}, mode="ops", max_seq=96,
        target="latency_us")
    rng = np.random.default_rng(11)
    g = samplers.sample_graph(rng)
    assert svc.predict(g, "latency_us") == svc.predict(g)
    with pytest.raises(KeyError):
        svc.predict(g, "register_pressure")


def test_unroll_advisor_refuses_single_head(small_dataset):
    """A one-head service cannot judge register feasibility: refuse,
    don't silently reuse the latency head."""
    from repro.core.service import UnrollAdvisor
    params = CM.conv_init(jax.random.PRNGKey(0), COSTMODEL_SMALL)
    svc = CostModelService(
        "conv1d", COSTMODEL_SMALL, params, small_dataset.vocab,
        {"mu": 0.0, "sigma": 1.0}, mode="ops", max_seq=96)
    rng = np.random.default_rng(12)
    g = samplers.sample_graph(rng)
    with pytest.raises(ValueError, match="distinct"):
        UnrollAdvisor(svc).advise(g)


def test_kernel_tower_multihead_parity(small_dataset):
    """conv_tower_apply stays a drop-in for conv_apply in both layouts."""
    from repro.kernels import ops as KOPS
    ids = jnp.asarray(small_dataset.ids[:4, :COSTMODEL_SMALL.max_seq])
    params = CM.conv_init(jax.random.PRNGKey(3), COSTMODEL_SMALL,
                          heads=HEADS)
    got = KOPS.conv_tower_apply(params, ids, use_kernel=False)
    want = CM.conv_apply(params, ids)
    assert set(got) == set(HEADS)
    for t in HEADS:
        np.testing.assert_allclose(np.asarray(got[t]), np.asarray(want[t]),
                                   rtol=1e-5, atol=1e-6)


def test_default_buckets_ladder():
    assert default_buckets(256) == (32, 64, 128, 256)
    assert default_buckets(96) == (32, 64, 96)
    assert default_buckets(16) == (16,)
