"""Real-MLIR front door: tolerant ingestion + OOV-robust tokenization
+ predict_text end to end.

Covers the never-raises contract (structured IngestError for any
bytes/str input — seeded fuzz corpus of >= 200 mutations plus a
hypothesis property over arbitrary byte-level damage), the parser on
printer round trips and hand-written StableHLO/affine, the unk-shard +
byte-fallback vocab machinery (deterministic across processes, legacy
vocabs bit-unchanged), the ServiceSpec wire round trip of the vocab
mode, arch-corpus acceptance (every lowered per-layer subgraph of >= 5
real architectures predicts with zero collapse onto bare <unk>), and
service/server prediction parity on ingested text."""
from __future__ import annotations

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                     # container lacks hypothesis;
    HAVE_HYPOTHESIS = False             # CI installs it

    def given(*a, **k):                 # noqa: D103 - stub decorators
        return lambda f: pytest.mark.skip("hypothesis not installed")(f)

    def settings(*a, **k):
        return lambda f: f

    class st:                           # noqa: N801
        @staticmethod
        def binary(**k):
            return None

        @staticmethod
        def integers(*a, **k):
            return None

        @staticmethod
        def data():
            return None

from repro.configs.costmodel import CostModelConfig
from repro.core import models as CM
from repro.core import tokenizer as TOK
from repro.core.server import CostModelServer
from repro.core.service import CostModelService
from repro.ir import frontdoor as FD
from repro.ir import printer, samplers
from repro.ir import stablehlo as SH
from repro.serving import ServiceSpec

CFG = CostModelConfig(name="fd-test", vocab_size=1024, max_seq=256,
                      embed_dim=16, conv_channels=(16,) * 2,
                      fc_dims=(32,))
ARCHS5 = ("qwen3-0.6b", "xlstm-125m", "whisper-small",
          "granite-moe-1b-a400m", "starcoder2-3b")
SH_TEXT = SH.lower_arch_corpus(["qwen3-0.6b"], seq=4)[0][2]


@pytest.fixture(scope="module")
def corpus():
    """(arch, layer, text) rows across >= 5 real architectures."""
    return SH.lower_arch_corpus(list(ARCHS5), seq=8)


@pytest.fixture(scope="module")
def service():
    rng = np.random.default_rng(7)
    seqs = [TOK.graph_tokens(samplers.sample_graph(rng), "ops")
            for _ in range(16)]
    vocab = TOK.extend_vocab_oov(TOK.fit_vocab(seqs, max_size=600),
                                 n_unk_buckets=32, byte_fallback=True,
                                 max_size=CFG.vocab_size)
    params = CM.conv_init(jax.random.PRNGKey(0), CFG,
                          heads=CM.DEFAULT_HEADS)
    stats = {t: {"mu": 0.2, "sigma": 1.3} for t in CM.DEFAULT_HEADS}
    return CostModelService("conv1d", CFG, params, vocab, stats,
                            mode="ops", max_seq=256)


# ------------------------------------------------------------- parser
def test_parse_mlir_recovers_structure():
    text = """
module {
  func.func @f(%arg0: tensor<8x64xf32>, %arg1: tensor<64x64xf32>)
      -> tensor<8x64xf32> {
    %0 = stablehlo.dot_general %arg0, %arg1 : tensor<8x64xf32>
    %1 = stablehlo.maximum %0, %0 : tensor<8x64xf32>
    return %1 : tensor<8x64xf32>
  }
}
"""
    g = FD.parse_mlir(text)
    assert g is not None
    g.validate()
    opcodes = [op.opcode for op in g.ops]
    assert "matmul" in opcodes          # dot_general mapped
    assert "max" in opcodes or "maximum" in opcodes
    # operand edge %1 <- %0 survived
    assert any(op.operands for op in g.ops)


def test_printer_roundtrip_structural():
    """Our own printer's output re-ingests structurally: same op count
    and opcode multiset (attrs are dropped by the parser, so struct
    keys may differ — structure must not)."""
    rng = np.random.default_rng(3)
    for fam in ["bert", "resnet"]:
        g = samplers.sample_graph(rng, fam)
        res = FD.ingest(printer.to_mlir(g))
        assert isinstance(res, FD.IngestResult)
        assert res.graph is not None
        assert res.n_ops == len(g.ops)
        assert sorted(o.opcode for o in res.graph.ops) == \
            sorted(o.opcode for o in g.ops)


def test_affine_example_ingests():
    res = FD.ingest(FD.AFFINE_EXAMPLE)
    assert isinstance(res, FD.IngestResult)
    assert "affine" in res.dialects
    assert len(res.tokens) > 10         # loop nests lex to real content


def test_ingest_error_taxonomy():
    assert FD.ingest(12345).stage == "empty"
    assert FD.ingest("").stage == "empty"
    assert FD.ingest("   \n\t ").stage == "empty"
    err = FD.ingest(None)
    assert isinstance(err, FD.IngestError)
    assert err.stage == "empty"


def test_ingest_accepts_bytes_and_mojibake():
    res = FD.ingest(b"%0 = stablehlo.add %a, %b : tensor<4xf32>\xff\xfe")
    assert isinstance(res, (FD.IngestResult, FD.IngestError))


# ------------------------------------------------------ OOV machinery
def _base_vocab(**kw):
    return TOK.fit_vocab([["xpu.matmul", "(8,8)f32", "xpu.add"]],
                         max_size=600, **kw)


def test_unk_shards_deterministic_across_instances():
    va = _base_vocab(n_unk_buckets=8)
    vb = TOK.Vocab(dict(va.token_to_id), n_unk_buckets=8)
    toks = ["totally_unseen_token_%d__________________" % i
            for i in range(20)]
    np.testing.assert_array_equal(va.encode(toks, 32),
                                  vb.encode(toks, 32))
    ids = va.encode(toks, 32)
    assert va.unk_fraction(ids) == 0.0  # sharded, not collapsed
    # shard ids really are the reserved <unk#k> rows
    shard_ids = {va.token_to_id[TOK.unk_shard_token(k)]
                 for k in range(8)}
    assert set(ids[:len(toks)]) <= shard_ids


def test_byte_fallback_expands_short_tokens():
    v = _base_vocab(byte_fallback=True)
    ids = v.encode(["ab"], 8)
    assert ids[0] == v.token_to_id[TOK.byte_token(ord("a"))]
    assert ids[1] == v.token_to_id[TOK.byte_token(ord("b"))]
    assert v.unk_fraction(ids) == 0.0
    # long tokens skip byte expansion (no shards here -> bare unk)
    long = "x" * (TOK.BYTE_FALLBACK_MAX + 1)
    assert v.encode([long], 4)[0] == v.token_to_id[TOK.UNK]


def test_legacy_vocab_bit_unchanged():
    v = _base_vocab()
    assert not v.n_unk_buckets and not v.byte_fallback
    ids = v.encode(["xpu.matmul", "never_seen"], 4)
    assert ids[1] == v.token_to_id[TOK.UNK]
    assert v.oov_rate(["xpu.matmul", "never_seen"]) == 0.5


def test_encode_many_matches_encode_with_oov(service):
    v = service.vocab
    rng = np.random.default_rng(0)
    known = list(v.token_to_id)[:50]
    rows = []
    for _ in range(12):
        row = [known[i] for i in rng.integers(0, 50, 6)]
        if rng.random() < 0.7:
            row.append(f"oov_{rng.integers(1 << 30)}")
        rows.append(row)
    got = v.encode_many(rows, 16)
    want = np.stack([v.encode(r, 16) for r in rows])
    np.testing.assert_array_equal(got, want)


def test_vocab_save_load_roundtrip_oov(tmp_path):
    v = _base_vocab(n_unk_buckets=4, byte_fallback=True)
    p = tmp_path / "v.json"
    v.save(str(p))
    w = TOK.Vocab.load(str(p))
    assert w.n_unk_buckets == 4 and w.byte_fallback
    assert w.token_to_id == v.token_to_id
    # legacy on-disk format (plain dict) still loads, machinery off
    import json
    q = tmp_path / "legacy.json"
    q.write_text(json.dumps(v.token_to_id))
    legacy = TOK.Vocab.load(str(q))
    assert legacy.n_unk_buckets == 0 and not legacy.byte_fallback


def test_extend_vocab_oov_respects_embedding_cap():
    v = _base_vocab()
    with pytest.raises(ValueError):
        TOK.extend_vocab_oov(v, n_unk_buckets=32, byte_fallback=True,
                             max_size=len(v.token_to_id) + 10)
    w = TOK.extend_vocab_oov(v, n_unk_buckets=32, byte_fallback=True,
                             max_size=1024)
    assert max(w.token_to_id.values()) < 1024


def test_servicespec_carries_vocab_mode(service):
    spec = ServiceSpec.from_service(service)
    assert spec.n_unk_buckets == 32 and spec.byte_fallback
    rebuilt = spec.build()
    assert rebuilt.vocab.n_unk_buckets == 32
    assert rebuilt.vocab.byte_fallback
    toks = ["xpu.matmul", "never_seen_anywhere", "zz"]
    np.testing.assert_array_equal(service.vocab.encode(toks, 8),
                                  rebuilt.vocab.encode(toks, 8))


# ------------------------------------------------------- end to end
def test_arch_corpus_predicts_with_zero_unk(corpus, service):
    """Acceptance: lowered per-layer subgraphs of >= 5 real archs all
    predict end to end with zero collapse onto bare <unk>."""
    assert len({a for a, _, _ in corpus}) >= 5
    before = service.phase_stats()["ingested_texts"]
    for arch, layer, text in corpus:
        out = service.predict_text(text)
        assert not isinstance(out, FD.IngestError), (arch, layer, out)
        assert out.unk_rate == 0.0, (arch, layer)
        assert out.n_ops > 0, (arch, layer)
        assert set(out.predictions) == set(service.heads)
        assert all(np.isfinite(v) for v in out.predictions.values())
    ps = service.phase_stats()
    assert ps["ingested_texts"] == before + len(corpus)
    assert 0.0 <= ps["oov_rate"] <= 1.0


def test_struct_key_unifies_text_and_graph_cache(service):
    """An ingested program and its re-ingestion share one LRU entry."""
    _, _, text = SH.lower_arch_corpus(["qwen3-0.6b"], seq=8)[0]
    ent1 = service.ingest_text(text)
    ent2 = service.ingest_text(text)
    assert ent1.key == ent2.key
    a = service.predict_text(text)
    b = service.predict_text(text)
    assert a.predictions == b.predictions


def test_server_and_service_predict_text_parity(corpus, service):
    want = {}
    for arch, layer, text in corpus[:6]:
        out = service.predict_text(text)
        want[(arch, layer)] = out.predictions
    with CostModelServer(service, max_batch=8, flush_us=500) as server:
        for arch, layer, text in corpus[:6]:
            got = server.predict_text(text)
            assert not isinstance(got, FD.IngestError)
            assert got.predictions == want[(arch, layer)]
        snap = server.metrics_snapshot()
        assert "phase_oov_rate" in snap
        assert 0.0 <= snap["phase_oov_rate"] <= 1.0
    # stopped server: still structured, never raises
    err = server.predict_text(corpus[0][2])
    assert isinstance(err, FD.IngestError)
    assert err.stage == "predict"


def test_fuzz_corpus_never_raises(corpus, service):
    """>= 200 mutated/truncated/dialect-spliced inputs, zero uncaught
    exceptions (the PR's hard robustness gate, mirrored in gate.py)."""
    seeds = [t for _, _, t in corpus[:8]] + [FD.AFFINE_EXAMPLE]
    mutated = FD.fuzz_corpus(seeds, 200, np.random.default_rng(5))
    assert len(mutated) >= 200
    errors = 0
    for text in mutated:
        out = service.predict_text(text)   # must not raise
        if isinstance(out, FD.IngestError):
            errors += 1
        else:
            assert all(np.isfinite(v)
                       for v in out.predictions.values())
    assert errors < len(mutated)           # not everything degrades


@settings(max_examples=25, deadline=None)
@given(data=st.binary(max_size=300))
def test_predict_text_total_on_arbitrary_bytes(service, data):
    """Hypothesis property: any byte string yields a TextPrediction or
    an IngestError — predict_text is a total function of its input."""
    out = service.predict_text(data)
    assert isinstance(out, (FD.TextPrediction, FD.IngestError))


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_predict_text_total_under_mutation(service, data):
    """Truncations/splices of real lowered text never escape either."""
    text = SH_TEXT
    n = data.draw(st.integers(0, len(text)))
    mode = data.draw(st.integers(0, 2))
    if mode == 0:
        mutated = text[:n]                          # truncation
    elif mode == 1:
        mutated = text[:n] + "\x00\xff" + text[n:]  # byte damage
    else:
        mutated = text[:n] + FD.AFFINE_EXAMPLE      # dialect splice
    out = service.predict_text(mutated)
    assert isinstance(out, (FD.TextPrediction, FD.IngestError))
