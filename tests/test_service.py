"""CostModelService, advisors, and the real-MLIR (StableHLO) pathway."""
import numpy as np
import pytest

from repro.configs import COSTMODEL_SMALL
from repro.core import models as CM
from repro.core import trainer as TR
from repro.core.service import (CostModelService, FusionAdvisor,
                                RecompileAdvisor, UnrollAdvisor,
                                fuse_elementwise, unroll_graph)
from repro.core import augment as AUG
from repro.ir import dataset as DS, samplers
from repro.ir.graph import Graph, Tensor


@pytest.fixture(scope="module")
def service():
    """One multi-head service predicting every target."""
    ds = DS.build_dataset(400, mode="ops", max_seq=64, vocab_size=512,
                          augment_factor=1, seed=2)
    tr, _ = ds.split(0.1)
    res = TR.train_model("conv1d", COSTMODEL_SMALL, tr, CM.DEFAULT_HEADS,
                         steps=150, batch_size=64)
    return CostModelService("conv1d", COSTMODEL_SMALL, res.params, ds.vocab,
                            res.norm_stats, mode="ops", max_seq=64)


def test_service_batched_predict_and_cache(service):
    svc = service
    rng = np.random.default_rng(0)
    gs = [samplers.sample_graph(rng) for _ in range(8)]
    p1 = svc.predict_graphs(gs + gs, "latency_us")  # dups -> cache hits
    assert p1.shape == (16,)
    np.testing.assert_allclose(p1[:8], p1[8:])
    assert len(svc._cache) <= len(gs)
    assert (p1 > 0).all()                  # denormalized target space


def test_service_predict_all_single_pass(service):
    rng = np.random.default_rng(4)
    gs = [samplers.sample_graph(rng) for _ in range(4)]
    out = service.predict_all(gs)
    assert set(out) == set(CM.DEFAULT_HEADS)
    for v in out.values():
        assert v.shape == (4,)
        assert np.isfinite(v).all()


def test_fusion_advisor(service):
    adv = FusionAdvisor(service)
    rng = np.random.default_rng(1)
    g = samplers.sample_graph(rng, "resnet")
    do_fuse, c0, c1 = adv.advise(g)
    assert isinstance(do_fuse, bool) and c0 > 0 and c1 > 0


def test_fuse_elementwise_semantics():
    t = Tensor((8, 128))
    g = Graph()
    a = g.add_arg(t)
    x = g.add_op("relu", [a], t)
    x = g.add_op("tanh", [x], t)
    x = g.add_op("sigmoid", [x], t)
    g.outputs = [x]
    f = fuse_elementwise(g)
    f.validate()
    assert len(f.ops) < len(g.ops)


def test_unroll_advisor_single_service(service):
    """UnrollAdvisor reads latency AND register pressure from ONE service."""
    adv = UnrollAdvisor(service, register_budget=1e9)  # everything feasible
    rng = np.random.default_rng(2)
    g = samplers.sample_graph(rng, "bert")
    out = adv.advise(g, factors=(1, 2, 4))
    assert out["best_factor"] in (1, 2, 4)
    assert set(out["per_iter_latency"]) == {1, 2, 4}
    assert set(out["register_pressure"]) == {1, 2, 4}
    u4 = unroll_graph(g, 4)
    assert len(u4.ops) == 4 * len(g.ops)


def test_recompile_advisor(service):
    adv = RecompileAdvisor(service, threshold=0.0)
    rng = np.random.default_rng(3)
    g = samplers.sample_graph(rng, "unet")
    same = adv.advise(g, g)
    assert not same["recompile"] or same["shift"] == 0.0
    g2 = AUG.jitter_shapes(g, rng)
    out = adv.advise(g, g2)
    assert {"recompile", "predicted_old", "predicted_new",
            "shift"} <= set(out)


def test_single_head_service_compat():
    """Legacy single-target services still construct and predict."""
    ds = DS.build_dataset(120, mode="ops", max_seq=64, vocab_size=512,
                          augment_factor=1, seed=5)
    tr, _ = ds.split(0.1)
    res = TR.train_model("conv1d", COSTMODEL_SMALL, tr, "latency_us",
                         steps=30, batch_size=32)
    svc = CostModelService("conv1d", COSTMODEL_SMALL, res.params, ds.vocab,
                           res.norm_stats, mode="ops", max_seq=64)
    rng = np.random.default_rng(6)
    g = samplers.sample_graph(rng)
    assert svc.predict(g) > 0
    # a single-head service answers any target request with its only head
    assert svc.predict(g, "latency_us") == svc.predict(g)


def test_stablehlo_pathway_tokenizes():
    """jax .lower() MLIR text is real and tokenizable; XLA targets align
    with the roofline constants."""
    from repro.core import tokenizer as TOK
    from repro.ir import stablehlo as SH
    rng = np.random.default_rng(0)
    rows = SH.sample_stablehlo_corpus(rng, n=4)
    assert len(rows) == 4
    for text, targets in rows:
        assert "stablehlo" in text or "func.func" in text
        toks = TOK.tokenize_text(text)
        assert len(toks) > 10
        assert targets["latency_us"] >= 0


def test_text_dataset_from_stablehlo():
    """build_text_dataset over real lowered MLIR — train a tiny model on
    XLA-derived latency targets end to end."""
    from repro.ir import stablehlo as SH
    rng = np.random.default_rng(1)
    rows = SH.sample_stablehlo_corpus(rng, n=8)
    ds = DS.build_text_dataset(rows, max_seq=256, vocab_size=1024)
    assert ds.ids.shape == (8, 256)
    assert ds.mode == "text"
    assert "latency_us" in ds.targets and (ds.targets["flops"] >= 0).all()
