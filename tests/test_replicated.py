"""Replicated serving tier: spawned replicas behind the struct-key
router — prediction parity vs the single-process service, routing that
keeps per-replica LRUs hot, the shared cross-replica cache tier, the
wire format, and the client's retry/backoff/health/shed state machine
(driven through a fake transport, no processes needed)."""
import hashlib
import os
import queue
import signal
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs.costmodel import CostModelConfig
from repro.core import models as CM
from repro.core import tokenizer as TOK
from repro.core.server import ServerOverloadedError
from repro.core.service import CostModelService
from repro.ir import samplers
from repro.serving import (HashRing, ReplicaClient, ServiceSpec,
                           SharedRowCache, start_replicas)
from repro.serving import transport as T

CFG = CostModelConfig(name="repl-test", vocab_size=512, max_seq=64,
                      embed_dim=16, conv_channels=(16,) * 2,
                      fc_dims=(32,))
N_REPLICAS = 4


def _sha_keys(n, salt=""):
    """Production-shaped keys: struct_key() is sha1 hex, so its high
    bits are uniform — the ring's hex fast path hashes those, and keys
    like f"{i:040x}" (all-zero prefixes) would degenerate onto one
    replica by construction."""
    return [hashlib.sha1(f"{salt}k{i}".encode()).hexdigest()
            for i in range(n)]


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(11)
    graphs = [samplers.sample_graph(rng) for _ in range(24)]
    vocab = TOK.fit_vocab([TOK.graph_tokens(g, "ops") for g in graphs],
                          max_size=512)
    return graphs, vocab


@pytest.fixture(scope="module")
def service(corpus):
    _, vocab = corpus
    params = CM.conv_init(jax.random.PRNGKey(3), CFG,
                          heads=CM.DEFAULT_HEADS)
    stats = {t: {"mu": 0.2, "sigma": 1.3} for t in CM.DEFAULT_HEADS}
    return CostModelService("conv1d", CFG, params, vocab, stats,
                            mode="ops", max_seq=64, max_batch=8,
                            buckets=(32, 64), batch_ladder=(1, 2, 4, 8))


@pytest.fixture(scope="module")
def spec(service):
    return ServiceSpec.from_service(service)


@pytest.fixture(scope="module")
def tier(spec):
    """One real spawned tier shared by the process-backed tests."""
    tier = start_replicas(spec, N_REPLICAS, n_clients=3,
                          flush_us=300.0, start_timeout_s=240.0)
    yield tier
    tier.stop()


# ------------------------------------------------------------ real tier
def test_replicated_parity_matches_direct(corpus, service, tier):
    """Predictions through 4 replicas + router == single-process
    predict_all, within float tolerance (acceptance criterion)."""
    graphs, _ = corpus
    want = service.predict_all(graphs)
    client = ReplicaClient(tier.client_handle(0))
    got = client.predict_all(graphs)
    assert set(got) == set(want)
    for t in want:
        np.testing.assert_allclose(got[t], want[t], rtol=1e-6)
    # repeat from the client's local LRU: same answers
    again = client.predict_all(graphs)
    for t in want:
        np.testing.assert_allclose(again[t], want[t], rtol=1e-6)
    assert client.shed_count == 0


def test_replicated_predict_text_parity(service, tier):
    """The real-MLIR front door through the replica tier: same
    predictions as the single-process service for the same text, and
    garbage degrades to a structured IngestError, never an exception
    (acceptance criterion: tier parity for predict_text)."""
    from repro.ir import frontdoor as FD
    text = FD.AFFINE_EXAMPLE
    want = service.predict_text(text)
    assert not isinstance(want, FD.IngestError)
    client = ReplicaClient(tier.client_handle(1))
    got = client.predict_text(text)
    assert not isinstance(got, FD.IngestError)
    assert got.key == want.key
    for t, v in want.predictions.items():
        np.testing.assert_allclose(got.predictions[t], v, rtol=1e-6)
    # repeat answers from the client-side LRU, identically
    again = client.predict_text(text)
    np.testing.assert_allclose(
        [again.predictions[t] for t in sorted(want.predictions)],
        [want.predictions[t] for t in sorted(want.predictions)],
        rtol=1e-6)
    err = client.predict_text(b"\x00\xff")
    assert isinstance(err, FD.IngestError)
    # a real lowered arch subgraph rides the same path (truncated to
    # this fixture's 64-token bucket identically on both sides)
    from repro.ir import stablehlo as SH
    _, _, mlir = SH.lower_arch_corpus(["qwen3-0.6b"], seq=4)[0]
    direct = service.predict_text(mlir)
    via = client.predict_text(mlir)
    assert not isinstance(via, FD.IngestError)
    assert via.key == direct.key
    for t, v in direct.predictions.items():
        np.testing.assert_allclose(via.predictions[t], v, rtol=1e-6)


def test_replicated_use_kernel_parity(corpus, service):
    """use_kernel survives the ServiceSpec export/import round trip and
    a spawned replica tier serving the fused Pallas forward returns the
    same predictions as the plain-jnp single-process service (allclose
    — the kernel's accumulation order differs from XLA's)."""
    graphs, vocab = corpus
    ksvc = CostModelService("conv1d", CFG, service.params, vocab,
                            service.norm_stats, mode="ops", max_seq=64,
                            max_batch=8, buckets=(32, 64),
                            batch_ladder=(1, 2, 4, 8), use_kernel=True)
    want = service.predict_all(graphs)
    kspec = ServiceSpec.from_service(ksvc)
    assert kspec.use_kernel is True
    rebuilt = kspec.build()
    assert rebuilt.use_kernel is True
    got = rebuilt.predict_all(graphs)
    assert set(got) == set(want)
    for t in want:
        np.testing.assert_allclose(got[t], want[t], rtol=2e-4, atol=2e-4)
    ktier = start_replicas(kspec, 2, n_clients=1, flush_us=300.0,
                           start_timeout_s=240.0)
    try:
        client = ReplicaClient(ktier.client_handle(0))
        via_tier = client.predict_all(graphs)
        for t in want:
            np.testing.assert_allclose(via_tier[t], want[t],
                                       rtol=2e-4, atol=2e-4)
        assert client.shed_count == 0
    finally:
        ktier.stop()


def test_struct_key_routing_preserves_replica_lru(corpus, tier):
    """Struct-key routing sends a key to the same replica every time,
    so repeat queries hit that replica's own LRU (acceptance
    criterion). The client runs with local_cache=False so every query
    actually travels to the replicas."""
    graphs, _ = corpus
    client = ReplicaClient(tier.client_handle(1), local_cache=False)
    client.clear_caches()              # fresh replica LRUs for this test
    tier.shared_cache.clear()
    # cache COUNTERS are cumulative across the module-scoped tier, so
    # judge this test's traffic by before/after deltas
    before = {s["replica_id"]: s["cache"]
              for s in client.replica_stats() if s}
    client.predict_all(graphs)         # pass 1: compulsory misses
    client.predict_all(graphs)         # pass 2: must be replica-LRU hits
    stats = [s for s in client.replica_stats() if s]
    assert len(stats) == N_REPLICAS
    delta = {}
    for s in stats:
        b, c = before[s["replica_id"]], s["cache"]
        delta[s["replica_id"]] = (c["hits"] - b["hits"],
                                  c["misses"] - b["misses"])
    used = {r: d for r, d in delta.items() if d[0] + d[1] > 0}
    assert len(used) >= 2, "routing degenerated onto one replica"
    for r, (hits, misses) in used.items():
        # each unique key: exactly one miss (pass 1) then hits — a
        # routing flap would send pass-2 keys to a cold replica and
        # drag the hit share under 0.5
        assert hits / (hits + misses) >= 0.5 - 1e-9, \
            f"replica {r} LRU went cold: hits={hits} misses={misses}"
    total_misses = sum(d[1] for d in used.values())
    uniq = len({g.struct_key() for g in graphs})
    assert total_misses == uniq


def test_shared_cache_serves_cross_replica_misses(corpus, service, tier):
    """A row published to the shared tier is served without a forward
    pass: plant a sentinel row for a never-seen graph and check the
    tier answers with it."""
    rng = np.random.default_rng(99)
    g = samplers.sample_graph(rng, "unet")
    key = service.key_of(g)
    client = ReplicaClient(tier.client_handle(2), local_cache=False)
    client.clear_caches()
    sentinel = np.full((len(service.heads),), 0.125, np.float32)
    tier.shared_cache.put(key, sentinel)
    got = client.predict_all([g])
    want = service.denormalize_rows(sentinel[None])
    for t in want:
        np.testing.assert_allclose(got[t], want[t], rtol=1e-6)


def test_replicated_entrypoint_exports(tier):
    assert tier.n_replicas == N_REPLICAS
    assert all(tier.alive())


# ------------------------------------------------- shared cache (unit)
def test_shared_row_cache_roundtrip():
    c = SharedRowCache(n_heads=3, n_slots=64)
    assert c.get("a" * 40) is None
    row = np.array([1.5, -2.0, 0.25], np.float32)
    c.put("a" * 40, row)
    np.testing.assert_array_equal(c.get("a" * 40), row)
    assert c.fill() == 1
    # refresh in place, not a second slot
    c.put("a" * 40, row * 2)
    np.testing.assert_array_equal(c.get("a" * 40), row * 2)
    assert c.fill() == 1
    # non-hex keys digest through sha1
    c.put("not-a-hex-key", row)
    np.testing.assert_array_equal(c.get("not-a-hex-key"), row)
    c.clear()
    assert c.fill() == 0
    assert c.get("a" * 40) is None


def test_shared_row_cache_eviction_bounded():
    c = SharedRowCache(n_heads=2, n_slots=8)
    keys = [f"{i:040x}" for i in range(64)]
    c.put_many([(k, np.array([i, -i], np.float32))
                for i, k in enumerate(keys)])
    assert c.fill() <= 8                # capacity is a hard bound
    live = [k for k in keys if c.get(k) is not None]
    assert live                         # some survivors...
    for k in live:                      # ...with intact rows
        i = int(k, 16)
        np.testing.assert_array_equal(
            c.get(k), np.array([i, -i], np.float32))


def test_shared_row_cache_get_many():
    c = SharedRowCache(n_heads=1, n_slots=32)
    c.put("b" * 40, np.array([7.0], np.float32))
    got = c.get_many(["b" * 40, "c" * 40])
    np.testing.assert_array_equal(got[0], [7.0])
    assert got[1] is None


# ------------------------------------------------------ transport (unit)
def test_pack_unpack_entries_roundtrip():
    entries = [("k1", np.arange(8, dtype=np.int32)),
               ("k2", np.arange(100, 116, dtype=np.int32)),
               ("k3", np.zeros(0, np.int32))]
    keys, lens_b, ids_b = T.pack_entries(entries)
    back = T.unpack_entries(keys, lens_b, ids_b)
    assert [k for k, _ in back] == ["k1", "k2", "k3"]
    for (_, a), (_, b) in zip(entries, back):
        np.testing.assert_array_equal(a, b)
    assert T.pack_entries([]) == ([], b"", b"")


def test_pack_unpack_rows_roundtrip():
    rows = [np.array([1.0, 2.0, 3.0], np.float32),
            np.array([-1.0, 0.5, 9.0], np.float32)]
    rows_b, nh = T.pack_rows(rows)
    assert nh == 3
    np.testing.assert_array_equal(T.unpack_rows(rows_b, nh),
                                  np.stack(rows))


def test_service_spec_rebuild_parity(corpus, service, spec):
    """build() in the SAME process must reproduce the service exactly —
    the cross-process parity case is test_replicated_parity."""
    graphs, _ = corpus
    rebuilt = spec.build()
    want = service.predict_all(graphs)
    got = rebuilt.predict_all(graphs)
    for t in want:
        np.testing.assert_allclose(got[t], want[t], rtol=1e-6)


def test_export_import_cache_roundtrip(corpus, service):
    graphs, _ = corpus
    donor = ServiceSpec.from_service(service).build()
    donor.predict_all(graphs)
    items = donor.export_cache()
    assert len(items) == len({g.struct_key() for g in graphs})
    recip = ServiceSpec.from_service(service).build()
    assert recip.import_cache(items) == len(items)
    before = recip.phase_stats()["forward_s"]
    got = recip.predict_all(graphs)    # all answered from imported rows
    assert recip.phase_stats()["forward_s"] == before
    want = service.predict_all(graphs)
    for t in want:
        np.testing.assert_allclose(got[t], want[t], rtol=1e-6)


# ------------------------------------------------------------- hash ring
def test_hash_ring_stable_and_balanced():
    ring = HashRing(4, vnodes=32)
    keys = _sha_keys(1000)
    owners = [ring.primary(k) for k in keys]
    assert owners == [ring.primary(k) for k in keys]   # deterministic
    counts = np.bincount(owners, minlength=4)
    assert (counts > 0).all()
    assert counts.max() <= 3 * counts.min() + 8        # no degenerate split
    order = ring.route(keys[0])
    assert sorted(order) == [0, 1, 2, 3]               # full fallback chain
    assert ring.route(keys[0], 2) == order[:2]


# --------------------------------------- router state machine (no procs)
def _row_for(key: str, n_heads: int) -> np.ndarray:
    h = int(key[:8], 16) if len(key) == 40 else abs(hash(key))
    return (np.arange(n_heads, dtype=np.float32) + h % 97) / 97.0


class FakeTransport:
    """Scripted tier: behavior(replica, keys) decides each request's
    fate — ("ok",), ("overload", retry_after), ("err",), ("drop",)."""

    def __init__(self, n_replicas, behavior, n_heads=3):
        self.n_replicas = n_replicas
        self.client_id = 0
        self.behavior = behavior
        self.n_heads = n_heads
        self.q = queue.Queue()
        self.sent = []                 # (replica, keys) per request

    def send(self, replica, msg):
        if msg[0] != T.MSG_REQ:
            return                     # control traffic: ignored here
        _, _client, bid, keys, _lens, _ids = msg
        self.sent.append((replica, list(keys)))
        act = self.behavior(replica, keys)
        if act[0] == "ok":
            rows_b, nh = T.pack_rows(
                [_row_for(k, self.n_heads) for k in keys])
            self.q.put((T.MSG_RES, bid, list(range(len(keys))),
                        rows_b, nh))
        elif act[0] == "overload":
            self.q.put((T.MSG_OVERLOAD, bid, list(range(len(keys))),
                        act[1]))
        elif act[0] == "err":
            self.q.put((T.MSG_ERR, bid, list(range(len(keys))),
                        "scripted failure"))
        # "drop": no reply at all (dead replica)

    def recv(self, timeout):
        return self.q.get(timeout=timeout)


@pytest.fixture()
def fake_client(spec):
    def make(behavior, **kw):
        tr = FakeTransport(4, behavior)
        kw.setdefault("backoff_s", 0.001)
        kw.setdefault("timeout_s", 0.25)
        kw.setdefault("cooldown_s", 0.02)
        return ReplicaClient(transport=tr, spec=spec, **kw), tr
    return make


def _entries(n, start=0):
    return [(k, np.arange(4, dtype=np.int32))
            for k in _sha_keys(n, salt=f"{start}-")]


def test_router_happy_path_routes_by_ring(fake_client):
    client, tr = fake_client(lambda r, ks: ("ok",))
    ents = _entries(32)
    got = client._fetch(ents)
    assert set(got) == {k for k, _ in ents}
    for k in got:
        np.testing.assert_array_equal(got[k], _row_for(k, 3))
    for replica, keys in tr.sent:      # every key on its ring primary
        for k in keys:
            assert client.ring.primary(k) == replica
    assert sum(h.ok for h in client.health) == len(tr.sent)


def test_router_reroutes_around_overloaded_replica(fake_client):
    client, tr = fake_client(
        lambda r, ks: ("overload", 0.01) if r == 0 else ("ok",))
    ents = _entries(64)
    primaries = {k: HashRing(4).primary(k) for k, _ in ents}
    assert any(p == 0 for p in primaries.values())
    got = client._fetch(ents)
    assert len(got) == len(ents)       # everything resolved via fallback
    assert client.health[0].overload >= 1
    assert client.health[0].consecutive_failures >= 1
    assert client.health[0].unhealthy_until > time.monotonic() - 1.0
    assert client.shed_count == 0
    # retried keys landed on a non-0 replica the second time
    retried = [(r, ks) for r, ks in tr.sent[1:] if r != 0
               and any(primaries[k] == 0 for k in ks)]
    assert retried


def test_router_sheds_when_all_replicas_overloaded(fake_client):
    client, tr = fake_client(lambda r, ks: ("overload", 0.001),
                             max_retries=2)
    with pytest.raises(ServerOverloadedError):
        client._fetch(_entries(4))
    assert client.shed_count == 1
    # 3 rounds (initial + 2 retries), each round >= 1 request
    rounds = len(tr.sent)
    assert rounds >= 3
    assert sum(h.overload for h in client.health) == rounds


def test_router_honors_retry_after_hint(fake_client):
    state = {"n": 0}

    def behavior(r, ks):
        state["n"] += 1
        return ("overload", 0.15) if state["n"] == 1 else ("ok",)

    client, _ = fake_client(behavior)
    ents = _entries(1)
    t0 = time.monotonic()
    got = client._fetch(ents)
    assert len(got) == 1
    assert time.monotonic() - t0 >= 0.15   # backoff floored by the hint


def test_router_reroutes_around_dead_replica(fake_client):
    ents = _entries(1, start=5000)
    dead = HashRing(4).primary(ents[0][0])
    client, tr = fake_client(
        lambda r, ks: ("drop",) if r == dead else ("ok",),
        timeout_s=0.05)
    got = client._fetch(ents)
    assert len(got) == 1
    assert client.health[dead].timeout >= 1
    assert tr.sent[0][0] == dead           # tried the primary first
    assert tr.sent[-1][0] != dead          # resolved on a fallback


def test_router_shared_client_concurrent_fetch(fake_client):
    """One ReplicaClient shared by many threads (the serve driver's
    closed-loop session): replies for different in-flight batches
    arrive on ONE queue, so the reply demux must hand each thread its
    own batch instead of dropping what it didn't send. Pre-demux this
    shed spuriously under concurrency."""
    client, tr = fake_client(lambda r, ks: ("ok",), timeout_s=5.0)
    n_threads, per_thread = 8, 12
    errs, done = [], []

    def worker(w):
        try:
            for i in range(per_thread):
                ents = _entries(3, start=w * 1000 + i * 10)
                got = client._fetch(ents)
                assert set(got) == {k for k, _ in ents}
                for k in got:
                    np.testing.assert_array_equal(got[k], _row_for(k, 3))
            done.append(w)
        except Exception as e:           # pragma: no cover - regression
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(w,))
               for w in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert len(done) == n_threads
    assert client.shed_count == 0
    assert sum(h.timeout for h in client.health) == 0
    assert not client._mail and not client._live   # demux drained


def test_router_scripted_error_counts_and_reroutes(fake_client):
    ents = _entries(1, start=900)
    bad = HashRing(4).primary(ents[0][0])
    client, _ = fake_client(
        lambda r, ks: ("err",) if r == bad else ("ok",))
    got = client._fetch(ents)
    assert len(got) == 1
    assert client.health[bad].err == 1
    st = client.stats()
    assert st["health"][bad]["err"] == 1
    assert st["shed_count"] == 0


# --------------------------------------------- hard failure (real tier)
def test_replica_sigkill_mid_load_recovers(corpus, service, spec):
    """SIGKILL a replica while a client is driving load: in-flight and
    subsequent requests reroute to the survivor (zero exceptions, zero
    wrong predictions), the supervisor respawns the dead slot, and ring
    ownership lands back on the respawned replica."""
    from repro.serving import ReplicaSupervisor
    graphs, _ = corpus
    want = service.predict_all(graphs)
    tier2 = start_replicas(spec, 2, n_clients=1, flush_us=300.0,
                           start_timeout_s=240.0)
    sup = None
    try:
        # death detection is exitcode-driven; the huge heartbeat
        # timeout keeps wedge detection out of this test's way
        sup = ReplicaSupervisor(tier2, heartbeat_s=0.25,
                                heartbeat_timeout_s=60.0,
                                restart_backoff_s=0.05,
                                start_timeout_s=240.0).start()
        client = ReplicaClient(tier2.client_handle(0),
                               local_cache=False, timeout_s=2.0,
                               cooldown_s=0.05)
        results, errs = [], []
        stop = threading.Event()

        def load():
            while not stop.is_set():
                try:
                    results.append(client.predict_all(graphs))
                except Exception as e:    # pragma: no cover - regression
                    errs.append(e)
                    return

        t = threading.Thread(target=load)
        t.start()
        time.sleep(0.5)                    # mid-load
        os.kill(tier2.procs[0].pid, signal.SIGKILL)
        deadline = time.monotonic() + 240.0
        while time.monotonic() < deadline:
            st = sup.stats()
            if st["restarts_recovered"] >= 1 and not st["respawning"]:
                break
            time.sleep(0.25)
        stop.set()
        t.join(timeout=120.0)
        assert not t.is_alive()
        assert not errs                    # rerouting absorbed the death
        assert results
        for r in results:                  # zero wrong predictions
            for tgt in want:
                np.testing.assert_allclose(r[tgt], want[tgt], rtol=1e-6)
        # the client actually saw (and rode out) the failure
        assert client.health[0].timeout + client.health[0].reroutes >= 1
        st = sup.stats()
        assert st["restarts_total"] >= 1
        assert st["restarts_recovered"] >= 1
        assert any(rec["replica"] == 0 and rec["reason"] == "died"
                   for rec in st["restart_log"])
        assert all(tier2.alive())
        # ownership restored: slot 0 serves its keys again once its
        # routing cooldown (escalated during the outage, possibly
        # refreshed by the load thread's final timeout) drains
        before = client.health[0].ok
        deadline = time.monotonic() + 30.0
        while client.health[0].ok == before and \
                time.monotonic() < deadline:
            time.sleep(0.5)
            client.predict_all(graphs)
        assert client.health[0].ok > before
        payloads = [p for p in client.replica_stats() if p]
        assert {p["replica_id"] for p in payloads} == {0, 1}
    finally:
        if sup is not None:
            sup.stop()
        tier2.stop()
