"""Sharding resolver + distributed-runtime unit tests (small host meshes).

Note: these tests must NOT set xla_force_host_platform_device_count (the
dry-run owns that); they exercise the resolver logic against 1-device
meshes, where every rule falls back to replication but the resolution
logic (divisibility, axis reuse) is identical.
"""
import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.runtime.sharding import ShardingRules


class FakeMesh:
    """Duck-typed mesh for resolver logic tests (no devices needed)."""

    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.empty(shape)


def rules_for(shape=(16, 16), names=("data", "model"), overrides=None):
    return ShardingRules.__new__(ShardingRules).__init__ if False else \
        _mk(shape, names, overrides)


def _mk(shape, names, overrides=None):
    r = ShardingRules.__new__(ShardingRules)
    r.mesh = FakeMesh(shape, names)
    from repro.runtime.sharding import DEFAULT_RULES
    r.rules = dict(DEFAULT_RULES)
    if overrides:
        for k, v in overrides.items():
            r.rules[k] = (v,) if isinstance(v, str) else tuple(v or ())
    r.axis_sizes = dict(zip(names, shape))
    return r


def test_divisible_dims_shard():
    r = _mk((16, 16), ("data", "model"))
    # wide-DP default: a 256 batch claims both axes; heads fall back
    spec = r.spec(("batch", None, "heads", None), (256, 4096, 32, 128))
    assert spec == P(("data", "model"), None, None, None)
    # smaller batch leaves the model axis to the heads
    spec2 = r.spec(("batch", None, "heads", None), (32, 4096, 32, 128))
    assert spec2 == P("data", None, "model", None)


def test_indivisible_dims_fall_back_to_replication():
    r = _mk((16, 16), ("data", "model"))
    # 40 heads % 16 != 0 -> heads replicated (batch 32: data only)
    spec = r.spec(("batch", "qseq", "heads", None), (32, 4096, 40, 128))
    assert spec[2] is None
    # qseq picks up the freed model axis (context parallelism)
    assert spec[1] == "model"


def test_axis_never_used_twice():
    r = _mk((16, 16), ("data", "model"))
    spec = r.spec(("heads", "ffn"), (32, 1024))  # both want 'model'
    assert spec == P("model", None)


def test_batch_composes_pod_and_data():
    r = _mk((2, 16, 16), ("pod", "data", "model"))
    spec = r.spec(("batch", None), (256, 8))
    assert spec == P(("pod", "data"), None)


def test_batch_of_one_replicates():
    r = _mk((2, 16, 16), ("pod", "data", "model"))
    spec = r.spec(("batch", "cache_seq"), (1, 524288))
    assert spec[0] is None
    assert spec[1] == "model"


def test_overrides():
    r = _mk((16, 16), ("data", "model"),
            overrides={"batch": ("data", "model")})
    spec = r.spec(("batch", None), (256, 8))
    assert spec == P(("data", "model"), None)


def test_real_constrain_on_single_device():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    r = ShardingRules(mesh)
    with mesh:
        x = jax.jit(lambda v: r.constrain(v * 2, "batch", "embed"))(
            jax.numpy.ones((4, 8)))
    assert x.shape == (4, 8)
