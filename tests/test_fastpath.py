"""Hot-path featurization + bf16 quantized serving.

Covers the incremental-hashing/encoding overhaul: struct_key caching and
rewrite-threaded hash inheritance, the service ids cache + parent-delta
token splicing, vectorized encode_many, key-first LRU probing (cache
hits never tokenize), the truncation counter, and bf16-vs-f32 drift
gates."""
import jax
import numpy as np
import pytest

from repro.configs.costmodel import CostModelConfig
from repro.core import models as CM
from repro.core import tokenizer as TOK
from repro.core.server import CostModelServer
from repro.core.service import CostModelService
from repro.ir import graph as IRG
from repro.ir import samplers
from repro.ir.graph import Graph, Tensor
from repro.opt import rewrites as RW


# ---------------------------------------------------------------- fixtures
def _mk_service(**kw):
    cfg = CostModelConfig(name="fastpath", vocab_size=1024, max_seq=160,
                          embed_dim=16, conv_channels=(16,) * 6,
                          fc_dims=(32, 16))
    rng = np.random.default_rng(7)
    graphs = [samplers.sample_graph(rng) for _ in range(24)]
    seqs = [TOK.graph_tokens(g, "ops") for g in graphs]
    seqs += [TOK.graph_tokens(RW.random_rewrite(g, rng), "ops")
             for g in graphs[:8]]
    vocab = TOK.fit_vocab(seqs, max_size=1024)
    params = CM.conv_init(jax.random.PRNGKey(0), cfg, heads=CM.DEFAULT_HEADS)
    stats = {t: {"mu": 0.3, "sigma": 1.7} for t in CM.DEFAULT_HEADS}
    kw = {"mode": "ops", "max_seq": 160, **kw}
    svc = CostModelService("conv1d", cfg, params, vocab, stats, **kw)
    return svc, graphs


@pytest.fixture(scope="module")
def fast_and_legacy():
    fast, graphs = _mk_service()
    legacy, _ = _mk_service(fast_encode=False)
    return fast, legacy, graphs


def _rewrite_children(graphs, per_rule=2):
    out = []
    for g in graphs:
        for r in RW.default_rules():
            for s in r.applicable(g)[:per_rule]:
                try:
                    out.append(r.apply(g, s))
                except AssertionError:
                    pass
    return out


# ----------------------------------------------------- incremental hashing
def test_struct_key_cached_and_invalidated():
    t = Tensor((4, 32))
    g = Graph()
    a = g.add_arg(t)
    x = g.add_op("relu", [a], t)
    g.outputs = [x]
    k1 = g.struct_key()
    assert g.struct_key() == k1 == g.struct_key_fresh()
    g.add_op("exp", [x], t)              # append invalidates the cache
    assert g.struct_key() != k1
    g2_key = g.struct_key()
    g.outputs = [g.n_args + 1]           # reassigning outputs too
    assert g.struct_key() != g2_key
    assert g.struct_key() == g.struct_key_fresh()


def test_incremental_equals_scratch_across_all_rules():
    rng = np.random.default_rng(0)
    checked = 0
    for fam in sorted(samplers.SAMPLERS):
        for seed in range(4):
            out = samplers.sample_graph(np.random.default_rng(seed), fam)
            for _ in range(4):
                firing = [(r, s) for r in RW.default_rules()
                          for s in r.applicable(out)]
                if not firing:
                    break
                r, s = firing[int(rng.integers(0, len(firing)))]
                try:
                    out = r.apply(out, s)
                except AssertionError:
                    continue
                assert out.struct_key() == out.struct_key_fresh(), r.name
                checked += 1
    assert checked > 30                  # the loop really exercised rules


def test_incremental_hashing_flag_restores_scratch_walks():
    g = samplers.sample_graph(np.random.default_rng(3), "bert")
    k = g.struct_key()
    prev = IRG.set_incremental_hashing(False)
    try:
        f = RW.REGISTRY["dtype_narrow"]
        child = f.apply(g, f.applicable(g)[0])
        assert child._inherited is None  # no inheritance while disabled
        assert child.struct_key() == child.struct_key_fresh()
        assert g.struct_key() == k       # keys agree across modes
    finally:
        IRG.set_incremental_hashing(prev)


def test_rewrite_children_inherit_most_hashes():
    """DCE on an n-op graph re-hashes nothing (all survivors are verbatim
    copies with clean operands); the combine step alone must change."""
    t = Tensor((4, 32))
    g = Graph()
    a = g.add_arg(t)
    live = g.add_op("relu", [a], t)
    g.add_op("exp", [a], t)              # dead
    g.add_op("tanh", [live], t)
    g.outputs = [g.n_args + 2]
    dce = RW.REGISTRY["dce"]
    child = dce.apply(g, dce.applicable(g)[0])
    assert set(child._inherited) == set(range(len(child.values)))
    assert child.struct_key() == child.struct_key_fresh()


# ------------------------------------------------ delta/ids-cache encoding
def test_fast_and_legacy_predictions_identical(fast_and_legacy):
    fast, legacy, graphs = fast_and_legacy
    children = _rewrite_children(graphs[:10])
    assert children, "rewrites produced no candidates"
    for batch in (graphs, children, graphs + children):
        o1 = fast.predict_all(batch)
        o2 = legacy.predict_all(batch)
        for t in fast.heads:
            np.testing.assert_array_equal(o1[t], o2[t])


def test_delta_splice_equals_fresh_encode(fast_and_legacy):
    fast, _, graphs = fast_and_legacy
    fast.predict_all(graphs)             # parents' ids now cached
    children = _rewrite_children(graphs)
    spliced = 0
    for c in children:
        got = fast._delta_ids(c)
        if got is not None:
            fresh_ids, n_tok = fast._fresh_ids(c)
            np.testing.assert_array_equal(got[0], fresh_ids)
            assert got[1] == n_tok
            spliced += 1
    assert spliced > 10                  # the delta path really fired


def test_cache_hit_skips_tokenization(fast_and_legacy):
    fast, _, graphs = fast_and_legacy
    g = graphs[0]
    fast.predict_all([g])
    before = fast.phase_stats()["full_encodes"]
    for _ in range(3):                   # repeats: key-first LRU hits
        fast.predict_all([g])
    assert fast.phase_stats()["full_encodes"] == before


def test_server_submit_key_first_parity(fast_and_legacy):
    fast, _, graphs = fast_and_legacy
    direct = fast.predict_all(graphs)
    with CostModelServer(fast, max_batch=16, flush_us=500) as server:
        before = fast.phase_stats()["full_encodes"]
        via = server.predict_all(graphs)     # all LRU hits at submit
        assert fast.phase_stats()["full_encodes"] == before
        for t in fast.heads:
            np.testing.assert_array_equal(via[t], direct[t])


# ------------------------------------------------------ truncation counter
def test_truncation_counter_surfaced():
    svc, _ = _mk_service(max_seq=32, buckets=(32,))
    rng = np.random.default_rng(1)
    big = None
    while big is None:
        g = samplers.sample_graph(rng, "bert")
        if len(TOK.graph_tokens(g, "ops")) > 32:
            big = g
    assert svc.truncations == 0
    svc.predict_all([big])
    assert svc.truncations == 1
    assert svc.cache_stats()["truncations"] == 1
    svc.predict_all([big])               # LRU hit: no new truncation
    assert svc.cache_stats()["truncations"] == 1


def test_truncation_counter_legacy_path():
    svc, _ = _mk_service(max_seq=32, buckets=(32,), fast_encode=False)
    rng = np.random.default_rng(1)
    g = samplers.sample_graph(rng, "bert")
    toks = TOK.graph_tokens(g, "ops")
    svc.predict_all([g])
    assert svc.truncations == (1 if len(toks) > 32 else 0)


# ------------------------------------------------------------ bf16 serving
def test_bf16_drift_within_gates(fast_and_legacy):
    """bf16-quantized serving: params cast once, rows widened to f32
    before the (float32-exact) denormalize; prediction drift vs f32 is
    bounded — Spearman >= 0.99 and small relative error per target."""
    from repro.opt.evaluate import spearman
    fast, _, graphs = fast_and_legacy
    bf16, _ = _mk_service(dtype="bf16")
    p32 = fast.predict_all(graphs)
    pbf = bf16.predict_all(graphs)
    for t in bf16.heads:
        assert pbf[t].dtype == np.float32
        rel = np.abs(pbf[t] - p32[t]) / np.maximum(np.abs(p32[t]), 1e-9)
        assert rel.max() <= 0.05, (t, rel.max())
        assert spearman(pbf[t], p32[t]) >= 0.99, t


def test_bf16_stays_quantized_for_all_families():
    """bf16-cast params must run a bf16 network for every registered
    family — masks/initial state/attention bias follow the embedding
    dtype, so nothing silently promotes back to f32 mid-tower."""
    import jax.numpy as jnp
    cfg = CostModelConfig(name="bf16-kinds", vocab_size=128, max_seq=32,
                          embed_dim=8, conv_filters=(2, 2),
                          conv_channels=(8, 8), fc_dims=(16, 8),
                          lstm_hidden=8)
    ids = np.zeros((2, 32), np.int32)
    ids[:, :6] = 3

    def cast(x):
        a = jnp.asarray(x)
        return a.astype(jnp.bfloat16) \
            if jnp.issubdtype(a.dtype, jnp.floating) else a

    for kind in ("fc", "conv1d", "lstm", "xformer"):
        init_fn, apply_fn, _ = CM.get_model(kind)
        params = init_fn(jax.random.PRNGKey(0), cfg,
                         heads=CM.DEFAULT_HEADS)
        out = apply_fn(jax.tree.map(cast, params), ids)
        for t, v in out.items():
            assert v.dtype == jnp.bfloat16, (kind, t, v.dtype)
            assert np.isfinite(np.asarray(v, np.float32)).all(), (kind, t)


def test_bf16_warmup_covers_programs():
    bf16, _ = _mk_service(dtype="bf16", max_batch=4,
                          buckets=(32, 64), batch_ladder=(1, 4))
    assert bf16.warmup() == 4            # (2 buckets x 2 ladder) programs


def test_bf16_rejects_unknown_dtype():
    with pytest.raises(ValueError):
        _mk_service(dtype="fp8")


# -------------------------------------------------------- tokenizer fixes
def test_tokenize_text_wide_dtype_shapes():
    """Satellite regression: i64/f64/i16/i1 (and bf16) tensor shapes stay
    single tokens instead of shattering into <unk> fragments."""
    text = ("func.func @f(%arg0: tensor<4x4xi64>) { "
            "%0 = stablehlo.add %arg0, %arg0 : 8x8xf64 } "
            "2x3xi16 5xi1 4xbf16 7x2xf16 9xi8 6x6xi32 3x3xf32")
    toks = TOK.tokenize_text(text)
    for want in ("4x4xi64", "8x8xf64", "2x3xi16", "5xi1", "4xbf16",
                 "7x2xf16", "9xi8", "6x6xi32", "3x3xf32"):
        assert want in toks, want
    # no fragment tokens survive from a shattered shape
    assert "5xi" not in toks and "8x8xf6" not in toks


def test_encode_many_matches_encode_loop():
    rng = np.random.default_rng(0)
    seqs = [TOK.graph_tokens(samplers.sample_graph(rng), "ops")
            for _ in range(12)]
    v = TOK.fit_vocab(seqs[:6], max_size=256)   # rest has OOV tokens
    for max_len in (8, 40, 200):
        batch = v.encode_many(seqs, max_len)
        for row, s in zip(batch, seqs):
            np.testing.assert_array_equal(row, v.encode(s, max_len))
    assert v.encode_many([], 16).shape == (0, 16)
    np.testing.assert_array_equal(
        v.encode_many([[]], 16)[0], v.encode([], 16))
