"""Unified telemetry layer: trace primitives (sampling, span trees,
bounded recorder, wire context), the metrics registry + exporters, the
drift sentinel, and the tracing woven through the serving tiers — the
router state machine driven through a trace-tolerant fake transport
(retry / failover / shed keep one trace id), plus a live 2-replica
tier asserting that sampled requests reconstruct complete span trees
across process boundaries."""
import json
import time

import jax
import numpy as np
import pytest

from repro.configs.costmodel import CostModelConfig
from repro.core import models as CM
from repro.core import tokenizer as TOK
from repro.core.server import CostModelServer, ServerOverloadedError
from repro.core.service import CostModelService
from repro.ir import samplers
from repro.obs import (JsonlExporter, MetricsRegistry, TraceContext,
                       Tracer, assemble, completeness, register_drift,
                       register_server, to_prometheus)
from repro.obs.drift import Alarm, DriftMonitor, attach
from repro.obs.trace import TraceRecorder, _new_id
from repro.serving import ReplicaClient, ServiceSpec, start_replicas
from repro.serving import transport as T

CFG = CostModelConfig(name="obs-test", vocab_size=512, max_seq=64,
                      embed_dim=16, conv_channels=(16,) * 2,
                      fc_dims=(32,))


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(7)
    graphs = [samplers.sample_graph(rng) for _ in range(24)]
    vocab = TOK.fit_vocab([TOK.graph_tokens(g, "ops") for g in graphs],
                          max_size=512)
    return graphs, vocab


@pytest.fixture(scope="module")
def service(corpus):
    _, vocab = corpus
    params = CM.conv_init(jax.random.PRNGKey(5), CFG,
                          heads=CM.DEFAULT_HEADS)
    stats = {t: {"mu": 0.3, "sigma": 1.7} for t in CM.DEFAULT_HEADS}
    return CostModelService("conv1d", CFG, params, vocab, stats,
                            mode="ops", max_seq=64, max_batch=8,
                            buckets=(32, 64), batch_ladder=(1, 2, 4, 8))


@pytest.fixture(scope="module")
def spec(service):
    return ServiceSpec.from_service(service)


# --------------------------------------------------- trace primitives
def test_new_ids_unique():
    ids = {_new_id() for _ in range(4096)}
    assert len(ids) == 4096


def test_trace_context_wire_roundtrip():
    ctx = TraceContext("t1", "s1")
    back = TraceContext.from_wire(ctx.to_wire())
    assert (back.trace_id, back.span_id) == ("t1", "s1")
    assert TraceContext.from_wire(None) is None
    assert TraceContext.from_wire(()) is None


def test_tracer_head_sampling_rate():
    tr = Tracer(sample_every=4)
    hits = [tr.sample() for _ in range(100)]
    assert sum(c is not None for c in hits) == 25
    assert all(tr.sample(force=True) is not None for _ in range(3))


def test_span_tree_assembly_walk_and_completeness():
    tr = Tracer(sample_every=1, proc="t")
    ctx = tr.sample()
    root = tr.start("root", ctx)
    with tr.span("child-a", root.ctx) as a:
        tr.emit("grandchild", a.ctx, 0.001)
    tr.end(root, n=1)
    trees = assemble(tr.recorder.snapshot())
    assert len(trees) == 1
    tree = trees[root.trace_id]
    assert tree.complete
    assert completeness(trees) == 1.0
    names = [(d, s["name"]) for d, s in tree.walk()]
    assert names == [(0, "root"), (1, "child-a"), (2, "grandchild")]
    # an orphan (parent id that never lands) breaks completeness
    tr.emit("stray", TraceContext(root.trace_id, "no-such-span"), 0.0)
    trees = assemble(tr.recorder.snapshot())
    assert not trees[root.trace_id].complete
    assert completeness(trees) == 0.0


def test_error_span_is_always_on():
    tr = Tracer(sample_every=1 << 30)      # nothing head-samples
    assert tr.sample() is None
    ctx = tr.error_span("server.shed", None, pending=3)
    recs = tr.recorder.snapshot()
    assert len(recs) == 1
    assert recs[0]["status"] == "err"
    assert recs[0]["tags"]["forced"] == 1
    assert recs[0]["trace"] == ctx.trace_id


def test_recorder_bounded_and_take():
    rec = TraceRecorder(capacity=4)
    for i in range(6):
        rec.record_raw({"trace": f"t{i % 2}", "span": f"s{i}",
                        "parent": "", "name": "x", "proc": "p",
                        "t_wall": 0.0, "dur_s": 0.0, "status": "ok",
                        "tags": {}})
    assert len(rec) == 4
    assert rec.dropped == 2
    taken = rec.take(["t0"])
    assert all(r["trace"] == "t0" for r in taken)
    assert all(r["trace"] == "t1" for r in rec.snapshot())
    assert rec.take([]) == []


# ------------------------------------------------------------ registry
def test_registry_instruments_sources_and_schema():
    reg = MetricsRegistry()
    reg.counter("reqs").inc(3)
    reg.gauge("depth").set(1.5)
    h = reg.histogram("lat")
    for v in (1.0, 2.0, 3.0):
        h.observe(v)
    reg.add_source("svc", lambda: {"a": 1, "nested": {"b": 2.5},
                                   "flag": True, "skip": "string"})
    snap = reg.snapshot()
    assert snap["schema"] == "repro.obs/v1"
    m = snap["metrics"]
    assert m["reqs"] == 3 and m["depth"] == 1.5
    assert m["lat.count"] == 3.0 and m["lat.mean"] == 2.0
    assert m["svc.a"] == 1 and m["svc.nested.b"] == 2.5
    assert m["svc.flag"] == 1 and "svc.skip" not in m
    assert snap["seq"] + 1 == reg.snapshot()["seq"]


def test_registry_source_failure_never_raises():
    reg = MetricsRegistry()

    def bad():
        raise RuntimeError("source down")

    reg.add_source("bad", bad)
    reg.add_source("ok", lambda: {"x": 1})
    snap = reg.snapshot()
    assert snap["metrics"]["ok.x"] == 1
    assert snap["metrics"]["obs.source_errors"] == 1
    # same-prefix re-registration replaces, not duplicates
    reg.add_source("bad", lambda: {"y": 2})
    snap = reg.snapshot()
    assert snap["metrics"]["bad.y"] == 2
    assert snap["metrics"]["obs.source_errors"] == 1


def test_prometheus_exposition_format():
    reg = MetricsRegistry()
    reg.counter("server.requests").inc(7)
    reg.gauge("drift.oov_rate").set(0.125)
    text = to_prometheus(reg.snapshot())
    assert "server_requests 7\n" in text
    assert "drift_oov_rate 0.125\n" in text
    assert text.rstrip().endswith("obs_snapshot_seq 1")


def test_jsonl_exporter_writes_metrics_and_spans(tmp_path):
    reg = MetricsRegistry()
    reg.counter("n").inc()
    tr = Tracer(sample_every=1)
    with tr.span("op", tr.sample()):
        pass
    path = str(tmp_path / "obs.jsonl")
    exp = JsonlExporter(path, reg, tracer=tr, interval_s=60.0)
    exp.tick()
    kinds = [json.loads(line)["kind"]
             for line in open(path) if line.strip()]
    assert kinds.count("metrics") == 1
    assert kinds.count("span") == 1
    assert len(tr.recorder) == 0           # tick drains the ring
    assert exp.lines_written == 2


# --------------------------------------------------------------- drift
def test_alarm_hysteresis_never_flaps_in_band():
    a = Alarm(hi=0.25, lo=0.10)
    assert not a.update(0.2)               # below hi: stays off
    assert a.update(0.3)                   # arms
    assert a.update(0.15)                  # in band: stays ARMED
    assert not a.update(0.05)              # disarms only under lo
    assert not a.update(0.2)


def test_drift_monitor_scores_and_gauges():
    def oracle(g):
        return {"latency_us": 10.0 * g}

    mon = DriftMonitor(oracle, targets=("latency_us",), sample_every=1,
                       score_interval_s=0.0)
    # gauges are fully populated BEFORE any traffic
    g0 = mon.gauges()
    assert g0["spearman.latency_us"] == 0.0
    assert g0["window_n.latency_us"] == 0
    assert g0["oov_rate"] == 0.0 and g0["oov_alarm"] == 0
    graphs = list(range(1, 9))
    preds = {"latency_us": np.array([10.0 * g + 0.5 for g in graphs])}
    mon.observe_batch(graphs, preds)
    mon.flush()
    g1 = mon.gauges()
    assert g1["observed"] == 8 and g1["scored"] == 8
    assert g1["window_n.latency_us"] == 8
    assert g1["spearman.latency_us"] == pytest.approx(1.0)
    assert g1["mae.latency_us"] == pytest.approx(0.5)


def test_drift_note_text_feeds_ewma_alarms():
    mon = DriftMonitor(lambda g: {}, oov_alarm=(0.5, 0.2),
                       unk_alarm=(0.5, 0.2), ewma_alpha=1.0)
    mon.note_text(0.6, 0.0)
    g = mon.gauges()
    assert g["oov_alarm"] == 1 and g["unk_alarm"] == 0
    mon.note_text(0.1, 0.0)
    assert mon.gauges()["oov_alarm"] == 0


def test_drift_attach_wires_service_hook(service):
    mon = attach(service, DriftMonitor(
        lambda g: {}, sample_every=1, score_interval_s=0.0))
    try:
        assert service.drift is mon
        assert mon.targets == tuple(service.heads)
        rng = np.random.default_rng(3)
        service.predict_all([samplers.sample_graph(rng)])
        assert mon.observed == 1
    finally:
        mon.stop()
        service.drift = None


# ------------------------------------------- in-process traced gateway
def test_server_predict_all_builds_complete_tree(corpus, service):
    graphs, _ = corpus
    tracer = Tracer(sample_every=1, proc="gw")
    server = CostModelServer(service, max_batch=8, flush_us=300.0,
                             tracer=tracer)
    server.start(warmup=False)
    try:
        with service._cache_lock:
            service._cache.clear()
        server.predict_all(graphs[:6])
    finally:
        server.stop()
    trees = assemble(tracer.recorder.snapshot())
    assert len(trees) == 1
    tree = next(iter(trees.values()))
    assert tree.complete
    names = {s["name"] for s in tree.spans}
    assert {"client.predict_all", "server.queue",
            "server.forward"} <= names
    root = tree.roots[0]
    assert root["name"] == "client.predict_all"
    assert root["tags"]["n_graphs"] == 6


def test_registry_adapts_live_server(corpus, service):
    graphs, _ = corpus
    server = CostModelServer(service, max_batch=8, flush_us=300.0)
    server.start(warmup=False)
    reg = MetricsRegistry()
    register_server(reg, server)
    mon = DriftMonitor(lambda g: {}, targets=tuple(service.heads))
    register_drift(reg, mon)
    try:
        server.predict_all(graphs[:4])
        m = reg.snapshot()["metrics"]
        assert m["server.requests"] >= 4
        for t in service.heads:
            assert f"drift.spearman.{t}" in m
        assert "drift.oov_rate" in m
    finally:
        server.stop()


# ------------------------------- traced router over a fake transport
def _row_for(key, n_heads=3):
    h = int(key[:8], 16) if len(key) == 40 else abs(hash(key))
    return (np.arange(n_heads, dtype=np.float32) + h % 97) / 97.0


class TracedFakeTransport:
    """Like test_replicated's FakeTransport, but trace-aware: tolerant
    of the optional 7th MSG_REQ element, and for traced requests it
    ships back a synthesized replica-side span on MSG_RES — the shape
    a real replica produces."""

    def __init__(self, n_replicas, behavior, n_heads=3):
        import queue as _q
        self.n_replicas = n_replicas
        self.client_id = 0
        self.behavior = behavior
        self.n_heads = n_heads
        self.q = _q.Queue()
        self.sent = []                 # (replica, keys, trace_or_None)

    def send(self, replica, msg):
        if msg[0] != T.MSG_REQ:
            return
        _, _client, bid, keys, _lens, _ids = msg[:6]
        wire = T.req_trace(msg)
        self.sent.append((replica, list(keys), wire))
        act = self.behavior(replica, keys)
        if act[0] == "ok":
            rows_b, nh = T.pack_rows(
                [_row_for(k, self.n_heads) for k in keys])
            res = (T.MSG_RES, bid, list(range(len(keys))), rows_b, nh)
            if wire is not None:
                res = res + ([{
                    "trace": wire[0], "span": f"fake-{bid}",
                    "parent": wire[1], "name": "replica.batch",
                    "proc": "fake-replica", "t_wall": time.time(),
                    "dur_s": 0.001, "status": "ok", "tags": {}}],)
            self.q.put(res)
        elif act[0] == "overload":
            self.q.put((T.MSG_OVERLOAD, bid, list(range(len(keys))),
                        act[1]))
        elif act[0] == "err":
            self.q.put((T.MSG_ERR, bid, list(range(len(keys))),
                        "scripted failure"))
        # "drop": no reply (dead replica)

    def recv(self, timeout):
        import queue as _q
        try:
            return self.q.get(timeout=timeout)
        except _q.Empty:
            raise


@pytest.fixture()
def traced_client(spec):
    def make(behavior, **kw):
        tr = TracedFakeTransport(4, behavior)
        kw.setdefault("backoff_s", 0.001)
        kw.setdefault("timeout_s", 0.25)
        kw.setdefault("cooldown_s", 0.02)
        kw.setdefault("local_cache", False)
        tracer = Tracer(sample_every=1, proc="client")
        return ReplicaClient(transport=tr, spec=spec, tracer=tracer,
                             **kw), tr, tracer
    return make


def test_router_traced_request_tree_spans_processes(corpus,
                                                    traced_client):
    graphs, _ = corpus
    client, tr, tracer = traced_client(lambda r, ks: ("ok",))
    client.predict_all(graphs[:4])
    trees = assemble(tracer.recorder.snapshot())
    assert len(trees) == 1
    tree = next(iter(trees.values()))
    assert tree.complete
    names = {s["name"] for s in tree.spans}
    assert {"client.predict_all", "client.featurize", "router.fetch",
            "router.rpc", "replica.batch"} <= names
    assert "fake-replica" in tree.procs    # wire-imported spans
    # every traced wire request carried the (trace_id, span_id) pair
    assert all(w is not None and w[0] == tree.trace_id
               for _, _, w in tr.sent)


def test_untraced_requests_keep_classic_wire_shape(corpus, spec):
    graphs, _ = corpus
    tr = TracedFakeTransport(4, lambda r, ks: ("ok",))
    client = ReplicaClient(transport=tr, spec=spec, local_cache=False,
                           backoff_s=0.001, timeout_s=0.25)
    client.predict_all(graphs[:4])
    assert tr.sent and all(w is None for _, _, w in tr.sent)


def test_trace_id_survives_retry_and_failover(corpus, traced_client):
    graphs, _ = corpus
    state = {"n": 0}

    def flaky(r, ks):
        state["n"] += 1
        return ("overload", 0.001) if state["n"] == 1 else ("ok",)

    client, tr, tracer = traced_client(flaky)
    client.predict_all(graphs[:3])
    trees = assemble(tracer.recorder.snapshot())
    assert len(trees) == 1
    tree = next(iter(trees.values()))
    assert tree.complete
    rpcs = [s for s in tree.spans if s["name"] == "router.rpc"]
    assert len(rpcs) >= 2                  # first attempt + the retry
    assert {s["status"] for s in rpcs} == {"overload", "ok"}
    assert len({s["trace"] for s in rpcs}) == 1


def test_shed_emits_error_span_under_the_same_trace(corpus,
                                                    traced_client):
    graphs, _ = corpus
    client, tr, tracer = traced_client(
        lambda r, ks: ("overload", 0.001), max_retries=1)
    with pytest.raises(ServerOverloadedError):
        client.predict_all(graphs[:2])
    trees = assemble(tracer.recorder.snapshot())
    assert len(trees) == 1
    tree = next(iter(trees.values()))
    assert tree.complete                   # even the failure tree stitches
    by_name = {s["name"]: s for s in tree.spans}
    assert by_name["router.shed"]["status"] == "err"
    assert by_name["router.fetch"]["status"] == "shed"
    assert by_name["client.predict_all"]["status"] == "err"


# ------------------------------------------------- live 2-replica tier
@pytest.fixture(scope="module")
def traced_tier(spec):
    tier = start_replicas(spec, 2, n_clients=1, flush_us=300.0,
                          start_timeout_s=240.0, obs_trace=True)
    yield tier
    tier.stop()


def test_live_tier_span_trees_complete_across_processes(corpus, spec,
                                                        traced_tier):
    """Acceptance: >= 99% of sampled requests through a real spawned
    tier reconstruct COMPLETE span trees client-side — every replica
    span shipped back over the wire and parented onto the client's
    tree. Runs a cold pass (forward-pass spans) and a warm pass
    (replica-LRU hit spans): both must stitch."""
    graphs, _ = corpus
    tracer = Tracer(sample_every=1, proc="client")
    client = ReplicaClient(traced_tier.client_handle(0),
                           local_cache=False, tracer=tracer)
    client.clear_caches()
    for g in graphs:                       # cold: replicas compute
        client.predict_all([g])
    for g in graphs[:8]:                   # warm: replica-LRU hits
        client.predict_all([g])
    trees = assemble(tracer.recorder.snapshot())
    assert len(trees) == len(graphs) + 8
    assert completeness(trees) >= 0.99
    assert client.shed_count == 0
    # trace ids crossed the process boundary: replica procs appear in
    # (at least) every cold tree, parented under the client's rpc span
    replica_procs = {p for t in trees.values() for p in t.procs
                     if p.startswith("replica-")}
    assert replica_procs                   # spans came back over MSG_RES
    n_with_replica = sum(
        any(p.startswith("replica-") for p in t.procs)
        for t in trees.values())
    assert n_with_replica == len(trees)
    names = {s["name"] for t in trees.values() for s in t.spans}
    assert {"client.predict_all", "router.rpc", "replica.batch",
            "server.queue", "server.forward"} <= names


def test_live_tier_stats_expose_obs_and_cooldown(corpus, traced_tier):
    graphs, _ = corpus
    client = ReplicaClient(traced_tier.client_handle(0),
                           local_cache=False)
    client.predict_all(graphs[:4])
    st = client.stats()
    assert "cooldown_remaining_s" in st["health"][0]
    assert st["failures"]["overload"] == 0
    assert st["unhealthy_now"] == 0
    rstats = [s for s in client.replica_stats() if s]
    assert rstats and all("obs" in s for s in rstats)
    assert all(s["obs"]["spans_dropped"] == 0 for s in rstats)


# ----------------------------------------------------------- obs CLI
def test_obs_cli_report_reconstructs_jsonl(tmp_path, capsys):
    from repro.launch import obs as OBS
    tr = Tracer(sample_every=1, proc="cli")
    ctx = tr.sample()
    root = tr.start("client.predict_all", ctx)
    with tr.span("router.fetch", root.ctx):
        time.sleep(0.001)
    tr.end(root)
    reg = MetricsRegistry()
    reg.gauge("drift.oov_rate").set(0.0)
    path = str(tmp_path / "t.jsonl")
    JsonlExporter(path, reg, tracer=tr, interval_s=60.0).tick()
    spans, metrics = OBS.read_records(path)
    assert len(spans) == 2 and len(metrics) == 1
    rows = OBS.waterfall(spans)
    assert {r[0] for r in rows} == {"client.predict_all",
                                    "router.fetch"}
    rc = OBS.main(["report", path])
    out = capsys.readouterr().out
    assert rc == 0
    assert "complete" in out and "client.predict_all" in out
