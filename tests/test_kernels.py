"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracle,
in interpret mode (CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as KOPS
from repro.kernels import ref as REF
from repro.kernels.conv1d_stack import conv1d_stack_fused
from repro.configs import COSTMODEL_SMALL
from repro.core import models as CM

SHAPES = [
    (1, 16, 8),
    (4, 32, 16),
    (5, 64, 32),    # non-divisible batch vs bblk
    (8, 128, 64),
]
FILTERS = [(2, 2, 2), (16, 16, 8, 8, 2, 1), (3, 5), (1,)]


def _mk(rng, B, S, C, fs_list, dtype):
    x = jnp.asarray(rng.normal(size=(B, S, C)), dtype)
    mask = jnp.asarray(rng.random((B, S)) < 0.85, jnp.float32)
    mask = mask.at[:, 0].set(1.0)
    ws, bs, cin = [], [], C
    for fs in fs_list:
        ws.append(jnp.asarray(rng.normal(size=(fs, cin, C)) * 0.2, dtype))
        bs.append(jnp.asarray(rng.normal(size=(C,)) * 0.1, dtype))
    return x, ws, bs, mask


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("fs_list", FILTERS)
def test_conv1d_stack_matches_ref(shape, fs_list):
    B, S, C = shape
    rng = np.random.default_rng(hash((shape, fs_list)) % 2**31)
    x, ws, bs, mask = _mk(rng, B, S, C, fs_list, jnp.float32)
    out_k = conv1d_stack_fused(x, ws, bs, mask, bblk=4, interpret=True)
    out_r = REF.conv1d_stack_ref(x, ws, bs, mask)
    # fp32 with different accumulation order (shifted-matmul vs conv)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_conv1d_stack_dtypes(dtype):
    rng = np.random.default_rng(7)
    x, ws, bs, mask = _mk(rng, 4, 64, 32, (2, 2, 2, 2), dtype)
    out_k = conv1d_stack_fused(x, ws, bs, mask, bblk=2, interpret=True)
    out_r = REF.conv1d_stack_ref(x.astype(jnp.float32),
                                 [w.astype(jnp.float32) for w in ws],
                                 [b.astype(jnp.float32) for b in bs], mask)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r), rtol=tol, atol=tol)


def test_kernel_tower_matches_model_apply():
    """ops.conv_tower_apply(use_kernel) == core.models.conv_apply."""
    cfg = COSTMODEL_SMALL
    params = CM.conv_init(jax.random.PRNGKey(3), cfg)
    rng = np.random.default_rng(5)
    ids = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, cfg.max_seq)),
                      jnp.int32)
    ids = ids.at[:, -5:].set(0)  # padding tail
    got = KOPS.conv_tower_apply(params, ids, use_kernel=True,
                                interpret=True)
    want = CM.conv_apply(params, ids)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_service_use_kernel_parity_and_guards():
    """CostModelService(use_kernel=True) serves the same predictions as
    the plain-jnp forward (allclose — the fused tower's accumulation
    order differs from XLA's), and the flag rejects unsupported
    kind/dtype combinations up front."""
    from repro.core.service import CostModelService
    from repro.core import trainer as TR
    from repro.ir import dataset as DS, samplers

    ds = DS.build_dataset(200, mode="ops", max_seq=64, vocab_size=512,
                          augment_factor=1, seed=11)
    tr, _ = ds.split(0.1)
    res = TR.train_model("conv1d", COSTMODEL_SMALL, tr, CM.DEFAULT_HEADS,
                         steps=60, batch_size=64)

    def mk(**kw):
        return CostModelService("conv1d", COSTMODEL_SMALL, res.params,
                                ds.vocab, res.norm_stats, mode="ops",
                                max_seq=64, **kw)

    plain, fused = mk(), mk(use_kernel=True)
    rng = np.random.default_rng(13)
    gs = [samplers.sample_graph(rng) for _ in range(6)]
    want, got = plain.predict_all(gs), fused.predict_all(gs)
    assert set(got) == set(want)
    for t in want:
        np.testing.assert_allclose(got[t], want[t], rtol=2e-4, atol=2e-4)

    with pytest.raises(ValueError, match="not conv1d"):
        mk_kind = dict(mode="ops", max_seq=64, use_kernel=True)
        CostModelService("fc", COSTMODEL_SMALL, res.params,
                         ds.vocab, res.norm_stats, **mk_kind)
    with pytest.raises(ValueError, match="f32"):
        mk(use_kernel=True, dtype="bf16")


def test_decode_attention_ref_normalizes():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(2, 2, 4, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 2, 16, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 2, 16, 8)), jnp.float32)
    out = REF.decode_attention_ref(q, k, v, 7)
    assert out.shape == (2, 2, 4, 8)
    assert bool(jnp.isfinite(out).all())
