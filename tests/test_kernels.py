"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracle,
in interpret mode (CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as KOPS
from repro.kernels import ref as REF
from repro.kernels.conv1d_stack import conv1d_stack_fused
from repro.kernels.lstm_scan import lstm_scan_fused
from repro.configs import COSTMODEL_SMALL
from repro.configs.costmodel import CostModelConfig
from repro.core import models as CM

SHAPES = [
    (1, 16, 8),
    (4, 32, 16),
    (5, 64, 32),    # non-divisible batch vs bblk
    (8, 128, 64),
]
FILTERS = [(2, 2, 2), (16, 16, 8, 8, 2, 1), (3, 5), (1,)]


def _mk(rng, B, S, C, fs_list, dtype):
    x = jnp.asarray(rng.normal(size=(B, S, C)), dtype)
    mask = jnp.asarray(rng.random((B, S)) < 0.85, jnp.float32)
    mask = mask.at[:, 0].set(1.0)
    ws, bs, cin = [], [], C
    for fs in fs_list:
        ws.append(jnp.asarray(rng.normal(size=(fs, cin, C)) * 0.2, dtype))
        bs.append(jnp.asarray(rng.normal(size=(C,)) * 0.1, dtype))
    return x, ws, bs, mask


def _conv_cfg(fs_list):
    return CostModelConfig(
        name="kernel-test", vocab_size=128, max_seq=32, embed_dim=8,
        conv_filters=tuple(fs_list),
        conv_channels=(8,) * len(fs_list), fc_dims=(16, 8),
        lstm_hidden=8)


def _ragged_ids(rng, B, S, vocab, all_pad_row=False):
    """Random ids with ragged valid lengths; optionally one all-PAD row."""
    ids = rng.integers(1, vocab, (B, S))
    lens = rng.integers(1, S + 1, (B,))
    ids[np.arange(S)[None, :] >= lens[:, None]] = 0
    if all_pad_row:
        ids[0] = 0
    return jnp.asarray(ids, jnp.int32)


def _cast16(p):
    return jax.tree.map(
        lambda a: a.astype(jnp.bfloat16)
        if jnp.issubdtype(a.dtype, jnp.floating) else a, p)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("fs_list", FILTERS)
def test_conv1d_stack_matches_ref(shape, fs_list):
    B, S, C = shape
    rng = np.random.default_rng(hash((shape, fs_list)) % 2**31)
    x, ws, bs, mask = _mk(rng, B, S, C, fs_list, jnp.float32)
    out_k = conv1d_stack_fused(x, ws, bs, mask, bblk=4, interpret=True)
    out_r = REF.conv1d_stack_ref(x, ws, bs, mask)
    # fp32 with different accumulation order (shifted-matmul vs conv)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_conv1d_stack_dtypes(dtype):
    rng = np.random.default_rng(7)
    x, ws, bs, mask = _mk(rng, 4, 64, 32, (2, 2, 2, 2), dtype)
    out_k = conv1d_stack_fused(x, ws, bs, mask, bblk=2, interpret=True)
    out_r = REF.conv1d_stack_ref(x.astype(jnp.float32),
                                 [w.astype(jnp.float32) for w in ws],
                                 [b.astype(jnp.float32) for b in bs], mask)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r), rtol=tol, atol=tol)


def test_kernel_tower_matches_model_apply():
    """ops.conv_tower_apply(use_kernel) == core.models.conv_apply."""
    cfg = COSTMODEL_SMALL
    params = CM.conv_init(jax.random.PRNGKey(3), cfg)
    rng = np.random.default_rng(5)
    ids = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, cfg.max_seq)),
                      jnp.int32)
    ids = ids.at[:, -5:].set(0)  # padding tail
    got = KOPS.conv_tower_apply(params, ids, use_kernel=True,
                                interpret=True)
    want = CM.conv_apply(params, ids)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_service_use_kernel_parity_and_guards():
    """CostModelService(use_kernel=True) serves the same predictions as
    the plain-jnp forward for both kernel kinds (allclose — the fused
    forward's accumulation order differs from XLA's), composes with
    dtype="bf16" (Spearman-gated drift), and rejects kernel-less kinds
    up front with a message naming the supported ones."""
    from repro.core.service import CostModelService
    from repro.core import trainer as TR
    from repro.ir import dataset as DS, samplers
    from repro.opt.evaluate import spearman

    ds = DS.build_dataset(200, mode="ops", max_seq=64, vocab_size=512,
                          augment_factor=1, seed=11)
    tr, _ = ds.split(0.1)
    rng = np.random.default_rng(13)
    gs = [samplers.sample_graph(rng) for _ in range(24)]
    for kind in KOPS.KERNEL_KINDS:
        res = TR.train_model(kind, COSTMODEL_SMALL, tr, CM.DEFAULT_HEADS,
                             steps=60, batch_size=64)

        def mk(**kw):
            return CostModelService(kind, COSTMODEL_SMALL, res.params,
                                    ds.vocab, res.norm_stats, mode="ops",
                                    max_seq=64, **kw)

        plain, fused = mk(), mk(use_kernel=True)
        want, got = plain.predict_all(gs), fused.predict_all(gs)
        assert set(got) == set(want)
        for t in want:
            np.testing.assert_allclose(got[t], want[t],
                                       rtol=2e-4, atol=2e-4)
        # bf16 composes with use_kernel: bf16 param reads, f32 in-kernel
        # accumulation; parity vs f32 is rank-order (the PR-5 drift gate)
        quant = mk(use_kernel=True, dtype="bf16").predict_all(gs)
        for t in want:
            assert spearman(want[t], quant[t]) >= 0.99, t

    with pytest.raises(ValueError, match="no kernel"):
        mk_kind = dict(mode="ops", max_seq=64, use_kernel=True)
        CostModelService("fc", COSTMODEL_SMALL, res.params,
                         ds.vocab, res.norm_stats, **mk_kind)


# ------------------------------------------------- fused ids-in conv forward
@pytest.mark.parametrize("fs_list", FILTERS)
@pytest.mark.parametrize("heads", [None, CM.DEFAULT_HEADS])
def test_conv_forward_fused_filter_mixes(fs_list, heads):
    """Ids-in/predictions-out kernel vs conv_apply, every config
    filter-size mix, both head layouts, ragged masks, one all-PAD row,
    and B=5 (not a bblk multiple)."""
    cfg = _conv_cfg(fs_list)
    params = CM.conv_init(jax.random.PRNGKey(1), cfg, heads=heads)
    rng = np.random.default_rng(hash((fs_list, bool(heads))) % 2**31)
    ids = _ragged_ids(rng, 5, cfg.max_seq, cfg.vocab_size,
                      all_pad_row=True)
    got = KOPS.conv_forward_apply(params, ids, interpret=True)
    want = REF.conv_forward_ref(params, ids)
    if heads:
        assert set(got) == set(heads)
        for t in heads:
            np.testing.assert_allclose(np.asarray(got[t]),
                                       np.asarray(want[t]),
                                       rtol=2e-4, atol=2e-4)
    else:
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)


def test_conv_forward_fused_matches_model_apply():
    """The fully fused forward equals core.models.conv_apply end to end
    (gather + tower + FC + heads), not just the ref oracle."""
    params = CM.conv_init(jax.random.PRNGKey(3), COSTMODEL_SMALL,
                          heads=CM.DEFAULT_HEADS)
    rng = np.random.default_rng(5)
    ids = _ragged_ids(rng, 9, COSTMODEL_SMALL.max_seq,
                      COSTMODEL_SMALL.vocab_size)
    got = KOPS.conv_forward_apply(params, ids, interpret=True)
    want = CM.conv_apply(params, ids)
    for t in CM.DEFAULT_HEADS:
        np.testing.assert_allclose(np.asarray(got[t]),
                                   np.asarray(want[t]),
                                   rtol=2e-4, atol=2e-4)


def test_conv_forward_fused_bf16():
    """bf16 params run bf16 HBM reads with f32 accumulation: output is
    float32 and close to the f32 reference at bf16 tolerance."""
    params = CM.conv_init(jax.random.PRNGKey(7), COSTMODEL_SMALL,
                          heads=CM.DEFAULT_HEADS)
    rng = np.random.default_rng(9)
    ids = _ragged_ids(rng, 6, COSTMODEL_SMALL.max_seq,
                      COSTMODEL_SMALL.vocab_size)
    got = KOPS.conv_forward_apply(_cast16(params), ids, interpret=True)
    want = REF.conv_forward_ref(params, ids)
    for t in CM.DEFAULT_HEADS:
        assert got[t].dtype == jnp.float32
        np.testing.assert_allclose(np.asarray(got[t]),
                                   np.asarray(want[t]),
                                   rtol=5e-2, atol=5e-2)


# --------------------------------------------------------- lstm_scan kernel
@pytest.mark.parametrize("shape", [(1, 16, 8), (5, 32, 16), (8, 64, 16)])
def test_lstm_scan_matches_ref(shape):
    B, S, H = shape
    rng = np.random.default_rng(hash(shape) % 2**31)
    xw = jnp.asarray(rng.normal(size=(B, S, 4 * H)) * 0.5, jnp.float32)
    mask = jnp.asarray(rng.random((B, S)) < 0.8, jnp.float32)
    mask = mask.at[0].set(0.0)          # one fully masked row
    wh = jnp.asarray(rng.normal(size=(H, 4 * H)) * 0.3, jnp.float32)
    got = lstm_scan_fused(xw, mask, wh, bblk=4, interpret=True)
    want = REF.lstm_scan_ref(xw, mask, wh)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    assert np.abs(np.asarray(got)[0]).max() == 0.0  # masked row: h stays 0


@pytest.mark.parametrize("heads", [None, CM.DEFAULT_HEADS])
@pytest.mark.parametrize("dtype", ["f32", "bf16"])
def test_lstm_forward_apply_matches_model(heads, dtype):
    """Pallas-recurrence forward vs core.models.lstm_apply, both head
    layouts and both dtypes (bf16 parity at bf16 tolerance — the kernel
    accumulates f32 where the jnp scan rounds per step)."""
    from repro.opt.evaluate import spearman
    params = CM.lstm_init(jax.random.PRNGKey(11), COSTMODEL_SMALL,
                          heads=heads)
    rng = np.random.default_rng(17)
    ids = _ragged_ids(rng, 7, COSTMODEL_SMALL.max_seq,
                      COSTMODEL_SMALL.vocab_size, all_pad_row=True)
    want = CM.lstm_apply(params, ids)
    p = _cast16(params) if dtype == "bf16" else params
    got = KOPS.lstm_forward_apply(p, ids, interpret=True)
    names = heads or [None]
    for t in names:
        w = np.asarray(want[t] if t else want, np.float32)
        g = np.asarray(got[t] if t else got, np.float32)
        if dtype == "f32":
            np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-5)
        else:
            np.testing.assert_allclose(g, w, rtol=1e-1, atol=1e-1)
            assert spearman(w, g) >= 0.9


def test_forward_apply_rejects_kernel_less_kinds():
    with pytest.raises(ValueError, match="conv1d"):
        KOPS.forward_apply("xformer", {}, jnp.zeros((1, 8), jnp.int32))


def test_decode_attention_ref_normalizes():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(2, 2, 4, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 2, 16, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 2, 16, 8)), jnp.float32)
    out = REF.decode_attention_ref(q, k, v, 7)
    assert out.shape == (2, 2, 4, 8)
    assert bool(jnp.isfinite(out).all())
