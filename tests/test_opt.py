"""repro.opt: rewrite legality, struct-key dedup, batched beam search
through the serving stack, and the closed-loop oracle acceptance bar."""
import jax
import numpy as np
import pytest

from repro.configs.costmodel import CostModelConfig
from repro.core import augment as AUG
from repro.core import models as CM
from repro.core import tokenizer as TOK
from repro.core import trainer as TR
from repro.core.server import CostModelServer
from repro.core.service import CostModelService
from repro.ir import analyzers, dataset as DS, samplers
from repro.ir.graph import FUSED_OP, Graph, Tensor
from repro.opt import evaluate as OE
from repro.opt import rewrites as RW
from repro.opt import search as SE


# --------------------------------------------------------------- fixtures
def _chain_graph():
    t = Tensor((8, 128))
    g = Graph(name="chain")
    a = g.add_arg(t)
    x = g.add_op("relu", [a], t)
    x = g.add_op("tanh", [x], t)
    x = g.add_op("sigmoid", [x], t)
    g.outputs = [x]
    return g


def _dead_op_graph():
    t = Tensor((4, 64))
    g = Graph(name="dead")
    a = g.add_arg(t)
    live = g.add_op("relu", [a], t)
    g.add_op("exp", [a], t)            # never used, not an output
    g.outputs = [live]
    return g


@pytest.fixture(scope="module")
def untrained_service():
    """Untrained multi-head service: scheduling/caching semantics only."""
    cfg = CostModelConfig(name="opt-test", vocab_size=512, max_seq=160,
                          embed_dim=16, conv_channels=(16,) * 6,
                          fc_dims=(32, 16))
    rng = np.random.default_rng(3)
    graphs = [samplers.sample_graph(rng) for _ in range(24)]
    vocab = TOK.fit_vocab([TOK.graph_tokens(g, "ops") for g in graphs],
                          max_size=512)
    params = CM.conv_init(jax.random.PRNGKey(0), cfg,
                          heads=CM.DEFAULT_HEADS)
    stats = {t: {"mu": 0.3, "sigma": 1.7} for t in CM.DEFAULT_HEADS}
    return CostModelService("conv1d", cfg, params, vocab, stats,
                            mode="ops", max_seq=160)


@pytest.fixture(scope="module")
def trained_service():
    """Cost model trained on a rewrite-augmented corpus, so fused/bf16
    IR is in-vocabulary and the search has real guidance."""
    cfg = CostModelConfig(name="opt-trained", vocab_size=4096, max_seq=160,
                          embed_dim=64, conv_channels=(64,) * 6,
                          fc_dims=(256, 64))
    ds = DS.build_dataset(600, mode="ops", max_seq=160, vocab_size=4096,
                          augment_factor=1, rewrite_factor=1, seed=9)
    tr, _ = ds.split(0.1)
    res = TR.TrainEngine("conv1d", cfg, CM.DEFAULT_HEADS, steps=250,
                         batch_size=128, lr=2e-3, seed=9).fit(tr)
    return CostModelService("conv1d", cfg, res.params, ds.vocab,
                            res.norm_stats, mode="ops", max_seq=160)


class CountingProxy:
    """Duck-typed service wrapper counting predict_all calls."""

    def __init__(self, svc):
        self.svc = svc
        self.calls = 0

    @property
    def heads(self):
        return self.svc.heads

    def resolve_target(self, t):
        return self.svc.resolve_target(t)

    def predict_all(self, graphs):
        self.calls += 1
        return self.svc.predict_all(graphs)


# ----------------------------------------------------------------- fusion
def test_fuse_emits_single_fused_op():
    """Satellite: a fused chain is ONE `fused` op with n_fused/chain
    attrs — visibly different IR text — not a re-emitted producer."""
    g = _chain_graph()
    f = RW.fuse_elementwise(g)
    assert len(f.ops) == 1                       # old chain collapsing
    op = f.ops[0]
    assert op.opcode == FUSED_OP
    assert op.attrs["n_fused"] == 3
    assert op.attrs["chain"] == "relu|tanh|sigmoid"
    assert f.values[f.outputs[0]] == g.values[g.outputs[0]]
    # the tokenizer sees the transform in the text
    assert "xpu.fused" in TOK.graph_tokens(f, "ops")
    # and the oracle charges one HBM round trip instead of three
    assert analyzers.latency_us(f) < analyzers.latency_us(g)
    assert analyzers.valu_utilization(f) == analyzers.valu_utilization(g)


def test_fuse_respects_fanout_and_outputs():
    """A multi-use intermediate (or one that is a graph output) must not
    be swallowed into a fusion group."""
    t = Tensor((8, 128))
    g = Graph(name="fanout")
    a = g.add_arg(t)
    x = g.add_op("relu", [a], t)
    y = g.add_op("tanh", [x], t)
    z = g.add_op("exp", [x], t)        # second consumer of x
    g.outputs = [y, z]
    f = RW.fuse_elementwise(g)
    assert len(f.ops) == 3             # nothing legal to fuse
    # fused chains re-fuse with downstream consumers (n_fused adds up)
    g2 = _chain_graph()
    s1 = RW.REGISTRY["fuse_elementwise"].applicable(g2)
    partial = RW.REGISTRY["fuse_elementwise"].apply(
        g2, RW.Site("fuse_elementwise", s1[0].detail[:2]))
    full = RW.fuse_elementwise(partial)
    assert len(full.ops) == 1 and full.ops[0].attrs["n_fused"] == 3


# -------------------------------------------------------------- struct key
def test_struct_key_invariant_under_renumber_and_reorder():
    """Satellite: canonical hash is stable under SSA id renumbering and
    topological re-scheduling of independent ops, and sensitive to any
    real structural change."""
    rng = np.random.default_rng(0)
    for fam in sorted(samplers.SAMPLERS):
        g = samplers.sample_graph(rng, fam)
        k = g.struct_key()
        for _ in range(4):
            r = AUG.reorder_ops(g, rng)   # re-schedule + renumber SSA
            assert r.struct_key() == k
        if g.ops:
            mut = AUG.reorder_ops(g, rng)
            mut.ops[-1].attrs = dict(mut.ops[-1].attrs, mutated=1)
            assert mut.struct_key() != k


def test_struct_key_is_the_service_lru_key(untrained_service):
    """The LRU and the search dedup share one canonical identity: a
    re-scheduled spelling of a cached program is a cache hit."""
    svc = untrained_service
    rng = np.random.default_rng(1)
    g = samplers.sample_graph(rng, "bert")
    assert svc.entry(g)[0] == g.struct_key()
    reordered = AUG.reorder_ops(g, rng)
    with svc._cache_lock:
        svc._cache.clear()
    out1 = svc.predict_all([g])
    out2 = svc.predict_all([reordered])
    assert len(svc._cache) == 1
    for t in svc.heads:
        np.testing.assert_array_equal(out1[t], out2[t])


# ---------------------------------------------------------------- legality
def _site_pool():
    """Sampled graphs from all five families + handcrafted graphs that
    guarantee every rule has at least one applicable site."""
    rng = np.random.default_rng(5)
    pool = [samplers.sample_graph(rng, fam)
            for fam in sorted(samplers.SAMPLERS) for _ in range(2)]
    pool += [_chain_graph(), _dead_op_graph()]
    return pool


def test_rewrite_legality_every_rule_every_site():
    """Satellite: every registered rule, at every applicable site of the
    pool, yields a validate()-clean graph with unchanged output shapes;
    CSE/DCE additionally never make any analyzer target worse (latency
    and vALU within float tolerance; pressure may grow by at most one
    live tile when a merged value's live range extends)."""
    pool = _site_pool()
    fired = {r.name: 0 for r in RW.default_rules()}
    for g in pool:
        base = analyzers.analyze(g)
        for rule in RW.default_rules():
            for site in rule.applicable(g):
                ng = rule.apply(g, site)   # check_legal runs inside
                fired[rule.name] += 1
                outs = [ng.values[o] for o in ng.outputs]
                want = [g.values[o] for o in g.outputs]
                if rule.preserves_outputs:
                    assert [t.shape for t in outs] == \
                        [t.shape for t in want]
                    if rule.preserves_dtypes:
                        assert outs == want
                else:                      # unroll: shapes per replica
                    n = len(want)
                    assert [t.shape for t in outs[:n]] == \
                        [t.shape for t in want]
                if rule.name in ("cse", "dce"):
                    after = analyzers.analyze(ng)
                    tol = 1e-9
                    assert after["latency_us"] <= \
                        base["latency_us"] * (1 + tol)
                    assert after["valu_utilization"] <= \
                        base["valu_utilization"]
                    assert after["register_pressure"] <= \
                        base["register_pressure"] + analyzers.TILE_VREGS
    assert all(n > 0 for n in fired.values()), fired


def test_oracle_equivalence_hook():
    """check_legal's pluggable oracle hook gates an apply."""
    g = _dead_op_graph()
    site = RW.REGISTRY["dce"].applicable(g)[0]
    ng = RW.REGISTRY["dce"].apply(g, site)
    RW.check_legal(g, ng, oracle_check=lambda a, b: (
        analyzers.latency_us(b) <= analyzers.latency_us(a)))
    with pytest.raises(AssertionError, match="oracle"):
        RW.check_legal(g, ng, oracle_check=lambda a, b: False)


# ------------------------------------------------------------------ search
def test_one_predict_all_per_frontier_expansion(untrained_service):
    """Acceptance: every frontier expansion is exactly ONE batched
    predict_all (+1 for costing the root)."""
    proxy = CountingProxy(untrained_service)
    rng = np.random.default_rng(2)
    g = samplers.sample_graph(rng, "bert")
    res = SE.beam_search(proxy, g, beam_width=3, max_steps=4,
                         eval_budget=64)
    assert res.expansions >= 1
    assert proxy.calls == 1 + res.expansions == res.predict_calls
    assert res.evaluated <= 64


def test_search_dedups_frontier_and_respects_budget(untrained_service):
    """Struct-key dedup: the same program derived through two rewrite
    orders is costed once; the candidate budget is a hard cap."""
    proxy = CountingProxy(untrained_service)
    rng = np.random.default_rng(4)
    g = samplers.sample_graph(rng, "bert")
    res = SE.beam_search(proxy, g, beam_width=4, max_steps=6,
                         record_candidates=True, eval_budget=48)
    keys = [c.struct_key() for c, _ in res.candidates]
    assert len(keys) == len(set(keys))
    assert res.evaluated <= 48


def test_greedy_mode_stops_and_unroll_needs_optin(untrained_service):
    g = _chain_graph()
    res = SE.greedy_search(untrained_service, g,
                           rules=[RW.REGISTRY["fuse_elementwise"]])
    # one chain -> at most one improving step, then a stopping expansion
    assert len(res.best_seq) <= 1
    # output-arity-changing rules never become replacement candidates
    # unless explicitly admitted
    res2 = SE.beam_search(untrained_service, g,
                          rules=[RW.Unroll(factors=(2,))], max_steps=2)
    assert res2.evaluated == 0
    res3 = SE.beam_search(untrained_service, g,
                          rules=[RW.Unroll(factors=(2,))], max_steps=1,
                          preserve_outputs=False)
    assert res3.evaluated == 1


def test_objective_register_budget_constrains(untrained_service):
    """The composite objective is a hard constraint: candidates over the
    register budget score inf and the incumbent survives."""
    # expm1-denormalized pressure is always > -1: nothing is feasible
    obj = SE.Objective(register_budget=-1.0)
    rng = np.random.default_rng(6)
    g = samplers.sample_graph(rng, "bert")
    res = SE.beam_search(untrained_service, g, objective=obj, max_steps=2)
    assert res.best_seq == [] and res.best is g


def test_objective_refuses_budget_without_pressure_head(untrained_service):
    """Requesting a finite register budget against a service that cannot
    serve the pressure head is an error, never a silently-dropped
    constraint (same policy as UnrollAdvisor)."""
    svc = untrained_service
    single = CostModelService(
        "conv1d", svc.cfg,
        CM.conv_init(jax.random.PRNGKey(0), svc.cfg), svc.vocab,
        {"mu": 0.0, "sigma": 1.0}, mode="ops", max_seq=svc.max_seq,
        target="latency_us")
    with pytest.raises(ValueError, match="register_budget"):
        SE.Objective(register_budget=64.0).bind(single)
    # infinite budget: pure latency, no pressure head needed
    assert SE.Objective().bind(single).reg_t is None


# ------------------------------------------------- closed loop / acceptance
def test_replay_reproduces_search(untrained_service):
    rng = np.random.default_rng(8)
    g = samplers.sample_graph(rng, "bert")
    res = SE.beam_search(untrained_service, g, beam_width=3, max_steps=3)
    final = OE.replay(res)
    assert final.struct_key() == res.best.struct_key()


def test_beam_search_beats_fusion_baseline_on_oracle(trained_service):
    """Acceptance bar: over >=20 graphs from all five samplers, beam
    search with the full rule set — served through the async
    micro-batching gateway — achieves mean ORACLE latency no worse than
    the one-shot greedy fusion baseline, strictly better on at least a
    quarter, with every expansion one batched predict_all."""
    rng = np.random.default_rng(10)
    fams = sorted(samplers.SAMPLERS)
    graphs = [samplers.sample_graph(rng, fams[i % len(fams)])
              for i in range(20)]
    with CostModelServer(trained_service, max_batch=64,
                         flush_us=500) as server:
        report = OE.evaluate_search(server, graphs, beam_width=3,
                                    max_steps=4, eval_budget=128)
    s = report["summary"]
    assert s["n_graphs"] == 20
    assert s["mean_oracle_best_us"] <= s["mean_oracle_baseline_us"] + 1e-9
    assert s["frac_strictly_better_than_baseline"] >= 0.25
    # per-graph: every frontier expansion was one batched predict_all
    # (plus the single root-costing call)
    for r in report["per_graph"]:
        assert r["predict_calls"] == 1 + r["expansions"]
    # rank correlation is reported at both granularities: pooled (graphs
    # of different sizes — the model must at least order those) and mean
    # within-search (near-tie candidates; noisy by nature, so only its
    # presence/range is asserted — the oracle outcomes above are the bar)
    assert s["spearman_pred_oracle_pooled"] > 0.3
    assert -1.0 <= s["spearman_pred_oracle"] <= 1.0


def test_advisors_are_search_wrappers(trained_service):
    """The migrated advisors keep their contracts on a trained model."""
    from repro.core.service import FusionAdvisor, UnrollAdvisor
    rng = np.random.default_rng(11)
    fusion = FusionAdvisor(trained_service)
    do_fuse, c0, c1 = fusion.advise(_chain_graph())
    assert isinstance(do_fuse, bool) and c0 > 0 and c1 > 0
    unroll = UnrollAdvisor(trained_service, register_budget=1e9)
    out = unroll.advise(samplers.sample_graph(rng, "bert"),
                        factors=(1, 2, 4))
    assert out["best_factor"] in (1, 2, 4)
    assert set(out["per_iter_latency"]) == {1, 2, 4}


# ----------------------------------------------------------------- dataset
def test_dataset_rewrite_factor_streaming_determinism():
    """rewrite_factor rides the two-pass count-then-encode build: two
    builds are identical, row count scales, and targets stay finite."""
    kw = dict(mode="ops", max_seq=96, vocab_size=1024, augment_factor=1,
              rewrite_factor=1, seed=13)
    d1 = DS.build_dataset(30, **kw)
    d2 = DS.build_dataset(30, **kw)
    assert len(d1) == 60
    np.testing.assert_array_equal(d1.ids, d2.ids)
    for t in d1.targets:
        np.testing.assert_array_equal(d1.targets[t], d2.targets[t])
        assert np.isfinite(d1.targets[t]).all()
    # rewritten rows really differ from their base graphs somewhere
    assert any((d1.ids[2 * i + 1] != d1.ids[2 * i]).any()
               for i in range(30))
