"""Cross-formulation consistency tests.

These validate the *optimized* code paths against naive references:
* fused (chunked) unembed+loss == naive full-logits cross entropy;
* recurrent decode (mLSTM step / mamba step / KV-cache attention) matches
  the parallel train/prefill formulation token-by-token;
* chunked MoE dispatch == unchunked.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import model as MODEL
from repro.models import steps as STEPS

B, S = 2, 16


def _batch(cfg, rng):
    b = {"tokens": jnp.asarray(rng.integers(1, cfg.vocab, (B, S)),
                               jnp.int32)}
    if cfg.frontend == "audio":
        b["frame_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)) * 0.02,
            jnp.float32)
    if cfg.frontend == "vision":
        b["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.vision_patches, cfg.d_model)) * 0.02,
            jnp.float32)
    return b


def test_fused_loss_equals_naive():
    cfg = get_arch("qwen3-0.6b").reduced()
    rng = np.random.default_rng(0)
    params = MODEL.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, rng)
    labels = jnp.asarray(rng.integers(-1, cfg.vocab, (B, S)), jnp.int32)
    logits, _ = MODEL.forward(params, cfg, batch, cdt=jnp.float32)
    naive = STEPS.cross_entropy_loss(logits, labels, cfg.vocab)
    h, _ = MODEL.forward(params, cfg, batch, cdt=jnp.float32,
                         unembed=False)
    fused = STEPS.fused_unembed_loss(
        h, MODEL.unembed_table(params, cfg), labels, cfg.vocab, chunk=5)
    np.testing.assert_allclose(float(fused), float(naive), rtol=1e-5)


@pytest.mark.parametrize("name", ["xlstm-125m", "jamba-v0.1-52b"])
def test_recurrent_decode_matches_parallel(name):
    """Chunkwise/associative-scan training formulations vs O(1) decode.

    MoE capacity dropping is chunk-size dependent by design (training-time
    regularization); boost the capacity factor so routing is dropless and
    the two paths are comparable."""
    cfg = get_arch(name).reduced()
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    rng = np.random.default_rng(1)
    params = MODEL.init_params(jax.random.PRNGKey(1), cfg)
    batch = _batch(cfg, rng)
    logits_par, _ = MODEL.forward(params, cfg, batch, cdt=jnp.float32,
                                  remat=False)
    cache = MODEL.init_cache(cfg, B, S, kv_dtype=jnp.float32)
    toks = batch["tokens"]
    outs = []
    for i in range(S):
        lg, cache = MODEL.decode_forward(params, cfg, toks[:, i:i + 1],
                                         cache, jnp.int32(i),
                                         cdt=jnp.float32)
        outs.append(lg)
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(logits_par, np.float32), rtol=5e-2, atol=5e-2)


def test_whisper_decode_matches_forward():
    cfg = get_arch("whisper-small").reduced()
    rng = np.random.default_rng(2)
    params = MODEL.init_params(jax.random.PRNGKey(2), cfg)
    batch = _batch(cfg, rng)
    logits_par, _ = MODEL.forward(params, cfg, batch, cdt=jnp.float32,
                                  remat=False)
    cache = MODEL.init_cache(cfg, B, S, kv_dtype=jnp.float32)
    enc_out = MODEL._run_encoder(params, cfg, batch["frame_embeds"],
                                 None, jnp.float32)
    cache["enc_out"] = enc_out.astype(jnp.float32) \
        if cache["enc_out"].dtype == jnp.float32 else \
        enc_out.astype(cache["enc_out"].dtype)
    toks = batch["tokens"]
    outs = []
    for i in range(S):
        lg, cache = MODEL.decode_forward(params, cfg, toks[:, i:i + 1],
                                         cache, jnp.int32(i),
                                         cdt=jnp.float32)
        outs.append(lg)
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(logits_par, np.float32), rtol=5e-2, atol=5e-2)


def _moe_chunk_outputs(cfg, chunk_sizes):
    """moe_apply output for each chunk size, same params/inputs."""
    from repro.models import moe as M
    params = MODEL.init_params(jax.random.PRNGKey(3), cfg)
    p = jax.tree.map(lambda x: x[0], params["layers"])["moe"]
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(2, 12, cfg.d_model)) * 0.1,
                    jnp.float32)
    old = M.MOE_CHUNK
    outs = []
    try:
        for c in chunk_sizes:
            M.MOE_CHUNK = c
            out, _ = M.moe_apply(p, x, cfg, cdt=jnp.float32)
            outs.append(np.asarray(out))
    finally:
        M.MOE_CHUNK = old
    return outs


def test_moe_chunking_invariance_dropless():
    """Chunked dispatch == unchunked where the property truly holds.

    Capacity dropping is *chunk-local by design* (the per-chunk slot
    cumsum is the training-time regularizer), so exact invariance only
    holds when capacity covers every assignment. cf = n_experts makes
    per-chunk capacity >= chunk_tokens * top_k — dropless — and then the
    chunking must be numerics-exact."""
    cfg = get_arch("granite-moe-1b-a400m").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe,
                                     capacity_factor=float(cfg.moe.n_experts)))
    full, chunked = _moe_chunk_outputs(cfg, [12, 4])
    np.testing.assert_allclose(chunked, full, rtol=1e-5, atol=1e-6)


def test_moe_chunking_bounded_drop_disagreement():
    """In the droppy default regime (cf=1.25) chunkings legitimately drop
    different tokens: a token kept under one chunking can overflow its
    expert's (smaller) per-chunk capacity under another. Tolerate that —
    but only on a bounded fraction of tokens, and never with exploding
    magnitude (a regression here would indicate a real dispatch bug, not
    capacity policy)."""
    cfg = get_arch("granite-moe-1b-a400m").reduced()
    full, chunked = _moe_chunk_outputs(cfg, [12, 4])
    tok_diff = np.abs(chunked - full).max(axis=-1)      # (B, S)
    disagree = tok_diff > 1e-4
    assert disagree.mean() <= 0.25, \
        f"{disagree.mean():.1%} of tokens differ (expect only capacity drops)"
    # a dropped expert contribution is bounded by the combine weights
    assert float(tok_diff.max()) < 1.0
