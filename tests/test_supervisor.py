"""Supervision, graceful degradation, and the fault harness: scale
policy / restart budget units, fault-plan determinism + FaultyTransport
message faults, shared-cache torn-write and wedged-lock degradation,
the router's analyzer-oracle floor / deadline budget / decorrelated
jitter / ring-resize behavior (fake transports, no processes), and one
real spawned tier exercised end to end: wedge detection -> in-slot
respawn, then signal-driven scale-up and scale-down."""
import hashlib
import multiprocessing as mp
import os
import queue
import random
import signal
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs.costmodel import CostModelConfig
from repro.core import models as CM
from repro.core import tokenizer as TOK
from repro.core.server import ServerOverloadedError
from repro.core.service import CostModelService
from repro.ir import samplers
from repro.serving import (FaultEvent, FaultPlan, FaultyTransport,
                           ReplicaClient, ReplicaSupervisor,
                           RestartBudget, ScalePolicy, ServiceSpec,
                           SharedRowCache, start_replicas)
from repro.serving import transport as T
from repro.serving.faults import corrupt_slot
from repro.serving.shared_cache import _DIGEST

CFG = CostModelConfig(name="sup-test", vocab_size=512, max_seq=64,
                      embed_dim=16, conv_channels=(16,) * 2,
                      fc_dims=(32,))


def _sha_keys(n, salt=""):
    return [hashlib.sha1(f"{salt}k{i}".encode()).hexdigest()
            for i in range(n)]


def _entries(n, salt=""):
    return [(k, np.arange(4, dtype=np.int32))
            for k in _sha_keys(n, salt=salt)]


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(23)
    graphs = [samplers.sample_graph(rng) for _ in range(16)]
    vocab = TOK.fit_vocab([TOK.graph_tokens(g, "ops") for g in graphs],
                          max_size=512)
    return graphs, vocab


@pytest.fixture(scope="module")
def service(corpus):
    _, vocab = corpus
    params = CM.conv_init(jax.random.PRNGKey(5), CFG,
                          heads=CM.DEFAULT_HEADS)
    stats = {t: {"mu": 0.2, "sigma": 1.3} for t in CM.DEFAULT_HEADS}
    return CostModelService("conv1d", CFG, params, vocab, stats,
                            mode="ops", max_seq=64, max_batch=8,
                            buckets=(32, 64), batch_ladder=(1, 2, 4, 8))


@pytest.fixture(scope="module")
def spec(service):
    return ServiceSpec.from_service(service)


# --------------------------------------------------- scale policy (unit)
def test_scale_policy_scales_up_on_pressure():
    p = ScalePolicy(min_replicas=1, max_replicas=4)
    assert p.decide(2, [{"shed_delta": 1.0}]) == 3
    assert p.decide(2, [{"queue_depth": 100.0}]) == 3
    assert p.decide(4, [{"shed_delta": 5.0}]) == 4        # capped
    assert p.decide(2, [{"queue_depth": 0.0,
                         "arrival_per_s": 3.0}]) == 2     # steady
    assert p.decide(2, []) == 2                           # blind: hold
    # client-side shed / cooldown signals also count as pressure
    assert p.decide(2, [{"queue_depth": 0.0, "arrival_per_s": 3.0}],
                    router={"shed_count": 1}) == 3
    assert p.decide(2, [{"queue_depth": 0.0, "arrival_per_s": 3.0}],
                    router={"shed_count": 0, "unhealthy_now": 1}) == 3


def test_scale_policy_scale_down_waits_settle():
    p = ScalePolicy(min_replicas=1, max_replicas=4, settle_ticks=3)
    quiet = [{"arrival_per_s": 0.0}]
    assert p.decide(3, quiet) == 3
    assert p.decide(3, quiet) == 3
    assert p.decide(3, quiet) == 2       # third consecutive quiet tick
    # a busy tick resets the settle counter
    assert p.decide(2, quiet) == 2
    assert p.decide(2, [{"arrival_per_s": 10.0}]) == 2
    assert p.decide(2, quiet) == 2
    assert p.decide(2, quiet) == 2
    assert p.decide(2, quiet) == 1
    assert p.decide(1, quiet) == 1       # floor holds


# ------------------------------------------------- restart budget (unit)
def test_restart_budget_escalates_and_trips():
    b = RestartBudget(backoff_s=0.5, max_restarts=3, window_s=60.0,
                      cap_s=4.0)
    assert b.next_delay(0.0) == 0.0      # first failure: immediate
    b.note_restart(0.0)
    assert b.next_delay(1.0) == 0.5
    b.note_restart(1.0)
    assert b.next_delay(2.0) == 1.0
    b.note_restart(2.0)
    assert b.crash_looping(3.0)
    # window expiry forgives the slot
    assert not b.crash_looping(100.0)
    assert b.next_delay(100.0) == 0.0


def test_restart_budget_caps_delay():
    b = RestartBudget(backoff_s=1.0, max_restarts=10, window_s=1e6,
                      cap_s=3.0)
    for t in range(6):
        b.note_restart(float(t))
    assert b.next_delay(6.0) == 3.0


# ---------------------------------------------------- fault plan (unit)
def test_fault_plan_fires_in_order_once():
    plan = FaultPlan([FaultEvent(at=5, kind="drop"),
                      FaultEvent(at=1, kind="kill", replica=2),
                      FaultEvent(at=5, kind="dup", replica=1)], seed=7)
    assert [e.kind for e in plan.events] == ["kill", "drop", "dup"]
    assert plan.due(0) == []
    assert [e.kind for e in plan.due(3)] == ["kill"]
    assert plan.due(3) == []             # each event fires exactly once
    assert [e.kind for e in plan.due(5)] == ["drop", "dup"]
    assert plan.exhausted


def test_fault_plan_seeded_rng_replayable():
    a = FaultPlan([], seed=123).rng.random()
    b = FaultPlan([], seed=123).rng.random()
    assert a == b


class _RecorderTransport:
    """Inner transport that just records sends (FaultyTransport duck)."""

    def __init__(self, n=4):
        self.n_replicas = n
        self.client_id = 0
        self.sent = []

    def send(self, replica, msg):
        self.sent.append((replica, msg))

    def recv(self, timeout):
        raise queue.Empty


def _req(key="k"):
    return (T.MSG_REQ, 0, 1, [key], b"", b"")


def test_faulty_transport_message_faults():
    inner = _RecorderTransport()
    plan = FaultPlan([FaultEvent(at=0, kind="drop", replica=0),
                      FaultEvent(at=1, kind="dup", replica=1),
                      FaultEvent(at=2, kind="delay", replica=2,
                                 delay_s=0.05)])
    ft = FaultyTransport(inner, plan)
    ft.send(0, _req("a"))                # dropped
    assert inner.sent == []
    ft.send(1, _req("b"))                # duplicated
    assert len(inner.sent) == 2
    ft.send(2, _req("c"))                # delayed: lands later
    assert len(inner.sent) == 2
    deadline = time.monotonic() + 5.0
    while len(inner.sent) < 3 and time.monotonic() < deadline:
        time.sleep(0.01)
    assert len(inner.sent) == 3 and inner.sent[-1][0] == 2
    kinds = [e["kind"] for e in ft.log]
    for k in ("drop", "dropped", "dup", "duplicated", "delay",
              "delayed"):
        assert k in kinds
    assert plan.exhausted


def test_faulty_transport_spares_control_traffic():
    inner = _RecorderTransport()
    plan = FaultPlan([FaultEvent(at=0, kind="drop", replica=0,
                                 count=2)])
    ft = FaultyTransport(inner, plan)
    ft.send(0, (T.MSG_STATS, 0, 1))      # control RPC: never dropped
    assert len(inner.sent) == 1
    ft.send(0, _req("x"))                # the request eats the drop
    assert len(inner.sent) == 1


def test_faulty_transport_process_faults_need_tier():
    inner = _RecorderTransport()
    ft = FaultyTransport(inner, FaultPlan(
        [FaultEvent(at=0, kind="kill", replica=0)]))
    ft.send(0, _req())
    assert ft.log[0]["kind"] == "kill"
    assert ft.log[0]["applied"] is False   # no tier to signal


# -------------------------------------- shared cache hardening (unit)
def test_corrupt_slot_detected_by_crc():
    c = SharedRowCache(n_heads=3, n_slots=64)
    key = "d" * 40
    c.put(key, np.array([1.0, 2.0, 3.0], np.float32))
    assert corrupt_slot(c, key, random.Random(5))
    assert c.get(key) is None            # torn payload reads as a miss
    assert c.torn_drops == 1
    assert c.get(key) is None            # slot dropped, crc paid once
    assert c.torn_drops == 1
    assert not corrupt_slot(c, "e" * 40)   # absent key: nothing to tear


def test_faulty_transport_corrupts_shared_cache():
    c = SharedRowCache(n_heads=2, n_slots=32)
    c.put("f" * 40, np.array([5.0, 6.0], np.float32))
    ft = FaultyTransport(_RecorderTransport(), FaultPlan(
        [FaultEvent(at=0, kind="corrupt", key="f" * 40)]),
        shared_cache=c)
    ft.send(0, _req())
    assert ft.log[0]["applied"] is True
    assert c.get("f" * 40) is None


def test_shared_cache_torn_write_reads_as_miss():
    c = SharedRowCache(n_heads=2, n_slots=32)
    c.put("c" * 40, np.array([9.0, -9.0], np.float32))
    view = c._view()
    s = next(i for i in range(c.n_slots) if view[i][0])
    view[s][1 + _DIGEST] ^= 0xFF         # flip one row byte
    assert c.get("c" * 40) is None
    assert c.torn_drops == 1


def test_shared_cache_wedged_lock_degrades_and_recovers():
    c = SharedRowCache(n_heads=2, n_slots=32, lock_timeout_s=0.05)
    c.put("a" * 40, np.array([1.0, 2.0], np.float32))
    assert c._lock.acquire()             # simulate a dead holder
    assert c.get("a" * 40) is None       # bounded miss, no wedge
    c.put("b" * 40, np.array([3.0, 4.0], np.float32))   # skipped
    assert c.fill() == -1
    assert c.clear() is False
    assert c.lock_timeouts >= 3
    assert c.recover(timeout_s=0.05) is True
    np.testing.assert_array_equal(c.get("a" * 40), [1.0, 2.0])
    assert c.get("b" * 40) is None       # the publish really skipped
    assert c.recover(timeout_s=0.05) is False   # healthy lock: no-op
    st = c.stats()
    assert st["lock_timeouts"] >= 3 and st["fill"] == 1


# ------------------------------------ degradation ladder (fake tier)
class _ScriptedTransport:
    """Uniform-fate fake tier: every request is answered "ok" (constant
    rows), shed with MSG_OVERLOAD, or silently dropped."""

    def __init__(self, n_replicas=2, mode="overload", n_heads=3):
        self.n_replicas = n_replicas
        self.client_id = 0
        self.mode = mode
        self.n_heads = n_heads
        self.q = queue.Queue()
        self.reqs = []                   # (replica, keys)

    def send(self, replica, msg):
        if msg[0] != T.MSG_REQ:
            return
        _, _c, bid, keys, _l, _i = msg
        self.reqs.append((replica, list(keys)))
        if self.mode == "ok":
            rows_b, nh = T.pack_rows(
                [np.full(self.n_heads, 0.5, np.float32) for _ in keys])
            self.q.put((T.MSG_RES, bid, list(range(len(keys))),
                        rows_b, nh))
        elif self.mode == "overload":
            self.q.put((T.MSG_OVERLOAD, bid, list(range(len(keys))),
                        0.0))
        # "drop": no reply at all

    def recv(self, timeout):
        return self.q.get(timeout=timeout)


def test_router_oracle_fallback_matches_analyzers(corpus, spec):
    from repro.ir.analyzers import TARGETS
    graphs, _ = corpus
    client = ReplicaClient(transport=_ScriptedTransport(), spec=spec,
                           oracle_fallback=True, max_retries=1,
                           backoff_s=0.001, timeout_s=0.25,
                           cooldown_s=0.01)
    out = client.predict_all(graphs)     # no raise: the oracle floor
    n_uniq = len({g.struct_key() for g in graphs})
    assert client.degraded_count == n_uniq
    for t, fn in TARGETS.items():        # degraded == analyzer oracle
        if t in out:
            want = np.array([fn(g) for g in graphs], np.float32)
            np.testing.assert_allclose(out[t], want, rtol=1e-4)
    st = client.stats()
    assert st["degraded_count"] == n_uniq
    assert client.fsvc.phase_stats()["degraded_preds"] == n_uniq
    # degraded rows are never cached: a repeat degrades again instead
    # of serving stale oracle values as if the tier had answered
    client.predict_all(graphs)
    assert client.degraded_count == 2 * n_uniq


def test_router_without_fallback_sheds(corpus, spec):
    graphs, _ = corpus
    client = ReplicaClient(transport=_ScriptedTransport(), spec=spec,
                           oracle_fallback=False, max_retries=1,
                           backoff_s=0.001, timeout_s=0.25,
                           cooldown_s=0.01)
    with pytest.raises(ServerOverloadedError):
        client.predict_all(graphs)
    assert client.degraded_count == 0


def test_router_deadline_budget_degrades_fast(corpus, spec):
    graphs, _ = corpus
    client = ReplicaClient(transport=_ScriptedTransport(mode="drop"),
                           spec=spec, oracle_fallback=True,
                           deadline_s=0.3, timeout_s=30.0,
                           backoff_s=0.001, cooldown_s=0.01)
    t0 = time.monotonic()
    out = client.predict_all(graphs)
    took = time.monotonic() - t0
    assert took < 5.0                    # 30s round timeout was clamped
    assert client.deadline_expired >= 1
    assert client.degraded_count > 0
    assert set(out) == set(client.heads)


def test_backoff_jitter_decorrelated_and_bounded(corpus, spec,
                                                 monkeypatch):
    graphs, _ = corpus
    sleeps = []
    monkeypatch.setattr(time, "sleep", lambda s: sleeps.append(s))

    def run(seed):
        sleeps.clear()
        client = ReplicaClient(transport=_ScriptedTransport(),
                               spec=spec, oracle_fallback=True,
                               max_retries=4, backoff_s=0.01,
                               backoff_mult=2.0, timeout_s=0.25,
                               cooldown_s=0.001, jitter_seed=seed)
        client.predict_all(graphs[:4])
        return list(sleeps)

    a, b, c = run(1), run(2), run(1)
    assert a == c                        # seeded: replayable
    assert a != b                        # decorrelated across clients
    cap = 0.01 * 2.0 ** 4                # old exponential ceiling
    for s in a + b:
        assert 0.01 - 1e-9 <= s <= cap + 1e-9


def test_client_ring_tracks_published_active_count(spec):
    tr = _ScriptedTransport(n_replicas=4, mode="ok")
    tr.active = mp.Value("i", 2)         # supervisor-published count
    client = ReplicaClient(transport=tr, spec=spec, local_cache=False)
    assert client.ring.n_replicas == 2
    assert len(client.health) == 4       # sized for the slot maximum
    client._fetch(_entries(64, salt="pre"))
    assert {r for r, _ in tr.reqs} <= {0, 1}
    tr.active.value = 4                  # scale-up published
    tr.reqs.clear()
    client._fetch(_entries(64, salt="post"))
    assert client.ring.n_replicas == 4
    assert {r for r, _ in tr.reqs} == {0, 1, 2, 3}
    tr.active.value = 3                  # scale-down published
    tr.reqs.clear()
    client._fetch(_entries(64, salt="down"))
    assert client.ring.n_replicas == 3
    assert {r for r, _ in tr.reqs} <= {0, 1, 2}


# --------------------------------------------------- real spawned tier
@pytest.fixture(scope="module")
def tier(spec):
    """Two live replicas with one pre-allocated headroom slot."""
    tier = start_replicas(spec, 2, n_clients=2, flush_us=300.0,
                          start_timeout_s=240.0, max_replicas=3)
    yield tier
    tier.stop()


def _wait(pred, timeout_s, tick=0.25):
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        if pred():
            return True
        time.sleep(tick)
    return pred()


def test_supervisor_respawns_wedged_replica(corpus, service, tier):
    """SIGSTOP leaves the process alive but heartbeat-silent — exactly
    the failure is_alive() can't see. The supervisor must detect the
    wedge, SIGKILL, respawn into the same slot, and the tier must keep
    answering correctly throughout."""
    graphs, _ = corpus
    want = service.predict_all(graphs)
    sup = ReplicaSupervisor(tier, heartbeat_s=0.25,
                            heartbeat_timeout_s=3.0,
                            restart_backoff_s=0.1,
                            start_timeout_s=240.0).start()
    try:
        client = ReplicaClient(tier.client_handle(0), local_cache=False,
                               timeout_s=2.0, cooldown_s=0.05)
        got = client.predict_all(graphs)
        for t in want:
            np.testing.assert_allclose(got[t], want[t], rtol=1e-6)
        os.kill(tier.procs[1].pid, signal.SIGSTOP)      # wedge, not die
        assert _wait(lambda: any(
            r["replica"] == 1 and r["reason"] == "wedged"
            and "recovered_in_s" in r
            for r in sup.stats()["restart_log"]), 240.0)
        st = sup.stats()
        assert st["restarts_total"] >= 1
        assert st["restarts_recovered"] >= 1
        assert st["recovery_s_max"] > 0.0
        assert all(tier.alive()[:2])
        time.sleep(0.2)                  # let routing cooldowns expire
        got = client.predict_all(graphs)     # correct after recovery
        for t in want:
            np.testing.assert_allclose(got[t], want[t], rtol=1e-6)
        # supervisor counters ride the one metrics registry
        from repro.obs import MetricsRegistry, register_supervisor
        reg = MetricsRegistry()
        register_supervisor(reg, sup)
        m = reg.snapshot()["metrics"]
        assert m["supervisor.restarts_total"] >= 1
        assert m["supervisor.restarts_recovered"] >= 1
        # the narrative restart log stays out of the metrics payload
        assert not any(k.startswith("supervisor.restart_log")
                       for k in m)
    finally:
        sup.stop()


def test_supervisor_scales_up_then_down(corpus, service, tier):
    """Pressure signals grow the tier into the pre-allocated slot (the
    new count published only after the newcomer warms), and sustained
    quiet shrinks it back; a live client's ring follows both moves."""
    graphs, _ = corpus
    want = service.predict_all(graphs)
    client = ReplicaClient(tier.client_handle(1), local_cache=False,
                           timeout_s=5.0)
    assert client.ring.n_replicas == 2
    hot = ScalePolicy(min_replicas=2, max_replicas=3,
                      high_queue_depth=-1.0)   # every signal reads hot
    sup = ReplicaSupervisor(tier, heartbeat_s=0.25,
                            heartbeat_timeout_s=30.0, scale=hot,
                            scale_interval_s=0.5,
                            start_timeout_s=240.0).start()
    try:
        assert _wait(lambda: tier.active.value == 3, 240.0)
        assert sup.stats()["scale_ups"] >= 1
    finally:
        sup.stop()
    got = client.predict_all(graphs)     # ring follows the publish
    assert client.ring.n_replicas == 3
    for t in want:
        np.testing.assert_allclose(got[t], want[t], rtol=1e-6)
    quiet = ScalePolicy(min_replicas=2, max_replicas=3,
                        high_queue_depth=1e9, low_rate_per_s=1e9,
                        settle_ticks=2)
    sup = ReplicaSupervisor(tier, heartbeat_s=0.25,
                            heartbeat_timeout_s=30.0, scale=quiet,
                            scale_interval_s=0.3,
                            start_timeout_s=240.0).start()
    try:
        assert _wait(lambda: tier.active.value == 2, 120.0)
        assert sup.stats()["scale_downs"] >= 1
        # the retired slot drains its MSG_STOP and exits
        assert _wait(lambda: not tier.procs[2].is_alive(), 60.0)
    finally:
        sup.stop()
    got = client.predict_all(graphs)
    assert client.ring.n_replicas == 2
    for t in want:
        np.testing.assert_allclose(got[t], want[t], rtol=1e-6)
    assert client.shed_count == 0
