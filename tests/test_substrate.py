"""Substrate tests: checkpointing (atomic/resume/elastic), data pipeline
determinism, optimizer, gradient compression, fault-tolerance supervisor."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.data import pipeline as PIPE
from repro.optim import adamw, compress
from repro.runtime import fault


# ------------------------------------------------------------- checkpoint
def _state():
    return {"w": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((5,)), "count": jnp.int32(7)}}


def test_ckpt_save_restore_roundtrip(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 10, _state(), extra={"loader": {"epoch": 1}})
    like = jax.tree.map(jnp.zeros_like, _state())
    restored, step, extra = ckpt.restore(d, like, verify=True)
    assert step == 10 and extra["loader"]["epoch"] == 1
    np.testing.assert_allclose(restored["w"], _state()["w"])


def test_ckpt_picks_newest_committed_and_gc(tmp_path):
    d = str(tmp_path / "ck")
    for s in [1, 2, 3, 4, 5]:
        ckpt.save(d, s, _state(), keep=3)
    assert ckpt.latest_steps(d) == [3, 4, 5]
    _, step, _ = ckpt.restore(d, _state())
    assert step == 5


def test_ckpt_ignores_uncommitted(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, _state())
    # simulate crash mid-save: committed dir without marker
    os.makedirs(os.path.join(d, "step_000000002"))
    _, step, _ = ckpt.restore(d, _state())
    assert step == 1


def test_ckpt_shape_mismatch_raises(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, _state())
    bad = {"w": jnp.zeros((2, 2)),
           "nested": {"b": jnp.ones((5,)), "count": jnp.int32(0)}}
    with pytest.raises(AssertionError):
        ckpt.restore(d, bad)


def test_ckpt_elastic_reshard_onto_mesh(tmp_path):
    """Restore with explicit shardings (1-device mesh stands in for the
    re-meshed cluster — the code path is identical)."""
    d = str(tmp_path / "ck")
    ckpt.save(d, 3, _state())
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    shardings = jax.tree.map(lambda _: sh, _state())
    restored, step, _ = ckpt.restore(d, _state(), shardings=shardings)
    assert restored["w"].sharding == sh


# ------------------------------------------------------------- pipeline
def test_loader_deterministic_and_sharded():
    src = PIPE.ArraySource(x=np.arange(64).reshape(64, 1))
    a = PIPE.Loader(src, 8, seed=3, shard_index=0, num_shards=2)
    b = PIPE.Loader(src, 8, seed=3, shard_index=1, num_shards=2)
    ba = next(iter(a))["x"]
    bb = next(iter(b))["x"]
    assert ba.shape == (4, 1) and bb.shape == (4, 1)
    assert set(ba.ravel()).isdisjoint(set(bb.ravel()))
    # deterministic across re-instantiation
    ba2 = next(iter(PIPE.Loader(src, 8, seed=3, shard_index=0,
                                num_shards=2)))["x"]
    np.testing.assert_array_equal(ba, ba2)


def test_loader_resumes_from_cursor():
    src = PIPE.ArraySource(x=np.arange(64).reshape(64, 1))
    l1 = PIPE.Loader(src, 8, seed=0)
    it = iter(l1)
    first = [next(it)["x"] for _ in range(3)]
    cursor = PIPE.LoaderState(**l1.state.as_dict())
    l2 = PIPE.Loader(src, 8, seed=0, state=cursor)
    fourth = next(iter(l2))["x"]
    it_ref = iter(PIPE.Loader(src, 8, seed=0))
    for _ in range(3):
        next(it_ref)
    np.testing.assert_array_equal(fourth, next(it_ref)["x"])


def test_loader_honors_drop_remainder():
    src = PIPE.ArraySource(x=np.arange(70).reshape(70, 1))
    dropped = PIPE.Loader(src, 16, seed=0, drop_remainder=True)
    kept = PIPE.Loader(src, 16, seed=0, drop_remainder=False)
    assert dropped.steps_per_epoch() == 4
    assert kept.steps_per_epoch() == 5
    it = iter(kept)
    sizes = [len(next(it)["x"]) for _ in range(5)]
    assert sorted(sizes) == [6, 16, 16, 16, 16]
    # sharded: the tail is trimmed to a multiple of num_shards
    sh = PIPE.Loader(src, 16, seed=0, drop_remainder=False, num_shards=4)
    tail = [len(b["x"]) for b, _ in zip(iter(sh), range(5))]
    assert sorted(tail) == [1, 4, 4, 4, 4]   # 6 -> 4 rows, 1 per shard


def test_loader_empty_epoch_raises_not_hangs():
    """batch_size > usable rows must fail loudly on the consumer thread
    (a dead producer would otherwise block q.get() forever)."""
    src = PIPE.ArraySource(x=np.arange(10).reshape(10, 1))
    with pytest.raises(ValueError, match="empty epoch"):
        iter(PIPE.Loader(src, 16, seed=0))


def test_homogeneous_small_buckets_promoted_not_starved():
    """A bucket with fewer rows than the batch size merges into the next
    bucket up instead of being silently excluded every epoch."""
    ids = np.ones((40, 64), np.int32)
    bucket_by = np.where(np.arange(40) < 6, 16, 64)   # 6-row small bucket
    src = PIPE.ArraySource(ids=ids, r=np.arange(40))
    ld = PIPE.Loader(src, 8, seed=0, bucket_by=bucket_by,
                     bucket_mode="homogeneous")
    it = iter(ld)
    seen = set()
    for _ in range(2 * ld.steps_per_epoch()):
        seen.update(next(it)["r"].tolist())
    assert set(range(6)) <= seen, "small-bucket rows never trained"


def test_loader_resume_identical_batch_stream():
    """Same seed + restored cursor => identical batch stream, across an
    epoch boundary, in both plain and bucketed modes."""
    rng = np.random.default_rng(5)
    ids = rng.integers(1, 9, (48, 32)).astype(np.int32)
    bucket_by = np.where(np.arange(48) % 3 == 0, 16, 32)
    for kw in [{}, {"bucket_by": bucket_by},
               {"bucket_by": bucket_by, "bucket_mode": "homogeneous"}]:
        src = PIPE.ArraySource(ids=ids, r=np.arange(48))
        l1 = PIPE.Loader(src, 8, seed=11, **kw)
        it1 = iter(l1)
        for _ in range(8):           # past the 6-step epoch boundary
            next(it1)
        cursor = PIPE.LoaderState(**l1.state.as_dict())
        l2 = PIPE.Loader(src, 8, seed=11, state=cursor, **kw)
        ref = iter(PIPE.Loader(src, 8, seed=11, **kw))
        for _ in range(8):
            next(ref)
        it2 = iter(l2)
        for _ in range(10):
            a, b = next(it2), next(ref)
            np.testing.assert_array_equal(a["ids"], b["ids"])
            np.testing.assert_array_equal(a["r"], b["r"])


def test_synthetic_lm_batches():
    it = PIPE.synthetic_lm_batches(100, 4, 16)
    b = next(it)
    assert b["tokens"].shape == (4, 16)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


# ------------------------------------------------------------- optimizer
def test_adamw_converges_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, total_steps=200,
                            warmup_steps=0, schedule="constant")
    params = {"x": jnp.array([5.0, -3.0])}
    state = adamw.init_state(params)
    step = jax.jit(lambda p, s: adamw.apply_updates(
        p, jax.grad(lambda q: jnp.sum(q["x"] ** 2))(p), s, cfg))
    for _ in range(200):
        params, state, _ = step(params, state)
    assert float(jnp.abs(params["x"]).max()) < 1e-2


def test_clip_by_global_norm():
    g = {"a": jnp.ones((4,)) * 100.0}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(200.0)
    assert float(adamw.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_schedule_warmup_and_decay():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            schedule="cosine", min_lr_ratio=0.1)
    assert float(adamw.schedule_lr(cfg, jnp.int32(5))) == pytest.approx(0.5)
    assert float(adamw.schedule_lr(cfg, jnp.int32(10))) == pytest.approx(1.0)
    assert float(adamw.schedule_lr(cfg, jnp.int32(100))) == \
        pytest.approx(0.1, rel=1e-3)


# ------------------------------------------------------------- compression
def test_compression_error_feedback_preserves_signal():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)}
    err = compress.init_error_state(g)
    acc_true = np.zeros((64, 64))
    acc_comp = np.zeros((64, 64))
    for _ in range(50):
        ghat, err = compress.compress_grads(g, err)
        acc_true += np.asarray(g["w"])
        acc_comp += np.asarray(ghat["w"])
    # error feedback: accumulated compressed grads track the true sum
    rel = np.abs(acc_comp - acc_true).max() / np.abs(acc_true).max()
    assert rel < 0.01


def test_quantize_dequantize_bounded_error():
    x = jnp.linspace(-3, 3, 1000)
    q, s = compress.quantize(x)
    back = compress.dequantize(q, s)
    assert float(jnp.abs(back - x).max()) <= float(s) * 0.5 + 1e-6


# ------------------------------------------------------------- fault
def test_supervisor_resumes_after_crash(tmp_path):
    d = str(tmp_path / "ck")
    sup = fault.TrainSupervisor(d, save_every=5, max_step_retries=0)
    calls = {"n": 0}

    def crashing_step(state, step):
        calls["n"] += 1
        if step == 7 and calls["n"] <= 8:
            raise RuntimeError("injected node failure")
        return {"w": state["w"] + 1}

    state = {"w": jnp.zeros(())}
    with pytest.raises(RuntimeError):
        sup.run(state, crashing_step, 10)
    # restart: restore from last committed (step 5) and finish
    state2, start, _ = sup.try_restore({"w": jnp.zeros(())})
    assert start == 7  # crash-save at step 7
    final = sup.run(state2, crashing_step, 10, start_step=start)
    assert float(final["w"]) == 10.0


def test_straggler_detection_and_rebalance():
    mon = fault.HeartbeatMonitor(4, straggler_factor=2.0, timeout_s=10)
    now = 100.0
    for w in range(4):
        for _ in range(5):
            mon.beat(w, step_duration=1.0 if w != 2 else 5.0, now=now)
    assert mon.stragglers(now=now) == [2]
    shards = {0: 4, 1: 4, 2: 4, 3: 4}
    new = mon.rebalance_shards(shards, now=now)
    assert new[2] == 3 and sum(new.values()) == 16
    # timeout-based detection
    mon.beat(3, now=now)
    assert 1 not in mon.stragglers(now=now + 5)
    assert set(mon.stragglers(now=now + 50)) == {0, 1, 2, 3}
