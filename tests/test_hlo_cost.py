"""Loop-aware HLO cost analyzer: validated against known-flops programs."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_cost as HC
from repro.launch.roofline import collective_bytes as rl_collective_bytes


def _compiled(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_single_matmul_flops():
    a = jnp.ones((128, 256))
    b = jnp.ones((256, 64))
    t = HC.analyze_hlo(_compiled(lambda a, b: a @ b, a, b).as_text())
    assert t.flops == pytest.approx(2 * 128 * 256 * 64, rel=0.01)
    # operands + result traffic
    expect = (128 * 256 + 256 * 64 + 128 * 64) * 4
    assert t.hbm_bytes == pytest.approx(expect, rel=0.2)


def test_scan_multiplies_by_trip_count():
    a = jnp.ones((64, 64))
    ws = jnp.ones((8, 64, 64))

    def f(a, ws):
        def body(h, w):
            return jnp.tanh(h @ w), None
        return jax.lax.scan(body, a, ws)[0]

    t = HC.analyze_hlo(_compiled(f, a, ws).as_text())
    per_layer = 2 * 64 ** 3
    assert t.flops >= 8 * per_layer
    assert t.flops < 10 * per_layer  # not wildly over


def test_nested_scan():
    a = jnp.ones((32, 32))
    ws = jnp.ones((4, 3, 32, 32))

    def f(a, ws):
        def outer(h, wgroup):
            def inner(hh, w):
                return hh @ w, None
            return jax.lax.scan(inner, h, wgroup)[0], None
        return jax.lax.scan(outer, a, ws)[0]

    t = HC.analyze_hlo(_compiled(f, a, ws).as_text())
    assert t.flops == pytest.approx(12 * 2 * 32 ** 3, rel=0.1)


def test_elementwise_not_counted_as_hbm():
    x = jnp.ones((1024, 1024))
    t = HC.analyze_hlo(_compiled(
        lambda x: jnp.tanh(x) * 2 + 1, x).as_text())
    assert t.hbm_bytes == 0.0  # fused elementwise: no contraction boundary
    assert t.contraction_flops == 0.0


def test_convolution_flops():
    x = jnp.ones((1, 16, 16, 8))
    w = jnp.ones((3, 3, 8, 4))

    def f(x, w):
        return jax.lax.conv_general_dilated(
            x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))

    t = HC.analyze_hlo(_compiled(f, x, w).as_text())
    assert t.flops == pytest.approx(2 * 16 * 16 * 4 * 3 * 3 * 8, rel=0.05)


def test_roofline_collective_parser_smoke():
    # plain-text regression for the standalone parser
    hlo = """
ENTRY %main (p0: f32[64,64]) -> f32[64,64] {
  %p0 = f32[64,64]{1,0} parameter(0)
  %ar = f32[64,64]{1,0} all-reduce(%p0), replica_groups={}, to_apply=%add
  ROOT %ag = f32[128,64]{1,0} all-gather(%ar), dimensions={0}
}
"""
    per = rl_collective_bytes(hlo)
    assert per["all-reduce"] == 2 * 64 * 64 * 4
    assert per["all-gather"] == 128 * 64 * 4
