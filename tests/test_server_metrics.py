"""ServerMetrics + adaptive-flush unit coverage.

Exercises the gateway's observability surface against known inputs — a
stub service stands in for the JAX model, so everything here is
deterministic and runs in milliseconds: percentile math on a known
latency sequence, the bounded reservoir, the queue-depth gauge under
real backpressure (sheds included), the coalesce counter, the adaptive
flush deadline's clamp behavior, and the phase_*/gauge passthrough the
replicated tier's stats RPC rides on.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.server import (CostModelServer, ServerMetrics,
                               ServerOverloadedError)


class StubService:
    """Minimal duck-typed CostModelService: fixed bucket, zero rows."""

    buckets = (8,)
    batch_ladder = (1, 2, 4, 8)
    max_batch = 8
    heads = ("latency", "regs")

    def __init__(self):
        self.forwards = 0

    def _ladder_batch(self, n):
        return n

    def warmup(self, batch_sizes=None):
        pass

    def cache_lookup(self, key):
        return None

    def phase_stats(self):
        return {"hash_s": 1.5, "encode_s": 0.25, "oov_rate": 0.125}

    def forward_entries_dispatch(self, entries):
        self.forwards += 1
        return entries

    def forward_entries_collect(self, entries):
        return np.zeros((len(entries), len(self.heads)), np.float32)


def _ids():
    return np.zeros(8, np.int32)


# ------------------------------------------------------------- percentiles
def test_percentiles_match_numpy_on_known_sequence():
    m = ServerMetrics()
    lats = [float(v) for v in range(1, 101)]        # 1..100 us
    m.observe_latencies(lats)
    snap = m.snapshot()
    for name, q in (("p50", 50), ("p95", 95), ("p99", 99)):
        assert snap[f"latency_{name}_us"] == pytest.approx(
            float(np.percentile(lats, q)))
    assert snap["latency_p50_us"] < snap["latency_p95_us"] \
        < snap["latency_p99_us"]


def test_empty_reservoir_reports_zero_percentiles():
    snap = ServerMetrics().snapshot()
    for name in ("p50", "p95", "p99"):
        assert snap[f"latency_{name}_us"] == 0.0


def test_reservoir_bounded_and_keeps_newest():
    m = ServerMetrics(reservoir=8192)
    m.observe_latencies([float(v) for v in range(10_000)])
    assert len(m._lat_us) == 8192
    # oldest 1808 observations fell off the deque; percentiles are over
    # the retained window [1808, 10000)
    kept = np.arange(1808, 10_000, dtype=np.float64)
    snap = m.snapshot()
    assert snap["latency_p50_us"] == pytest.approx(
        float(np.percentile(kept, 50)))
    assert min(m._lat_us) == 1808.0


def test_custom_reservoir_size():
    m = ServerMetrics(reservoir=16)
    m.observe_latencies([float(v) for v in range(100)])
    assert len(m._lat_us) == 16
    assert list(m._lat_us) == [float(v) for v in range(84, 100)]


# ---------------------------------------------------------------- counters
def test_note_request_counters_and_hit_rate():
    m = ServerMetrics()
    m.note_request(cache_hit=True)
    m.note_request(coalesced=True, queue_depth=3)
    m.note_request(shed=True)
    m.note_request(queue_depth=7)
    snap = m.snapshot(queue_depth=2)
    assert snap["requests"] == 4
    assert snap["cache_hits"] == 1
    assert snap["cache_hit_rate"] == pytest.approx(0.25)
    assert snap["coalesced"] == 1
    assert snap["shed"] == 1
    assert snap["queue_depth"] == 2
    assert snap["max_queue_depth"] == 7


def test_phase_source_and_gauges_travel_in_snapshot():
    m = ServerMetrics()
    m.phase_source = lambda: {"hash_s": 2.0, "truncated": 3,
                              "oov_rate": 0.25}
    m.gauges["flush_us_effective"] = 123.0
    snap = m.snapshot()
    assert snap["phase_hash_s"] == 2.0
    assert snap["phase_truncated"] == 3
    # the front door's vocabulary-drift signal must reach operators
    assert snap["phase_oov_rate"] == 0.25
    assert snap["flush_us_effective"] == 123.0


# ------------------------------------------------- backpressure (stub server)
def test_queue_depth_gauge_and_shed_under_backpressure():
    svc = StubService()
    server = CostModelServer(svc, max_batch=8, flush_us=500.0, max_queue=4)
    # no worker thread: the queue can only build, so the gauge is exact
    server._running = True
    try:
        for i in range(4):
            server.submit_entry(f"k{i}", _ids())
        with pytest.raises(ServerOverloadedError) as ei:
            server.submit_entry("k-over", _ids())
        assert ei.value.retry_after_s > 0.0
        snap = server.metrics_snapshot()
        assert snap["queue_depth"] == 4
        assert snap["max_queue_depth"] == 4
        assert snap["shed"] == 1
        assert snap["requests"] == 5
    finally:
        server._running = False


def test_coalesce_counter_on_duplicate_inflight_key():
    svc = StubService()
    server = CostModelServer(svc, max_batch=8, flush_us=500.0)
    server._running = True
    try:
        server.submit_entry("same", _ids())
        server.submit_entry("same", _ids())
        snap = server.metrics_snapshot()
        assert snap["coalesced"] == 1
        assert snap["queue_depth"] == 1          # one unique entry queued
        assert server._n_pending == 2            # but two waiters pending
    finally:
        server._running = False


def test_stub_end_to_end_resolves_and_observes_latency():
    svc = StubService()
    server = CostModelServer(svc, max_batch=4, flush_us=200.0)
    with server:                                 # start(warmup=True) is a
        futs = [server.submit_entry(f"g{i}", _ids())  # no-op on the stub
                for i in range(4)]
        rows = [f.result(timeout=10.0) for f in futs]
    assert all(r.shape == (2,) for r in rows)
    snap = server.metrics_snapshot()
    assert snap["batches"] >= 1
    assert snap["batch_occupancy"] > 0
    assert snap["latency_p50_us"] > 0
    assert snap["phase_hash_s"] == 1.5           # stub phase passthrough
    assert svc.forwards >= 1


# ---------------------------------------------------------- adaptive flush
def _adaptive_server(**kw):
    kw.setdefault("flush_us", 1000.0)
    kw.setdefault("adaptive_flush", True)
    return CostModelServer(StubService(), max_batch=8, **kw)


def test_adaptive_flush_defaults_to_budget_before_any_arrivals():
    s = _adaptive_server()
    assert s._effective_flush_us_locked() == 1000.0


def test_adaptive_flush_scales_with_arrival_rate():
    s = _adaptive_server(adaptive_k=8.0)
    s._arrival_ewma_us = 25.0                    # fast arrivals
    assert s._effective_flush_us_locked() == pytest.approx(200.0)
    assert s.metrics.gauges["flush_us_effective"] == pytest.approx(200.0)
    snap = s.metrics.snapshot()                  # gauge rides the snapshot
    assert snap["flush_us_effective"] == pytest.approx(200.0)


def test_adaptive_flush_collapses_when_arrivals_outpace_budget():
    s = _adaptive_server()
    s._arrival_ewma_us = 5000.0                  # slower than the budget
    assert s._effective_flush_us_locked() == s.flush_us_min
    assert s.flush_us_min < s.flush_us


def test_adaptive_flush_clamped_to_budget():
    s = _adaptive_server(adaptive_k=8.0)
    s._arrival_ewma_us = 900.0                   # k*ewma would exceed it
    assert s._effective_flush_us_locked() == 1000.0


def test_disabled_adaptive_flush_is_constant():
    s = CostModelServer(StubService(), max_batch=8, flush_us=750.0)
    s._arrival_ewma_us = 10.0
    assert s._effective_flush_us_locked() == 750.0


def test_arrival_ewma_clamps_idle_gaps():
    s = _adaptive_server()
    s._note_arrival_locked(0.0)
    s._note_arrival_locked(60.0)                 # one minute idle
    # a single huge gap is clamped at 8 budgets, not 60s
    assert s._arrival_ewma_us == pytest.approx(8 * s.flush_us)
    before = s._arrival_ewma_us
    s._note_arrival_locked(60.0001)              # 100us gap: EWMA decays
    assert s._arrival_ewma_us < before
