"""CostModelServer: coalescing, flush paths, backpressure, warm-up,
metrics, and bit-parity with direct CostModelService calls."""
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs.costmodel import CostModelConfig
from repro.core import models as CM
from repro.core import tokenizer as TOK
from repro.core.server import CostModelServer, ServerOverloadedError
from repro.core.service import CostModelService, UnrollAdvisor
from repro.ir import samplers

CFG = CostModelConfig(name="srv-test", vocab_size=512, max_seq=64,
                      embed_dim=16, conv_channels=(16,) * 6,
                      fc_dims=(32, 16))


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(7)
    graphs = [samplers.sample_graph(rng) for _ in range(48)]
    vocab = TOK.fit_vocab([TOK.graph_tokens(g, "ops") for g in graphs],
                          max_size=512)
    return graphs, vocab


@pytest.fixture(scope="module")
def make_service(corpus):
    """Fresh, identically-weighted services (untrained params: parity
    and scheduling do not depend on training)."""
    _, vocab = corpus
    params = CM.conv_init(jax.random.PRNGKey(0), CFG, heads=CM.DEFAULT_HEADS)
    stats = {t: {"mu": 0.3, "sigma": 1.7} for t in CM.DEFAULT_HEADS}

    def make(**kw):
        kw.setdefault("max_batch", 8)
        return CostModelService("conv1d", CFG, params, vocab, stats,
                                mode="ops", max_seq=64, **kw)
    return make


def test_server_bit_identical_to_direct_service(corpus, make_service):
    """Interleaved multi-client submission through the server returns
    exactly the bytes direct predict_all returns — across coalesced
    batches, both flush paths, and the LRU."""
    graphs, _ = corpus
    direct = make_service()
    want = direct.predict_all(graphs)

    served = make_service()
    results = {}
    res_lock = threading.Lock()
    with CostModelServer(served, max_batch=8, flush_us=1000) as server:
        def client(idxs):
            for i in idxs:
                out = server.predict_all([graphs[i]])
                with res_lock:
                    results[i] = out
        threads = [threading.Thread(target=client,
                                    args=(range(k, len(graphs), 6),))
                   for k in range(6)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    assert set(results) == set(range(len(graphs)))
    for i in range(len(graphs)):
        for t in CM.DEFAULT_HEADS:
            got, exp = results[i][t][0], want[t][i]
            assert got == exp, (i, t, got, exp)   # bit-identical


def test_deadline_flush_path(corpus, make_service):
    """Fewer requests than max_batch resolve via the deadline/stall
    path, never a full-batch flush, and still match direct results."""
    graphs, _ = corpus
    direct = make_service()
    svc = make_service()
    with CostModelServer(svc, max_batch=8, flush_us=500) as server:
        out = server.predict_all(graphs[:3])
        m = server.metrics.snapshot()
    want = direct.predict_all(graphs[:3])
    for t in CM.DEFAULT_HEADS:
        np.testing.assert_array_equal(out[t], want[t])
    assert m["full_flushes"] == 0
    assert m["deadline_flushes"] + m["stagnant_flushes"] >= 1
    assert m["requests"] == 3


def test_full_batch_flush_path(corpus, make_service):
    """A bucket reaching max_batch flushes immediately even though the
    deadline is far away — and matches direct results bit-for-bit."""
    graphs, _ = corpus
    svc = make_service()
    # same-bucket graphs so one queue can actually fill
    by_bucket = {}
    for g in graphs:
        _, ids = svc.entry(g)
        by_bucket.setdefault(len(ids), []).append(g)
    bucket_graphs = max(by_bucket.values(), key=len)[:4]
    assert len(bucket_graphs) == 4

    direct = make_service()
    want = direct.predict_all(bucket_graphs)
    svc2 = make_service(max_batch=4)
    with CostModelServer(svc2, max_batch=4, flush_us=10_000_000) as server:
        futs = [server.submit(g) for g in bucket_graphs]
        raw = np.stack([f.result(timeout=30) for f in futs])
        m = server.metrics.snapshot()
        out = svc2.denormalize_rows(raw)
    for t in CM.DEFAULT_HEADS:
        np.testing.assert_array_equal(out[t], want[t])
    assert m["full_flushes"] >= 1


def test_cache_hit_and_coalescing(corpus, make_service):
    graphs, _ = corpus
    svc = make_service()
    with CostModelServer(svc, max_batch=8, flush_us=2000) as server:
        g = graphs[0]
        first = server.predict_all([g])
        # identical re-query: resolved at submit time from the LRU
        again = server.predict_all([g])
        m = server.metrics.snapshot()
        assert m["cache_hits"] >= 1
        assert m["cache_hit_rate"] > 0
        for t in CM.DEFAULT_HEADS:
            np.testing.assert_array_equal(first[t], again[t])

        # concurrent duplicates of a NEW graph coalesce onto one compute
        g2 = graphs[1]
        futs = [server.submit(g2) for _ in range(5)]
        rows = [f.result(timeout=30) for f in futs]
        m = server.metrics.snapshot()
        assert m["coalesced"] >= 1
        for r in rows[1:]:
            np.testing.assert_array_equal(r, rows[0])


def test_backpressure_load_shed(corpus, make_service):
    """A full bounded queue sheds load with ServerOverloadedError."""
    graphs, _ = corpus

    def slow_dispatch_factory(service):
        orig = service.forward_entries_dispatch

        def slow_dispatch(entries):
            time.sleep(0.25)           # hold the worker so the queue fills
            return orig(entries)
        return slow_dispatch

    svc = make_service()
    svc.forward_entries_dispatch = slow_dispatch_factory(svc)
    with CostModelServer(svc, max_batch=2, flush_us=100,
                         max_queue=2) as server:
        futs = []
        with pytest.raises(ServerOverloadedError):
            for g in graphs[:12]:      # distinct graphs; queue bound is 2
                futs.append(server.submit(g))
        m = server.metrics.snapshot()
        assert m["shed"] >= 1
        for f in futs:                 # accepted requests still complete
            assert f.result(timeout=30) is not None

    # a storm on ONE hot in-flight key is bounded too: coalesced
    # waiters count against max_queue
    svc2 = make_service()
    svc2.forward_entries_dispatch = slow_dispatch_factory(svc2)
    with CostModelServer(svc2, max_batch=2, flush_us=100,
                         max_queue=2) as server:
        futs = []
        with pytest.raises(ServerOverloadedError):
            for _ in range(12):
                futs.append(server.submit(graphs[0]))
        for f in futs:
            assert f.result(timeout=30) is not None


def test_warmup_precompiles_every_program(make_service):
    """start(warmup=True) AOT-compiles every (bucket x ladder-batch)
    program: serving traffic afterwards never triggers a new compile."""
    svc = make_service(max_batch=4, batch_ladder=(1, 2, 4))
    n = svc.warmup()
    assert n == len(svc.buckets) * len(svc.batch_ladder)
    if not hasattr(svc._apply, "_cache_size"):
        pytest.skip("jit cache introspection unavailable")
    compiled = svc._apply._cache_size()
    assert compiled == n
    rng = np.random.default_rng(3)
    svc.predict_all([samplers.sample_graph(rng) for _ in range(9)])
    assert svc._apply._cache_size() == compiled   # no first-call compile


def test_server_drives_advisors(corpus, make_service):
    """The gateway duck-types the service API: advisors work unchanged
    and agree with the same advisor over the direct service."""
    graphs, _ = corpus
    direct = make_service()
    svc = make_service()
    with CostModelServer(svc, max_batch=8, flush_us=500) as server:
        a_direct = UnrollAdvisor(direct, register_budget=1e9)
        a_served = UnrollAdvisor(server, register_budget=1e9)
        g = graphs[2]
        want = a_direct.advise(g, factors=(1, 2))
        got = a_served.advise(g, factors=(1, 2))
    assert got == want


def test_metrics_latency_percentiles(corpus, make_service):
    graphs, _ = corpus
    svc = make_service()
    with CostModelServer(svc, max_batch=8, flush_us=500) as server:
        server.predict_all(graphs[:10])
        m = server.metrics.snapshot(server.queue_depth())
    assert m["requests"] == 10
    assert m["batches"] >= 1
    assert m["batch_occupancy"] > 0
    assert 0 < m["latency_p50_us"] <= m["latency_p95_us"] \
        <= m["latency_p99_us"]
    assert m["queue_depth"] == 0


def test_submit_requires_started_server(corpus, make_service):
    graphs, _ = corpus
    server = CostModelServer(make_service())
    with pytest.raises(RuntimeError):
        server.submit(graphs[0])
    server.start(warmup=False)
    assert np.isfinite(server.predict(graphs[0], "latency_us"))
    server.stop()
    with pytest.raises(RuntimeError):
        server.submit(graphs[0])


def test_service_lru_thread_safety_hammer(corpus, make_service):
    """Concurrent direct predict_all callers on one service with a tiny
    LRU (constant eviction) neither crash nor corrupt results."""
    graphs, _ = corpus
    svc = make_service(cache_size=8)
    want = {t: v.copy()
            for t, v in make_service().predict_all(graphs).items()}
    errs = []

    def hammer(seed):
        rng = np.random.default_rng(seed)
        try:
            for _ in range(6):
                idx = rng.integers(0, len(graphs), 12)
                out = svc.predict_all([graphs[i] for i in idx])
                for t in CM.DEFAULT_HEADS:
                    np.testing.assert_array_equal(out[t], want[t][idx])
        except Exception as e:
            errs.append(e)

    threads = [threading.Thread(target=hammer, args=(s,)) for s in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    stats = svc.cache_stats()
    assert stats["size"] <= 8
    # duplicate graphs inside one call dedup before the probe, so the
    # exact count varies — but both counters must have moved
    assert stats["misses"] > 0 and stats["hits"] > 0
