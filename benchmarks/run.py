"""Benchmark harness — one entry per paper table/figure + system benches.

Prints ``name,us_per_call,derived`` CSV rows (per the repo convention).

  paper_rmse        — §4: RMSE of FC / LSTM / Conv1D on register pressure
                      and vALU utilization (ops-only tokens).
  operand_ablation  — Fig 6: ops-only vs ops+operands Conv1D accuracy,
                      %-exact for register pressure.
  inference_speed   — §5 claim: Conv1D model is much faster than LSTM.
  kernel_bench      — fused Pallas serving forward (ids-in conv kernel,
                      lstm recurrence kernel) vs the plain-XLA apply
                      over the serving (bucket x ladder) shape set:
                      f32/bf16 parity, wall time, and cost_analysis
                      bytes fed through launch/roofline.py (roofline
                      fraction + aggregate HBM-traffic reduction; gated
                      by gate.py::gate_kernel_bench).
  serve_bench       — unified multi-target service vs three single-target
                      services on the same request stream (req/s).
  serve_concurrent  — async micro-batching CostModelServer under 1/8/64
                      closed-loop clients vs serialized per-request
                      predict_all (req/s + latency percentiles).
  obs_overhead      — unified-telemetry tax on the gateway hot path:
                      steady req/s with tracing + registry export +
                      drift sentinel on vs off (gated >= 0.97x), plus
                      a forced-sampling span-tree completeness check
                      (gated >= 0.99) and drift-gauge presence.
  opt_search        — repro.opt beam search over rewrite sequences
                      through the server vs the one-shot FusionAdvisor
                      baseline (graphs/s + oracle latency improvement).
  search_fleet      — N concurrent beam_search workers against ONE
                      CostModelServer gateway: candidates-costed/s with
                      the incremental hashing + encode_many hot path vs
                      the from-scratch baseline (flag-switched), plus
                      cross-search cache hit rates, batch occupancy,
                      per-phase timing split, and bf16-vs-f32 drift.
  roofline_table    — reads experiments/dryrun/*.json into the §Roofline
                      table (derived = roofline fraction).
  chaos_serve       — supervised 2-replica tier under a seeded scripted
                      fault plan (kill, wedge, drop/delay/dup, torn
                      shared-cache slot): availability, recovery time,
                      bit-parity of non-degraded replies, and obs
                      counter presence (gated by gate_chaos_serve).

``--full`` uses paper-scale settings (20k+ graphs); default is CI-scale.
``--json-dir DIR`` additionally writes one ``BENCH_<name>.json`` record
per bench (the CI bench-smoke job uploads these as artifacts, and
``benchmarks/gate.py`` enforces the serve_concurrent perf gate on them).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.costmodel import CostModelConfig
from repro.core import models as CM
from repro.core import trainer as TR
from repro.ir import dataset as DS


def _row(name, us, derived):
    print(f"{name},{us:.2f},{derived}", flush=True)


def _bench(fn, *args, iters=20, warmup=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


# ---------------------------------------------------------------- paper_rmse
def paper_rmse(full: bool = False, seed: int = 0):
    n = 10000 if full else 2000
    steps = {"fc": 3000 if full else 500,
             "lstm": 1200 if full else 200,
             "conv1d": 3000 if full else 700}
    cfg = CostModelConfig(name="bench", vocab_size=4096, max_seq=160,
                          embed_dim=64, conv_channels=(64,) * 6,
                          fc_dims=(256, 64), lstm_hidden=64)
    ds = DS.build_dataset(n, mode="ops", max_seq=160, vocab_size=4096,
                          augment_factor=2, seed=seed)
    tr, te = ds.split(0.1)
    results = {}
    for target in ["register_pressure", "valu_utilization"]:
        for kind in ["fc", "lstm", "conv1d"]:
            t0 = time.time()
            res = TR.TrainEngine(kind, cfg, target, steps=steps[kind],
                                 batch_size=128, lr=2e-3, seed=seed).fit(tr)
            m = TR.evaluate(kind, cfg, res, te, target)
            results[(kind, target)] = m
            _row(f"paper_rmse/{kind}/{target}", (time.time() - t0) * 1e6,
                 f"rmse_rel={m['rmse_rel_pct']:.2f}%"
                 f";mape={m['mape_pct']:.2f}%"
                 f";exact={m['exact_pct']:.1f}%")
    return results


# ---------------------------------------------------------- operand_ablation
def operand_ablation(full: bool = False, seed: int = 0):
    n = 6000 if full else 2000
    steps = 1800 if full else 700
    out = {}
    for mode, fs in [("ops", (2, 2, 2, 2, 2, 2)),
                     ("ops_operands", (16, 16, 8, 8, 2, 1))]:
        max_seq = 160 if mode == "ops" else 640  # ~4x longer sequences
        cfg = CostModelConfig(
            name=f"bench-{mode}", vocab_size=8192, max_seq=max_seq,
            embed_dim=64, conv_filters=fs, conv_channels=(64,) * 6,
            fc_dims=(256, 64))
        ds = DS.build_dataset(n, mode=mode, max_seq=max_seq,
                              vocab_size=8192, augment_factor=2, seed=seed)
        tr, te = ds.split(0.1)
        t0 = time.time()
        res = TR.TrainEngine("conv1d", cfg, "register_pressure",
                             steps=steps, batch_size=64, lr=2e-3,
                             seed=seed).fit(tr)
        m = TR.evaluate("conv1d", cfg, res, te, "register_pressure")
        out[mode] = m
        _row(f"operand_ablation/{mode}", (time.time() - t0) * 1e6,
             f"rmse_rel={m['rmse_rel_pct']:.2f}%;exact={m['exact_pct']:.1f}%"
             f";within5={m['within5_pct']:.1f}%")
    return out


# ---------------------------------------------------------- inference_speed
def inference_speed(full: bool = False, seed: int = 0):
    cfg = CostModelConfig(name="bench", vocab_size=4096, max_seq=256,
                          embed_dim=64, conv_channels=(64,) * 6,
                          fc_dims=(256, 64), lstm_hidden=64)
    rng = np.random.default_rng(seed)
    ids = jnp.asarray(rng.integers(1, 4096, (64, 256)), jnp.int32)
    out = {}
    for kind in ["fc", "conv1d", "lstm"]:
        init_fn, apply_fn, _ = CM.get_model(kind)
        params = init_fn(jax.random.PRNGKey(seed), cfg)
        f = jax.jit(apply_fn)
        us = _bench(f, params, ids)
        out[kind] = us
        _row(f"inference_speed/{kind}", us, f"per_graph_us={us/64:.2f}")
    _row("inference_speed/conv_vs_lstm", 0.0,
         f"speedup={out['lstm']/out['conv1d']:.1f}x")
    return out


# ------------------------------------------------------------- kernel_bench
def _ragged_ids(rng, B, S, vocab):
    """Random token ids with ragged valid lengths (PAD id 0), the
    serving distribution: short graphs bucket-padded up to S."""
    ids = rng.integers(1, vocab, (B, S))
    lens = rng.integers(max(1, S // 4), S + 1, (B,))
    ids[np.arange(S)[None, :] >= lens[:, None]] = 0
    return jnp.asarray(ids, jnp.int32)


def _cost_bytes_flops(fn, *args):
    """(bytes accessed, flops) of ``fn`` from the compiled module's
    cost_analysis (list-shaped on some backends)."""
    compiled = jax.jit(fn).lower(*args).compile()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return float(ca.get("bytes accessed", 0.0)), float(ca.get("flops", 0.0))


def kernel_bench(full: bool = False, seed: int = 0):
    """Fused Pallas serving forward vs the plain-XLA apply, over the
    serving (bucket x batch-ladder) shape set.

    Per shape: f32 parity (max abs err vs conv_apply/lstm_apply), wall
    time, and modeled HBM traffic — unfused bytes from the compiled
    reference's ``cost_analysis()``, fused bytes from the kernel's
    ids+params+out analytic model — both fed through
    ``launch/roofline.py`` for roofline fractions. bf16 parity pools
    predictions across every shape and reports per-head Spearman vs the
    f32 reference. ``gate.py::gate_kernel_bench`` enforces f32 parity,
    bf16 Spearman >= 0.99, and an aggregate >= 3x traffic reduction
    always; the fused-vs-ref wall-clock ratio only on non-interpret
    backends (interpret-mode wall time measures the Pallas emulator,
    not the kernel)."""
    from repro.kernels import ops as KOPS
    from repro.launch.roofline import RooflineReport
    from repro.opt.evaluate import spearman

    cfg = CostModelConfig(name="bench", vocab_size=4096, max_seq=256,
                          embed_dim=64, conv_channels=(64,) * 6,
                          fc_dims=(256, 64), lstm_hidden=64)
    buckets = (64, 128, 256)
    ladder = (4, 8, 32, 64) if full else (8, 32)
    interpret = jax.default_backend() == "cpu"
    iters, warmup = (3, 1) if interpret else (20, 3)
    rng = np.random.default_rng(seed)
    heads = CM.DEFAULT_HEADS

    def _cast16(p):
        return jax.tree.map(
            lambda a: a.astype(jnp.bfloat16)
            if jnp.issubdtype(a.dtype, jnp.floating) else a, p)

    out = {"backend": jax.default_backend(), "interpret": interpret,
           "buckets": list(buckets), "batch_ladder": list(ladder),
           "shapes": [], "models": {}}

    for kind, init, apply_fn, fused_fn in (
            ("conv1d", CM.conv_init, CM.conv_apply,
             KOPS.conv_forward_apply),
            ("lstm", CM.lstm_init, CM.lstm_apply,
             KOPS.lstm_forward_apply)):
        p32 = init(jax.random.PRNGKey(seed), cfg, heads=heads)
        p16 = _cast16(p32)
        ref_jit = jax.jit(apply_fn)
        max_err = 0.0
        ref_us_total = fused_us_total = 0.0
        unfused_bytes = fused_bytes = 0.0
        pooled = {t: {"ref": [], "bf16": []} for t in heads}
        for S in buckets:
            for B in ladder:
                ids = _ragged_ids(rng, B, S, cfg.vocab_size)
                want = {t: np.asarray(v, np.float32)
                        for t, v in ref_jit(p32, ids).items()}
                got = fused_fn(p32, ids)
                err = max(float(np.abs(np.asarray(got[t]) - want[t]).max())
                          for t in heads)
                max_err = max(max_err, err)
                got16 = fused_fn(p16, ids)
                for t in heads:
                    pooled[t]["ref"].append(want[t])
                    pooled[t]["bf16"].append(np.asarray(got16[t],
                                                        np.float32))
                us_ref = _bench(ref_jit, p32, ids, iters=iters,
                                warmup=warmup)
                us_fused = _bench(lambda i: fused_fn(p32, i), ids,
                                  iters=iters, warmup=warmup)
                ref_us_total += us_ref
                fused_us_total += us_fused
                row = {"kind": kind, "batch": B, "seq": S,
                       "f32_max_err": err, "ref_us": us_ref,
                       "fused_us": us_fused}
                if kind == "conv1d":
                    # unfused traffic: what XLA's compiled module says it
                    # moves; fused traffic: one read of ids+params, one
                    # write of the predictions (the kernel's contract)
                    ub, fl = _cost_bytes_flops(apply_fn, p32, ids)
                    fb = float(KOPS.fused_forward_bytes(p32, B, S))
                    unfused_bytes += ub
                    fused_bytes += fb
                    mk = dict(arch="costmodel-conv1d", mesh="1x1",
                              chips=1, coll_bytes_per_chip=0.0,
                              flops_per_chip=fl, model_flops=fl,
                              shape=f"B{B}xS{S}")
                    r_un = RooflineReport(bytes_per_chip=ub, **mk)
                    r_fu = RooflineReport(bytes_per_chip=fb, **mk)
                    row.update(
                        unfused_bytes=ub, fused_bytes=fb,
                        traffic_reduction=ub / max(fb, 1.0),
                        unfused_roofline_fraction=r_un.roofline_fraction,
                        fused_roofline_fraction=r_fu.roofline_fraction,
                        unfused_bottleneck=r_un.bottleneck,
                        fused_bottleneck=r_fu.bottleneck)
                    _row(f"kernel_bench/{kind}/B{B}xS{S}", us_fused,
                         f"err={err:.1e}"
                         f";traffic={ub / max(fb, 1.0):.1f}x"
                         f";roofline {r_un.roofline_fraction:.3f}->"
                         f"{r_fu.roofline_fraction:.3f}")
                else:
                    _row(f"kernel_bench/{kind}/B{B}xS{S}", us_fused,
                         f"err={err:.1e};ref_us={us_ref:.0f}")
                out["shapes"].append(row)
        rho = {t: spearman(np.concatenate(pooled[t]["ref"]),
                           np.concatenate(pooled[t]["bf16"]))
               for t in heads}
        m = {"f32_max_err": max_err,
             "bf16_spearman": {t: float(r) for t, r in rho.items()},
             "bf16_spearman_min": float(min(rho.values())),
             "ref_us_total": ref_us_total,
             "fused_us_total": fused_us_total,
             "wall_ratio": ref_us_total / max(fused_us_total, 1e-9)}
        if kind == "conv1d":
            m["unfused_bytes_total"] = unfused_bytes
            m["fused_bytes_total"] = fused_bytes
            m["traffic_reduction"] = unfused_bytes / max(fused_bytes, 1.0)
        out["models"][kind] = m
        _row(f"kernel_bench/{kind}/summary", fused_us_total,
             f"max_err={max_err:.1e}"
             f";bf16_spearman_min={min(rho.values()):.4f}"
             f";wall_ratio={m['wall_ratio']:.2f}x")
    out["traffic_reduction"] = out["models"]["conv1d"]["traffic_reduction"]
    _row("kernel_bench/traffic", 0.0,
         f"aggregate={out['traffic_reduction']:.1f}x reduction "
         f"({out['models']['conv1d']['unfused_bytes_total'] / 1e6:.1f}MB->"
         f"{out['models']['conv1d']['fused_bytes_total'] / 1e6:.1f}MB)"
         f";interpret={interpret}")
    return out


# ------------------------------------------------------------ roofline_table
def roofline_table(full: bool = False, seed: int = 0,
                   dryrun_dir: str = "experiments/dryrun"):
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        r = rec["roofline"]
        t_bound = max(r["t_compute"], r["t_memory"], r["t_collective"])
        _row(f"roofline/{rec['mesh']}/{rec['arch']}/{rec['shape']}",
             t_bound * 1e6,
             f"bottleneck={r['bottleneck']}"
             f";frac={r['roofline_fraction']:.3f}")
        rows.append(rec)
    if not rows:
        _row("roofline/none", 0.0, "no dry-run records found")
    return rows


# --------------------------------------------------------------- serve_bench
def serve_bench(full: bool = False, seed: int = 0):
    """Unified multi-target serving vs three single-target services.

    Same conv1d encoder topology and identical request stream; only the
    head layout differs. The unified service runs ONE encoder forward
    pass per graph and reads all three targets off per-target heads;
    the baseline runs the encoder once per (graph, target). Weights are
    untrained — throughput does not depend on them."""
    from repro.core import tokenizer as TOK
    from repro.core.service import CostModelService
    from repro.ir import samplers

    n_req = 512 if full else 128
    cfg = CostModelConfig(name="serve-bench", vocab_size=4096, max_seq=160,
                          embed_dim=64, conv_channels=(64,) * 6,
                          fc_dims=(256, 64))
    rng = np.random.default_rng(seed)
    graphs = [samplers.sample_graph(rng) for _ in range(n_req)]
    vocab = TOK.fit_vocab([TOK.graph_tokens(g, "ops") for g in graphs],
                          max_size=4096)
    heads = CM.DEFAULT_HEADS
    stats1 = {"mu": 0.0, "sigma": 1.0}
    key = jax.random.PRNGKey(seed)
    unified = CostModelService(
        "conv1d", cfg, CM.conv_init(key, cfg, heads=heads), vocab,
        {t: stats1 for t in heads}, mode="ops", max_seq=160)
    singles = [CostModelService(
        "conv1d", cfg, CM.conv_init(key, cfg), vocab, stats1,
        mode="ops", max_seq=160, target=t) for t in heads]

    def run_unified():
        unified._cache.clear()
        unified.predict_all(graphs)

    def run_singles():
        for s in singles:
            s._cache.clear()
            s.predict_graphs(graphs)

    iters = 10 if full else 5
    out = {}
    for name, fn in [("unified_multi_head", run_unified),
                     ("three_single_head", run_singles)]:
        fn()                           # warmup: trigger per-bucket JIT
        t0 = time.perf_counter()
        for _ in range(iters):
            fn()
        us = (time.perf_counter() - t0) / iters * 1e6
        req_s = n_req / (us / 1e6)
        out[name] = req_s
        _row(f"serve_bench/{name}", us,
             f"req_s={req_s:.0f};targets={len(heads)}")
    speedup = out["unified_multi_head"] / out["three_single_head"]
    _row("serve_bench/speedup", 0.0, f"speedup={speedup:.2f}x")
    return out


# ---------------------------------------------------------- serve_concurrent
def serve_concurrent(full: bool = False, seed: int = 0):
    """Async micro-batching gateway vs today's serialized serving, at
    matched offered load.

    At each concurrency level c, c closed-loop clients (each keeps
    exactly one request outstanding, firing the next on completion)
    push the same request stream through two serving designs:

    * ``serialized`` — the synchronous service behind one lock: every
      client's ``predict_all([g])`` is a whole batch-of-one forward
      pass, one caller at a time (the pre-server state this PR's
      motivation describes). Synchronous serving forces one OS thread
      per client — that thread count is part of the design's cost.
    * ``server`` — CostModelServer's native async API: clients are
      future callbacks (``submit`` -> resolve -> next request), no
      thread per client, and submissions coalesce into shared
      per-bucket batched forward passes.

    Weights are untrained (throughput does not depend on them); the LRU
    is cleared before every timed run and the stream has no duplicate
    graphs, so req/s measures forward-pass scheduling, not caching."""
    from repro.core import tokenizer as TOK
    from repro.core.server import CostModelServer
    from repro.core.service import CostModelService
    from repro.ir import samplers

    n_req = 2048 if full else 384
    max_batch = 64
    if full:    # paper-scale: the best Conv1D topology from §4
        cfg = CostModelConfig(name="serve-conc", vocab_size=4096,
                              max_seq=160, embed_dim=64,
                              conv_channels=(64,) * 6, fc_dims=(256, 64))
    else:       # CI-scale: narrower tower, same serving pipeline
        cfg = CostModelConfig(name="serve-conc", vocab_size=4096,
                              max_seq=160, embed_dim=48,
                              conv_filters=(2,) * 4,
                              conv_channels=(48,) * 4, fc_dims=(128, 48))
    rng = np.random.default_rng(seed)
    graphs = [samplers.sample_graph(rng) for _ in range(n_req)]
    vocab = TOK.fit_vocab([TOK.graph_tokens(g, "ops") for g in graphs],
                          max_size=4096)
    heads = CM.DEFAULT_HEADS
    stats = {t: {"mu": 0.0, "sigma": 1.0} for t in heads}
    svc = CostModelService(
        "conv1d", cfg, CM.conv_init(jax.random.PRNGKey(seed), cfg,
                                    heads=heads),
        vocab, stats, mode="ops", max_seq=160, max_batch=max_batch)
    svc.warmup()                       # AOT: no XLA compiles in timed runs

    def clear():
        with svc._cache_lock:
            svc._cache.clear()

    def drive_threads(conc, request_fn):
        """Thread-per-client closed loop (the sync design's shape)."""
        slices = [graphs[i::conc] for i in range(conc)]

        def client(gs):
            for g in gs:
                request_fn(g)

        threads = [threading.Thread(target=client, args=(s,))
                   for s in slices]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        return time.perf_counter() - t0

    def drive_async(server, conc):
        """Closed loop on the async API: conc logical clients, each one
        outstanding ``submit`` whose completion callback consumes the
        prediction and fires the next request. No thread per client."""
        state_lock = threading.Lock()
        state = {"next": 0, "outstanding": 0}
        done = threading.Event()
        errors = []
        denorm = server.service.denormalize_rows

        def fail(e):
            errors.append(e)
            done.set()                 # surface, never hang the bench

        def pump():
            while True:
                with state_lock:
                    i = state["next"]
                    if i >= n_req:
                        if state["outstanding"] == 0:
                            done.set()
                        return
                    state["next"] = i + 1
                    state["outstanding"] += 1
                try:
                    fut = server.submit(graphs[i])
                except Exception as e:
                    fail(e)
                    return
                if fut.done():         # cache-hit fast path: stay inline
                    denorm(fut.result()[None])
                    with state_lock:
                        state["outstanding"] -= 1
                    continue

                def cb(f):
                    try:
                        denorm(f.result()[None])
                        with state_lock:
                            state["outstanding"] -= 1
                        pump()
                    except Exception as e:
                        fail(e)

                fut.add_done_callback(cb)
                return

        t0 = time.perf_counter()
        for _ in range(conc):
            pump()
        done.wait(timeout=300)
        if errors:
            raise errors[0]
        if not done.is_set():
            raise TimeoutError("serve_concurrent clients stalled")
        return time.perf_counter() - t0

    serial_lock = threading.Lock()

    def serialized_request(g):
        with serial_lock:              # one forward pass at a time
            svc.predict_all([g])

    out = {"n_requests": n_req, "max_batch": max_batch, "levels": {}}
    for conc in (1, 8, 64):
        clear()
        base_dt = drive_threads(conc, serialized_request)
        base_req_s = n_req / base_dt

        clear()
        server = CostModelServer(svc, max_batch=max_batch, flush_us=2000)
        server.start(warmup=False)     # service programs already warm
        dt = drive_async(server, conc)
        m = server.metrics.snapshot(server.queue_depth())
        server.stop()
        req_s = n_req / dt
        lvl = {"req_s": req_s, "serialized_req_s": base_req_s,
               "speedup_vs_serialized": req_s / base_req_s,
               "p50_us": m["latency_p50_us"], "p95_us": m["latency_p95_us"],
               "p99_us": m["latency_p99_us"],
               "batch_occupancy": m["batch_occupancy"],
               "full_flushes": m["full_flushes"],
               "deadline_flushes": m["deadline_flushes"],
               "stagnant_flushes": m["stagnant_flushes"],
               # service phase split (hash/encode/forward seconds) as
               # exported by ServerMetrics.snapshot via phase_source
               **{k: v for k, v in m.items()
                  if k.startswith("phase_")}}
        out["levels"][str(conc)] = lvl
        _row(f"serve_concurrent/serialized_c{conc}",
             base_dt / n_req * 1e6, f"req_s={base_req_s:.0f}")
        _row(f"serve_concurrent/server_c{conc}", dt / n_req * 1e6,
             f"req_s={req_s:.0f};speedup={req_s / base_req_s:.2f}x"
             f";occupancy={m['batch_occupancy']:.1f}"
             f";p50_ms={m['latency_p50_us'] / 1e3:.2f}"
             f";p99_ms={m['latency_p99_us'] / 1e3:.2f}")
    # legacy single-thread reference == serialized_c1
    out["serialized_baseline"] = {
        "req_s": out["levels"]["1"]["serialized_req_s"]}
    return out


# -------------------------------------------------------------- search_fleet
def obs_overhead(full: bool = False, seed: int = 0):
    """Cost of the unified telemetry stack on the serving hot path.

    Interleaved best-of-5 passes through the async gateway with the
    FULL obs stack on (head-sampled tracing, the metrics-registry
    JSONL exporter ticking, the drift sentinel scoring in the
    background) vs everything off — the ratio is the observability
    tax, gated in gate.py at >= 0.97x.

    The drive is *occupancy-controlled*: one client submits
    full-``max_batch`` ``predict_all`` calls serially, so every wire
    batch is exactly one full dispatch and the flush timer never
    fires. A thread-herd drive on a shared 1-core CI runner measures
    stochastic batch coalescing (~10-17% CV — scheduler noise swamps
    a 3% gate); with occupancy pinned, the off-pass CV drops to ~3%
    and the ratio actually measures the telemetry code. A separate
    pass with sampling forced to every request and 8 concurrent
    clients then checks that span trees reconstruct under contention
    (completeness >= 0.99 gate) and that drift gauges are present in
    the registry snapshot."""
    import tempfile

    from repro.core import tokenizer as TOK
    from repro.core.server import CostModelServer
    from repro.core.service import CostModelService
    from repro.ir import samplers
    from repro.obs import (JsonlExporter, MetricsRegistry, Tracer,
                           assemble, completeness, register_drift,
                           register_server, register_service,
                           register_tracer)
    from repro.obs.drift import DriftMonitor, attach

    n_req = 1280 if full else 640
    chunk = 16                         # one full wire batch per call
    conc = 8                           # completeness-pass clients
    cfg = CostModelConfig(name="obs-ovh", vocab_size=4096, max_seq=160,
                          embed_dim=48, conv_filters=(2,) * 4,
                          conv_channels=(48,) * 4, fc_dims=(128, 48))
    rng = np.random.default_rng(seed)
    graphs = [samplers.sample_graph(rng) for _ in range(n_req)]
    vocab = TOK.fit_vocab([TOK.graph_tokens(g, "ops") for g in graphs],
                          max_size=4096)
    heads = CM.DEFAULT_HEADS
    stats = {t: {"mu": 0.0, "sigma": 1.0} for t in heads}
    svc = CostModelService(
        "conv1d", cfg, CM.conv_init(jax.random.PRNGKey(seed), cfg,
                                    heads=heads),
        vocab, stats, mode="ops", max_seq=160, max_batch=chunk)
    svc.warmup()                       # AOT: no XLA compiles in timing
    chunks = [graphs[i:i + chunk] for i in range(0, n_req, chunk)]

    def clear():
        with svc._cache_lock:
            svc._cache.clear()

    def drive(server):
        """Occupancy-controlled closed loop on the traced entry point
        (``predict_all``, where sampling, span creation and the drift
        hook live): each call is one full wire batch, so the flush
        timer never fires and batch coalescing is deterministic."""
        t0 = time.perf_counter()
        for c in chunks:
            server.predict_all(c)
        return n_req / (time.perf_counter() - t0)

    def run_pass(obs_on: bool, tmpdir: str, rep: int):
        tracer = drift = exporter = None
        if obs_on:
            tracer = Tracer(sample_every=4)
            drift = attach(svc, DriftMonitor(sample_every=8))
            reg = MetricsRegistry()
            register_service(reg, svc)
            register_drift(reg, drift)
            register_tracer(reg, tracer)
            exporter = JsonlExporter(
                os.path.join(tmpdir, f"obs_{rep}.jsonl"), reg,
                tracer=tracer, interval_s=0.25)
        server = CostModelServer(svc, max_batch=chunk, flush_us=2000,
                                 tracer=tracer)
        if obs_on:
            register_server(reg, server)
            exporter.start()
        server.start(warmup=False)
        clear()
        try:
            req_s = drive(server)
        finally:
            server.stop()
            if obs_on:
                drift.stop()           # drains + scores its queue
                exporter.stop()
                svc.drift = None       # next OFF pass pays nothing
        lines = exporter.lines_written if obs_on else 0
        scored = drift.scored if obs_on else 0
        return req_s, lines, scored

    off_s, on_s, jsonl_lines, drift_scored = [], [], 0, 0
    with tempfile.TemporaryDirectory() as tmpdir:
        run_pass(False, tmpdir, 98)    # untimed warmups, both paths
        run_pass(True, tmpdir, 99)
        for rep in range(5):           # interleaved best-of-5
            r, _, _ = run_pass(False, tmpdir, rep)
            off_s.append(r)
            r, ln, sc = run_pass(True, tmpdir, rep)
            on_s.append(r)
            jsonl_lines, drift_scored = ln, sc

    ratio = max(on_s) / max(off_s)
    _row("obs_overhead/off", 1e6 / max(off_s),
         f"req_s={max(off_s):.0f}")
    _row("obs_overhead/on", 1e6 / max(on_s),
         f"req_s={max(on_s):.0f};ratio={ratio:.3f}"
         f";jsonl_lines={jsonl_lines};drift_scored={drift_scored}")

    # trace-completeness pass: force-sample EVERY request, then check
    # the span trees reconstruct end to end
    tracer = Tracer(sample_every=1)
    drift = attach(svc, DriftMonitor(sample_every=4))
    server = CostModelServer(svc, max_batch=64, flush_us=2000,
                             tracer=tracer)
    server.start(warmup=False)
    clear()
    try:
        sub = graphs[:min(128, n_req)]
        slices = [sub[i::8] for i in range(8)]
        threads = [threading.Thread(
            target=lambda gs: [server.predict_all([g]) for g in gs],
            args=(s,)) for s in slices]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    finally:
        server.stop()
        drift.stop()
        svc.drift = None
    trees = assemble(tracer.recorder.snapshot())
    comp = completeness(trees)
    reg = MetricsRegistry()
    register_drift(reg, drift)
    snap = reg.snapshot()["metrics"]
    want = {"drift.oov_rate"} | {f"drift.spearman.{t}" for t in heads}
    gauges_present = want <= set(snap)
    _row("obs_overhead/trace", 0.0,
         f"traces={len(trees)};completeness={comp:.3f}"
         f";drift_gauges={int(gauges_present)}")
    return {"n_requests": n_req, "concurrency": conc,
            "req_s_off": max(off_s), "req_s_on": max(on_s),
            "overhead_ratio": ratio,
            "jsonl_lines": jsonl_lines, "drift_scored": drift_scored,
            "trace": {"n_traces": len(trees),
                      "completeness": comp},
            "drift_gauges_present": gauges_present}


def _unoptimized_ir(g, rng):
    """Dress a sampled graph up as the *unoptimized* IR a compiler
    hands the optimizer: naive elementwise chains (fusion fodder),
    duplicated subexpressions (CSE fodder), and dead ops (DCE
    fodder), so every search has a rich rewrite frontier instead of
    the handful of sites already-clean graphs expose."""
    from repro.ir import graph as IRG
    from repro.ir.graph import ELEMENTWISE, Tensor
    ew = sorted(ELEMENTWISE)
    new = IRG.Graph(name=g.name + "_raw")
    new.values = list(g.values[:g.n_args])
    new.n_args = g.n_args
    for op in g.ops:
        new.add_op(op.opcode, list(op.operands),
                   g.values[op.result], **op.attrs)
    new.outputs = list(g.outputs)
    results = [op.result for op in new.ops]
    for _ in range(6):               # fusable chains ending in outputs
        v = results[int(rng.integers(0, len(results)))]
        for _ in range(int(rng.integers(3, 7))):
            t = new.values[v]
            v = new.add_op(ew[int(rng.integers(0, len(ew)))], [v],
                           Tensor(t.shape, t.dtype))
        new.outputs.append(v)
    for _ in range(4):               # duplicate subexpressions (CSE)
        op = new.ops[int(rng.integers(0, len(new.ops)))]
        d = new.add_op(op.opcode, list(op.operands),
                       new.values[op.result], **op.attrs)
        t = new.values[d]
        new.outputs.append(
            new.add_op("relu", [d], Tensor(t.shape, t.dtype)))
    for _ in range(3):               # dead ops (DCE)
        v = results[int(rng.integers(0, len(results)))]
        t = new.values[v]
        new.add_op("exp", [v], Tensor(t.shape, t.dtype))
    new.validate()
    return new


def _fleet_fixture(full: bool, seed: int):
    """Shared pool / vocab / params / knobs for the fleet benches
    (search_fleet and search_fleet_replicated run identical work)."""
    from repro.core import tokenizer as TOK
    from repro.core.service import CostModelService
    from repro.ir import samplers
    from repro.opt import rewrites as RW

    n_workers = 12 if full else 8
    n_pool = 10 if full else 5
    beam, steps, budget = (4, 4, 128) if full else (4, 3, 64)
    max_batch = 32
    cfg = CostModelConfig(name="fleet", vocab_size=4096, max_seq=256,
                          embed_dim=48, conv_filters=(2,) * 4,
                          conv_channels=(48,) * 4, fc_dims=(128, 48))
    rng = np.random.default_rng(seed)
    fams = sorted(samplers.SAMPLERS)
    pool = [_unoptimized_ir(
        samplers.sample_graph(rng, fams[i % len(fams)]), rng)
        for i in range(n_pool)]
    # vocab over the pool + rewritten variants, so fused/bf16 candidate
    # text is in-vocabulary (as a rewrite_factor training corpus would be)
    vocab_seqs = [TOK.graph_tokens(g, "ops") for g in pool]
    vocab_seqs += [TOK.graph_tokens(RW.random_rewrite(g, rng), "ops")
                   for g in pool for _ in range(3)]
    vocab = TOK.fit_vocab(vocab_seqs, max_size=4096)
    heads = CM.DEFAULT_HEADS
    params = CM.conv_init(jax.random.PRNGKey(seed), cfg, heads=heads)
    stats = {t: {"mu": 0.0, "sigma": 1.0} for t in heads}

    def make_service(**kw):
        return CostModelService("conv1d", cfg, params, vocab, stats,
                                mode="ops", max_seq=256,
                                max_batch=max_batch,
                                buckets=(64, 128, 256),
                                batch_ladder=(1, 2, 4, 8, 16, 32), **kw)

    return {"n_workers": n_workers, "n_pool": n_pool, "beam": beam,
            "steps": steps, "budget": budget, "max_batch": max_batch,
            "cfg": cfg, "pool": pool, "vocab": vocab, "heads": heads,
            "params": params, "stats": stats,
            "make_service": make_service}


def search_fleet(full: bool = False, seed: int = 0):
    """Fleet-scale concurrent search: N beam_search workers drive ONE
    async micro-batching CostModelServer gateway.

    Workers optimize the same graph pool in rotated order, the
    compiler-fleet shape the server was built for: different searches
    re-derive the same candidates, so requests coalesce in flight and
    cross-search LRU hits dominate — exactly the regime where candidate
    *featurization* (struct hashing + tokenization + encoding), not the
    forward pass, is the hot path. The fleet runs twice over identical
    work:

    * ``fast``     — incremental struct hashing (rewrites thread parent
      hash memos), key-first LRU probes, ids cache + parent-delta token
      splicing, vectorized encode_many.
    * ``baseline`` — both switched off (``set_incremental_hashing(False)``
      + ``fast_encode=False``): every candidate pays a full SHA-1 Merkle
      walk per struct_key call and a full re-lex + dict.get encode, the
      pre-incremental behavior.

    Reports candidates-costed/s per mode (gate: fast >= ~2x baseline),
    cache/dedup hit rates, batch occupancy, the tokenize/encode/hash vs
    forward wall-clock split, and bf16-vs-f32 serving drift (gate:
    Spearman >= 0.99 per target on the candidate corpus). Weights are
    untrained — throughput and drift ranking do not depend on them."""
    from repro.core.server import CostModelServer
    from repro.ir import graph as IRG
    from repro.opt import rewrites as RW
    from repro.opt import search as OS

    fx = _fleet_fixture(full, seed)
    n_workers, n_pool = fx["n_workers"], fx["n_pool"]
    beam, steps, budget = fx["beam"], fx["steps"], fx["budget"]
    max_batch, pool, heads = fx["max_batch"], fx["pool"], fx["heads"]
    make_service = fx["make_service"]

    def run_fleet(svc):
        """Drive the full fleet once; returns (wall_s, candidates, mode
        metrics). Caller owns warmup/cache state."""
        server = CostModelServer(svc, max_batch=max_batch,
                                 flush_us=150)
        server.start(warmup=False)
        results, errs = [], []

        def worker(w):
            try:
                gs = pool[w % n_pool:] + pool[:w % n_pool]
                for g in gs:
                    results.append(OS.beam_search(
                        server, g, beam_width=beam, max_steps=steps,
                        eval_budget=budget))
            except Exception as e:       # surface, don't hang the bench
                errs.append(e)

        threads = [threading.Thread(target=worker, args=(w,))
                   for w in range(n_workers)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        dt = time.perf_counter() - t0
        if errs:
            raise errs[0]
        m = server.metrics.snapshot(server.queue_depth())
        server.stop()
        # every evaluated candidate plus each search's root was costed
        cands = sum(r.evaluated + 1 for r in results)
        return dt, cands, m

    out = {"n_workers": n_workers, "n_pool": n_pool,
           "searches": n_workers * n_pool, "beam": beam,
           "max_steps": steps, "eval_budget": budget, "modes": {}}

    def _fleet_pass(mode, svc):
        """One fleet pass under the mode's hashing flag; returns
        (wall, candidates, server metrics, phase delta)."""
        prev = IRG.set_incremental_hashing(mode == "fast")
        try:
            with svc._cache_lock:
                svc._phase_s = {k: 0.0 for k in svc._phase_s}
            dt, cands, m = run_fleet(svc)
            return dt, cands, m, svc.phase_stats()
        finally:
            IRG.set_incremental_hashing(prev)

    modes = ("fast", "baseline")
    svcs, cold, cstats = {}, {}, {}
    for mode in modes:
        svc = make_service(fast_encode=(mode == "fast"))
        svc.warmup()                     # AOT: no XLA compiles when timed
        _fleet_pass(mode, svc)           # untimed: python warm
        with svc._cache_lock:            # cold pass starts cache-cold
            svc._cache.clear()
            svc._ids_cache.clear()
        # cold pass: compulsory misses — forward passes, batching and
        # cross-search dedup are all on the clock
        cold[mode] = _fleet_pass(mode, svc)
        cstats[mode] = svc.cache_stats()
        svcs[mode] = svc
    # steady passes: caches stay warm (the long-running-fleet regime the
    # hot path is built for) — every candidate is still re-derived,
    # re-hashed, and re-featurized per search, but predictions answer
    # from the shared LRU, so the clock isolates exactly the
    # per-candidate featurization cost the incremental path removes.
    # Modes alternate (fast, baseline, fast, ...) and take best-of-3 so
    # load drift on a shared runner hits both modes alike.
    steady = {m: None for m in modes}
    for _ in range(3):
        for mode in modes:
            d, c, _, p = _fleet_pass(mode, svcs[mode])
            if steady[mode] is None or c / d > \
                    steady[mode][1] / steady[mode][0]:
                steady[mode] = (d, c, p)

    def _worker_pass(mode):
        """One single-worker pass over the warm pool, through the
        gateway -> candidates/s. The search loop is GIL-bound python, so
        aggregate fleet candidates/s tracks per-worker per-candidate
        cost — and single-threaded passes resist shared-runner scheduler
        noise far better than N-thread wall clock. Best-of-3, modes
        interleaved (same rationale as the fleet steady passes), is the
        gated speedup; the fleet wall ratios are reported alongside."""
        svc = svcs[mode]
        prev = IRG.set_incremental_hashing(mode == "fast")
        try:
            server = CostModelServer(svc, max_batch=max_batch,
                                     flush_us=150)
            server.start(warmup=False)
            cands = 0
            t0 = time.perf_counter()
            for g in pool:
                r = OS.beam_search(server, g, beam_width=beam,
                                   max_steps=steps, eval_budget=budget)
                cands += r.evaluated + 1
            dt = time.perf_counter() - t0
            server.stop()
            return cands / dt
        finally:
            IRG.set_incremental_hashing(prev)

    worker_cps = {m: 0.0 for m in modes}
    for _ in range(3):
        for mode in modes:
            worker_cps[mode] = max(worker_cps[mode], _worker_pass(mode))
    for mode in modes:
        dt_c, cands_c, m, phase = cold[mode]
        dt_s, cands_s, phase_s = steady[mode]
        st = cstats[mode]
        featurize_s = phase["hash_s"] + phase["encode_s"]
        rec = {"cold": {"wall_s": dt_c, "candidates_costed": cands_c,
                        "candidates_per_s": cands_c / dt_c},
               "steady": {"wall_s": dt_s, "candidates_costed": cands_s,
                          "candidates_per_s": cands_s / dt_s,
                          "hash_s": phase_s["hash_s"],
                          "encode_s": phase_s["encode_s"]},
               "phase_split": {
                   "hash_s": phase["hash_s"],
                   "encode_s": phase["encode_s"],
                   "forward_s": phase["forward_s"],
                   "featurize_frac_of_wall": featurize_s / dt_c},
               "lru_hit_rate": st["hit_rate"],
               "ids_cache_hit_rate": st["ids_hit_rate"],
               "delta_encodes": phase["delta_encodes"],
               "full_encodes": phase["full_encodes"],
               "truncations": st["truncations"],
               "server": {"requests": m["requests"],
                          "cache_hit_rate": m["cache_hit_rate"],
                          "coalesced": m["coalesced"],
                          "batches": m["batches"],
                          "batch_occupancy": m["batch_occupancy"],
                          # service phase split as exported by
                          # ServerMetrics.snapshot (phase_source)
                          **{k: v for k, v in m.items()
                             if k.startswith("phase_")}}}
        out["modes"][mode] = rec
        _row(f"search_fleet/{mode}_cold", dt_c / cands_c * 1e6,
             f"cands_s={cands_c / dt_c:.0f};workers={n_workers}"
             f";lru_hit={st['hit_rate']:.1%}"
             f";occupancy={m['batch_occupancy']:.1f}"
             f";hash_ms={phase['hash_s'] * 1e3:.0f}"
             f";encode_ms={phase['encode_s'] * 1e3:.0f}"
             f";forward_ms={phase['forward_s'] * 1e3:.0f}")
        _row(f"search_fleet/{mode}_steady", dt_s / cands_s * 1e6,
             f"cands_s={cands_s / dt_s:.0f}"
             f";hash_ms={phase_s['hash_s'] * 1e3:.0f}"
             f";encode_ms={phase_s['encode_s'] * 1e3:.0f}")
    speedup = worker_cps["fast"] / worker_cps["baseline"]
    fleet_speedup = (out["modes"]["fast"]["steady"]["candidates_per_s"]
                     / out["modes"]["baseline"]["steady"]
                     ["candidates_per_s"])
    cold_speedup = (out["modes"]["fast"]["cold"]["candidates_per_s"]
                    / out["modes"]["baseline"]["cold"]["candidates_per_s"])
    out["per_worker_steady_cands_s"] = worker_cps
    out["speedup_vs_baseline"] = speedup      # per-worker steady (gated)
    out["fleet_steady_speedup_vs_baseline"] = fleet_speedup
    out["cold_speedup_vs_baseline"] = cold_speedup
    _row("search_fleet/speedup", 0.0,
         f"per_worker_steady={speedup:.2f}x"
         f";fleet_steady={fleet_speedup:.2f}x;cold={cold_speedup:.2f}x")

    # bf16 serving drift vs f32 on the fleet's candidate corpus: same
    # params, bf16-cast once; gate.py enforces Spearman >= 0.99/target
    corpus = list(pool)
    crng = np.random.default_rng(seed + 7)
    corpus += [RW.random_rewrite(g, crng) for g in pool for _ in range(5)]
    # tie-averaging + degenerate-safe rank correlation (0.0, not NaN,
    # when a head collapses — a NaN must not slip past the drift gate)
    from repro.opt.evaluate import spearman
    svc_f32 = make_service()
    svc_bf16 = make_service(dtype="bf16")
    p32 = svc_f32.predict_all(corpus)
    pbf = svc_bf16.predict_all(corpus)

    drift = {"spearman": {}, "max_rel_err": {}}
    for t in heads:
        drift["spearman"][t] = spearman(p32[t], pbf[t])
        rel = np.abs(pbf[t] - p32[t]) / np.maximum(np.abs(p32[t]), 1e-9)
        drift["max_rel_err"][t] = float(rel.max())
    drift["spearman_min"] = min(drift["spearman"].values())
    drift["max_rel_err_all"] = max(drift["max_rel_err"].values())
    out["bf16"] = drift
    _row("search_fleet/bf16_drift", 0.0,
         f"spearman_min={drift['spearman_min']:.4f}"
         f";max_rel_err={drift['max_rel_err_all']:.4f}"
         f";corpus={len(corpus)}")
    return out


# --------------------------------------------------- search_fleet_replicated
def search_fleet_replicated(full: bool = False, seed: int = 0,
                            replicas: int = 4):
    """Replicated serving tier vs the thread fleet, on identical work.

    * ``baseline`` — today's pre-replication worst case: N *thread*
      workers convoying on the GIL through one in-process gateway, with
      incremental hashing and fast_encode both off (every candidate
      re-hashed and re-encoded from scratch).
    * ``replicated`` — N *process* workers, each a persistent
      :class:`~repro.serving.router.ReplicaClient` (GIL-free search +
      featurization + local LRU), routing misses by struct key across
      ``replicas`` spawned model replicas with adaptive flush deadlines
      and a shared cross-replica cache behind them.

    Both run the same pool / rotation / search parameters: a warm
    (untimed) pass, a cache-cold timed pass, then best-of-3 steady
    passes (each steady pass repeats the pool 3x inside one timed
    window, so the per-pass barrier does not pollute the short
    steady measurement). gate.py enforces replicated steady >= 3x
    baseline steady
    locally (>= 2x on shared CI runners) at replicas >= 4. Also reports
    per-replica LRU hit rates (struct-key routing should keep each
    replica's working set disjoint and hot), router health, shared-tier
    hits, and the adaptive effective-flush gauge."""
    from repro.core.server import CostModelServer
    from repro.ir import graph as IRG
    from repro.opt import search as OS
    from repro.serving import FleetDriver, ServiceSpec, start_replicas

    fx = _fleet_fixture(full, seed)
    n_workers, pool = fx["n_workers"], fx["pool"]
    search_kw = {"beam_width": fx["beam"], "max_steps": fx["steps"],
                 "eval_budget": fx["budget"]}
    max_batch = fx["max_batch"]
    out = {"n_workers": n_workers, "n_pool": fx["n_pool"],
           "replicas": replicas, "modes": {}, **search_kw}

    # ---- baseline: thread fleet, from-scratch featurization ----------
    svc = fx["make_service"](fast_encode=False)
    svc.warmup()

    def _thread_pass(rounds=1):
        prev = IRG.set_incremental_hashing(False)
        try:
            server = CostModelServer(svc, max_batch=max_batch,
                                     flush_us=150)
            server.start(warmup=False)
            results, errs = [], []

            def worker(w):
                try:
                    for _ in range(rounds):
                        results.extend(OS.search_pool(
                            server, pool, offset=w, **search_kw))
                except Exception as e:
                    errs.append(e)

            threads = [threading.Thread(target=worker, args=(w,))
                       for w in range(n_workers)]
            t0 = time.perf_counter()
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            dt = time.perf_counter() - t0
            server.stop()
            if errs:
                raise errs[0]
            return dt, sum(r.evaluated + 1 for r in results)
        finally:
            IRG.set_incremental_hashing(prev)

    def _clear_base():
        with svc._cache_lock:
            svc._cache.clear()
            svc._ids_cache.clear()

    # steady passes repeat the pool STEADY_ROUNDS times inside one
    # timed pass (both modes): the measurement window grows ~3x while
    # the per-pass setup/barrier cost is paid once, which keeps
    # scheduler noise on a busy host out of the gated ratio
    STEADY_ROUNDS = 3
    _thread_pass()                     # python-warm, untimed
    _clear_base()
    base_cold = _thread_pass()
    base_steady = base_cold
    for _ in range(3):
        d, c = _thread_pass(rounds=STEADY_ROUNDS)
        if c / d > base_steady[1] / base_steady[0]:
            base_steady = (d, c)

    # ---- replicated: process fleet behind the router -----------------
    spec = ServiceSpec.from_service(fx["make_service"](fast_encode=True))
    tier = start_replicas(spec, replicas, n_clients=n_workers,
                          max_batch=max_batch, flush_us=150.0,
                          adaptive_flush=True)
    try:
        driver = FleetDriver.start(tier, pool, n_workers,
                                   search_kw=search_kw)
        try:
            driver.run_pass()          # warm, untimed
            driver.clear()
            tier.shared_cache.clear()
            rep_cold = driver.run_pass()
            rep_steady = rep_cold
            for _ in range(3):
                p = driver.run_pass(rounds=STEADY_ROUNDS)
                if p["candidates"] / p["wall_s"] > \
                        rep_steady["candidates"] / rep_steady["wall_s"]:
                    rep_steady = p
            stats = driver.stats(include_replicas=True)
        finally:
            driver.stop()
    finally:
        tier.stop()

    def _cps(dt, cands):
        return cands / dt

    out["modes"]["baseline"] = {
        "cold": {"wall_s": base_cold[0], "candidates": base_cold[1],
                 "candidates_per_s": _cps(*base_cold)},
        "steady": {"wall_s": base_steady[0], "candidates": base_steady[1],
                   "candidates_per_s": _cps(*base_steady)}}
    rep_rec = {
        "cold": {"wall_s": rep_cold["wall_s"],
                 "candidates": rep_cold["candidates"],
                 "candidates_per_s": rep_cold["candidates"]
                 / rep_cold["wall_s"]},
        "steady": {"wall_s": rep_steady["wall_s"],
                   "candidates": rep_steady["candidates"],
                   "candidates_per_s": rep_steady["candidates"]
                   / rep_steady["wall_s"]}}
    replica_stats = (stats[0] or {}).get("replicas") or []
    per_replica = []
    for payload in replica_stats:
        if not payload:
            continue
        s, c = payload["server"], payload["cache"]
        per_replica.append({
            "replica_id": payload["replica_id"],
            "requests": s["requests"],
            "batches": s["batches"],
            "batch_occupancy": s["batch_occupancy"],
            "lru_hit_rate": c["hit_rate"],
            "lru_size": c["size"],
            "flush_us_effective": s.get("flush_us_effective"),
            "shared_hits": payload["shared_hits"],
            "shared_misses": payload["shared_misses"],
            **{k: v for k, v in s.items() if k.startswith("phase_")}})
    rep_rec["per_replica"] = per_replica
    rep_rec["router"] = {
        "shed_total": sum(w["shed_count"] for w in stats if w),
        "health": [w["health"] for w in stats if w],
        "local_hit_rates": [w["local_cache"]["hit_rate"]
                            for w in stats if w]}
    rep_rec["shared_cache_fill"] = tier.shared_cache.fill()
    out["modes"]["replicated"] = rep_rec

    steady_ratio = (rep_rec["steady"]["candidates_per_s"]
                    / out["modes"]["baseline"]["steady"]
                    ["candidates_per_s"])
    cold_ratio = (rep_rec["cold"]["candidates_per_s"]
                  / out["modes"]["baseline"]["cold"]["candidates_per_s"])
    out["replicated_steady_speedup_vs_baseline"] = steady_ratio
    out["replicated_cold_speedup_vs_baseline"] = cold_ratio
    for mode in ("baseline", "replicated"):
        for ph in ("cold", "steady"):
            r = out["modes"][mode][ph]
            _row(f"search_fleet_replicated/{mode}_{ph}",
                 r["wall_s"] / r["candidates"] * 1e6,
                 f"cands_s={r['candidates_per_s']:.0f}"
                 f";workers={n_workers};replicas="
                 f"{replicas if mode == 'replicated' else 0}")
    hits = [f"{r['lru_hit_rate']:.0%}" for r in per_replica]
    _row("search_fleet_replicated/speedup", 0.0,
         f"steady={steady_ratio:.2f}x;cold={cold_ratio:.2f}x"
         f";replica_lru_hits={'/'.join(hits)}"
         f";shed={rep_rec['router']['shed_total']}")
    return out


# ---------------------------------------------------------------- opt_search
def opt_search(full: bool = False, seed: int = 0):
    """Cost-model-guided beam search (repro.opt) vs the one-shot
    FusionAdvisor baseline, judged by the ir/analyzers oracle.

    Trains a joint multi-target conv1d model on a rewrite-augmented
    corpus (so fused / bf16 IR text is in-vocabulary), serves it behind
    the async micro-batching CostModelServer, then beam-searches rewrite
    sequences over graphs sampled from all five families. Reports search
    throughput (graphs/s — every frontier expansion is ONE batched
    predict_all through the server) and oracle latency improvement;
    ``gate.py`` soft-gates beam improvement >= the baseline's."""
    from repro.core.server import CostModelServer
    from repro.core.service import CostModelService
    from repro.ir import samplers
    from repro.opt import evaluate as OE
    from repro.opt import search as OS

    n_train = 3000 if full else 700
    steps = 700 if full else 250
    n_eval = 50 if full else 20
    cfg = CostModelConfig(name="opt-bench", vocab_size=4096, max_seq=160,
                          embed_dim=64, conv_channels=(64,) * 6,
                          fc_dims=(256, 64))
    ds = DS.build_dataset(n_train, mode="ops", max_seq=160,
                          vocab_size=4096, augment_factor=1,
                          rewrite_factor=1, seed=seed)
    tr, _ = ds.split(0.1)
    t0 = time.time()
    res = TR.TrainEngine("conv1d", cfg, CM.DEFAULT_HEADS, steps=steps,
                         batch_size=128, lr=2e-3, seed=seed).fit(tr)
    _row("opt_search/train", (time.time() - t0) * 1e6,
         f"steps={steps};rows={len(tr)}")
    svc = CostModelService("conv1d", cfg, res.params, ds.vocab,
                           res.norm_stats, mode="ops", max_seq=160)
    rng = np.random.default_rng(seed + 1)
    fams = sorted(samplers.SAMPLERS)
    graphs = [samplers.sample_graph(rng, fams[i % len(fams)])
              for i in range(n_eval)]
    with CostModelServer(svc, max_batch=64, flush_us=1000) as server:
        t0 = time.perf_counter()
        report = OE.evaluate_search(
            server, graphs, objective=OS.Objective(),
            beam_width=4 if full else 3, max_steps=6 if full else 4,
            eval_budget=256 if full else 128)
        dt = time.perf_counter() - t0
        metrics = server.metrics.snapshot()
    phase = svc.phase_stats()
    s = report["summary"]
    throughput = n_eval / dt
    _row("opt_search/phase_split", 0.0,
         f"hash_ms={phase['hash_s'] * 1e3:.0f}"
         f";encode_ms={phase['encode_s'] * 1e3:.0f}"
         f";forward_ms={phase['forward_s'] * 1e3:.0f}"
         f";delta_encodes={phase['delta_encodes']}"
         f";full_encodes={phase['full_encodes']}")
    _row("opt_search/beam", dt / n_eval * 1e6,
         f"graphs_s={throughput:.2f}"
         f";oracle_impr={s['oracle_improvement_mean']:.1%}"
         f";fuse_baseline={s['baseline_oracle_improvement_mean']:.1%}"
         f";beat_baseline={s['frac_strictly_better_than_baseline']:.0%}")
    _row("opt_search/model_fidelity", 0.0,
         f"pred_impr={s['pred_improvement_mean']:.1%}"
         f";spearman_within={s['spearman_pred_oracle']:.3f}"
         f";spearman_pooled={s['spearman_pred_oracle_pooled']:.3f}"
         f";candidates={s['candidates_costed']}"
         f";predict_calls={s['predict_calls']}")
    return {"n_eval": n_eval, "throughput_graphs_s": throughput,
            "summary": s, "per_graph": report["per_graph"],
            "phase_split": {k: phase[k]
                            for k in ("hash_s", "encode_s", "forward_s",
                                      "delta_encodes", "full_encodes")},
            "server": {k: metrics[k] for k in
                       ("requests", "batches", "batch_occupancy",
                        "cache_hit_rate")}}


# --------------------------------------------------------------- train_bench
def train_bench(full: bool = False, seed: int = 0):
    """TrainEngine bucketed batching vs max_seq padding on a mixed-length
    corpus: steady-state steps/s (median step time, robust to per-bucket
    compile spikes) and per-target eval parity on the same seed.

    ``bucketed`` is the engine default (batch_max: identical batch
    composition, per-batch bucket pad width — gradient-identical to the
    padded baseline, so eval metrics match to float noise).
    ``bucketed_homogeneous`` single-bucket batches are the throughput
    ceiling; their batches are length-correlated, so eval parity is NOT
    claimed for them (see data/pipeline.py)."""
    n = 4000 if full else 1000
    steps = 400 if full else 160
    cfg = CostModelConfig(name="train-bench", vocab_size=4096, max_seq=256,
                          embed_dim=64, conv_channels=(64,) * 6,
                          fc_dims=(256, 64))
    ds = DS.build_dataset(n, mode="ops", max_seq=256, vocab_size=4096,
                          augment_factor=2, seed=seed)
    tr, te = ds.split(0.1)
    out = {}
    runs = [("padded_max_seq", dict(bucketed=False)),
            ("bucketed", dict(bucketed=True)),
            ("bucketed_homogeneous",
             dict(bucketed=True, bucket_mode="homogeneous",
                  drop_remainder=False))]   # tails: every bucket trains
    for name, kw in runs:
        dts = []
        eng = TR.TrainEngine("conv1d", cfg, "register_pressure",
                             steps=steps, batch_size=64, lr=2e-3,
                             seed=seed, **kw)
        res = eng.fit(tr, on_step=lambda s, dt: dts.append(dt))
        m = TR.evaluate("conv1d", cfg, res, te, "register_pressure")
        med = float(np.median(dts))
        out[name] = {"steps_per_s": 1.0 / med, "metrics": m}
        _row(f"train_bench/{name}", med * 1e6,
             f"steps_s={1.0 / med:.1f}"
             f";rmse_rel={m['rmse_rel_pct']:.2f}%"
             f";exact={m['exact_pct']:.1f}%")
    for name in ["bucketed", "bucketed_homogeneous"]:
        speedup = out[name]["steps_per_s"] / \
            out["padded_max_seq"]["steps_per_s"]
        _row(f"train_bench/speedup_{name}", 0.0, f"speedup={speedup:.2f}x")
    return out


# ------------------------------------------------- transformer_extension
def transformer_extension(full: bool = False, seed: int = 0):
    """Beyond-paper: the paper's §6 future-work #1 (Transformer cost
    model) head-to-head with its best Conv1D model."""
    n = 6000 if full else 1200
    steps = 1200 if full else 300
    cfg = CostModelConfig(name="bench-xf", vocab_size=2048, max_seq=128,
                          embed_dim=64, conv_channels=(64,) * 6,
                          fc_dims=(128, 64))
    ds = DS.build_dataset(n, mode="ops", max_seq=128, vocab_size=2048,
                          augment_factor=2, seed=seed)
    tr, te = ds.split(0.1)
    out = {}
    for kind in ["conv1d", "xformer"]:
        t0 = time.time()
        res = TR.TrainEngine(kind, cfg, "register_pressure",
                             steps=steps, batch_size=64,
                             lr=2e-3 if kind == "conv1d" else 1e-3,
                             seed=seed).fit(tr)
        m = TR.evaluate(kind, cfg, res, te, "register_pressure")
        out[kind] = m
        _row(f"transformer_extension/{kind}", (time.time() - t0) * 1e6,
             f"rmse_rel={m['rmse_rel_pct']:.2f}%"
             f";within5={m['within5_pct']:.1f}%")
    return out


# --------------------------------------------------------------- ingest
def ingest(full: bool = False, seed: int = 0):
    """Real-MLIR front door: arch-corpus ingestion throughput plus fuzz
    robustness.

    Lowers the per-layer StableHLO subgraphs of real architectures from
    ``repro.configs.ARCHS``, pushes every text through
    ``CostModelService.predict_text`` with an OOV-extended vocab (hash
    unk shards + byte fallback), then a seeded fuzz corpus of >= 200
    mutated/truncated/dialect-spliced texts. ``gate.py`` hard-gates
    zero uncaught exceptions, zero arch-corpus ingest errors, and zero
    collapse onto bare ``<unk>``."""
    from repro.core import tokenizer as TOKZ
    from repro.core.service import CostModelService
    from repro.ir import frontdoor as FD
    from repro.ir import samplers
    from repro.ir import stablehlo as SH

    names = None if full else ["qwen3-0.6b", "xlstm-125m",
                               "whisper-small", "granite-moe-1b-a400m",
                               "starcoder2-3b"]
    t0 = time.perf_counter()
    corpus = SH.lower_arch_corpus(names, seq=8)
    lower_s = time.perf_counter() - t0

    cfg = CostModelConfig(name="bench-ingest", vocab_size=1024,
                          max_seq=256, embed_dim=16,
                          conv_channels=(16,) * 2, fc_dims=(32,))
    rng = np.random.default_rng(seed)
    seqs = [TOKZ.graph_tokens(samplers.sample_graph(rng), "ops")
            for _ in range(16)]
    vocab = TOKZ.extend_vocab_oov(
        TOKZ.fit_vocab(seqs, max_size=600), n_unk_buckets=32,
        byte_fallback=True, max_size=cfg.vocab_size)
    params = CM.conv_init(jax.random.PRNGKey(seed), cfg,
                          heads=CM.DEFAULT_HEADS)
    stats = {t: {"mu": 0.2, "sigma": 1.3} for t in CM.DEFAULT_HEADS}
    svc = CostModelService("conv1d", cfg, params, vocab, stats,
                           mode="ops", max_seq=256)

    texts = [t for _, _, t in corpus]
    t0 = time.perf_counter()
    outs = [svc.predict_text(t) for t in texts]
    arch_dt = time.perf_counter() - t0
    preds = [o for o in outs if not isinstance(o, FD.IngestError)]
    arch = {
        "texts": len(texts),
        "errors": len(outs) - len(preds),
        "unk_rate_max": max((p.unk_rate for p in preds), default=1.0),
        "oov_rate_mean": float(np.mean([p.oov_rate for p in preds]))
        if preds else 1.0,
        "texts_per_s": len(texts) / arch_dt if arch_dt else 0.0,
    }
    _row("ingest/arch_corpus", arch_dt / max(len(texts), 1) * 1e6,
         f"texts={arch['texts']};errors={arch['errors']}"
         f";unk_max={arch['unk_rate_max']:.2f}"
         f";oov_mean={arch['oov_rate_mean']:.2f}")

    n_fuzz = 400 if full else 200
    mutated = FD.fuzz_corpus(texts, n_fuzz,
                             np.random.default_rng(seed + 1))
    ok = err = uncaught = 0
    t0 = time.perf_counter()
    for t in mutated:
        try:
            out = svc.predict_text(t)
            if isinstance(out, FD.IngestError):
                err += 1
            else:
                ok += 1
        except Exception:
            uncaught += 1
    fuzz_dt = time.perf_counter() - t0
    fuzz = {"n": len(mutated), "predictions": ok,
            "structured_errors": err, "uncaught": uncaught,
            "texts_per_s": len(mutated) / fuzz_dt if fuzz_dt else 0.0}
    _row("ingest/fuzz", fuzz_dt / max(len(mutated), 1) * 1e6,
         f"n={fuzz['n']};ok={ok};err={err};uncaught={uncaught}")

    ps = svc.phase_stats()
    return {"archs": len({a for a, _, _ in corpus}),
            "lower_s": lower_s, "arch": arch, "fuzz": fuzz,
            "service_oov_rate": ps["oov_rate"],
            "ingest_errors": ps["ingest_errors"]}


# ---------------------------------------------------------- chaos_serve
def chaos_serve(full: bool = False, seed: int = 0):
    """Supervised replicated tier under a seeded, scripted fault plan.

    A 2-replica tier with a :class:`ReplicaSupervisor` serves a
    closed-loop request stream through a :class:`FaultyTransport`
    whose :class:`FaultPlan` (clocked by the send-op counter, so the
    schedule replays byte-for-byte) covers every fault seam: SIGKILL a
    replica, SIGSTOP-wedge the other (the heartbeat's job to catch),
    drop/delay/duplicate requests, and scribble over two occupied
    shared-cache slots. ``gate.py`` hard-gates availability >= 0.99
    across chaos rounds, bounded slot recovery, zero divergence of
    non-degraded replies from the fault-free reference round (bit
    parity, not tolerance), the kill+wedge events actually landing,
    and the supervisor/router counters surfacing through the obs
    registry snapshot."""
    from repro.core import tokenizer as TOK
    from repro.core.service import CostModelService
    from repro.ir import samplers
    from repro.obs import (MetricsRegistry, register_router,
                           register_supervisor)
    from repro.serving import (FaultEvent, FaultPlan, FaultyTransport,
                               QueueTransport, ReplicaClient,
                               ReplicaSupervisor, ServiceSpec,
                               start_replicas)

    cfg = CostModelConfig(name="chaos-serve", vocab_size=512,
                          max_seq=64, embed_dim=16,
                          conv_channels=(16,) * 2, fc_dims=(32,))
    rng = np.random.default_rng(seed)
    graphs = [samplers.sample_graph(rng) for _ in range(24)]
    vocab = TOK.fit_vocab([TOK.graph_tokens(g, "ops") for g in graphs],
                          max_size=512)
    heads = CM.DEFAULT_HEADS
    svc = CostModelService(
        "conv1d", cfg,
        CM.conv_init(jax.random.PRNGKey(seed), cfg, heads=heads),
        vocab, {t: {"mu": 0.2, "sigma": 1.3} for t in heads},
        mode="ops", max_seq=64, max_batch=8)
    spec = ServiceSpec.from_service(svc)
    u = len({g.struct_key() for g in graphs})

    # The workload is the clock: round 0 spans ops [0, u) and stays
    # clean (it is the parity reference), then every seam in order.
    # The wedged slot is recovered BY the supervisor (its respawn
    # SIGKILLs the stopped process) — the late unwedge lands on the
    # already-respawned healthy slot, exercising the event kind as a
    # harmless no-op rather than racing the heartbeat detector.
    plan = FaultPlan(seed=seed, events=[
        FaultEvent(at=u, kind="corrupt",
                   key=graphs[0].struct_key()),
        FaultEvent(at=u + 1, kind="corrupt",
                   key=graphs[1].struct_key()),
        FaultEvent(at=2 * u, kind="kill", replica=0),
        FaultEvent(at=12 * u, kind="wedge", replica=1),
        FaultEvent(at=20 * u, kind="drop", replica=0, count=3),
        FaultEvent(at=20 * u + 1, kind="delay", replica=1, count=2,
                   delay_s=0.05),
        FaultEvent(at=20 * u + 2, kind="dup", replica=0, count=2),
        FaultEvent(at=40 * u, kind="unwedge", replica=1),
    ])

    tier = start_replicas(spec, 2, n_clients=1, flush_us=300.0,
                          start_timeout_s=240.0)
    reg = MetricsRegistry()
    rounds = []
    try:
        sup = ReplicaSupervisor(tier, heartbeat_s=0.25,
                                heartbeat_timeout_s=3.0,
                                restart_backoff_s=0.05,
                                start_timeout_s=240.0).start()
        try:
            handle = tier.client_handle(0)
            ft = FaultyTransport(QueueTransport(handle), plan,
                                 tier=tier)
            client = ReplicaClient(handle, transport=ft,
                                   local_cache=False, timeout_s=1.0,
                                   deadline_s=3.0, cooldown_s=0.05,
                                   oracle_fallback=True,
                                   jitter_seed=seed)
            register_supervisor(reg, sup)
            register_router(reg, client)
            ref = None

            def one_round():
                d0 = client.degraded_count
                t0 = time.perf_counter()
                try:
                    got = client.predict_all(graphs)
                    err = None
                except Exception as e:
                    got, err = None, repr(e)
                rec = {"wall_s": time.perf_counter() - t0,
                       "ok": err is None,
                       "degraded": client.degraded_count - d0,
                       "error": err}
                # parity is only claimed for rounds the tier fully
                # answered; degraded rounds carry oracle rows by design
                # and are flagged, not compared
                if got is not None and ref is not None \
                        and rec["degraded"] == 0:
                    rec["bit_equal"] = all(
                        np.array_equal(got[t], ref[t]) for t in ref)
                rounds.append(rec)
                return got

            ref = one_round()              # fault-free reference round
            if ref is None:
                raise RuntimeError("reference round failed: "
                                   f"{rounds[0]['error']}")
            n_rounds = 120 if full else 60
            stop_at = time.monotonic() + 240.0
            while time.monotonic() < stop_at:
                one_round()
                # pace the closed loop: cache-hot rounds run ~1ms, and
                # an unpaced op-clock would burn through the whole
                # schedule before the heartbeat detector (wall-clock
                # timescale) ever saw the wedge
                time.sleep(0.02)
                st = sup.stats()
                if len(rounds) >= n_rounds and plan.exhausted \
                        and st["restarts_recovered"] >= 2 \
                        and not st["respawning"]:
                    break
            # closing rounds: the tier must come all the way back —
            # non-degraded and bit-identical — once faults stop
            final_clean = False
            for _ in range(10):
                one_round()
                r = rounds[-1]
                if r["ok"] and r["degraded"] == 0 \
                        and r.get("bit_equal"):
                    final_clean = True
                    break
                time.sleep(0.5)    # residual routing cooldown drains
            st = sup.stats()
            snap = reg.snapshot()["metrics"]
            router = client.stats()
        finally:
            sup.stop()
    finally:
        tier.stop()

    avail = sum(r["ok"] for r in rounds) / len(rounds)
    nd = [r for r in rounds if r.get("bit_equal") is not None]
    diverged = sum(not r["bit_equal"] for r in nd)
    applied = {}
    for e in ft.log:
        if e["applied"]:
            applied[e["kind"]] = applied.get(e["kind"], 0) + 1
    mean_wall = float(np.mean([r["wall_s"] for r in rounds]))
    out = {
        "rounds": len(rounds),
        "availability": avail,
        "non_degraded_rounds": len(nd),
        "degraded_rounds": sum(r["degraded"] > 0 for r in rounds),
        "degraded_preds": client.degraded_count,
        "diverged": diverged,
        "final_clean": final_clean,
        "plan_exhausted": plan.exhausted,
        "faults_applied": applied,
        "kill_applied": applied.get("kill", 0) >= 1,
        "wedge_applied": applied.get("wedge", 0) >= 1,
        "restarts_total": st["restarts_total"],
        "restarts_recovered": st["restarts_recovered"],
        "recovery_s_max": st["recovery_s_max"],
        "crash_loops": st["crash_loops"],
        "inbox_resets": st["inbox_resets"],
        "tick_errors": st["tick_errors"],
        "router": {k: router[k] for k in
                   ("shed_count", "degraded_count", "deadline_expired",
                    "recv_errors", "failures", "unhealthy_now")},
        "obs_counters_present": (
            "supervisor.restarts_total" in snap
            and "router.degraded_count" in snap),
        "mean_round_s": mean_wall,
    }
    _row("chaos_serve/rounds", mean_wall * 1e6,
         f"rounds={out['rounds']};avail={avail:.3f}"
         f";degraded={out['degraded_rounds']};diverged={diverged}")
    _row("chaos_serve/recovery", st["recovery_s_max"] * 1e6,
         f"restarts={st['restarts_total']}"
         f";recovered={st['restarts_recovered']}"
         f";inbox_resets={st['inbox_resets']}"
         f";final_clean={final_clean}")
    return out


BENCHES = {
    "paper_rmse": paper_rmse,
    "operand_ablation": operand_ablation,
    "inference_speed": inference_speed,
    "kernel_bench": kernel_bench,
    "serve_bench": serve_bench,
    "serve_concurrent": serve_concurrent,
    "obs_overhead": obs_overhead,
    "opt_search": opt_search,
    "search_fleet": search_fleet,
    "search_fleet_replicated": search_fleet_replicated,
    "train_bench": train_bench,
    "transformer_extension": transformer_extension,
    "roofline_table": roofline_table,
    "ingest": ingest,
    "chaos_serve": chaos_serve,
}


def _jsonable(x):
    """Benchmark returns -> JSON: tuple keys become strings, numpy
    scalars/arrays become python numbers/lists."""
    if isinstance(x, dict):
        return {"/".join(k) if isinstance(k, tuple) else str(k):
                _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    if isinstance(x, np.ndarray):
        return x.tolist()
    if isinstance(x, (np.integer, np.floating, np.bool_)):
        return x.item()
    return x


# --------------------------------------------------------- perf trajectory
def _git_sha() -> str:
    try:
        import subprocess
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True,
            cwd=os.path.dirname(os.path.abspath(__file__)))
        return out.stdout.strip() or "unknown"
    except Exception:
        return "unknown"


# Per-bench summarizers: the handful of headline scalars a trajectory
# plot wants, not the whole payload. Benches without one fall through
# to the generic ratio/speedup scrape.
_HISTORY_SUMMARY = {
    "serve_concurrent": lambda r: {
        f"speedup_c{c}": lvl["speedup_vs_serialized"]
        for c, lvl in r["levels"].items()},
    "search_fleet": lambda r: {
        "per_worker_steady_speedup": r["speedup_vs_baseline"],
        "fleet_steady_speedup": r["fleet_steady_speedup_vs_baseline"],
        "cold_speedup": r["cold_speedup_vs_baseline"],
        "bf16_spearman_min": r["bf16"]["spearman_min"]},
    "kernel_bench": lambda r: {
        "traffic_reduction": r["traffic_reduction"],
        "conv_f32_max_err": r["models"]["conv1d"]["f32_max_err"],
        "conv_bf16_spearman_min":
            r["models"]["conv1d"]["bf16_spearman_min"],
        "lstm_bf16_spearman_min":
            r["models"]["lstm"]["bf16_spearman_min"],
        "conv_wall_ratio": r["models"]["conv1d"]["wall_ratio"],
        "interpret": r["interpret"]},
    "ingest": lambda r: {
        "unk_rate_max": r["arch"]["unk_rate_max"],
        "arch_errors": r["arch"]["errors"],
        "fuzz_uncaught": r["fuzz"]["uncaught"],
        "ingest_texts_per_s": r["arch"]["texts_per_s"]},
    "search_fleet_replicated": lambda r: {
        "replicated_steady_speedup":
            r["replicated_steady_speedup_vs_baseline"],
        "replicated_cold_speedup":
            r["replicated_cold_speedup_vs_baseline"],
        "replicas": r["replicas"],
        "shed_total": r["modes"]["replicated"]["router"]["shed_total"]},
    "obs_overhead": lambda r: {
        "overhead_ratio": r["overhead_ratio"],
        "trace_completeness": r["trace"]["completeness"],
        "drift_gauges_present": r["drift_gauges_present"]},
    "chaos_serve": lambda r: {
        "availability": r["availability"],
        "diverged": r["diverged"],
        "recovery_s_max": r["recovery_s_max"],
        "restarts_recovered": r["restarts_recovered"],
        "degraded_rounds": r["degraded_rounds"]},
}


def _history_summary(name, result) -> dict:
    fn = _HISTORY_SUMMARY.get(name)
    if fn is not None:
        try:
            return _jsonable(fn(result))
        except Exception:
            pass
    if isinstance(result, dict):       # generic: headline scalars only
        return {k: _jsonable(v) for k, v in result.items()
                if isinstance(v, (int, float, np.integer, np.floating))
                and any(s in k for s in
                        ("speedup", "ratio", "rmse", "spearman"))}
    return {}


def append_history(path: str, args, summaries: dict) -> None:
    """Append one rolled-up entry (sha + per-bench headline numbers) to
    the trajectory file — the cross-PR record BENCH_*.json artifacts
    never gave us, since each run overwrote the last."""
    hist = {"entries": []}
    if os.path.exists(path):
        try:
            with open(path) as f:
                hist = json.load(f)
            hist.setdefault("entries", [])
        except Exception:
            pass                       # corrupt file: restart trajectory
    hist["entries"].append({
        "ts": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "sha": _git_sha(),
        "full": bool(args.full),
        "seed": args.seed,
        "benches": summaries})
    with open(path, "w") as f:
        json.dump(hist, f, indent=2)
        f.write("\n")
    print(f"# appended history entry -> {path} "
          f"({len(hist['entries'])} total)", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=sorted(BENCHES))
    ap.add_argument("--full", action="store_true",
                    help="paper-scale dataset/steps (slow)")
    ap.add_argument("--json-dir", default=None,
                    help="write one BENCH_<name>.json record per bench "
                         "run (CI uploads these as workflow artifacts)")
    ap.add_argument("--replicas", type=int, default=4,
                    help="replica-process count for "
                         "search_fleet_replicated")
    ap.add_argument("--history", default=None,
                    help="append a rolled-up entry (git sha + headline "
                         "numbers per bench) to this BENCH_history.json "
                         "trajectory file after the run")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    summaries = {}
    for name, fn in BENCHES.items():
        if args.only and name != args.only:
            continue
        kw = {"full": args.full, "seed": args.seed}
        if name == "search_fleet_replicated":
            kw["replicas"] = args.replicas
        result = fn(**kw)
        summaries[name] = _history_summary(name, result)
        if args.json_dir:
            os.makedirs(args.json_dir, exist_ok=True)
            path = os.path.join(args.json_dir, f"BENCH_{name}.json")
            with open(path, "w") as f:
                json.dump({"bench": name, "full": args.full,
                           "seed": args.seed,
                           "result": _jsonable(result)}, f, indent=2)
            print(f"# wrote {path}", flush=True)
    if args.history and summaries:
        append_history(args.history, args, summaries)


if __name__ == '__main__':
    main()
