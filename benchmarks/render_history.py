"""Render a markdown trend table from BENCH_history.json.

The history file (``benchmarks/run.py --history``) accumulates one
rolled-up entry per bench run — git sha + the headline scalars of each
bench. This renders the trajectory as one markdown table per bench:
the metric's value over the last N entries, each with its sha and the
delta vs the previous entry, so a PR review answers "did the serving
benches move, and when" without opening any JSON.

    PYTHONPATH=src python -m benchmarks.render_history \
        [--history benchmarks/BENCH_history.json] [--last 10] \
        [--out BENCH_TRENDS.md]
"""
from __future__ import annotations

import argparse
import json
from typing import Any, Dict, List


def load_entries(path: str) -> List[Dict[str, Any]]:
    with open(path) as f:
        hist = json.load(f)
    return list(hist.get("entries", []))


def _fmt(v: Any) -> str:
    if isinstance(v, bool):
        return str(v)
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def _delta(cur: Any, prev: Any) -> str:
    """Signed delta vs the previous entry carrying this metric."""
    if not (isinstance(cur, (int, float)) and
            isinstance(prev, (int, float))) or \
            isinstance(cur, bool) or isinstance(prev, bool):
        return ""
    d = cur - prev
    if d == 0:
        return "="
    return f"{d:+.3g}"


def bench_table(name: str, entries: List[Dict[str, Any]],
                last: int) -> List[str]:
    """One markdown table: rows = history entries (oldest first),
    columns = that bench's headline metrics, each cell value(delta)."""
    rows = [(e.get("ts", "?"), e.get("sha", "?"),
             e["benches"][name]) for e in entries
            if isinstance(e.get("benches"), dict)
            and name in e["benches"]]
    if not rows:
        return []
    rows = rows[-last:]
    metrics: List[str] = []
    for _, _, b in rows:               # stable union of metric keys
        for k in b:
            if k not in metrics:
                metrics.append(k)
    out = [f"### {name}", "",
           "| date | sha | " + " | ".join(metrics) + " |",
           "|---|---|" + "---|" * len(metrics)]
    prev: Dict[str, Any] = {}
    for ts, sha, b in rows:
        cells = []
        for m in metrics:
            if m not in b:
                cells.append("—")
                continue
            d = _delta(b[m], prev.get(m))
            cells.append(f"{_fmt(b[m])}" + (f" ({d})" if d else ""))
            prev[m] = b[m]
        out.append(f"| {ts[:10]} | `{sha}` | " + " | ".join(cells)
                   + " |")
    out.append("")
    return out


def render(entries: List[Dict[str, Any]], last: int) -> str:
    names: List[str] = []
    for e in entries:                  # first-seen bench order
        for n in (e.get("benches") or {}):
            if n not in names:
                names.append(n)
    lines = ["# Bench trends", "",
             f"{len(entries)} history entries; last {last} shown "
             f"per bench. Value (delta vs previous run of that "
             f"bench).", ""]
    for n in names:
        lines += bench_table(n, entries, last)
    return "\n".join(lines) + "\n"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--history", default="benchmarks/BENCH_history.json")
    ap.add_argument("--last", type=int, default=10,
                    help="entries per bench table")
    ap.add_argument("--out", default=None,
                    help="write markdown here (default: stdout)")
    args = ap.parse_args()
    md = render(load_entries(args.history), args.last)
    if args.out:
        with open(args.out, "w") as f:
            f.write(md)
        print(f"wrote {args.out}")
    else:
        print(md, end="")


if __name__ == "__main__":
    main()
