"""Soft perf gates over BENCH_*.json records (dispatched on bench name).

* ``kernel_bench`` — fails if the fused Pallas serving forward drifts
  from the plain-jnp apply (f32 max abs err, bf16 per-target Spearman)
  or its aggregate modeled HBM-traffic reduction over the unfused
  tower drops below ``--kernel-traffic-reduction`` (3x). The fused-vs-
  unfused wall-clock ratio is gated only on non-interpret backends:
  interpret-mode wall time measures the Pallas emulator, not the
  kernel, so CPU CI skips it rather than fails.
* ``serve_concurrent`` — fails (exit 1) if the async CostModelServer's
  req/s at concurrency 64 fell below the serialized per-request baseline
  — i.e. if micro-batching stopped paying for itself. The paper-level
  target is >=3x; CI machines are noisy shared runners, so the gate only
  enforces >= the baseline (ratio 1.0 by default) and prints the
  measured ratio for the artifact trail.
* ``opt_search`` — fails if beam search's mean *oracle* latency
  improvement fell below the greedy one-shot fusion baseline's (within
  ``--opt-tolerance``) — i.e. if the model-guided search stopped beating
  the single-rule advisor it replaced.
* ``search_fleet`` — fails if (a) the steady-state fleet's
  candidates-costed/s with the incremental hashing + encode_many hot
  path fell below ``--fleet-min-ratio`` x the from-scratch baseline
  (the hot-path refactor stopped paying for itself), or (b) bf16
  serving's per-target Spearman vs f32 on the candidate corpus dropped
  below ``--bf16-spearman`` (quantized serving stopped ranking like
  full precision).
* ``search_fleet_replicated`` — fails if the replicated serving tier's
  steady-state candidates/s fell below ``--replicated-min-ratio`` x the
  GIL-convoyed thread-fleet baseline, or if the record ran fewer than 4
  replicas (the tier's win must hold at fleet scale, not just N=2).
* ``chaos_serve`` — fails if the supervised tier under the scripted
  fault plan (kill/wedge/drop/delay/dup/corrupt) dropped below
  ``--chaos-availability`` availability, let any non-degraded reply
  diverge bit-wise from the fault-free reference, failed to land or
  recover both process faults within ``--chaos-recovery-s``, never
  returned to clean parity after the plan drained, or stopped
  publishing supervisor/router counters to the obs registry.
* ``obs_overhead`` — fails if the unified telemetry layer (head-sampled
  tracing + metrics-registry export + drift sentinel) costs more than
  ``--obs-min-ratio`` of untraced gateway throughput, if forced-sampling
  span trees reconstruct below ``--obs-min-completeness`` complete, or
  if registry snapshots stop carrying the drift gauges.

    python benchmarks/gate.py bench-artifacts/BENCH_serve_concurrent.json
    python benchmarks/gate.py bench-artifacts/BENCH_opt_search.json
    python benchmarks/gate.py bench-artifacts/BENCH_search_fleet.json
"""
from __future__ import annotations

import argparse
import json
import sys


def gate_serve_concurrent(rec, args) -> int:
    result = rec["result"]
    lvl = result["levels"][args.concurrency]
    # matched-load serialized baseline (same client count); fall back to
    # the single-thread reference for older records
    base = lvl.get("serialized_req_s",
                   result["serialized_baseline"]["req_s"])
    ratio = lvl["req_s"] / base
    print(f"serve_concurrent c{args.concurrency}: {lvl['req_s']:.0f} req/s "
          f"vs serialized {base:.0f} req/s -> {ratio:.2f}x "
          f"(gate: >= {args.min_ratio:.2f}x)")
    if ratio < args.min_ratio:
        print("PERF GATE FAILED: micro-batched serving is not beating "
              "the serialized baseline", file=sys.stderr)
        return 1
    print("perf gate passed")
    return 0


def gate_opt_search(rec, args) -> int:
    s = rec["result"]["summary"]
    beam = s["oracle_improvement_mean"]
    base = s["baseline_oracle_improvement_mean"]
    print(f"opt_search: beam oracle improvement {beam:.1%} vs one-shot "
          f"fusion baseline {base:.1%} "
          f"(gate: beam >= baseline - {args.opt_tolerance:.1%}; "
          f"strictly better on "
          f"{s['frac_strictly_better_than_baseline']:.0%} of graphs)")
    if beam < base - args.opt_tolerance:
        print("PERF GATE FAILED: beam search is not matching the greedy "
              "single-rule fusion baseline on the oracle", file=sys.stderr)
        return 1
    print("perf gate passed")
    return 0


def gate_search_fleet(rec, args) -> int:
    r = rec["result"]
    ratio = r["speedup_vs_baseline"]
    fleet = r.get("fleet_steady_speedup_vs_baseline", 0.0)
    cold = r.get("cold_speedup_vs_baseline", 0.0)
    sp = r["bf16"]["spearman_min"]
    print(f"search_fleet: per-worker steady fast path {ratio:.2f}x "
          f"baseline candidates/s (fleet steady {fleet:.2f}x, cold "
          f"{cold:.2f}x; gate: >= {args.fleet_min_ratio:.2f}x); "
          f"bf16 spearman_min={sp:.4f} "
          f"(gate: >= {args.bf16_spearman:.2f}; max_rel_err="
          f"{r['bf16']['max_rel_err_all']:.3f})")
    rc = 0
    if ratio < args.fleet_min_ratio:
        print("PERF GATE FAILED: incremental hashing/encoding hot path "
              "is not beating the from-scratch baseline at fleet scale",
              file=sys.stderr)
        rc = 1
    if sp < args.bf16_spearman:
        print("DRIFT GATE FAILED: bf16 serving no longer ranks like f32",
              file=sys.stderr)
        rc = 1
    if rc == 0:
        print("perf gate passed")
    return rc


def gate_search_fleet_replicated(rec, args) -> int:
    r = rec["result"]
    steady = r["replicated_steady_speedup_vs_baseline"]
    cold = r.get("replicated_cold_speedup_vs_baseline", 0.0)
    replicas = r.get("replicas", 0)
    shed = r["modes"]["replicated"]["router"].get("shed_total", 0)
    hits = [p["lru_hit_rate"]
            for p in r["modes"]["replicated"].get("per_replica", [])]
    print(f"search_fleet_replicated: {replicas} replicas, steady "
          f"{steady:.2f}x the thread-fleet baseline (cold {cold:.2f}x; "
          f"gate: >= {args.replicated_min_ratio:.2f}x at >= 4 replicas); "
          f"shed={shed}; replica lru hit rates="
          f"{['%.0f%%' % (h * 100) for h in hits]}")
    rc = 0
    if replicas < 4:
        print("PERF GATE FAILED: replicated bench must run >= 4 "
              "replicas to count", file=sys.stderr)
        rc = 1
    if steady < args.replicated_min_ratio:
        print("PERF GATE FAILED: the replicated tier is not beating the "
              "thread-fleet baseline at steady state", file=sys.stderr)
        rc = 1
    if rc == 0:
        print("perf gate passed")
    return rc


def gate_kernel_bench(rec, args) -> int:
    r = rec["result"]
    conv, lstm = r["models"]["conv1d"], r["models"]["lstm"]
    traffic = r["traffic_reduction"]
    interp = r.get("interpret", True)
    err = max(conv["f32_max_err"], lstm["f32_max_err"])
    sp = min(conv["bf16_spearman_min"], lstm["bf16_spearman_min"])
    wall = conv["wall_ratio"]
    print(f"kernel_bench: f32 max_err={err:.2e} "
          f"(gate: <= {args.kernel_max_err:.0e}); "
          f"bf16 spearman_min={sp:.4f} (gate: >= {args.bf16_spearman:.2f}); "
          f"modeled HBM traffic {traffic:.1f}x reduction "
          f"(gate: >= {args.kernel_traffic_reduction:.1f}x); "
          f"conv wall ratio {wall:.2f}x on backend="
          f"{r.get('backend')!r} interpret={interp}")
    rc = 0
    if err > args.kernel_max_err:
        print("PARITY GATE FAILED: fused Pallas forward no longer "
              "matches the plain-jnp apply in f32", file=sys.stderr)
        rc = 1
    if sp < args.bf16_spearman:
        print("DRIFT GATE FAILED: bf16 kernels no longer rank like the "
              "f32 reference", file=sys.stderr)
        rc = 1
    if traffic < args.kernel_traffic_reduction:
        print("TRAFFIC GATE FAILED: the fused forward's modeled HBM "
              "traffic reduction fell below the floor", file=sys.stderr)
        rc = 1
    if interp:
        print("wall-clock gate skipped: interpret-mode timing measures "
              "the Pallas emulator, not the kernel")
    elif wall < args.kernel_wall_ratio:
        print("PERF GATE FAILED: the fused forward is slower than the "
              "unfused XLA apply on a real backend", file=sys.stderr)
        rc = 1
    if rc == 0:
        print("kernel gate passed")
    return rc


def gate_obs_overhead(rec, args) -> int:
    """Observability-tax gate: the unified telemetry layer (tracing +
    registry export + drift sentinel) must keep >= ``--obs-min-ratio``
    of the untraced gateway throughput, forced-sampling span trees must
    reconstruct >= ``--obs-min-completeness`` complete, and every
    registry snapshot must carry the drift gauges."""
    r = rec["result"]
    ratio = r["overhead_ratio"]
    comp = r["trace"]["completeness"]
    gauges = bool(r.get("drift_gauges_present"))
    print(f"obs_overhead: {r['req_s_on']:.0f} req/s traced vs "
          f"{r['req_s_off']:.0f} untraced -> {ratio:.3f}x "
          f"(gate: >= {args.obs_min_ratio:.2f}x); span-tree "
          f"completeness {comp:.3f} over {r['trace']['n_traces']} "
          f"traces (gate: >= {args.obs_min_completeness:.2f}); "
          f"drift_gauges_present={gauges}; "
          f"drift_scored={r.get('drift_scored', 0)}")
    rc = 0
    if ratio < args.obs_min_ratio:
        print("PERF GATE FAILED: the telemetry layer's overhead on the "
              "gateway hot path exceeds the budget", file=sys.stderr)
        rc = 1
    if comp < args.obs_min_completeness:
        print("TRACE GATE FAILED: sampled requests no longer "
              "reconstruct complete span trees", file=sys.stderr)
        rc = 1
    if not gauges:
        print("DRIFT GATE FAILED: registry snapshots are missing the "
              "drift sentinel gauges", file=sys.stderr)
        rc = 1
    if rc == 0:
        print("obs gate passed")
    return rc


def gate_ingest(rec, args) -> int:
    """Hard robustness gate on the real-MLIR front door: the arch
    corpus must ingest without a single structured error or collapse
    onto bare ``<unk>``, and the fuzz corpus must never escape the
    never-raises contract."""
    r = rec["result"]
    arch, fuzz = r["arch"], r["fuzz"]
    print(f"ingest: {arch['texts']} arch texts "
          f"(errors={arch['errors']}, "
          f"unk_rate_max={arch['unk_rate_max']:.3f}, "
          f"oov_rate_mean={arch['oov_rate_mean']:.3f}); "
          f"fuzz n={fuzz['n']} uncaught={fuzz['uncaught']}")
    rc = 0
    if arch["errors"] != 0:
        print("INGEST GATE FAILED: real-arch lowered texts no longer "
              "ingest cleanly", file=sys.stderr)
        rc = 1
    if arch["unk_rate_max"] != 0.0:
        print("OOV GATE FAILED: some arch-corpus tokens collapsed onto "
              "bare <unk> despite shard/byte fallback", file=sys.stderr)
        rc = 1
    if fuzz["n"] < 200:
        print("FUZZ GATE FAILED: fuzz corpus shrank below 200 inputs",
              file=sys.stderr)
        rc = 1
    if fuzz["uncaught"] != 0:
        print("FUZZ GATE FAILED: predict_text raised instead of "
              "returning a structured IngestError", file=sys.stderr)
        rc = 1
    if rc == 0:
        print("ingest gate passed")
    return rc


def gate_chaos_serve(rec, args) -> int:
    """Robustness gate on the supervised tier under scripted chaos:
    availability across chaos rounds, bounded in-slot recovery, zero
    bit-level divergence of non-degraded replies from the fault-free
    reference, the kill+wedge events actually landing, a clean final
    round after the plan drains, and the supervisor/router counters
    present in the obs registry snapshot."""
    r = rec["result"]
    print(f"chaos_serve: {r['rounds']} rounds, availability "
          f"{r['availability']:.3f} (gate: >= "
          f"{args.chaos_availability:.2f}); diverged={r['diverged']} "
          f"over {r['non_degraded_rounds']} non-degraded rounds "
          f"(gate: == 0); degraded_rounds={r['degraded_rounds']}; "
          f"restarts={r['restarts_total']} "
          f"recovered={r['restarts_recovered']} "
          f"recovery_s_max={r['recovery_s_max']:.1f}s (gate: <= "
          f"{args.chaos_recovery_s:.0f}s); "
          f"faults_applied={r['faults_applied']}; "
          f"final_clean={r['final_clean']}; "
          f"obs_counters_present={r['obs_counters_present']}")
    rc = 0
    if r["availability"] < args.chaos_availability:
        print("CHAOS GATE FAILED: availability under fault injection "
              "fell below the floor", file=sys.stderr)
        rc = 1
    if r["diverged"] != 0:
        print("CHAOS GATE FAILED: a non-degraded reply diverged from "
              "the fault-free reference (wrong answer under chaos)",
              file=sys.stderr)
        rc = 1
    if not (r["kill_applied"] and r["wedge_applied"]
            and r["plan_exhausted"]):
        print("CHAOS GATE FAILED: the fault schedule did not fully "
              "land (kill/wedge missing or plan not drained) — the "
              "run proved nothing", file=sys.stderr)
        rc = 1
    if r["restarts_recovered"] < 2:
        print("CHAOS GATE FAILED: the supervisor did not recover both "
              "the killed and the wedged replica", file=sys.stderr)
        rc = 1
    if r["recovery_s_max"] > args.chaos_recovery_s:
        print("CHAOS GATE FAILED: in-slot respawn exceeded the "
              "recovery-time bound", file=sys.stderr)
        rc = 1
    if not r["final_clean"]:
        print("CHAOS GATE FAILED: the tier never returned to "
              "non-degraded bit-parity after the plan drained",
              file=sys.stderr)
        rc = 1
    if not r["obs_counters_present"]:
        print("CHAOS GATE FAILED: supervisor/router counters are "
              "missing from the obs registry snapshot",
              file=sys.stderr)
        rc = 1
    if rc == 0:
        print("chaos gate passed")
    return rc


GATES = {
    "kernel_bench": gate_kernel_bench,
    "serve_concurrent": gate_serve_concurrent,
    "opt_search": gate_opt_search,
    "search_fleet": gate_search_fleet,
    "search_fleet_replicated": gate_search_fleet_replicated,
    "ingest": gate_ingest,
    "obs_overhead": gate_obs_overhead,
    "chaos_serve": gate_chaos_serve,
}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("record", help="path to a BENCH_<name>.json record")
    ap.add_argument("--concurrency", default="64",
                    help="serve_concurrent: client-count level to gate on")
    ap.add_argument("--min-ratio", type=float, default=1.0,
                    help="serve_concurrent: minimum req/s ratio over the "
                         "serialized baseline (soft gate; local target "
                         "is 3.0)")
    ap.add_argument("--opt-tolerance", type=float, default=0.01,
                    help="opt_search: slack on beam-vs-baseline oracle "
                         "improvement (absolute)")
    ap.add_argument("--fleet-min-ratio", type=float, default=2.0,
                    help="search_fleet: minimum steady-state "
                         "candidates/s ratio of the incremental hot "
                         "path over the from-scratch baseline")
    ap.add_argument("--replicated-min-ratio", type=float, default=3.0,
                    help="search_fleet_replicated: minimum steady-state "
                         "candidates/s ratio of the replicated tier "
                         "over the thread-fleet baseline (local target "
                         "3.0; CI passes 2.0 for shared-runner noise)")
    ap.add_argument("--bf16-spearman", type=float, default=0.99,
                    help="search_fleet/kernel_bench: minimum per-target "
                         "Spearman of bf16 vs f32 predictions on the "
                         "bench corpus")
    ap.add_argument("--kernel-max-err", type=float, default=1e-3,
                    help="kernel_bench: max abs f32 error of the fused "
                         "forward vs the plain-jnp apply (accumulation "
                         "order differs, so nonzero but small)")
    ap.add_argument("--kernel-traffic-reduction", type=float, default=3.0,
                    help="kernel_bench: minimum aggregate modeled "
                         "HBM-traffic reduction of the fused forward "
                         "over the unfused tower (cost_analysis bytes)")
    ap.add_argument("--obs-min-ratio", type=float, default=0.97,
                    help="obs_overhead: minimum traced/untraced req/s "
                         "ratio on the gateway hot path (the telemetry "
                         "tax budget)")
    ap.add_argument("--obs-min-completeness", type=float, default=0.99,
                    help="obs_overhead: minimum fraction of sampled "
                         "requests whose span trees reconstruct "
                         "complete (one root, no orphans)")
    ap.add_argument("--chaos-availability", type=float, default=0.99,
                    help="chaos_serve: minimum fraction of chaos-loop "
                         "rounds answered without an exception (the "
                         "graceful-degradation floor)")
    ap.add_argument("--chaos-recovery-s", type=float, default=120.0,
                    help="chaos_serve: maximum seconds for one in-slot "
                         "respawn to report recovered (spawn + JAX "
                         "import + warmup on a shared runner)")
    ap.add_argument("--kernel-wall-ratio", type=float, default=1.0,
                    help="kernel_bench: minimum unfused/fused wall-clock "
                         "ratio; only enforced on non-interpret backends "
                         "(interpret mode emulates the kernel in python)")
    args = ap.parse_args()
    with open(args.record) as f:
        rec = json.load(f)
    gate = GATES.get(rec.get("bench"))
    if gate is None:
        print(f"no gate defined for bench {rec.get('bench')!r}; skipping")
        return 0
    return gate(rec, args)


if __name__ == "__main__":
    sys.exit(main())
