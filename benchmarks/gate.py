"""Soft perf gate over BENCH_serve_concurrent.json.

Fails (exit 1) if the async CostModelServer's req/s at concurrency 64
fell below the serialized per-request baseline — i.e. if micro-batching
stopped paying for itself. The paper-level target is >=3x; CI machines
are noisy shared runners, so the gate only enforces >= the baseline
(ratio 1.0 by default) and prints the measured ratio for the artifact
trail.

    python benchmarks/gate.py bench-artifacts/BENCH_serve_concurrent.json
"""
from __future__ import annotations

import argparse
import json
import sys


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("record", help="path to BENCH_serve_concurrent.json")
    ap.add_argument("--concurrency", default="64",
                    help="which client-count level to gate on")
    ap.add_argument("--min-ratio", type=float, default=1.0,
                    help="minimum req/s ratio over the serialized "
                         "baseline (soft gate; local target is 3.0)")
    args = ap.parse_args()
    with open(args.record) as f:
        rec = json.load(f)
    result = rec["result"]
    lvl = result["levels"][args.concurrency]
    # matched-load serialized baseline (same client count); fall back to
    # the single-thread reference for older records
    base = lvl.get("serialized_req_s",
                   result["serialized_baseline"]["req_s"])
    ratio = lvl["req_s"] / base
    print(f"serve_concurrent c{args.concurrency}: {lvl['req_s']:.0f} req/s "
          f"vs serialized {base:.0f} req/s -> {ratio:.2f}x "
          f"(gate: >= {args.min_ratio:.2f}x)")
    if ratio < args.min_ratio:
        print("PERF GATE FAILED: micro-batched serving is not beating "
              "the serialized baseline", file=sys.stderr)
        return 1
    print("perf gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
