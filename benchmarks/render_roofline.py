"""Render EXPERIMENTS.md tables from experiments/dryrun/*.json.

    PYTHONPATH=src python -m benchmarks.render_roofline \
        [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def fmt_t(x):
    return f"{x*1e3:.1f}" if x < 10 else f"{x*1e3:.0f}"


def load(dir_):
    recs = []
    for p in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def _refresh_roofline(rec):
    """Recompute derived roofline fields from the stored raw terms using the
    current model-flops accounting (PaLM-style incl. attention)."""
    from repro.configs import get_arch, SHAPES
    from repro.launch import roofline as RL
    r = rec["roofline"]
    rep = RL.RooflineReport(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        chips=rec["chips"], flops_per_chip=r["flops_per_chip"],
        bytes_per_chip=r["bytes_per_chip"],
        coll_bytes_per_chip=r["coll_bytes_per_chip"],
        coll_breakdown=r.get("coll_breakdown", {}),
        peak_memory_per_chip=r.get("peak_memory_per_chip", 0.0),
        model_flops=RL.model_flops_for(get_arch(rec["arch"]),
                                       SHAPES[rec["shape"]]))
    rec["roofline"] = rep.to_dict()
    return rec


def render(recs, mesh="pod16x16"):
    rows = []
    print(f"\n### Mesh {mesh}\n")
    print("| arch | shape | status | t_comp (ms) | t_mem (ms) | t_coll (ms) "
          "| bottleneck | HLO GFLOPs/chip | peak mem/chip | useful-flops | "
          "roofline frac |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for rec in recs:
        if rec.get("mesh") != mesh:
            continue
        a, s = rec["arch"], rec["shape"]
        if rec["status"] == "skipped":
            print(f"| {a} | {s} | skip | — | — | — | — | — | — | — |")
            continue
        if rec["status"] != "ok":
            print(f"| {a} | {s} | FAIL | — | — | — | — | — | — | — |")
            continue
        rec = _refresh_roofline(rec)
        r = rec["roofline"]
        mem = rec.get("memory", {})
        peak = (mem.get("argument_size_in_bytes", 0)
                + mem.get("temp_size_in_bytes", 0))
        print(f"| {a} | {s} | ok | {fmt_t(r['t_compute'])} | "
              f"{fmt_t(r['t_memory'])} | {fmt_t(r['t_collective'])} | "
              f"{r['bottleneck']} | {r['flops_per_chip']/1e9:.0f} | "
              f"{peak/2**30:.1f} GiB | {r['useful_flops_ratio']:.2f} | "
              f"{r['roofline_fraction']:.1%} |")


def note_for(rec) -> str:
    """One sentence: what would move the dominant term down."""
    r = rec["roofline"]
    b, shape, arch = r["bottleneck"], rec["shape"], rec["arch"]
    coll = r.get("coll_breakdown", {})
    top_coll = max(coll, key=coll.get) if any(coll.values()) else ""
    decode = "decode" in shape or "long" in shape
    if b == "collective":
        if "moe" in arch or "jamba" in arch:
            return (f"dominant {top_coll}: MoE dispatch + TP activation "
                    "re-sharding; overlap via latency-hiding scheduler and "
                    "wider expert-parallel groups would hide most of it")
        return (f"dominant {top_coll}: per-layer TP/SP activation "
                "re-sharding; for sub-1B models map batch over the model "
                "axis too (pure DP, see §Perf cell 1)")
    if b == "memory":
        if decode:
            return ("KV-cache/state streaming is irreducible at batch "
                    f"{rec.get('shape')}: raise arithmetic intensity via "
                    "grouped/speculative decode or int8/fp8 cache")
        return ("activation traffic between contraction boundaries; a "
                "fused (Pallas) attention/FFN pipeline or bf16 logits "
                "would cut the largest dot operands")
    return ("compute-bound: increase per-chip work (bigger microbatch) or "
            "accept — this is the roofline target")


def render_notes(recs, mesh="pod16x16"):
    print(f"\n### Per-cell notes ({mesh})\n")
    for rec in recs:
        if rec.get("mesh") != mesh or rec.get("status") != "ok":
            continue
        rec = _refresh_roofline(rec)
        print(f"* **{rec['arch']} × {rec['shape']}** "
              f"({rec['roofline']['bottleneck']}-bound): {note_for(rec)}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--notes", action="store_true")
    args = ap.parse_args()
    recs = load(args.dir)
    meshes = [args.mesh] if args.mesh else ["pod16x16", "pod2x16x16"]
    for m in meshes:
        render(recs, m)
    if args.notes:
        render_notes(recs, "pod16x16")
    n_ok = sum(r["status"] == "ok" for r in recs)
    n_skip = sum(r["status"] == "skipped" for r in recs)
    n_fail = len(recs) - n_ok - n_skip
    print(f"\n{n_ok} ok / {n_skip} skipped / {n_fail} failed "
          f"of {len(recs)} cells")


if __name__ == "__main__":
    main()
