"""Ground-truth analyzers: the "compile + run on the accelerator" oracle.

The paper harvests ground truth by running 20k+ graphs on an Intel AI
accelerator. Without that hardware we use deterministic analyzers over the
same graphs (see DESIGN.md §2): the *learning problem* — predict a hardware
characteristic from IR text alone — is unchanged, and the analyzers model a
TPU-v5e-class chip:

* register_pressure — peak live vector-register units over the program,
  classic liveness on the SSA use-def chains. A live tensor occupies
  ``ceil(resident_tile / (8*128 lanes))`` VREG units (capped: spills go to
  VMEM). This is the TPU analogue of the paper's register/spill target.
* valu_utilization — number of vector-ALU issue slots: elementwise and
  reduction ops issue ``ceil(numel/VLEN)`` vector instructions; contraction
  ops run on the MXU but issue epilogue vALU work.
* latency_us — three-term roofline over ops: max(FLOPs/peak, bytes/HBM_bw)
  accumulated, in microseconds.
"""
from __future__ import annotations

import math
from typing import Dict

from repro.ir.graph import (Graph, Op, Tensor, ELEMENTWISE, FUSED_OP,
                            REDUCTION, CONTRACTION, DATA_MOVEMENT)

VLEN = 8 * 128            # one VREG: 8 sublanes x 128 lanes of f32
TILE_VREGS = 16           # a live value holds a streaming tile window of at
                          # most this many VREGs (rest resides in VMEM/HBM)
PEAK_FLOPS = 197e12       # bf16 TPU v5e-class
HBM_BW = 819e9


def _vreg_units(t: Tensor) -> int:
    return min(math.ceil(t.numel / VLEN), TILE_VREGS)


def op_flops(g: Graph, op: Op) -> float:
    out = g.values[op.result]
    if op.opcode == FUSED_OP:
        # a fused elementwise chain does every constituent's arithmetic
        # but only one HBM round trip (op_bytes sees just its operands
        # and result — the intermediates never materialize)
        return float(out.numel) * int(op.attrs.get("n_fused", 1))
    if op.opcode == "matmul":
        a = g.values[op.operands[0]]
        k = a.shape[-1]
        return 2.0 * out.numel * k
    if op.opcode in ("conv2d", "depthwise_conv2d"):
        a = g.values[op.operands[0]]
        kh = kw = int(op.attrs.get("kernel", 3))
        cin = a.shape[-1] if op.opcode == "conv2d" else 1
        return 2.0 * out.numel * kh * kw * cin
    if op.opcode == "attention":
        return 4.0 * out.numel * out.shape[-1]
    if op.opcode in REDUCTION:
        a = g.values[op.operands[0]]
        return 4.0 * a.numel  # multi-pass (max/sub/exp/sum style)
    if op.opcode in ELEMENTWISE:
        return float(out.numel)
    return 0.0


def op_bytes(g: Graph, op: Op) -> float:
    read = sum(g.values[o].bytes for o in op.operands)
    return float(read + g.values[op.result].bytes)


def _valu_issues(g: Graph, op: Op) -> int:
    out = g.values[op.result]
    if op.opcode == FUSED_OP:
        return int(op.attrs.get("n_fused", 1)) * \
            math.ceil(out.numel / VLEN)
    if op.opcode in ELEMENTWISE:
        return math.ceil(out.numel / VLEN)
    if op.opcode in REDUCTION:
        a = g.values[op.operands[0]]
        return 4 * math.ceil(a.numel / VLEN)
    if op.opcode in CONTRACTION:
        # MXU does the MACs; vALU handles accumulation epilogue
        return math.ceil(out.numel / VLEN)
    if op.opcode in DATA_MOVEMENT:
        return math.ceil(out.numel / (2 * VLEN))
    return 0


def register_pressure(g: Graph) -> int:
    """Peak live VREG units over program points (liveness over use-def)."""
    last_use: Dict[int, int] = {}
    for i, op in enumerate(g.ops):
        for o in op.operands:
            last_use[o] = i
    for o in g.outputs:
        last_use[o] = len(g.ops)
    live = {a for a in range(g.n_args) if a in last_use}
    peak = sum(_vreg_units(g.values[v]) for v in live)
    cur = peak
    for i, op in enumerate(g.ops):
        live.add(op.result)
        cur += _vreg_units(g.values[op.result])
        peak = max(peak, cur)
        for o in set(op.operands) | {op.result}:
            if last_use.get(o, -1) == i:
                live.discard(o)
                cur -= _vreg_units(g.values[o])
    return int(peak)


def valu_utilization(g: Graph) -> int:
    """Total vector-ALU issue slots for the graph (paper's xpu utilization:
    'the number of times the vector ALU unit is utilized')."""
    return int(sum(_valu_issues(g, op) for op in g.ops))


def latency_us(g: Graph) -> float:
    """Roofline latency estimate in microseconds."""
    total = 0.0
    for op in g.ops:
        t_c = op_flops(g, op) / PEAK_FLOPS
        t_m = op_bytes(g, op) / HBM_BW
        total += max(t_c, t_m)
    return total * 1e6


TARGETS = {
    "register_pressure": register_pressure,
    "valu_utilization": valu_utilization,
    "latency_us": latency_us,
}


def analyze(g: Graph) -> Dict[str, float]:
    return {k: float(fn(g)) for k, fn in TARGETS.items()}
