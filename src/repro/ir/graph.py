"""Dataflow-graph IR: SSA ops over tensors, the `xpu` dialect's substrate.

Mirrors the paper's Fig. 2: a function embodies the (sub)graph, operators are
`xpu.*` opcodes, data dependencies are SSA use-def chains, and values are
tensors with shape + element dtype.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

Shape = Tuple[int, ...]

# Incremental structural hashing (see Graph.struct_key): rewrite-derived
# graphs inherit the per-value hashes of ops copied verbatim from their
# parent, so only the rewrite's dirty cone is re-hashed. Disable to force
# every struct_key() call back to the full from-scratch Merkle walk (the
# pre-incremental behavior) — the flag-switchable baseline the
# ``search_fleet`` benchmark measures against.
_INCREMENTAL_HASHING = True


def set_incremental_hashing(enabled: bool) -> bool:
    """Toggle incremental struct_key hashing; returns the previous value."""
    global _INCREMENTAL_HASHING
    prev = _INCREMENTAL_HASHING
    _INCREMENTAL_HASHING = bool(enabled)
    return prev


def incremental_hashing_enabled() -> bool:
    return _INCREMENTAL_HASHING


@dataclass(frozen=True)
class Tensor:
    shape: Shape
    dtype: str = "f32"

    @property
    def numel(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1

    @property
    def bytes(self) -> int:
        # ingested MLIR can carry any element type (i64, f64, i1, ...);
        # unknown widths default to 4 rather than KeyError mid-analysis
        width = {"f32": 4, "bf16": 2, "f16": 2, "i8": 1, "i32": 4,
                 "f64": 8, "i64": 8, "i16": 2, "i1": 1}.get(self.dtype, 4)
        return self.numel * width

    def mlir(self) -> str:
        dims = "x".join(str(d) for d in self.shape)
        return f"tensor<{dims}x{self.dtype}>" if self.shape else \
            f"tensor<{self.dtype}>"

    def shape_token(self) -> str:
        """The paper tokenizes a full shape as a single entity."""
        dims = "x".join(str(d) for d in self.shape)
        return f"{dims}x{self.dtype}" if self.shape else self.dtype


@dataclass
class Op:
    opcode: str                 # e.g. "mult", "matmul", "conv2d", "relu"
    operands: List[int]         # SSA value ids (graph.values indices)
    result: int                 # SSA id of the produced value
    attrs: Dict[str, object] = field(default_factory=dict)


@dataclass
class Graph:
    """SSA graph. values[i] is the Tensor type of SSA id i; ids < n_args are
    function arguments (%arg0..); the rest are op results (%0..)."""
    values: List[Tensor] = field(default_factory=list)
    n_args: int = 0
    ops: List[Op] = field(default_factory=list)
    outputs: List[int] = field(default_factory=list)
    name: str = "graph"
    # --- struct_key memoization (never part of graph identity/equality) ---
    # value id -> structural hash, filled lazily by value_hashes()
    _vhash: Optional[Dict[int, str]] = field(
        default=None, repr=False, compare=False)
    # value id -> hash inherited from a parent graph (adopt_hashes)
    _inherited: Optional[Dict[int, str]] = field(
        default=None, repr=False, compare=False)
    # ((n_ops, n_args, outputs), key): finished-key cache, invalidated
    # when the cheap shape token no longer matches
    _key_cache: Optional[Tuple[Tuple, str]] = field(
        default=None, repr=False, compare=False)
    # ops-mode token-splice hint set by adopt_hashes:
    # (parent struct key, {child op index: parent op index})
    _tok_delta: Optional[Tuple[str, Dict[int, int]]] = field(
        default=None, repr=False, compare=False)

    def add_arg(self, t: Tensor) -> int:
        assert not self.ops, "args must precede ops"
        self.values.append(t)
        self.n_args += 1
        return len(self.values) - 1

    def add_op(self, opcode: str, operands: Sequence[int], out: Tensor,
               **attrs) -> int:
        self.values.append(out)
        vid = len(self.values) - 1
        self.ops.append(Op(opcode, list(operands), vid, attrs))
        return vid

    def ssa_name(self, vid: int) -> str:
        if vid < self.n_args:
            return f"%arg{vid}"
        return f"%{vid - self.n_args}"

    def validate(self) -> None:
        defined = set(range(self.n_args))
        for op in self.ops:
            for o in op.operands:
                assert o in defined, f"use before def: {o} in {op.opcode}"
            assert op.result not in defined
            defined.add(op.result)
        for o in self.outputs:
            assert o in defined

    def toposort_is_program_order(self) -> bool:
        try:
            self.validate()
            return True
        except AssertionError:
            return False

    def _compute_hashes(self, inherited: Dict[int, str]) -> Dict[int, str]:
        """Merkle walk: args by position, op results by opcode + operand
        hashes + attrs + result type. Values present in ``inherited``
        skip payload construction and SHA-1 entirely."""
        memo: Dict[int, str] = {}
        for i in range(self.n_args):
            h = inherited.get(i)
            if h is None:
                t = self.values[i]
                h = hashlib.sha1(
                    f"arg{i}:{t.shape}:{t.dtype}".encode()).hexdigest()
            memo[i] = h
        for op in self.ops:
            h = inherited.get(op.result)
            if h is None:
                t = self.values[op.result]
                attrs = ",".join(f"{k}={op.attrs[k]!r}"
                                 for k in sorted(op.attrs))
                payload = (f"{op.opcode}"
                           f"({','.join(memo[o] for o in op.operands)})"
                           f"[{attrs}]->{t.shape}:{t.dtype}")
                h = hashlib.sha1(payload.encode()).hexdigest()
            memo[op.result] = h
        return memo

    def _combine_key(self, memo: Dict[int, str]) -> str:
        """Op-hash *multiset* + output tuple -> the canonical key."""
        body = ",".join(sorted(memo[op.result] for op in self.ops))
        outs = ",".join(memo[o] for o in self.outputs)
        return hashlib.sha1(
            f"{self.n_args}|{body}|{outs}".encode()).hexdigest()

    def value_hashes(self) -> Dict[int, str]:
        """Per-value structural hashes, memoized on the graph (recomputed
        if values were appended since), honoring inherited hashes."""
        memo = self._vhash
        if memo is None or len(memo) != len(self.values):
            memo = self._compute_hashes(self._inherited or {})
            self._vhash = memo
        return memo

    def adopt_hashes(self, parent: "Graph", copied: Dict[int, int],
                     tok_copied: Optional[Dict[int, int]] = None) -> None:
        """Declare values copied verbatim from ``parent`` (child value id
        -> parent value id): their structural hashes are inherited, so
        the first struct_key() re-hashes only the rewrite's dirty cone.
        Callers (the repro.opt rewrite builder) guarantee that a declared
        copy has the same opcode/attrs/result type AND that every operand
        is itself a declared copy — the property tests hold incremental
        keys equal to from-scratch keys across all rule families.

        Also records the ops-mode token-splice hint consumed by
        CostModelService's parent-delta tokenization path. ``tok_copied``
        is the (usually broader) set of ops whose *token pair* (opcode +
        result shape) is unchanged: ops downstream of a rewrite must
        re-hash (their operand hashes changed) but still tokenize
        identically, so they splice. No reference to ``parent`` is kept
        — hashes resolve eagerly and the token hint is keyed by the
        parent's struct key."""
        if not _INCREMENTAL_HASHING:
            return
        ph = parent.value_hashes()
        self._inherited = {cv: ph[pv] for cv, pv in copied.items()}
        self._vhash = None
        self._key_cache = None
        if self.n_args == parent.n_args:
            # op j's result id is n_args + j for add_op-built graphs
            self._tok_delta = (parent.struct_key(), {
                cv - self.n_args: pv - parent.n_args
                for cv, pv in (tok_copied or copied).items()
                if cv >= self.n_args})

    def struct_key(self) -> str:
        """Canonical structural hash of the dataflow graph.

        Merkle-hashes every value through the use-def chains and combines
        the op-hash *multiset* with the output tuple. The key is
        therefore invariant under SSA id renumbering and under reordering
        of independent ops (any topological re-schedule), but
        distinguishes any change to an opcode, operand wiring, attribute,
        or tensor type. It is the canonical identity used by the
        CostModelService LRU, the server's in-flight dedup, and the
        opt.search frontier dedup.

        The finished key is cached on the graph; appending ops/args or
        reassigning ``outputs`` invalidates it (in-place edits to an
        existing Op after the first call do not — build-then-hash is the
        contract, and every rewrite builds a fresh graph). Rewrite-derived
        graphs inherit per-value hashes for verbatim-copied ops
        (:meth:`adopt_hashes`), so only the dirty cone is re-hashed."""
        if not _INCREMENTAL_HASHING:
            return self.struct_key_fresh()
        token = (len(self.ops), self.n_args, tuple(self.outputs))
        if self._key_cache is not None and self._key_cache[0] == token:
            return self._key_cache[1]
        key = self._combine_key(self.value_hashes())
        self._key_cache = (token, key)
        return key

    def struct_key_fresh(self) -> str:
        """From-scratch reference walk: ignores every memo and inherited
        hash (and caches nothing). The invariant incremental hashing must
        preserve — property tests compare against this — and the whole
        behavior when ``set_incremental_hashing(False)``."""
        return self._combine_key(self._compute_hashes({}))


# Op categories used by the analyzers (vector-ALU vs MXU vs memory ops).
# The opt rewrites additionally emit the synthetic FUSED_OP ("fused", with
# an n_fused attr counting its constituent elementwise ops); it is kept out
# of these sets so category membership stays paper-faithful — the analyzers
# model it explicitly.
FUSED_OP = "fused"
ELEMENTWISE = {"add", "sub", "mult", "div", "relu", "gelu", "silu", "tanh",
               "sigmoid", "exp", "neg", "abs", "maximum", "minimum", "rsqrt"}
REDUCTION = {"softmax", "layernorm", "batchnorm", "reduce_sum", "reduce_max",
             "reduce_mean"}
CONTRACTION = {"matmul", "conv2d", "depthwise_conv2d", "attention"}
DATA_MOVEMENT = {"reshape", "transpose", "concat", "slice", "broadcast",
                 "pool_max", "pool_avg", "upsample", "pad"}
ALL_OPCODES = sorted(ELEMENTWISE | REDUCTION | CONTRACTION | DATA_MOVEMENT)
