"""Dataflow-graph IR: SSA ops over tensors, the `xpu` dialect's substrate.

Mirrors the paper's Fig. 2: a function embodies the (sub)graph, operators are
`xpu.*` opcodes, data dependencies are SSA use-def chains, and values are
tensors with shape + element dtype.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

import numpy as np

Shape = Tuple[int, ...]


@dataclass(frozen=True)
class Tensor:
    shape: Shape
    dtype: str = "f32"

    @property
    def numel(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1

    @property
    def bytes(self) -> int:
        width = {"f32": 4, "bf16": 2, "f16": 2, "i8": 1, "i32": 4}[self.dtype]
        return self.numel * width

    def mlir(self) -> str:
        dims = "x".join(str(d) for d in self.shape)
        return f"tensor<{dims}x{self.dtype}>" if self.shape else \
            f"tensor<{self.dtype}>"

    def shape_token(self) -> str:
        """The paper tokenizes a full shape as a single entity."""
        dims = "x".join(str(d) for d in self.shape)
        return f"{dims}x{self.dtype}" if self.shape else self.dtype


@dataclass
class Op:
    opcode: str                 # e.g. "mult", "matmul", "conv2d", "relu"
    operands: List[int]         # SSA value ids (graph.values indices)
    result: int                 # SSA id of the produced value
    attrs: Dict[str, object] = field(default_factory=dict)


@dataclass
class Graph:
    """SSA graph. values[i] is the Tensor type of SSA id i; ids < n_args are
    function arguments (%arg0..); the rest are op results (%0..)."""
    values: List[Tensor] = field(default_factory=list)
    n_args: int = 0
    ops: List[Op] = field(default_factory=list)
    outputs: List[int] = field(default_factory=list)
    name: str = "graph"

    def add_arg(self, t: Tensor) -> int:
        assert not self.ops, "args must precede ops"
        self.values.append(t)
        self.n_args += 1
        return len(self.values) - 1

    def add_op(self, opcode: str, operands: Sequence[int], out: Tensor,
               **attrs) -> int:
        self.values.append(out)
        vid = len(self.values) - 1
        self.ops.append(Op(opcode, list(operands), vid, attrs))
        return vid

    def ssa_name(self, vid: int) -> str:
        if vid < self.n_args:
            return f"%arg{vid}"
        return f"%{vid - self.n_args}"

    def validate(self) -> None:
        defined = set(range(self.n_args))
        for op in self.ops:
            for o in op.operands:
                assert o in defined, f"use before def: {o} in {op.opcode}"
            assert op.result not in defined
            defined.add(op.result)
        for o in self.outputs:
            assert o in defined

    def toposort_is_program_order(self) -> bool:
        try:
            self.validate()
            return True
        except AssertionError:
            return False


# Op categories used by the analyzers (vector-ALU vs MXU vs memory ops).
ELEMENTWISE = {"add", "sub", "mult", "div", "relu", "gelu", "silu", "tanh",
               "sigmoid", "exp", "neg", "abs", "maximum", "minimum", "rsqrt"}
REDUCTION = {"softmax", "layernorm", "batchnorm", "reduce_sum", "reduce_max",
             "reduce_mean"}
CONTRACTION = {"matmul", "conv2d", "depthwise_conv2d", "attention"}
DATA_MOVEMENT = {"reshape", "transpose", "concat", "slice", "broadcast",
                 "pool_max", "pool_avg", "upsample", "pad"}
ALL_OPCODES = sorted(ELEMENTWISE | REDUCTION | CONTRACTION | DATA_MOVEMENT)
