"""Dataflow-graph IR: SSA ops over tensors, the `xpu` dialect's substrate.

Mirrors the paper's Fig. 2: a function embodies the (sub)graph, operators are
`xpu.*` opcodes, data dependencies are SSA use-def chains, and values are
tensors with shape + element dtype.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

import numpy as np

Shape = Tuple[int, ...]


@dataclass(frozen=True)
class Tensor:
    shape: Shape
    dtype: str = "f32"

    @property
    def numel(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1

    @property
    def bytes(self) -> int:
        width = {"f32": 4, "bf16": 2, "f16": 2, "i8": 1, "i32": 4}[self.dtype]
        return self.numel * width

    def mlir(self) -> str:
        dims = "x".join(str(d) for d in self.shape)
        return f"tensor<{dims}x{self.dtype}>" if self.shape else \
            f"tensor<{self.dtype}>"

    def shape_token(self) -> str:
        """The paper tokenizes a full shape as a single entity."""
        dims = "x".join(str(d) for d in self.shape)
        return f"{dims}x{self.dtype}" if self.shape else self.dtype


@dataclass
class Op:
    opcode: str                 # e.g. "mult", "matmul", "conv2d", "relu"
    operands: List[int]         # SSA value ids (graph.values indices)
    result: int                 # SSA id of the produced value
    attrs: Dict[str, object] = field(default_factory=dict)


@dataclass
class Graph:
    """SSA graph. values[i] is the Tensor type of SSA id i; ids < n_args are
    function arguments (%arg0..); the rest are op results (%0..)."""
    values: List[Tensor] = field(default_factory=list)
    n_args: int = 0
    ops: List[Op] = field(default_factory=list)
    outputs: List[int] = field(default_factory=list)
    name: str = "graph"

    def add_arg(self, t: Tensor) -> int:
        assert not self.ops, "args must precede ops"
        self.values.append(t)
        self.n_args += 1
        return len(self.values) - 1

    def add_op(self, opcode: str, operands: Sequence[int], out: Tensor,
               **attrs) -> int:
        self.values.append(out)
        vid = len(self.values) - 1
        self.ops.append(Op(opcode, list(operands), vid, attrs))
        return vid

    def ssa_name(self, vid: int) -> str:
        if vid < self.n_args:
            return f"%arg{vid}"
        return f"%{vid - self.n_args}"

    def validate(self) -> None:
        defined = set(range(self.n_args))
        for op in self.ops:
            for o in op.operands:
                assert o in defined, f"use before def: {o} in {op.opcode}"
            assert op.result not in defined
            defined.add(op.result)
        for o in self.outputs:
            assert o in defined

    def toposort_is_program_order(self) -> bool:
        try:
            self.validate()
            return True
        except AssertionError:
            return False

    def struct_key(self) -> str:
        """Canonical structural hash of the dataflow graph.

        Merkle-hashes every value through the use-def chains (args by
        position, op results by opcode + operand hashes + attrs + result
        type) and combines the op-hash *multiset* with the output tuple.
        The key is therefore invariant under SSA id renumbering and under
        reordering of independent ops (any topological re-schedule), but
        distinguishes any change to an opcode, operand wiring, attribute,
        or tensor type. It is the canonical identity used by both the
        CostModelService LRU and the opt.search frontier dedup."""
        memo: Dict[int, str] = {}
        for i in range(self.n_args):
            t = self.values[i]
            memo[i] = hashlib.sha1(
                f"arg{i}:{t.shape}:{t.dtype}".encode()).hexdigest()
        for op in self.ops:
            t = self.values[op.result]
            attrs = ",".join(f"{k}={op.attrs[k]!r}"
                             for k in sorted(op.attrs))
            payload = (f"{op.opcode}"
                       f"({','.join(memo[o] for o in op.operands)})"
                       f"[{attrs}]->{t.shape}:{t.dtype}")
            memo[op.result] = hashlib.sha1(payload.encode()).hexdigest()
        body = ",".join(sorted(memo[op.result] for op in self.ops))
        outs = ",".join(memo[o] for o in self.outputs)
        return hashlib.sha1(
            f"{self.n_args}|{body}|{outs}".encode()).hexdigest()


# Op categories used by the analyzers (vector-ALU vs MXU vs memory ops).
# The opt rewrites additionally emit the synthetic FUSED_OP ("fused", with
# an n_fused attr counting its constituent elementwise ops); it is kept out
# of these sets so category membership stays paper-faithful — the analyzers
# model it explicitly.
FUSED_OP = "fused"
ELEMENTWISE = {"add", "sub", "mult", "div", "relu", "gelu", "silu", "tanh",
               "sigmoid", "exp", "neg", "abs", "maximum", "minimum", "rsqrt"}
REDUCTION = {"softmax", "layernorm", "batchnorm", "reduce_sum", "reduce_max",
             "reduce_mean"}
CONTRACTION = {"matmul", "conv2d", "depthwise_conv2d", "attention"}
DATA_MOVEMENT = {"reshape", "transpose", "concat", "slice", "broadcast",
                 "pool_max", "pool_avg", "upsample", "pad"}
ALL_OPCODES = sorted(ELEMENTWISE | REDUCTION | CONTRACTION | DATA_MOVEMENT)
