"""Family-specific dataflow-graph samplers (Resnet/BERT/Unet/SSD/Yolo-like).

The paper's 20k-sample corpus is extracted from these five model families;
we sample random subgraphs with the same op mix and a *frequent-shape pool*
(the paper keeps OOV shape tokens rare by reusing frequent tensor sizes).
"""
from __future__ import annotations

import numpy as np

from repro.ir.graph import Graph, Tensor

# Frequent-shape pools (paper: "many of the tensor sizes appear frequently
# across multiple models").
BATCHES = [1, 8, 16, 32]
SPATIAL = [7, 14, 28, 56, 112, 224]
CHANNELS = [3, 16, 32, 64, 128, 256, 512, 1024]
HIDDEN = [128, 256, 512, 768, 1024, 2048, 4096]
SEQ = [64, 128, 256, 512]


def _conv_block(g, rng, x, t, channels):
    c_out = int(rng.choice(channels))
    n, h, w, _ = t.shape
    stride = int(rng.choice([1, 1, 1, 2]))
    h2, w2 = max(h // stride, 1), max(w // stride, 1)
    out_t = Tensor((n, h2, w2, c_out), t.dtype)
    x = g.add_op("conv2d", [x], out_t, stride=stride, kernel=3)
    if rng.random() < 0.7:
        x = g.add_op("batchnorm", [x], out_t)
    act = rng.choice(["relu", "silu", "gelu"])
    x = g.add_op(str(act), [x], out_t)
    return x, out_t


def sample_resnet(rng: np.random.Generator) -> Graph:
    g = Graph(name="resnet_sub")
    n = int(rng.choice(BATCHES))
    s = int(rng.choice(SPATIAL))
    c = int(rng.choice(CHANNELS))
    t = Tensor((n, s, s, c))
    x = g.add_arg(t)
    for _ in range(rng.integers(1, 5)):
        skip, skip_t = x, t
        x, t = _conv_block(g, rng, x, t, CHANNELS)
        x2, t2 = _conv_block(g, rng, x, t, [t.shape[-1]])
        if t2.shape == skip_t.shape:
            x = g.add_op("add", [x2, skip], t2)
            t = t2
        else:
            x, t = x2, t2
    if rng.random() < 0.3:
        n_, h_, w_, c_ = t.shape
        t = Tensor((n_, max(h_ // 2, 1), max(w_ // 2, 1), c_))
        x = g.add_op("pool_max", [x], t)
    g.outputs = [x]
    return g


def sample_bert(rng: np.random.Generator) -> Graph:
    g = Graph(name="bert_sub")
    b = int(rng.choice(BATCHES))
    s = int(rng.choice(SEQ))
    d = int(rng.choice(HIDDEN))
    ff = int(rng.choice([2 * d, 4 * d]))
    t = Tensor((b, s, d))
    x = g.add_arg(t)
    wq = g.add_arg(Tensor((d, d)))
    wo = g.add_arg(Tensor((d, d)))
    wf1 = g.add_arg(Tensor((d, ff)))
    wf2 = g.add_arg(Tensor((ff, d)))
    for _ in range(rng.integers(1, 4)):
        q = g.add_op("matmul", [x, wq], t)
        k = g.add_op("matmul", [x, wq], t)
        v = g.add_op("matmul", [x, wq], t)
        at = Tensor((b, s, s))
        a = g.add_op("matmul", [q, k], at, transpose_b=True)
        a = g.add_op("softmax", [a], at)
        o = g.add_op("matmul", [a, v], t)
        o = g.add_op("matmul", [o, wo], t)
        x = g.add_op("add", [x, o], t)
        x = g.add_op("layernorm", [x], t)
        h_t = Tensor((b, s, ff))
        h = g.add_op("matmul", [x, wf1], h_t)
        h = g.add_op("gelu", [h], h_t)
        h2 = g.add_op("matmul", [h, wf2], t)
        x = g.add_op("add", [x, h2], t)
        x = g.add_op("layernorm", [x], t)
    g.outputs = [x]
    return g


def sample_unet(rng: np.random.Generator) -> Graph:
    g = Graph(name="unet_sub")
    n = int(rng.choice([1, 2, 4]))
    s = int(rng.choice([56, 112, 224]))
    c = int(rng.choice([16, 32, 64]))
    t = Tensor((n, s, s, c))
    x = g.add_arg(t)
    skips = []
    depth = int(rng.integers(1, 4))
    for _ in range(depth):  # down path
        x, t = _conv_block(g, rng, x, t, [t.shape[-1] * 2])
        skips.append((x, t))
        n_, h_, w_, c_ = t.shape
        t = Tensor((n_, max(h_ // 2, 1), max(w_ // 2, 1), c_))
        x = g.add_op("pool_max", [x], t)
    for sx, st in reversed(skips):  # up path
        n_, h_, w_, c_ = t.shape
        t_up = Tensor((n_, h_ * 2, w_ * 2, c_))
        x = g.add_op("upsample", [x], t_up)
        if t_up.shape[:3] == st.shape[:3]:
            t = Tensor(t_up.shape[:3] + (t_up.shape[3] + st.shape[3],))
            x = g.add_op("concat", [x, sx], t)
        else:
            t = t_up
        x, t = _conv_block(g, rng, x, t, [st.shape[-1]])
    g.outputs = [x]
    return g


def _detector(rng, name, heads):
    g = Graph(name=name)
    n = int(rng.choice([1, 8]))
    s = int(rng.choice([28, 56, 112]))
    c = int(rng.choice([64, 128, 256]))
    t = Tensor((n, s, s, c))
    x = g.add_arg(t)
    for _ in range(rng.integers(2, 6)):  # backbone
        x, t = _conv_block(g, rng, x, t, CHANNELS)
    outs = []
    for _ in range(heads):  # detection heads
        n_, h_, w_, c_ = t.shape
        box_t = Tensor((n_, h_, w_, int(rng.choice([4, 8, 12]))))
        cls_t = Tensor((n_, h_, w_, int(rng.choice([20, 80, 91]))))
        b = g.add_op("conv2d", [x], box_t, stride=1, kernel=3)
        cl = g.add_op("conv2d", [x], cls_t, stride=1, kernel=3)
        cl = g.add_op("sigmoid", [cl], cls_t)
        outs += [b, cl]
    g.outputs = outs
    return g


def sample_ssd(rng):
    return _detector(rng, "ssd_sub", heads=int(rng.integers(1, 4)))


def sample_yolo(rng):
    return _detector(rng, "yolo_sub", heads=int(rng.integers(1, 3)))


SAMPLERS = {
    "resnet": sample_resnet,
    "bert": sample_bert,
    "unet": sample_unet,
    "ssd": sample_ssd,
    "yolo": sample_yolo,
}


def sample_graph(rng: np.random.Generator, family: str = None) -> Graph:
    fam = family or rng.choice(sorted(SAMPLERS))
    g = SAMPLERS[str(fam)](rng)
    g.validate()
    return g
