"""xpu-dialect MLIR printer — paper Fig. 2 textual form.

Example output::

    func.func @graph(%arg0: tensor<8x224x224x3xf32>)
        -> tensor<8x112x112x64xf32> {
      %0 = "xpu.conv2d"(%arg0) : (tensor<8x224x224x3xf32>)
          -> tensor<8x112x112x64xf32>
      %1 = "xpu.relu"(%0) : (tensor<8x112x112x64xf32>)
          -> tensor<8x112x112x64xf32>
      return %1 : tensor<8x112x112x64xf32>
    }
"""
from __future__ import annotations

from repro.ir.graph import Graph


def to_mlir(g: Graph, dialect: str = "xpu") -> str:
    args = ", ".join(
        f"{g.ssa_name(i)}: {g.values[i].mlir()}" for i in range(g.n_args))
    rets = ", ".join(g.values[o].mlir() for o in g.outputs)
    lines = [f"func.func @{g.name}({args}) -> ({rets}) {{"]
    for op in g.ops:
        operands = ", ".join(g.ssa_name(o) for o in op.operands)
        in_types = ", ".join(g.values[o].mlir() for o in op.operands)
        out_type = g.values[op.result].mlir()
        attrs = ""
        if op.attrs:
            kv = ", ".join(f"{k} = {v}" for k, v in sorted(op.attrs.items()))
            attrs = f" {{{kv}}}"
        lines.append(
            f"  {g.ssa_name(op.result)} = \"{dialect}.{op.opcode}\""
            f"({operands}){attrs} : ({in_types}) -> {out_type}")
    ret_vals = ", ".join(g.ssa_name(o) for o in g.outputs)
    lines.append(f"  return {ret_vals} : {rets}")
    lines.append("}")
    return "\n".join(lines)
