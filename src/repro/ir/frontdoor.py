"""Real-MLIR front door: tolerant ingestion of lowered MLIR text.

This is the layer that lets the served cost model eat programs it did
not generate: ``jax.jit(fn).lower().as_text()`` StableHLO, affine/scf
loop nests, arith, or the repo's own ``xpu`` printer output. Design
contract (the whole point of the module):

* **never raises on input.** Every entry point returns either a parsed
  :class:`IngestResult` or a structured :class:`IngestError` naming the
  stage that failed — malformed, truncated, or adversarial text is an
  expected input, not an exception path (the fuzz corpus in tests holds
  this property under hypothesis as well).
* **best-effort structural parse.** A line-oriented parser maps SSA ops
  onto the internal :class:`~repro.ir.graph.Graph` (opcode-mapped into
  the ``xpu`` dialect where known, name-preserved otherwise). When no
  structure is recoverable but the text still lexes, ingestion degrades
  to the raw :func:`~repro.core.tokenizer.tokenize_text` token stream —
  predictions still flow, keyed by a content hash of the tokens.
* **cache-compatible keys.** A parsed graph is keyed by its canonical
  ``struct_key()`` (so an ingested program and the same program built
  through the Graph API share LRU entries across the service, server,
  and replicated tier); the degraded path uses ``"text:" + sha1`` of
  the token stream, namespaced so it can never collide with a struct
  key (struct keys are 40 hex chars).

The serving integration (``predict_text`` on CostModelService /
CostModelServer / ReplicaClient) lives with each serving layer; this
module owns parsing, the error/result types, and the seeded fuzz-corpus
generator used by tests, the ``ingest`` benchmark, and
``launch/ingest.py``.
"""
from __future__ import annotations

import hashlib
import re
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import tokenizer as TOK
from repro.ir.graph import Graph, Tensor


# ------------------------------------------------------------ result types
@dataclass
class IngestError:
    """Structured ingestion failure; returned, never raised.

    ``stage`` says how far the text got: ``empty`` (no input), ``lex``
    (nothing tokenizable), ``parse`` (reserved for callers that require
    a structural graph), ``encode`` / ``predict`` (set by the serving
    layers when vocabulary or forward-pass handling fails)."""

    stage: str
    reason: str
    detail: str = ""

    def __repr__(self) -> str:  # compact: shows up in bench/CLI output
        d = f" ({self.detail})" if self.detail else ""
        return f"IngestError[{self.stage}] {self.reason}{d}"


@dataclass
class IngestResult:
    """A successfully ingested text: either a structural graph (with
    its canonical struct key) or the degraded token-stream form."""

    key: str                     # struct_key or "text:"+sha1(tokens)
    tokens: List[str]            # raw lexed tokens (fallback stream)
    graph: Optional[Graph]       # None -> token-stream-only ingestion
    dialects: Tuple[str, ...]    # dialect prefixes seen (sorted)
    n_ops: int                   # structural ops recovered (0 if none)


@dataclass
class TextEntry:
    """A featurized text: the ids-first batch entry plus ingest stats.

    Produced by ``CostModelService.ingest_text`` — ``(key, ids)`` slots
    straight into ``predict_entries`` / ``submit_entry`` / the replica
    wire format, so every cache layer treats ingested text exactly like
    a Graph submit."""

    key: str
    ids: "np.ndarray"
    n_tokens: int
    oov_rate: float              # fraction of tokens outside the vocab
    unk_rate: float              # fraction of ids collapsed to <unk>
    dialects: Tuple[str, ...] = ()
    n_ops: int = 0               # 0 -> token-stream fallback path


@dataclass
class TextPrediction:
    """predict_text() payload: denormalized predictions + ingest stats."""

    predictions: Dict[str, float]
    key: str
    n_tokens: int
    oov_rate: float              # fraction of tokens outside the vocab
    unk_rate: float              # fraction of ids collapsed to <unk>
    dialects: Tuple[str, ...] = ()
    n_ops: int = 0               # 0 -> token-stream fallback path


def prediction_from(entry: TextEntry,
                    predictions: Dict[str, float]) -> TextPrediction:
    """Attach denormalized head predictions to a featurized entry —
    shared by the service, the async server, and the replica client so
    all three tiers return identical payload shapes."""
    return TextPrediction(predictions=predictions, key=entry.key,
                          n_tokens=entry.n_tokens,
                          oov_rate=entry.oov_rate,
                          unk_rate=entry.unk_rate,
                          dialects=entry.dialects, n_ops=entry.n_ops)


# ------------------------------------------------------------- the parser
# `%out = "dialect.op"(...)` (generic) or `%out = dialect.op ...`
# (pretty). Multi-result ops (`%0:2 = ...`) keep one result value.
_OP_RE = re.compile(
    r'^\s*%([A-Za-z0-9_]+)(?::\d+)?\s*=\s*'
    r'(?:"([A-Za-z_][\w$.]*)"|([A-Za-z_]\w*\.[\w.]+))\s*(.*)$')
_TYPE_RE = re.compile(r"(?:tensor|memref|vector)<([^>]*)>")
_OPERAND_RE = re.compile(r"%([A-Za-z0-9_]+)")
_RETURN_RE = re.compile(r"^\s*(?:func\.)?return\b(.*)$")
_SCALAR_DTYPES = ("bf16", "f64", "f32", "f16",
                  "i64", "i32", "i16", "i8", "i1")

# Known op-name -> xpu opcode translations (StableHLO / arith / math /
# the repo's own printer). Unknown names keep their bare op name, which
# the OOV-extended tokenizer resolves to shard/byte ids instead of a
# single <unk>.
OPCODE_MAP = {
    "dot_general": "matmul", "dot": "matmul", "einsum": "matmul",
    "convolution": "conv2d", "conv": "conv2d",
    "add": "add", "addf": "add", "addi": "add",
    "subtract": "sub", "subf": "sub", "subi": "sub",
    "multiply": "mult", "mulf": "mult", "muli": "mult",
    "divide": "div", "divf": "div", "divi": "div",
    "maximum": "maximum", "maxf": "maximum", "maximumf": "maximum",
    "minimum": "minimum", "minf": "minimum",
    "exponential": "exp", "exp": "exp", "negate": "neg", "abs": "abs",
    "tanh": "tanh", "logistic": "sigmoid", "rsqrt": "rsqrt",
    "sqrt": "rsqrt", "power": "exp",
    "reduce": "reduce_sum", "reduce_sum": "reduce_sum",
    "reduce_max": "reduce_max", "reduce_window": "pool_max",
    "broadcast_in_dim": "broadcast", "broadcast": "broadcast",
    "reshape": "reshape", "transpose": "transpose",
    "concatenate": "concat", "slice": "slice",
    "dynamic_slice": "slice", "pad": "pad", "select": "maximum",
    "load": "slice", "store": "pad",      # affine/memref data movement
}


def _parse_type(txt: str) -> Tensor:
    """Best-effort Tensor from one MLIR type spelling. Dynamic dims
    (``?``) become 1; unknown element types ride through as-is (the
    Graph layer is dtype-string tolerant)."""
    m = _TYPE_RE.search(txt)
    if m:
        parts = [p for p in m.group(1).split("x") if p]
        dims: List[int] = []
        dtype = "f32"
        for p in parts:
            if p.isdigit():
                dims.append(int(p))
            elif p == "?":
                dims.append(1)
            else:
                dtype = p.split(" ")[0].strip()
        return Tensor(tuple(dims), dtype)
    for d in _SCALAR_DTYPES:
        if re.search(rf"\b{d}\b", txt):
            return Tensor((), d)
    return Tensor((), "f32")


def _xpu_opcode(raw: str) -> str:
    """Map ``dialect.op`` onto an xpu opcode; unknown names keep the
    sanitized op name (OOV-safe downstream)."""
    name = raw.rsplit(".", 1)[-1]
    return OPCODE_MAP.get(name, name)


def _signature_args(text: str) -> List[Tuple[str, Tensor]]:
    """(%name, type) pairs from func.func signatures (possibly spanning
    lines). Tolerant: a missing/garbled signature just yields []."""
    args: List[Tuple[str, Tensor]] = []
    for m in re.finditer(r"func\.func[^{]*", text):
        sig = m.group(0)
        for am in re.finditer(
                r"%([A-Za-z0-9_]+):\s*((?:tensor|memref|vector)<[^>]*>"
                r"|[a-z]\w*)", sig):
            args.append((am.group(1), _parse_type(am.group(2))))
    return args


def parse_mlir(text: str) -> Optional[Graph]:
    """Best-effort structural parse of MLIR text into a Graph.

    Returns None when no SSA ops are recoverable (callers fall back to
    the token stream). Never raises: unparsable lines are skipped,
    unknown operand references are dropped from the op's operand list,
    and region ops (reduce bodies etc.) flatten into the op sequence.
    """
    try:
        g = Graph(name="ingested")
        env: Dict[str, int] = {}
        for name, t in _signature_args(text):
            if name not in env:
                env[name] = g.add_arg(t)
        returns: List[str] = []
        for line in text.splitlines():
            rm = _RETURN_RE.match(line)
            if rm:
                returns.extend(_OPERAND_RE.findall(rm.group(1)))
                continue
            m = _OP_RE.match(line)
            if m is None:
                continue
            out_name = m.group(1)
            raw_op = m.group(2) or m.group(3)
            rest = m.group(4)
            # operands: %refs before the trailing type annotation
            head = rest.split(" : ")[0]
            operands = [env[r] for r in _OPERAND_RE.findall(head)
                        if r in env]
            # result type: prefer the type after ->, else the last
            # type in the line, else scalar f32
            arrow = rest.rsplit("->", 1)
            t = _parse_type(arrow[1] if len(arrow) == 2 else rest)
            if out_name in env:          # redefinition (regions): skip
                continue
            env[out_name] = g.add_op(_xpu_opcode(raw_op), operands, t)
        if not g.ops:
            return None
        outs = [env[r] for r in returns if r in env]
        g.outputs = outs or [g.ops[-1].result]
        g.validate()
        return g
    except Exception:
        return None


def _dialects(text: str) -> Tuple[str, ...]:
    seen = set(re.findall(
        r"\b(stablehlo|mhlo|affine|scf|arith|math|func|memref|linalg"
        r"|xpu|chlo|vhlo)\.", text))
    return tuple(sorted(seen))


def text_key(tokens: Sequence[str]) -> str:
    """Cache key for token-stream-only ingestion: content hash of the
    lexed stream (whitespace/formatting mutations collapse onto one
    entry), namespaced so it can't collide with 40-hex struct keys."""
    h = hashlib.sha1("\x00".join(tokens).encode("utf-8")).hexdigest()
    return f"text:{h}"


def ingest(text) -> "IngestResult | IngestError":
    """Parse arbitrary MLIR-ish text. Never raises.

    Structural parse first; token-stream fallback second; only inputs
    with no lexable content at all come back as an IngestError."""
    try:
        if not isinstance(text, str):
            if isinstance(text, (bytes, bytearray)):
                text = bytes(text).decode("utf-8", "replace")
            else:
                return IngestError("empty", "input is not text",
                                   type(text).__name__)
        if not text.strip():
            return IngestError("empty", "no input text")
        tokens = TOK.tokenize_text(text)
        # tokenize_text always adds BOS/EOS; anything else is content
        if len(tokens) <= 2:
            return IngestError("lex", "no tokenizable content",
                               f"{len(text)} chars")
        g = parse_mlir(text)
        if g is not None:
            return IngestResult(key=g.struct_key(), tokens=tokens,
                                graph=g, dialects=_dialects(text),
                                n_ops=len(g.ops))
        return IngestResult(key=text_key(tokens), tokens=tokens,
                            graph=None, dialects=_dialects(text),
                            n_ops=0)
    except Exception as e:      # absolute backstop: still structured
        return IngestError("lex", type(e).__name__, str(e)[:200])


# --------------------------------------------------------- example corpus
# A hand-written affine/scf loop nest: parser coverage for the paper's
# "lower-level dialects produce much larger sequences" scenario and a
# seed for dialect-mixing fuzz (nothing in the jnp pool lowers to
# affine, so this keeps that dialect exercised honestly).
AFFINE_EXAMPLE = """\
module {
  func.func @saxpy(%arg0: memref<256xf32>, %arg1: memref<256xf32>,
                   %arg2: f32) {
    affine.for %i = 0 to 256 {
      %0 = affine.load %arg0[%i] : memref<256xf32>
      %1 = affine.load %arg1[%i] : memref<256xf32>
      %2 = arith.mulf %0, %arg2 : f32
      %3 = arith.addf %2, %1 : f32
      affine.store %3, %arg1[%i] : memref<256xf32>
    }
    return
  }
  func.func @tile(%arg0: memref<64x64xf32>, %arg1: memref<64x64xf32>) {
    %c0 = arith.constant 0 : index
    scf.for %i = %c0 to %c0 step %c0 {
      %0 = affine.load %arg0[%i, %i] : memref<64x64xf32>
      %1 = arith.mulf %0, %0 : f32
      %2 = math.tanh %1 : f32
      affine.store %2, %arg1[%i, %i] : memref<64x64xf32>
    }
    return
  }
}
"""


# ------------------------------------------------------------ fuzz corpus
def mutate_text(text: str, rng: np.random.Generator) -> str:
    """One random mutation: truncation, byte substitution, line
    shuffling, char deletion, garbage injection, or dialect splicing."""
    kind = int(rng.integers(0, 7))
    if not text:
        return text
    if kind == 0:                               # hard truncation
        return text[: int(rng.integers(0, len(text)))]
    if kind == 1:                               # byte substitutions
        b = bytearray(text.encode("utf-8"))
        for _ in range(int(rng.integers(1, 8))):
            b[int(rng.integers(0, len(b)))] = int(rng.integers(0, 256))
        return b.decode("utf-8", "replace")
    if kind == 2:                               # shuffle lines
        lines = text.splitlines()
        rng.shuffle(lines)
        return "\n".join(lines)
    if kind == 3:                               # delete a char span
        i = int(rng.integers(0, len(text)))
        j = min(len(text), i + int(rng.integers(1, 64)))
        return text[:i] + text[j:]
    if kind == 4:                               # garbage injection
        junk = "".join(chr(int(c)) for c in rng.integers(1, 0x2FF, 16))
        i = int(rng.integers(0, len(text)))
        return text[:i] + junk + text[i:]
    if kind == 5:                               # dialect mixing
        lines = text.splitlines()
        extra = AFFINE_EXAMPLE.splitlines()
        i = int(rng.integers(0, len(lines) + 1))
        return "\n".join(lines[:i] + extra + lines[i:])
    return text + text[: int(rng.integers(0, len(text)))]  # duplication


def fuzz_corpus(seed_texts: Sequence[str], n: int,
                rng: np.random.Generator) -> List[str]:
    """``n`` mutated inputs from ``seed_texts``: every mutation kind
    above, stacked 1-3 deep, plus the degenerate empties. Deterministic
    given the rng state — tests and the bench share seeds."""
    out: List[str] = ["", " \n\t ", "\x00\xff\xfe", "%"]
    seeds = [s for s in seed_texts if s] or [AFFINE_EXAMPLE]
    while len(out) < n:
        t = seeds[int(rng.integers(0, len(seeds)))]
        for _ in range(int(rng.integers(1, 4))):
            t = mutate_text(t, rng)
        out.append(t)
    return out[:n]
