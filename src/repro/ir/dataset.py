"""Dataset builder: sampled graphs -> (tokens, targets) arrays + vocab.

Mirrors the paper's corpus: >20k MLIR functions from the five families plus
augmentation; ~10% held out for test. Rows carry the full MLIR text, the
input/output shapes (via shape tokens), and every target variable.
"""
from __future__ import annotations

import dataclasses
import os
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import augment as AUG
from repro.core import tokenizer as TOK
from repro.ir import analyzers, printer, samplers
from repro.ir.graph import Graph


@dataclass
class CostDataset:
    ids: np.ndarray            # (N, max_seq) int32 token ids
    targets: Dict[str, np.ndarray]
    vocab: TOK.Vocab
    mode: str
    max_seq: int
    texts: Optional[List[str]] = None   # raw MLIR (kept for service demos)

    def split(self, test_frac: float = 0.1, seed: int = 0):
        rng = np.random.default_rng(seed)
        n = len(self.ids)
        perm = rng.permutation(n)
        n_test = int(n * test_frac)
        te, tr = perm[:n_test], perm[n_test:]

        def take(idx):
            return CostDataset(
                ids=self.ids[idx],
                targets={k: v[idx] for k, v in self.targets.items()},
                vocab=self.vocab, mode=self.mode, max_seq=self.max_seq)
        return take(tr), take(te)

    def save(self, path: str):
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        np.savez_compressed(
            path, ids=self.ids, mode=self.mode, max_seq=self.max_seq,
            **{f"target_{k}": v for k, v in self.targets.items()},
            vocab=np.array(list(self.vocab.token_to_id.items()), object))

    @classmethod
    def load(cls, path: str) -> "CostDataset":
        z = np.load(path, allow_pickle=True)
        vocab = TOK.Vocab({k: int(v) for k, v in z["vocab"]})
        targets = {k[len("target_"):]: z[k] for k in z.files
                   if k.startswith("target_")}
        return cls(ids=z["ids"], targets=targets, vocab=vocab,
                   mode=str(z["mode"]), max_seq=int(z["max_seq"]))


def build_dataset(n_graphs: int = 2000, *, mode: str = "ops",
                  max_seq: int = 256, vocab_size: int = 8192,
                  augment_factor: int = 1, seed: int = 0,
                  keep_texts: bool = False,
                  families: Optional[List[str]] = None) -> CostDataset:
    """Sample graphs, augment, tokenize, fit vocab, encode, analyze."""
    rng = np.random.default_rng(seed)
    fams = families or sorted(samplers.SAMPLERS)
    graphs: List[Graph] = []
    for i in range(n_graphs):
        g = samplers.sample_graph(rng, fams[i % len(fams)])
        graphs.append(g)
        for _ in range(augment_factor - 1):
            graphs.append(AUG.augment(g, rng))
    token_seqs = [TOK.graph_tokens(g, mode) for g in graphs]
    vocab = TOK.fit_vocab(token_seqs, max_size=vocab_size)
    ids = np.stack([vocab.encode(t, max_seq) for t in token_seqs])
    targets: Dict[str, List[float]] = {k: [] for k in analyzers.TARGETS}
    for g in graphs:
        res = analyzers.analyze(g)
        for k, v in res.items():
            targets[k].append(v)
    texts = [printer.to_mlir(g) for g in graphs] if keep_texts else None
    return CostDataset(
        ids=ids,
        targets={k: np.asarray(v, np.float32) for k, v in targets.items()},
        vocab=vocab, mode=mode, max_seq=max_seq, texts=texts)


def build_text_dataset(rows, *, max_seq: int = 1024,
                       vocab_size: int = 16384,
                       target: str = "latency_us") -> CostDataset:
    """Dataset from raw MLIR text (e.g. the StableHLO corpus from
    ir/stablehlo.py): rows = [(mlir_text, {target: value, ...}), ...].

    This is the paper's lower-dialect pathway — 'affine or scf ... much
    larger sequences of the order of thousands of tokens'."""
    from repro.core import tokenizer as TOK
    token_seqs = [TOK.tokenize_text(text) for text, _ in rows]
    vocab = TOK.fit_vocab(token_seqs, max_size=vocab_size)
    ids = np.stack([vocab.encode(t, max_seq) for t in token_seqs])
    keys = rows[0][1].keys()
    targets = {k: np.asarray([t[k] for _, t in rows], np.float32)
               for k in keys}
    return CostDataset(ids=ids, targets=targets, vocab=vocab,
                       mode="text", max_seq=max_seq,
                       texts=[text for text, _ in rows])


def normalize_targets(y: np.ndarray) -> Tuple[np.ndarray, Dict[str, float]]:
    """log1p + z-score; returns (normalized, stats for denorm)."""
    ly = np.log1p(y)
    mu, sigma = float(ly.mean()), float(ly.std() + 1e-8)
    return (ly - mu) / sigma, {"mu": mu, "sigma": sigma}


def denormalize(pred: np.ndarray, stats: Dict[str, float]) -> np.ndarray:
    return np.expm1(pred * stats["sigma"] + stats["mu"])


def normalize_targets_multi(
        targets: Dict[str, np.ndarray], heads: Tuple[str, ...]
) -> Tuple[Dict[str, np.ndarray], Dict[str, Dict[str, float]]]:
    """Per-target normalize_targets; stats keyed by target name."""
    ys, stats = {}, {}
    for t in heads:
        ys[t], stats[t] = normalize_targets(targets[t])
    return ys, stats


def stacked_normalized_targets(
        targets: Dict[str, np.ndarray], heads: Tuple[str, ...]
) -> Tuple[np.ndarray, Dict[str, Dict[str, float]]]:
    """Multi-target labels as one (N, len(heads)) float32 array.

    Column i is heads[i] — the contract the joint loss's ``y[:, i]``
    indexing consumes (the single place this ordering is encoded)."""
    ys, stats = normalize_targets_multi(targets, heads)
    y = np.stack([ys[t] for t in heads], axis=1).astype(np.float32)
    return y, stats
