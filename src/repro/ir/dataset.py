"""Dataset builder: sampled graphs -> (tokens, targets) arrays + vocab.

Mirrors the paper's corpus: >20k MLIR functions from the five families plus
augmentation; ~10% held out for test. Rows carry the full MLIR text, the
input/output shapes (via shape tokens), and every target variable.

The build is streaming and two-pass ("count-then-encode"): pass 1 walks a
deterministic graph generator accumulating token counts (vocab fit), targets
and sequence lengths; pass 2 re-walks the same generator and encodes ids
directly into preallocated arrays. No pass holds more than one graph's
tokens, so corpus size is bounded by the *output* arrays, not the working
set — the corpus is no longer RAM-bound.

Two id layouts exist:

* ``layout="dense"`` (default) — one ``(N, max_seq)`` array, every row
  padded to the global ``max_seq``. The legacy layout; all in-memory
  callers keep working unchanged.
* ``layout="bucketed"`` — ids grouped by power-of-two sequence bucket
  (:func:`default_buckets`, the same ladder serving uses): bucket ``b``
  holds an ``(n_b, b)`` array plus the global row indices it covers.
  Mixed-length corpora store ~the sum of bucket lengths instead of
  ``N * max_seq``, and the train Loader batches bucket-homogeneously so
  each step jits one program per bucket instead of padding to ``max_seq``.
"""
from __future__ import annotations

import os
from collections import Counter
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.core import augment as AUG
from repro.core import tokenizer as TOK
from repro.ir import analyzers, printer, samplers
from repro.ir.graph import Graph


def default_buckets(max_seq: int, min_bucket: int = 32) -> Tuple[int, ...]:
    """Power-of-two sequence-length buckets up to (and including) max_seq.

    Canonical definition — ``repro.core.service`` re-exports it (serving
    and training share one bucket ladder)."""
    out = []
    b = min_bucket
    while b < max_seq:
        out.append(b)
        b *= 2
    out.append(max_seq)
    return tuple(out)


def bucket_lengths(seq_lens: np.ndarray, buckets: Tuple[int, ...],
                   pad_slack: int = 0) -> np.ndarray:
    """Per-row bucket length: the smallest bucket >= seq_len + pad_slack
    (rows longer than every bucket land in the largest)."""
    ladder = np.asarray(sorted(buckets))
    idx = np.searchsorted(ladder, np.asarray(seq_lens) + pad_slack)
    return ladder[np.minimum(idx, len(ladder) - 1)]


@dataclass
class CostDataset:
    # dense layout: (N, max_seq) int32 token ids; None when bucketed
    ids: Optional[np.ndarray]
    targets: Dict[str, np.ndarray]
    vocab: TOK.Vocab
    mode: str
    max_seq: int
    texts: Optional[List[str]] = None   # raw MLIR (kept for service demos)
    seq_lens: Optional[np.ndarray] = None  # true (pre-pad) token count/row
    # bucketed layout: bucket length -> (n_b, bucket) ids / global row idx
    bucket_ids: Optional[Dict[int, np.ndarray]] = None
    bucket_rows: Optional[Dict[int, np.ndarray]] = None

    @property
    def n(self) -> int:
        return len(next(iter(self.targets.values())))

    def __len__(self) -> int:
        return self.n

    # ------------------------------------------------------------ id access
    def get_seq_lens(self) -> np.ndarray:
        """True token count per row (derived from PAD=0 when not stored)."""
        if self.seq_lens is None:
            self.seq_lens = (self.dense_ids() != 0).sum(axis=1) \
                .astype(np.int32)
        return self.seq_lens

    def _row_map(self) -> Tuple[np.ndarray, np.ndarray]:
        """Per-global-row (bucket_len, local_index) for the bucketed
        layout; built once and cached (row_ids is the per-batch hot path)."""
        cached = getattr(self, "_row_map_cache", None)
        if cached is None:
            rb = np.zeros(self.n, np.int64)
            rl = np.zeros(self.n, np.int64)
            for b, rows in self.bucket_rows.items():
                rb[rows] = b
                rl[rows] = np.arange(len(rows))
            cached = self._row_map_cache = (rb, rl)
        return cached

    def row_ids(self, idx: np.ndarray, width: int) -> np.ndarray:
        """Gather rows ``idx`` as an (len(idx), width) id array, slicing or
        zero-padding (PAD id is 0) to ``width`` as needed."""
        from repro.data.pipeline import fit_width
        idx = np.asarray(idx)
        if self.ids is not None:
            return fit_width(self.ids[idx], width)
        out = np.zeros((len(idx), width), np.int32)
        rb, rl = self._row_map()
        for b, arr in self.bucket_ids.items():
            sel = np.flatnonzero(rb[idx] == b)
            if not len(sel):
                continue
            w = min(b, width)
            out[sel, :w] = arr[rl[idx[sel]], :w]
        return out

    def dense_ids(self) -> np.ndarray:
        """The (N, max_seq) dense view (materialized for bucketed layouts)."""
        if self.ids is not None:
            return self.ids
        return self.row_ids(np.arange(self.n), self.max_seq)

    # ---------------------------------------------------------------- split
    def split(self, test_frac: float = 0.1, seed: int = 0):
        rng = np.random.default_rng(seed)
        perm = rng.permutation(self.n)
        n_test = int(self.n * test_frac)
        te, tr = perm[:n_test], perm[n_test:]
        return self.take(tr), self.take(te)

    def take(self, idx: np.ndarray) -> "CostDataset":
        """Row subset (in ``idx`` order), preserving the id layout."""
        idx = np.asarray(idx)
        sub = dict(
            targets={k: v[idx] for k, v in self.targets.items()},
            vocab=self.vocab, mode=self.mode, max_seq=self.max_seq,
            seq_lens=None if self.seq_lens is None else self.seq_lens[idx])
        if self.ids is not None:
            return CostDataset(ids=self.ids[idx], **sub)
        new_index = np.full(self.n, -1, np.int64)
        new_index[idx] = np.arange(len(idx))
        b_ids, b_rows = {}, {}
        for b, rows in self.bucket_rows.items():
            keep = new_index[rows] >= 0
            if not keep.any():
                continue
            b_ids[b] = self.bucket_ids[b][keep]
            b_rows[b] = new_index[rows][keep]
        return CostDataset(ids=None, bucket_ids=b_ids, bucket_rows=b_rows,
                           **sub)

    # ------------------------------------------------------------------ io
    def save(self, path: str):
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        payload = {f"target_{k}": v for k, v in self.targets.items()}
        if self.seq_lens is not None:
            payload["seq_lens"] = self.seq_lens
        if self.ids is not None:
            payload["ids"] = self.ids
        else:
            for b in self.bucket_ids:
                payload[f"bucket_ids_{b}"] = self.bucket_ids[b]
                payload[f"bucket_rows_{b}"] = self.bucket_rows[b]
        np.savez_compressed(
            path, mode=self.mode, max_seq=self.max_seq,
            vocab=np.array(list(self.vocab.token_to_id.items()), object),
            **payload)

    @classmethod
    def load(cls, path: str) -> "CostDataset":
        z = np.load(path, allow_pickle=True)
        vocab = TOK.Vocab({k: int(v) for k, v in z["vocab"]})
        targets = {k[len("target_"):]: z[k] for k in z.files
                   if k.startswith("target_")}
        common = dict(targets=targets, vocab=vocab, mode=str(z["mode"]),
                      max_seq=int(z["max_seq"]),
                      seq_lens=z["seq_lens"] if "seq_lens" in z.files
                      else None)
        if "ids" in z.files:
            return cls(ids=z["ids"], **common)
        b_ids = {int(k[len("bucket_ids_"):]): z[k] for k in z.files
                 if k.startswith("bucket_ids_")}
        b_rows = {int(k[len("bucket_rows_"):]): z[k] for k in z.files
                  if k.startswith("bucket_rows_")}
        return cls(ids=None, bucket_ids=b_ids, bucket_rows=b_rows, **common)


def sample_graph_stream(n_graphs: int, *, augment_factor: int = 1,
                        seed: int = 0,
                        families: Optional[List[str]] = None,
                        rewrite_factor: int = 0
                        ) -> Iterator[Graph]:
    """Deterministic generator over sampled (+augmented) graphs.

    Two walks with the same arguments yield identical graphs — the
    count-then-encode build's contract.

    ``rewrite_factor`` additionally yields, per base graph, that many
    variants produced by short random ``repro.opt`` rewrite sequences
    (fusion, CSE, DCE, recompute, bf16 narrowing, unrolling) with
    targets recomputed by the analyzers. This is how ``xpu.fused`` ops
    and ``...xbf16`` shape tokens get into training corpora — and hence
    the vocab — so a deployed model can rank the optimizer's candidate
    rewrites instead of seeing them as OOV text."""
    rng = np.random.default_rng(seed)
    fams = families or sorted(samplers.SAMPLERS)
    if rewrite_factor:
        from repro.opt import rewrites as RW   # opt sits above ir
        rules = RW.default_rules()
    for i in range(n_graphs):
        g = samplers.sample_graph(rng, fams[i % len(fams)])
        yield g
        for _ in range(augment_factor - 1):
            yield AUG.augment(g, rng)
        for _ in range(rewrite_factor):
            yield RW.random_rewrite(g, rng, rules)


def build_dataset(n_graphs: int = 2000, *, mode: str = "ops",
                  max_seq: int = 256, vocab_size: int = 8192,
                  augment_factor: int = 1, seed: int = 0,
                  keep_texts: bool = False,
                  families: Optional[List[str]] = None,
                  layout: str = "dense",
                  rewrite_factor: int = 0) -> CostDataset:
    """Stream graphs, fit vocab from counts, encode, analyze.

    Pass 1 accumulates token counts, targets, lengths (and texts);
    pass 2 regenerates the same graphs and encodes ids straight into the
    output arrays — graphs and token sequences are never all in memory.
    """
    if layout not in ("dense", "bucketed"):
        raise ValueError(f"unknown layout {layout!r}")
    stream = dict(augment_factor=augment_factor, seed=seed,
                  families=families, rewrite_factor=rewrite_factor)
    counts: Counter = Counter()
    targets: Dict[str, List[float]] = {k: [] for k in analyzers.TARGETS}
    seq_lens: List[int] = []
    texts: Optional[List[str]] = [] if keep_texts else None
    for g in sample_graph_stream(n_graphs, **stream):
        toks = TOK.graph_tokens(g, mode)
        counts.update(toks)
        seq_lens.append(min(len(toks), max_seq))
        for k, v in analyzers.analyze(g).items():
            targets[k].append(v)
        if keep_texts:
            texts.append(printer.to_mlir(g))
    vocab = TOK.vocab_from_counts(counts, max_size=vocab_size)
    lens = np.asarray(seq_lens, np.int32)
    common = dict(
        targets={k: np.asarray(v, np.float32) for k, v in targets.items()},
        vocab=vocab, mode=mode, max_seq=max_seq, texts=texts, seq_lens=lens)

    if layout == "dense":
        # encode in bounded chunks through the vectorized encode_many
        # (one frozen-table lookup per chunk instead of a dict.get per
        # token) — the working set stays CHUNK sequences, not the corpus
        CHUNK = 512
        ids = np.zeros((len(lens), max_seq), np.int32)   # PAD id is 0
        buf: List[List[str]] = []
        row0 = 0
        for row, g in enumerate(sample_graph_stream(n_graphs, **stream)):
            buf.append(TOK.graph_tokens(g, mode))
            if len(buf) == CHUNK:
                ids[row0:row0 + len(buf)] = vocab.encode_many(buf, max_seq)
                row0 += len(buf)
                buf = []
        if buf:
            ids[row0:row0 + len(buf)] = vocab.encode_many(buf, max_seq)
        return CostDataset(ids=ids, **common)

    row_buckets = bucket_lengths(lens, default_buckets(max_seq))
    b_ids = {int(b): np.zeros((int(c), int(b)), np.int32)
             for b, c in zip(*np.unique(row_buckets, return_counts=True))}
    b_rows = {b: np.flatnonzero(row_buckets == b) for b in b_ids}
    cursor = {b: 0 for b in b_ids}
    for row, g in enumerate(sample_graph_stream(n_graphs, **stream)):
        b = int(row_buckets[row])
        b_ids[b][cursor[b]] = vocab.encode(TOK.graph_tokens(g, mode), b)
        cursor[b] += 1
    return CostDataset(ids=None, bucket_ids=b_ids, bucket_rows=b_rows,
                       **common)


def build_text_dataset(rows, *, max_seq: int = 1024,
                       vocab_size: int = 16384,
                       target: str = "latency_us") -> CostDataset:
    """Dataset from raw MLIR text (e.g. the StableHLO corpus from
    ir/stablehlo.py): rows = [(mlir_text, {target: value, ...}), ...].

    This is the paper's lower-dialect pathway — 'affine or scf ... much
    larger sequences of the order of thousands of tokens'."""
    from repro.core import tokenizer as TOK
    token_seqs = [TOK.tokenize_text(text) for text, _ in rows]
    vocab = TOK.fit_vocab(token_seqs, max_size=vocab_size)
    ids = vocab.encode_many(token_seqs, max_seq)
    keys = rows[0][1].keys()
    targets = {k: np.asarray([t[k] for _, t in rows], np.float32)
               for k in keys}
    return CostDataset(ids=ids, targets=targets, vocab=vocab,
                       mode="text", max_seq=max_seq,
                       texts=[text for text, _ in rows],
                       seq_lens=np.asarray(
                           [min(len(t), max_seq) for t in token_seqs],
                           np.int32))


def normalize_targets(y: np.ndarray) -> Tuple[np.ndarray, Dict[str, float]]:
    """log1p + z-score; returns (normalized, stats for denorm)."""
    ly = np.log1p(y)
    mu, sigma = float(ly.mean()), float(ly.std() + 1e-8)
    return (ly - mu) / sigma, {"mu": mu, "sigma": sigma}


def denormalize(pred: np.ndarray, stats: Dict[str, float]) -> np.ndarray:
    return np.expm1(pred * stats["sigma"] + stats["mu"])


def normalize_targets_multi(
        targets: Dict[str, np.ndarray], heads: Tuple[str, ...]
) -> Tuple[Dict[str, np.ndarray], Dict[str, Dict[str, float]]]:
    """Per-target normalize_targets; stats keyed by target name."""
    ys, stats = {}, {}
    for t in heads:
        ys[t], stats[t] = normalize_targets(targets[t])
    return ys, stats


def stacked_normalized_targets(
        targets: Dict[str, np.ndarray], heads: Tuple[str, ...]
) -> Tuple[np.ndarray, Dict[str, Dict[str, float]]]:
    """Multi-target labels as one (N, len(heads)) float32 array.

    Column i is heads[i] — the contract the joint loss's ``y[:, i]``
    indexing consumes (the single place this ordering is encoded)."""
    ys, stats = normalize_targets_multi(targets, heads)
    y = np.stack([ys[t] for t in heads], axis=1).astype(np.float32)
    return y, stats
