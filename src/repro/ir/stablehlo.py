"""Real-MLIR pathway: StableHLO text from ``jax.jit(...).lower().as_text()``.

JAX natively emits MLIR (StableHLO dialect), so the paper's "lower-level
dialects (affine/scf) produce much larger sequences" scenario is exercised
on *genuine* compiler IR, not simulated text. Ground truth for these samples
comes from XLA itself: ``compiled.cost_analysis()`` FLOPs/bytes and the
roofline latency derived from them — i.e. we predict what the compiler
would report, without compiling.

Graph sources: per-layer subgraphs of the assigned LM architectures
(reduced widths) and jnp translations of the sampled dataflow graphs.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.ir.analyzers import HBM_BW, PEAK_FLOPS


def lower_fn(fn: Callable, *args) -> Tuple[str, Dict[str, float]]:
    """Lower fn to StableHLO text and harvest XLA cost analysis targets."""
    lowered = jax.jit(fn).lower(*args)
    text = lowered.as_text()
    compiled = lowered.compile()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # newer jax: one dict per device
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    bytes_ = float(ca.get("bytes accessed", 0.0))
    targets = {
        "flops": flops,
        "bytes": bytes_,
        "latency_us": max(flops / PEAK_FLOPS, bytes_ / HBM_BW) * 1e6,
    }
    return text, targets


# A pool of jnp subgraphs mirroring the xpu-dialect op mix.
def _mlp(b, s, d, f):
    def fn(x, w1, w2):
        return jax.nn.gelu(x @ w1) @ w2
    args = (jnp.ones((b, s, d), jnp.float32),
            jnp.ones((d, f), jnp.float32), jnp.ones((f, d), jnp.float32))
    return fn, args


def _attn(b, s, d, h):
    hd = d // h

    def fn(x, wq, wk, wv):
        q = (x @ wq).reshape(b, s, h, hd)
        k = (x @ wk).reshape(b, s, h, hd)
        v = (x @ wv).reshape(b, s, h, hd)
        a = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
        w = jax.nn.softmax(a, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", w, v).reshape(b, s, d)
    w = jnp.ones((d, d), jnp.float32)
    return fn, (jnp.ones((b, s, d), jnp.float32), w, w, w)


def _conv(b, s, cin, cout):
    def fn(x, w):
        return jax.nn.relu(jax.lax.conv_general_dilated(
            x, w, (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC")))
    return fn, (jnp.ones((b, s, s, cin), jnp.float32),
                jnp.ones((3, 3, cin, cout), jnp.float32))


def _norm_residual(b, s, d):
    def fn(x, g):
        mu = x.mean(-1, keepdims=True)
        var = ((x - mu) ** 2).mean(-1, keepdims=True)
        return x + (x - mu) * jax.lax.rsqrt(var + 1e-5) * g
    return fn, (jnp.ones((b, s, d), jnp.float32), jnp.ones((d,), jnp.float32))


def sample_stablehlo_corpus(rng: np.random.Generator, n: int = 64
                            ) -> List[Tuple[str, Dict[str, float]]]:
    """Generate (stablehlo_text, targets) rows by lowering real jnp graphs."""
    rows = []
    makers = [
        lambda: _mlp(int(rng.choice([1, 4, 8])), int(rng.choice([64, 128])),
                     int(rng.choice([128, 256, 512])),
                     int(rng.choice([256, 512, 1024]))),
        lambda: _attn(int(rng.choice([1, 4])), int(rng.choice([64, 128])),
                      int(rng.choice([128, 256])), int(rng.choice([4, 8]))),
        lambda: _conv(int(rng.choice([1, 4])), int(rng.choice([14, 28])),
                      int(rng.choice([16, 32])), int(rng.choice([32, 64]))),
        lambda: _norm_residual(int(rng.choice([1, 8])),
                               int(rng.choice([64, 256])),
                               int(rng.choice([256, 1024]))),
    ]
    for i in range(n):
        fn, args = makers[i % len(makers)]()
        rows.append(lower_fn(fn, *args))
    return rows
