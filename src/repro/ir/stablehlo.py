"""Real-MLIR pathway: StableHLO text from ``jax.jit(...).lower().as_text()``.

JAX natively emits MLIR (StableHLO dialect), so the served model sees
*genuine* compiler IR, not simulated text. Ground truth for these
samples comes from XLA itself: ``compiled.cost_analysis()`` FLOPs/bytes
and the roofline latency derived from them — i.e. we predict what the
compiler would report, without compiling.

Graph sources:

* :func:`sample_stablehlo_corpus` — a fixed pool of jnp subgraphs
  (mlp / attention / conv / norm-residual) mirroring the xpu op mix.
* :func:`arch_subgraphs` / :func:`lower_arch_corpus` — per-layer
  subgraphs (attention, SwiGLU MLP, norms, router, lm head) of the real
  architectures registered in ``repro.configs.ARCHS`` at reduced
  widths, lowered from ``jax.ShapeDtypeStruct`` specs (no tensor data
  materialized). These are the "ingest a program we did not generate"
  acceptance inputs for the front door.

The affine/scf "lower-level dialects produce much larger sequences"
scenario is NOT produced here — nothing in this module lowers to
affine. That corpus lives in
:data:`repro.ir.frontdoor.AFFINE_EXAMPLE`, which the tolerant ingestion
parser (:mod:`repro.ir.frontdoor`) and its fuzz corpus exercise.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.ir.analyzers import HBM_BW, PEAK_FLOPS


def lower_fn(fn: Callable, *args) -> Tuple[str, Dict[str, float]]:
    """Lower fn to StableHLO text and harvest XLA cost analysis targets.

    Robust to degraded backends: CPU-only builds may return a cost
    analysis without ``flops`` / ``bytes accessed`` keys (or none at
    all), and compilation itself can fail where lowering succeeded —
    in every such case the text still comes back, with zeroed targets
    instead of an exception."""
    lowered = jax.jit(fn).lower(*args)
    text = lowered.as_text()
    ca: Dict[str, float] = {}
    try:
        compiled = lowered.compile()
        got = compiled.cost_analysis() or {}
        if isinstance(got, (list, tuple)):  # newer jax: dict per device
            got = got[0] if got else {}
        if isinstance(got, dict):
            ca = got
    except Exception:
        pass                     # lowering-only targets: zeros below
    try:
        flops = float(ca.get("flops", 0.0) or 0.0)
    except (TypeError, ValueError):
        flops = 0.0
    try:
        bytes_ = float(ca.get("bytes accessed", 0.0) or 0.0)
    except (TypeError, ValueError):
        bytes_ = 0.0
    targets = {
        "flops": flops,
        "bytes": bytes_,
        "latency_us": max(flops / PEAK_FLOPS, bytes_ / HBM_BW) * 1e6,
    }
    return text, targets


# A pool of jnp subgraphs mirroring the xpu-dialect op mix.
def _mlp(b, s, d, f):
    def fn(x, w1, w2):
        return jax.nn.gelu(x @ w1) @ w2
    args = (jnp.ones((b, s, d), jnp.float32),
            jnp.ones((d, f), jnp.float32), jnp.ones((f, d), jnp.float32))
    return fn, args


def _attn(b, s, d, h):
    hd = d // h

    def fn(x, wq, wk, wv):
        q = (x @ wq).reshape(b, s, h, hd)
        k = (x @ wk).reshape(b, s, h, hd)
        v = (x @ wv).reshape(b, s, h, hd)
        a = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
        w = jax.nn.softmax(a, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", w, v).reshape(b, s, d)
    w = jnp.ones((d, d), jnp.float32)
    return fn, (jnp.ones((b, s, d), jnp.float32), w, w, w)


def _conv(b, s, cin, cout):
    def fn(x, w):
        return jax.nn.relu(jax.lax.conv_general_dilated(
            x, w, (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC")))
    return fn, (jnp.ones((b, s, s, cin), jnp.float32),
                jnp.ones((3, 3, cin, cout), jnp.float32))


def _norm_residual(b, s, d):
    def fn(x, g):
        mu = x.mean(-1, keepdims=True)
        var = ((x - mu) ** 2).mean(-1, keepdims=True)
        return x + (x - mu) * jax.lax.rsqrt(var + 1e-5) * g
    return fn, (jnp.ones((b, s, d), jnp.float32), jnp.ones((d,), jnp.float32))


def sample_stablehlo_corpus(rng: np.random.Generator, n: int = 64
                            ) -> List[Tuple[str, Dict[str, float]]]:
    """Generate (stablehlo_text, targets) rows by lowering real jnp graphs."""
    rows = []
    makers = [
        lambda: _mlp(int(rng.choice([1, 4, 8])), int(rng.choice([64, 128])),
                     int(rng.choice([128, 256, 512])),
                     int(rng.choice([256, 512, 1024]))),
        lambda: _attn(int(rng.choice([1, 4])), int(rng.choice([64, 128])),
                      int(rng.choice([128, 256])), int(rng.choice([4, 8]))),
        lambda: _conv(int(rng.choice([1, 4])), int(rng.choice([14, 28])),
                      int(rng.choice([16, 32])), int(rng.choice([32, 64]))),
        lambda: _norm_residual(int(rng.choice([1, 8])),
                               int(rng.choice([64, 256])),
                               int(rng.choice([256, 1024]))),
    ]
    for i in range(n):
        fn, args = makers[i % len(makers)]()
        rows.append(lower_fn(fn, *args))
    return rows


# ------------------------------------------- real-architecture subgraphs
def arch_subgraphs(name: str, batch: int = 1, seq: int = 8
                   ) -> List[Tuple[str, Callable, Tuple]]:
    """Per-layer jnp subgraphs of a registered architecture at reduced
    widths: ``(layer_name, fn, arg_specs)`` triples, args as
    ``jax.ShapeDtypeStruct`` so lowering materializes nothing.

    These are the front door's acceptance inputs — real architectures
    from ``configs/``, lowered with ``jax.jit(fn).lower(*specs)``, fed
    back through ``predict_text``."""
    from repro.configs import get_arch
    cfg = get_arch(name).reduced()
    d = cfg.d_model
    h, hd = cfg.n_heads, cfg.resolved_head_dim
    ff = cfg.d_ff or 4 * d
    f32 = jnp.float32

    def spec(*shape):
        return jax.ShapeDtypeStruct(shape, f32)

    def attention(x, wq, wk, wv, wo):
        b, s, _ = x.shape
        q = (x @ wq).reshape(b, s, h, hd)
        k = (x @ wk).reshape(b, s, h, hd)
        v = (x @ wv).reshape(b, s, h, hd)
        a = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
        w = jax.nn.softmax(a, axis=-1)
        return (jnp.einsum("bhqk,bkhd->bqhd", w, v)
                .reshape(b, s, h * hd)) @ wo

    def mlp_swiglu(x, wg, wu, wd):
        return (jax.nn.silu(x @ wg) * (x @ wu)) @ wd

    def rmsnorm_residual(x, g):
        var = (x * x).mean(-1, keepdims=True)
        return x + x * jax.lax.rsqrt(var + cfg.norm_eps) * g

    def lm_head(x, w):
        return jax.nn.log_softmax(x @ w, axis=-1)

    out: List[Tuple[str, Callable, Tuple]] = [
        ("attention", attention,
         (spec(batch, seq, d), spec(d, h * hd), spec(d, h * hd),
          spec(d, h * hd), spec(h * hd, d))),
        ("mlp_swiglu", mlp_swiglu,
         (spec(batch, seq, d), spec(d, ff), spec(d, ff), spec(ff, d))),
        ("rmsnorm_residual", rmsnorm_residual,
         (spec(batch, seq, d), spec(d))),
        ("lm_head", lm_head, (spec(batch, seq, d), spec(d, cfg.vocab))),
    ]
    if cfg.moe is not None:
        def moe_router(x, wr):
            logits = x @ wr
            probs = jax.nn.softmax(logits, axis=-1)
            top = jax.lax.top_k(probs, cfg.moe.top_k)[0]
            return top / top.sum(-1, keepdims=True)
        out.append(("moe_router", moe_router,
                    (spec(batch, seq, d), spec(d, cfg.moe.n_experts))))
    return out


def lower_arch_corpus(names: Optional[List[str]] = None, batch: int = 1,
                      seq: int = 8) -> List[Tuple[str, str, str]]:
    """Lower every per-layer subgraph of the given architectures ->
    ``(arch, layer, stablehlo_text)`` rows. ``names=None`` lowers all
    registered archs."""
    from repro.configs import ARCHS
    rows: List[Tuple[str, str, str]] = []
    for name in (names if names is not None else sorted(ARCHS)):
        for layer, fn, specs in arch_subgraphs(name, batch=batch,
                                               seq=seq):
            text = jax.jit(fn).lower(*specs).as_text()
            rows.append((name, layer, text))
    return rows
