"""Replica lifecycle supervision: respawn, crash-loop budget, scaling.

PR 6 taught the router to route *around* failure (cooldowns, ring
successors, shed); this module closes the loop so the fleet also heals.
A :class:`ReplicaSupervisor` runs a watch thread in the tier's parent
process and

* **detects death and wedge** — ``Process.is_alive()``/exitcode catches
  crashes; a periodic heartbeat RPC (the existing ``MSG_STATS``
  round-trip on a reserved control queue) catches replicas that are
  alive but no longer serving (stuck forward pass, SIGSTOP, deadlocked
  runtime). A wedged replica is SIGKILLed before respawn.
* **respawns into the same ring slot** — replicas are rebuilt from the
  picklable :class:`~repro.serving.transport.ServiceSpec` via
  :meth:`ReplicaTier.spawn`, reusing slot ``i``'s inbox. Consistent-hash
  ownership never churns: surviving replicas keep their keys (and their
  LRU locality), and requests queued to the dead slot are simply served
  by its successor process after re-warm.
* **meters restarts** — each respawn waits out an escalating backoff
  (``restart_backoff_s * 2^k``), and more than ``max_restarts``
  restarts inside ``restart_window_s`` marks the slot *crash-looping*:
  the supervisor stops feeding it and leaves the router's reroute /
  oracle-fallback ladder to absorb the loss.
* **scales the tier** — a :class:`ScalePolicy` turns the arrival-rate /
  queue-depth / shed signals from heartbeat payloads (plus, optionally,
  a router ``stats()`` source) into a target replica count. Scale-up
  spawns into pre-allocated inbox slots (``start_replicas(...,
  max_replicas=)``) and only publishes the new count — through the
  shared ``active`` value every :class:`ReplicaClient` watches — once
  the newcomer reports warmed, so clients never route to a cold
  replica. Scale-down retires the highest slot first.

After killing a replica the supervisor also runs
:meth:`SharedRowCache.recover` — a holder SIGKILLed mid-publish leaves
the cross-process mutex acquired forever, and every other replica would
otherwise be stuck in bounded-timeout miss mode.

Everything observable lands in :meth:`stats` (restarts, recovery
durations, crash-loops, scale events, heartbeat ages);
``repro.obs.registry.register_supervisor`` snapshots it.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.serving import transport as T
from repro.serving.replica import ReplicaTier


@dataclass
class ScalePolicy:
    """Target-count policy over per-replica load signals.

    ``decide`` sees one dict per *responsive* active replica:
    ``arrival_per_s`` (request rate since the last heartbeat),
    ``queue_depth`` and ``shed_delta`` (server-side backpressure), plus
    an optional fleet-level ``router`` dict (a ``ReplicaClient.stats()``
    snapshot: sheds and cooldown counts seen from the client side).
    Scale-up is eager (any shed or deep queue); scale-down waits
    ``settle_ticks`` consecutive quiet evaluations so a bursty search
    loop doesn't flap the fleet."""

    min_replicas: int = 1
    max_replicas: int = 8
    high_queue_depth: float = 32.0
    low_rate_per_s: float = 0.5
    settle_ticks: int = 3
    _quiet: int = field(default=0, repr=False)

    def decide(self, active: int, signals: List[Dict[str, float]],
               router: Optional[Dict[str, Any]] = None) -> int:
        lo = max(1, self.min_replicas)
        hi = max(lo, self.max_replicas)
        if not signals:
            return min(max(active, lo), hi)
        hot = any(s.get("shed_delta", 0) > 0
                  or s.get("queue_depth", 0) > self.high_queue_depth
                  for s in signals)
        if router is not None:
            hot = hot or router.get("shed_count", 0) > 0 \
                or router.get("unhealthy_now", 0) > 0
        if hot:
            self._quiet = 0
            return min(active + 1, hi)
        if all(s.get("arrival_per_s", 0.0) < self.low_rate_per_s
               for s in signals) and active > lo:
            self._quiet += 1
            if self._quiet >= self.settle_ticks:
                self._quiet = 0
                return active - 1
        else:
            self._quiet = 0
        return max(active, lo)


class RestartBudget:
    """Escalating, windowed restart metering for one replica slot."""

    def __init__(self, backoff_s: float = 0.5, max_restarts: int = 5,
                 window_s: float = 60.0, cap_s: float = 30.0):
        self.backoff_s = float(backoff_s)
        self.max_restarts = int(max_restarts)
        self.window_s = float(window_s)
        self.cap_s = float(cap_s)
        self._stamps: deque = deque()

    def _recent(self, now: float) -> int:
        while self._stamps and now - self._stamps[0] > self.window_s:
            self._stamps.popleft()
        return len(self._stamps)

    def crash_looping(self, now: float) -> bool:
        return self._recent(now) >= self.max_restarts

    def next_delay(self, now: float) -> float:
        """Backoff before the next respawn (0 for the first failure in
        a window); call :meth:`note_restart` when the respawn happens."""
        n = self._recent(now)
        if n == 0:
            return 0.0
        return min(self.backoff_s * (2.0 ** (n - 1)), self.cap_s)

    def note_restart(self, now: float) -> None:
        self._stamps.append(now)


class ReplicaSupervisor:
    """Watches a :class:`ReplicaTier`; respawns, meters, and scales."""

    def __init__(self, tier: ReplicaTier, *,
                 heartbeat_s: float = 0.5,
                 heartbeat_timeout_s: float = 5.0,
                 restart_backoff_s: float = 0.5,
                 max_restarts: int = 5,
                 restart_window_s: float = 60.0,
                 start_timeout_s: float = 180.0,
                 scale: Optional[ScalePolicy] = None,
                 scale_interval_s: float = 2.0,
                 router_stats_fn: Optional[Callable[[], Dict]] = None,
                 recover_shared_lock: bool = True):
        self.tier = tier
        self.heartbeat_s = float(heartbeat_s)
        self.heartbeat_timeout_s = float(heartbeat_timeout_s)
        self.start_timeout_s = float(start_timeout_s)
        self.scale = scale
        self.scale_interval_s = float(scale_interval_s)
        self.router_stats_fn = router_stats_fn
        self.recover_shared_lock = recover_shared_lock
        n_slots = tier.max_replicas
        self._budget = [RestartBudget(restart_backoff_s, max_restarts,
                                      restart_window_s)
                        for _ in range(n_slots)]
        now = time.monotonic()
        self._last_seen = [now] * n_slots      # heartbeat grace at start
        self._payload: List[Optional[Dict]] = [None] * n_slots
        self._prev_requests: List[Optional[float]] = [None] * n_slots
        self._prev_shed = [0.0] * n_slots
        self._rate: List[float] = [0.0] * n_slots
        self._respawn_at: Dict[int, float] = {}   # slot -> due time
        self._respawning: Dict[int, float] = {}   # slot -> spawn stamp
        self._pending_up: Dict[int, float] = {}   # scale-up warms
        self._failed: set = set()                 # crash-looping slots
        self.restart_log: List[Dict[str, Any]] = []
        self.scale_events: List[Dict[str, Any]] = []
        self.lock_recoveries = 0
        self.inbox_resets = 0
        self.tick_errors = 0
        self.last_tick_error = ""
        self._hb_seq = 0
        self._last_hb = 0.0
        self._last_scale = now
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "ReplicaSupervisor":
        if self._thread is not None:
            raise RuntimeError("supervisor already started")
        self._thread = threading.Thread(
            target=self._run, name="replica-supervisor", daemon=True)
        self._thread.start()
        return self

    def stop(self, timeout: float = 10.0) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=timeout)
            self._thread = None

    def __enter__(self) -> "ReplicaSupervisor":
        return self.start() if self._thread is None else self

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------ main loop
    @property
    def active(self) -> int:
        return int(self.tier.active.value) if self.tier.active \
            is not None else self.tier.n_replicas

    def _run(self) -> None:
        tick = max(self.heartbeat_s / 4.0, 0.02)
        while not self._stop.wait(tick):
            now = time.monotonic()
            try:
                self._drain_ready(now)
                self._drain_heartbeats(now)
                if now - self._last_hb >= self.heartbeat_s:
                    self._send_heartbeats()
                    self._last_hb = now
                self._check_replicas(now)
                self._do_due_respawns(now)
                if self.scale is not None and \
                        now - self._last_scale >= self.scale_interval_s:
                    self._evaluate_scale(now)
                    self._last_scale = now
            except Exception as e:
                # the supervisor must outlive anything the fleet throws
                # at it; one bad tick never stops the watch — but the
                # failure is recorded, not swallowed
                self.tick_errors += 1
                self.last_tick_error = repr(e)

    # ------------------------------------------------------------ heartbeat
    def _send_heartbeats(self) -> None:
        cid = self.tier.control_id
        for r in range(self.active):
            if r in self._failed or r in self._respawning:
                continue
            self._hb_seq += 1
            try:
                self.tier.inboxes[r].put((T.MSG_STATS, cid,
                                          self._hb_seq))
            except Exception:
                pass

    def _drain_heartbeats(self, now: float) -> None:
        q = self.tier.control_queue
        while True:
            try:
                msg = q.get_nowait()
            except Exception:
                return
            if not msg or msg[0] != T.MSG_STATS_RES:
                continue
            payload = msg[2]
            r = payload.get("replica_id") if isinstance(payload, dict) \
                else None
            if r is None or not 0 <= r < len(self._last_seen):
                continue
            dt = now - self._last_seen[r]
            self._last_seen[r] = now
            srv = payload.get("server", {})
            reqs = float(srv.get("requests", 0.0))
            prev = self._prev_requests[r]
            if prev is not None and dt > 0:
                self._rate[r] = max(reqs - prev, 0.0) / dt
            self._prev_requests[r] = reqs
            self._payload[r] = payload

    # ------------------------------------------------------------- respawn
    def _check_replicas(self, now: float) -> None:
        for r in range(self.active):
            if r in self._failed or r in self._respawn_at:
                continue
            if r in self._respawning:
                if now - self._respawning[r] > self.start_timeout_s:
                    self._respawning.pop(r, None)
                    self._plan_respawn(r, "start_timeout", now)
                continue
            p = self.tier.procs[r] if r < len(self.tier.procs) else None
            if p is None or not p.is_alive():
                self._plan_respawn(r, "died", now)
            elif now - self._last_seen[r] > self.heartbeat_timeout_s:
                self._plan_respawn(r, "wedged", now)

    def _plan_respawn(self, r: int, reason: str, now: float) -> None:
        budget = self._budget[r]
        if budget.crash_looping(now):
            with self._lock:
                if r not in self._failed:
                    self._failed.add(r)
                    self.restart_log.append(
                        {"replica": r, "reason": "crash_loop",
                         "detected_s": now, "gave_up": True})
            return
        # a wedged-but-alive process is killed outright: SIGTERM can sit
        # undelivered behind a stuck forward pass (or a SIGSTOP)
        p = self.tier.procs[r] if r < len(self.tier.procs) else None
        if p is not None and p.is_alive():
            try:
                p.kill()
                p.join(timeout=5.0)
            except Exception:
                pass
        if self.recover_shared_lock:
            try:
                if self.tier.shared_cache.recover():
                    self.lock_recoveries += 1
            except Exception:
                pass
        # a replica dies holding its inbox's reader lock (it waits in
        # get() with it held) and may leave a half-read frame in the
        # pipe; either would wedge the successor forever. A fresh inbox
        # per respawn generation sidesteps both (see
        # :meth:`ReplicaTier.reset_inbox`).
        try:
            self.tier.reset_inbox(r)
            self.inbox_resets += 1
        except Exception:
            pass
        with self._lock:
            self.restart_log.append({"replica": r, "reason": reason,
                                     "detected_s": now})
        self._respawn_at[r] = now + budget.next_delay(now)

    def _do_due_respawns(self, now: float) -> None:
        for r, due in list(self._respawn_at.items()):
            if now < due:
                continue
            self._respawn_at.pop(r, None)
            self._budget[r].note_restart(now)
            try:
                self.tier.spawn(r)
            except Exception:
                self._plan_respawn(r, "spawn_failed", now)
                continue
            self._respawning[r] = now

    def _drain_ready(self, now: float) -> None:
        q = self.tier.ready
        if q is None:
            return
        while True:
            try:
                msg = q.get_nowait()
            except Exception:
                return
            if not msg:
                continue
            if msg[0] == "ready":
                r = msg[1]
                started = self._respawning.pop(r, None)
                self._last_seen[r] = now
                self._prev_requests[r] = None
                publish = None
                with self._lock:
                    if started is not None:
                        for rec in reversed(self.restart_log):
                            if rec["replica"] == r and \
                                    "recovered_in_s" not in rec and \
                                    not rec.get("gave_up"):
                                rec["recovered_in_s"] = \
                                    now - rec["detected_s"]
                                break
                    if r in self._pending_up:     # scale-up warm done
                        self._pending_up.pop(r, None)
                        publish = r + 1
                if publish is not None:   # outside the lock: publishing
                    #                       re-takes it for the event log
                    self._publish_active(publish, "up")
            elif msg[0] == "error":
                # startup failures carry no replica id on the ready
                # queue; attribute to the oldest in-flight spawn (the
                # start timeout catches any mis-attribution)
                if self._respawning:
                    r = min(self._respawning,
                            key=self._respawning.__getitem__)
                    self._respawning.pop(r, None)
                    self._pending_up.pop(r, None)
                    self._plan_respawn(r, "start_error", now)

    # ------------------------------------------------------------- scaling
    def _publish_active(self, n: int, direction: str) -> None:
        if self.tier.active is None:
            return
        with self._lock:
            self.tier.active.value = n
            self.scale_events.append({"t_s": time.monotonic(),
                                      "direction": direction,
                                      "active": n})

    def _evaluate_scale(self, now: float) -> None:
        if self._pending_up or self._respawn_at or self._respawning:
            return                       # settle before re-deciding
        active = self.active
        signals = []
        for r in range(active):
            if r in self._failed:
                continue
            payload = self._payload[r]
            if payload is None:
                continue
            srv = payload.get("server", {})
            shed = float(srv.get("shed", 0.0))
            signals.append({"arrival_per_s": self._rate[r],
                            "queue_depth": float(
                                srv.get("queue_depth", 0.0)),
                            "shed_delta": shed - self._prev_shed[r]})
            self._prev_shed[r] = shed
        router = None
        if self.router_stats_fn is not None:
            try:
                router = self.router_stats_fn()
            except Exception:
                router = None
        target = self.scale.decide(active, signals, router)
        target = min(target, self.tier.max_replicas)
        if target > active:
            r = active                   # next pre-allocated slot
            if r in self._failed:
                return
            try:
                self.tier.spawn(r)
            except Exception:
                return
            self._respawning[r] = now
            self._pending_up[r] = now    # publish only once warmed
        elif target < active:
            r = active - 1
            self._publish_active(target, "down")
            try:                         # retire the vacated slot
                self.tier.inboxes[r].put((T.MSG_STOP,))
            except Exception:
                pass
            self._last_seen[r] = now

    # --------------------------------------------------------------- stats
    def stats(self) -> Dict[str, Any]:
        now = time.monotonic()
        with self._lock:
            log = [dict(rec) for rec in self.restart_log]
            events = [dict(e) for e in self.scale_events]
            failed = sorted(self._failed)
        restarts = [r for r in log if not r.get("gave_up")]
        recovered = [r["recovered_in_s"] for r in restarts
                     if "recovered_in_s" in r]
        return {
            "active": self.active,
            "max_replicas": self.tier.max_replicas,
            "restarts_total": len(restarts),
            "restarts_recovered": len(recovered),
            "recovery_s_max": max(recovered) if recovered else 0.0,
            "crash_loops": len(failed),
            "failed_slots": failed,
            "respawning": sorted(self._respawning),
            "lock_recoveries": self.lock_recoveries,
            "inbox_resets": self.inbox_resets,
            "tick_errors": self.tick_errors,
            "scale_ups": sum(e["direction"] == "up" for e in events),
            "scale_downs": sum(e["direction"] == "down"
                               for e in events),
            "heartbeat_age_s": {
                r: now - self._last_seen[r]
                for r in range(self.active)},
            "restart_log": log,
        }
