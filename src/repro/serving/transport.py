"""Wire format + picklable service recipe for the replicated tier.

Replicas are separate OS processes (spawned — JAX must never be forked
mid-session), so everything crossing the boundary is defined here:

* :class:`ServiceSpec` — a picklable recipe that rebuilds an identical
  :class:`~repro.core.service.CostModelService` in any process (params
  are carried as numpy; JAX re-commits them per process). The router
  builds one too — as its *featurizer* (struct keys + token ids + the
  client-side LRU); it never runs a forward pass.
* request/response packing — requests ship ``(struct_key, token ids)``
  per entry: the router featurizes ONCE client-side, the replica's
  key-first cache probe and ids-first submit seam mean nothing is ever
  re-tokenized server-side. Batches pack all ids into one contiguous
  ``int32`` buffer (one allocation each way, cheap to pickle); response
  rows pack as one ``(n, n_heads) float32`` block.

Message tuples (first element is the type tag):

  ``(MSG_REQ, client_id, batch_id, keys, lens_b, ids_b[, trace])``
  router->replica; ``(MSG_RES, batch_id, rids, rows_b, n_heads[, spans])``
  replica->router; ``(MSG_OVERLOAD, batch_id, rids, retry_after_s)``
  replica->router; ``(MSG_ERR, batch_id, rids, repr)``    replica->router
  ``(MSG_STATS, client_id, rid)`` / ``(MSG_STATS_RES, rid, payload)``
  ``(MSG_CLEAR, client_id, rid)`` — drop replica caches (bench cold runs)
  ``(MSG_STOP,)``

Tracing rides the wire as *optional trailing elements* — requests the
client head-sampled append a 7th element ``trace = (trace_id,
parent_span_id)`` to MSG_REQ, and the replica ships that trace's span
records back as a 6th MSG_RES element. Untraced traffic keeps the
original tuple arity, so both sides unpack length-tolerantly
(:func:`req_trace` / :func:`res_spans`) and old-shaped messages remain
valid forever.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

MSG_REQ = "req"
MSG_RES = "res"
MSG_OVERLOAD = "overload"
MSG_ERR = "err"
MSG_STATS = "stats"
MSG_STATS_RES = "stats_res"
MSG_CLEAR = "clear"
MSG_STOP = "stop"


def req_trace(msg) -> Optional[Tuple[str, str]]:
    """Optional trace context on a MSG_REQ tuple (None when untraced)."""
    return msg[6] if len(msg) > 6 else None


def res_spans(msg) -> Optional[list]:
    """Optional span records riding a MSG_RES tuple."""
    return msg[5] if len(msg) > 5 else None


@dataclass
class ServiceSpec:
    """Everything needed to rebuild one CostModelService, picklable.

    ``params`` is a numpy pytree (converted via :meth:`from_service` /
    :meth:`make`); the rebuilt service re-bakes or re-commits it to the
    local device exactly like a directly-constructed one."""

    kind: str
    cfg: Any
    params: Any
    vocab: Any
    norm_stats: Dict[str, Any]
    mode: str = "ops"
    max_seq: int = 256
    max_batch: int = 256
    cache_size: int = 4096
    dtype: str = "f32"
    fast_encode: bool = True
    use_kernel: bool = False
    buckets: Optional[Tuple[int, ...]] = None
    batch_ladder: Optional[Tuple[int, ...]] = None
    # OOV vocab mode, mirrored explicitly on the wire: the pickled
    # Vocab carries these fields itself on current builds, but the
    # spec is the authoritative copy — build() re-applies them, so a
    # legacy-pickled vocab (plain id dict) still comes up in the mode
    # the router featurizes with. Router and replica MUST agree here:
    # the shard/byte id resolution happens client-side in encode().
    n_unk_buckets: int = 0
    byte_fallback: bool = False
    extra: Dict[str, Any] = field(default_factory=dict)

    @classmethod
    def from_service(cls, svc) -> "ServiceSpec":
        """Capture a live service's configuration (params -> numpy)."""
        return cls(kind=svc.kind, cfg=svc.cfg, params=_to_numpy(svc.params),
                   vocab=svc.vocab, norm_stats=svc.norm_stats,
                   mode=svc.mode, max_seq=svc.max_seq,
                   max_batch=svc.max_batch, cache_size=svc.cache_size,
                   dtype=svc.dtype, fast_encode=svc.fast_encode,
                   use_kernel=svc.use_kernel,
                   buckets=tuple(svc.buckets),
                   batch_ladder=tuple(svc.batch_ladder),
                   n_unk_buckets=getattr(svc.vocab, "n_unk_buckets", 0),
                   byte_fallback=getattr(svc.vocab, "byte_fallback",
                                         False))

    def build(self, **overrides):
        """Instantiate the CostModelService in THIS process."""
        import jax
        import jax.numpy as jnp
        from repro.core.service import CostModelService
        from repro.core.tokenizer import Vocab
        # re-commit the numpy pytree to this process's device: the jit
        # closures index params directly, so they must be jax arrays
        params = jax.tree.map(jnp.asarray, self.params)
        vocab = self.vocab
        if (self.n_unk_buckets or self.byte_fallback) and \
                isinstance(vocab, Vocab) and (
                getattr(vocab, "n_unk_buckets", 0) != self.n_unk_buckets
                or getattr(vocab, "byte_fallback", False)
                != self.byte_fallback):
            vocab = Vocab(vocab.token_to_id,
                          n_unk_buckets=self.n_unk_buckets,
                          byte_fallback=self.byte_fallback)
        kw = dict(mode=self.mode, max_seq=self.max_seq,
                  max_batch=self.max_batch, cache_size=self.cache_size,
                  dtype=self.dtype, fast_encode=self.fast_encode,
                  use_kernel=self.use_kernel, buckets=self.buckets,
                  batch_ladder=self.batch_ladder, **self.extra)
        kw.update(overrides)
        return CostModelService(self.kind, self.cfg, params,
                                vocab, self.norm_stats, **kw)


def _to_numpy(tree):
    import jax
    return jax.tree.map(lambda x: np.asarray(x), tree)


# ------------------------------------------------------------ entry packing
def pack_entries(entries: Sequence[Tuple[str, np.ndarray]]
                 ) -> Tuple[List[str], bytes, bytes]:
    """(key, ids) batch -> (keys, packed lengths, packed ids).

    Entries may span buckets (different ids lengths); ids concatenate
    into one int32 buffer with an explicit length table so unpacking is
    two ``np.frombuffer`` views + slicing, no per-entry pickling."""
    keys = [k for k, _ in entries]
    lens = np.asarray([len(ids) for _, ids in entries], np.int32)
    if entries:
        ids_b = np.concatenate(
            [np.asarray(ids, np.int32) for _, ids in entries]).tobytes()
    else:
        ids_b = b""
    return keys, lens.tobytes(), ids_b


def unpack_entries(keys: Sequence[str], lens_b: bytes, ids_b: bytes
                   ) -> List[Tuple[str, np.ndarray]]:
    lens = np.frombuffer(lens_b, np.int32)
    flat = np.frombuffer(ids_b, np.int32)
    out: List[Tuple[str, np.ndarray]] = []
    pos = 0
    for k, n in zip(keys, lens):
        out.append((k, flat[pos:pos + n]))
        pos += int(n)
    return out


def pack_rows(rows: Sequence[np.ndarray]) -> Tuple[bytes, int]:
    """Normalized (n_heads,) rows -> one f32 block + the head count."""
    block = np.stack([np.asarray(r, np.float32) for r in rows])
    return block.tobytes(), int(block.shape[1])


def unpack_rows(rows_b: bytes, n_heads: int) -> np.ndarray:
    return np.frombuffer(rows_b, np.float32).reshape(-1, n_heads)
