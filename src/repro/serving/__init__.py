"""Replicated serving tier: struct-key-routed multi-process replicas.

Layers (bottom up):

* :mod:`repro.serving.transport` — picklable :class:`ServiceSpec`
  recipe + the ids-first wire format (featurize once client-side).
* :mod:`repro.serving.shared_cache` — :class:`SharedRowCache`, the
  cross-replica second-chance prediction cache in shared memory.
* :mod:`repro.serving.replica` — :func:`start_replicas` /
  :class:`ReplicaTier`: N spawned processes, each a full
  service+server stack with adaptive flush deadlines.
* :mod:`repro.serving.router` — :class:`ReplicaClient`, the
  service-shaped client: consistent-hash routing on struct keys,
  retry/backoff honoring replica ``retry_after_s`` hints, reroute on
  failure, shed after ``max_retries``.
* :mod:`repro.serving.fleet` — :class:`FleetDriver`, the multi-process
  fleet-client harness the replicated search bench drives.
"""
from repro.serving.replica import ReplicaTier, TierHandle, start_replicas
from repro.serving.router import HashRing, QueueTransport, ReplicaClient
from repro.serving.shared_cache import SharedRowCache
from repro.serving.transport import ServiceSpec
from repro.serving.fleet import FleetDriver, fleet_worker_main

__all__ = [
    "FleetDriver", "HashRing", "QueueTransport", "ReplicaClient",
    "ReplicaTier", "ServiceSpec", "SharedRowCache", "TierHandle",
    "fleet_worker_main", "start_replicas",
]
