"""Replicated serving tier: struct-key-routed multi-process replicas.

Layers (bottom up):

* :mod:`repro.serving.transport` — picklable :class:`ServiceSpec`
  recipe + the ids-first wire format (featurize once client-side).
* :mod:`repro.serving.shared_cache` — :class:`SharedRowCache`, the
  cross-replica second-chance prediction cache in shared memory
  (bounded lock acquire + crc-validated slots, so a dying holder
  degrades to misses instead of wedging or corrupting the fleet).
* :mod:`repro.serving.replica` — :func:`start_replicas` /
  :class:`ReplicaTier`: N spawned processes, each a full
  service+server stack with adaptive flush deadlines; slots are
  respawnable in place.
* :mod:`repro.serving.router` — :class:`ReplicaClient`, the
  service-shaped client: consistent-hash routing on struct keys,
  retry with decorrelated-jitter backoff honoring replica
  ``retry_after_s`` hints, reroute on failure, per-request deadline
  budgets, and an optional analyzer-oracle fallback floor.
* :mod:`repro.serving.supervisor` — :class:`ReplicaSupervisor`:
  heartbeat liveness, in-slot respawn with crash-loop budgets, and
  signal-driven scale up/down.
* :mod:`repro.serving.faults` — seeded :class:`FaultPlan` /
  :class:`FaultyTransport`, the deterministic chaos harness behind
  the ``chaos_serve`` gate.
* :mod:`repro.serving.fleet` — :class:`FleetDriver`, the multi-process
  fleet-client harness the replicated search bench drives.
"""
from repro.serving.faults import FaultEvent, FaultPlan, FaultyTransport
from repro.serving.replica import ReplicaTier, TierHandle, start_replicas
from repro.serving.router import HashRing, QueueTransport, ReplicaClient
from repro.serving.shared_cache import SharedRowCache
from repro.serving.supervisor import (ReplicaSupervisor, RestartBudget,
                                      ScalePolicy)
from repro.serving.transport import ServiceSpec
from repro.serving.fleet import FleetDriver, fleet_worker_main

__all__ = [
    "FaultEvent", "FaultPlan", "FaultyTransport", "FleetDriver",
    "HashRing", "QueueTransport", "ReplicaClient", "ReplicaSupervisor",
    "ReplicaTier", "RestartBudget", "ScalePolicy", "ServiceSpec",
    "SharedRowCache", "TierHandle", "fleet_worker_main",
    "start_replicas",
]
