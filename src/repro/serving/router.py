"""Struct-key router client for the replicated serving tier.

A :class:`ReplicaClient` looks exactly like a
:class:`~repro.core.service.CostModelService` to callers (``heads`` /
``resolve_target`` / ``predict_all`` / ``predict_graphs`` / ``predict``)
but fans misses out across N replica processes:

* **Featurize once, client-side.** The client owns a *featurizer*
  service built from the same :class:`~repro.serving.transport.ServiceSpec`
  (struct keys, incremental token ids, and — optionally — a local
  prediction LRU). It never runs a forward pass; requests ship
  ``(struct_key, ids)`` so replicas skip re-tokenization entirely.
* **Consistent-hash routing.** ``HashRing`` maps each struct key to a
  stable replica (virtual nodes keep the split even), so repeat queries
  for a graph family land on the replica whose LRU already holds them.
  The ring's successor order doubles as the reroute fallback chain.
* **Retry / backoff / shed.** Overload replies carry the replica's own
  ``retry_after_s`` hint; the client backs off with *decorrelated
  jitter* (so N fleet workers retrying the same recovering replica
  don't thundering-herd it in lockstep), reroutes around replicas in
  cooldown or timing out, and sheds with
  :class:`~repro.core.server.ServerOverloadedError` once
  ``max_retries`` rounds exhaust. Per-replica health counters
  (sent/ok/overload/err/timeout/reroutes, consecutive failures,
  cooldown window) feed both routing and ``stats()``.
* **Deadline budgets + oracle floor.** ``deadline_s`` bounds a whole
  fetch (retries included). With ``oracle_fallback=True`` a blown
  deadline or exhausted tier degrades to the analyzer oracle — the
  paper's static cost model — instead of raising, so beam search keeps
  making progress through a dying fleet. Degraded predictions are
  counted here (``degraded_count``), flagged in the featurizer's
  ``phase_stats()``, and never cached.

The transport is pluggable (anything with ``n_replicas`` / ``send`` /
``recv``), so tests can drive the full retry state machine without
spawning processes.
"""
from __future__ import annotations

import hashlib
import os
import queue
import random
import threading
import time
from bisect import bisect_right
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.server import ServerOverloadedError
from repro.serving import transport as T


class HashRing:
    """Consistent-hash ring over replica ids with virtual nodes.

    ``route(key)`` returns replicas in ring-successor order (primary
    first) — the natural fallback chain when the primary is shedding or
    dead. Stable under key renaming noise because points hash the
    replica id, and balanced because each replica contributes ``vnodes``
    points."""

    def __init__(self, n_replicas: int, vnodes: int = 32):
        if n_replicas < 1:
            raise ValueError("need at least one replica")
        self.n_replicas = n_replicas
        pts: List[Tuple[int, int]] = []
        for r in range(n_replicas):
            for v in range(vnodes):
                h = hashlib.sha1(f"replica-{r}-vnode-{v}".encode()).digest()
                pts.append((int.from_bytes(h[:8], "big"), r))
        pts.sort()
        self._points = [p for p, _ in pts]
        self._owners = [r for _, r in pts]

    def _key_point(self, key: str) -> int:
        if len(key) == 40:                  # struct keys are sha1 hex
            try:
                return int(key[:16], 16)
            except ValueError:
                pass
        d = hashlib.sha1(key.encode()).digest()
        return int.from_bytes(d[:8], "big")

    def route(self, key: str, n: Optional[int] = None) -> List[int]:
        """Distinct replicas in preference order for ``key``."""
        want = self.n_replicas if n is None else min(n, self.n_replicas)
        i = bisect_right(self._points, self._key_point(key))
        out: List[int] = []
        for j in range(len(self._owners)):
            r = self._owners[(i + j) % len(self._owners)]
            if r not in out:
                out.append(r)
                if len(out) == want:
                    break
        return out

    def primary(self, key: str) -> int:
        return self.route(key, 1)[0]


class QueueTransport:
    """Default transport: the mp queues carried by a
    :class:`~repro.serving.replica.TierHandle`."""

    def __init__(self, handle):
        self.handle = handle
        self.client_id = handle.client_id

    @property
    def n_replicas(self) -> int:
        return self.handle.n_replicas

    def send(self, replica: int, msg) -> None:
        self.handle.inboxes[replica].put(msg)

    def recv(self, timeout: float):
        """Next message for this client; raises ``queue.Empty``."""
        return self.handle.resp_queue.get(timeout=timeout)


class _Health:
    __slots__ = ("sent", "ok", "overload", "err", "timeout", "reroutes",
                 "consecutive_failures", "unhealthy_until")

    def __init__(self):
        self.sent = 0
        self.ok = 0
        self.overload = 0
        self.err = 0
        self.timeout = 0
        self.reroutes = 0
        self.consecutive_failures = 0
        self.unhealthy_until = 0.0

    def note_ok(self):
        self.ok += 1
        self.consecutive_failures = 0
        self.unhealthy_until = 0.0

    def note_failure(self, kind: str, cooldown_s: float,
                     retry_after_s: float = 0.0):
        setattr(self, kind, getattr(self, kind) + 1)
        self.consecutive_failures += 1
        # Escalating cooldown: repeated failures push the replica out of
        # the routing preference for longer; the replica's own
        # retry_after hint floors the window.
        w = max(retry_after_s,
                cooldown_s * min(self.consecutive_failures, 8))
        self.unhealthy_until = max(self.unhealthy_until,
                                   time.monotonic() + w)

    def as_dict(self) -> Dict[str, float]:
        d = {k: getattr(self, k) for k in self.__slots__}
        # how much cooldown is actually left NOW (0 when healthy) —
        # `unhealthy_until` alone is a raw monotonic stamp, useless to
        # a dashboard on its own
        d["cooldown_remaining_s"] = max(
            0.0, self.unhealthy_until - time.monotonic())
        return d


class ReplicaClient:
    """Service-shaped client that routes predictions across replicas."""

    def __init__(self, handle=None, spec: Optional[T.ServiceSpec] = None,
                 *, transport=None, local_cache: bool = True,
                 vnodes: int = 32, max_retries: int = 4,
                 backoff_s: float = 0.005, backoff_mult: float = 2.0,
                 backoff_cap_s: Optional[float] = None,
                 timeout_s: float = 60.0, cooldown_s: float = 0.05,
                 deadline_s: Optional[float] = None,
                 oracle_fallback: bool = False,
                 jitter_seed: Optional[int] = None,
                 tracer=None):
        if transport is None:
            transport = QueueTransport(handle)
        self.transport = transport
        # optional repro.obs.trace.Tracer: head-samples requests here at
        # the tier's front door and imports replica-side spans shipped
        # back on MSG_RES, so one client recorder holds complete trees
        self.tracer = tracer
        self.client_id = getattr(transport, "client_id", 0)
        self.vnodes = vnodes
        # scaling: the supervisor publishes the routed replica count in
        # a shared Value; the ring tracks it lazily (see _maybe_resize)
        self._active = getattr(handle, "active", None)
        if self._active is None:
            self._active = getattr(transport, "active", None)
        n_active = self._active.value if self._active is not None \
            else transport.n_replicas
        self.ring = HashRing(n_active, vnodes=vnodes)
        self.local_cache = local_cache
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.backoff_mult = backoff_mult
        # decorrelated jitter: sleep ~ U(base, 3*prev) capped; the cap
        # defaults to where the old exponential schedule would have
        # topped out, keeping worst-case retry latency unchanged
        self.backoff_cap_s = backoff_cap_s if backoff_cap_s is not None \
            else backoff_s * (backoff_mult ** max_retries)
        seed = jitter_seed if jitter_seed is not None else (
            (os.getpid() << 20) ^ getattr(transport, "client_id", 0)
            ^ int(time.monotonic_ns() & 0xFFFFF))
        self._jitter = random.Random(seed)
        self.timeout_s = timeout_s
        self.cooldown_s = cooldown_s
        self.deadline_s = deadline_s
        self.oracle_fallback = oracle_fallback
        self.degraded_count = 0         # analyzer-fallback predictions
        self.deadline_expired = 0       # fetches cut short by the budget
        self.recv_errors = 0            # torn replies read as timeouts
        # The featurizer: same recipe as the replicas, used ONLY for
        # struct keys / token ids / (optionally) the local row LRU.
        if spec is None:
            spec = handle_spec(handle)
        self.spec = spec
        self.fsvc = spec.build()
        self.health = [_Health() for _ in range(transport.n_replicas)]
        self.shed_count = 0
        self._batch_seq = 0
        self._lock = threading.Lock()
        self._stray: List[Any] = []     # unknown-tag msgs seen mid-wait
        # Reply demux: all of one client's replies arrive on ONE queue,
        # but predict_all may be called from many threads (e.g. the
        # closed-loop serve driver shares a client across its client
        # threads). Whichever thread is pulling the queue delivers
        # messages for OTHER live batches into their mailbox instead of
        # dropping them; waiters are woken through the condition.
        self._cond = threading.Condition()
        self._mail: Dict[int, List[Any]] = {}     # live bid -> replies
        self._live: set = set()                   # bids awaited somewhere
        self._rx_busy = False                     # a thread owns recv()

    # ------------------------------------------------------- service duck
    @property
    def heads(self):
        return self.fsvc.heads

    def resolve_target(self, target: Optional[str]) -> str:
        return self.fsvc.resolve_target(target)

    def predict_all(self, graphs) -> Dict[str, np.ndarray]:
        if not len(graphs):
            return {t: np.zeros((0,), np.float32) for t in self.heads}
        tr = self.tracer
        root = None
        if tr is not None:             # head decision for this request
            root = tr.start("client.predict_all", tr.sample(),
                            tags={"n_graphs": len(graphs)})
        sub = root.ctx if root is not None else None
        try:
            feat = tr.start("client.featurize", sub) if tr else None
            keys: List[str] = []
            vals: Dict[str, np.ndarray] = {}
            miss_graphs: Dict[str, Any] = {}
            for g in graphs:
                h = self.fsvc.key_of(g)
                keys.append(h)
                if h in vals or h in miss_graphs:
                    continue
                hit = self.fsvc.cache_lookup(h) if self.local_cache \
                    else None
                if hit is not None:
                    vals[h] = hit
                else:
                    miss_graphs[h] = g
            entries = self.fsvc.entries_for(
                list(miss_graphs.values()), list(miss_graphs)) \
                if miss_graphs else []
            if tr is not None:
                tr.end(feat, n_miss=len(miss_graphs),
                       local_hits=len(vals))
            if entries:
                if self.oracle_fallback:
                    fetched, left = self._fetch_rounds(entries, trace=sub)
                else:
                    fetched, left = self._fetch(entries, trace=sub), {}
                vals.update(fetched)
                if self.local_cache and fetched:
                    self.fsvc.import_cache(list(fetched.items()))
                if left:
                    # tier exhausted / deadline blown: the analyzer
                    # oracle is the availability floor. Degraded rows
                    # are flagged (counters here + featurizer
                    # phase_stats) and NEVER cached, so real serving
                    # takes back over the moment the tier recovers.
                    vals.update(self._oracle_rows(
                        {k: miss_graphs[k] for k in left}))
                    self.degraded_count += len(left)
                    self.fsvc.note_degraded(len(left))
                    if tr is not None:
                        tr.error_span("router.degraded", sub,
                                      n_degraded=len(left))
        except BaseException:
            if tr is not None:
                tr.end(root, status="err")
            raise
        if tr is not None:
            tr.end(root)
        raw = np.stack([vals[k] for k in keys])
        out = self.fsvc.denormalize_rows(raw)
        drift = getattr(self.fsvc, "drift", None)
        if drift is not None:          # accuracy sentinel rides the tier
            drift.observe_batch(graphs, out)
        return out

    def predict_graphs(self, graphs, target: Optional[str] = None
                       ) -> np.ndarray:
        return self.predict_all(graphs)[self.resolve_target(target)]

    def predict(self, g, target: Optional[str] = None) -> float:
        return float(self.predict_graphs([g], target)[0])

    def predict_text(self, text):
        """Replicated-tier text prediction: the client featurizer does
        ingest + encode + OOV accounting locally, the struct/text key
        routes on the ring like any graph key, and the miss ships the
        usual ``(key, ids)`` wire entry — replicas never see raw text.
        Returns a TextPrediction or a structured IngestError (tier
        overload/timeouts surface as ``predict``-stage errors)."""
        from repro.ir import frontdoor as FD
        ent = self.fsvc.ingest_text(text)
        if isinstance(ent, FD.IngestError):
            return ent
        row = self.fsvc.cache_lookup(ent.key) if self.local_cache \
            else None
        if row is None:
            try:
                got = self._fetch([(ent.key, ent.ids)])
                row = got[ent.key]
            except Exception as e:
                return FD.IngestError("predict", type(e).__name__,
                                      str(e)[:200])
            if self.local_cache:
                self.fsvc.import_cache([(ent.key, row)])
        preds = self.fsvc.denormalize_rows(np.asarray(row)[None])
        return FD.prediction_from(
            ent, {t: float(preds[t][0]) for t in self.heads})

    # --------------------------------------------------------- fetch core
    def _next_batch_id(self) -> int:
        with self._lock:
            self._batch_seq += 1
            return (self.client_id << 20) | self._batch_seq

    def _pick_replica(self, key: str, now: float) -> int:
        """Primary unless it's in failure cooldown — then the first
        healthy successor on the ring (all cooling: primary anyway)."""
        order = self.ring.route(key)
        for i, r in enumerate(order):
            if self.health[r].unhealthy_until <= now:
                if i > 0:
                    self.health[order[0]].reroutes += 1
                return r
        return order[0]

    def _maybe_resize(self) -> None:
        """Track the supervisor-published routed replica count. Cheap
        (one shared-int read); the ring is rebuilt only on change, and
        slot identities are stable so surviving replicas keep their key
        ownership."""
        if self._active is None:
            return
        n = self._active.value
        if n != self.ring.n_replicas and n >= 1:
            self.ring = HashRing(n, vnodes=self.vnodes)

    def _fetch(self, entries: Sequence[Tuple[str, np.ndarray]],
               trace=None) -> Dict[str, np.ndarray]:
        """Resolve (key, ids) misses through the tier, with retry,
        reroute-on-failure, backoff, and final shed."""
        got, pending = self._fetch_rounds(entries, trace=trace)
        if pending:
            raise ServerOverloadedError(
                f"{len(pending)} request(s) shed after "
                f"{self.max_retries + 1} attempts across "
                f"{self.ring.n_replicas} replicas")
        return got

    def _fetch_rounds(self, entries: Sequence[Tuple[str, np.ndarray]],
                      trace=None) -> Tuple[Dict[str, np.ndarray],
                                           Dict[str, np.ndarray]]:
        """Retry/backoff core: returns ``(got, still_pending)``; the
        caller decides whether leftovers raise (``_fetch``) or degrade
        to the oracle (``predict_all``)."""
        tr = self.tracer
        span = tr.start("router.fetch", trace,
                        tags={"n_entries": len(entries)}) if tr else None
        sub = span.ctx if span is not None else None
        self._maybe_resize()
        pending: Dict[str, np.ndarray] = dict(entries)
        got: Dict[str, np.ndarray] = {}
        deadline = time.monotonic() + self.deadline_s \
            if self.deadline_s is not None else None
        sleep = self.backoff_s
        attempt = 0
        for attempt in range(self.max_retries + 1):
            if not pending:
                break
            if deadline is not None and time.monotonic() >= deadline:
                self.deadline_expired += 1
                break
            hint = self._round(pending, got, trace=sub,
                               deadline=deadline)
            if pending and attempt < self.max_retries:
                # decorrelated jitter (not plain exponential): each
                # client walks its own randomized schedule, so a fleet
                # of workers retrying a recovering replica spreads out
                # instead of re-converging every backoff_mult^k ticks
                sleep = min(self.backoff_cap_s,
                            self._jitter.uniform(self.backoff_s,
                                                 max(sleep * 3.0,
                                                     self.backoff_s)))
                wait = max(hint, sleep)
                if deadline is not None:
                    wait = min(wait, max(deadline - time.monotonic(),
                                         0.0))
                time.sleep(wait)
        if pending:
            self.shed_count += 1
            if tr is not None:          # sheds are always-on telemetry
                tr.error_span("router.shed", sub,
                              n_pending=len(pending),
                              attempts=attempt + 1)
                tr.end(span, status="shed", attempts=attempt + 1)
        elif tr is not None:
            tr.end(span, attempts=attempt + 1)
        return got, pending

    def _oracle_rows(self, graphs_by_key: Dict[str, Any]
                     ) -> Dict[str, np.ndarray]:
        """Analyzer-oracle fallback rows (normalized space, so they ride
        the same denormalize path as model rows). Heads the static
        analyzers don't model fall back to the training mean
        (normalized 0)."""
        from repro.ir.analyzers import TARGETS
        heads = list(self.heads)
        out: Dict[str, np.ndarray] = {}
        for key, g in graphs_by_key.items():
            den = np.zeros((1, len(heads)), np.float32)
            known = np.zeros(len(heads), bool)
            for i, t in enumerate(heads):
                fn = TARGETS.get(t)
                if fn is not None:
                    den[0, i] = float(fn(g))
                    known[i] = True
            raw = self.fsvc.normalize_rows(den)[0]
            out[key] = np.where(known, raw, 0.0).astype(np.float32)
        return out

    def _recv_any(self, bids, deadline: float):
        """Next reply addressed to one of ``bids`` (all registered in
        ``_live``), or ``None`` once ``deadline`` passes. Thread-safe
        over the shared per-client response queue: with one caller this
        degenerates to a plain ``transport.recv``; with several, the
        thread holding the transport forwards replies it doesn't own."""
        while True:
            with self._cond:
                for bid in bids:
                    box = self._mail.get(bid)
                    if box:
                        msg = box.pop(0)
                        if not box:
                            del self._mail[bid]
                        return msg
                left = deadline - time.monotonic()
                if left <= 0:
                    return None
                if self._rx_busy:
                    self._cond.wait(timeout=left)
                    continue
                self._rx_busy = True
            try:
                msg = self.transport.recv(
                    max(deadline - time.monotonic(), 1e-3))
            except queue.Empty:
                msg = None
            except Exception:
                # a replica dying mid-reply can tear the response
                # stream; a torn message reads as a timeout (and a
                # retry), never a crashed fetch
                self.recv_errors += 1
                msg = None
            finally:
                with self._cond:
                    self._rx_busy = False
                    self._cond.notify_all()
            if msg is None:
                return None
            bid = msg[1] if len(msg) > 1 else None
            if bid in bids:
                return msg
            with self._cond:
                if bid in self._live:             # another thread's batch
                    self._mail.setdefault(bid, []).append(msg)
                    self._cond.notify_all()
                elif msg[0] not in (T.MSG_RES, T.MSG_OVERLOAD, T.MSG_ERR,
                                    T.MSG_STATS_RES):
                    self._stray.append(msg)
                # else: stale reply for a finished round — dropped

    def _track(self, bids) -> None:
        with self._cond:
            self._live.update(bids)

    def _untrack(self, bids) -> None:
        with self._cond:
            self._live.difference_update(bids)
            for bid in bids:
                self._mail.pop(bid, None)

    def _round(self, pending: Dict[str, np.ndarray],
               got: Dict[str, np.ndarray], trace=None,
               deadline: Optional[float] = None) -> float:
        """One routed send/collect round. Resolved keys move from
        ``pending`` to ``got``; returns the max retry_after hint.

        When traced, each per-replica wire batch gets its own
        ``router.rpc`` span (retries create new ones under the same
        trace, so the tree shows every attempt); the trace context rides
        MSG_REQ as an optional 7th element — appended ONLY for traced
        sends, so untraced traffic keeps the classic 6-tuple shape."""
        tr = self.tracer
        now = time.monotonic()
        groups: Dict[int, List[Tuple[str, np.ndarray]]] = {}
        for key, ids in pending.items():
            groups.setdefault(self._pick_replica(key, now), []).append(
                (key, ids))
        outstanding: Dict[int, Tuple[int, List[str], Any]] = {}
        tracked: set = set()
        for replica, ents in groups.items():
            bid = self._next_batch_id()
            ks, lens_b, ids_b = T.pack_entries(ents)
            sp = tr.start("router.rpc", trace,
                          tags={"replica": replica, "n_keys": len(ks)}) \
                if tr is not None and trace is not None else None
            msg = (T.MSG_REQ, self.client_id, bid, ks, lens_b, ids_b)
            if sp is not None:
                msg = msg + (sp.ctx.to_wire(),)
            # register the bid BEFORE the send: with a shared client,
            # another thread can pull our reply off the queue the
            # instant the send lands, and an untracked bid reads as
            # stale and gets dropped (a spurious 1-round timeout)
            self._track({bid})
            tracked.add(bid)
            try:
                self.transport.send(replica, msg)
                self.health[replica].sent += 1
                outstanding[bid] = (replica, ks, sp)
            except Exception:
                self._untrack({bid})
                tracked.discard(bid)
                self.health[replica].note_failure(
                    "err", self.cooldown_s)
                if tr is not None:
                    tr.end(sp, status="err", stage="send")
        hint = 0.0
        round_deadline = time.monotonic() + self.timeout_s
        if deadline is not None:        # per-request budget clamps the
            round_deadline = min(round_deadline, deadline)   # wait too
        deadline = round_deadline
        try:
            while outstanding:
                msg = self._recv_any(set(outstanding), deadline)
                if msg is None:             # deadline: everything left
                    for bid, (replica, ks, sp) in outstanding.items():
                        self.health[replica].note_failure(
                            "timeout", self.cooldown_s)
                        if tr is not None:
                            tr.end(sp, status="timeout")
                    break
                tag = msg[0]
                if tag == T.MSG_RES:
                    bid, rids, rows_b, nh = msg[1], msg[2], msg[3], msg[4]
                    spans = T.res_spans(msg)
                    if spans and tr is not None:
                        tr.recorder.extend(spans)   # replica-side spans
                    replica, ks, sp = outstanding[bid]
                    rows = T.unpack_rows(rows_b, nh)
                    for rid, row in zip(rids, rows):
                        key = ks[rid]
                        got[key] = row
                        pending.pop(key, None)
                    self.health[replica].note_ok()
                    if not any(k in pending for k in ks):
                        outstanding.pop(bid, None)
                        if tr is not None:
                            tr.end(sp, n_rows=len(rids))
                elif tag == T.MSG_OVERLOAD:
                    _, bid, rids, retry_after = msg
                    replica, ks, sp = outstanding.pop(bid)
                    hint = max(hint, float(retry_after))
                    self.health[replica].note_failure(
                        "overload", self.cooldown_s,
                        retry_after_s=float(retry_after))
                    if tr is not None:
                        tr.end(sp, status="overload",
                               retry_after_s=float(retry_after))
                elif tag == T.MSG_ERR:
                    _, bid, rids, why = msg
                    replica, ks, sp = outstanding.pop(bid)
                    self.health[replica].note_failure(
                        "err", self.cooldown_s)
                    if tr is not None:
                        tr.end(sp, status="err")
        finally:
            self._untrack(tracked)
        return hint

    # ------------------------------------------------------------- control
    def _rpc(self, tag: str, timeout_s: float = 30.0
             ) -> List[Optional[Dict[str, Any]]]:
        """Broadcast a control message; collect one reply per replica."""
        rids = {}
        for r in range(self.ring.n_replicas):
            rid = self._next_batch_id()
            rids[rid] = r
            self._track({rid})          # before the send (demux race)
            try:
                self.transport.send(r, (tag, self.client_id, rid))
            except Exception:
                self._untrack({rid})
                del rids[rid]
        out: List[Optional[Dict[str, Any]]] = \
            [None] * self.ring.n_replicas
        deadline = time.monotonic() + timeout_s
        tracked = set(rids)
        try:
            want = len(rids)
            while want:
                msg = self._recv_any(tracked, deadline)
                if msg is None:
                    break
                if msg[0] == T.MSG_STATS_RES:
                    out[rids[msg[1]]] = msg[2]
                    want -= 1
        finally:
            self._untrack(tracked)
        return out

    def replica_stats(self) -> List[Optional[Dict[str, Any]]]:
        return self._rpc(T.MSG_STATS)

    def clear_caches(self, remote: bool = True) -> None:
        """Drop the client featurizer caches (rows + ids) and, when
        ``remote``, every replica's too — bench cold-pass reset."""
        with self.fsvc._cache_lock:
            self.fsvc._cache.clear()
            self.fsvc._ids_cache.clear()
        if remote:
            self._rpc(T.MSG_CLEAR)

    def stats(self) -> Dict[str, Any]:
        now = time.monotonic()
        return {
            "client_id": self.client_id,
            "n_replicas": self.ring.n_replicas,
            "shed_count": self.shed_count,
            "degraded_count": self.degraded_count,
            "deadline_expired": self.deadline_expired,
            "recv_errors": self.recv_errors,
            "local_cache": self.fsvc.cache_stats(),
            "health": {r: h.as_dict()
                       for r, h in enumerate(self.health)},
            # fleet-level rollups: per-kind failure totals and how many
            # replicas are in cooldown right now — the one-look summary
            # the registry snapshot and dashboards key on
            "failures": {k: sum(getattr(h, k) for h in self.health)
                         for k in ("overload", "err", "timeout",
                                   "reroutes")},
            "unhealthy_now": sum(h.unhealthy_until > now
                                 for h in self.health),
        }


def handle_spec(handle) -> T.ServiceSpec:
    spec = getattr(handle, "spec", None)
    if spec is None:
        raise ValueError("ReplicaClient needs a ServiceSpec: pass "
                         "spec= or a TierHandle that carries one")
    return spec
