"""Shared cross-replica prediction cache (lock-guarded shared memory).

Struct-key routing keeps each replica's *own* LRU hot, but a key's first
query still misses everywhere — and after a reroute (replica death,
overload cooldown) the fallback replica starts cold for that key's
neighborhood. This tier is the fleet's second-chance cache: a fixed-slot
open-addressed hash table in a ``multiprocessing`` shared byte array,
consulted by every replica on local-LRU miss and published to after
every computed batch. Writes are tiny (one 20-byte digest + the
``n_heads`` float32 row), so a single cross-process mutex is plenty at
cost-model scale.

Slot layout (fixed ``n_heads``):

  [1B valid][20B sha1 digest][n_heads * 4B f32 row][4B crc32]

The trailing crc32 covers digest + row and is what makes the table safe
against a *holder dying mid-write*: a replica SIGKILLed halfway through
a slot update leaves either ``valid == 0`` (write-in-progress marker) or
a payload whose checksum no longer matches — both read as a miss, never
as a wrong row. The same property catches deliberate corruption from
the fault harness (:mod:`repro.serving.faults`).

Because a dead holder also leaves the cross-process mutex acquired
forever, every operation takes the lock with a *bounded*
``acquire(timeout=lock_timeout_s)`` and degrades to a cache miss (or a
skipped publish) on timeout instead of wedging the whole fleet;
``lock_timeouts`` counts those per process. The supervisor calls
:meth:`recover` after killing a replica to force-release an orphaned
lock.

Collisions overwrite (cache semantics); two *different* keys sharing a
full 160-bit digest is out of scope. The table is picklable into
spawned children (the shared block and lock travel through
``multiprocessing``'s inheritance machinery), so one instance built by
the parent serves every replica and client process.
"""
from __future__ import annotations

import hashlib
import multiprocessing as mp
import zlib
from typing import List, Optional, Sequence, Tuple

import numpy as np

_DIGEST = 20                     # sha1
_CRC = 4                         # trailing crc32 (little-endian u32)


def _digest(key: str) -> bytes:
    """20-byte digest of a struct key. Graph.struct_key is already a
    sha1 hexdigest, so the common case is a cheap unhex."""
    if len(key) == 2 * _DIGEST:
        try:
            return bytes.fromhex(key)
        except ValueError:
            pass
    return hashlib.sha1(key.encode()).digest()


def _crc32(payload: np.ndarray) -> np.ndarray:
    """crc32 of a uint8 payload as a 4-byte little-endian array."""
    c = zlib.crc32(payload.tobytes()) & 0xFFFFFFFF
    return np.frombuffer(c.to_bytes(_CRC, "little"), np.uint8)


class SharedRowCache:
    """Fixed-capacity shared-memory map: struct key -> (n_heads,) f32."""

    PROBES = 8

    def __init__(self, n_heads: int, n_slots: int = 16384,
                 ctx: Optional[mp.context.BaseContext] = None,
                 lock_timeout_s: float = 1.0):
        ctx = ctx or mp.get_context("spawn")
        self.n_heads = int(n_heads)
        self.n_slots = int(n_slots)
        self.row_bytes = 4 * self.n_heads
        self.slot_bytes = 1 + _DIGEST + self.row_bytes + _CRC
        self.lock_timeout_s = float(lock_timeout_s)
        self._buf = ctx.RawArray("B", self.n_slots * self.slot_bytes)
        self._lock = ctx.Lock()
        # per-process degradation counters (each process pickles its own
        # copy; replicas report theirs through the stats RPC)
        self.lock_timeouts = 0
        self.torn_drops = 0

    # NOTE: np.frombuffer views are rebuilt per call — the object must
    # stay picklable (views of shared ctypes are not).
    def _view(self) -> np.ndarray:
        return np.frombuffer(self._buf, np.uint8).reshape(
            self.n_slots, self.slot_bytes)

    def _slots_for(self, dig: bytes) -> List[int]:
        h = int.from_bytes(dig[:8], "little")
        return [(h + i) % self.n_slots for i in range(self.PROBES)]

    def _acquire(self) -> bool:
        """Bounded lock acquire; a timeout means a wedged/dead holder
        and the caller degrades (miss / skipped publish), never blocks
        the fleet."""
        if self._lock.acquire(timeout=self.lock_timeout_s):
            return True
        self.lock_timeouts += 1
        return False

    def _row_of(self, slot: np.ndarray) -> Optional[np.ndarray]:
        """Validated row copy, or None (torn/corrupt payload is dropped
        so later probes stop paying the crc check)."""
        payload = slot[1:1 + _DIGEST + self.row_bytes]
        if not np.array_equal(slot[1 + _DIGEST + self.row_bytes:],
                              _crc32(payload)):
            slot[0] = 0
            self.torn_drops += 1
            return None
        return slot[1 + _DIGEST:1 + _DIGEST + self.row_bytes] \
            .copy().view(np.float32)

    def get(self, key: str) -> Optional[np.ndarray]:
        dig = np.frombuffer(_digest(key), np.uint8)
        if not self._acquire():
            return None
        try:
            view = self._view()
            for s in self._slots_for(dig.tobytes()):
                slot = view[s]
                if slot[0] and np.array_equal(slot[1:1 + _DIGEST], dig):
                    return self._row_of(slot)
        finally:
            self._lock.release()
        return None

    def get_many(self, keys: Sequence[str]
                 ) -> List[Optional[np.ndarray]]:
        digs = [np.frombuffer(_digest(k), np.uint8) for k in keys]
        out: List[Optional[np.ndarray]] = [None] * len(keys)
        if not self._acquire():
            return out
        try:
            view = self._view()
            for i, dig in enumerate(digs):
                for s in self._slots_for(dig.tobytes()):
                    slot = view[s]
                    if slot[0] and np.array_equal(
                            slot[1:1 + _DIGEST], dig):
                        out[i] = self._row_of(slot)
                        break
        finally:
            self._lock.release()
        return out

    def put(self, key: str, row: np.ndarray) -> None:
        self.put_many([(key, row)])

    def put_many(self, items: Sequence[Tuple[str, np.ndarray]]) -> None:
        packed = []
        for key, row in items:
            dig = _digest(key)
            row8 = np.ascontiguousarray(
                np.asarray(row, np.float32)).view(np.uint8)
            packed.append((dig, np.frombuffer(dig, np.uint8), row8))
        if not self._acquire():
            return                               # skipped publish
        try:
            view = self._view()
            for dig, dig8, row8 in packed:
                slots = self._slots_for(dig)
                target = None
                for s in slots:
                    slot = view[s]
                    if not slot[0]:          # first empty slot
                        if target is None:
                            target = s
                        continue
                    if np.array_equal(slot[1:1 + _DIGEST], dig8):
                        target = s           # refresh in place
                        break
                if target is None:           # probe window full: evict a
                    target = slots[dig[8] % self.PROBES]   # stable victim
                slot = view[target]
                # write-in-progress marker first: a writer dying inside
                # this block leaves valid=0, not a half-written "hit"
                slot[0] = 0
                slot[1:1 + _DIGEST] = dig8
                slot[1 + _DIGEST:1 + _DIGEST + self.row_bytes] = row8
                slot[1 + _DIGEST + self.row_bytes:] = _crc32(
                    slot[1:1 + _DIGEST + self.row_bytes])
                slot[0] = 1
        finally:
            self._lock.release()

    def fill(self) -> int:
        """Occupied slot count (diagnostics; takes the lock).
        Returns -1 when the lock holder is wedged."""
        if not self._acquire():
            return -1
        try:
            return int(self._view()[:, 0].sum())
        finally:
            self._lock.release()

    def clear(self) -> bool:
        """Invalidate every slot (bench cold-pass reset)."""
        if not self._acquire():
            return False
        try:
            self._view()[:, 0] = 0
            return True
        finally:
            self._lock.release()

    def recover(self, timeout_s: Optional[float] = None) -> bool:
        """Force-release a lock orphaned by a dead holder.

        Call *only* after the suspect process is confirmed dead (the
        supervisor does, post-SIGKILL). If the lock is free or a live
        holder releases it within ``timeout_s`` nothing is done; an
        acquire timeout then means no live holder exists and the
        semaphore is posted back. Returns True when a recovery
        happened."""
        t = self.lock_timeout_s if timeout_s is None else float(timeout_s)
        if self._lock.acquire(timeout=t):
            self._lock.release()
            return False
        try:
            self._lock.release()
        except ValueError:                       # raced: already free
            return False
        return True

    def stats(self) -> dict:
        """Per-process degradation counters + occupancy."""
        return {"lock_timeouts": self.lock_timeouts,
                "torn_drops": self.torn_drops,
                "fill": self.fill()}
