"""Shared cross-replica prediction cache (lock-guarded shared memory).

Struct-key routing keeps each replica's *own* LRU hot, but a key's first
query still misses everywhere — and after a reroute (replica death,
overload cooldown) the fallback replica starts cold for that key's
neighborhood. This tier is the fleet's second-chance cache: a fixed-slot
open-addressed hash table in a ``multiprocessing`` shared byte array,
consulted by every replica on local-LRU miss and published to after
every computed batch. Writes are tiny (one 20-byte digest + the
``n_heads`` float32 row), so a single cross-process mutex is plenty at
cost-model scale.

Slot layout (fixed ``n_heads``):

  [1B valid][20B sha1 digest of the struct key][n_heads * 4B f32 row]

Collisions overwrite (cache semantics); two *different* keys sharing a
full 160-bit digest is out of scope. The table is picklable into
spawned children (the shared block and lock travel through
``multiprocessing``'s inheritance machinery), so one instance built by
the parent serves every replica and client process.
"""
from __future__ import annotations

import hashlib
import multiprocessing as mp
from typing import List, Optional, Sequence, Tuple

import numpy as np

_DIGEST = 20                     # sha1


def _digest(key: str) -> bytes:
    """20-byte digest of a struct key. Graph.struct_key is already a
    sha1 hexdigest, so the common case is a cheap unhex."""
    if len(key) == 2 * _DIGEST:
        try:
            return bytes.fromhex(key)
        except ValueError:
            pass
    return hashlib.sha1(key.encode()).digest()


class SharedRowCache:
    """Fixed-capacity shared-memory map: struct key -> (n_heads,) f32."""

    PROBES = 8

    def __init__(self, n_heads: int, n_slots: int = 16384,
                 ctx: Optional[mp.context.BaseContext] = None):
        ctx = ctx or mp.get_context("spawn")
        self.n_heads = int(n_heads)
        self.n_slots = int(n_slots)
        self.slot_bytes = 1 + _DIGEST + 4 * self.n_heads
        self._buf = ctx.RawArray("B", self.n_slots * self.slot_bytes)
        self._lock = ctx.Lock()

    # NOTE: np.frombuffer views are rebuilt per call — the object must
    # stay picklable (views of shared ctypes are not).
    def _view(self) -> np.ndarray:
        return np.frombuffer(self._buf, np.uint8).reshape(
            self.n_slots, self.slot_bytes)

    def _slots_for(self, dig: bytes) -> List[int]:
        h = int.from_bytes(dig[:8], "little")
        return [(h + i) % self.n_slots for i in range(self.PROBES)]

    def get(self, key: str) -> Optional[np.ndarray]:
        dig = np.frombuffer(_digest(key), np.uint8)
        with self._lock:
            view = self._view()
            for s in self._slots_for(dig.tobytes()):
                slot = view[s]
                if slot[0] and np.array_equal(slot[1:1 + _DIGEST], dig):
                    return slot[1 + _DIGEST:].copy().view(np.float32)
        return None

    def get_many(self, keys: Sequence[str]
                 ) -> List[Optional[np.ndarray]]:
        digs = [np.frombuffer(_digest(k), np.uint8) for k in keys]
        out: List[Optional[np.ndarray]] = [None] * len(keys)
        with self._lock:
            view = self._view()
            for i, dig in enumerate(digs):
                for s in self._slots_for(dig.tobytes()):
                    slot = view[s]
                    if slot[0] and np.array_equal(
                            slot[1:1 + _DIGEST], dig):
                        out[i] = slot[1 + _DIGEST:].copy().view(np.float32)
                        break
        return out

    def put(self, key: str, row: np.ndarray) -> None:
        self.put_many([(key, row)])

    def put_many(self, items: Sequence[Tuple[str, np.ndarray]]) -> None:
        packed = []
        for key, row in items:
            dig = _digest(key)
            row8 = np.ascontiguousarray(
                np.asarray(row, np.float32)).view(np.uint8)
            packed.append((dig, np.frombuffer(dig, np.uint8), row8))
        with self._lock:
            view = self._view()
            for dig, dig8, row8 in packed:
                slots = self._slots_for(dig)
                target = None
                for s in slots:
                    slot = view[s]
                    if not slot[0]:          # first empty slot
                        if target is None:
                            target = s
                        continue
                    if np.array_equal(slot[1:1 + _DIGEST], dig8):
                        target = s           # refresh in place
                        break
                if target is None:           # probe window full: evict a
                    target = slots[dig[8] % self.PROBES]   # stable victim
                slot = view[target]
                slot[0] = 1
                slot[1:1 + _DIGEST] = dig8
                slot[1 + _DIGEST:] = row8

    def fill(self) -> int:
        """Occupied slot count (diagnostics; takes the lock)."""
        with self._lock:
            return int(self._view()[:, 0].sum())

    def clear(self) -> None:
        """Invalidate every slot (bench cold-pass reset)."""
        with self._lock:
            self._view()[:, 0] = 0
