"""Replica process: one CostModelService + async server per process.

Each replica is a spawned worker (JAX is never forked) that rebuilds the
model from a :class:`~repro.serving.transport.ServiceSpec` and serves
ids-first request batches through its own
:class:`~repro.core.server.CostModelServer` — so every replica owns its
params, its AOT warmup, its LRU and in-flight dedup, and an *adaptive*
flush deadline that tracks its observed arrival rate. On a local-LRU
miss the replica consults the shared cross-replica cache tier before
computing, and publishes every computed row back to it.

Request batches resolve through the server's futures; one combined
response message per inbound batch goes back on the requesting client's
queue once the whole batch lands (split into per-outcome messages only
when some entries shed). Replies never re-serialize graphs — rows pack
as one float32 block.
"""
from __future__ import annotations

import multiprocessing as mp
import os
import threading
import traceback
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

from repro.serving import transport as T
from repro.serving.shared_cache import SharedRowCache


@dataclass
class ReplicaTier:
    """Parent-side handle on the spawned replica fleet.

    ``client_handle(i)`` returns the picklable bundle a client (in this
    or any spawned process) needs to talk to the tier. The tier also
    retains everything :func:`replica_main` needs (spawn context, server
    kwargs, the readiness queue) so a dead or wedged replica can be
    respawned *into the same slot* — same inbox, same ring identity —
    by :class:`~repro.serving.supervisor.ReplicaSupervisor`."""

    procs: List[Optional[mp.Process]]  # slot i <-> ring identity i
    inboxes: List[Any]                 # one request queue per replica
    client_queues: List[Any]           # one response queue per client id
    #                                    (+ one trailing control queue)
    shared_cache: SharedRowCache
    spec: T.ServiceSpec
    active: Any = None                 # ctx.Value("i"): routed count
    ctx: Any = None
    server_kw: Optional[Dict[str, Any]] = None
    warmup: bool = True
    ready: Any = None                  # replicas report ("ready", id)

    @property
    def n_replicas(self) -> int:
        return len(self.procs)

    @property
    def max_replicas(self) -> int:
        return len(self.inboxes)

    @property
    def control_queue(self) -> Any:
        """The supervisor's response queue (reserved trailing slot)."""
        return self.client_queues[-1]

    @property
    def control_id(self) -> int:
        return len(self.client_queues) - 1

    def client_handle(self, client_id: int) -> "TierHandle":
        return TierHandle(client_id=client_id, inboxes=self.inboxes,
                          resp_queue=self.client_queues[client_id],
                          n_replicas=len(self.inboxes), spec=self.spec,
                          active=self.active)

    def alive(self) -> List[bool]:
        return [p is not None and p.is_alive() for p in self.procs]

    def reset_inbox(self, i: int) -> None:
        """Give slot ``i`` a fresh inbox pipe. A SIGKILLed replica dies
        holding the queue's reader lock (it waits in ``get()`` with it
        held) and can leave a half-read frame behind — the successor
        would wedge on the orphaned semaphore or desync on the torn
        stream. Replacing the queue sidesteps both: ``inboxes`` is the
        same list object inside every in-process client handle, so
        routers pick up the new pipe on their next send, and requests
        stranded in the old one are re-sent by the client's normal
        timeout/reroute path. (Clients in *other* processes hold a
        pickled copy and keep the stale queue: their traffic for this
        slot reroutes to the survivors, which is degraded but never
        wrong.)"""
        ctx = self.ctx or mp.get_context("spawn")
        self.inboxes[i] = ctx.Queue()

    def spawn(self, i: int) -> mp.Process:
        """(Re)spawn slot ``i`` from the stored spec; non-blocking (the
        child reports on :attr:`ready` once rebuilt + warmed). The slot
        reuses inbox ``i``, so consistent-hash ownership and the other
        replicas' LRU locality are undisturbed."""
        if not 0 <= i < len(self.inboxes):
            raise IndexError(f"replica slot {i} out of range")
        p = self.ctx.Process(
            target=replica_main,
            args=(i, self.spec, self.inboxes[i], self.client_queues,
                  self.shared_cache, self.server_kw, self.warmup,
                  self.ready),
            name=f"costmodel-replica-{i}", daemon=True)
        p.start()
        while len(self.procs) <= i:
            self.procs.append(None)
        self.procs[i] = p
        return p

    def stop(self, timeout: float = 10.0) -> None:
        for q in self.inboxes:
            try:
                q.put((T.MSG_STOP,))
            except Exception:
                pass
        live = [p for p in self.procs if p is not None]
        for p in live:
            p.join(timeout=timeout)
        for p in live:
            if p.is_alive():
                p.terminate()
                p.join(timeout=5.0)

    def __enter__(self) -> "ReplicaTier":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()


@dataclass
class TierHandle:
    """What one client needs: every replica's inbox, its own response
    queue, and the replica count (ring construction). Picklable into
    spawned fleet-client processes."""

    client_id: int
    inboxes: List[Any]
    resp_queue: Any
    n_replicas: int
    spec: Any = None
    active: Any = None          # shared routed-replica count (scaling)


def start_replicas(spec: T.ServiceSpec, n_replicas: int, *,
                   n_clients: int = 1, warmup: bool = True,
                   max_batch: Optional[int] = None,
                   flush_us: float = 500.0,
                   max_queue: int = 4096,
                   adaptive_flush: bool = True,
                   shared_slots: int = 16384,
                   start_timeout_s: float = 180.0,
                   obs_trace: bool = False,
                   max_replicas: Optional[int] = None) -> ReplicaTier:
    """Spawn ``n_replicas`` model-serving processes + the shared cache.

    Blocks until every replica reports ready (model rebuilt, programs
    warmed), so the first real request never pays child-process startup.
    ``n_clients`` response queues are created up front (plus one
    trailing control queue reserved for the supervisor's heartbeat RPC);
    client ids are assigned by the caller via
    :meth:`ReplicaTier.client_handle`. ``max_replicas`` pre-allocates
    extra inbox slots so the supervisor can scale the tier up later
    without re-plumbing existing clients."""
    ctx = mp.get_context("spawn")
    max_replicas = max(n_replicas, max_replicas or n_replicas)
    n_heads = len(spec.norm_stats) if isinstance(spec.norm_stats, dict) \
        and all(isinstance(v, dict) for v in spec.norm_stats.values()) \
        else 1
    shared = SharedRowCache(n_heads, n_slots=shared_slots, ctx=ctx)
    inboxes = [ctx.Queue() for _ in range(max_replicas)]
    client_queues = [ctx.Queue() for _ in range(n_clients + 1)]
    ready = ctx.Queue()
    server_kw = dict(max_batch=max_batch, flush_us=flush_us,
                     max_queue=max_queue, adaptive_flush=adaptive_flush,
                     obs_trace=obs_trace)
    tier = ReplicaTier(procs=[], inboxes=inboxes,
                       client_queues=client_queues, shared_cache=shared,
                       spec=spec, active=ctx.Value("i", n_replicas),
                       ctx=ctx, server_kw=server_kw, warmup=warmup,
                       ready=ready)
    for i in range(n_replicas):
        tier.spawn(i)
    for _ in range(n_replicas):
        try:
            msg = ready.get(timeout=start_timeout_s)
        except Exception:
            tier.stop()
            raise RuntimeError(
                f"replica tier failed to start within "
                f"{start_timeout_s:.0f}s") from None
        if msg[0] != "ready":
            tier.stop()
            raise RuntimeError(f"replica failed to start: {msg[1]}")
    return tier


def replica_main(replica_id: int, spec: T.ServiceSpec, inbox,
                 client_queues, shared: SharedRowCache,
                 server_kw: Dict[str, Any], warmup: bool,
                 ready) -> None:
    """Child entry point (module-level so spawn can import it)."""
    os.environ.setdefault("XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false")
    try:
        from repro.core.server import (CostModelServer,
                                       ServerOverloadedError)
        server_kw = dict(server_kw)
        tracer = None
        if server_kw.pop("obs_trace", False):
            # replica-side tracer: never head-samples on its own (the
            # client makes the head decision); it only honors contexts
            # arriving on the wire, so sample_every is effectively off
            from repro.obs.trace import TraceContext, Tracer
            tracer = Tracer(sample_every=1 << 30,
                            proc=f"replica-{replica_id}")
        svc = spec.build()
        server = CostModelServer(
            svc, tracer=tracer,
            **{k: v for k, v in server_kw.items() if v is not None})
        server.start(warmup=warmup)
    except Exception as e:                       # startup failure: report
        ready.put(("error", f"{e!r}\n{traceback.format_exc()}"))
        return
    ready.put(("ready", replica_id))

    shared_hits = 0
    shared_misses = 0
    send_lock = threading.Lock()                 # callbacks run in the
    #                                              server worker thread

    def _send(client: int, msg) -> None:
        with send_lock:
            client_queues[client].put(msg)

    def _handle_batch(client: int, batch_id: int, keys, lens_b, ids_b,
                      trace=None):
        nonlocal shared_hits, shared_misses
        entries = T.unpack_entries(keys, lens_b, ids_b)
        rids = list(range(len(entries)))
        rows: List[Optional[Any]] = [None] * len(entries)
        shed: List[int] = []
        retry_after = 0.0
        # n starts at 1: the submission loop itself holds a ref so a
        # fast callback can't finalize the batch mid-loop.
        pend = {"n": 1, "done": False}
        pend_lock = threading.Lock()
        computed: List = []                      # -> shared tier
        batch_span = None
        if tracer is not None and trace is not None:
            batch_span = tracer.start(
                "replica.batch", TraceContext.from_wire(trace),
                tags={"replica": replica_id, "n_entries": len(entries)})
        sub_ctx = batch_span.ctx if batch_span is not None else None

        def _finish_if_complete():
            with pend_lock:
                if pend["n"] != 0 or pend["done"]:
                    return
                pend["done"] = True
            if computed:
                shared.put_many(computed)
            ok = [i for i in rids if rows[i] is not None]
            spans = None
            if batch_span is not None:
                # the batch span + every child this trace produced in
                # this process ship back with the response; by the time
                # a future callback lands here the server worker has
                # already emitted its queue/forward spans (it resolves
                # futures only after recording them)
                tracer.end(batch_span,
                           status="overload" if shed else "ok",
                           n_ok=len(ok), n_shed=len(shed))
                if ok:
                    spans = tracer.recorder.take([batch_span.trace_id])
            if ok:
                res = (T.MSG_RES, batch_id, ok,
                       *T.pack_rows([rows[i] for i in ok]))
                if spans:
                    res = res + (spans,)
                _send(client, res)
            if shed:
                _send(client, (T.MSG_OVERLOAD, batch_id, shed,
                               retry_after))

        for i, (key, ids) in enumerate(entries):
            hit = svc.cache_lookup(key)
            if hit is not None:
                if sub_ctx is not None:
                    tracer.emit("replica.cache_hit", sub_ctx, 0.0,
                                tags={"tier": "local"})
                rows[i] = hit
                continue
            srow = shared.get(key)               # cross-replica tier
            if srow is not None:
                shared_hits += 1
                if sub_ctx is not None:
                    tracer.emit("replica.cache_hit", sub_ctx, 0.0,
                                tags={"tier": "shared"})
                svc.import_cache([(key, srow)])
                rows[i] = srow
                continue
            shared_misses += 1
            try:
                fut = server.submit_entry(key, ids, probe=False,
                                          trace=sub_ctx)
            except ServerOverloadedError as e:
                shed.append(i)
                retry_after = max(retry_after, e.retry_after_s)
                continue
            with pend_lock:
                pend["n"] += 1

            def _on_done(f, i=i, key=key):
                try:
                    row = f.result()
                    rows[i] = row
                    computed.append((key, row))
                except Exception:
                    pass                         # row stays None -> err
                with pend_lock:
                    pend["n"] -= 1
                _finish_if_complete()

            fut.add_done_callback(_on_done)
        with pend_lock:
            pend["n"] -= 1                       # release the loop's ref
        _finish_if_complete()

    while True:
        msg = inbox.get()
        tag = msg[0]
        if tag == T.MSG_STOP:
            break
        if tag == T.MSG_REQ:
            # length-tolerant: traced requests carry an optional 7th
            # element (see transport docstring); classic 6-tuples are
            # untraced
            _, client, batch_id, keys, lens_b, ids_b = msg[:6]
            try:
                _handle_batch(client, batch_id, keys, lens_b, ids_b,
                              trace=T.req_trace(msg))
            except Exception as e:               # never kill the replica
                _send(client, (T.MSG_ERR, batch_id,
                               list(range(len(keys))), repr(e)))
        elif tag == T.MSG_STATS:
            _, client, rid = msg
            m = server.metrics_snapshot()
            payload = {"replica_id": replica_id,
                       "server": m,
                       "cache": svc.cache_stats(),
                       "shared_hits": shared_hits,
                       "shared_misses": shared_misses,
                       "shared_lock_timeouts": shared.lock_timeouts,
                       "shared_torn_drops": shared.torn_drops}
            if tracer is not None:
                payload["obs"] = {
                    "spans_buffered": len(tracer.recorder),
                    "spans_dropped": tracer.recorder.dropped}
            _send(client, (T.MSG_STATS_RES, rid, payload))
        elif tag == T.MSG_CLEAR:
            _, client, rid = msg
            with svc._cache_lock:
                svc._cache.clear()
                svc._ids_cache.clear()
            _send(client, (T.MSG_STATS_RES, rid, {"cleared": True}))
    server.stop()
