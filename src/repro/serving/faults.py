"""Deterministic fault injection for the replicated serving tier.

Chaos testing only earns trust when a failing run can be replayed, so
every fault here is *scripted*, not sampled: a :class:`FaultPlan` is an
ordered schedule of :class:`FaultEvent`\\ s that fire when the
transport's send-op counter reaches each event's ``at`` — the clock is
the workload itself, which makes a single-driver schedule reproducible
across runs and machines. The only randomness (corruption bytes) comes
from the plan's seeded RNG.

Faults land at the three seams a real fleet fails at:

* **process** — ``kill`` (SIGKILL, a crashed replica) and ``wedge``
  (SIGSTOP: the process stays ``is_alive()`` but stops serving — the
  exact failure the supervisor's heartbeat exists to catch).
* **message** — ``drop`` / ``delay`` / ``dup`` applied to the next
  request(s) bound for a replica, via :class:`FaultyTransport`, a
  drop-in wrapper over any router transport.
* **shared state** — ``corrupt`` scribbles seeded garbage over a
  occupied :class:`~repro.serving.shared_cache.SharedRowCache` slot's
  row bytes while leaving it marked valid; the cache's crc check must
  turn that into a miss, never a wrong prediction.

``FaultyTransport`` records every applied event in ``log`` so the
chaos bench can assert the schedule actually ran.
"""
from __future__ import annotations

import os
import random
import signal
import threading
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np

from repro.serving import transport as T
from repro.serving.shared_cache import _CRC, _DIGEST, SharedRowCache


@dataclass(frozen=True)
class FaultEvent:
    """One scripted fault.

    ``at``      send-op count that triggers it (0-based: fires before
                the ``at``-th send is delivered)
    ``kind``    kill | wedge | unwedge | drop | delay | dup | corrupt
    ``replica`` target replica slot (process + message kinds)
    ``count``   how many subsequent sends the fault covers (drop/delay)
    ``delay_s`` added latency for ``delay``
    ``key``     struct key whose slot to corrupt (``corrupt``)
    """

    at: int
    kind: str
    replica: int = 0
    count: int = 1
    delay_s: float = 0.0
    key: str = ""


@dataclass
class FaultPlan:
    """Seeded, ordered fault schedule (sorted by ``at``)."""

    events: List[FaultEvent] = field(default_factory=list)
    seed: int = 0

    def __post_init__(self):
        self.events = sorted(self.events, key=lambda e: e.at)
        self.rng = random.Random(self.seed)
        self._next = 0

    def due(self, op: int) -> List[FaultEvent]:
        """Events whose trigger point has been reached (each returned
        exactly once)."""
        out = []
        while self._next < len(self.events) and \
                self.events[self._next].at <= op:
            out.append(self.events[self._next])
            self._next += 1
        return out

    @property
    def exhausted(self) -> bool:
        return self._next >= len(self.events)


def corrupt_slot(cache: SharedRowCache, key: str,
                 rng: Optional[random.Random] = None) -> bool:
    """Overwrite ``key``'s row bytes with garbage while keeping the slot
    valid (a torn write frozen mid-flight). Returns False when the key
    isn't resident. The crc trailer is deliberately left stale — a
    subsequent probe must detect the tear and miss."""
    from repro.serving import shared_cache as SC
    rng = rng or random.Random(0)
    dig = SC._digest(key)
    junk = bytes(rng.randrange(256) for _ in range(cache.row_bytes))
    if not cache._acquire():
        return False
    try:
        view = cache._view()
        dig8 = np.frombuffer(dig, np.uint8)
        for s in cache._slots_for(dig):
            slot = view[s]
            if slot[0] and np.array_equal(slot[1:1 + _DIGEST], dig8):
                slot[1 + _DIGEST:cache.slot_bytes - _CRC] = \
                    np.frombuffer(junk, np.uint8)
                return True
    finally:
        cache._lock.release()
    return False


class FaultyTransport:
    """Transport wrapper that applies a :class:`FaultPlan`.

    Duck-types the router transport (``n_replicas`` / ``send`` /
    ``recv`` / ``client_id``); process faults need ``tier`` and slot
    corruption needs ``shared_cache`` (both optional — message faults
    work against any inner transport, including test fakes)."""

    def __init__(self, inner, plan: FaultPlan, *, tier=None,
                 shared_cache: Optional[SharedRowCache] = None):
        self.inner = inner
        self.plan = plan
        self.tier = tier
        self.shared_cache = shared_cache \
            if shared_cache is not None \
            else getattr(tier, "shared_cache", None)
        self.client_id = getattr(inner, "client_id", 0)
        self.ops = 0
        self.log: List[Dict[str, Any]] = []
        self._drop: Dict[int, int] = {}       # replica -> sends to drop
        self._delay: Dict[int, List] = {}     # replica -> [count, s]
        self._dup: Dict[int, int] = {}
        self._lock = threading.Lock()

    @property
    def n_replicas(self) -> int:
        return self.inner.n_replicas

    @property
    def active(self):
        return getattr(self.inner, "active", None)

    # ----------------------------------------------------------- fire side
    def _signal(self, replica: int, sig) -> bool:
        procs = getattr(self.tier, "procs", None)
        if not procs or replica >= len(procs) or procs[replica] is None:
            return False
        pid = procs[replica].pid
        try:
            os.kill(pid, sig)
            return True
        except (ProcessLookupError, OSError):
            return False

    def _apply(self, ev: FaultEvent) -> None:
        ok = True
        if ev.kind == "kill":
            ok = self._signal(ev.replica, signal.SIGKILL)
        elif ev.kind == "wedge":
            ok = self._signal(ev.replica, signal.SIGSTOP)
        elif ev.kind == "unwedge":
            ok = self._signal(ev.replica, signal.SIGCONT)
        elif ev.kind == "drop":
            self._drop[ev.replica] = \
                self._drop.get(ev.replica, 0) + ev.count
        elif ev.kind == "delay":
            self._delay.setdefault(ev.replica, []).append(
                [ev.count, ev.delay_s])
        elif ev.kind == "dup":
            self._dup[ev.replica] = \
                self._dup.get(ev.replica, 0) + ev.count
        elif ev.kind == "corrupt":
            ok = self.shared_cache is not None and corrupt_slot(
                self.shared_cache, ev.key, self.plan.rng)
        else:
            ok = False
        self.log.append({"op": self.ops, "kind": ev.kind,
                         "replica": ev.replica, "applied": bool(ok),
                         "key": ev.key})

    # ------------------------------------------------------- transport duck
    def send(self, replica: int, msg) -> None:
        with self._lock:
            op = self.ops
            self.ops += 1
            for ev in self.plan.due(op):
                self._apply(ev)
            # message faults only touch request traffic; control RPCs
            # (stats/clear) stay reliable so supervision isn't blinded
            is_req = bool(msg) and msg[0] == T.MSG_REQ
            if is_req and self._drop.get(replica, 0) > 0:
                self._drop[replica] -= 1
                self.log.append({"op": op, "kind": "dropped",
                                 "replica": replica, "applied": True,
                                 "key": ""})
                return
            delay_s = 0.0
            dq = self._delay.get(replica)
            if is_req and dq:
                dq[0][0] -= 1
                delay_s = dq[0][1]
                if dq[0][0] <= 0:
                    dq.pop(0)
            dup = is_req and self._dup.get(replica, 0) > 0
            if dup:
                self._dup[replica] -= 1
        if delay_s > 0.0:
            t = threading.Timer(delay_s, self.inner.send,
                                args=(replica, msg))
            t.daemon = True
            t.start()
            self.log.append({"op": op, "kind": "delayed",
                             "replica": replica, "applied": True,
                             "key": ""})
            return
        self.inner.send(replica, msg)
        if dup:
            self.inner.send(replica, msg)
            self.log.append({"op": op, "kind": "duplicated",
                             "replica": replica, "applied": True,
                             "key": ""})

    def recv(self, timeout: float):
        return self.inner.recv(timeout)
