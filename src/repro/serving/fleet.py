"""Multi-process fleet-client harness for the replicated tier.

The search_fleet bench's thread workers convoy on the GIL: every
worker's python search loop (expand -> hash -> featurize) serializes
through one interpreter, so adding workers adds context-switch churn,
not throughput. This harness runs each fleet worker as its OWN spawned
process holding a persistent :class:`~repro.serving.router.ReplicaClient`
— the client-side featurizer, local LRU, and search loop all execute
GIL-free, and only cache *misses* cross a process boundary.

Workers are long-lived and command-driven (pass / clear / stats /
stop), so a bench can run warm, cold, and steady passes against the
same fleet without re-paying process spawn or JAX import, mirroring a
long-running compiler fleet.
"""
from __future__ import annotations

import multiprocessing as mp
import time
import traceback
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional


def fleet_worker_main(worker_id: int, handle, pool, client_kw,
                      search_kw, cmd_q, res_q) -> None:
    """Child entry point: one persistent client, command loop."""
    try:
        from repro.opt import search as OS
        from repro.serving.router import ReplicaClient
        client_kw = dict(client_kw or {})
        # obs passthrough: `obs_sample=N` (not a ReplicaClient kwarg)
        # gives this worker's client its own head-sampling tracer;
        # trace health then rides the normal stats reply
        obs_sample = int(client_kw.pop("obs_sample", 0) or 0)
        tracer = None
        if obs_sample:
            from repro.obs.trace import Tracer
            tracer = Tracer(sample_every=obs_sample,
                            proc=f"fleet-{worker_id}")
            client_kw["tracer"] = tracer
        client = ReplicaClient(handle, **client_kw)
    except Exception as e:
        res_q.put(("error", worker_id,
                   f"{e!r}\n{traceback.format_exc()}"))
        return
    res_q.put(("ready", worker_id))
    base_kw = dict(search_kw or {})
    while True:
        msg = cmd_q.get()
        tag = msg[0]
        if tag == "stop":
            break
        try:
            if tag == "pass":
                kw = dict(base_kw)
                kw.update(msg[1] or {})
                # rounds > 1 repeats the pool inside ONE timed pass so
                # short steady measurements amortize the driver's
                # broadcast/collect barrier instead of re-paying it
                rounds = int(kw.pop("rounds", 1))
                t0 = time.perf_counter()
                cands = 0
                for _ in range(rounds):
                    results = OS.search_pool(client, pool,
                                             offset=worker_id, **kw)
                    cands += sum(r.evaluated + 1 for r in results)
                dt = time.perf_counter() - t0
                res_q.put(("pass", worker_id, dt, cands))
            elif tag == "clear":
                # remote clear from worker 0 only — one broadcast per
                # fleet reset, not one per worker
                client.clear_caches(remote=(worker_id == 0))
                res_q.put(("clear", worker_id))
            elif tag == "stats":
                payload = client.stats()
                if tracer is not None:
                    from repro.obs.trace import assemble, completeness
                    recs = tracer.recorder.snapshot()
                    trees = assemble(recs)
                    payload["obs"] = {
                        "spans": len(recs), "traces": len(trees),
                        "complete_frac": completeness(trees)}
                if msg[1]:                   # include replica-side stats
                    payload["replicas"] = client.replica_stats()
                res_q.put(("stats", worker_id, payload))
            else:
                res_q.put(("error", worker_id, f"unknown cmd {tag!r}"))
        except Exception as e:               # keep the worker alive
            res_q.put(("error", worker_id,
                       f"{e!r}\n{traceback.format_exc()}"))


@dataclass
class FleetDriver:
    """Parent-side controller over N spawned fleet-worker processes."""

    procs: List[mp.Process]
    cmd_qs: List[Any]
    res_q: Any
    n_workers: int
    errors: List[str] = field(default_factory=list)

    @classmethod
    def start(cls, tier, pool, n_workers: int, *,
              client_kw: Optional[Dict[str, Any]] = None,
              search_kw: Optional[Dict[str, Any]] = None,
              start_timeout_s: float = 300.0) -> "FleetDriver":
        """Spawn workers bound to ``tier`` (which must have been started
        with ``n_clients >= n_workers``) and wait until every client has
        built its featurizer."""
        ctx = mp.get_context("spawn")
        cmd_qs = [ctx.Queue() for _ in range(n_workers)]
        res_q = ctx.Queue()
        procs = []
        for w in range(n_workers):
            p = ctx.Process(
                target=fleet_worker_main,
                args=(w, tier.client_handle(w), pool, client_kw,
                      search_kw, cmd_qs[w], res_q),
                name=f"fleet-worker-{w}", daemon=True)
            p.start()
            procs.append(p)
        drv = cls(procs=procs, cmd_qs=cmd_qs, res_q=res_q,
                  n_workers=n_workers)
        for _ in range(n_workers):
            msg = drv._get(start_timeout_s)
            if msg[0] != "ready":
                drv.stop()
                raise RuntimeError(f"fleet worker failed: {msg[2]}")
        return drv

    def _get(self, timeout_s: float):
        try:
            return self.res_q.get(timeout=timeout_s)
        except Exception:
            raise RuntimeError(
                f"fleet worker reply timed out after {timeout_s:.0f}s "
                f"(alive={[p.is_alive() for p in self.procs]})") from None

    def _collect(self, tag: str, timeout_s: float) -> List[Any]:
        out: List[Any] = []
        while len(out) < self.n_workers:
            msg = self._get(timeout_s)
            if msg[0] == tag:
                out.append(msg)
            elif msg[0] == "error":
                self.errors.append(msg[2])
                raise RuntimeError(
                    f"fleet worker {msg[1]} errored: {msg[2]}")
        return out

    def run_pass(self, timeout_s: float = 600.0,
                 **search_overrides) -> Dict[str, Any]:
        """Broadcast one search pass to every worker; returns driver
        wall time plus per-worker walls and total candidates costed.
        Pass ``rounds=K`` to repeat the pool K times per worker within
        the single timed pass (barrier paid once, not K times)."""
        t0 = time.perf_counter()
        for q in self.cmd_qs:
            q.put(("pass", search_overrides))
        msgs = self._collect("pass", timeout_s)
        wall = time.perf_counter() - t0
        return {"wall_s": wall,
                "candidates": sum(m[3] for m in msgs),
                "worker_wall_s": [m[2] for m in msgs]}

    def clear(self, timeout_s: float = 60.0) -> None:
        """Fleet-wide cache reset: every worker's local featurizer, the
        replica LRUs (broadcast once, from worker 0)."""
        for q in self.cmd_qs:
            q.put(("clear",))
        self._collect("clear", timeout_s)

    def stats(self, include_replicas: bool = False,
              timeout_s: float = 60.0) -> List[Dict[str, Any]]:
        """Per-worker client stats, ordered by worker id; worker 0 can
        also carry the replica-side snapshots."""
        for w, q in enumerate(self.cmd_qs):
            q.put(("stats", include_replicas and w == 0))
        msgs = self._collect("stats", timeout_s)
        return [m[2] for m in sorted(msgs, key=lambda m: m[1])]

    def stop(self, timeout: float = 10.0) -> None:
        for q in self.cmd_qs:
            try:
                q.put(("stop",))
            except Exception:
                pass
        for p in self.procs:
            p.join(timeout=timeout)
        for p in self.procs:
            if p.is_alive():
                p.terminate()
                p.join(timeout=5.0)

    def __enter__(self) -> "FleetDriver":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()
