"""Dependency-free request tracing for the serving stack.

One sampled request produces a *span tree* that crosses process
boundaries: the client's featurize/fetch spans, the router's per-replica
RPC spans, and the replica's queue-wait/forward spans all share one
``trace_id`` and parent onto each other by ``span_id`` — the ids (not
clocks) stitch the tree together, because ``time.perf_counter`` has a
different origin in every process. Each span therefore carries

* ``t_wall`` — a ``time.time()`` stamp taken once at start, comparable
  across processes on one host (display ordering only), and
* ``dur_s``  — a ``perf_counter`` delta (monotonic, NTP-safe), the
  number every latency aggregate is computed from.

Sampling is *head-based*: the decision is made once per request at the
client (default 1 in ``sample_every``, counter-driven so overhead is a
predictable modulo, not an RNG call) and the resulting
:class:`TraceContext` is what propagates — unsampled requests carry
``None`` everywhere and cost one ``is None`` check per hook. Errors and
sheds are always recorded: :meth:`Tracer.error_span` emits a span even
for unsampled requests, so failure telemetry never depends on the
sampling dice.

The API is deliberately tiny (the serving hot path is the caller):
``Tracer.span`` is a context manager for straight-line code;
``start``/``end`` are the explicit pair for async code (the server's
futures resolve in another thread); ``emit`` records an
already-measured span retroactively (the server worker learns a
request's queue wait only at dispatch time). Finished spans land in a
bounded ring-buffer :class:`TraceRecorder`; exporters drain it, the
replica wire path ``take``s spans per trace id to ship them back to
the client with the response.
"""
from __future__ import annotations

import itertools
import json
import os
import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

# Ids are a random per-process prefix plus an atomic counter — globally
# unique across the tier's processes without paying an os.urandom
# syscall per span (a traced 16-entry wire batch emits ~35 spans).
_ID_PREFIX = os.urandom(5).hex()
_ID_COUNTER = itertools.count(1)


def _new_id() -> str:
    return f"{_ID_PREFIX}{next(_ID_COUNTER):06x}"


class TraceContext:
    """What propagates: a trace id plus the current parent span id.

    Serializes to a plain ``(trace_id, span_id)`` tuple for the wire
    (picklable, no class dependency on the receiving side)."""

    __slots__ = ("trace_id", "span_id")

    def __init__(self, trace_id: str, span_id: str = ""):
        self.trace_id = trace_id
        self.span_id = span_id

    def to_wire(self) -> Tuple[str, str]:
        return (self.trace_id, self.span_id)

    @classmethod
    def from_wire(cls, wire) -> Optional["TraceContext"]:
        if not wire:
            return None
        return cls(str(wire[0]), str(wire[1]))

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"TraceContext({self.trace_id}, parent={self.span_id!r})"


class Span:
    """One timed operation. ``end()`` is idempotent; tags are free-form
    (numbers/strings) and travel into the JSONL record."""

    __slots__ = ("trace_id", "span_id", "parent_id", "name", "proc",
                 "t_wall", "dur_s", "status", "tags", "_t0")

    def __init__(self, trace_id: str, name: str, *, proc: str = "main",
                 parent_id: str = "", tags: Optional[Dict] = None):
        self.trace_id = trace_id
        self.span_id = _new_id()
        self.parent_id = parent_id
        self.name = name
        self.proc = proc
        self.t_wall = time.time()
        self._t0 = time.perf_counter()
        self.dur_s: float = 0.0
        self.status = "ok"
        self.tags: Dict[str, Any] = dict(tags) if tags else {}

    @property
    def ctx(self) -> TraceContext:
        """Context for children of this span (in-process or wire)."""
        return TraceContext(self.trace_id, self.span_id)

    def close(self, status: Optional[str] = None) -> "Span":
        if self._t0 is not None:
            self.dur_s = time.perf_counter() - self._t0
            self._t0 = None
        if status is not None:
            self.status = status
        return self

    def to_record(self) -> Dict[str, Any]:
        return {"trace": self.trace_id, "span": self.span_id,
                "parent": self.parent_id, "name": self.name,
                "proc": self.proc, "t_wall": self.t_wall,
                "dur_s": self.dur_s, "status": self.status,
                "tags": self.tags}


class TraceRecorder:
    """Bounded ring buffer of finished span *records* (plain dicts —
    picklable, JSONL-ready). Thread-safe; oldest spans fall off when
    ``capacity`` is exceeded, so a long-running server cannot grow
    memory on unread telemetry."""

    def __init__(self, capacity: int = 8192):
        self._lock = threading.Lock()
        self._spans: deque = deque(maxlen=int(capacity))
        self.dropped = 0

    def record(self, span: Span) -> None:
        self.record_raw(span.to_record())

    def record_raw(self, rec: Dict[str, Any]) -> None:
        with self._lock:
            if len(self._spans) == self._spans.maxlen:
                self.dropped += 1
            self._spans.append(rec)

    def extend(self, recs: Iterable[Dict[str, Any]]) -> None:
        """Import span records produced in another process (the replica
        ships its spans back inside the response message)."""
        for rec in recs:
            self.record_raw(rec)

    def __len__(self) -> int:
        with self._lock:
            return len(self._spans)

    def snapshot(self) -> List[Dict[str, Any]]:
        with self._lock:
            return list(self._spans)

    def drain(self) -> List[Dict[str, Any]]:
        """Remove and return everything (the exporter's per-tick pull)."""
        with self._lock:
            out = list(self._spans)
            self._spans.clear()
        return out

    def take(self, trace_ids) -> List[Dict[str, Any]]:
        """Remove and return the spans of the given traces only — the
        replica-side handoff: spans for a finished wire batch ride the
        response, everything else stays buffered."""
        want = set(trace_ids)
        if not want:
            return []
        keep: List[Dict[str, Any]] = []
        out: List[Dict[str, Any]] = []
        with self._lock:
            for rec in self._spans:
                (out if rec["trace"] in want else keep).append(rec)
            self._spans.clear()
            self._spans.extend(keep)
        return out


class Tracer:
    """Sampling front door + span factory for one process.

    ``sample()`` makes the head-based decision (1 in ``sample_every``
    requests, counter-driven); every other method takes the resulting
    context and is a no-op when it is ``None`` — except
    :meth:`error_span`, which records unconditionally (errors/sheds are
    always-on telemetry)."""

    def __init__(self, *, sample_every: int = 64, proc: str = "main",
                 recorder: Optional[TraceRecorder] = None,
                 capacity: int = 8192):
        self.sample_every = max(1, int(sample_every))
        self.proc = proc
        self.recorder = recorder or TraceRecorder(capacity)
        self._n = 0
        self._lock = threading.Lock()

    # ------------------------------------------------------------ sampling
    def sample(self, force: bool = False) -> Optional[TraceContext]:
        """Head decision for a new request: a fresh root context, or
        ``None`` (the request goes untraced)."""
        with self._lock:
            self._n += 1
            hit = force or (self._n % self.sample_every == 0)
        return TraceContext(_new_id()) if hit else None

    # ----------------------------------------------------------- span API
    def start(self, name: str, ctx: Optional[TraceContext],
              tags: Optional[Dict] = None) -> Optional[Span]:
        """Explicit-start span (async code ends it itself via ``end``)."""
        if ctx is None:
            return None
        return Span(ctx.trace_id, name, proc=self.proc,
                    parent_id=ctx.span_id, tags=tags)

    def end(self, span: Optional[Span], status: Optional[str] = None,
            **tags) -> None:
        if span is None:
            return
        if tags:
            span.tags.update(tags)
        self.recorder.record(span.close(status))

    @contextmanager
    def span(self, name: str, ctx: Optional[TraceContext],
             tags: Optional[Dict] = None):
        """Context manager for straight-line code; yields the Span (or
        None when untraced) so callers can add tags / derive child
        contexts. Exceptions mark the span ``err`` and re-raise."""
        sp = self.start(name, ctx, tags)
        try:
            yield sp
        except BaseException:
            self.end(sp, status="err")
            raise
        self.end(sp)

    def emit(self, name: str, ctx: Optional[TraceContext], dur_s: float,
             *, t_wall: Optional[float] = None, status: str = "ok",
             tags: Optional[Dict] = None) -> None:
        """Record a span whose duration was measured elsewhere (the
        server worker learns queue wait / forward wall retroactively)."""
        if ctx is None:
            return
        sp = Span(ctx.trace_id, name, proc=self.proc,
                  parent_id=ctx.span_id, tags=tags)
        sp._t0 = None
        sp.dur_s = float(dur_s)
        if t_wall is not None:
            sp.t_wall = float(t_wall)
        sp.status = status
        self.recorder.record(sp)

    def error_span(self, name: str, ctx: Optional[TraceContext] = None,
                   **tags) -> TraceContext:
        """Always-on failure telemetry: records even when the request
        was not head-sampled (a forced one-span trace is synthesized,
        tagged ``forced``). Returns the context it recorded under."""
        if ctx is None:
            ctx = TraceContext(_new_id())
            tags["forced"] = 1
        self.emit(name, ctx, 0.0, status="err", tags=tags)
        return ctx


# --------------------------------------------------------- tree assembly
class TraceTree:
    """One trace's spans, indexed for tree walks."""

    def __init__(self, trace_id: str, spans: List[Dict[str, Any]]):
        self.trace_id = trace_id
        self.spans = spans
        by_id = {s["span"]: s for s in spans}
        self.roots = [s for s in spans if not s["parent"]]
        self.orphans = [s for s in spans
                        if s["parent"] and s["parent"] not in by_id]
        self.children: Dict[str, List[Dict[str, Any]]] = {}
        for s in spans:
            if s["parent"] in by_id:
                self.children.setdefault(s["parent"], []).append(s)
        for kids in self.children.values():
            kids.sort(key=lambda s: s["t_wall"])

    @property
    def complete(self) -> bool:
        """Exactly one root and every parent id resolves — the span
        tree reconstructed end to end with no orphan spans."""
        return len(self.roots) == 1 and not self.orphans

    @property
    def procs(self) -> List[str]:
        return sorted({s["proc"] for s in self.spans})

    @property
    def dur_s(self) -> float:
        return self.roots[0]["dur_s"] if self.roots else \
            max((s["dur_s"] for s in self.spans), default=0.0)

    def walk(self):
        """Yield ``(depth, span)`` in tree order from each root."""
        def rec(span, depth):
            yield depth, span
            for kid in self.children.get(span["span"], []):
                yield from rec(kid, depth + 1)
        for root in sorted(self.roots, key=lambda s: s["t_wall"]):
            yield from rec(root, 0)


def assemble(records: Sequence[Dict[str, Any]]) -> Dict[str, TraceTree]:
    """Group span records into per-trace trees (input order preserved
    within a trace; metrics records and junk without a trace id are
    ignored)."""
    by_trace: Dict[str, List[Dict[str, Any]]] = {}
    for rec in records:
        tid = rec.get("trace")
        if tid and "span" in rec:
            by_trace.setdefault(tid, []).append(rec)
    return {tid: TraceTree(tid, spans)
            for tid, spans in by_trace.items()}


def completeness(trees: Dict[str, TraceTree]) -> float:
    """Fraction of traces whose span tree reconstructs completely."""
    if not trees:
        return 0.0
    return sum(t.complete for t in trees.values()) / len(trees)


def dump_jsonl(records: Sequence[Dict[str, Any]], path: str) -> int:
    """Append span records to a JSONL file (one ``kind: span`` line
    each) — the offline sibling of the live JsonlExporter."""
    with open(path, "a", encoding="utf-8") as f:
        for rec in records:
            f.write(json.dumps({"kind": "span", **rec}) + "\n")
    return len(records)
