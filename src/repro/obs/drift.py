"""Accuracy sentinel: online drift monitoring against the oracle.

A deployed cost model is only trustworthy while its *ranking* of
programs still tracks the ground truth — train-time eval says nothing
about the traffic it actually serves six hours in. The
:class:`DriftMonitor` closes that gap on the production side:

* ``observe_batch`` samples served ``(graph, prediction)`` pairs off
  the hot path (counter-based, default 1 in ``sample_every``). The
  sampling counter is deliberately *racy* — under concurrent callers
  it may pick slightly more or fewer items, which is fine for a
  sampler — so the common unsampled call costs one modulo and zero
  lock acquisitions; only an actual pick takes the queue lock;
* a background thread scores each sampled graph with the analyzer
  oracle (:func:`repro.ir.analyzers.analyze` by default — the same
  ground truth the opt benches judge against) and feeds rolling
  per-target windows. Scoring is pure Python, so the thread paces
  itself (``score_interval_s`` between oracle calls) to keep the GIL
  available to the serving threads it shares the process with;
  ``flush()`` / ``stop(drain=True)`` drain the queue unpaced;
* ``gauges()`` exposes per-target Spearman + MAE over the window, the
  sample/score/drop counters, and the front door's ``oov_rate`` /
  ``unk_fraction`` EWMAs with **hysteresis alarms** (an alarm arms
  above ``hi`` and only disarms below ``lo``, so a rate oscillating
  around one threshold cannot flap the flywheel's drift gate).

Every gauge key is always present — a registry snapshot taken before
any traffic still carries ``spearman.<target>`` (0.0) and ``oov_rate``
— so downstream consumers (the hot-swap gate, dashboards) never need
existence checks.

The monitor attaches to a service as ``svc.drift``; the serving tiers
call the hooks through ``getattr``, so :mod:`repro.core` keeps zero
import-time dependency on this package.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence


class Alarm:
    """Two-threshold hysteresis: arms at ``>= hi``, disarms at
    ``<= lo`` — never flaps in the band between them."""

    __slots__ = ("hi", "lo", "armed")

    def __init__(self, hi: float, lo: float):
        if lo > hi:
            raise ValueError(f"alarm lo={lo} must be <= hi={hi}")
        self.hi = float(hi)
        self.lo = float(lo)
        self.armed = False

    def update(self, value: float) -> bool:
        if self.armed:
            if value <= self.lo:
                self.armed = False
        elif value >= self.hi:
            self.armed = True
        return self.armed


class DriftMonitor:
    """Samples served predictions, scores them against the oracle in
    the background, and serves rolling accuracy gauges."""

    def __init__(self, oracle: Optional[Callable[[Any], Dict[str, float]]]
                 = None, *, targets: Sequence[str] = (),
                 sample_every: int = 16, window: int = 256,
                 max_queue: int = 128, score_interval_s: float = 0.05,
                 oov_alarm: tuple = (0.25, 0.10),
                 unk_alarm: tuple = (0.25, 0.10),
                 ewma_alpha: float = 0.2):
        if oracle is None:
            from repro.ir.analyzers import analyze as oracle
        self.oracle = oracle
        self.targets = tuple(targets)
        self.sample_every = max(1, int(sample_every))
        self.window = int(window)
        self.max_queue = int(max_queue)
        self.score_interval_s = float(score_interval_s)
        self._n = 0
        self._lock = threading.Lock()
        self._queue: deque = deque()
        self._windows: Dict[str, deque] = {
            t: deque(maxlen=self.window) for t in self.targets}
        self.observed = 0
        self.scored = 0
        self.oracle_errors = 0
        self.queue_drops = 0
        self._oov_ewma: Optional[float] = None
        self._unk_ewma: Optional[float] = None
        self._alpha = float(ewma_alpha)
        self.oov_alarm = Alarm(*oov_alarm)
        self.unk_alarm = Alarm(*unk_alarm)
        self._stop = threading.Event()
        self._wake = threading.Condition(self._lock)
        self._thread: Optional[threading.Thread] = None

    # ----------------------------------------------------------- lifecycle
    def start(self) -> "DriftMonitor":
        if self._thread is None:
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._run, name="obs-drift-monitor", daemon=True)
            self._thread.start()
        return self

    def stop(self, drain: bool = True) -> None:
        """Stop the scorer; by default score whatever is still queued
        first, so short runs (benches, tests) keep their samples."""
        if drain:
            self.flush()
        self._stop.set()
        with self._wake:
            self._wake.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None

    def __enter__(self) -> "DriftMonitor":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # --------------------------------------------------------- hot path
    def observe_batch(self, graphs: Sequence[Any],
                      preds: Dict[str, Any]) -> None:
        """Hook for ``predict_all``-shaped results: ``preds`` maps
        target -> (N,) denormalized array aligned with ``graphs``.

        Lock-free until a pick: the counter update is racy on purpose
        (concurrent callers may shift which requests get sampled — a
        sampler tolerates that), so the 1-in-``sample_every`` common
        case never contends with other serving threads."""
        n = len(graphs)
        if n == 0:
            return
        k = self.sample_every
        base = self._n
        self._n = base + n
        picks: List[int] = [i for i in range(n)
                            if (base + i + 1) % k == 0]
        if not picks:
            return
        items = [(graphs[i], {t: float(preds[t][i]) for t in preds})
                 for i in picks]
        with self._lock:
            for item in items:
                if len(self._queue) >= self.max_queue:
                    self._queue.popleft()
                    self.queue_drops += 1
                self._queue.append(item)
                self.observed += 1
            self._wake.notify()

    def observe(self, graph: Any, preds: Dict[str, float]) -> None:
        self.observe_batch([graph],
                           {t: [v] for t, v in preds.items()})

    def note_text(self, oov_rate: float, unk_rate: float) -> None:
        """Front-door ingest hook: per-text OOV/unk rates feed the
        EWMAs the hysteresis alarms watch."""
        with self._lock:
            a = self._alpha
            self._oov_ewma = float(oov_rate) if self._oov_ewma is None \
                else (1 - a) * self._oov_ewma + a * float(oov_rate)
            self._unk_ewma = float(unk_rate) if self._unk_ewma is None \
                else (1 - a) * self._unk_ewma + a * float(unk_rate)
            self.oov_alarm.update(self._oov_ewma)
            self.unk_alarm.update(self._unk_ewma)

    # ------------------------------------------------------- background
    def _score_one(self, graph, preds: Dict[str, float]) -> None:
        try:
            truth = self.oracle(graph)
        except Exception:
            with self._lock:
                self.oracle_errors += 1
            return
        with self._lock:
            for t, p in preds.items():
                if t not in truth:
                    continue
                self._windows.setdefault(
                    t, deque(maxlen=self.window)).append(
                    (p, float(truth[t])))
            self.scored += 1

    def _run(self) -> None:
        while True:
            with self._wake:
                while not self._queue and not self._stop.is_set():
                    self._wake.wait(timeout=0.5)
                if self._stop.is_set() and not self._queue:
                    return
                item = self._queue.popleft() if self._queue else None
            if item is not None:
                self._score_one(*item)
                if self.score_interval_s > 0.0:
                    # pace the pure-Python oracle so the sentinel never
                    # monopolizes the GIL against the serving threads
                    self._stop.wait(self.score_interval_s)

    def flush(self, timeout_s: float = 10.0) -> None:
        """Synchronously score everything queued (bench/test barrier)."""
        import time as _time
        deadline = _time.monotonic() + timeout_s
        while _time.monotonic() < deadline:
            with self._lock:
                item = self._queue.popleft() if self._queue else None
            if item is None:
                return
            self._score_one(*item)

    # ------------------------------------------------------------ gauges
    def gauges(self) -> Dict[str, Any]:
        from repro.opt.evaluate import spearman
        with self._lock:
            windows = {t: list(w) for t, w in self._windows.items()}
            out: Dict[str, Any] = {
                "observed": self.observed,
                "scored": self.scored,
                "oracle_errors": self.oracle_errors,
                "queue_drops": self.queue_drops,
                "queued": len(self._queue),
                "oov_rate": self._oov_ewma or 0.0,
                "unk_fraction": self._unk_ewma or 0.0,
                "oov_alarm": int(self.oov_alarm.armed),
                "unk_alarm": int(self.unk_alarm.armed),
            }
        for t in set(self.targets) | set(windows):
            pairs = windows.get(t, [])
            if len(pairs) >= 2:
                p = [a for a, _ in pairs]
                o = [b for _, b in pairs]
                rho = spearman(p, o)
                mae = sum(abs(a - b) for a, b in pairs) / len(pairs)
            else:
                rho, mae = 0.0, 0.0
            out[f"spearman.{t}"] = rho
            out[f"mae.{t}"] = mae
            out[f"window_n.{t}"] = len(pairs)
        return out


def attach(svc, monitor: DriftMonitor) -> DriftMonitor:
    """Bind a monitor to a service (or a router's featurizer): the
    serving tiers look for ``svc.drift`` via ``getattr``, so this is
    the only coupling point. Fills the monitor's target set from the
    service's heads when unset, and starts the scorer."""
    if not monitor.targets:
        monitor.targets = tuple(svc.heads)
        for t in monitor.targets:
            monitor._windows.setdefault(
                t, deque(maxlen=monitor.window))
    svc.drift = monitor
    return monitor.start()
