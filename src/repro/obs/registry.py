"""One typed metrics registry over the stack's fragmented telemetry.

Before this module, every tier spoke its own schema:
``ServerMetrics.snapshot()`` (gateway counters + latency percentiles),
``CostModelService.phase_stats()`` / ``cache_stats()`` (hot-path wall
split, ingest/OOV tallies, LRU rates), ``ReplicaClient.stats()``
(router health + shed counters), ``SharedRowCache.fill()`` (shared-tier
occupancy), and the drift monitor's gauges. The registry adapts them
all into one versioned snapshot::

    {"schema": "repro.obs/v1", "seq": N, "ts": ..., "metrics": {flat}}

where ``metrics`` is a flat ``component.metric`` -> number mapping —
the shape both the JSONL exporter and the Prometheus exposition
consume. Typed instruments (:class:`Counter`/:class:`Gauge`/
:class:`Histogram`) cover metrics that have no existing source;
*sources* (``add_source``) pull the existing snapshot dicts at
``snapshot()`` time, so adapting a tier costs one closure, not a
parallel set of counters to keep in sync. A failing source increments
``obs.source_errors`` instead of breaking the snapshot — telemetry
must never take the serving path down.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional

SCHEMA = "repro.obs/v1"


class Counter:
    __slots__ = ("_lock", "value")

    def __init__(self):
        self._lock = threading.Lock()
        self.value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self.value += n


class Gauge:
    __slots__ = ("_lock", "value")

    def __init__(self):
        self._lock = threading.Lock()
        self.value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self.value = float(v)


class Histogram:
    """Bounded reservoir; snapshots as count/mean/p50/p95/p99."""

    __slots__ = ("_lock", "_vals", "count", "total")

    def __init__(self, reservoir: int = 2048):
        self._lock = threading.Lock()
        self._vals: deque = deque(maxlen=int(reservoir))
        self.count = 0
        self.total = 0.0

    def observe(self, v: float) -> None:
        with self._lock:
            self._vals.append(float(v))
            self.count += 1
            self.total += float(v)

    def summary(self) -> Dict[str, float]:
        with self._lock:
            vals = sorted(self._vals)
            count, total = self.count, self.total
        out = {"count": float(count),
               "mean": total / count if count else 0.0}
        for name, q in (("p50", 0.50), ("p95", 0.95), ("p99", 0.99)):
            out[name] = vals[min(int(q * len(vals)), len(vals) - 1)] \
                if vals else 0.0
        return out


def _flatten(prefix: str, obj: Any, out: Dict[str, Any]) -> None:
    """Nested snapshot dicts -> flat dotted keys; numbers and bools
    only (strings and arbitrary objects are dropped — the snapshot is
    a metrics payload, not a log line)."""
    if isinstance(obj, dict):
        for k, v in obj.items():
            _flatten(f"{prefix}.{k}" if prefix else str(k), v, out)
    elif isinstance(obj, (list, tuple)):
        for i, v in enumerate(obj):
            _flatten(f"{prefix}.{i}", v, out)
    elif isinstance(obj, bool):
        out[prefix] = int(obj)
    elif isinstance(obj, (int, float)):
        out[prefix] = obj


class MetricsRegistry:
    """Get-or-create typed instruments + pull-through sources."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._hists: Dict[str, Histogram] = {}
        self._sources: List[tuple] = []          # (prefix, fn)
        self._seq = 0
        self.source_errors = 0

    # ------------------------------------------------------- instruments
    def counter(self, name: str) -> Counter:
        with self._lock:
            return self._counters.setdefault(name, Counter())

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            return self._gauges.setdefault(name, Gauge())

    def histogram(self, name: str, reservoir: int = 2048) -> Histogram:
        with self._lock:
            return self._hists.setdefault(name, Histogram(reservoir))

    def add_source(self, prefix: str,
                   fn: Callable[[], Dict[str, Any]]) -> None:
        """Register a snapshot-source callable; its dict is flattened
        under ``prefix.`` at every :meth:`snapshot`."""
        with self._lock:
            self._sources = [(p, f) for p, f in self._sources
                             if p != prefix] + [(prefix, fn)]

    # ---------------------------------------------------------- snapshot
    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            self._seq += 1
            seq = self._seq
            counters = list(self._counters.items())
            gauges = list(self._gauges.items())
            hists = list(self._hists.items())
            sources = list(self._sources)
        metrics: Dict[str, Any] = {}
        for name, c in counters:
            metrics[name] = c.value
        for name, g in gauges:
            metrics[name] = g.value
        for name, h in hists:
            for k, v in h.summary().items():
                metrics[f"{name}.{k}"] = v
        for prefix, fn in sources:
            try:
                _flatten(prefix, fn(), metrics)
            except Exception:
                self.source_errors += 1
        metrics["obs.source_errors"] = self.source_errors
        return {"schema": SCHEMA, "seq": seq, "ts": time.time(),
                "metrics": metrics}


# ------------------------------------------------------------- adapters
def register_server(reg: MetricsRegistry, server,
                    prefix: str = "server") -> None:
    """Adapt a CostModelServer: its ``metrics_snapshot()`` already
    merges the wrapped service's ``phase_*`` split and gauges."""
    reg.add_source(prefix, server.metrics_snapshot)


def register_service(reg: MetricsRegistry, svc,
                     prefix: str = "service") -> None:
    """Adapt a CostModelService (or a ReplicaClient's featurizer):
    phase split + ingest/OOV tallies + both LRU caches."""
    reg.add_source(
        prefix, lambda: {**svc.phase_stats(), "cache": svc.cache_stats()})


def register_router(reg: MetricsRegistry, client,
                    prefix: str = "router") -> None:
    """Adapt a ReplicaClient: shed count, local-cache rates, and the
    full per-replica health detail (consecutive_failures, remaining
    cooldown, per-kind failure counts)."""
    reg.add_source(prefix, client.stats)


def register_shared_cache(reg: MetricsRegistry, cache,
                          prefix: str = "shared_cache") -> None:
    reg.add_source(prefix, lambda: {"fill": cache.fill(),
                                    "n_slots": cache.n_slots,
                                    "lock_timeouts": cache.lock_timeouts,
                                    "torn_drops": cache.torn_drops})


def register_supervisor(reg: MetricsRegistry, sup,
                        prefix: str = "supervisor") -> None:
    """Adapt a ReplicaSupervisor: restart/recovery counters, crash-loop
    slots, scale events, heartbeat ages. The restart log itself is
    narrative (strings), so only its numeric fields survive flattening
    — the counters are the gated surface."""
    def _stats():
        s = sup.stats()
        s.pop("restart_log", None)     # per-event detail stays in-proc
        return s
    reg.add_source(prefix, _stats)


def register_drift(reg: MetricsRegistry, monitor,
                   prefix: str = "drift") -> None:
    reg.add_source(prefix, monitor.gauges)


def register_tracer(reg: MetricsRegistry, tracer,
                    prefix: str = "trace") -> None:
    """Tracing's own health: buffered/dropped span counts and the
    sampling rate actually in force."""
    reg.add_source(prefix, lambda: {
        "buffered_spans": len(tracer.recorder),
        "dropped_spans": tracer.recorder.dropped,
        "sample_every": tracer.sample_every})
