"""Unified observability for the serving stack.

Three parts, all dependency-free (stdlib + the repo itself):

* :mod:`repro.obs.trace` — head-sampled cross-process request tracing:
  :class:`Tracer` / :class:`TraceRecorder` / :func:`assemble`. Trace
  contexts ride the replicated tier's wire format, so one sampled
  request reconstructs a single client -> router -> replica -> forward
  span tree.
* :mod:`repro.obs.registry` — one typed metrics registry
  (:class:`MetricsRegistry`) with adapters over every existing
  telemetry source (server metrics, service phase/cache stats, router
  health, shared-cache occupancy, drift gauges), snapshotting to one
  versioned schema.
* :mod:`repro.obs.drift` — :class:`DriftMonitor`, the online accuracy
  sentinel: sampled served predictions scored against the analyzer
  oracle in the background, rolling per-target Spearman/MAE plus
  OOV/unk hysteresis alarms.

Egress lives in :mod:`repro.obs.export` (periodic JSONL stream +
opt-in Prometheus text endpoint); ``launch/obs.py`` is the CLI over
the stream. See ``docs/observability.md``.
"""
from repro.obs.drift import Alarm, DriftMonitor
from repro.obs.export import JsonlExporter, PromExporter, to_prometheus
from repro.obs.registry import (MetricsRegistry, register_drift,
                                register_router, register_server,
                                register_service, register_shared_cache,
                                register_supervisor, register_tracer)
from repro.obs.trace import (Span, TraceContext, TraceRecorder, Tracer,
                             TraceTree, assemble, completeness)

__all__ = [
    "Alarm", "DriftMonitor", "JsonlExporter", "MetricsRegistry",
    "PromExporter", "Span", "TraceContext", "TraceRecorder", "Tracer",
    "TraceTree", "assemble", "completeness", "register_drift",
    "register_router", "register_server", "register_service",
    "register_shared_cache", "register_supervisor", "register_tracer",
    "to_prometheus",
]
