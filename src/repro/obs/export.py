"""Telemetry egress: periodic JSONL stream + optional Prometheus text.

The JSONL stream is the canonical artifact — one self-describing line
per record, two kinds::

    {"kind": "metrics", "schema": "repro.obs/v1", "seq": ..,
     "ts": .., "metrics": {...}}
    {"kind": "span", "trace": .., "span": .., "parent": .., ...}

``launch/obs.py tail`` follows it live; ``launch/obs.py report``
reconstructs span trees and latency waterfalls from it offline. The
exporter runs on its own daemon thread on a fixed interval, drains the
tracer's ring buffer each tick (so spans are spilled to disk before
the ring can overwrite them), and always writes one final tick on
``stop()`` — short runs still get their telemetry.

The Prometheus-style exposition is opt-in (stdlib ``http.server``
only, no client library): :class:`PromExporter` serves the current
registry snapshot at ``/metrics`` in the text format scrapers expect.
"""
from __future__ import annotations

import json
import re
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

from repro.obs.registry import MetricsRegistry
from repro.obs.trace import Tracer


class JsonlExporter:
    """Background writer: registry snapshot + drained spans per tick."""

    def __init__(self, path: str, registry: MetricsRegistry,
                 tracer: Optional[Tracer] = None,
                 interval_s: float = 1.0):
        self.path = path
        self.registry = registry
        self.tracer = tracer
        self.interval_s = float(interval_s)
        self.lines_written = 0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._io_lock = threading.Lock()

    def tick(self) -> int:
        """One export round (also callable inline, e.g. from tests)."""
        lines = [json.dumps({"kind": "metrics",
                             **self.registry.snapshot()})]
        if self.tracer is not None:
            lines += [json.dumps({"kind": "span", **rec})
                      for rec in self.tracer.recorder.drain()]
        with self._io_lock:
            with open(self.path, "a", encoding="utf-8") as f:
                f.write("\n".join(lines) + "\n")
            self.lines_written += len(lines)
        return len(lines)

    def _run(self) -> None:
        while not self._stop.wait(self.interval_s):
            try:
                self.tick()
            except Exception:
                pass                    # telemetry must never crash serving

    def start(self) -> "JsonlExporter":
        if self._thread is None:
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._run, name="obs-jsonl-exporter", daemon=True)
            self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
        try:
            self.tick()                 # final flush: snapshot + spans
        except Exception:
            pass

    def __enter__(self) -> "JsonlExporter":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


# ----------------------------------------------------------- prometheus
_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def to_prometheus(snapshot: dict) -> str:
    """Registry snapshot -> Prometheus text exposition (flat keys
    sanitized to metric-name charset, dots become underscores)."""
    lines = []
    for key in sorted(snapshot.get("metrics", {})):
        val = snapshot["metrics"][key]
        if not isinstance(val, (int, float)) or isinstance(val, bool):
            continue
        lines.append(f"{_NAME_RE.sub('_', key.replace('.', '_'))} "
                     f"{float(val):g}")
    lines.append(f"obs_snapshot_seq {snapshot.get('seq', 0)}")
    return "\n".join(lines) + "\n"


class PromExporter:
    """Opt-in ``/metrics`` endpoint over stdlib http.server."""

    def __init__(self, registry: MetricsRegistry, port: int,
                 host: str = "127.0.0.1"):
        self.registry = registry
        registry_ref = registry

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):          # noqa: N802 - stdlib interface
                if self.path.rstrip("/") not in ("", "/metrics"):
                    self.send_error(404)
                    return
                body = to_prometheus(registry_ref.snapshot()).encode()
                self.send_response(200)
                self.send_header("Content-Type",
                                 "text/plain; version=0.0.4")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *a):  # pragma: no cover - quiet server
                pass

        self._server = ThreadingHTTPServer((host, port), Handler)
        self.port = self._server.server_address[1]
        self._thread = threading.Thread(
            target=self._server.serve_forever, name="obs-prom-exporter",
            daemon=True)

    def start(self) -> "PromExporter":
        self._thread.start()
        return self

    def stop(self) -> None:
        self._server.shutdown()
        self._server.server_close()
