"""Pallas TPU kernel for the LSTM cost-model recurrence.

``core/models.py::lstm_encode`` is the paper's middle model: a masked
LSTM scan whose final hidden state feeds the regression heads. The
input projection ``xw = x @ wx + b`` is one large batched matmul that
XLA already runs at MXU peak, so it stays outside; what XLA lowers
poorly is the *recurrence* — ``lax.scan`` emits a dynamic-slice +
matmul + elementwise chain per step, spilling the ``(B, H)`` carry to
HBM between steps. This kernel runs the whole sequence loop inside one
grid step:

* gates ``h @ wh`` as an MXU matmul per step (``wh`` pinned in VMEM);
* the ``(h, c)`` carry lives in VMEM registers across the
  ``fori_loop`` — zero HBM traffic between timesteps;
* masked-carry semantics identical to ``core/models.py::step``: padded
  positions pass the previous ``(h, c)`` through unchanged, and the
  forget gate keeps the paper's +1.0 bias.

Params/activations may be f32 or bf16; the carry and all gate math are
float32 in-kernel either way (bf16 HBM reads, f32 accumulation), and
the final hidden state comes out float32.

VMEM per grid step (bblk=8, S<=1024, H<=128): xw tile
8*1024*512*4 = 16 MiB at H=128 f32 — tight, so serving configs with
long buckets should pass bf16 ``xw`` (halves it) or drop ``bblk``.
At the repo's default H<=64 the tile is <=8 MiB and f32 fits easily.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lstm_kernel(xw_ref, mask_ref, wh_ref, out_ref, *, hidden: int):
    xw = xw_ref[...].astype(jnp.float32)      # (bblk, S, 4H)
    mask = mask_ref[...]                      # (bblk, S) f32
    wh = wh_ref[...].astype(jnp.float32)      # (H, 4H)
    bblk, S, _ = xw.shape

    def step(t, carry):
        h, c = carry
        gates = xw[:, t, :] + h @ wh
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        i = jax.nn.sigmoid(i)
        f = jax.nn.sigmoid(f + 1.0)           # paper's forget-gate bias
        o = jax.nn.sigmoid(o)
        g = jnp.tanh(g)
        c_new = f * c + i * g
        h_new = o * jnp.tanh(c_new)
        keep = mask[:, t][:, None]
        return (h_new * keep + h * (1 - keep),
                c_new * keep + c * (1 - keep))

    h0 = jnp.zeros((bblk, hidden), jnp.float32)
    h, _ = jax.lax.fori_loop(0, S, step, (h0, h0))
    out_ref[...] = h


def lstm_scan_fused(xw: jax.Array, mask: jax.Array, wh: jax.Array, *,
                    bblk: int = 8, interpret: bool = False) -> jax.Array:
    """Masked LSTM recurrence: precomputed gates in, final hidden out.

    xw: (B, S, 4H) = x @ wx + b (f32 or bf16); mask: (B, S) (1 = valid);
    wh: (H, 4H). Returns (B, H) float32. Pads B to a bblk multiple
    (pad rows are fully masked, so their carry stays zero)."""
    B, S, four_h = xw.shape
    hidden = wh.shape[0]
    assert four_h == 4 * hidden, (four_h, hidden)
    mask = mask.astype(jnp.float32)
    Bp = ((B + bblk - 1) // bblk) * bblk
    if Bp != B:
        xw = jnp.pad(xw, ((0, Bp - B), (0, 0), (0, 0)))
        mask = jnp.pad(mask, ((0, Bp - B), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_lstm_kernel, hidden=hidden),
        grid=(Bp // bblk,),
        in_specs=[
            pl.BlockSpec((bblk, S, four_h), lambda i: (i, 0, 0)),
            pl.BlockSpec((bblk, S), lambda i: (i, 0)),
            pl.BlockSpec(wh.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bblk, hidden), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, hidden), jnp.float32),
        interpret=interpret,
    )(xw, mask, wh)
    return out[:B]
