"""Pure-jnp oracles for the Pallas kernels (allclose reference)."""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp


def conv1d_same(x, w, b):
    """'same'-padded 1D conv, matching core/models.py::conv1d.
    x: (B, S, Cin); w: (fs, Cin, Cout); b: (Cout,)."""
    fs = w.shape[0]
    out = jax.lax.conv_general_dilated(
        x, w, window_strides=(1,), padding=[((fs - 1) // 2, fs // 2)],
        dimension_numbers=("NWC", "WIO", "NWC"))
    return out + b


def conv1d_stack_ref(x, weights: Sequence, biases: Sequence,
                     mask=None):
    """The paper's Conv1D tower: N x (conv1d 'same' + ReLU), then MaxPool1D
    over the sequence. x: (B, S, C0) -> (B, C_last).

    mask: optional (B, S) validity mask — padded positions are excluded
    from the final max (set to -inf before pooling)."""
    h = x
    for w, b in zip(weights, biases):
        h = jax.nn.relu(conv1d_same(h, w, b))
    if mask is not None:
        h = jnp.where(mask[..., None] > 0, h, -jnp.inf)
    out = h.max(axis=1)
    # all-masked rows: ReLU output floor is 0
    return jnp.maximum(out, 0.0) if mask is not None else out


def lstm_scan_ref(xw, mask, wh):
    """Masked LSTM recurrence oracle, mirroring kernels/lstm_scan.py and
    core/models.py::lstm_encode's ``step`` (forget bias +1.0, padded
    positions pass the carry through). xw: (B, S, 4H) precomputed input
    gates; mask: (B, S); wh: (H, 4H). Returns (B, H) float32."""
    xw = xw.astype(jnp.float32)
    mask = mask.astype(jnp.float32)
    wh = wh.astype(jnp.float32)
    B = xw.shape[0]
    hidden = wh.shape[0]

    def step(carry, inp):
        h, c = carry
        xt, mt = inp
        gates = xt + h @ wh
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        i, f, o = (jax.nn.sigmoid(i), jax.nn.sigmoid(f + 1.0),
                   jax.nn.sigmoid(o))
        g = jnp.tanh(g)
        c_new = f * c + i * g
        h_new = o * jnp.tanh(c_new)
        keep = mt[:, None]
        return (h_new * keep + h * (1 - keep),
                c_new * keep + c * (1 - keep)), None

    h0 = jnp.zeros((B, hidden), jnp.float32)
    (h, _), _ = jax.lax.scan(step, (h0, h0),
                             (xw.transpose(1, 0, 2), mask.T))
    return h


def conv_forward_ref(params, ids):
    """Ids-in/predictions-out oracle for the fully fused conv forward:
    core/models.py::conv_apply on f32-cast params (the fused kernel's
    contract is exact conv_apply semantics — unmasked maxpool included —
    with f32 accumulation regardless of the param dtype)."""
    from repro.core.models import conv_apply
    p32 = jax.tree.map(
        lambda a: a.astype(jnp.float32)
        if jnp.issubdtype(a.dtype, jnp.floating) else a, params)
    return conv_apply(p32, ids)


def decode_attention_ref(q, k_cache, v_cache, index):
    """Grouped decode attention oracle. q: (B, nkv, G, D);
    k_cache/v_cache: (B, nkv, S, D); attends positions <= index."""
    import numpy as np
    D = q.shape[-1]
    logits = jnp.einsum("bhgd,bhsd->bhgs", q.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) / np.sqrt(D)
    S = k_cache.shape[2]
    valid = jnp.arange(S) <= index
    logits = jnp.where(valid[None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhgs,bhsd->bhgd", w, v_cache.astype(jnp.float32))
