"""Pure-jnp oracles for the Pallas kernels (allclose reference)."""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp


def conv1d_same(x, w, b):
    """'same'-padded 1D conv, matching core/models.py::conv1d.
    x: (B, S, Cin); w: (fs, Cin, Cout); b: (Cout,)."""
    fs = w.shape[0]
    out = jax.lax.conv_general_dilated(
        x, w, window_strides=(1,), padding=[((fs - 1) // 2, fs // 2)],
        dimension_numbers=("NWC", "WIO", "NWC"))
    return out + b


def conv1d_stack_ref(x, weights: Sequence, biases: Sequence,
                     mask=None):
    """The paper's Conv1D tower: N x (conv1d 'same' + ReLU), then MaxPool1D
    over the sequence. x: (B, S, C0) -> (B, C_last).

    mask: optional (B, S) validity mask — padded positions are excluded
    from the final max (set to -inf before pooling)."""
    h = x
    for w, b in zip(weights, biases):
        h = jax.nn.relu(conv1d_same(h, w, b))
    if mask is not None:
        h = jnp.where(mask[..., None] > 0, h, -jnp.inf)
    out = h.max(axis=1)
    # all-masked rows: ReLU output floor is 0
    return jnp.maximum(out, 0.0) if mask is not None else out


def decode_attention_ref(q, k_cache, v_cache, index):
    """Grouped decode attention oracle. q: (B, nkv, G, D);
    k_cache/v_cache: (B, nkv, S, D); attends positions <= index."""
    import numpy as np
    D = q.shape[-1]
    logits = jnp.einsum("bhgd,bhsd->bhgs", q.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) / np.sqrt(D)
    S = k_cache.shape[2]
    valid = jnp.arange(S) <= index
    logits = jnp.where(valid[None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhgs,bhsd->bhgd", w, v_cache.astype(jnp.float32))
