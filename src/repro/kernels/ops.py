"""jit'd public wrappers for the Pallas kernels.

``conv_tower_apply`` mirrors core/models.py::conv_apply but runs the fused
kernel for the Conv1D+ReLU+MaxPool tower; on CPU it transparently uses
interpret mode (the TPU path compiles the same kernel natively).
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.models import fc_finish
from repro.kernels.conv1d_stack import conv1d_stack_fused
from repro.kernels import ref as REF


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("bblk", "interpret"))
def conv1d_stack(x, weights: Sequence, biases: Sequence, mask, *,
                 bblk: int = 8, interpret: bool | None = None):
    interp = _on_cpu() if interpret is None else interpret
    return conv1d_stack_fused(x, list(weights), list(biases), mask,
                              bblk=bblk, interpret=interp)


def conv_tower_apply(params, ids, *, use_kernel: bool = True,
                     interpret: bool | None = None):
    """Drop-in for core.models.conv_apply using the fused kernel."""
    mask = (ids != 0).astype(jnp.float32)
    x = params["emb"][ids] * mask[..., None]
    weights = [lyr["w"] for lyr in params["convs"]]
    biases = [lyr["b"] for lyr in params["convs"]]
    if use_kernel:
        h = conv1d_stack(x, weights, biases, mask, interpret=interpret)
    else:
        h = REF.conv1d_stack_ref(x, weights, biases, mask)
    return fc_finish(params, h)
