"""jit'd public wrappers for the Pallas kernels.

Three serving entry points, all drop-ins for ``core/models.py`` applies:

* :func:`conv_tower_apply` — mirrors ``conv_apply`` but runs the fused
  Conv1D+ReLU+MaxPool tower kernel (embedding gather still in plain
  jnp; kept for composability and as the bench's half-fused rung).
* :func:`conv_forward_apply` — the full fusion: token ids in,
  per-target predictions out, one ``pallas_call`` (embedding gather,
  pad mask, conv tower, FC stack, and stacked linear heads all inside
  the grid step — no intermediate HBM traffic).
* :func:`lstm_forward_apply` — mirrors ``lstm_apply``: the input
  projection stays a plain XLA matmul, the recurrence runs in the
  Pallas ``lstm_scan`` kernel with the carry in VMEM.

Params may be f32 or bf16 (accumulation is f32 in-kernel either way).
On CPU the wrappers transparently use interpret mode; the TPU path
compiles the same kernels natively.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.models import fc_finish, model_heads, scalar_head
from repro.kernels.conv1d_stack import conv1d_stack_fused, conv_forward_fused
from repro.kernels.lstm_scan import lstm_scan_fused
from repro.kernels import ref as REF

# Model kinds with a fused Pallas serving forward (see forward_apply).
KERNEL_KINDS = ("conv1d", "lstm")


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("bblk", "interpret"))
def conv1d_stack(x, weights: Sequence, biases: Sequence, mask, *,
                 bblk: int = 8, interpret: bool | None = None):
    interp = _on_cpu() if interpret is None else interpret
    return conv1d_stack_fused(x, list(weights), list(biases), mask,
                              bblk=bblk, interpret=interp)


@functools.partial(jax.jit, static_argnames=("bblk", "interpret"))
def lstm_scan(xw, mask, wh, *, bblk: int = 8,
              interpret: bool | None = None):
    interp = _on_cpu() if interpret is None else interpret
    return lstm_scan_fused(xw, mask, wh, bblk=bblk, interpret=interp)


def conv_tower_apply(params, ids, *, use_kernel: bool = True,
                     interpret: bool | None = None):
    """Drop-in for core.models.conv_apply using the fused tower kernel
    (gather outside the kernel; see conv_forward_apply for full fusion)."""
    mask = (ids != 0).astype(jnp.float32)
    x = params["emb"][ids] * mask[..., None].astype(params["emb"].dtype)
    weights = [lyr["w"] for lyr in params["convs"]]
    biases = [lyr["b"] for lyr in params["convs"]]
    if use_kernel:
        h = conv1d_stack(x, weights, biases, mask, interpret=interpret)
    else:
        h = REF.conv1d_stack_ref(x, weights, biases, mask)
    return fc_finish(params, h)


def _stacked_heads(params):
    """(head_w, head_b, names) with per-target columns stacked so every
    head is one matmul. Single-head layout: the head is ``fc[-1]``."""
    names = model_heads(params)
    if names is None:
        head = params["fc"][-1]
        return head["w"], head["b"], None
    hs = [params["heads"][t] for t in names]
    return (jnp.concatenate([h["w"] for h in hs], axis=1),
            jnp.concatenate([h["b"] for h in hs], axis=0), names)


def conv_forward_apply(params, ids, *, interpret: bool | None = None,
                       bblk: int = 8):
    """Full fused serving forward for kind="conv1d": ids -> predictions.

    Output matches ``conv_apply``: a ``{target: (B,)}`` dict for the
    multi-head layout, a ``(B,)`` array for single-head — but always
    float32 (the kernel accumulates f32 even for bf16 params)."""
    head_w, head_b, names = _stacked_heads(params)
    hidden_fc = params["fc"] if names is not None else params["fc"][:-1]
    out = _conv_forward(
        ids, params["emb"],
        tuple(lyr["w"] for lyr in params["convs"]),
        tuple(lyr["b"] for lyr in params["convs"]),
        tuple(lyr["w"] for lyr in hidden_fc),
        tuple(lyr["b"] for lyr in hidden_fc),
        head_w, head_b, bblk=bblk, interpret=interpret)
    if names is None:
        return out[:, 0]
    return {t: out[:, i] for i, t in enumerate(names)}


@functools.partial(jax.jit, static_argnames=("bblk", "interpret"))
def _conv_forward(ids, emb, conv_ws, conv_bs, fc_ws, fc_bs, head_w,
                  head_b, *, bblk: int = 8,
                  interpret: bool | None = None):
    interp = _on_cpu() if interpret is None else interpret
    return conv_forward_fused(ids, emb, list(conv_ws), list(conv_bs),
                              list(fc_ws), list(fc_bs), head_w, head_b,
                              bblk=bblk, interpret=interp)


def lstm_forward_apply(params, ids, *, interpret: bool | None = None,
                       bblk: int = 8):
    """Fused serving forward for kind="lstm": input projection in XLA,
    recurrence in the Pallas lstm_scan kernel, heads on the f32 hidden
    state. Output matches ``lstm_apply`` (f32)."""
    mask = (ids != 0).astype(jnp.float32)
    x = params["emb"][ids]
    xw = x @ params["wx"] + params["b"]
    h = lstm_scan(xw, mask, params["wh"], bblk=bblk, interpret=interpret)
    names = model_heads(params)
    if names is None:
        return scalar_head(params["head"], h)
    return {t: scalar_head(params["heads"][t], h) for t in names}


def forward_apply(kind: str, params, ids, *,
                  interpret: bool | None = None):
    """Dispatch to the fused Pallas forward for ``kind``.

    Raises ValueError for kinds without a kernel (see KERNEL_KINDS)."""
    if kind == "conv1d":
        return conv_forward_apply(params, ids, interpret=interpret)
    if kind == "lstm":
        return lstm_forward_apply(params, ids, interpret=interpret)
    raise ValueError(
        f"use_kernel supports kinds {KERNEL_KINDS}, not {kind!r}")


def fused_forward_bytes(params, batch: int, seq: int) -> int:
    """Modeled HBM traffic of one fused conv forward: ids + one read of
    every param + the predictions. Used by the kernel_bench roofline."""
    names = model_heads(params)
    n_heads = len(names) if names else 1
    p_bytes = sum(a.size * a.dtype.itemsize for a in jax.tree.leaves(params))
    return batch * seq * 4 + p_bytes + batch * n_heads * 4
