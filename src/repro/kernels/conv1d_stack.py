"""Pallas TPU kernels: the fused Conv1D serving forward for the cost model.

The paper's deployed model runs thousands of inferences per compilation
session, so this is the perf-critical hot spot. A naive XLA lowering runs
each Conv1D as a separate HBM round-trip (6 layers x (B,S,C) activations);
at C=64 the tower is heavily memory-bound (arithmetic intensity ~= fs*C/6
FLOPs/byte). Two fusion levels live here:

* :func:`conv1d_stack_fused` — the tower only: embedded activations in,
  pooled features out, whole tower held in VMEM (the PR-6 kernel, kept
  as the composable building block).
* :func:`conv_forward_fused` — the full serving forward: **token ids
  in, per-target predictions out**. The embedding gather + pad mask run
  inside the grid step (the ``(B,S,E)`` embedded activations are never
  materialized in HBM — previously the single largest remaining HBM
  round trip), and the FC stack + stacked per-target linear heads fold
  into the same call. One HBM read of ids and params yields the
  ``(B, n_heads)`` normalized predictions.

TPU mapping:
* channels sit on the 128-wide lane dimension (C padded to 128);
* the embedding table is pinned whole in VMEM (index_map block 0), so
  the gather is a VMEM-local dynamic lookup, not an HBM gather;
* sequence sits on sublanes; each conv tap is a (S, Cin) @ (Cin, Cout)
  MXU matmul — the fs-tap conv = fs shifted matmuls accumulated in fp32;
* grid over batch tiles; weights are broadcast to every grid step
  (index_map pins them to block 0).

Accumulation is float32 regardless of the parameter dtype, so bf16-cast
params (quantized serving) run bf16 HBM reads with f32 in-kernel math;
predictions always come out float32.

VMEM budget per grid step (defaults: bblk=8, S<=1024, C<=128, V<=8192):
    emb table 8192*128*4 = 4 MiB, x tile 8*1024*128*4 = 4 MiB, two
    ping-pong layer buffers 8 MiB, weights sum(fs*C*C)*4 + FC/head
    stacks << 1 MiB -> fits the ~16 MiB VMEM of v5e (bf16 params halve
    the table and weight terms).
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pinned_spec(shape):
    """BlockSpec broadcasting one whole operand to every grid step.

    The index map must not close over loop variables (a late-binding
    ``lambda i: (0,) * w.ndim`` inside the operand loop would see only
    the final ``w``), so the rank is bound here, per call."""
    n = len(shape)
    return pl.BlockSpec(shape, lambda i, _n=n: (0,) * _n)


def _tower(h, mask, refs, n_layers, filter_sizes, *, masked_pool):
    """Conv tower + ReLU per layer + MaxPool, all in f32 VMEM.

    ``refs[2i], refs[2i+1]`` are the layer-i weight/bias refs. Returns
    (bblk, C_last) pooled features. ``masked_pool`` excludes pad
    positions from the max (the tower-only kernel's contract, matching
    conv1d_stack_ref(mask); all-pad rows pool to the ReLU floor of 0);
    the full-forward kernel pools every position, exactly matching
    core/models.py::conv_apply."""
    S = h.shape[1]
    for i in range(n_layers):
        w = refs[2 * i][...].astype(jnp.float32)      # (fs, Cin, Cout)
        b = refs[2 * i + 1][...].astype(jnp.float32)  # (Cout,)
        fs = filter_sizes[i]
        pad_l, pad_r = (fs - 1) // 2, fs // 2
        acc = jnp.zeros(h.shape[:2] + (w.shape[2],), jnp.float32)
        # conv = sum of shifted matmuls on the MXU
        hp = jnp.pad(h, ((0, 0), (pad_l, pad_r), (0, 0)))
        for k in range(fs):
            acc += jax.lax.dot_general(
                hp[:, k:k + S, :], w[k],
                dimension_numbers=(((2,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
        h = jnp.maximum(acc + b, 0.0)             # ReLU
    if not masked_pool:
        return h.max(axis=1)                      # MaxPool1D, all positions
    h = jnp.where(mask[..., None] > 0, h, -jnp.inf)
    return jnp.maximum(h.max(axis=1), 0.0)


def _kernel(x_ref, mask_ref, *refs, n_layers: int, filter_sizes, out_dtype):
    """Tower-only kernel. refs = (w0, b0, w1, b1, ..., out_ref)."""
    out_ref = refs[-1]
    x = x_ref[...].astype(jnp.float32)            # (bblk, S, C0)
    mask = mask_ref[...]                          # (bblk, S)
    out_ref[...] = _tower(x, mask, refs[:-1], n_layers, filter_sizes,
                          masked_pool=True).astype(out_dtype)


def conv1d_stack_fused(x: jax.Array, weights: Sequence[jax.Array],
                       biases: Sequence[jax.Array],
                       mask: jax.Array, *, bblk: int = 8,
                       interpret: bool = False) -> jax.Array:
    """Fused tower. x: (B, S, C0); mask: (B, S) (1 = valid token).
    Returns (B, C_last). Pads B to a bblk multiple and C dims are used
    as given (pad to 128 upstream for lane alignment on real hardware)."""
    B, S, C0 = x.shape
    n_layers = len(weights)
    filter_sizes = tuple(int(w.shape[0]) for w in weights)
    c_last = weights[-1].shape[2]
    Bp = ((B + bblk - 1) // bblk) * bblk
    if Bp != B:
        x = jnp.pad(x, ((0, Bp - B), (0, 0), (0, 0)))
        mask = jnp.pad(mask, ((0, Bp - B), (0, 0)))
    grid = (Bp // bblk,)

    in_specs = [
        pl.BlockSpec((bblk, S, C0), lambda i: (i, 0, 0)),
        pl.BlockSpec((bblk, S), lambda i: (i, 0)),
    ]
    operands = [x, mask]
    for w, b in zip(weights, biases):
        in_specs.append(_pinned_spec(w.shape))
        in_specs.append(_pinned_spec(b.shape))
        operands += [w, b]

    out = pl.pallas_call(
        functools.partial(_kernel, n_layers=n_layers,
                          filter_sizes=filter_sizes, out_dtype=x.dtype),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bblk, c_last), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, c_last), x.dtype),
        interpret=interpret,
    )(*operands)
    return out[:B]


def _forward_kernel(ids_ref, emb_ref, *refs, n_layers: int, filter_sizes,
                    n_fc: int):
    """Ids-in / predictions-out kernel.

    refs = (w0, b0, ..., w{L-1}, b{L-1},          conv tower
            fw0, fb0, ..., fw{n_fc-1}, fb{n_fc-1}, hidden FC stack
            head_w, head_b, out_ref)              stacked linear heads
    """
    out_ref = refs[-1]
    ids = ids_ref[...]                            # (bblk, S) int32
    emb = emb_ref[...].astype(jnp.float32)        # (V, E), VMEM-resident
    # embedding gather + pad mask, entirely on-chip: the (bblk, S, E)
    # activations live only in VMEM
    x = jnp.take(emb, ids.reshape(-1), axis=0).reshape(
        ids.shape + (emb.shape[1],))
    mask = (ids != 0).astype(jnp.float32)         # PAD id is 0
    x = x * mask[..., None]
    conv_refs = refs[:2 * n_layers]
    # pool over every position (pads included), exactly like conv_apply:
    # the serving tier's bucket pad_slack relies on those semantics
    pooled = _tower(x, mask, conv_refs, n_layers, filter_sizes,
                    masked_pool=False)
    # hidden FC stack (ReLU), then all heads as ONE (F, n_heads) matmul
    off = 2 * n_layers
    h = pooled
    for i in range(n_fc):
        fw = refs[off + 2 * i][...].astype(jnp.float32)
        fb = refs[off + 2 * i + 1][...].astype(jnp.float32)
        h = jnp.maximum(h @ fw + fb, 0.0)
    head_w = refs[off + 2 * n_fc][...].astype(jnp.float32)   # (F, n_heads)
    head_b = refs[off + 2 * n_fc + 1][...].astype(jnp.float32)
    out_ref[...] = h @ head_w + head_b


def conv_forward_fused(ids: jax.Array, emb: jax.Array,
                       conv_weights: Sequence[jax.Array],
                       conv_biases: Sequence[jax.Array],
                       fc_weights: Sequence[jax.Array],
                       fc_biases: Sequence[jax.Array],
                       head_w: jax.Array, head_b: jax.Array, *,
                       bblk: int = 8,
                       interpret: bool = False) -> jax.Array:
    """The full fused serving forward: token ids -> (B, n_heads) f32.

    ids: (B, S) int32 (PAD id 0); emb: (V, E); head_w: (F, n_heads)
    with the per-target head columns stacked. Params may be f32 or bf16
    — accumulation is f32 in-kernel either way. One HBM read of ids and
    params, one HBM write of the predictions; no intermediate tensor
    (embedded activations, conv layers, pooled/FC features) ever leaves
    VMEM."""
    B, S = ids.shape
    n_layers = len(conv_weights)
    filter_sizes = tuple(int(w.shape[0]) for w in conv_weights)
    n_fc = len(fc_weights)
    n_heads = head_w.shape[1]
    Bp = ((B + bblk - 1) // bblk) * bblk
    if Bp != B:
        ids = jnp.pad(ids, ((0, Bp - B), (0, 0)))   # pad rows are all-PAD
    grid = (Bp // bblk,)

    in_specs = [pl.BlockSpec((bblk, S), lambda i: (i, 0)),
                _pinned_spec(emb.shape)]
    operands = [ids, emb]
    for w, b in zip(conv_weights, conv_biases):
        in_specs += [_pinned_spec(w.shape), _pinned_spec(b.shape)]
        operands += [w, b]
    for w, b in zip(fc_weights, fc_biases):
        in_specs += [_pinned_spec(w.shape), _pinned_spec(b.shape)]
        operands += [w, b]
    in_specs += [_pinned_spec(head_w.shape), _pinned_spec(head_b.shape)]
    operands += [head_w, head_b]

    out = pl.pallas_call(
        functools.partial(_forward_kernel, n_layers=n_layers,
                          filter_sizes=filter_sizes, n_fc=n_fc),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bblk, n_heads), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, n_heads), jnp.float32),
        interpret=interpret,
    )(*operands)
    return out[:B]
