"""Pallas TPU kernel: fused Conv1D tower + ReLU + MaxPool for the cost model.

The paper's deployed model runs thousands of inferences per compilation
session, so this is the perf-critical hot spot. A naive XLA lowering runs
each Conv1D as a separate HBM round-trip (6 layers x (B,S,C) activations);
at C=64 the tower is heavily memory-bound (arithmetic intensity ~= fs*C/6
FLOPs/byte). The fusion keeps the whole tower in VMEM: one HBM read of the
embedded tokens, one HBM write of the pooled features — a ~7x reduction in
HBM traffic (see benchmarks/kernel_bench.py).

TPU mapping:
* channels sit on the 128-wide lane dimension (C padded to 128);
* sequence sits on sublanes; each conv tap is a (S, Cin) @ (Cin, Cout)
  MXU matmul — the fs-tap conv = fs shifted matmuls accumulated in fp32;
* grid over batch tiles; weights are broadcast to every grid step
  (index_map pins them to block 0).

VMEM budget per grid step (defaults: bblk=8, S<=1024, C<=128 fp32):
    x tile 8*1024*128*4 = 4 MiB, two ping-pong layer buffers 8 MiB,
    weights sum(fs*C*C)*4 << 1 MiB  -> fits the ~16 MiB VMEM of v5e.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, mask_ref, *refs, n_layers: int, filter_sizes, out_dtype):
    """refs = (w0, b0, w1, b1, ..., out_ref)."""
    out_ref = refs[-1]
    x = x_ref[...].astype(jnp.float32)            # (bblk, S, C0)
    mask = mask_ref[...]                          # (bblk, S)
    h = x
    S = x.shape[1]
    for i in range(n_layers):
        w = refs[2 * i][...].astype(jnp.float32)      # (fs, Cin, Cout)
        b = refs[2 * i + 1][...].astype(jnp.float32)  # (Cout,)
        fs = filter_sizes[i]
        pad_l, pad_r = (fs - 1) // 2, fs // 2
        acc = jnp.zeros(h.shape[:2] + (w.shape[2],), jnp.float32)
        # conv = sum of shifted matmuls on the MXU
        hp = jnp.pad(h, ((0, 0), (pad_l, pad_r), (0, 0)))
        for k in range(fs):
            acc += jax.lax.dot_general(
                hp[:, k:k + S, :], w[k],
                dimension_numbers=(((2,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
        h = jnp.maximum(acc + b, 0.0)             # ReLU
    # MaxPool1D over valid sequence positions
    h = jnp.where(mask[..., None] > 0, h, -jnp.inf)
    pooled = jnp.maximum(h.max(axis=1), 0.0)
    out_ref[...] = pooled.astype(out_dtype)


def conv1d_stack_fused(x: jax.Array, weights: Sequence[jax.Array],
                       biases: Sequence[jax.Array],
                       mask: jax.Array, *, bblk: int = 8,
                       interpret: bool = False) -> jax.Array:
    """Fused tower. x: (B, S, C0); mask: (B, S) (1 = valid token).
    Returns (B, C_last). Pads B to a bblk multiple and C dims are used
    as given (pad to 128 upstream for lane alignment on real hardware)."""
    B, S, C0 = x.shape
    n_layers = len(weights)
    filter_sizes = tuple(int(w.shape[0]) for w in weights)
    c_last = weights[-1].shape[2]
    Bp = ((B + bblk - 1) // bblk) * bblk
    if Bp != B:
        x = jnp.pad(x, ((0, Bp - B), (0, 0), (0, 0)))
        mask = jnp.pad(mask, ((0, Bp - B), (0, 0)))
    grid = (Bp // bblk,)

    in_specs = [
        pl.BlockSpec((bblk, S, C0), lambda i: (i, 0, 0)),
        pl.BlockSpec((bblk, S), lambda i: (i, 0)),
    ]
    operands = [x, mask]
    for w, b in zip(weights, biases):
        in_specs.append(pl.BlockSpec(w.shape, lambda i: (0,) * w.ndim))
        in_specs.append(pl.BlockSpec(b.shape, lambda i: (0,)))
        operands += [w, b]

    out = pl.pallas_call(
        functools.partial(_kernel, n_layers=n_layers,
                          filter_sizes=filter_sizes, out_dtype=x.dtype),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bblk, c_last), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, c_last), x.dtype),
        interpret=interpret,
    )(*operands)
    return out[:B]
