"""Rewrite-rule registry over the ``xpu`` dataflow IR.

Each rule implements the uniform :class:`Rewrite` interface —
``applicable(g) -> [Site]`` enumerates every location the rule can fire,
``apply(g, site) -> Graph`` fires it at one location — and every ``apply``
passes through :func:`check_legal`: the result must be ``validate()``-clean
with output shapes (and, unless the rule is an explicit precision
tradeoff, dtypes) preserved, plus an optional oracle-equivalence hook for
stronger semantic checks.

Shipped rules (the paper's §1 graph-level optimizations):

* ``fuse_elementwise`` — producer→consumer elementwise chains collapse
  into ONE ``xpu.fused`` op carrying ``n_fused``/``chain`` attrs, so the
  tokenizer emits visibly different IR for fused programs and the
  analyzers charge one HBM round trip instead of one per constituent.
* ``cse``       — dedup structurally-identical ops (same opcode, operands,
  attrs, result type), rewiring uses onto the first occurrence.
* ``dce``       — drop ops whose result is never used (and not an output).
* ``recompute`` — duplicate a cheap (elementwise) multi-consumer producer
  per consumer: recompute-vs-materialize, the enabling move for fusion
  across what used to be a fan-out point.
* ``dtype_narrow`` — narrow f32 *intermediates* to bf16 (graph outputs
  keep their dtype): halves the HBM traffic the roofline oracle charges.
* ``unroll``    — replicate the body (shared args) as an unrolled inner
  loop would look to the cost model; output count scales by the factor,
  so this rule alone opts out of exact output preservation.

Sites discovered on a graph are only valid on that exact graph — a
search applies one site, then re-enumerates on the rewritten result.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

from repro.ir.graph import ELEMENTWISE, FUSED_OP, Graph, Op, Tensor


class Site:
    """One applicable rewrite location.

    ``detail`` is rule-specific (op indices, factors); ``weight`` is the
    objective's latency divisor (an unroll by f does f iterations' work,
    so its per-iteration latency is latency/f)."""

    __slots__ = ("rule", "detail", "weight")

    def __init__(self, rule: str, detail: Tuple = (), weight: float = 1.0):
        self.rule = rule
        self.detail = tuple(detail)
        self.weight = float(weight)

    def __repr__(self) -> str:
        return f"{self.rule}{self.detail}"


def use_counts(g: Graph) -> Dict[int, int]:
    """SSA id -> number of uses (operand slots + graph outputs)."""
    uses: Dict[int, int] = {}
    for op in g.ops:
        for o in op.operands:
            uses[o] = uses.get(o, 0) + 1
    for o in g.outputs:
        uses[o] = uses.get(o, 0) + 1
    return uses


def producers(g: Graph) -> Dict[int, int]:
    """SSA id -> index of the op producing it (args absent)."""
    return {op.result: i for i, op in enumerate(g.ops)}


def _clone_args(g: Graph, name: str) -> Tuple[Graph, Dict[int, int]]:
    new = Graph(name=name)
    new.values = list(g.values[:g.n_args])
    new.n_args = g.n_args
    return new, {i: i for i in range(g.n_args)}


def _seq_layout(g: Graph) -> bool:
    """True when op ``i`` produces value ``n_args + i`` — the layout every
    ``add_op``/``_Derive``-built graph has. Checked once and memoized on
    the graph; the bulk prefix-sharing fast path below requires it."""
    v = getattr(g, "_seq_layout_ok", None)
    if v is None:
        na = g.n_args
        v = all(op.result == na + i for i, op in enumerate(g.ops))
        g._seq_layout_ok = v
    return v


class _Derive:
    """Build a graph derived from a parent while tracking which new ops
    are *verbatim copies* of parent ops (same opcode/attrs/result type,
    operands remapped onto values that are themselves verbatim copies).

    On :meth:`finish` the copy map is handed to ``Graph.adopt_hashes``,
    so the child's ``struct_key()`` inherits the parent's per-value
    hashes and re-hashes only the rewrite's dirty cone — the incremental
    hot path a beam search over candidates lives on. The same map feeds
    the serving layer's parent-delta tokenization (unchanged op token
    spans are sliced from the parent's cached ids, not re-lexed)."""

    __slots__ = ("parent", "new", "id_map", "copied", "tok_copied")

    def __init__(self, g: Graph, name: Optional[str] = None):
        self.parent = g
        self.new, self.id_map = _clone_args(
            g, g.name if name is None else name)
        # child value id -> parent value id with identical structural hash
        self.copied: Dict[int, int] = {i: i for i in range(g.n_args)}
        # child value id -> parent value id with identical ops-mode token
        # pair (opcode + result shape): a superset of ``copied`` — ops
        # downstream of a rewrite re-hash but still tokenize identically
        self.tok_copied: Dict[int, int] = dict(self.copied)

    def copy(self, op, remap: bool = True) -> int:
        """Emit a verbatim copy of a parent op. ``remap=False`` leaves
        ``id_map`` alone (recompute's private duplicate clones).

        This is the single hottest loop of the whole search (it runs
        once per surviving op per candidate), so it bypasses
        ``Graph.add_op`` — no operand re-copy, no kwargs splat — and
        SHARES the parent op's attrs dict: ops are immutable once built
        (the ``struct_key`` contract), so aliasing is safe."""
        id_map, new, copied = self.id_map, self.new, self.copied
        new.values.append(self.parent.values[op.result])
        nid = len(new.values) - 1
        # hash-clean only if every operand is itself a clean copy of the
        # SAME parent value — otherwise the op re-hashes (conservative)
        clean = True
        operands = []
        for o in op.operands:
            m = id_map[o]
            operands.append(m)
            if clean and copied.get(m) != o:
                clean = False
        new.ops.append(Op(op.opcode, operands, nid, op.attrs))
        if clean:
            copied[nid] = op.result
        self.tok_copied[nid] = op.result
        if remap:
            id_map[op.result] = nid
        return nid

    def copy_prefix(self, k: int) -> None:
        """Bulk-share the first *k* parent ops verbatim.

        Until the first rewrite site, the copy map is the identity — a
        per-op :meth:`copy` would append the same value, remap every
        operand to itself, and rebuild an identical ``Op``. When the
        parent has the sequential ``add_op`` layout and nothing has been
        emitted yet, the whole prefix can instead be list-sliced in and
        the parent ``Op`` objects SHARED outright (ops are immutable once
        built — the ``struct_key`` contract — so aliasing whole ops is as
        safe as aliasing their attrs). Profiles put per-op copying at
        ~half of steady-state search time; this turns the untouched
        prefix into a few C-level slice/update calls."""
        if k <= 0:
            return
        p, new = self.parent, self.new
        na = p.n_args
        if new.ops or not _seq_layout(p):
            for op in p.ops[:k]:           # rare fallback: odd layouts
                self.copy(op)
            return
        new.values.extend(p.values[na:na + k])
        new.ops.extend(p.ops[:k])
        ids = range(na, na + k)
        ident = dict(zip(ids, ids))
        self.id_map.update(ident)
        self.copied.update(ident)
        self.tok_copied.update(ident)

    def emit(self, opcode: str, operands, out, **attrs) -> int:
        """Emit a fresh (rewritten) op; its hash is always recomputed.
        Inlines ``Graph.add_op`` (same layout) — emit runs once per
        rewritten op per candidate, so the extra call + kwargs re-splat
        showed up in search profiles."""
        new = self.new
        new.values.append(out)
        nid = len(new.values) - 1
        new.ops.append(Op(opcode, list(operands), nid, attrs))
        return nid

    def alias(self, parent_vid: int, child_vid: int) -> None:
        """Map a parent value onto an existing child value (CSE dedup)."""
        self.id_map[parent_vid] = child_vid

    def finish(self, *, preserve_outputs: bool = True,
               oracle_check=None) -> Graph:
        self.new.outputs = [self.id_map[o] for o in self.parent.outputs]
        self.new.adopt_hashes(self.parent, self.copied, self.tok_copied)
        return check_legal(self.parent, self.new,
                           preserve_outputs=preserve_outputs,
                           oracle_check=oracle_check)


def check_legal(old: Graph, new: Graph, *, preserve_outputs: bool = True,
                oracle_check: Optional[Callable[[Graph, Graph], bool]]
                = None) -> Graph:
    """Legality gate every ``apply`` returns through: SSA-valid, and (for
    output-preserving rules) the same number of outputs with unchanged
    shape and dtype. ``oracle_check(old, new)`` is the pluggable
    equivalence hook — e.g. analyzer-target non-increase for CSE/DCE, or
    a numeric executor when one exists."""
    new.validate()
    if preserve_outputs:
        assert len(new.outputs) == len(old.outputs), \
            f"output arity changed: {len(old.outputs)}->{len(new.outputs)}"
        for a, b in zip(old.outputs, new.outputs):
            ta, tb = old.values[a], new.values[b]
            assert ta.shape == tb.shape, f"output shape {ta}->{tb}"
            assert ta.dtype == tb.dtype, f"output dtype {ta}->{tb}"
    if oracle_check is not None:
        assert oracle_check(old, new), "oracle-equivalence check failed"
    return new


class Rewrite:
    """Uniform rewrite interface; subclasses are stateless and shared."""

    name: str = "rewrite"
    # False: the rule changes intermediate dtypes (precision tradeoff)
    preserves_dtypes: bool = True
    # False: the rule may change output arity (unroll replicates outputs)
    preserves_outputs: bool = True

    def applicable(self, g: Graph) -> List[Site]:
        raise NotImplementedError

    def apply(self, g: Graph, site: Site) -> Graph:
        raise NotImplementedError


REGISTRY: Dict[str, Rewrite] = {}


def register(cls):
    """Class decorator: instantiate (default construction) and register."""
    inst = cls()
    REGISTRY[inst.name] = inst
    return cls


def default_rules() -> List[Rewrite]:
    """Every registered rule, in stable (name) order."""
    return [REGISTRY[k] for k in sorted(REGISTRY)]


# ------------------------------------------------------------------ fusion
def _fusable(op) -> bool:
    return op.opcode in ELEMENTWISE or op.opcode == FUSED_OP


def _chain_parts(op) -> List[str]:
    if op.opcode == FUSED_OP:
        return str(op.attrs.get("chain", FUSED_OP)).split("|")
    return [op.opcode]


@register
class FuseElementwise(Rewrite):
    """Collapse a producer→consumer elementwise chain into one ``fused``
    op. A chain extends through unary elementwise/fused consumers whose
    operand has exactly one use; the head may be any elementwise op (its
    operands become the fused op's operands)."""

    name = "fuse_elementwise"

    def chains(self, g: Graph) -> List[List[int]]:
        uses, prod = use_counts(g), producers(g)
        chains: List[List[int]] = []
        chain_of: Dict[int, List[int]] = {}
        for i, op in enumerate(g.ops):
            if not (_fusable(op) and len(op.operands) == 1):
                continue
            src = op.operands[0]
            j = prod.get(src)
            if j is None or not _fusable(g.ops[j]) or uses.get(src) != 1:
                continue
            ch = chain_of.get(j)
            if ch is None:
                ch = [j]
                chains.append(ch)
                chain_of[j] = ch
            ch.append(i)
            chain_of[i] = ch
        return chains

    def applicable(self, g: Graph) -> List[Site]:
        return [Site(self.name, tuple(ch)) for ch in self.chains(g)]

    def apply(self, g: Graph, site: Site) -> Graph:
        return _fuse(g, [list(site.detail)])


def _fuse(g: Graph, chains: List[List[int]]) -> Graph:
    members = {i for ch in chains for i in ch}
    last = {ch[-1]: ch for ch in chains}
    b = _Derive(g, g.name if g.name.endswith("_fused")
                else g.name + "_fused")
    first = min(members)
    b.copy_prefix(first)
    for i in range(first, len(g.ops)):
        op = g.ops[i]
        if i in members and i not in last:
            continue
        if i in last:
            ch = last[i]
            head = g.ops[ch[0]]
            parts = [p for j in ch for p in _chain_parts(g.ops[j])]
            nid = b.emit(FUSED_OP,
                         [b.id_map[o] for o in head.operands],
                         g.values[op.result],
                         n_fused=len(parts), chain="|".join(parts))
            b.id_map[op.result] = nid
        else:
            b.copy(op)
    return b.finish()


def fuse_elementwise(g: Graph) -> Graph:
    """Fuse every producer→consumer elementwise chain into single
    ``xpu.fused`` ops (each carrying ``n_fused`` + ``chain`` attrs), the
    graph-level operator-fusion transform. Runs to fixpoint; a graph with
    no chains is returned as a (renamed) structural copy."""
    rule: FuseElementwise = REGISTRY["fuse_elementwise"]  # type: ignore
    out = g
    for _ in range(4):                 # chains are maximal; 1 pass + slack
        chains = rule.chains(out)
        if not chains:
            break
        out = _fuse(out, chains)
    return out


# --------------------------------------------------------------------- CSE
def _op_signature(g: Graph, op) -> Tuple:
    return (op.opcode, tuple(op.operands),
            tuple(sorted(op.attrs.items())), g.values[op.result])


@register
class CommonSubexpression(Rewrite):
    """Dedup structurally-identical ops: same opcode, same operand ids,
    same attrs, same result type. Transitively-equal subtrees converge
    under repeated application (each merge makes the parents' operand
    lists equal)."""

    name = "cse"

    def applicable(self, g: Graph) -> List[Site]:
        seen: Dict[Tuple, int] = {}
        sites = []
        for i, op in enumerate(g.ops):
            sig = _op_signature(g, op)
            if sig in seen:
                sites.append(Site(self.name, (i, seen[sig])))
            else:
                seen[sig] = i
        return sites

    def apply(self, g: Graph, site: Site) -> Graph:
        dup, canon = site.detail
        assert _op_signature(g, g.ops[dup]) == \
            _op_signature(g, g.ops[canon]), "stale CSE site"
        b = _Derive(g)
        b.copy_prefix(dup)
        b.alias(g.ops[dup].result, b.id_map[g.ops[canon].result])
        for op in g.ops[dup + 1:]:
            b.copy(op)
        return b.finish()


# --------------------------------------------------------------------- DCE
@register
class DeadOpElimination(Rewrite):
    """Drop an op whose result has no uses and is not a graph output."""

    name = "dce"

    def applicable(self, g: Graph) -> List[Site]:
        uses = use_counts(g)
        return [Site(self.name, (i,)) for i, op in enumerate(g.ops)
                if uses.get(op.result, 0) == 0]

    def apply(self, g: Graph, site: Site) -> Graph:
        (dead,) = site.detail
        b = _Derive(g)
        b.copy_prefix(dead)
        for op in g.ops[dead + 1:]:
            b.copy(op)
        return b.finish()


# --------------------------------------------------- recompute vs materialize
@register
class RecomputeCheapProducer(Rewrite):
    """Give each consumer of a cheap (elementwise) fan-out producer its
    own private copy. Alone this adds arithmetic; its value is that each
    copy is single-use, so fusion can then swallow it into its consumer
    — the classic recompute-instead-of-materialize tradeoff, discovered
    by the *search over sequences* rather than any one-shot advisor."""

    name = "recompute"

    def applicable(self, g: Graph) -> List[Site]:
        # one pass over operand slots (distinct consumer OPS per value),
        # not a per-op rescan of the whole op list — applicable() runs
        # for every frontier parent on every expansion, so the old
        # O(n_ops^2) walk was a measurable share of search wall time
        consumers: Dict[int, set] = {}
        for j, c in enumerate(g.ops):
            for o in c.operands:
                consumers.setdefault(o, set()).add(j)
        return [Site(self.name, (i,)) for i, op in enumerate(g.ops)
                if _fusable(op) and len(consumers.get(op.result, ())) >= 2]

    def apply(self, g: Graph, site: Site) -> Graph:
        (pi,) = site.detail
        prod = g.ops[pi]
        consumers = [j for j, c in enumerate(g.ops)
                     if prod.result in c.operands]
        assert len(consumers) >= 2, "stale recompute site"
        b = _Derive(g)
        dup_consumers = set(consumers[1:])
        first = consumers[1]
        b.copy_prefix(first)
        for i in range(first, len(g.ops)):
            op = g.ops[i]
            if i in dup_consumers:
                # the private clone is itself a verbatim copy of the
                # producer (hash-identical); the consumer re-hashes
                clone = b.copy(prod, remap=False)
                operands = [clone if o == prod.result else b.id_map[o]
                            for o in op.operands]
                b.id_map[op.result] = b.emit(
                    op.opcode, operands, g.values[op.result], **op.attrs)
            else:
                b.copy(op)
        return b.finish()


# ---------------------------------------------------------- dtype narrowing
@register
class DtypeNarrow(Rewrite):
    """Narrow every f32 *intermediate* (op results that are not graph
    outputs) to bf16. Graph outputs keep their shape AND dtype, so the
    interface is preserved; the tokenizer emits ``...xbf16`` shape tokens
    for the narrowed values, and the roofline oracle charges half the
    HBM bytes for them."""

    name = "dtype_narrow"
    preserves_dtypes = False

    def applicable(self, g: Graph) -> List[Site]:
        outs = set(g.outputs)
        if any(op.result not in outs
               and g.values[op.result].dtype == "f32" for op in g.ops):
            return [Site(self.name)]
        return []

    def apply(self, g: Graph, site: Site) -> Graph:
        outs = set(g.outputs)
        b = _Derive(g)
        ops, n = g.ops, len(g.ops)
        first = 0
        while first < n:
            t = g.values[ops[first].result]
            if ops[first].result not in outs and t.dtype == "f32":
                break
            first += 1
        b.copy_prefix(first)
        for op in ops[first:]:
            t = g.values[op.result]
            if op.result not in outs and t.dtype == "f32":
                b.id_map[op.result] = b.emit(
                    op.opcode, [b.id_map[o] for o in op.operands],
                    Tensor(t.shape, "bf16"), **op.attrs)
            else:
                b.copy(op)
        return b.finish()


# ------------------------------------------------------------------ unroll
def unroll_graph(g: Graph, factor: int) -> Graph:
    """Model loop unrolling of the graph body: replicate ops with renamed
    SSA ids (shared args), as an unrolled inner loop would look to the
    cost model. Every replica op is a verbatim copy of its original, so
    the unrolled graph's struct_key inherits all per-value hashes and
    re-hashes nothing."""
    new = Graph(name=f"{g.name}_u{factor}")
    new.values = list(g.values[:g.n_args])
    new.n_args = g.n_args
    copied = {i: i for i in range(g.n_args)}
    outs = []
    na, k = g.n_args, len(g.values) - g.n_args
    seq = _seq_layout(g)
    for rep in range(factor):
        if seq and rep == 0:
            # replica 0 is an identity copy: bulk-share the parent ops
            # (immutable) instead of re-building them one by one
            new.values.extend(g.values[na:])
            new.ops.extend(g.ops)
            ids = range(na, len(g.values))
            copied.update(zip(ids, ids))
            outs.extend(g.outputs)
            continue
        if seq:
            # replica r's ids are the parent's shifted by a constant
            # rep*k (op i yields value na+i), so operand renaming is
            # arithmetic — no per-op id_map dict
            off = rep * k
            new.values.extend(g.values[na:])
            new.ops.extend(
                Op(op.opcode,
                   [o if o < na else o + off for o in op.operands],
                   op.result + off, op.attrs)
                for op in g.ops)
            copied.update(zip(range(na + off, na + off + k),
                              range(na, na + k)))
            outs.extend(o if o < na else o + off for o in g.outputs)
            continue
        id_map = {i: i for i in range(na)}
        for op in g.ops:
            # fast verbatim copy (see _Derive.copy): attrs dict shared,
            # no add_op overhead — every replica op is a clean copy
            new.values.append(g.values[op.result])
            nid = len(new.values) - 1
            new.ops.append(Op(op.opcode,
                              [id_map[o] for o in op.operands], nid,
                              op.attrs))
            id_map[op.result] = nid
            copied[nid] = op.result
        outs.extend(id_map[o] for o in g.outputs)
    new.outputs = outs
    new.adopt_hashes(g, copied)
    new.validate()
    return new


@register
class Unroll(Rewrite):
    """Unroll the body by a factor; per-replica outputs keep the original
    shapes, so Site.weight = factor lets an objective judge per-iteration
    cost. ``max_ops`` bounds the unrolled size (None disables)."""

    name = "unroll"
    preserves_outputs = False

    def __init__(self, factors: Tuple[int, ...] = (2, 4),
                 max_ops: Optional[int] = 64):
        self.factors = tuple(factors)
        self.max_ops = max_ops

    def applicable(self, g: Graph) -> List[Site]:
        return [Site(self.name, (f,), weight=f) for f in self.factors
                if g.ops and (self.max_ops is None
                              or len(g.ops) * f <= self.max_ops)]

    def apply(self, g: Graph, site: Site) -> Graph:
        (factor,) = site.detail
        return check_legal(g, unroll_graph(g, factor),
                           preserve_outputs=False)


# ------------------------------------------------------- corpus augmentation
def random_rewrite(g: Graph, rng, rules: Optional[List[Rewrite]] = None,
                   max_steps: int = 3) -> Graph:
    """Apply 1..max_steps randomly-chosen legal rewrites (uniform over
    *rules* first, then over that rule's sites, so rare rules stay
    represented). Deterministic given the rng state — the dataset
    builder's two-pass count-then-encode contract — and the way fused /
    bf16 IR text gets into training corpora (and hence the vocab)."""
    rules = list(rules) if rules is not None else default_rules()
    out = g
    for _ in range(int(rng.integers(1, max_steps + 1))):
        firing = [(r, s) for r in rules
                  for s in [r.applicable(out)] if s]
        if not firing:
            break
        rule, sites = firing[int(rng.integers(0, len(firing)))]
        out = rule.apply(out, sites[int(rng.integers(0, len(sites)))])
    return out
