"""Closed-loop evaluation: does the model actually steer the compiler?

The paper's deployment question made measurable. For each input graph
the harness (1) runs the model-guided search, (2) *replays* the chosen
rewrite sequence from scratch — every step re-applied and legality-
checked, and the result must reproduce the search's best graph
struct-key-for-struct-key — and (3) judges the outcome with the
``ir/analyzers`` ground-truth oracle, never the model: predicted vs
oracle improvement, win rate against the one-shot FusionAdvisor
baseline, and Spearman rank correlation between predicted and oracle
latency over every candidate the search costed.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.ir import analyzers
from repro.ir.graph import Graph
from repro.opt import rewrites as RW
from repro.opt import search as SE


def _ranks(x: np.ndarray) -> np.ndarray:
    """Average ranks (ties share their mean rank)."""
    order = np.argsort(x, kind="stable")
    ranks = np.empty(len(x), np.float64)
    vals = x[order]
    i = 0
    while i < len(vals):
        j = i
        while j + 1 < len(vals) and vals[j + 1] == vals[i]:
            j += 1
        ranks[order[i:j + 1]] = (i + j) / 2.0
        i = j + 1
    return ranks


def spearman(a: Sequence[float], b: Sequence[float]) -> float:
    """Spearman rank correlation (0.0 when degenerate)."""
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    if len(a) < 2 or np.all(a == a[0]) or np.all(b == b[0]):
        return 0.0
    rho = np.corrcoef(_ranks(a), _ranks(b))[0, 1]
    return float(rho) if math.isfinite(rho) else 0.0


def replay(result: SE.SearchResult,
           rules: Optional[Sequence[RW.Rewrite]] = None) -> Graph:
    """Re-apply the chosen sequence from the root, legality-checked step
    by step; assert it reproduces the search's best graph."""
    by_name = {r.name: r for r in
               (rules if rules is not None else RW.default_rules())}
    g = result.root
    for rname, site in result.best_seq:
        g = by_name[rname].apply(g, site)
    assert g.struct_key() == result.best.struct_key(), \
        "replayed sequence does not reproduce the searched graph"
    return g


def fusion_baseline(service, g: Graph,
                    latency_target: str = "latency_us") -> Graph:
    """The pre-PR-4 one-shot FusionAdvisor: fully fuse, keep the fused
    graph iff the model predicts it cheaper."""
    fused = RW.fuse_elementwise(g)
    t = service.resolve_target(latency_target)
    c = service.predict_all([g, fused])[t]
    return fused if c[1] < c[0] else g


def evaluate_search(service, graphs: Sequence[Graph], *,
                    rules: Optional[Sequence[RW.Rewrite]] = None,
                    objective: Optional[SE.Objective] = None,
                    beam_width: int = 4, max_steps: int = 5,
                    max_candidates: int = 64, eval_budget: int = 256,
                    greedy: bool = False) -> Dict:
    """Search every graph, replay + oracle-judge every outcome.

    Returns ``{"per_graph": [...], "summary": {...}}``; all latencies are
    oracle (``ir/analyzers``) microseconds except the ``pred_*`` fields.
    """
    rules = list(rules) if rules is not None else RW.default_rules()
    obj = objective or SE.Objective()
    lat_t = service.resolve_target(obj.latency_target)
    per: List[Dict] = []
    pred_lat: List[float] = []
    oracle_lat: List[float] = []
    search_rhos: List[float] = []
    for g in graphs:
        res = SE.beam_search(service, g, rules, objective=obj,
                             beam_width=beam_width, max_steps=max_steps,
                             max_candidates=max_candidates,
                             eval_budget=eval_budget, greedy=greedy,
                             record_candidates=True)
        final = replay(res, rules)
        base = fusion_baseline(service, g, obj.latency_target)
        cand_pred = [pl for _, pl in res.candidates]
        cand_oracle = [analyzers.latency_us(cg)
                       for cg, _ in res.candidates]
        pred_lat.extend(cand_pred)
        oracle_lat.extend(cand_oracle)
        rho = spearman(cand_pred, cand_oracle) \
            if len(cand_pred) >= 3 else None
        if rho is not None:
            # within-search ranking is what beam selection depends on
            search_rhos.append(rho)
        per.append({
            "spearman_candidates": rho,
            "graph": g.name,
            "n_ops": len(g.ops),
            "oracle_root": analyzers.latency_us(g),
            "oracle_best": analyzers.latency_us(final),
            "oracle_fuse_baseline": analyzers.latency_us(base),
            "pred_root": res.root_preds[lat_t],
            "pred_best": res.best_preds[lat_t],
            "steps": len(res.best_seq),
            "evaluated": res.evaluated,
            "expansions": res.expansions,
            "predict_calls": res.predict_calls,
            "seq": [repr(s) for _, s in res.best_seq],
        })
    o_root = np.asarray([r["oracle_root"] for r in per])
    o_best = np.asarray([r["oracle_best"] for r in per])
    o_base = np.asarray([r["oracle_fuse_baseline"] for r in per])
    p_root = np.asarray([r["pred_root"] for r in per])
    p_best = np.asarray([r["pred_best"] for r in per])
    eps = 1e-12
    summary = {
        "n_graphs": len(per),
        "mean_oracle_root_us": float(o_root.mean()),
        "mean_oracle_best_us": float(o_best.mean()),
        "mean_oracle_baseline_us": float(o_base.mean()),
        # improvements are relative to the unoptimized root
        "oracle_improvement_mean": float(
            np.mean(1.0 - o_best / np.maximum(o_root, eps))),
        "baseline_oracle_improvement_mean": float(
            np.mean(1.0 - o_base / np.maximum(o_root, eps))),
        "pred_improvement_mean": float(
            np.mean(1.0 - p_best / np.maximum(p_root, eps))),
        "frac_improved_vs_root": float(
            np.mean(o_best < o_root - eps)),
        "frac_strictly_better_than_baseline": float(
            np.mean(o_best < o_base - eps)),
        # mean WITHIN-search rank correlation over each search's costed
        # candidates — the ranking beam selection actually relies on.
        # The pooled variant mixes graphs of very different sizes, so a
        # model that only ranked big-vs-small would score high on it;
        # kept for reference, labeled as such.
        "spearman_pred_oracle": float(np.mean(search_rhos))
        if search_rhos else 0.0,
        "spearman_pred_oracle_pooled": spearman(pred_lat, oracle_lat),
        "candidates_costed": int(sum(r["evaluated"] for r in per)),
        "predict_calls": int(sum(r["predict_calls"] for r in per)),
    }
    return {"per_graph": per, "summary": summary}
