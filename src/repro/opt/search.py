"""Batched beam search over rewrite sequences, guided by the cost model.

The consumer the whole serving stack exists for: every frontier expansion
gathers ALL candidate graphs from every beam state × rule × site and
costs them in ONE ``service.predict_all`` call — which, when ``service``
is the async :class:`~repro.core.server.CostModelServer`, rides the
bucketed micro-batching, in-flight dedup, and shared LRU for free (a
graph costed while optimizing one function is a cache hit while
optimizing the next).

Search state is deduplicated by :meth:`Graph.struct_key`, so re-deriving
an already-visited program through a different rewrite order costs
nothing. A per-search candidate budget bounds total model queries.

``Objective`` is the composite scoring knob: minimize a latency target
subject to a register-pressure constraint (pluggable target names per
deploy target; candidates over budget score ``inf``, so the constraint
is hard while the incumbent stays the fallback).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.ir.graph import Graph
from repro.opt import rewrites as RW


@dataclass
class Objective:
    """Minimize ``latency_target``; constrain ``pressure_target``.

    With an infinite budget (default) or a service that does not serve
    the pressure head, scoring is pure latency. ``Site.weight`` divides
    latency (an unroll by f does f iterations' work)."""

    latency_target: str = "latency_us"
    pressure_target: Optional[str] = "register_pressure"
    register_budget: float = float("inf")

    def bind(self, service) -> "BoundObjective":
        lat = service.resolve_target(self.latency_target)
        reg = None
        if self.pressure_target is not None and \
                np.isfinite(self.register_budget):
            try:
                reg = service.resolve_target(self.pressure_target)
            except (KeyError, ValueError) as e:
                raise ValueError(
                    f"register_budget={self.register_budget} needs a "
                    f"service with a {self.pressure_target!r} head; "
                    f"got heads={list(service.heads)}") from e
            if reg == lat:
                # a single-head service would silently judge feasibility
                # on latency numbers — refuse instead (same policy as
                # UnrollAdvisor)
                raise ValueError(
                    f"register_budget={self.register_budget} needs "
                    f"distinct {self.latency_target!r} and "
                    f"{self.pressure_target!r} heads; "
                    f"got heads={list(service.heads)}")
        return BoundObjective(self, lat, reg)


@dataclass
class BoundObjective:
    """Objective resolved against one service's heads."""

    spec: Objective
    lat_t: str
    reg_t: Optional[str]

    def scores(self, preds: Dict[str, np.ndarray],
               weights: Optional[Sequence[float]] = None) -> np.ndarray:
        lat = np.asarray(preds[self.lat_t], np.float64)
        if weights is not None:
            lat = lat / np.asarray(weights, np.float64)
        if self.reg_t is None:
            return lat
        reg = np.asarray(preds[self.reg_t], np.float64)
        return np.where(reg > self.spec.register_budget, np.inf, lat)


def cost_graphs(service, graphs: Sequence[Graph],
                objective: BoundObjective,
                weights: Optional[Sequence[float]] = None
                ) -> Tuple[np.ndarray, Dict[str, np.ndarray]]:
    """Score a candidate set with ONE batched ``predict_all`` through the
    serving stack. Returns (scores, denormalized per-target rows)."""
    preds = service.predict_all(list(graphs))
    return objective.scores(preds, weights), preds


@dataclass
class _State:
    graph: Graph
    key: str
    seq: List[Tuple[str, RW.Site]]
    score: float
    preds: Dict[str, float]


@dataclass
class SearchResult:
    root: Graph
    best: Graph
    best_seq: List[Tuple[str, RW.Site]]
    root_score: float
    best_score: float
    root_preds: Dict[str, float]
    best_preds: Dict[str, float]
    expansions: int = 0
    evaluated: int = 0               # candidates costed (root excluded)
    predict_calls: int = 0           # == 1 (root) + expansions
    # populated when record_candidates=True: (graph, predicted latency)
    candidates: Optional[List[Tuple[Graph, float]]] = None
    trace: List[Dict] = field(default_factory=list)

    @property
    def improved(self) -> bool:
        return bool(self.best_seq)

    def describe(self) -> str:
        if not self.best_seq:
            return "<no-op>"
        return " -> ".join(repr(s) for _, s in self.best_seq)


def _expand_lazy(frontier: List["_State"], rules, visited: set,
                 cap: int) -> List[Tuple]:
    """Round-robin over every parent's rewrite sites, constructing a
    candidate graph (rule.apply + struct_key) only when the cursor
    actually reaches its site under ``cap``.

    The eager version applied and hashed EVERY site's graph just to
    throw most away at the cap — at fleet scale that construction was
    the largest single share of search wall time. Illegal sites and
    already-visited keys don't consume cap slots (same contract as
    before: only candidates actually costed become visited)."""
    per_parent = [[(st, r, s) for r in rules
                   for s in r.applicable(st.graph)] for st in frontier]
    batch: List[Tuple] = []
    proposed = set()                     # this expansion's intra-dedup
    rank = 0
    while len(batch) < cap and any(rank < len(p) for p in per_parent):
        for lst in per_parent:
            if rank >= len(lst) or len(batch) >= cap:
                continue
            st, rule, site = lst[rank]
            try:
                ng = rule.apply(st.graph, site)
            except AssertionError:
                continue                 # illegal here: not a candidate
            key = ng.struct_key()
            if key in visited or key in proposed:
                continue
            proposed.add(key)
            batch.append((st, rule.name, site, ng, key))
        rank += 1
    return batch


def beam_search(service, g: Graph,
                rules: Optional[Sequence[RW.Rewrite]] = None, *,
                objective: Optional[Objective] = None,
                beam_width: int = 4, max_steps: int = 6,
                max_candidates: int = 64, eval_budget: int = 256,
                greedy: bool = False, preserve_outputs: bool = True,
                record_candidates: bool = False) -> SearchResult:
    """Beam search over rewrite sequences from ``g``.

    Per step: expand every frontier state through every rule site, dedup
    candidates against every struct_key visited this search, cost the
    whole set in ONE batched ``predict_all``, keep the ``beam_width``
    best. ``eval_budget`` caps total candidates costed; ``greedy=True``
    is the cheap mode — beam 1, stop at the first non-improving step.

    ``preserve_outputs`` (default) is the legality gate for a search
    whose result *replaces* the input function: rules that change output
    arity (unroll replicates the body's outputs) cannot yield a legal
    replacement — and no later rewrite restores the arity — so their
    sites are pruned up front. Factor-style decisions over such rules
    belong to weight-normalized single-rule searches (UnrollAdvisor);
    ``preserve_outputs=False`` admits them here too.
    """
    rules = list(rules) if rules is not None else RW.default_rules()
    if preserve_outputs:
        rules = [r for r in rules if r.preserves_outputs]
    if greedy:
        beam_width = 1
    obj = (objective or Objective()).bind(service)
    preds0 = service.predict_all([g])
    root_row = {t: float(v[0]) for t, v in preds0.items()}
    root_score = float(obj.scores(preds0)[0])
    root = _State(g, g.struct_key(), [], root_score, root_row)
    visited = {root.key}
    best = root
    frontier = [root]
    res = SearchResult(root=g, best=g, best_seq=[], root_score=root_score,
                       best_score=root_score, root_preds=root_row,
                       best_preds=root_row, predict_calls=1)
    if record_candidates:
        res.candidates = [(g, root_row[obj.lat_t])]
    for _ in range(max_steps):
        cap = min(max_candidates, eval_budget - res.evaluated)
        batch = _expand_lazy(frontier, rules, visited, cap) \
            if cap > 0 else []
        if not batch:
            break
        # only candidates actually costed become visited: states dropped
        # by the cap stay re-derivable by a later (affordable) expansion
        visited.update(c[4] for c in batch)
        # THE one batched model query of this frontier expansion
        preds = service.predict_all([c[3] for c in batch])
        res.predict_calls += 1
        res.expansions += 1
        res.evaluated += len(batch)
        scores = obj.scores(preds)
        states = []
        for i, (parent, rname, site, ng, key) in enumerate(batch):
            row = {t: float(v[i]) for t, v in preds.items()}
            states.append(_State(ng, key, parent.seq + [(rname, site)],
                                 float(scores[i]), row))
            if res.candidates is not None:
                res.candidates.append((ng, row[obj.lat_t]))
        states.sort(key=lambda s: s.score)
        res.trace.append({"candidates": len(batch),
                          "best_score": states[0].score})
        if states[0].score < best.score:
            best = states[0]
        if greedy and states[0].score >= frontier[0].score:
            break
        frontier = states[:beam_width]
        if res.evaluated >= eval_budget:
            break
    res.best = best.graph
    res.best_seq = best.seq
    res.best_score = best.score
    res.best_preds = best.preds
    return res


def greedy_search(service, g: Graph,
                  rules: Optional[Sequence[RW.Rewrite]] = None,
                  **kw) -> SearchResult:
    """Cheap mode: beam of 1, stop as soon as no candidate improves."""
    kw.setdefault("max_steps", 8)
    return beam_search(service, g, rules, greedy=True, **kw)


def search_pool(service, pool: Sequence[Graph], offset: int = 0,
                **search_kw) -> List[SearchResult]:
    """One fleet-worker pass: beam-search every graph in ``pool``,
    rotated by ``offset`` so concurrent workers traverse the same pool
    out of phase (maximizing in-flight coalescing and cross-search LRU
    hits without ever searching the same graph simultaneously).

    ``service`` is anything beam_search can cost through — an in-process
    CostModelService, an async CostModelServer gateway, or a replicated
    :class:`~repro.serving.router.ReplicaClient`."""
    k = offset % len(pool) if pool else 0
    gs = list(pool[k:]) + list(pool[:k])
    return [beam_search(service, g, **search_kw) for g in gs]
