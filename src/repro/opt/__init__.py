"""repro.opt — the cost-model-guided graph optimization engine.

The paper trains the cost model so the DL compiler can "make the best
decisions" during graph-level optimization. This package is that
compiler-in-the-loop consumer, as a first-class subsystem:

* :mod:`repro.opt.rewrites` — a registry of legality-checked rewrite
  rules over the ``xpu`` dataflow IR (fusion, CSE, DCE, recompute,
  dtype narrowing, unrolling).
* :mod:`repro.opt.search` — batched beam/greedy search over rewrite
  *sequences*; every frontier expansion costs all candidates in ONE
  ``predict_all`` call through the micro-batching serving stack.
* :mod:`repro.opt.evaluate` — closed-loop harness replaying chosen
  sequences against the ``ir/analyzers`` ground-truth oracle
  (predicted-vs-oracle improvement + rank correlation).
"""
from repro.opt import evaluate, rewrites, search  # noqa: F401
from repro.opt.rewrites import (  # noqa: F401
    REGISTRY, Rewrite, Site, default_rules, fuse_elementwise,
    random_rewrite, unroll_graph)
from repro.opt.search import (  # noqa: F401
    Objective, SearchResult, beam_search, cost_graphs, greedy_search)
from repro.opt.evaluate import evaluate_search, replay  # noqa: F401
