"""Divisibility-aware logical-axis sharding resolver.

Logical tensor axes (``"batch"``, ``"vocab"``, ``"heads"``, ``"ffn"``,
``"experts"``, ``"seq"``, ``"embed"``, ...) are mapped to mesh axes by a rule
table. A mesh axis is *dropped* (falls back to replication for that dim) when
the dimension size is not divisible by the mesh axis size — GSPMD rejects
uneven explicit shardings, and this resolver is what lets one rule table
serve every architecture (e.g. 40 attention heads cannot shard over a 16-way
``model`` axis; the resolver drops it and the context-parallel ``seq`` rule
picks up the parallelism instead).
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisRule = Union[None, str, Tuple[str, ...]]

# Default logical->mesh rules. 'pod' composes with 'data' for the batch dim
# so the same table serves single-pod (no 'pod' axis) and multi-pod meshes.
DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    # batch spreads over the model axis too when divisible (wide DP): the
    # §Perf hillclimb showed per-layer TP activation collectives dominate
    # train steps at every model size (0.6B..52B), while weight gathers
    # (FSDP, from the 2D param sharding below) are smaller and overlappable.
    # Smaller batches (prefill 32, decode 128) gracefully fall back to
    # data-only sharding via the divisibility resolver.
    "batch":   ("pod", "data", "model"),
    "vocab":   ("model",),
    "heads":   ("model",),      # q heads
    "kv_heads": ("model",),     # usually dropped (kv < 16) -> replicated
    "ffn":     ("model",),
    "experts": ("model",),
    "embed":   ("data",),       # d_model dim of PARAMS: FSDP-style 2D
                                # sharding (model x data) so 30-50B param
                                # + optimizer states fit 16 GB/chip; on
                                # activations the batch dim claims "data"
                                # first, so h stays batch-sharded
    "seq":     (),              # train/prefill seq: context-parallel override
    "cache_seq": ("model",),    # decode KV-cache sequence dim
    "qseq":    ("model",),      # query-seq context parallelism: picks up the
                                # model axis when head sharding can't (the
                                # attention layer gates this on divisibility)
    "conv_seq": (),
    "stack":   (),              # scanned-layer leading dim: never sharded
}


class ShardingRules:
    """Resolves logical axis names to PartitionSpecs on a concrete mesh."""

    def __init__(self, mesh: Mesh,
                 overrides: Optional[Dict[str, AxisRule]] = None):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES)
        if overrides:
            for k, v in overrides.items():
                if v is None:
                    self.rules[k] = ()
                elif isinstance(v, str):
                    self.rules[k] = (v,)
                else:
                    self.rules[k] = tuple(v)
        self.axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def _axes_for(self, logical: Optional[str],
                  dim: int) -> Optional[Tuple[str, ...]]:
        if logical is None:
            return None
        axes = [a for a in self.rules.get(logical, ()) if a in self.axis_sizes]
        kept = []
        remaining = dim
        for a in axes:
            n = self.axis_sizes[a]
            if remaining % n == 0 and n > 1:
                kept.append(a)
                remaining //= n
        if not kept:
            return None
        return tuple(kept)

    def spec(self, logical_axes: Sequence[Optional[str]],
             shape: Sequence[int]) -> P:
        assert len(logical_axes) == len(shape), (logical_axes, shape)
        used: set = set()
        parts = []
        for name, dim in zip(logical_axes, shape):
            axes = self._axes_for(name, dim)
            if axes is None:
                parts.append(None)
                continue
            axes = tuple(a for a in axes if a not in used)
            if not axes:
                parts.append(None)
                continue
            used.update(axes)
            parts.append(axes if len(axes) > 1 else axes[0])
        return P(*parts)

    def sharding(self, logical_axes: Sequence[Optional[str]],
                 shape: Sequence[int]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical_axes, shape))

    def constrain(self, x, *logical_axes):
        """with_sharding_constraint using logical axes for x's shape."""
        return jax.lax.with_sharding_constraint(
            x, self.sharding(logical_axes, x.shape))

    def divisible(self, dim: int, axis: str) -> bool:
        n = self.axis_sizes.get(axis, 1)
        return n > 1 and dim % n == 0


def tree_shardings(rules: ShardingRules, tree_axes, tree_shapes):
    """Map a pytree of logical-axis tuples + matching shapes to
    NamedShardings."""
    return jax.tree.map(
        lambda axes, shape: rules.sharding(axes, shape),
        tree_axes, tree_shapes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
