"""Fault-tolerant training runtime: checkpoint/restart loop, preemption
handling, heartbeat-based straggler detection, elastic re-mesh.

The pieces a 1000+-node job needs, host-side (none of this is simulated in
the math — these run for real in the drivers; only the *failures* are
injected in tests):

* TrainSupervisor — owns the step loop; periodic + on-signal checkpointing,
  automatic resume from the last committed step (with the data-pipeline
  cursor), bounded retry on transient step failures.
* HeartbeatMonitor — per-worker heartbeats; workers falling behind the
  p50 step time by `straggler_factor` are flagged; the supervisor's policy
  hook can rebalance data shards or evict.
* ElasticPolicy — on re-mesh (pod added/removed), recompute shardings and
  restore the same checkpoint onto the new topology (ckpt.py stores
  gathered arrays, so reshard = device_put with new shardings).
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.checkpoint import ckpt


@dataclass
class HeartbeatMonitor:
    """Tracks per-worker heartbeat timestamps and step durations."""
    n_workers: int
    straggler_factor: float = 2.0
    timeout_s: float = 60.0
    last_beat: Dict[int, float] = field(default_factory=dict)
    durations: Dict[int, List[float]] = field(default_factory=dict)

    def beat(self, worker: int, step_duration: Optional[float] = None,
             now: Optional[float] = None):
        now = time.monotonic() if now is None else now
        self.last_beat[worker] = now
        if step_duration is not None:
            self.durations.setdefault(worker, []).append(step_duration)
            self.durations[worker] = self.durations[worker][-32:]

    def _median_duration(self) -> Optional[float]:
        all_d = sorted(d for ds in self.durations.values() for d in ds)
        return all_d[len(all_d) // 2] if all_d else None

    def stragglers(self, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        med = self._median_duration()
        out = []
        for w in range(self.n_workers):
            if now - self.last_beat.get(w, now) > self.timeout_s:
                out.append(w)
                continue
            ds = self.durations.get(w)
            if med and ds and ds[-1] > self.straggler_factor * med:
                out.append(w)
        return out

    def rebalance_shards(self, shards: Dict[int, int],
                         now: Optional[float] = None) -> Dict[int, int]:
        """Move one unit of data-shard weight away from each straggler."""
        slow = set(self.stragglers(now=now))
        fast = [w for w in shards if w not in slow]
        if not fast:
            return shards
        new = dict(shards)
        for w in slow:
            if new.get(w, 0) > 0:
                new[w] -= 1
                new[min(fast, key=lambda f: new.get(f, 0))] += 1
        return new


@dataclass
class TrainSupervisor:
    """Checkpoint/restart step-loop wrapper.

    ``ckpt_dir=None`` disables persistence: the loop (and its retry
    policy) still runs, saves become no-ops and restore finds nothing —
    this is how the TrainEngine serves throwaway in-memory training and
    production resumable training through ONE step loop."""
    ckpt_dir: Optional[str]
    save_every: int = 100
    keep: int = 3
    max_step_retries: int = 2
    preempted: bool = field(default=False, init=False)

    def install_signal_handler(self):
        def _handler(signum, frame):
            self.preempted = True
        signal.signal(signal.SIGTERM, _handler)

    def _save(self, step, state, extra_fn: Optional[Callable]):
        if self.ckpt_dir is None:
            return
        ckpt.save(self.ckpt_dir, step, state,
                  extra=(extra_fn() if extra_fn else {}), keep=self.keep)

    def try_restore(self, state, shardings=None, check_treedef: bool = True):
        """Returns (state, start_step, extra) — or the inputs if no ckpt.

        check_treedef is forwarded to ckpt.restore; pass False to resume
        across benign treedef-repr drift (e.g. a JAX upgrade)."""
        if self.ckpt_dir is None:
            return state, 0, {}
        try:
            state, step, extra = ckpt.restore(self.ckpt_dir, state,
                                              shardings=shardings,
                                              check_treedef=check_treedef)
            return state, step, extra
        except FileNotFoundError:
            return state, 0, {}

    def run(self, state, step_fn: Callable, n_steps: int, *,
            start_step: int = 0, extra_fn: Callable = None,
            on_step: Callable = None) -> Any:
        """step_fn(state, step) -> state. Checkpoints every save_every and on
        preemption; retries a failing step up to max_step_retries."""
        step = start_step
        while step < n_steps:
            t0 = time.monotonic()
            attempt = 0
            while True:
                try:
                    state = step_fn(state, step)
                    break
                except Exception:
                    attempt += 1
                    if attempt > self.max_step_retries:
                        self._save(step, state, extra_fn)
                        raise
            step += 1
            if on_step:
                on_step(step, time.monotonic() - t0)
            if step % self.save_every == 0 or self.preempted:
                self._save(step, state, extra_fn)
                if self.preempted:
                    return state
        self._save(n_steps, state, extra_fn)
        return state


def elastic_reshard(state, old_mesh_shape, new_rules, abstract_state_axes):
    """Recompute shardings for a new mesh and re-place the state."""
    import jax
    shardings = jax.tree.map(
        lambda leaf, axes: new_rules.sharding(axes, leaf.shape),
        state, abstract_state_axes,
        is_leaf=lambda x: hasattr(x, "shape"))
    return jax.tree.map(jax.device_put, state, shardings)
