"""Production mesh construction.

A function, not a module-level constant, so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before first init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 4, *,
                    multi_pod: bool = False):
    """Small mesh for tests (requires >= n_data*n_model host devices)."""
    shape = (2, n_data, n_model) if multi_pod else (n_data, n_model)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_single_device_mesh():
    return jax.make_mesh((1, 1), ("data", "model"))
