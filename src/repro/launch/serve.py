"""Batched inference server driver for the deployed cost model.

Simulates the DL-compiler's usage pattern: bursts of small prediction
requests (one per candidate transformation) that the service batches,
buckets by sequence length, caches (bounded LRU), and answers. One
multi-head service predicts every hardware characteristic — register
pressure, vALU utilization, latency — from a single encoder forward
pass. Prints throughput and cache statistics.

    PYTHONPATH=src python -m repro.launch.serve --requests 2000
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs.costmodel import CostModelConfig
from repro.core import models as CM
from repro.core import trainer as TR
from repro.core.service import (CostModelService, FusionAdvisor,
                                RecompileAdvisor, UnrollAdvisor)
from repro.core import augment as AUG
from repro.ir import dataset as DS, samplers


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=500)
    ap.add_argument("--train-steps", type=int, default=400)
    ap.add_argument("--n-graphs", type=int, default=1500)
    ap.add_argument("--cache-size", type=int, default=4096)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = CostModelConfig(name="serve", vocab_size=4096, max_seq=160,
                          embed_dim=64, conv_channels=(64,) * 6,
                          fc_dims=(256, 64))
    ds = DS.build_dataset(args.n_graphs, mode="ops", max_seq=160,
                          vocab_size=4096, augment_factor=2, seed=args.seed)
    tr, te = ds.split(0.1)
    print(f"training joint multi-target cost model "
          f"({', '.join(CM.DEFAULT_HEADS)})...")
    engine = TR.TrainEngine("conv1d", cfg, CM.DEFAULT_HEADS,
                            steps=args.train_steps, batch_size=128,
                            lr=2e-3, seed=args.seed)
    res = engine.fit(tr)
    print(f"trained at {res.stats['steps_per_s']:.1f} steps/s "
          f"(bucketed batches)")

    svc = CostModelService("conv1d", cfg, res.params, ds.vocab,
                           res.norm_stats, mode="ops", max_seq=160,
                           cache_size=args.cache_size)
    print(f"service heads={list(svc.heads)} buckets={list(svc.buckets)} "
          f"cache_bound={svc.cache_size}")

    rng = np.random.default_rng(args.seed + 1)
    graphs = [samplers.sample_graph(rng) for _ in range(args.requests // 2)]
    # compiler sessions re-query slightly-modified graphs: 50% cache hits
    graphs = graphs + [g for g in graphs]
    rng.shuffle(graphs)

    t0 = time.time()
    preds = svc.predict_all(graphs)
    dt = time.time() - t0
    n_targets = len(svc.heads)
    print(f"served {len(graphs)} requests x {n_targets} targets in "
          f"{dt:.2f}s ({len(graphs)/dt:.0f} req/s, "
          f"{len(graphs)*n_targets/dt:.0f} predictions/s, "
          f"cache={len(svc._cache)} unique)")
    lat = preds["latency_us"]
    print(f"predicted latency: p50={np.median(lat):.1f}us "
          f"max={lat.max():.1f}us")

    fusion = FusionAdvisor(svc)
    unroll = UnrollAdvisor(svc, register_budget=64)
    recompile = RecompileAdvisor(svc)

    g = samplers.sample_graph(rng, "resnet")
    do_fuse, c0, c1 = fusion.advise(g)
    print(f"fusion advisor: fuse={do_fuse} "
          f"(unfused={c0:.1f}us fused={c1:.1f}us)")
    adv = unroll.advise(g)
    print(f"unroll advisor: best_factor={adv['best_factor']} "
          f"per-iter latency={ {k: round(v,1) for k, v in adv['per_iter_latency'].items()} }")
    g2 = AUG.jitter_shapes(g, rng)
    dec = recompile.advise(g, g2)
    print(f"recompile advisor: recompile={dec['recompile']} "
          f"shift={dec['shift']:.1%}")


if __name__ == "__main__":
    main()
