"""Async micro-batching inference gateway for the deployed cost model.

Simulates the DL-compiler's real usage pattern: many concurrent clients
(one per compile thread doing fusion/unroll/recompile search), each
issuing bursts of small prediction requests. The CostModelServer merges
them into coalesced per-bucket batches (flush on full batch or a
deadline), answers LRU-cached repeats at submit time, and pre-compiles
every (bucket x batch-ladder) XLA program at startup. One multi-head
service predicts every hardware characteristic — register pressure,
vALU utilization, latency — from a single encoder forward pass.

    PYTHONPATH=src python -m repro.launch.serve --requests 2000 \
        --concurrency 16 --flush-us 2000
"""
from __future__ import annotations

import argparse
import threading
import time

import numpy as np

from repro.configs.costmodel import CostModelConfig
from repro.core import augment as AUG
from repro.core import models as CM
from repro.core import trainer as TR
from repro.core.server import CostModelServer
from repro.core.service import (CostModelService, FusionAdvisor,
                                RecompileAdvisor, UnrollAdvisor)
from repro.ir import dataset as DS
from repro.ir import samplers


def run_clients(server: CostModelServer, graphs, concurrency: int) -> float:
    """Closed-loop clients: each thread owns a slice of the request
    stream and submits its next request as soon as the previous one
    resolves. Returns wall seconds for the whole stream."""
    slices = [graphs[i::concurrency] for i in range(concurrency)]
    errs = []

    def client(gs):
        try:
            for g in gs:
                server.predict_all([g])
        except Exception as e:          # surface, don't hang the driver
            errs.append(e)

    threads = [threading.Thread(target=client, args=(s,)) for s in slices]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    dt = time.perf_counter() - t0
    if errs:
        raise errs[0]
    return dt


def main():
    ap = argparse.ArgumentParser(
        description="Train a small multi-target cost model, then serve it "
                    "through the async micro-batching CostModelServer "
                    "under closed-loop concurrent clients.",
        formatter_class=argparse.ArgumentDefaultsHelpFormatter)
    ap.add_argument("--requests", type=int, default=500,
                    help="total prediction requests across all clients "
                         "(stream has ~50%% repeated graphs, like a "
                         "compiler re-querying modified candidates)")
    ap.add_argument("--concurrency", type=int, default=8,
                    help="closed-loop client threads submitting "
                         "concurrently; their requests coalesce into "
                         "shared batched forward passes")
    ap.add_argument("--flush-us", type=float, default=2000.0,
                    help="micro-batch flush deadline in microseconds: a "
                         "partially-filled bucket queue is flushed once "
                         "its oldest request has waited this long")
    ap.add_argument("--max-batch", type=int, default=64,
                    help="flush a bucket queue as soon as it holds this "
                         "many unique requests (full-batch path)")
    ap.add_argument("--max-queue", type=int, default=4096,
                    help="bound on queued entries across all buckets; "
                         "beyond it submits fail fast with "
                         "ServerOverloadedError (load shed)")
    ap.add_argument("--no-warmup", action="store_true",
                    help="skip AOT pre-compilation of the (bucket x "
                         "batch-ladder) XLA programs at startup")
    ap.add_argument("--train-steps", type=int, default=400,
                    help="training steps for the demo model")
    ap.add_argument("--n-graphs", type=int, default=1500,
                    help="synthetic training-set size")
    ap.add_argument("--cache-size", type=int, default=4096,
                    help="LRU prediction-cache bound (unique graphs)")
    ap.add_argument("--dtype", choices=("f32", "bf16"), default="f32",
                    help="serving precision: bf16 casts the baked params "
                         "once and runs quantized forward passes (the "
                         "denormalize path stays float32-exact; drift vs "
                         "f32 is gated in tests at Spearman >= 0.99)")
    ap.add_argument("--replicas", type=int, default=0,
                    help="serve through N replica processes behind the "
                         "struct-key consistent-hash router instead of "
                         "one in-process server (0 = in-process); each "
                         "replica owns its params, warmup, LRU and an "
                         "adaptive flush deadline, with a shared "
                         "cross-replica cache tier behind them")
    ap.add_argument("--kernel", action="store_true",
                    help="serve through the fused Pallas forward "
                         "(repro.kernels.ops): conv1d runs the full "
                         "ids-in/predictions-out kernel, lstm the "
                         "VMEM-carry recurrence kernel. Composes with "
                         "--dtype bf16 (bf16 params, f32 in-kernel "
                         "accumulation)")
    ap.add_argument("--supervise", action="store_true",
                    help="replicated tier only: run the "
                         "ReplicaSupervisor (heartbeat liveness, "
                         "in-slot respawn of crashed/wedged replicas "
                         "with crash-loop budgets, arrival-rate-driven "
                         "scale up/down within --max-replicas)")
    ap.add_argument("--max-replicas", type=int, default=None,
                    help="pre-allocated replica slot ceiling for "
                         "supervisor scale-up (default: --replicas, "
                         "i.e. no headroom)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline budget in the router "
                         "(retries included); a blown deadline sheds "
                         "or, with --degrade, falls back to the "
                         "analyzer oracle")
    ap.add_argument("--degrade", action="store_true",
                    help="replicated tier only: when the tier is "
                         "exhausted (all replicas shedding/cooling or "
                         "the deadline blown), answer from the "
                         "analyzer-oracle static cost model instead of "
                         "raising; degraded replies are counted in "
                         "phase_stats/router stats and the obs "
                         "registry")
    ap.add_argument("--obs", action="store_true",
                    help="unified telemetry: head-sampled request "
                         "tracing (spans cross the replica wire), one "
                         "metrics-registry JSONL stream, and the online "
                         "accuracy/drift sentinel. Inspect with "
                         "`python -m repro.launch.obs report <jsonl>`")
    ap.add_argument("--obs-jsonl", default="obs_telemetry.jsonl",
                    help="telemetry stream path (JSONL: interleaved "
                         "metrics snapshots + span records)")
    ap.add_argument("--obs-sample", type=int, default=16,
                    help="trace 1 in N requests (errors/sheds are "
                         "always traced)")
    ap.add_argument("--obs-prom-port", type=int, default=None,
                    help="also serve a Prometheus-style /metrics "
                         "endpoint on this port (0 = ephemeral)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = CostModelConfig(name="serve", vocab_size=4096, max_seq=160,
                          embed_dim=64, conv_channels=(64,) * 6,
                          fc_dims=(256, 64))
    ds = DS.build_dataset(args.n_graphs, mode="ops", max_seq=160,
                          vocab_size=4096, augment_factor=2, seed=args.seed)
    tr, te = ds.split(0.1)
    print(f"training joint multi-target cost model "
          f"({', '.join(CM.DEFAULT_HEADS)})...")
    engine = TR.TrainEngine("conv1d", cfg, CM.DEFAULT_HEADS,
                            steps=args.train_steps, batch_size=128,
                            lr=2e-3, seed=args.seed)
    res = engine.fit(tr)
    print(f"trained at {res.stats['steps_per_s']:.1f} steps/s "
          f"(bucketed batches)")

    svc = CostModelService("conv1d", cfg, res.params, ds.vocab,
                           res.norm_stats, mode="ops", max_seq=160,
                           cache_size=args.cache_size, dtype=args.dtype,
                           use_kernel=args.kernel)
    if args.replicas > 0:
        run_replicated(svc, args)
        return
    server = CostModelServer(svc, max_batch=args.max_batch,
                             flush_us=args.flush_us,
                             max_queue=args.max_queue)
    obs = setup_obs(args, server=server, service=svc)
    if obs:
        server.tracer = obs["tracer"]
    t0 = time.perf_counter()
    server.start(warmup=not args.no_warmup)
    try:
        run_session(server, svc, args, time.perf_counter() - t0)
    finally:
        server.stop()                  # fail leftover futures on error
        teardown_obs(args, obs)
    print(f"cache after session: {svc.cache_stats()['size']} unique "
          f"entries")


def setup_obs(args, *, server=None, service=None, router=None,
              shared_cache=None, supervisor=None):
    """Build the unified telemetry stack from CLI flags: one tracer,
    one registry over every tier's existing stats source, the drift
    sentinel on the (featurizer) service, and the JSONL exporter that
    streams it all to disk. Returns the bundle, or None when --obs is
    off — every call site is a no-op then."""
    if not getattr(args, "obs", False):
        return None
    from repro.obs import (JsonlExporter, MetricsRegistry, PromExporter,
                           Tracer, register_drift, register_router,
                           register_server, register_service,
                           register_shared_cache, register_supervisor,
                           register_tracer)
    from repro.obs.drift import DriftMonitor, attach
    tracer = Tracer(sample_every=max(1, args.obs_sample))
    reg = MetricsRegistry()
    drift = None
    if service is not None:
        drift = attach(service, DriftMonitor())
        register_service(reg, service)
        register_drift(reg, drift)
    if server is not None:
        register_server(reg, server)
    if router is not None:
        register_router(reg, router)
    if shared_cache is not None:
        register_shared_cache(reg, shared_cache)
    if supervisor is not None:
        register_supervisor(reg, supervisor)
    register_tracer(reg, tracer)
    exporter = JsonlExporter(args.obs_jsonl, reg, tracer=tracer,
                             interval_s=0.5).start()
    prom = None
    if args.obs_prom_port is not None:
        prom = PromExporter(reg, args.obs_prom_port).start()
        print(f"obs: /metrics on port {prom.port}")
    print(f"obs: tracing 1/{tracer.sample_every} requests "
          f"-> {args.obs_jsonl}")
    return {"tracer": tracer, "registry": reg, "drift": drift,
            "exporter": exporter, "prom": prom}


def teardown_obs(args, obs) -> None:
    """Flush + stop the telemetry stack and print the trace digest the
    session just produced (the same numbers `launch/obs.py report`
    computes offline from the JSONL)."""
    if not obs:
        return
    import json

    from repro.obs import assemble, completeness
    if obs["drift"] is not None:
        obs["drift"].stop()            # drains + scores the queue
    obs["exporter"].stop()             # final tick: snapshot + spans
    if obs["prom"] is not None:
        obs["prom"].stop()
    spans = []
    try:
        with open(args.obs_jsonl, encoding="utf-8") as f:
            spans = [json.loads(ln) for ln in f if '"kind": "span"' in ln]
    except OSError:
        pass
    trees = assemble(spans)
    if trees:
        print(f"obs: {len(spans)} spans across {len(trees)} traces, "
              f"completeness={completeness(trees):.1%}; inspect with "
              f"`python -m repro.launch.obs report {args.obs_jsonl}`")


def run_replicated(svc: CostModelService, args) -> None:
    """Serve the trained model through N replica processes behind the
    struct-key router; the client is duck-typed, so the same closed-loop
    driver and advisors run unchanged. With --supervise the tier is
    self-healing: a ReplicaSupervisor heartbeats every replica,
    respawns crashed/wedged ones into their ring slot, and scales the
    fleet from arrival-rate/health signals."""
    from repro.serving import (ReplicaClient, ReplicaSupervisor,
                               ScalePolicy, ServiceSpec, start_replicas)

    spec = ServiceSpec.from_service(svc)
    t0 = time.perf_counter()
    tier = start_replicas(spec, args.replicas, n_clients=1,
                          warmup=not args.no_warmup,
                          max_batch=args.max_batch,
                          flush_us=args.flush_us,
                          max_queue=args.max_queue,
                          obs_trace=args.obs,
                          max_replicas=args.max_replicas)
    obs = None
    sup = None
    try:
        client = ReplicaClient(
            tier.client_handle(0),
            deadline_s=args.deadline_ms / 1e3
            if args.deadline_ms else None,
            oracle_fallback=args.degrade)
        if args.supervise:
            sup = ReplicaSupervisor(
                tier,
                scale=ScalePolicy(min_replicas=1,
                                  max_replicas=tier.max_replicas),
                router_stats_fn=client.stats).start()
        obs = setup_obs(args, router=client, service=client.fsvc,
                        shared_cache=tier.shared_cache, supervisor=sup)
        if obs:
            client.tracer = obs["tracer"]
        run_session(client, client.fsvc, args, time.perf_counter() - t0)
        for payload in client.replica_stats():
            if payload is None:
                continue
            s, c = payload["server"], payload["cache"]
            print(f"  replica {payload['replica_id']}: "
                  f"requests={s['requests']} "
                  f"batches={s['batches']} "
                  f"occupancy={s['batch_occupancy']:.1f} "
                  f"lru_hit={c['hit_rate']:.1%} "
                  f"shared_hits={payload['shared_hits']}")
        h = client.stats()["health"]
        print(f"  router: sent={[h[r]['sent'] for r in sorted(h)]} "
              f"shed={client.shed_count} "
              f"degraded={client.degraded_count}")
        if sup is not None:
            ss = sup.stats()
            print(f"  supervisor: active={ss['active']} "
                  f"restarts={ss['restarts_total']} "
                  f"scale_ups={ss['scale_ups']} "
                  f"scale_downs={ss['scale_downs']}")
    finally:
        if sup is not None:
            sup.stop()
        tier.stop()
        teardown_obs(args, obs)


def run_session(server: CostModelServer, svc: CostModelService, args,
                warmup_s: float) -> None:
    print(f"server up: heads={list(svc.heads)} buckets={list(svc.buckets)} "
          f"batch_ladder={list(svc.batch_ladder)} warmup={warmup_s:.2f}s")

    rng = np.random.default_rng(args.seed + 1)
    graphs = [samplers.sample_graph(rng) for _ in range(args.requests // 2)]
    # compiler sessions re-query slightly-modified graphs: 50% cache hits
    graphs = graphs + [g for g in graphs]
    rng.shuffle(graphs)

    dt = run_clients(server, graphs, args.concurrency)
    n_targets = len(svc.heads)
    print(f"served {len(graphs)} requests x {n_targets} targets in "
          f"{dt:.2f}s ({len(graphs) / dt:.0f} req/s, "
          f"{len(graphs) * n_targets / dt:.0f} predictions/s) "
          f"at concurrency {args.concurrency}")
    if hasattr(server, "metrics_snapshot"):   # in-process gateway only:
        m = server.metrics_snapshot()         # replicas report their own
        print(f"  batches={m['batches']} "
              f"occupancy={m['batch_occupancy']:.1f} "
              f"full={m['full_flushes']} "
              f"deadline={m['deadline_flushes']}")
        print(f"  latency p50={m['latency_p50_us'] / 1e3:.2f}ms "
              f"p95={m['latency_p95_us'] / 1e3:.2f}ms "
              f"p99={m['latency_p99_us'] / 1e3:.2f}ms")
        print(f"  cache_hit_rate={m['cache_hit_rate']:.1%} "
              f"coalesced={m['coalesced']} shed={m['shed']} "
              f"max_queue_depth={m['max_queue_depth']}")

    # the advisors drive the SAME gateway (duck-typed service API)
    fusion = FusionAdvisor(server)
    unroll = UnrollAdvisor(server, register_budget=64)
    recompile = RecompileAdvisor(server)

    g = samplers.sample_graph(rng, "resnet")
    do_fuse, c0, c1 = fusion.advise(g)
    print(f"fusion advisor: fuse={do_fuse} "
          f"(unfused={c0:.1f}us fused={c1:.1f}us)")
    adv = unroll.advise(g)
    per_iter = {k: round(v, 1) for k, v in adv['per_iter_latency'].items()}
    print(f"unroll advisor: best_factor={adv['best_factor']} "
          f"per-iter latency={per_iter}")
    g2 = AUG.jitter_shapes(g, rng)
    dec = recompile.advise(g, g2)
    print(f"recompile advisor: recompile={dec['recompile']} "
          f"shift={dec['shift']:.1%}")


if __name__ == "__main__":
    main()
