"""Batched inference server driver for the deployed cost model.

Simulates the DL-compiler's usage pattern: bursts of small prediction
requests (one per candidate transformation) that the service batches,
caches, and answers. Prints throughput and cache statistics.

    PYTHONPATH=src python -m repro.launch.serve --requests 2000
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.costmodel import COSTMODEL_BASE, CostModelConfig
from repro.core import models as CM
from repro.core import trainer as TR
from repro.core.service import (CostModelService, FusionAdvisor,
                                RecompileAdvisor, UnrollAdvisor)
from repro.core import augment as AUG
from repro.ir import dataset as DS, samplers


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=500)
    ap.add_argument("--train-steps", type=int, default=400)
    ap.add_argument("--n-graphs", type=int, default=1500)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = CostModelConfig(name="serve", vocab_size=4096, max_seq=160,
                          embed_dim=64, conv_channels=(64,) * 6,
                          fc_dims=(256, 64))
    ds = DS.build_dataset(args.n_graphs, mode="ops", max_seq=160,
                          vocab_size=4096, augment_factor=2, seed=args.seed)
    tr, te = ds.split(0.1)
    print("training latency cost model for the service...")
    res_lat = TR.train_model("conv1d", cfg, tr, "latency_us",
                             steps=args.train_steps, batch_size=128, lr=2e-3)
    res_reg = TR.train_model("conv1d", cfg, tr, "register_pressure",
                             steps=args.train_steps, batch_size=128, lr=2e-3)

    lat_svc = CostModelService("conv1d", cfg, res_lat.params, ds.vocab,
                               res_lat.norm_stats, mode="ops", max_seq=160)
    reg_svc = CostModelService("conv1d", cfg, res_reg.params, ds.vocab,
                               res_reg.norm_stats, mode="ops", max_seq=160)

    rng = np.random.default_rng(args.seed + 1)
    graphs = [samplers.sample_graph(rng) for _ in range(args.requests // 2)]
    # compiler sessions re-query slightly-modified graphs: 50% cache hits
    graphs = graphs + [g for g in graphs]
    rng.shuffle(graphs)

    t0 = time.time()
    preds = lat_svc.predict_graphs(graphs)
    dt = time.time() - t0
    print(f"served {len(graphs)} requests in {dt:.2f}s "
          f"({len(graphs)/dt:.0f} req/s, "
          f"cache={len(lat_svc._cache)} unique)")
    print(f"predicted latency: p50={np.median(preds):.1f}us "
          f"max={preds.max():.1f}us")

    fusion = FusionAdvisor(lat_svc)
    unroll = UnrollAdvisor(lat_svc, reg_svc, register_budget=64)
    recompile = RecompileAdvisor(lat_svc)

    g = samplers.sample_graph(rng, "resnet")
    do_fuse, c0, c1 = fusion.advise(g)
    print(f"fusion advisor: fuse={do_fuse} "
          f"(unfused={c0:.1f}us fused={c1:.1f}us)")
    adv = unroll.advise(g)
    print(f"unroll advisor: best_factor={adv['best_factor']} "
          f"per-iter latency={ {k: round(v,1) for k, v in adv['per_iter_latency'].items()} }")
    g2 = AUG.jitter_shapes(g, rng)
    dec = recompile.advise(g, g2)
    print(f"recompile advisor: recompile={dec['recompile']} "
          f"shift={dec['shift']:.1%}")


if __name__ == "__main__":
    main()
