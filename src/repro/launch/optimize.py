"""Cost-model-guided graph optimization driver (the repro.opt CLI).

The paper's deployment loop, end to end: train (or resume) a joint
multi-target cost model on a rewrite-augmented corpus, stand it up
behind the async micro-batching CostModelServer, then beam-search
rewrite sequences (fusion / CSE / DCE / recompute / bf16-narrowing /
unroll) over sampled graphs from all five model families — every
frontier expansion costed in ONE batched ``predict_all`` — and judge
the chosen sequences against the ``ir/analyzers`` ground-truth oracle.

    PYTHONPATH=src python -m repro.launch.optimize --eval-graphs 20 \
        --beam 4 --depth 5 --register-budget 64
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs.costmodel import CostModelConfig
from repro.core import models as CM
from repro.core import trainer as TR
from repro.core.server import CostModelServer
from repro.core.service import CostModelService
from repro.ir import dataset as DS
from repro.ir import samplers
from repro.opt import evaluate as OE
from repro.opt import search as OPT


def main():
    ap = argparse.ArgumentParser(
        description="Train-or-load a cost model, serve it, and run "
                    "model-guided beam search over rewrite sequences.",
        formatter_class=argparse.ArgumentDefaultsHelpFormatter)
    ap.add_argument("--n-graphs", type=int, default=1200,
                    help="base training graphs (each also contributes a "
                         "rewrite-augmented variant)")
    ap.add_argument("--train-steps", type=int, default=400)
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint dir: resume/load the model from "
                         "here instead of retraining from scratch")
    ap.add_argument("--eval-graphs", type=int, default=20,
                    help="graphs to optimize, round-robin over families")
    ap.add_argument("--families", default=",".join(sorted(
        samplers.SAMPLERS)))
    ap.add_argument("--beam", type=int, default=4)
    ap.add_argument("--depth", type=int, default=5,
                    help="max rewrite-sequence length (search steps)")
    ap.add_argument("--max-candidates", type=int, default=64,
                    help="candidate cap per frontier expansion")
    ap.add_argument("--eval-budget", type=int, default=256,
                    help="total candidates costed per search")
    ap.add_argument("--register-budget", type=float, default=float("inf"),
                    help="hard register-pressure constraint on candidates")
    ap.add_argument("--greedy", action="store_true",
                    help="cheap mode: beam 1, stop on first non-improving "
                         "step")
    ap.add_argument("--direct", action="store_true",
                    help="query the service directly instead of through "
                         "the async micro-batching server")
    ap.add_argument("--flush-us", type=float, default=1000.0)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--dtype", choices=("f32", "bf16"), default="f32",
                    help="serving precision for candidate costing: bf16 "
                         "runs quantized forward passes (params cast "
                         "once; denormalize stays float32-exact)")
    ap.add_argument("--kernel", action="store_true",
                    help="cost candidates through the fused Pallas "
                         "serving forward (repro.kernels.ops); composes "
                         "with --dtype bf16")
    ap.add_argument("--obs", action="store_true",
                    help="unified telemetry on the serving gateway: "
                         "head-sampled tracing, metrics-registry JSONL "
                         "stream, and the drift sentinel (see "
                         "`python -m repro.launch.obs report`)")
    ap.add_argument("--obs-jsonl", default="obs_optimize.jsonl",
                    help="telemetry stream path for --obs")
    ap.add_argument("--obs-sample", type=int, default=64,
                    help="trace 1 in N predict_all calls")
    ap.add_argument("--obs-prom-port", type=int, default=None,
                    help="optional Prometheus /metrics port (0 = "
                         "ephemeral)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = CostModelConfig(name="optimize", vocab_size=4096, max_seq=160,
                          embed_dim=64, conv_channels=(64,) * 6,
                          fc_dims=(256, 64))
    ds = DS.build_dataset(args.n_graphs, mode="ops", max_seq=160,
                          vocab_size=4096, augment_factor=1,
                          rewrite_factor=1, seed=args.seed)
    tr, te = ds.split(0.1)
    print(f"training joint cost model on rewrite-augmented corpus "
          f"({len(tr)} rows, vocab={ds.vocab.size})...")
    engine = TR.TrainEngine("conv1d", cfg, CM.DEFAULT_HEADS,
                            steps=args.train_steps, batch_size=128,
                            lr=2e-3, seed=args.seed,
                            ckpt_dir=args.ckpt_dir)
    res = engine.fit(tr)
    if res.stats.get("steps"):
        print(f"trained {res.stats['steps']:.0f} steps at "
              f"{res.stats['steps_per_s']:.1f} steps/s")
    else:
        print(f"resumed completed run from {args.ckpt_dir}")
    for t, m in TR.evaluate("conv1d", cfg, res, te).items():
        print(f"  eval[{t}]: rmse_rel={m['rmse_rel_pct']:.1f}% "
              f"mape={m['mape_pct']:.1f}%")

    svc = CostModelService("conv1d", cfg, res.params, ds.vocab,
                           res.norm_stats, mode="ops", max_seq=160,
                           dtype=args.dtype, use_kernel=args.kernel)
    rng = np.random.default_rng(args.seed + 1)
    fams = [f for f in args.families.split(",") if f]
    graphs = [samplers.sample_graph(rng, fams[i % len(fams)])
              for i in range(args.eval_graphs)]
    objective = OPT.Objective(register_budget=args.register_budget)

    server = None
    backend = svc
    if not args.direct:
        server = CostModelServer(svc, max_batch=args.max_batch,
                                 flush_us=args.flush_us)
    from repro.launch.serve import setup_obs, teardown_obs
    obs = setup_obs(args, server=server, service=svc)
    if obs and server is not None:
        server.tracer = obs["tracer"]
    if server is not None:
        server.start()
        backend = server
    try:
        t0 = time.perf_counter()
        report = OE.evaluate_search(
            backend, graphs, objective=objective, beam_width=args.beam,
            max_steps=args.depth, max_candidates=args.max_candidates,
            eval_budget=args.eval_budget, greedy=args.greedy)
        dt = time.perf_counter() - t0
    finally:
        if server is not None:
            m = server.metrics.snapshot()
            server.stop()
        teardown_obs(args, obs)

    for r in report["per_graph"]:
        arrow = "↓" if r["oracle_best"] < r["oracle_root"] else "="
        print(f"  {r['graph']:<12} oracle {r['oracle_root']:9.1f}us "
              f"{arrow} {r['oracle_best']:9.1f}us  "
              f"steps={r['steps']} [{' '.join(r['seq']) or 'no-op'}]")
    s = report["summary"]
    print(f"optimized {s['n_graphs']} graphs in {dt:.2f}s "
          f"({s['n_graphs'] / dt:.2f} graphs/s, "
          f"{s['candidates_costed']} candidates costed in "
          f"{s['predict_calls']} batched predict_all calls)")
    print(f"  oracle latency improvement: mean "
          f"{s['oracle_improvement_mean']:.1%} "
          f"(one-shot fusion baseline "
          f"{s['baseline_oracle_improvement_mean']:.1%}); "
          f"improved on {s['frac_improved_vs_root']:.0%} of graphs")
    print(f"  predicted improvement {s['pred_improvement_mean']:.1%}; "
          f"pred-vs-oracle rank corr "
          f"rho={s['spearman_pred_oracle_pooled']:.3f} pooled / "
          f"{s['spearman_pred_oracle']:.3f} within-search")
    if server is not None:
        print(f"  server: {m['requests']} requests in {m['batches']} "
              f"batches (occupancy {m['batch_occupancy']:.1f}, "
              f"cache_hit_rate={m['cache_hit_rate']:.1%})")
    return report


if __name__ == "__main__":
    main()
