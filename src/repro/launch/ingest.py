"""Real-MLIR front-door CLI: lowered text in, cost predictions out.

Demonstrates the tolerant ingestion path end to end: train a small
multi-target cost model on synthetic graphs, extend its vocabulary
with the OOV machinery (hash-bucketed ``<unk#k>`` shards + byte
fallback), then feed it *genuine* compiler IR — the per-layer StableHLO
subgraphs of real architectures from ``repro.configs.ARCHS``, a user
file, or a seeded fuzz corpus of mutated/truncated/dialect-mixed
texts. Every input produces either a TextPrediction or a structured
IngestError; nothing raises.

    PYTHONPATH=src python -m repro.launch.ingest --fuzz 50
    PYTHONPATH=src python -m repro.launch.ingest --arch qwen3-0.6b
    PYTHONPATH=src python -m repro.launch.ingest --file my_module.mlir
"""
from __future__ import annotations

import argparse
import time

from repro.configs.costmodel import CostModelConfig
from repro.core import models as CM
from repro.core import trainer as TR
from repro.core.service import CostModelService
from repro.core.tokenizer import extend_vocab_oov
from repro.ir import dataset as DS
from repro.ir import frontdoor as FD
from repro.ir import stablehlo as SH

DEFAULT_ARCHS = ("qwen3-0.6b", "xlstm-125m", "whisper-small",
                 "granite-moe-1b-a400m", "starcoder2-3b")


def build_service(args) -> CostModelService:
    """Small trained conv model whose vocab carries the OOV machinery.

    The dataset vocab is fit below ``cfg.vocab_size`` on purpose: the
    spare id space holds the unk shards and the 256 byte tokens, so
    every extended id still fits the embedding table."""
    cfg = CostModelConfig(name="ingest", vocab_size=2048, max_seq=192,
                          embed_dim=32, conv_channels=(32,) * 3,
                          fc_dims=(64,))
    ds = DS.build_dataset(args.n_graphs, mode="ops", max_seq=192,
                          vocab_size=1500, seed=args.seed)
    vocab = extend_vocab_oov(ds.vocab, n_unk_buckets=32,
                             byte_fallback=True,
                             max_size=cfg.vocab_size)
    if args.train_steps > 0:
        engine = TR.TrainEngine("conv1d", cfg, CM.DEFAULT_HEADS,
                                steps=args.train_steps, batch_size=64,
                                lr=2e-3, seed=args.seed)
        res = engine.fit(ds)
        params, stats = res.params, res.norm_stats
    else:                              # untrained demo: path, not accuracy
        import jax
        params = CM.conv_init(jax.random.PRNGKey(args.seed), cfg,
                              heads=CM.DEFAULT_HEADS)
        stats = {t: {"mu": 0.0, "sigma": 1.0} for t in CM.DEFAULT_HEADS}
    return CostModelService("conv1d", cfg, params, vocab, stats,
                            mode="ops", max_seq=192)


def show(tag: str, out) -> None:
    """One result line per ingested text, prediction or error alike."""
    if isinstance(out, FD.IngestError):
        print(f"  {tag:40s} ERROR stage={out.stage} "
              f"reason={out.reason}")
        return
    preds = " ".join(f"{t}={v:.3g}" for t, v in
                     sorted(out.predictions.items()))
    print(f"  {tag:40s} n_ops={out.n_ops:3d} "
          f"tokens={out.n_tokens:4d} oov={out.oov_rate:.2f} "
          f"unk={out.unk_rate:.2f} {preds}")


def main():
    ap = argparse.ArgumentParser(
        description="Ingest lowered MLIR text (StableHLO/affine) through "
                    "the tolerant front door and print cost predictions.",
        formatter_class=argparse.ArgumentDefaultsHelpFormatter)
    ap.add_argument("--arch", default=",".join(DEFAULT_ARCHS),
                    help="comma-separated architecture names from "
                         "repro.configs.ARCHS to lower per-layer and "
                         "ingest ('all' = every registered arch, "
                         "'none' = skip the arch corpus)")
    ap.add_argument("--file", default=None,
                    help="path to an MLIR text file to ingest (e.g. "
                         "saved from jax.jit(fn).lower().as_text())")
    ap.add_argument("--fuzz", type=int, default=0,
                    help="additionally push N seeded mutations "
                         "(truncations, byte flips, dialect splices) "
                         "of the corpus through predict_text; every "
                         "one must yield a prediction or a structured "
                         "IngestError, never an exception")
    ap.add_argument("--seq", type=int, default=8,
                    help="sequence length for the lowered subgraphs")
    ap.add_argument("--train-steps", type=int, default=150,
                    help="training steps for the demo model (0 = "
                         "untrained params: exercises the path only)")
    ap.add_argument("--n-graphs", type=int, default=400,
                    help="synthetic training-set size")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    svc = build_service(args)
    print(f"service up: heads={list(svc.heads)} "
          f"vocab={len(svc.vocab.token_to_id)} ids "
          f"(unk_buckets={svc.vocab.n_unk_buckets} "
          f"byte_fallback={svc.vocab.byte_fallback})")

    texts = []
    if args.arch != "none":
        names = None if args.arch == "all" else args.arch.split(",")
        t0 = time.perf_counter()
        corpus = SH.lower_arch_corpus(names, seq=args.seq)
        print(f"lowered {len(corpus)} per-layer subgraphs of "
              f"{len({a for a, _, _ in corpus})} archs in "
              f"{time.perf_counter() - t0:.2f}s")
        for arch, layer, text in corpus:
            texts.append(text)
            show(f"{arch}/{layer}", svc.predict_text(text))

    if args.file:
        with open(args.file, "rb") as f:
            raw = f.read()
        texts.append(raw.decode("utf-8", "replace"))
        show(args.file, svc.predict_text(raw))

    if args.fuzz > 0:
        seeds = texts or [FD.AFFINE_EXAMPLE]
        import numpy as np
        corpus = FD.fuzz_corpus(seeds, args.fuzz,
                                np.random.default_rng(args.seed))
        ok = err = uncaught = 0
        for t in corpus:
            try:
                out = svc.predict_text(t)
                if isinstance(out, FD.IngestError):
                    err += 1
                else:
                    ok += 1
            except Exception as e:     # contract violation: report loudly
                uncaught += 1
                print(f"  UNCAUGHT {type(e).__name__}: {e!r}")
        print(f"fuzz: {len(corpus)} mutated inputs -> "
              f"{ok} predictions, {err} structured errors, "
              f"{uncaught} uncaught exceptions")

    ps = svc.phase_stats()
    print(f"ingested_texts={ps['ingested_texts']:.0f} "
          f"ingest_errors={ps['ingest_errors']:.0f} "
          f"oov_rate={ps['oov_rate']:.3f} "
          f"encode_s={ps.get('encode_s', 0.0):.3f}")


if __name__ == "__main__":
    main()
