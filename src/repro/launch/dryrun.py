import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay the first statements in this module — jax
locks the device count at first init, and the production meshes need 512
placeholder host devices. Nothing else in the repo sets this flag (tests
and benches see the real single device).

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \
        --shape train_4k [--multi-pod] [--all] [--out experiments/dryrun]

Per cell this builds the production mesh, resolves shardings for params /
optimizer state / batch / cache, lowers the appropriate step function with
jax.jit(..., in_shardings=..., out_shardings=...), compiles, and records
memory_analysis() + cost_analysis() + the collective-bytes breakdown that
the roofline report (launch/roofline.py) consumes.
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, get_arch, shape_eligible
from repro.launch import hlo_cost as HC
from repro.launch import roofline as RL
from repro.launch.mesh import make_production_mesh
from repro.models import model as MODEL
from repro.models import steps as STEPS
from repro.optim import adamw
from repro.runtime.sharding import ShardingRules

# decode cells whose bf16 KV cache exceeds per-chip HBM: serve with an
# int8-quantized cache (production KV-cache quantization).
INT8_KV_CELLS = {("qwen1.5-32b", "decode_32k")}


def _tree_shardings(rules: ShardingRules, axes_tree, abstract_tree):
    def is_axes(x):
        return isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x)
    return jax.tree.map(
        lambda axes, leaf: rules.sharding(axes, leaf.shape),
        axes_tree, abstract_tree, is_leaf=is_axes)


def _batch_shardings(rules: ShardingRules, specs: Dict[str, Any]):
    out = {}
    for k, v in specs.items():
        axes = ("batch",) + (None,) * (len(v.shape) - 1)
        out[k] = rules.sharding(axes, v.shape)
    return out


def _mem_summary(compiled) -> Dict[str, float]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        v = getattr(ma, attr, None)
        if v is not None:
            out[attr] = float(v)
    return out


def _cost_summary(compiled) -> Dict[str, float]:
    try:
        ca = compiled.cost_analysis() or {}
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float))}


def run_cell(arch_name: str, shape_name: str, *, multi_pod: bool = False,
             mesh=None, rules_overrides: Optional[Dict] = None,
             remat: bool = True, kv_dtype=None, grad_bf16: bool = False,
             pad_heads: bool = True,
             verbose: bool = True) -> Dict[str, Any]:
    """Lower+compile one (arch, shape, mesh) cell; return the record dict."""
    cfg = get_arch(arch_name)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    record: Dict[str, Any] = {"arch": arch_name, "shape": shape_name,
                              "mesh": mesh_name}
    ok, reason = shape_eligible(cfg, shape)
    if not ok:
        record.update(status="skipped", reason=reason)
        return record

    t0 = time.perf_counter()
    mesh = mesh or make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    overrides = dict(rules_overrides or {})
    if shape.kind != "train" and "embed" not in overrides:
        # inference: no optimizer states to fit, so drop the FSDP (data-
        # axis) dimension of the 2D param sharding — weights stay TP-
        # sharded over model and replicated over data, killing the
        # per-layer weight gathers that dominate decode collectives
        overrides["embed"] = None
    rules = ShardingRules(mesh, overrides=overrides)
    rules.pad_attention_heads = pad_heads
    abs_params = STEPS.abstract_params(cfg)
    paxes = MODEL.param_axes(cfg)
    p_sh = _tree_shardings(rules, paxes, abs_params)
    specs = STEPS.input_specs(cfg, shape)
    b_sh = _batch_shardings(rules, specs)

    with mesh:
        if shape.kind == "train":
            opt_cfg = adamw.AdamWConfig()
            abs_opt = STEPS.abstract_opt_state(abs_params)
            o_sh = {"m": p_sh, "v": p_sh,
                    "count": jax.sharding.NamedSharding(
                        mesh, jax.sharding.PartitionSpec())}
            gt = None
            if grad_bf16:
                # bf16 gradients on the wire (the DP all-reduce payload
                # halves); optimizer math stays fp32
                def gt(g):
                    return jax.tree.map(
                        lambda x: x.astype(jnp.bfloat16), g)
            step = STEPS.make_train_step(cfg, opt_cfg, rules=rules,
                                         remat=remat, grad_transform=gt)
            jitted = jax.jit(step,
                             in_shardings=(p_sh, o_sh, b_sh),
                             out_shardings=(p_sh, o_sh, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(abs_params, abs_opt, specs)
        elif shape.kind == "prefill":
            step = STEPS.make_prefill_step(cfg, rules=rules)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(abs_params, specs)
        else:  # decode
            kvd = kv_dtype
            if kvd is None:
                kvd = jnp.int8 if (arch_name, shape_name) in INT8_KV_CELLS \
                    else jnp.bfloat16
            abs_cache = STEPS.abstract_cache(cfg, shape.global_batch,
                                             shape.seq_len, kv_dtype=kvd)
            caxes = MODEL.cache_axes(cfg)
            c_sh = _tree_shardings(rules, caxes, abs_cache)
            step = STEPS.make_decode_step(cfg, rules=rules)
            jitted = jax.jit(
                step,
                in_shardings=(p_sh, c_sh, b_sh["tokens"], None),
                out_shardings=(b_sh["tokens"], c_sh),
                donate_argnums=(1,))
            lowered = jitted.lower(abs_params, abs_cache, specs["tokens"],
                                   jax.ShapeDtypeStruct((), jnp.int32))
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    mem = _mem_summary(compiled)
    cost = _cost_summary(compiled)
    # loop-aware HLO walk (XLA-CPU cost_analysis counts while bodies once;
    # see launch/hlo_cost.py) — this is the roofline source of truth.
    totals = HC.analyze_hlo(compiled.as_text())
    report = RL.RooflineReport(
        arch=arch_name, shape=shape_name, mesh=mesh_name, chips=chips,
        flops_per_chip=totals.flops,
        bytes_per_chip=totals.hbm_bytes,
        coll_bytes_per_chip=totals.coll_bytes,
        coll_breakdown=dict(totals.coll),
        peak_memory_per_chip=(mem.get("argument_size_in_bytes", 0.0)
                              + mem.get("temp_size_in_bytes", 0.0)
                              - mem.get("alias_size_in_bytes", 0.0)),
        model_flops=RL.model_flops_for(cfg, shape),
    )
    record.update(status="ok", chips=chips,
                  lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
                  memory=mem, cost=cost, roofline=report.to_dict())
    if verbose:
        print(f"[{mesh_name}] {arch_name} x {shape_name}: OK "
              f"({t_lower:.0f}s lower, {t_compile:.0f}s compile) "
              f"bottleneck={report.bottleneck} "
              f"t=({report.t_compute*1e3:.2f},{report.t_memory*1e3:.2f},"
              f"{report.t_collective*1e3:.2f})ms "
              f"roofline={report.roofline_fraction:.2%}")
        sizes = {k: f'{v/2**30:.2f}GiB'
                 for k, v in mem.items() if 'size' in k}
        print(f"  memory_analysis: {sizes}")
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    cells = []
    archs = list(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for mp in meshes:
        for a in archs:
            for s in shapes:
                cells.append((a, s, mp))

    os.makedirs(args.out, exist_ok=True)
    n_ok = n_skip = n_fail = 0
    for arch, shp, mp in cells:
        mesh_name = "pod2x16x16" if mp else "pod16x16"
        path = os.path.join(args.out,
                            f"{mesh_name}__{arch}__{shp}.json")
        if os.path.exists(path):
            with open(path) as f:
                rec = json.load(f)
            if rec.get("status") in ("ok", "skipped"):
                print(f"[cached] {mesh_name} {arch} x {shp}: "
                      f"{rec['status']}")
                n_ok += rec["status"] == "ok"
                n_skip += rec["status"] == "skipped"
                continue
        try:
            rec = run_cell(arch, shp, multi_pod=mp,
                           remat=not args.no_remat)
            n_ok += rec["status"] == "ok"
            n_skip += rec["status"] == "skipped"
            if rec["status"] == "skipped":
                print(f"[{mesh_name}] {arch} x {shp}: SKIPPED "
                      f"({rec['reason']})")
        except Exception as e:
            n_fail += 1
            rec = {"arch": arch, "shape": shp, "mesh": mesh_name,
                   "status": "failed", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
            print(f"[{mesh_name}] {arch} x {shp}: FAILED {type(e).__name__}: "
                  f"{str(e)[:200]}")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    print(f"\ndry-run summary: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
