"""Roofline-term derivation from a compiled dry-run artifact.

    compute term    = HLO_FLOPs_global    / (chips * PEAK_FLOPS)
    memory term     = HLO_bytes_global    / (chips * HBM_BW)
    collective term = collective_bytes_per_chip / LINK_BW

FLOPs/bytes come from ``compiled.cost_analysis()`` — XLA reports them for
the post-SPMD per-device module, so global = per-device * chips and the
first two terms reduce to per_device / peak.

collective_bytes is NOT in cost_analysis: we parse the post-partitioning
HLO text and sum the bytes each chip moves per collective:

    all-gather          result_bytes          (each chip receives the rest)
    all-reduce          2 x operand_bytes     (ring reduce-scatter+all-gather)
    reduce-scatter      operand_bytes
    all-to-all          result_bytes
    collective-permute  result_bytes

Hardware constants (TPU v5e-class, per the assignment): 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%?[\w.-]+)\s*=\s*(.*)$")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_OPCODE_RE = re.compile(r"\s([\w-]+)\(")


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-chip bytes moved per collective kind, from post-SPMD HLO text."""
    sizes: Dict[str, int] = {}
    defs = []  # (name, result_bytes, opcode, args_str)
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1).lstrip("%"), m.group(2)
        om = _OPCODE_RE.search(rhs)
        if not om:
            continue
        result_b = _shape_bytes(rhs[:om.start()])
        opcode = om.group(1)
        args = rhs[om.end():]
        close = args.find(")")
        args = args[:close] if close >= 0 else args
        sizes[name] = result_b
        defs.append((name, result_b, opcode, args))
    per_kind: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for name, result_b, opcode, args in defs:
        base = opcode[:-len("-start")] if opcode.endswith("-start") else opcode
        if base == "all-reduce-done" or base.endswith("-done"):
            continue
        if base not in _COLLECTIVES:
            continue
        operand_b = sum(sizes.get(a.group(1), 0)
                        for a in re.finditer(r"%?([\w.-]+)", args))
        if base == "all-reduce":
            per_kind[base] += 2 * (operand_b or result_b)
        elif base == "reduce-scatter":
            per_kind[base] += operand_b or result_b
        else:
            per_kind[base] += result_b
    return per_kind


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_breakdown: Dict[str, float] = field(default_factory=dict)
    peak_memory_per_chip: float = 0.0
    model_flops: float = 0.0          # 6*N(_active)*D convention, global

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / global HLO flops — remat/redundancy waste meter."""
        hlo_global = self.flops_per_chip * self.chips
        return self.model_flops / hlo_global if hlo_global else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute fraction of peak at the modeled step time."""
        if self.t_bound == 0:
            return 0.0
        return (self.model_flops / self.chips / self.t_bound) / PEAK_FLOPS

    def to_dict(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_chip": self.flops_per_chip,
            "bytes_per_chip": self.bytes_per_chip,
            "coll_bytes_per_chip": self.coll_bytes_per_chip,
            "coll_breakdown": self.coll_breakdown,
            "peak_memory_per_chip": self.peak_memory_per_chip,
            "model_flops": self.model_flops,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops_for(arch_cfg, shape_cfg) -> float:
    """Useful-FLOPs convention (PaLM-style MFU accounting):
    per token, 2*N_active for the forward matmuls plus the causal
    self-attention term 2*S_ctx*H*hd per attention layer (x0.5 causal);
    train multiplies by 3 (fwd + bwd)."""
    n = arch_cfg.active_param_count()
    L_attn = arch_cfg.attn_layers
    H, hd = arch_cfg.n_heads, arch_cfg.resolved_head_dim
    S = shape_cfg.seq_len

    if shape_cfg.kind in ("train", "prefill"):
        tokens = shape_cfg.global_batch * S
        # qk^T + pv = 2 matmuls: 2 * 2 * S * (H*hd), halved for causality
        attn_fwd_per_tok = 2.0 * S * H * hd * L_attn * 0.5
        fwd = 2.0 * n + attn_fwd_per_tok
        mult = 3.0 if shape_cfg.kind == "train" else 1.0
        return mult * fwd * tokens
    # decode: one token per sequence, attends the full cache
    attn_per_tok = 2.0 * 2.0 * S * H * hd * L_attn
    return (2.0 * n + attn_per_tok) * shape_cfg.global_batch
