"""Loop-aware HLO cost analysis from ``compiled.as_text()``.

Why this exists: XLA-CPU's ``cost_analysis()`` counts a ``while`` body ONCE
regardless of trip count, so scanned-layer models (all of ours) would be
undercounted by ~n_layers x. This walker parses the post-SPMD HLO module,
recurses through fusions/calls/while bodies, multiplies while-body costs by
the trip count recovered from the loop condition, and accumulates:

* flops           — dot: 2*result_numel*contracted_size; convolution:
                    2*result_numel*window*cin/groups; elementwise ~ numel;
                    reduce ~ operand numel.
* hbm_bytes       — TPU-fusion-approximating HBM traffic: on TPU,
                    elementwise/reduction chains fuse into their matmul
                    neighbors, so only (a) dot/convolution operands+results,
                    (b) dynamic-(update-)slice windows into large buffers
                    (KV-cache updates, scanned-weight slicing), (c) fusion
                    boundaries, and (d) collective payloads touch HBM.
                    Pure-elementwise traffic is deliberately excluded —
                    an under-estimate for elementwise-heavy blocks (mamba
                    scans), noted in EXPERIMENTS.md.
* collective bytes— per kind, with the all-reduce 2x (RS+AG ring) factor,
                    loop-multiplied like everything else.

All quantities are per-device (the module is post-partitioning).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-_]+)\s*\(.*->.*\{$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-_]+)\s*=\s*(.+)$")
_OPCODE_RE = re.compile(r"^\s*([\w\-]+)\(")

ELEMENTWISE_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "rsqrt", "sqrt", "tanh", "logistic", "power", "and", "or", "xor", "not",
    "select", "clamp", "compare", "floor", "ceil", "round-nearest-afz",
    "sign", "cosine", "sine", "atan2", "remainder", "shift-left",
    "shift-right-logical", "shift-right-arithmetic",
}
FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "reshape", "broadcast", "iota", "copy", "convert", "transpose",
    "slice", "dynamic-slice", "dynamic-update-slice", "concatenate",
    "pad", "reverse", "gather", "scatter", "reduce", "reduce-window",
    "rng", "rng-bit-generator", "after-all", "partition-id", "replica-id",
    "optimization-barrier", "copy-start", "copy-done", "custom-call",
    "get-dimension-size", "sort", "map", "infeed", "outfeed", "domain",
}
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_info(type_str: str) -> Tuple[int, List[Tuple[str, List[int]]]]:
    """bytes and [(dtype, dims)] of an HLO type string (maybe tuple)."""
    total, shapes = 0, []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims_s = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in dims_s.split(",") if d]
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
        shapes.append((dt, dims))
    return total, shapes


def _numel(dims: List[int]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


@dataclass
class Instr:
    name: str
    opcode: str
    result_type: str
    result_bytes: int
    result_shapes: List
    operands: List[str]
    raw: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    by_name: Dict[str, Instr] = field(default_factory=dict)


def parse_module(hlo_text: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if cur is None:
            m = _COMP_HDR.match(stripped)
            if m and stripped.endswith("{"):
                cur = Computation(m.group(1))
                if stripped.startswith("ENTRY"):
                    entry = m.group(1)
            continue
        if stripped.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        im = _INSTR_RE.match(line)
        if not im:
            continue
        name, rhs = im.group(1), im.group(2)
        om = None
        # result type = text up to the opcode token
        opm = re.search(r"\s([\w\-]+)\(", " " + rhs)
        if not opm:
            continue
        result_type = rhs[:opm.start()].strip() if opm.start() > 0 else ""
        opcode = opm.group(1)
        rbytes, rshapes = _shape_info(result_type)
        # operands: names inside the first paren group
        args_start = rhs.find(opcode + "(") + len(opcode) + 1
        depth, i = 1, args_start
        while i < len(rhs) and depth:
            if rhs[i] == "(":
                depth += 1
            elif rhs[i] == ")":
                depth -= 1
            i += 1
        args = rhs[args_start:i - 1]
        operands = re.findall(r"%([\w.\-_]+)", args)
        ins = Instr(name, opcode, result_type, rbytes, rshapes, operands, rhs)
        cur.instrs.append(ins)
        cur.by_name[name] = ins
    return comps, entry or ""


def _while_trip_count(comps, cond_name: str) -> int:
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    consts = {}
    for ins in cond.instrs:
        if ins.opcode == "constant":
            m = re.search(r"constant\((-?\d+)\)", ins.raw)
            if m:
                consts[ins.name] = int(m.group(1))
    for ins in cond.instrs:
        if ins.opcode == "compare":
            for o in ins.operands:
                if o in consts:
                    return max(consts[o], 1)
    return 1


_CALL_TARGET = re.compile(
    r"(?:calls|to_apply|body)=%?([\w.\-_]+)")
_COND_TARGET = re.compile(r"condition=%?([\w.\-_]+)")


def _dot_flops(comp: Computation, ins: Instr) -> float:
    lhs = comp.by_name.get(ins.operands[0]) if ins.operands else None
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.raw)
    if lhs is None or not lhs.result_shapes or m is None:
        return 2.0 * _numel(ins.result_shapes[0][1]) if ins.result_shapes \
            else 0.0
    dims = lhs.result_shapes[0][1]
    contracted = 1
    for d in m.group(1).split(","):
        if d:
            contracted *= dims[int(d)]
    out = _numel(ins.result_shapes[0][1]) if ins.result_shapes else 0
    return 2.0 * out * contracted


def _conv_flops(comp: Computation, ins: Instr) -> float:
    rhs_op = comp.by_name.get(ins.operands[1]) if len(ins.operands) > 1 \
        else None
    out = _numel(ins.result_shapes[0][1]) if ins.result_shapes else 0
    if rhs_op is None or not rhs_op.result_shapes:
        return 2.0 * out
    kdims = rhs_op.result_shapes[0][1]
    # kernel = spatial... x cin x cout; conservative: numel/cout
    cout = kdims[-1] if kdims else 1
    m = re.search(r"feature_group_count=(\d+)", ins.raw)
    groups = int(m.group(1)) if m else 1
    per_out = _numel(kdims) / max(cout, 1) / groups
    return 2.0 * out * per_out


@dataclass
class CostTotals:
    flops: float = 0.0
    contraction_flops: float = 0.0   # dot/conv only (fusion-boundary gate)
    hbm_bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=lambda: {
        k: 0.0 for k in COLLECTIVES})

    def add(self, other: "CostTotals", mult: float = 1.0):
        self.flops += other.flops * mult
        self.contraction_flops += other.contraction_flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        for k, v in other.coll.items():
            self.coll[k] += v * mult

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())


def _cost_of(comps, comp_name: str, memo: Dict[str, CostTotals]
             ) -> CostTotals:
    if comp_name in memo:
        return memo[comp_name]
    comp = comps.get(comp_name)
    total = CostTotals()
    if comp is None:
        memo[comp_name] = total
        return total
    memo[comp_name] = total  # break cycles
    for ins in comp.instrs:
        opc = ins.opcode
        operand_bytes = sum(
            comp.by_name[o].result_bytes for o in ins.operands
            if o in comp.by_name)
        if opc == "while":
            body_m = re.search(r"body=%?([\w.\-_]+)", ins.raw)
            tm_ = _TRIP_RE.search(ins.raw)
            if tm_:
                trip = int(tm_.group(1))
            else:
                cond_m = _COND_TARGET.search(ins.raw)
                trip = _while_trip_count(comps, cond_m.group(1)) \
                    if cond_m else 1
            if body_m:
                total.add(_cost_of(comps, body_m.group(1), memo), trip)
            continue
        if opc in ("fusion", "call"):
            tm = _CALL_TARGET.search(ins.raw)
            if tm:
                sub = _cost_of(comps, tm.group(1), memo)
                total.flops += sub.flops
                total.contraction_flops += sub.contraction_flops
                for k, v in sub.coll.items():
                    total.coll[k] += v
                # only contraction-bearing fusions are HBM boundaries; pure
                # elementwise fusions are assumed folded into their matmul
                # neighbors on TPU (the Pallas-fused ideal)
                if sub.contraction_flops > 0:
                    total.hbm_bytes += operand_bytes + ins.result_bytes
            continue
        if opc == "conditional":
            for tm in re.finditer(r"(?:true_computation|false_computation|"
                                  r"branch_computations)=.*?%?([\w.\-_]+)",
                                  ins.raw):
                total.add(_cost_of(comps, tm.group(1), memo), 1.0)
            continue
        base = opc[:-6] if opc.endswith("-start") else opc
        if base in COLLECTIVES:
            if base == "all-reduce":
                total.coll[base] += 2 * (operand_bytes or ins.result_bytes)
            elif base == "reduce-scatter":
                total.coll[base] += operand_bytes or ins.result_bytes
            else:
                total.coll[base] += ins.result_bytes
            total.hbm_bytes += operand_bytes + ins.result_bytes
            continue
        if opc == "dot":
            f = _dot_flops(comp, ins)
            total.flops += f
            total.contraction_flops += f
            total.hbm_bytes += operand_bytes + ins.result_bytes
        elif opc == "convolution":
            f = _conv_flops(comp, ins)
            total.flops += f
            total.contraction_flops += f
            total.hbm_bytes += operand_bytes + ins.result_bytes
        elif opc == "dynamic-slice":
            # reads only the sliced window (= result)
            total.hbm_bytes += ins.result_bytes
        elif opc == "dynamic-update-slice":
            # read-modify-write of the update window (operand 1)
            upd = comp.by_name.get(ins.operands[1]) \
                if len(ins.operands) > 1 else None
            total.hbm_bytes += 2 * (upd.result_bytes if upd else 0)
        elif opc in ("gather", "scatter"):
            total.hbm_bytes += 2 * ins.result_bytes
        elif opc in ELEMENTWISE_OPS:
            total.flops += float(_numel(ins.result_shapes[0][1])) \
                if ins.result_shapes else 0.0
        elif opc == "reduce":
            src = comp.by_name.get(ins.operands[0]) if ins.operands else None
            if src and src.result_shapes:
                total.flops += float(_numel(src.result_shapes[0][1]))
        # other elementwise/reduce/layout ops: fused on TPU, no HBM cost
    memo[comp_name] = total
    return total


def analyze_hlo(hlo_text: str) -> CostTotals:
    comps, entry = parse_module(hlo_text)
    if not entry:
        # fall back: largest computation
        entry = max(comps, key=lambda c: len(comps[c].instrs)) if comps \
            else ""
    return _cost_of(comps, entry, {})
