"""Telemetry CLI: tail / report over the obs JSONL stream.

The serving launchers (``serve.py --obs``, ``optimize.py --obs``) and
the benches stream interleaved metrics snapshots and span records to a
JSONL file (see :mod:`repro.obs.export`). This CLI is the offline /
live reader over that one artifact:

* ``tail`` — follow the stream and pretty-print records as they land
  (spans as one-liners, metrics snapshots as deltas of a few headline
  keys);
* ``report`` — reconstruct the whole session: span trees reassembled
  across processes, a per-phase latency waterfall (count / mean / p95 /
  errors per span name), the slowest-trace table with full tree
  rendering, and the final drift/alarm gauges.

    PYTHONPATH=src python -m repro.launch.obs report obs_telemetry.jsonl
    PYTHONPATH=src python -m repro.launch.obs tail obs_telemetry.jsonl
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Any, Dict, Iterable, List, Tuple

from repro.obs.trace import TraceTree, assemble, completeness


def read_records(path: str) -> Tuple[List[Dict[str, Any]],
                                     List[Dict[str, Any]]]:
    """Split one telemetry stream into (span records, metric snapshots).
    Tolerates junk lines — a telemetry reader must never crash on a
    torn write."""
    spans: List[Dict[str, Any]] = []
    metrics: List[Dict[str, Any]] = []
    with open(path, encoding="utf-8") as f:
        for ln in f:
            ln = ln.strip()
            if not ln:
                continue
            try:
                rec = json.loads(ln)
            except ValueError:
                continue
            if rec.get("kind") == "span":
                spans.append(rec)
            elif rec.get("kind") == "metrics":
                metrics.append(rec)
    return spans, metrics


# ------------------------------------------------------------------ report
def _pct(vals: List[float], q: float) -> float:
    if not vals:
        return 0.0
    s = sorted(vals)
    return s[min(int(q * len(s)), len(s) - 1)]


def waterfall(spans: Iterable[Dict[str, Any]]) -> List[Tuple]:
    """Per-phase latency table: (name, count, mean_ms, p95_ms, errs),
    heaviest total time first — the one-look answer to 'where does a
    request's wall actually go'."""
    by_name: Dict[str, List[float]] = {}
    errs: Dict[str, int] = {}
    for s in spans:
        by_name.setdefault(s["name"], []).append(float(s["dur_s"]))
        if s.get("status") not in (None, "ok"):
            errs[s["name"]] = errs.get(s["name"], 0) + 1
    rows = []
    for name, ds in by_name.items():
        rows.append((name, len(ds), 1e3 * sum(ds) / len(ds),
                     1e3 * _pct(ds, 0.95), errs.get(name, 0)))
    rows.sort(key=lambda r: -(r[1] * r[2]))
    return rows


def render_tree(tree: TraceTree, indent: str = "  ") -> List[str]:
    lines = []
    for depth, s in tree.walk():
        tags = " ".join(f"{k}={v}" for k, v in sorted(s["tags"].items()))
        mark = "" if s["status"] == "ok" else f" [{s['status']}]"
        lines.append(f"{indent * depth}{s['name']} "
                     f"{1e3 * s['dur_s']:.2f}ms ({s['proc']}){mark}"
                     f"{('  ' + tags) if tags else ''}")
    for s in tree.orphans:
        lines.append(f"  ?orphan {s['name']} (parent {s['parent'][:8]} "
                     f"missing)")
    return lines


def cmd_report(args) -> int:
    spans, metrics = read_records(args.path)
    trees = assemble(spans)
    print(f"{args.path}: {len(spans)} spans, {len(trees)} traces, "
          f"{len(metrics)} metric snapshots")
    if trees:
        procs = sorted({p for t in trees.values() for p in t.procs})
        print(f"traces reconstruct at {completeness(trees):.1%} "
              f"completeness across procs {procs}")
        print("\nlatency waterfall (per span name):")
        print(f"  {'phase':<24}{'count':>7}{'mean_ms':>10}"
              f"{'p95_ms':>10}{'errs':>6}")
        for name, n, mean, p95, ne in waterfall(spans):
            print(f"  {name:<24}{n:>7}{mean:>10.3f}{p95:>10.3f}{ne:>6}")
        slow = sorted((t for t in trees.values() if t.roots),
                      key=lambda t: -t.dur_s)[:args.slowest]
        print(f"\nslowest {len(slow)} trace(s):")
        for t in slow:
            state = "complete" if t.complete else \
                f"INCOMPLETE ({len(t.roots)} roots, " \
                f"{len(t.orphans)} orphans)"
            print(f"- trace {t.trace_id[:12]} {1e3 * t.dur_s:.2f}ms "
                  f"[{state}]")
            for ln in render_tree(t):
                print("    " + ln)
    if metrics:
        last = metrics[-1].get("metrics", {})
        drift = {k: v for k, v in sorted(last.items())
                 if k.startswith("drift.")}
        if drift:
            print("\nfinal drift gauges:")
            for k, v in drift.items():
                print(f"  {k} = {v:.4f}" if isinstance(v, float)
                      else f"  {k} = {v}")
        alarms = {k: v for k, v in last.items()
                  if k.endswith("_alarm") and v}
        if alarms:
            print(f"ALARMS ARMED: {sorted(alarms)}")
    return 0


# -------------------------------------------------------------------- tail
def _fmt_line(rec: Dict[str, Any]) -> str:
    if rec.get("kind") == "span":
        tags = " ".join(f"{k}={v}" for k, v in sorted(rec["tags"].items()))
        return (f"span  {rec['trace'][:10]} {rec['name']:<22} "
                f"{1e3 * rec['dur_s']:9.3f}ms {rec['proc']:<10} "
                f"{rec['status']}{('  ' + tags) if tags else ''}")
    if rec.get("kind") == "metrics":
        m = rec.get("metrics", {})
        keys = ("server.requests", "router.shed_count", "drift.scored",
                "drift.oov_alarm", "trace.buffered_spans")
        picks = " ".join(f"{k.split('.', 1)[1]}={m[k]}"
                         for k in keys if k in m)
        return f"metrics seq={rec.get('seq')} {picks}"
    return json.dumps(rec)[:120]


def cmd_tail(args) -> int:
    with open(args.path, encoding="utf-8") as f:
        while True:
            ln = f.readline()
            if ln:
                try:
                    rec = json.loads(ln)
                except ValueError:
                    continue
                if args.kind in (None, rec.get("kind")):
                    print(_fmt_line(rec), flush=True)
            elif args.follow:
                time.sleep(0.2)
            else:
                return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Inspect the unified-telemetry JSONL stream "
                    "written by --obs runs and the obs bench.")
    sub = ap.add_subparsers(dest="cmd", required=True)
    t = sub.add_parser("tail", help="print records (optionally follow)")
    t.add_argument("path")
    t.add_argument("--follow", "-f", action="store_true",
                   help="keep waiting for new lines (live session)")
    t.add_argument("--kind", choices=("span", "metrics"), default=None,
                   help="only this record kind")
    t.set_defaults(fn=cmd_tail)
    r = sub.add_parser("report", help="session report: trees, "
                       "waterfall, slowest traces, drift gauges")
    r.add_argument("path")
    r.add_argument("--slowest", type=int, default=3,
                   help="how many slowest traces to render fully")
    r.set_defaults(fn=cmd_report)
    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
