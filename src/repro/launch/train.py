"""Production training driver for the MLIR cost model.

Wires together every substrate layer: dataset build (or load), sharded data
pipeline, model init, mesh + sharding rules, AdamW, int8 error-feedback
gradient compression on the DP axis, fault-tolerant supervisor (atomic
checkpoints, resume, preemption handling), and evaluation.

    PYTHONPATH=src python -m repro.launch.train --preset small --steps 300
    PYTHONPATH=src python -m repro.launch.train --preset 100m --steps 200 \
        --mesh-data 2   # DP across host devices if available

On the production cluster the same driver runs under the 16x16 mesh with
``--mesh-data 16 --mesh-model 16`` (the cost model is small enough that DP
dominates; model axes shard the embedding + wide FC layers).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.costmodel import (COSTMODEL_100M, COSTMODEL_BASE,
                                     COSTMODEL_SMALL, CostModelConfig)
from repro.core import models as CM
from repro.core import trainer as TR
from repro.data import pipeline as PIPE
from repro.ir import dataset as DS
from repro.optim import adamw, compress
from repro.runtime import fault
from repro.runtime.sharding import ShardingRules

PRESETS = {"small": COSTMODEL_SMALL, "base": COSTMODEL_BASE,
           "100m": COSTMODEL_100M}


def build_or_load_dataset(args, cfg) -> DS.CostDataset:
    path = args.dataset
    if path and os.path.exists(path):
        return DS.CostDataset.load(path)
    ds = DS.build_dataset(args.n_graphs, mode=args.mode,
                          max_seq=cfg.max_seq, vocab_size=cfg.vocab_size,
                          augment_factor=2, seed=args.seed)
    if path:
        ds.save(path)
    return ds


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="small", choices=sorted(PRESETS))
    ap.add_argument("--model", default="conv1d",
                    choices=sorted(CM.MODELS))
    ap.add_argument("--target", default="register_pressure",
                    help="target name, comma-separated list for a joint "
                         "multi-head model, or 'all'")
    ap.add_argument("--mode", default="ops",
                    choices=["ops", "ops_operands"])
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--n-graphs", type=int, default=2000)
    ap.add_argument("--dataset", default=None)
    ap.add_argument("--ckpt-dir", default="checkpoints/costmodel")
    ap.add_argument("--save-every", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--no-check-treedef", action="store_true",
                    help="resume across benign checkpoint treedef-repr "
                         "drift (e.g. after a JAX upgrade)")
    ap.add_argument("--eval-only", action="store_true")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    ds = build_or_load_dataset(args, cfg)
    train, test = ds.split(0.1, seed=args.seed)
    print(f"dataset: {len(train.ids)} train / {len(test.ids)} test, "
          f"vocab={ds.vocab.size}, mode={ds.mode}")

    if args.target == "all":
        heads = tuple(sorted(train.targets))
    else:
        heads = tuple(t for t in args.target.split(",") if t)
    unknown = sorted(set(heads) - set(train.targets))
    if not heads or unknown:
        ap.error(f"unknown target(s) {unknown or [args.target]}; "
                 f"available: {sorted(train.targets)} or 'all'")
    multi = len(heads) > 1

    mesh = jax.make_mesh((args.mesh_data, args.mesh_model),
                         ("data", "model"))
    rules = ShardingRules(mesh)
    init_fn, apply_fn, axes_fn = CM.get_model(args.model)
    if multi:
        params = init_fn(jax.random.PRNGKey(args.seed), cfg, heads=heads)
    else:
        params = init_fn(jax.random.PRNGKey(args.seed), cfg)
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    print(f"model: {args.model}/{args.preset}, {n_params/1e6:.1f}M params, "
          f"heads={list(heads)}")

    if multi:
        y, norm_stats = DS.stacked_normalized_targets(train.targets, heads)
    else:
        y, norm_stats = DS.normalize_targets(train.targets[heads[0]])
        y = y.astype(np.float32)
    src = PIPE.ArraySource(ids=train.ids, y=y)
    loader = PIPE.Loader(src, args.batch, seed=args.seed)

    opt_cfg = adamw.AdamWConfig(lr=args.lr, total_steps=args.steps,
                                warmup_steps=min(50, args.steps // 10),
                                weight_decay=0.01)
    err_state = compress.init_error_state(params) if args.compress_grads \
        else None

    loss_fn = TR.make_loss_fn(apply_fn, heads if multi else None)

    @jax.jit
    def train_step(state, ids, yy):
        params, opt_state, err = state
        loss, grads = jax.value_and_grad(loss_fn)(params, ids, yy)
        if err is not None:
            grads, err = compress.compress_grads(grads, err)
        params, opt_state, m = adamw.apply_updates(params, grads, opt_state,
                                                   opt_cfg)
        return (params, opt_state, err), loss

    sup = fault.TrainSupervisor(args.ckpt_dir, save_every=args.save_every)
    sup.install_signal_handler()
    state = (params, adamw.init_state(params), err_state)
    state, start, extra = sup.try_restore(
        state, check_treedef=not args.no_check_treedef)
    if start:
        print(f"resumed from step {start}")
        loader.state = PIPE.LoaderState(**extra.get("loader", {}))

    it = iter(loader)
    losses = []

    def step_fn(state, step):
        batch = next(it)
        state, loss = train_step(state, jnp.asarray(batch["ids"]),
                                 jnp.asarray(batch["y"]))
        losses.append(float(loss))
        return state

    def on_step(step, dt):
        if step % 50 == 0 or step == args.steps:
            print(f"step {step}: loss={losses[-1]:.4f} ({dt*1e3:.0f} ms)")

    if not args.eval_only:
        t0 = time.time()
        with mesh:
            state = sup.run(state, step_fn, args.steps, start_step=start,
                            extra_fn=lambda: {"loader":
                                              loader.state.as_dict(),
                                              "norm_stats": norm_stats,
                                              "heads": list(heads)},
                            on_step=on_step)
        print(f"trained {args.steps - start} steps in "
              f"{time.time()-t0:.1f}s")

    result = TR.TrainResult(params=state[0], stats={},
                            norm_stats=norm_stats,
                            heads=heads if multi else None)
    if multi:
        metrics = TR.evaluate(args.model, cfg, result, test)
        for t, m in metrics.items():
            print(f"eval[{t}]:",
                  json.dumps({k: round(v, 3) for k, v in m.items()}))
    else:
        metrics = TR.evaluate(args.model, cfg, result, test, heads[0])
        print("eval:",
              json.dumps({k: round(v, 3) for k, v in metrics.items()}))
    return metrics


if __name__ == "__main__":
    main()
