"""Production training CLI — a thin argparse layer over TrainEngine.

The engine (core/trainer.py) owns the step loop and wires every substrate
layer: bucketed dataset build (or load), sharded bucket-aware pipeline,
mesh + sharding rules, AdamW, int8 error-feedback grad compression on the
DP axis, fault-tolerant supervisor (atomic checkpoints, resume with the
loader cursor, preemption handling), and evaluation.

    PYTHONPATH=src python -m repro.launch.train --preset small --steps 300
    PYTHONPATH=src python -m repro.launch.train --preset 100m --steps 200 \
        --mesh-data 2   # DP across host devices if available

On the production cluster the same driver runs under the 16x16 mesh with
``--mesh-data 16 --mesh-model 16`` (the cost model is small enough that DP
dominates; model axes shard the embedding + wide FC layers).
"""
from __future__ import annotations

import argparse
import json
import os

import jax

from repro.configs.costmodel import (COSTMODEL_100M, COSTMODEL_BASE,
                                     COSTMODEL_SMALL)
from repro.core import models as CM
from repro.core import trainer as TR
from repro.ir import dataset as DS
from repro.optim import adamw, compress
from repro.runtime import fault

PRESETS = {"small": COSTMODEL_SMALL, "base": COSTMODEL_BASE,
           "100m": COSTMODEL_100M}


def build_or_load_dataset(args, cfg) -> DS.CostDataset:
    path = args.dataset
    if path and os.path.exists(path):
        return DS.CostDataset.load(path)
    ds = DS.build_dataset(args.n_graphs, mode=args.mode,
                          max_seq=cfg.max_seq, vocab_size=cfg.vocab_size,
                          augment_factor=2, seed=args.seed,
                          layout=args.layout)
    if path:
        ds.save(path)
    return ds


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="small", choices=sorted(PRESETS))
    ap.add_argument("--model", default="conv1d",
                    choices=sorted(CM.MODELS))
    ap.add_argument("--target", default="register_pressure",
                    help="target name, comma-separated list for a joint "
                         "multi-head model, or 'all'")
    ap.add_argument("--mode", default="ops",
                    choices=["ops", "ops_operands"])
    ap.add_argument("--layout", default="bucketed",
                    choices=["bucketed", "dense"],
                    help="id storage: per-bucket arrays (RAM-proportional "
                         "to real tokens) or one (N, max_seq) array")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--n-graphs", type=int, default=2000)
    ap.add_argument("--dataset", default=None)
    ap.add_argument("--ckpt-dir", default="checkpoints/costmodel")
    ap.add_argument("--save-every", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--no-bucketing", action="store_true",
                    help="pad every batch to max_seq instead of per-bucket")
    ap.add_argument("--no-check-treedef", action="store_true",
                    help="resume across benign checkpoint treedef-repr "
                         "drift (e.g. after a JAX upgrade)")
    ap.add_argument("--eval-only", action="store_true")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    ds = build_or_load_dataset(args, cfg)
    train, test = ds.split(0.1, seed=args.seed)
    print(f"dataset: {len(train)} train / {len(test)} test, "
          f"vocab={ds.vocab.size}, mode={ds.mode}, layout="
          f"{'dense' if ds.ids is not None else 'bucketed'}")

    if args.target == "all":
        heads = tuple(sorted(train.targets))
    else:
        heads = tuple(t for t in args.target.split(",") if t)
    unknown = sorted(set(heads) - set(train.targets))
    if not heads or unknown:
        ap.error(f"unknown target(s) {unknown or [args.target]}; "
                 f"available: {sorted(train.targets)} or 'all'")
    target = heads if len(heads) > 1 else heads[0]

    engine = TR.TrainEngine(
        args.model, cfg, target,
        steps=args.steps, batch_size=args.batch, lr=args.lr,
        seed=args.seed, log_every=50, verbose=True,
        bucketed=not args.no_bucketing,
        mesh_data=args.mesh_data, mesh_model=args.mesh_model,
        compress_grads=args.compress_grads,
        ckpt_dir=args.ckpt_dir, save_every=args.save_every,
        check_treedef=not args.no_check_treedef, install_sigterm=True)

    if args.eval_only:
        init_kw = {"heads": engine.heads} if engine.heads else {}
        params = engine.init_fn(jax.random.PRNGKey(args.seed), cfg,
                                **init_kw)
        like = (params, adamw.init_state(params),
                compress.init_error_state(params)
                if args.compress_grads else None)
        sup = fault.TrainSupervisor(args.ckpt_dir)
        state, start, extra = sup.try_restore(
            like, check_treedef=not args.no_check_treedef)
        if not start:
            ap.error(f"--eval-only: no checkpoint under {args.ckpt_dir}")
        result = TR.TrainResult(params=state[0], stats={},
                                norm_stats=extra["norm_stats"],
                                heads=engine.heads)
    else:
        result = engine.fit(train)
        if result.stats["steps"]:
            print(f"trained {result.stats['steps']:.0f} steps in "
                  f"{result.stats['wall_time_s']:.1f}s "
                  f"({result.stats['steps_per_s']:.1f} steps/s)")
        else:
            print(f"run already complete in {args.ckpt_dir}; evaluating")

    if engine.heads:
        metrics = TR.evaluate(args.model, cfg, result, test)
        for t, m in metrics.items():
            print(f"eval[{t}]:",
                  json.dumps({k: round(v, 3) for k, v in m.items()}))
    else:
        metrics = TR.evaluate(args.model, cfg, result, test, target)
        print("eval:",
              json.dumps({k: round(v, 3) for k, v in metrics.items()}))
    return metrics


if __name__ == "__main__":
    main()
