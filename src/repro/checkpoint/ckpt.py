"""Sharded, atomic, elastic checkpointing (no orbax/tensorstore available).

Layout::

    <dir>/step_000123/
        manifest.json          # tree structure, shapes, dtypes, shard map,
                               # integrity hashes, loader cursor, mesh shape
        leaf_00000.npy ...     # one file per pytree leaf (np arrays)
        _COMMITTED             # written last: atomic-commit marker

Fault-tolerance contract:
* save is atomic — a crash mid-save leaves no _COMMITTED marker and the
  restore path ignores the partial directory;
* restore picks the newest committed step <= requested;
* elastic re-shard: arrays are stored unsharded (gathered views); on load
  they are device_put against the *current* mesh's shardings, so a job can
  restart on a different mesh/pod count without conversion.

For true at-scale use each host writes only the shards it owns; here the
single-process layout keeps the same manifest contract.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

COMMIT_MARKER = "_COMMITTED"


def _tree_paths(tree) -> Dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


def save(directory: str, step: int, tree, *, extra: Optional[Dict] = None,
         keep: int = 3) -> str:
    """Atomic checkpoint save. Returns the committed path."""
    final = os.path.join(directory, f"step_{step:09d}")
    os.makedirs(directory, exist_ok=True)
    tmp = tempfile.mkdtemp(prefix=".tmp_ckpt_", dir=directory)
    leaves, treedef = jax.tree.flatten(tree)
    manifest = {"step": step, "n_leaves": len(leaves),
                "treedef": str(treedef),
                "extra": extra or {}, "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append({
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "sha1": hashlib.sha1(arr.tobytes()).hexdigest()})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, COMMIT_MARKER), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int):
    steps = sorted(latest_steps(directory))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:09d}"),
                      ignore_errors=True)


def latest_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and os.path.exists(
                os.path.join(directory, name, COMMIT_MARKER)):
            out.append(int(name[5:]))
    return sorted(out)


def restore(directory: str, like, *, step: Optional[int] = None,
            shardings=None, verify: bool = False,
            check_treedef: bool = True
            ) -> Tuple[Any, int, Dict]:
    """Restore newest committed checkpoint into the structure of ``like``.

    shardings: optional pytree of NamedShardings (same structure) — enables
    elastic re-shard onto the current mesh.

    check_treedef guards structure drift: leaves are matched by flatten
    order, so restoring e.g. a single-head cost-model checkpoint into a
    multi-head param tree (or a tree with renamed heads) must fail loudly
    rather than silently permuting weights. Pass False only when the
    treedef repr is known to differ benignly (e.g. across JAX versions)."""
    steps = latest_steps(directory)
    if step is not None:
        steps = [s for s in steps if s <= step]
    if not steps:
        raise FileNotFoundError(f"no committed checkpoints in {directory}")
    chosen = steps[-1]
    path = os.path.join(directory, f"step_{chosen:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_like, treedef = jax.tree.flatten(like)
    if len(leaves_like) != manifest["n_leaves"]:
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, model expects "
            f"{len(leaves_like)} — was the model reconfigured (e.g. "
            f"single-head -> multi-head) since the checkpoint was saved?")
    if check_treedef and manifest.get("treedef") not in (None, str(treedef)):
        raise ValueError(
            f"checkpoint tree structure differs from the model's:\n"
            f"  ckpt:  {manifest['treedef']}\n"
            f"  model: {treedef}\n"
            f"(pass check_treedef=False to force order-based matching)")
    shard_leaves = jax.tree.leaves(shardings) if shardings is not None \
        else [None] * len(leaves_like)
    out = []
    for i, (meta, ref, shd) in enumerate(
            zip(manifest["leaves"], leaves_like, shard_leaves)):
        arr = np.load(os.path.join(path, meta["file"]))
        if verify:
            assert hashlib.sha1(arr.tobytes()).hexdigest() == meta["sha1"], \
                f"integrity failure on leaf {i}"
        assert tuple(arr.shape) == tuple(ref.shape), \
            f"leaf {i}: ckpt {arr.shape} vs model {ref.shape}"
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.device_put(arr))
    return jax.tree.unflatten(treedef, out), chosen, manifest["extra"]
