"""int8 error-feedback gradient compression for the data-parallel axis.

At 1000+-node scale the DP all-reduce dominates step time for small models
(like the cost model). We quantize gradients to int8 with per-tensor scale
before the reduction and carry the quantization error into the next step
(error feedback preserves convergence; Karimireddy et al. 2019).

Used as a ``grad_transform`` hook in the train steps; the quantize/
dequantize pair brackets the (implicit or explicit) all-reduce so XLA
transfers 1/4 of the bytes on the wire.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def init_error_state(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def quantize(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_grads(grads, error_state):
    """Returns (compressed-then-decompressed grads, new error state).

    The int8 representation is what crosses the DP axis; the residual is
    accumulated locally (error feedback)."""
    def one(g, e):
        g = g.astype(jnp.float32) + e
        q, scale = quantize(g)
        g_hat = dequantize(q, scale)
        return g_hat, g - g_hat

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_state)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tdef, [o[0] for o in outs]),
            jax.tree.unflatten(tdef, [o[1] for o in outs]))


def make_compressed_psum(axis_name: str):
    """Explicit compressed all-reduce for use inside shard_map: quantize,
    psum the int8 payload (as int32 accumulator), dequantize."""
    def compressed_psum(g):
        q, scale = quantize(g)
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        scale_max = jax.lax.pmax(scale, axis_name)
        n = jax.lax.psum(jnp.ones(()), axis_name)
        return total.astype(jnp.float32) * scale_max / n
    return compressed_psum
