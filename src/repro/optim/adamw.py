"""AdamW + schedules + global-norm clipping, pure JAX (no optax here).

State layout mirrors the param pytree: {"m": tree, "v": tree, "count": i32}.
All moments are fp32 regardless of param dtype.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"   # cosine | linear | constant
    min_lr_ratio: float = 0.1


def schedule_lr(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        decay = 1.0
    else:
        frac = jnp.clip((step - cfg.warmup_steps) /
                        jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                        0.0, 1.0)
        if cfg.schedule == "cosine":
            decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * \
                0.5 * (1 + jnp.cos(jnp.pi * frac))
        else:
            decay = 1.0 - (1 - cfg.min_lr_ratio) * frac
    return cfg.lr * warm * decay


def init_state(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros,
            "v": jax.tree.map(jnp.copy, zeros),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def apply_updates(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    lr = schedule_lr(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m_new / b1c
        vhat = v_new / b2c
        step_ = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:   # decay matrices only
            step_ = step_ + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * step_
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "count": count}, metrics
