"""Configs for the paper's cost model (tokenizer + Conv1D/LSTM/FC regressors).

The paper fixes: embedding dim 64; 6 stacked Conv1D (filter size 2 for the
ops-only tokenization; 16,16,8,8,2,1 for ops+operands), one MaxPool1D, 3 FC
layers. Channel widths are not given in the paper; we use 64 throughout for
the base model (matching the embedding width) and note this in DESIGN.md.

``COSTMODEL_100M`` is the scaled config used by the end-to-end training
driver (examples/train_costmodel_100m.py): same topology, wide channels.
"""
from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class CostModelConfig:
    name: str
    vocab_size: int            # filled after tokenizer fit; this is the cap
    max_seq: int               # token sequence length (padded/truncated)
    embed_dim: int = 64
    conv_filters: Tuple[int, ...] = (2, 2, 2, 2, 2, 2)       # ops-only (Fig 5)
    conv_channels: Tuple[int, ...] = (64, 64, 64, 64, 64, 64)
    # two hidden FC; the final scalar head is the 3rd
    fc_dims: Tuple[int, ...] = (256, 64)
    lstm_hidden: int = 128
    dropout: float = 0.0
    dtype: str = "float32"

    @property
    def n_conv(self) -> int:
        return len(self.conv_filters)


# Small config for unit tests.
COSTMODEL_SMALL = CostModelConfig(
    name="costmodel-small", vocab_size=512, max_seq=64,
    embed_dim=16, conv_channels=(16,) * 6, fc_dims=(32, 16), lstm_hidden=16)

# Paper-faithful base: ops-only tokenization, fs=2 x6 (Fig 5).
COSTMODEL_BASE = CostModelConfig(
    name="costmodel-base", vocab_size=8192, max_seq=256)

# Ops+operands variant: fs = 16,16,8,8,2,1 (Fig 6), ~4x longer sequences.
COSTMODEL_OPERAND = CostModelConfig(
    name="costmodel-operand", vocab_size=16384, max_seq=1024,
    conv_filters=(16, 16, 8, 8, 2, 1))

# ~100M-parameter scaled config for the end-to-end distributed driver.
# params: 32768*512 emb (16.8M) + convs (~21M) + fc 2048 (~65M) ~= 103M
COSTMODEL_100M = CostModelConfig(
    name="costmodel-100m", vocab_size=32768, max_seq=1024, embed_dim=512,
    conv_filters=(16, 16, 8, 8, 2, 1),
    conv_channels=(1024, 1024, 1024, 1024, 1024, 1024),
    fc_dims=(2048, 512), lstm_hidden=512)
