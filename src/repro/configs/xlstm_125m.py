"""xlstm-125m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified]."""
from repro.configs.base import ArchConfig, XLSTMConfig

CONFIG = ArchConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab=50304, head_dim=192,
    xlstm=XLSTMConfig(slstm_at=(1, 3, 5, 7, 9, 11)),
    tie_embeddings=True,
    source="arXiv:2405.04517; unverified",
)
