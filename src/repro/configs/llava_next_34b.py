"""llava-next-34b [vlm] — anyres tiling
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=20480,
    vocab=64000, head_dim=128,
    frontend="vision", vision_patches=2880,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
)
