"""Config registry: ``get_arch(name)`` / ``ARCHS`` / ``SHAPES``."""
from repro.configs.base import (ArchConfig, MoEConfig, HybridConfig,
                                XLSTMConfig, ShapeConfig, SHAPES,
                                shape_eligible)

from repro.configs.xlstm_125m import CONFIG as xlstm_125m
from repro.configs.granite_moe_1b_a400m import CONFIG as granite_moe_1b_a400m
from repro.configs.phi35_moe_42b_a66b import CONFIG as phi35_moe_42b_a66b
from repro.configs.qwen15_32b import CONFIG as qwen15_32b
from repro.configs.qwen3_06b import CONFIG as qwen3_06b
from repro.configs.starcoder2_3b import CONFIG as starcoder2_3b
from repro.configs.qwen3_17b import CONFIG as qwen3_17b
from repro.configs.whisper_small import CONFIG as whisper_small
from repro.configs.llava_next_34b import CONFIG as llava_next_34b
from repro.configs.jamba_v01_52b import CONFIG as jamba_v01_52b
from repro.configs.costmodel import (COSTMODEL_SMALL, COSTMODEL_BASE,
                                     COSTMODEL_100M)

ARCHS = {c.name: c for c in [
    xlstm_125m, granite_moe_1b_a400m, phi35_moe_42b_a66b, qwen15_32b,
    qwen3_06b, starcoder2_3b, qwen3_17b, whisper_small, llava_next_34b,
    jamba_v01_52b,
]}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


__all__ = ["ArchConfig", "MoEConfig", "HybridConfig", "XLSTMConfig",
           "ShapeConfig", "SHAPES", "ARCHS", "get_arch", "shape_eligible",
           "COSTMODEL_SMALL", "COSTMODEL_BASE", "COSTMODEL_100M"]
