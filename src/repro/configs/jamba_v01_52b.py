"""jamba-v0.1-52b [hybrid] — Mamba+attn 1:7 interleave, MoE
[arXiv:2403.19887; hf]."""
from repro.configs.base import ArchConfig, MoEConfig, HybridConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=65536, head_dim=128,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=14336, moe_every=2),
    hybrid=HybridConfig(period=8, attn_index=4, d_state=16, d_conv=4,
                        expand=2),
    source="arXiv:2403.19887; hf",
)
