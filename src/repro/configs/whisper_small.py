"""whisper-small [audio] — enc-dec, conv frontend (stub)
[arXiv:2212.04356; unverified]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
    vocab=51865, head_dim=64,
    encoder_decoder=True, n_encoder_layers=12, encoder_seq=1500,
    frontend="audio", tie_embeddings=True,
    source="arXiv:2212.04356; unverified",
)
