"""Architecture + shape configuration system.

Every assigned architecture is described by an :class:`ArchConfig`. Configs are
exact copies of the published numbers (see per-arch modules in this package).
``reduced()`` returns a CPU-smoke-test-sized config of the same family.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int          # per-expert hidden dim
    moe_every: int = 1        # 1 = every layer is MoE, 2 = alternate dense/MoE
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclass(frozen=True)
class HybridConfig:
    """Jamba-style interleave: one attention layer per ``period`` layers."""
    period: int = 8           # 1 attention : (period-1) mamba
    attn_index: int = 4       # which slot in the period is attention
    d_state: int = 16         # mamba SSM state dim
    d_conv: int = 4           # mamba conv kernel
    expand: int = 2           # mamba inner expansion


@dataclass(frozen=True)
class XLSTMConfig:
    """sLSTM/mLSTM block pattern for xLSTM."""
    slstm_at: Tuple[int, ...] = (1, 3, 5, 7, 9, 11)  # sLSTM slots; rest mLSTM
    proj_factor_mlstm: float = 2.0
    proj_factor_slstm: float = 4.0 / 3.0
    conv1d_kernel: int = 4


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str               # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0         # 0 -> d_model // n_heads
    # attention details
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    # MoE / hybrid / xlstm sub-configs
    moe: Optional[MoEConfig] = None
    hybrid: Optional[HybridConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    # enc-dec (whisper)
    encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 1500   # whisper: 30s audio -> 1500 frames
    # modality frontend stub: None | 'audio' | 'vision'
    frontend: Optional[str] = None
    vision_patches: int = 2880   # llava-next anyres: max patch-embedding count
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    source: str = ""          # provenance tag from the assignment table

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def attn_layers(self) -> int:
        """Number of attention layers (hybrid archs interleave)."""
        if self.hybrid is not None:
            return self.n_layers // self.hybrid.period
        return self.n_layers

    @property
    def subquadratic(self) -> bool:
        """True if the arch supports O(1)-state or mostly-recurrent decode,
        i.e. is eligible for the long_500k shape."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-flops accounting)."""
        d, hd = self.d_model, self.resolved_head_dim
        n_q, n_kv = self.n_heads, self.n_kv_heads
        attn = d * (n_q * hd) + 2 * d * (n_kv * hd) + (n_q * hd) * d
        if self.qkv_bias:
            attn += (n_q + 2 * n_kv) * hd
        dense_ffn = 3 * d * self.d_ff  # gate/up/down (SwiGLU)
        per_layer_norms = 2 * d
        total = 0
        if self.xlstm is not None:
            # mLSTM/sLSTM blocks: qkv + gates + proj, approximated exactly in
            # models/xlstm.py::count_params; here use the same formula.
            from repro.models import xlstm as _x
            return _x.count_params(self)
        for layer in range(self.n_layers):
            is_attn = True
            if self.hybrid is not None:
                is_attn = ((layer % self.hybrid.period)
                           == self.hybrid.attn_index)
            if is_attn:
                total += attn
            elif self.hybrid is not None:
                # mamba block params
                d_in = self.hybrid.expand * d
                total += (d * 2 * d_in                 # in_proj
                          + d_in * self.hybrid.d_conv  # conv
                          + d_in * (self.hybrid.d_state * 2 + 1)  # x_proj-ish
                          + d_in                        # dt
                          + d_in * self.hybrid.d_state  # A
                          + d_in * d)                   # out_proj
            if self.moe is not None and (layer % self.moe.moe_every) == 0:
                total += (self.moe.n_experts * 3 * d * self.moe.d_ff_expert
                          + d * self.moe.n_experts)
            elif self.d_ff > 0:
                total += dense_ffn
            total += per_layer_norms
        total += self.vocab * d           # embedding
        if not self.tie_embeddings:
            total += self.vocab * d       # lm head
        total += d                        # final norm
        if self.encoder_decoder:
            enc_attn = attn
            enc = self.n_encoder_layers * (
                enc_attn + dense_ffn + per_layer_norms)
            cross = self.n_layers * (attn + d)  # cross-attn per decoder layer
            total += enc + cross
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE uses top_k of n_experts)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        moe_layers = sum(1 for i in range(self.n_layers)
                         if (i % self.moe.moe_every) == 0)
        expert_p = 3 * self.d_model * self.moe.d_ff_expert
        inactive = (moe_layers * expert_p
                    * (self.moe.n_experts - self.moe.top_k))
        return int(full - inactive)

    def reduced(self) -> "ArchConfig":
        """Small same-family config for CPU smoke tests."""
        kw = dict(
            n_layers=min(self.n_layers, 2 if self.hybrid is None else
                         (self.hybrid.period if self.hybrid else 2)),
            d_model=64,
            n_heads=4,
            n_kv_heads=(min(self.n_kv_heads, 2)
                        if self.n_kv_heads < self.n_heads else 4),
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            head_dim=16,
            encoder_seq=8 if self.encoder_decoder else self.encoder_seq,
            vision_patches=(8 if self.frontend == "vision"
                            else self.vision_patches),
            n_encoder_layers=min(self.n_encoder_layers, 2),
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe, n_experts=4, top_k=min(self.moe.top_k, 2),
                d_ff_expert=32)
        if self.hybrid is not None:
            kw["hybrid"] = dataclasses.replace(
                self.hybrid, period=4, attn_index=2, d_state=8, expand=2)
            kw["n_layers"] = 4
        if self.xlstm is not None:
            kw["xlstm"] = dataclasses.replace(self.xlstm, slstm_at=(1,))
            kw["n_layers"] = 2
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # 'train' | 'prefill' | 'decode'

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


# The four assigned LM shapes (identical across archs; eligibility varies).
SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}


def shape_eligible(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a (arch, shape) cell is runnable; reason if not."""
    if shape.name == "long_500k" and not arch.subquadratic:
        return False, ("pure full-attention arch: 500k decode is quadratic-"
                       "KV-bound; skipped per brief (sub-quadratic only)")
    return True, ""
