"""Sharded, deterministic, prefetching data pipeline.

Design mirrors production input pipelines (tf.data/grain style) without the
dependency: a Source yields indexable records; the Loader owns a deterministic
shuffle (seeded per epoch), shards by (host, data-parallel rank), batches, and
prefetches on a background thread. Every batch is tagged with (epoch, step)
so a restarted job resumes mid-epoch from the checkpointed cursor — the
fault-tolerance contract (see runtime/fault.py).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterator, Optional, Sequence

import numpy as np


class ArraySource:
    """In-memory record source over parallel arrays (e.g. ids + targets)."""

    def __init__(self, **arrays: np.ndarray):
        lens = {len(v) for v in arrays.values()}
        assert len(lens) == 1, "all arrays must share leading dim"
        self.arrays = arrays
        self.n = lens.pop()

    def __len__(self):
        return self.n

    def gather(self, idx: np.ndarray) -> Dict[str, np.ndarray]:
        return {k: v[idx] for k, v in self.arrays.items()}


@dataclass
class LoaderState:
    epoch: int = 0
    step_in_epoch: int = 0

    def as_dict(self):
        return {"epoch": self.epoch, "step_in_epoch": self.step_in_epoch}


class Loader:
    """Deterministic sharded loader with background prefetch."""

    def __init__(self, source: ArraySource, batch_size: int, *,
                 seed: int = 0, shard_index: int = 0, num_shards: int = 1,
                 drop_remainder: bool = True, prefetch: int = 2,
                 state: Optional[LoaderState] = None):
        assert batch_size % num_shards == 0
        self.source = source
        self.global_batch = batch_size
        self.local_batch = batch_size // num_shards
        self.seed = seed
        self.shard_index = shard_index
        self.num_shards = num_shards
        self.prefetch = prefetch
        self.state = state or LoaderState()

    def _epoch_perm(self, epoch: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, epoch))
        return rng.permutation(len(self.source))

    def steps_per_epoch(self) -> int:
        return len(self.source) // self.global_batch

    def _make_batch(self, epoch: int, step: int) -> Dict[str, np.ndarray]:
        perm = self._epoch_perm(epoch)
        start = step * self.global_batch
        idx = perm[start:start + self.global_batch]
        local = idx[self.shard_index::self.num_shards]
        return self.source.gather(local)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        q: "queue.Queue" = queue.Queue(maxsize=self.prefetch)
        stop = threading.Event()

        def producer():
            epoch, step = self.state.epoch, self.state.step_in_epoch
            while not stop.is_set():
                if step >= self.steps_per_epoch():
                    epoch, step = epoch + 1, 0
                batch = self._make_batch(epoch, step)
                batch["_epoch"] = np.int64(epoch)
                batch["_step"] = np.int64(step)
                step += 1
                q.put(batch)

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                b = q.get()
                self.state.epoch = int(b.pop("_epoch"))
                self.state.step_in_epoch = int(b.pop("_step")) + 1
                yield b
        finally:
            stop.set()
            # drain so the producer can observe stop
            try:
                q.get_nowait()
            except queue.Empty:
                pass


def synthetic_lm_batches(vocab: int, batch: int, seq: int, *, seed: int = 0
                         ) -> Iterator[Dict[str, np.ndarray]]:
    """Synthetic token stream for the LM training drivers (structured enough
    to have learnable statistics: Zipfian unigram + local repeats)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = 1.0 / ranks
    probs /= probs.sum()
    while True:
        toks = rng.choice(vocab, size=(batch, seq + 1), p=probs)
        rep = rng.random((batch, seq + 1)) < 0.3   # local bigram structure
        toks[:, 1:] = np.where(rep[:, 1:], toks[:, :-1], toks[:, 1:])
        yield {"tokens": toks[:, :-1].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}
