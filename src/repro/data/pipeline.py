"""Sharded, deterministic, prefetching data pipeline.

Design mirrors production input pipelines (tf.data/grain style) without the
dependency: a Source yields indexable records; the Loader owns a deterministic
shuffle (seeded per epoch), shards by (host, data-parallel rank), batches, and
prefetches on a background thread. Every batch is tagged with (epoch, step)
so a restarted job resumes mid-epoch from the checkpointed cursor — the
fault-tolerance contract (see runtime/fault.py).

Bucket-aware batching: pass ``bucket_by`` (a per-row sequence-bucket length)
and each batch's ``ids`` are trimmed/padded to a bucket instead of the
global ``max_seq``, so a jitted train step compiles one program per bucket
(the same trick serving uses; see core/service.py). Two modes:

* ``bucket_mode="batch_max"`` (default) — the global shuffle is untouched
  (batch composition is **identical** to unbucketed loading) and each batch
  is padded to the smallest bucket covering its longest member. Because
  every model family's output is invariant to padding beyond its bucket
  (incl. the conv pad-slack rule), training is gradient-identical to
  max_seq padding — just faster.
* ``bucket_mode="homogeneous"`` — batches are drawn from rows of a single
  bucket (per-bucket shuffle -> fixed-size batches -> shuffled batch
  order). Maximum step-time win, but batches become length-correlated,
  which on length-correlated targets adds gradient noise; prefer
  ``batch_max`` when eval parity with padded training matters.

Either way the epoch plan is a pure function of (seed, epoch), so the
(epoch, step) cursor contract — and checkpoint/resume determinism — is
unchanged.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Callable, Dict, Iterator, List, Optional, Tuple

import numpy as np


class ArraySource:
    """In-memory record source over parallel arrays (e.g. ids + targets)."""

    def __init__(self, **arrays: np.ndarray):
        lens = {len(v) for v in arrays.values()}
        assert len(lens) == 1, "all arrays must share leading dim"
        self.arrays = arrays
        self.n = lens.pop()

    def __len__(self):
        return self.n

    def gather(self, idx: np.ndarray) -> Dict[str, np.ndarray]:
        return {k: v[idx] for k, v in self.arrays.items()}


class FnSource:
    """Record source over a gather function (e.g. bucket-grouped storage
    that materializes rows on demand); ``fn(idx) -> {key: array}``."""

    def __init__(self, n: int, fn: Callable[[np.ndarray],
                                            Dict[str, np.ndarray]]):
        self.n = n
        self.fn = fn

    def __len__(self):
        return self.n

    def gather(self, idx: np.ndarray) -> Dict[str, np.ndarray]:
        return self.fn(idx)


def fit_width(arr: np.ndarray, width: int) -> np.ndarray:
    """Trim or zero-pad (PAD id 0) the trailing dim to ``width``. The one
    place the pad convention for id rows lives (ir/dataset.py reuses it)."""
    if arr.shape[1] == width:
        return arr
    if arr.shape[1] > width:
        return np.ascontiguousarray(arr[:, :width])
    out = np.zeros((arr.shape[0], width), arr.dtype)
    out[:, :arr.shape[1]] = arr
    return out


@dataclass
class LoaderState:
    epoch: int = 0
    step_in_epoch: int = 0

    def as_dict(self):
        return {"epoch": self.epoch, "step_in_epoch": self.step_in_epoch}


class Loader:
    """Deterministic sharded loader with background prefetch.

    drop_remainder=False keeps each epoch's tail batch (per bucket, in
    bucketed mode), trimmed to a multiple of ``num_shards`` so every
    shard still sees the same local batch size within a step.
    """

    def __init__(self, source, batch_size: int, *,
                 seed: int = 0, shard_index: int = 0, num_shards: int = 1,
                 drop_remainder: bool = True, prefetch: int = 2,
                 bucket_by: Optional[np.ndarray] = None,
                 bucket_mode: str = "batch_max",
                 width_key: str = "ids",
                 state: Optional[LoaderState] = None):
        assert batch_size % num_shards == 0
        assert bucket_mode in ("batch_max", "homogeneous"), bucket_mode
        self.source = source
        self.global_batch = batch_size
        self.local_batch = batch_size // num_shards
        self.seed = seed
        self.shard_index = shard_index
        self.num_shards = num_shards
        self.drop_remainder = drop_remainder
        self.prefetch = prefetch
        self.bucket_by = None if bucket_by is None \
            else np.asarray(bucket_by)
        self.bucket_mode = bucket_mode
        self.width_key = width_key
        self.state = state or LoaderState()
        self._plan: Optional[Tuple[int, List]] = None   # (epoch, batches)
        if self.bucket_by is not None:
            assert len(self.bucket_by) == len(source), \
                "bucket_by must give one bucket length per source row"

    # ------------------------------------------------------------- planning
    def _chop(self, rows: np.ndarray, width: Optional[int], out: List):
        gb, ns = self.global_batch, self.num_shards
        n_full = len(rows) // gb
        for i in range(n_full):
            out.append((rows[i * gb:(i + 1) * gb], width))
        if not self.drop_remainder:
            tail = rows[n_full * gb:]
            tail = tail[:len(tail) - len(tail) % ns]
            if len(tail):
                out.append((tail, width))

    def _epoch_plan(self, epoch: int) -> List[Tuple[np.ndarray,
                                                    Optional[int]]]:
        """Batches of one epoch: a pure function of (seed, epoch)."""
        cached = self._plan   # single read: producer thread may swap it
        if cached is not None and cached[0] == epoch:
            return cached[1]
        rng = np.random.default_rng((self.seed, epoch))
        batches: List[Tuple[np.ndarray, Optional[int]]] = []
        if self.bucket_by is None:
            self._chop(rng.permutation(len(self.source)), None, batches)
        elif self.bucket_mode == "batch_max":
            # same permutation -> same batch composition as unbucketed;
            # only the pad width shrinks to the batch's largest bucket
            self._chop(rng.permutation(len(self.source)), None, batches)
            batches = [(idx, int(self.bucket_by[idx].max()))
                       for idx, _ in batches]
        else:
            # buckets too small for even one batch promote their rows to
            # the next bucket up (wider pad, but the rows stay trainable;
            # without this a small bucket would be excluded every epoch)
            carried = np.empty((0,), np.int64)
            ladder = np.unique(self.bucket_by)
            for j, b in enumerate(ladder):
                rows = np.concatenate(
                    [carried, np.flatnonzero(self.bucket_by == b)])
                if len(rows) < self.global_batch and j < len(ladder) - 1:
                    carried = rows
                    continue
                carried = np.empty((0,), np.int64)
                self._chop(rng.permutation(rows), int(b), batches)
            order = rng.permutation(len(batches))
            batches = [batches[i] for i in order]
        self._plan = (epoch, batches)
        return batches

    def steps_per_epoch(self) -> int:
        return len(self._epoch_plan(self.state.epoch))

    # ------------------------------------------------------------- batching
    def _make_batch(self, epoch: int, step: int) -> Dict[str, np.ndarray]:
        idx, width = self._epoch_plan(epoch)[step]
        local = idx[self.shard_index::self.num_shards]
        batch = self.source.gather(local)
        if width is not None and self.width_key in batch:
            # a bucket is always >= every member row's true length, so
            # trimming only ever removes padding
            batch[self.width_key] = fit_width(batch[self.width_key], width)
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        # validate eagerly on the consumer thread: an empty plan would
        # otherwise kill the producer and leave the consumer blocked forever
        if not self._epoch_plan(self.state.epoch):
            raise ValueError(
                f"empty epoch: no batch of {self.global_batch} rows can be "
                f"formed from {len(self.source)} source rows (lower "
                f"batch_size or pass drop_remainder=False)")
        return self._iterate()

    def _iterate(self) -> Iterator[Dict[str, np.ndarray]]:
        q: "queue.Queue" = queue.Queue(maxsize=self.prefetch)
        stop = threading.Event()

        def producer():
            epoch, step = self.state.epoch, self.state.step_in_epoch
            while not stop.is_set():
                if step >= len(self._epoch_plan(epoch)):
                    epoch, step = epoch + 1, 0
                batch = self._make_batch(epoch, step)
                batch["_epoch"] = np.int64(epoch)
                batch["_step"] = np.int64(step)
                step += 1
                q.put(batch)

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                b = q.get()
                self.state.epoch = int(b.pop("_epoch"))
                self.state.step_in_epoch = int(b.pop("_step")) + 1
                yield b
        finally:
            stop.set()
            # drain so the producer can observe stop
            try:
                q.get_nowait()
            except queue.Empty:
                pass


def synthetic_lm_batches(vocab: int, batch: int, seq: int, *, seed: int = 0
                         ) -> Iterator[Dict[str, np.ndarray]]:
    """Synthetic token stream for the LM training drivers (structured enough
    to have learnable statistics: Zipfian unigram + local repeats)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = 1.0 / ranks
    probs /= probs.sum()
    while True:
        toks = rng.choice(vocab, size=(batch, seq + 1), p=probs)
        rep = rng.random((batch, seq + 1)) < 0.3   # local bigram structure
        toks[:, 1:] = np.where(rep[:, 1:], toks[:, :-1], toks[:, 1:])
        yield {"tokens": toks[:, :-1].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}
