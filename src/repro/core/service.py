"""CostModelService — the deployed model, as the DL-compiler sees it.

The paper's end state: "Deploy the model which the DL-compiler can invoke
while compiling in order to make the best decisions." This module provides:

* batched, cached inference over MLIR graphs/text. One service predicts
  **all** trained targets (register pressure, vALU utilization, latency)
  from a single encoder forward pass when built from a multi-head model;
  single-head models keep working through the same API.
* sequence-length bucketing: each graph is padded to the smallest
  power-of-two bucket that fits it (not the global ``max_seq``), so short
  graphs stop paying full-length encoder cost. Every model family masks
  padding, so bucketed predictions equal unbucketed ones.
* a bounded LRU prediction cache (per-target vectors keyed by content
  hash) so a long-running compiler session can't grow memory without
  limit.
* an incremental featurization hot path (``fast_encode``, default): the
  LRU is probed by struct key BEFORE any tokenization, token-id arrays
  are cached by struct key, rewrite-derived graphs splice their ids
  from the parent's cached array (only the rewrite's dirty ops are
  re-lexed), and fresh batches encode through the vectorized
  ``Vocab.encode_many``. Phase timers (``phase_stats()``) attribute
  wall time to hash/encode/forward, and a ``truncations`` counter makes
  silent past-bucket drops observable.
* optional bf16 quantized serving (``dtype="bf16"``): params are cast
  once at construction, forward passes run bf16 over the same
  (bucket x ladder) program set (so ``warmup()`` covers them), and rows
  widen to float32 before the LRU so denormalization stays
  float32-exact. Drift vs f32 is gated in tests and the search_fleet
  benchmark (Spearman >= 0.99 per target).
* three compiler advisors built on top of it — since PR 4 each is a thin
  wrapper over a single-rule ``repro.opt`` search (the full multi-rule
  beam search lives in :mod:`repro.opt.search`):
  - FusionAdvisor:    greedy search over the elementwise-fusion rule
  - UnrollAdvisor:    one Unroll-rule expansion; pick the factor with the
                      best per-iteration predicted latency while register
                      pressure stays under budget (both targets from ONE
                      batched forward pass)
  - RecompileAdvisor: given new tensor shapes, reuse compiled code if the
                      predicted characteristic shift is below a threshold
                      (the paper's dynamic-runtime recompile decision).

The LRU is keyed by ``Graph.struct_key()`` — the same canonical
structural hash the opt search dedups its frontier with — so two
SSA-renumbered or re-scheduled spellings of one program share a cache
entry (and coalesce in flight at the server).
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core import models as CM
from repro.core import tokenizer as TOK
from repro.ir.graph import Graph


# Canonical bucket ladder lives in the dataset layer (training and serving
# share it); re-exported here for existing callers.
from repro.ir.dataset import default_buckets  # noqa: F401  (re-export)


def pad_slack(kind: str, cfg) -> int:
    """Extra pad positions a bucketed sequence needs so bucket-padded
    predictions exactly match max_seq-padded ones.

    Conv towers propagate boundary conditions inward by sum(fs//2)
    positions per side (the tower's right-edge "cone"). Keeping 2x that
    as pad slack leaves an interior run of constant pad activations
    between the last real token's cone and the bucket edge's cone, which
    makes bucketed outputs exactly match full-length padding. The other
    families mask padding position-wise, so 0 slack is enough."""
    if kind == "conv1d":
        return 2 * sum(fs // 2 for fs in cfg.conv_filters)
    return 0


@dataclass
class CostModelService:
    kind: str
    cfg: object
    params: object
    vocab: TOK.Vocab
    # single-head: {"mu", "sigma"}; multi-head: {target: {"mu", "sigma"}}
    norm_stats: Dict[str, Any]
    mode: str = "ops"
    max_seq: int = 256
    max_batch: int = 256
    # name of the single-head model's target (cosmetic for predict_all keys)
    target: Optional[str] = None
    cache_size: int = 4096
    # Serving precision: "f32" (exact) or "bf16" (params cast once at
    # construction; forward passes run bf16, rows are widened to float32
    # before the LRU and denormalize, so the denormalize path stays
    # float32-exact). Prediction drift vs f32 is gated in tests.
    dtype: str = "f32"
    # Hot-path featurization: token-id arrays cached by struct_key,
    # parent-delta tokenization for rewrite-derived graphs, vectorized
    # Vocab.encode_many for fresh batches, and LRU probes by key BEFORE
    # any tokenization. False restores the legacy always-re-lex path —
    # the flag-switchable baseline the search_fleet benchmark measures.
    fast_encode: bool = True
    ids_cache_size: int = 8192
    # Serve through the fused Pallas forward (kernels/ops.forward_apply;
    # interpret mode on CPU) instead of the plain-jnp apply. conv1d runs
    # the full ids-in/predictions-out kernel (gather + tower + FC +
    # heads in one pallas_call); lstm runs the VMEM-carry recurrence
    # kernel. Composes with dtype="bf16": kernels read bf16 params but
    # accumulate f32 in-kernel (drift vs f32 is Spearman-gated in tests
    # and kernel_bench). The kernels' accumulation order differs from
    # XLA's, so f32 parity is "allclose", not bit-identical.
    use_kernel: bool = False
    buckets: Optional[Tuple[int, ...]] = None   # None -> power-of-two ladder
    # batch sizes forward passes are padded up to (None -> power-of-two
    # ladder capped at max_batch). Fixing the set of executed (B, S)
    # shapes keeps the XLA program count finite — warmup() can pre-compile
    # all of them — and makes per-row results independent of how requests
    # were packed into batches (rows are data-parallel), so coalesced
    # server batches reproduce direct per-request predictions bit-for-bit.
    batch_ladder: Optional[Tuple[int, ...]] = None
    # content-hash -> (n_heads,) normalized prediction vector, LRU-ordered
    _cache: "OrderedDict[str, np.ndarray]" = field(
        default_factory=OrderedDict)
    _apply = None

    def __post_init__(self):
        # optional repro.obs.drift.DriftMonitor bound via drift.attach();
        # plain attribute so repro.core never imports the obs package
        self.drift = None
        _, apply_fn, _ = CM.get_model(self.kind)
        if self.dtype not in ("f32", "bf16"):
            raise ValueError(f"dtype must be f32 or bf16, got "
                             f"{self.dtype!r}")
        if self.use_kernel:
            from repro.kernels import ops as KOPS
            if self.kind not in KOPS.KERNEL_KINDS:
                raise ValueError(
                    f"use_kernel serves the fused Pallas forward for "
                    f"kinds {KOPS.KERNEL_KINDS}; kind={self.kind!r} has "
                    f"no kernel")
            kernel_kind = self.kind

            def apply_fn(params, ids):      # noqa: F811 — kernel forward
                return KOPS.forward_apply(kernel_kind, params, ids)
        # Bake small (fixed, inference-only) params into the jitted
        # callable as constants: per-call python then processes ONE ids
        # array instead of flattening the whole param tree, which is
        # most of a small model's dispatch latency on the serving hot
        # path (and all of it is per-request for a batch-of-one caller).
        # Constants are duplicated into every compiled (bucket x ladder)
        # program, so big param trees are committed to device once and
        # passed as an argument instead.
        params = self.params
        if self.dtype == "bf16":
            # cast floating leaves ONCE at construction; the (bucket x
            # ladder) program set stays identical in shape, so warmup()
            # covers the bf16 programs exactly as it does f32 ones
            import jax.numpy as jnp

            def _cast(x):
                a = jnp.asarray(x)
                return a.astype(jnp.bfloat16) \
                    if jnp.issubdtype(a.dtype, jnp.floating) else a
            params = jax.tree.map(_cast, params)
        n_bytes = sum(np.asarray(x).nbytes for x in jax.tree.leaves(params))
        if n_bytes <= 16 * 2**20:
            self._apply = jax.jit(lambda ids: apply_fn(params, ids))
        else:
            dev_params = jax.device_put(params)
            jitted = jax.jit(apply_fn)
            self._apply = lambda ids: jitted(dev_params, ids)
        self.heads = CM.model_heads(self.params) or (
            self.target or "prediction",)
        self._multi = CM.model_heads(self.params) is not None
        if self.buckets is None:
            self.buckets = default_buckets(self.max_seq)
        self.buckets = tuple(sorted(b for b in self.buckets
                                    if b <= self.max_seq)) or (self.max_seq,)
        self._pad_slack = pad_slack(self.kind, self.cfg)
        if self.batch_ladder is None:
            # powers of two plus midpoints (1,2,3,4,6,8,12,...): padding
            # waste stays under 33% at any coalesced-batch occupancy
            ladder = set()
            b = 1
            while b < self.max_batch:
                ladder.add(b)
                if b * 3 // 2 < self.max_batch:
                    ladder.add(b * 3 // 2)
                b *= 2
            ladder.add(self.max_batch)
            self.batch_ladder = tuple(sorted(ladder))
        self.batch_ladder = tuple(sorted(
            b for b in self.batch_ladder if b <= self.max_batch)) or (
            self.max_batch,)
        if self.batch_ladder[-1] < self.max_batch:
            # the ladder must cover max_batch: _forward pads UP to a
            # ladder entry, and chunks can be as large as max_batch
            self.batch_ladder += (self.max_batch,)
        # One lock guards the LRU dict and its hit/miss counters: the
        # CostModelServer worker and direct callers share this service
        # from multiple threads.
        self._cache_lock = threading.Lock()
        self.cache_hits = 0
        self.cache_misses = 0
        # token-id arrays keyed by struct_key: (bucket-padded ids, true
        # token count) — the featurization cache the parent-delta
        # tokenizer splices from. Guarded by _cache_lock.
        self._ids_cache: "OrderedDict[str, Tuple[np.ndarray, int]]" = \
            OrderedDict()
        self.ids_cache_hits = 0
        self.ids_cache_misses = 0
        self.delta_encodes = 0       # spliced from a parent's cached ids
        self.full_encodes = 0        # tokenized + encoded from scratch
        # sequences dropped past their bucket by Vocab.encode's silent
        # truncation — surfaced so bucketed-serving drops are observable
        self.truncations = 0
        # real-MLIR front door (ingest_text/predict_text): text count,
        # structured failures, and the running OOV token tally that
        # phase_stats() exposes as ``oov_rate`` — vocabulary drift on
        # live traffic is a served metric, not a silent degradation
        self.ingested_texts = 0
        self.ingest_errors = 0
        self.ingest_tokens = 0
        self.ingest_oov_tokens = 0
        # predictions served from the analyzer-oracle availability floor
        # instead of the model (replicated-tier degradation; see
        # repro.serving.router) — flagged so "the fleet was down and the
        # static cost model answered" is a counted, observable event
        self.degraded_preds = 0
        # wall-clock split of the serving hot path, for benchmark
        # attribution (tokenize/encode/hash vs forward)
        self._phase_s = {"hash_s": 0.0, "encode_s": 0.0, "forward_s": 0.0}
        # per-head (mu, sigma) as vectors: denormalizing all heads of a
        # row block is one vectorized expm1, not one call per target
        # float32 so block denorm rounds exactly like the per-target
        # scalar path (float32 rows * python-float stats -> float32)
        self._mu_vec = np.asarray(
            [self._stats_for(t)["mu"] for t in self.heads], np.float32)
        self._sigma_vec = np.asarray(
            [self._stats_for(t)["sigma"] for t in self.heads], np.float32)

    # ------------------------------------------------------------- encoding
    def _bucket_len(self, n_tokens: int) -> int:
        for b in self.buckets:
            if n_tokens + self._pad_slack <= b:
                return b
        return self.buckets[-1]

    def _phase_add(self, name: str, dt: float) -> None:
        with self._cache_lock:
            self._phase_s[name] += dt

    def phase_stats(self) -> Dict[str, float]:
        """Cumulative wall-clock split of the serving hot path: struct
        hashing vs tokenize/encode vs forward passes. Benchmarks emit
        this so perf PRs can attribute wins per phase. Also carries the
        front-door ingest counters — ``oov_rate`` is the running
        fraction of ingested-text tokens outside the vocabulary, the
        vocabulary-drift signal the server re-exports as
        ``phase_oov_rate`` in every metrics snapshot."""
        with self._cache_lock:
            out = dict(self._phase_s)
            out["truncations"] = self.truncations
            out["delta_encodes"] = self.delta_encodes
            out["full_encodes"] = self.full_encodes
            out["ingested_texts"] = self.ingested_texts
            out["ingest_errors"] = self.ingest_errors
            out["degraded_preds"] = self.degraded_preds
            out["oov_rate"] = (
                self.ingest_oov_tokens / self.ingest_tokens
                if self.ingest_tokens else 0.0)
        return out

    def key_of(self, g: Graph) -> str:
        """Canonical LRU/dedup key (Graph.struct_key, timed)."""
        t0 = time.perf_counter()
        key = g.struct_key()
        self._phase_add("hash_s", time.perf_counter() - t0)
        return key

    def _fresh_ids(self, g: Graph) -> Tuple[np.ndarray, int]:
        """Tokenize + encode from scratch -> (bucket-padded ids, n_tok)."""
        toks = TOK.graph_tokens(g, self.mode)
        bucket = self._bucket_len(len(toks))
        with self._cache_lock:
            self.full_encodes += 1
            if len(toks) > bucket:
                self.truncations += 1
        return self.vocab.encode(toks, bucket), len(toks)

    def _encode(self, g: Graph) -> np.ndarray:
        """Token ids padded to the graph's bucket, not the global max_seq."""
        t0 = time.perf_counter()
        ids, _ = self._fresh_ids(g)
        self._phase_add("encode_s", time.perf_counter() - t0)
        return ids

    def _delta_ids(self, g: Graph) -> Optional[Tuple[np.ndarray, int]]:
        """Splice a rewrite-derived graph's token ids from its parent's
        cached ids: copied op spans are gathered with one vectorized
        index, only the rewrite's dirty ops (plus the small output tail)
        are re-lexed. Returns None when no parent ids are cached, the
        mode is not "ops", or either side truncates (fresh encode then
        handles — and counts — the truncation)."""
        delta = g._tok_delta
        if delta is None or self.mode != "ops":
            return None
        parent_key, op_map = delta
        with self._cache_lock:
            ent = self._ids_cache.get(parent_key)
        if ent is None:
            return None
        p_ids, p_ntok = ent
        if p_ntok > len(p_ids):       # parent itself was truncated
            return None
        n_args, n_ops = g.n_args, len(g.ops)
        n_tok = 1 + n_args + 1 + 2 * n_ops + 1 + len(g.outputs) + 1
        bucket = self._bucket_len(n_tok)
        if n_tok > bucket:
            return None
        out = np.zeros((bucket,), np.int32)          # PAD id is 0
        base = n_args + 2                            # BOS + args + SEP
        out[:base] = p_ids[:base]
        if op_map:
            ci = np.fromiter(op_map.keys(), np.int64, len(op_map))
            pi = np.fromiter(op_map.values(), np.int64, len(op_map))
            dst, src = base + 2 * ci, base + 2 * pi
            out[dst] = p_ids[src]
            out[dst + 1] = p_ids[src + 1]
        t2i = self.vocab.token_to_id
        unk = t2i[TOK.UNK]
        for j, op in enumerate(g.ops):               # dirty ops only
            if j in op_map:
                continue
            out[base + 2 * j] = t2i.get(f"xpu.{op.opcode}", unk)
            out[base + 2 * j + 1] = t2i.get(
                g.values[op.result].shape_token(), unk)
        pos = base + 2 * n_ops
        out[pos] = t2i[TOK.SEP]
        for k, o in enumerate(g.outputs):
            out[pos + 1 + k] = t2i.get(g.values[o].shape_token(), unk)
        out[pos + 1 + len(g.outputs)] = t2i[TOK.EOS]
        with self._cache_lock:
            self.delta_encodes += 1
        return out, n_tok

    def _ids_cache_get(self, key: str) -> Optional[np.ndarray]:
        with self._cache_lock:
            ent = self._ids_cache.get(key)
            if ent is not None:
                self._ids_cache.move_to_end(key)
                self.ids_cache_hits += 1
                return ent[0]
            self.ids_cache_misses += 1
        return None

    def _ids_cache_put(self, key: str, ids: np.ndarray,
                       n_tok: int) -> None:
        with self._cache_lock:
            self._ids_cache[key] = (ids, n_tok)
            self._ids_cache.move_to_end(key)
            while len(self._ids_cache) > self.ids_cache_size:
                self._ids_cache.popitem(last=False)

    def ids_for(self, g: Graph, key: str) -> np.ndarray:
        """Bucket-padded token ids for one graph: ids-cache probe, then
        the parent-delta splice, then a from-scratch encode (legacy
        behavior — and the whole path when ``fast_encode=False``)."""
        if not self.fast_encode:
            return self._encode(g)
        ids = self._ids_cache_get(key)
        if ids is not None:
            return ids
        t0 = time.perf_counter()
        got = self._delta_ids(g)
        if got is None:
            got = self._fresh_ids(g)
        self._phase_add("encode_s", time.perf_counter() - t0)
        self._ids_cache_put(key, *got)
        return got[0]

    def entries_for(self, graphs: Sequence[Graph],
                    keys: Sequence[str]) -> List[Tuple[str, np.ndarray]]:
        """Batch ``(key, ids)`` entries: cached/delta graphs resolve
        individually; the remaining fresh ones are tokenized and pushed
        through ONE vectorized ``Vocab.encode_many`` per bucket."""
        t0 = time.perf_counter()
        out: List[Optional[np.ndarray]] = [None] * len(graphs)
        fresh: List[Tuple[int, str, List[str], int]] = []
        for i, (g, key) in enumerate(zip(graphs, keys)):
            ids = self._ids_cache_get(key)
            if ids is not None:
                out[i] = ids
                continue
            got = self._delta_ids(g)
            if got is not None:
                self._ids_cache_put(key, *got)
                out[i] = got[0]
                continue
            toks = TOK.graph_tokens(g, self.mode)
            bucket = self._bucket_len(len(toks))
            with self._cache_lock:
                self.full_encodes += 1
                if len(toks) > bucket:
                    self.truncations += 1
            fresh.append((i, key, toks, bucket))
        by_bucket: Dict[int, List[Tuple[int, str, List[str]]]] = {}
        for i, key, toks, bucket in fresh:
            by_bucket.setdefault(bucket, []).append((i, key, toks))
        for bucket, group in by_bucket.items():
            block = self.vocab.encode_many([t for _, _, t in group], bucket)
            for (i, key, toks), ids in zip(group, block):
                self._ids_cache_put(key, ids, len(toks))
                out[i] = ids
        self._phase_add("encode_s", time.perf_counter() - t0)
        return list(zip(keys, out))

    def entry(self, g: Graph) -> Tuple[str, np.ndarray]:
        """Batch entry for one graph: (struct key, bucket-padded ids).

        The canonical structural hash keys the LRU cache (invariant
        under SSA renumbering and re-scheduling, so a compiler re-query
        of a re-spelled program is a hit); ``len(ids)`` is the bucket,
        which a coalescing server uses to route the entry onto a queue
        of same-shape requests.

        Deliberate canonicalization trade: schedule-dependent targets
        (register pressure legitimately varies across topological
        re-schedules — see core/augment.py) are served at whichever
        spelling was costed first; the cache answers per dataflow
        graph, not per schedule. Callers that must distinguish
        schedules should query an empty-cache service or embed the
        schedule in the graph structure."""
        key = self.key_of(g)
        return key, self.ids_for(g, key)

    def _stats_for(self, t: str) -> Dict[str, float]:
        return self.norm_stats[t] if self._multi else self.norm_stats

    def denormalize_rows(self, raw: np.ndarray) -> Dict[str, np.ndarray]:
        """(N, n_heads) normalized rows -> {target: (N,) denormalized}.

        One vectorized expm1 over the whole block; numerically identical
        to per-target ``DS.denormalize`` (same ops, same dtype path)."""
        den = np.expm1(raw * self._sigma_vec + self._mu_vec)
        return {t: den[:, i] for i, t in enumerate(self.heads)}

    def normalize_rows(self, den: np.ndarray) -> np.ndarray:
        """(N, n_heads) denormalized values -> normalized rows; exact
        inverse of :meth:`denormalize_rows` (log1p z-score). Lets
        out-of-band predictions (the router's analyzer-oracle fallback)
        ride the same denormalize path as model rows."""
        den = np.asarray(den, np.float32)
        sigma = np.where(self._sigma_vec == 0.0, 1.0, self._sigma_vec)
        return ((np.log1p(den) - self._mu_vec) / sigma).astype(
            np.float32)

    def note_degraded(self, n: int) -> None:
        """Count ``n`` analyzer-fallback (degraded) predictions."""
        with self._cache_lock:
            self.degraded_preds += int(n)

    # ------------------------------------------------------------ inference
    def cache_lookup(self, h: str) -> Optional[np.ndarray]:
        """Thread-safe LRU probe; counts a hit or a miss."""
        with self._cache_lock:
            v = self._cache.get(h)
            if v is not None:
                self._cache.move_to_end(h)
                self.cache_hits += 1
            else:
                self.cache_misses += 1
        return v

    def _cache_put_many(
            self, items: Sequence[Tuple[str, np.ndarray]]) -> None:
        """Insert a whole flushed batch under one lock acquisition."""
        with self._cache_lock:
            for h, v in items:
                self._cache[h] = v
                self._cache.move_to_end(h)
            while len(self._cache) > self.cache_size:
                self._cache.popitem(last=False)

    def export_cache(self) -> List[Tuple[str, np.ndarray]]:
        """Snapshot the prediction LRU as ``(struct key, normalized
        (n_heads,) row)`` pairs in LRU order (oldest first, so importing
        into an empty service reproduces the eviction order). This is
        the replicated tier's cache handoff: a router pre-warms a fresh
        replica (or its own client cache) from any peer's export."""
        with self._cache_lock:
            return [(k, v.copy()) for k, v in self._cache.items()]

    def import_cache(self, items: Sequence[Tuple[str, np.ndarray]]) -> int:
        """Bulk-insert exported cache rows (newest-at-end, LRU bound
        enforced). Returns the number of entries inserted. Rows must be
        normalized (n_heads,) float32 vectors as produced by
        :meth:`export_cache` / the shared cross-replica tier."""
        items = [(k, np.asarray(v, np.float32)) for k, v in items]
        self._cache_put_many(items)
        return len(items)

    def cache_stats(self) -> Dict[str, float]:
        with self._cache_lock:
            hits, misses = self.cache_hits, self.cache_misses
            size = len(self._cache)
            ids_hits, ids_misses = self.ids_cache_hits, \
                self.ids_cache_misses
            ids_size = len(self._ids_cache)
            truncations = self.truncations
        total = hits + misses
        ids_total = ids_hits + ids_misses
        return {"hits": hits, "misses": misses, "size": size,
                "hit_rate": hits / total if total else 0.0,
                "ids_hits": ids_hits, "ids_misses": ids_misses,
                "ids_size": ids_size,
                "ids_hit_rate": ids_hits / ids_total if ids_total else 0.0,
                "truncations": truncations}

    def _ladder_batch(self, n: int) -> int:
        for b in self.batch_ladder:
            if n <= b:
                return b
        return self.batch_ladder[-1]

    def forward_dispatch(self, ids: np.ndarray) -> Tuple[Any, int]:
        """Enqueue one batched forward pass on the device WITHOUT waiting
        (JAX dispatch is async) and return an opaque handle for
        :meth:`forward_collect`. Pads the batch dim up to the ladder with
        all-PAD rows (sliced off at collect), so only |batch_ladder| x
        |buckets| programs ever compile."""
        t0 = time.perf_counter()
        n = ids.shape[0]
        nb = self._ladder_batch(n)
        if nb != n:
            ids = np.concatenate(
                [ids, np.zeros((nb - n, ids.shape[1]), ids.dtype)])
        handle = self._apply(ids), n
        self._phase_add("forward_s", time.perf_counter() - t0)
        return handle

    def forward_collect(self, handle: Tuple[Any, int]) -> np.ndarray:
        """Wait for a dispatched forward pass -> (B, n_heads) normalized
        predictions (padding rows removed). Rows are widened to float32
        (a no-op for f32 serving) so a bf16 service's LRU entries and
        denormalize path stay float32-exact."""
        t0 = time.perf_counter()
        out, n = handle
        if self._multi:
            out = jax.device_get(out)
            rows = np.stack([np.asarray(out[t], np.float32)
                             for t in self.heads], axis=1)
        else:
            rows = np.asarray(out, np.float32)[:, None]
        self._phase_add("forward_s", time.perf_counter() - t0)
        return rows[:n]

    def _forward(self, ids: np.ndarray) -> np.ndarray:
        """One synchronous batched forward -> (B, n_heads) rows."""
        return self.forward_collect(self.forward_dispatch(ids))

    def forward_entries(
            self, entries: Sequence[Tuple[str, np.ndarray]]) -> np.ndarray:
        """Forward a coalesced batch of same-bucket entries -> (N, n_heads)
        normalized rows, inserted into the LRU under each entry's hash.

        This is predict_all's compute kernel, split out so an async server
        can drive it with batches merged from many concurrent clients.
        Entries must share one ids length (one bucket); batches larger
        than max_batch are chunked."""
        hs = [h for h, _ in entries]
        ids = np.stack([i for _, i in entries])
        rows = []
        for i in range(0, len(ids), self.max_batch):
            preds = self._forward(ids[i:i + self.max_batch])
            self._cache_put_many(
                list(zip(hs[i:i + self.max_batch], preds)))
            rows.append(preds)
        return np.concatenate(rows)

    def forward_entries_dispatch(
            self, entries: Sequence[Tuple[str, np.ndarray]]):
        """Async variant of :meth:`forward_entries`: enqueue the forward
        pass and return a handle for :meth:`forward_entries_collect`.
        The batch must fit one forward pass (len(entries) <= max_batch);
        the cache is populated at collect time."""
        if len(entries) > self.max_batch:
            raise ValueError(
                f"async batch of {len(entries)} exceeds "
                f"max_batch={self.max_batch}")
        ids = np.stack([i for _, i in entries])
        return self.forward_dispatch(ids), [h for h, _ in entries]

    def forward_entries_collect(self, handle) -> np.ndarray:
        fwd, hs = handle
        preds = self.forward_collect(fwd)
        self._cache_put_many(list(zip(hs, preds)))
        return preds

    def predict_entries(
            self, entries: Sequence[Tuple[str, np.ndarray]]) -> np.ndarray:
        """Ids-first prediction: ``(struct key, bucket-padded ids)``
        entries -> (N, n_heads) normalized rows, LRU-probed by key first
        (hits skip the forward entirely), misses grouped per bucket and
        forwarded. The synchronous twin of the server's
        :meth:`~repro.core.server.CostModelServer.submit_entry` — the
        entry point a replica drives when the transport already carries
        token ids, so nothing is ever re-tokenized server-side."""
        rows: List[Optional[np.ndarray]] = [None] * len(entries)
        by_len: Dict[int, List[Tuple[int, str, np.ndarray]]] = {}
        pending: Dict[str, List[int]] = {}
        for i, (key, ids) in enumerate(entries):
            if key in pending:             # in-call duplicate
                pending[key].append(i)
                continue
            hit = self.cache_lookup(key)
            if hit is not None:
                rows[i] = hit
                continue
            pending[key] = [i]
            by_len.setdefault(len(ids), []).append((i, key, ids))
        for _, group in sorted(by_len.items()):
            preds = self.forward_entries([(k, ids) for _, k, ids in group])
            for (i, key, _), p in zip(group, preds):
                for j in pending[key]:
                    rows[j] = p
        return np.stack(rows)

    # ------------------------------------------------- real-MLIR front door
    def ingest_text(self, text):
        """Featurize raw MLIR text -> :class:`~repro.ir.frontdoor.
        TextEntry` or a structured :class:`~repro.ir.frontdoor.
        IngestError`; never raises on input.

        Structurally-parsed texts tokenize through the same
        ``graph_tokens`` path as Graph submits and are keyed by
        ``struct_key`` — an ingested program shares LRU entries with
        the identical program built through the Graph API. Unparsable
        (but lexable) texts degrade to the raw token stream under a
        content-hash key. Either way the ids are bucket-padded, so the
        entry drops straight into ``predict_entries`` /
        ``submit_entry`` / the replica wire format."""
        from repro.ir import frontdoor as FD
        res = FD.ingest(text)
        if isinstance(res, FD.IngestError):
            with self._cache_lock:
                self.ingest_errors += 1
            return res
        t0 = time.perf_counter()
        toks, key = res.tokens, res.key
        if res.graph is not None:
            try:
                toks = TOK.graph_tokens(res.graph, self.mode)
            except Exception:            # tolerate parser edge cases
                toks, key = res.tokens, FD.text_key(res.tokens)
        bucket = self._bucket_len(len(toks))
        ids = self.vocab.encode(toks, bucket)
        oov = self.vocab.oov_rate(toks)
        unk = self.vocab.unk_fraction(ids)
        with self._cache_lock:
            self.full_encodes += 1
            if len(toks) > bucket:
                self.truncations += 1
            self.ingested_texts += 1
            self.ingest_tokens += len(toks)
            self.ingest_oov_tokens += int(round(oov * len(toks)))
        self._phase_add("encode_s", time.perf_counter() - t0)
        if self.drift is not None:     # vocab-drift EWMAs + alarms
            self.drift.note_text(oov, unk)
        return FD.TextEntry(key=key, ids=ids, n_tokens=len(toks),
                            oov_rate=oov, unk_rate=unk,
                            dialects=res.dialects, n_ops=res.n_ops)

    def predict_text(self, text):
        """End-to-end text prediction: lowered MLIR in, denormalized
        predictions for every head out — or a structured IngestError
        (never an exception) when the input defeats ingestion.

        Runs the ids-first ``predict_entries`` path, so the prediction
        LRU, bucketing, and batch ladder behave exactly as for Graph
        queries."""
        from repro.ir import frontdoor as FD
        ent = self.ingest_text(text)
        if isinstance(ent, FD.IngestError):
            return ent
        try:
            raw = self.predict_entries([(ent.key, ent.ids)])
            preds = self.denormalize_rows(raw)
        except Exception as e:
            with self._cache_lock:
                self.ingest_errors += 1
            return FD.IngestError("predict", type(e).__name__,
                                  str(e)[:200])
        return FD.prediction_from(
            ent, {t: float(preds[t][0]) for t in self.heads})

    def warmup(self, batch_sizes: Optional[Sequence[int]] = None,
               buckets: Optional[Sequence[int]] = None) -> int:
        """AOT-compile every (bucket x ladder-batch) jitted program so no
        caller pays first-request XLA compile latency. Returns the number
        of programs warmed."""
        n = 0
        for s in (buckets if buckets is not None else self.buckets):
            for b in (batch_sizes if batch_sizes is not None
                      else self.batch_ladder):
                jax.block_until_ready(
                    self._apply(np.zeros((b, s), np.int32)))
                n += 1
        return n

    def predict_all(self, graphs: Sequence[Graph]) -> Dict[str, np.ndarray]:
        """All targets for every graph from one cached, batched, bucketed
        forward pass. Returns {target: (len(graphs),) denormalized array}.

        Fast path (``fast_encode``, default): the prediction LRU is
        probed by struct key FIRST — cache hits and in-call duplicates
        never tokenize at all — and the remaining misses featurize
        through the ids cache / parent-delta splice / batched
        ``encode_many``. The legacy path (``fast_encode=False``)
        tokenizes and encodes every graph before probing, exactly the
        pre-incremental behavior (the search_fleet baseline)."""
        if not graphs:
            return {t: np.zeros((0,), np.float32) for t in self.heads}
        keys: List[str] = []
        vals: Dict[str, np.ndarray] = {}   # this call's working set: the
        missing: Dict[str, np.ndarray] = {}  # LRU may evict entries mid-call
        if self.fast_encode:
            miss_graphs: Dict[str, Graph] = {}
            for g in graphs:
                h = self.key_of(g)
                keys.append(h)
                if h in vals or h in miss_graphs:
                    continue
                hit = self.cache_lookup(h)
                if hit is not None:
                    vals[h] = hit
                else:
                    miss_graphs[h] = g
            if miss_graphs:
                missing = dict(self.entries_for(
                    list(miss_graphs.values()), list(miss_graphs)))
        else:
            for g in graphs:
                h, ids = self.entry(g)
                keys.append(h)
                if h in vals or h in missing:
                    continue
                hit = self.cache_lookup(h)
                if hit is not None:
                    vals[h] = hit
                else:
                    missing[h] = ids
        if missing:
            # group by bucket length: one jitted program per bucket
            by_len: Dict[int, List[Tuple[str, np.ndarray]]] = {}
            for h, ids in missing.items():
                by_len.setdefault(len(ids), []).append((h, ids))
            for _, group in sorted(by_len.items()):
                preds = self.forward_entries(group)
                for (hh, _), p in zip(group, preds):
                    vals[hh] = p
        raw = np.stack([vals[k] for k in keys])  # (N, n_heads)
        out = self.denormalize_rows(raw)
        if self.drift is not None:     # accuracy sentinel (O(1) sampling)
            self.drift.observe_batch(graphs, out)
        return out

    def resolve_target(self, target: Optional[str]) -> str:
        """Map a requested target onto this service's heads.

        A single-head service answers ``target=None`` with its only head;
        it also answers a *mismatched* name only when its own target name
        is unknown (legacy unnamed construction) — a service that knows
        it predicts latency must not pass its output off as register
        pressure."""
        if target in self.heads:
            return target
        if len(self.heads) == 1 and (
                target is None
                or self._multi is False and self.target is None):
            return self.heads[0]
        if target is None:
            raise ValueError(
                f"multi-target service needs an explicit target; "
                f"one of {list(self.heads)}")
        raise KeyError(
            f"target {target!r} not served; heads={list(self.heads)}")

    def predict_graphs(self, graphs: Sequence[Graph],
                       target: Optional[str] = None) -> np.ndarray:
        """Batched prediction of one target (all targets are computed and
        cached regardless — asking for the others later is free)."""
        return self.predict_all(graphs)[self.resolve_target(target)]

    def predict(self, g: Graph, target: Optional[str] = None) -> float:
        return float(self.predict_graphs([g], target)[0])


# --------------------------------------------------------------- advisors
# The transforms themselves live in the repro.opt rewrite registry now;
# re-exported here for existing callers.
from repro.opt.rewrites import (  # noqa: E402  (re-export)
    FuseElementwise, Unroll, fuse_elementwise, unroll_graph)
from repro.opt import search as OPT  # noqa: E402


@dataclass
class FusionAdvisor:
    """One-rule wrapper over the opt search: greedily fuse elementwise
    chains while the model predicts an improvement."""
    service: CostModelService
    target: str = "latency_us"

    def advise(self, g: Graph) -> Tuple[bool, float, float]:
        obj = OPT.Objective(latency_target=self.target,
                            pressure_target=None)
        res = OPT.greedy_search(self.service, g,
                                rules=[FuseElementwise()], objective=obj)
        lat_t = self.service.resolve_target(self.target)
        return (res.improved, float(res.root_preds[lat_t]),
                float(res.best_preds[lat_t]))


@dataclass
class UnrollAdvisor:
    """Single-rule (Unroll) one-expansion search over ONE multi-target
    service: latency and register pressure for every factor come out of
    the same batched forward pass."""
    service: CostModelService
    register_budget: float = 64.0
    latency_target: str = "latency_us"
    pressure_target: str = "register_pressure"

    def advise(self, g: Graph, factors=(1, 2, 4, 8)) -> Dict:
        lat_t = self.service.resolve_target(self.latency_target)
        reg_t = self.service.resolve_target(self.pressure_target)
        if lat_t == reg_t:
            # a single-head service would silently judge register-budget
            # feasibility on latency numbers — refuse instead
            raise ValueError(
                f"UnrollAdvisor needs a service with distinct "
                f"{self.latency_target!r} and {self.pressure_target!r} "
                f"heads; got heads={list(self.service.heads)}")
        rule = Unroll(factors=tuple(factors), max_ops=None)
        obj = OPT.Objective(
            latency_target=self.latency_target,
            pressure_target=self.pressure_target,
            register_budget=self.register_budget).bind(self.service)
        sites = rule.applicable(g)
        cands = [rule.apply(g, s) for s in sites]
        # ONE batched predict_all for the whole factor sweep; scores are
        # per-iteration latency with the budget as a hard constraint
        scores, preds = OPT.cost_graphs(
            self.service, cands, obj, weights=[s.weight for s in sites])
        lat, reg = preds[lat_t], preds[reg_t]
        fs = [int(s.weight) for s in sites]
        best = fs[int(np.argmin(scores))] if np.isfinite(scores).any() \
            else 1
        return {"best_factor": int(best),
                "per_iter_latency": {f: float(lat[i] / f)
                                     for i, f in enumerate(fs)},
                "register_pressure": {f: float(reg[i])
                                      for i, f in enumerate(fs)}}


@dataclass
class RecompileAdvisor:
    """Dynamic-runtime decision: with operator shapes changed at runtime,
    is the already-compiled code still good enough, or is recompilation
    (expensive) worth it? Costing rides the search's batched path."""
    service: CostModelService
    threshold: float = 0.15   # recompile if predicted cost shifts > 15%
    target: str = "latency_us"

    def advise(self, compiled_graph: Graph, new_graph: Graph) -> Dict:
        obj = OPT.Objective(latency_target=self.target,
                            pressure_target=None).bind(self.service)
        _, preds = OPT.cost_graphs(
            self.service, [compiled_graph, new_graph], obj)
        c_old, c_new = preds[obj.lat_t]
        shift = abs(c_new - c_old) / max(abs(c_old), 1e-9)
        return {"recompile": bool(shift > self.threshold),
                "predicted_old": float(c_old),
                "predicted_new": float(c_new),
                "shift": float(shift)}
