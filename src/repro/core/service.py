"""CostModelService — the deployed model, as the DL-compiler sees it.

The paper's end state: "Deploy the model which the DL-compiler can invoke
while compiling in order to make the best decisions." This module provides:

* batched, cached inference over MLIR graphs/text;
* three compiler advisors built on top of it:
  - FusionAdvisor:    fuse A->B if predicted cost(fused) < cost(A)+cost(B)
  - UnrollAdvisor:    pick unroll factor in {1,2,4,8} minimizing predicted
                      latency while register pressure stays under budget
  - RecompileAdvisor: given new tensor shapes, reuse compiled code if the
                      predicted characteristic shift is below a threshold
                      (the paper's dynamic-runtime recompile decision).
"""
from __future__ import annotations

import copy
import hashlib
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import models as CM
from repro.core import tokenizer as TOK
from repro.ir import dataset as DS
from repro.ir.graph import Graph, Tensor


@dataclass
class CostModelService:
    kind: str
    cfg: object
    params: object
    vocab: TOK.Vocab
    norm_stats: Dict[str, float]
    mode: str = "ops"
    max_seq: int = 256
    max_batch: int = 256
    _cache: Dict[str, float] = field(default_factory=dict)
    _apply = None

    def __post_init__(self):
        _, apply_fn, _ = CM.get_model(self.kind)
        self._apply = jax.jit(apply_fn)

    # ------------------------------------------------------------- inference
    def _encode(self, g: Graph) -> np.ndarray:
        return self.vocab.encode(TOK.graph_tokens(g, self.mode), self.max_seq)

    def predict_graphs(self, graphs: Sequence[Graph]) -> np.ndarray:
        """Batched prediction with content-hash caching."""
        keys, missing, enc = [], [], []
        for g in graphs:
            ids = self._encode(g)
            h = hashlib.sha1(ids.tobytes()).hexdigest()
            keys.append(h)
            if h not in self._cache:
                missing.append(h)
                enc.append(ids)
        if enc:
            ids = np.stack(enc)
            preds = []
            for i in range(0, len(ids), self.max_batch):
                preds.append(np.asarray(
                    self._apply(self.params, jnp.asarray(ids[i:i + self.max_batch]))))
            for h, p in zip(missing, np.concatenate(preds)):
                self._cache[h] = float(p)
        raw = np.array([self._cache[k] for k in keys])
        return DS.denormalize(raw, self.norm_stats)

    def predict(self, g: Graph) -> float:
        return float(self.predict_graphs([g])[0])


# --------------------------------------------------------------- advisors
def fuse_elementwise(g: Graph) -> Graph:
    """Fuse producer->consumer elementwise chains into single 'xpu.fused'
    ops (a graph-level operator-fusion transform)."""
    from repro.ir.graph import ELEMENTWISE
    new = Graph(name=g.name + "_fused")
    new.values = list(g.values[:g.n_args])
    new.n_args = g.n_args
    id_map = {i: i for i in range(g.n_args)}
    uses: Dict[int, int] = {}
    for op in g.ops:
        for o in op.operands:
            uses[o] = uses.get(o, 0) + 1
    producer = {op.result: op for op in g.ops}
    fused_into: Dict[int, int] = {}
    for op in g.ops:
        if (op.opcode in ELEMENTWISE and len(op.operands) == 1
                and op.operands[0] in producer
                and producer[op.operands[0]].opcode in ELEMENTWISE
                and uses.get(op.operands[0], 0) == 1
                and op.operands[0] in fused_into):
            # extend the producer's fusion group
            fused_into[op.result] = fused_into[op.operands[0]]
            id_map[op.result] = id_map[op.operands[0]]
            new.values[id_map[op.result]] = g.values[op.result]
            continue
        nid = new.add_op(op.opcode, [id_map[o] for o in op.operands],
                         g.values[op.result], **op.attrs)
        id_map[op.result] = nid
        if op.opcode in ELEMENTWISE:
            fused_into[op.result] = nid
    new.outputs = [id_map[o] for o in g.outputs]
    new.validate()
    return new


@dataclass
class FusionAdvisor:
    service: CostModelService

    def advise(self, g: Graph) -> Tuple[bool, float, float]:
        fused = fuse_elementwise(g)
        c0, c1 = self.service.predict_graphs([g, fused])
        return bool(c1 < c0), float(c0), float(c1)


def unroll_graph(g: Graph, factor: int) -> Graph:
    """Model loop unrolling of the graph body: replicate ops with renamed
    SSA ids (shared args), as an unrolled inner loop would look to the
    cost model."""
    new = Graph(name=f"{g.name}_u{factor}")
    new.values = list(g.values[:g.n_args])
    new.n_args = g.n_args
    outs = []
    for rep in range(factor):
        id_map = {i: i for i in range(g.n_args)}
        for op in g.ops:
            nid = new.add_op(op.opcode, [id_map[o] for o in op.operands],
                             g.values[op.result], **op.attrs)
            id_map[op.result] = nid
        outs.extend(id_map[o] for o in g.outputs)
    new.outputs = outs
    new.validate()
    return new


@dataclass
class UnrollAdvisor:
    latency_service: CostModelService
    regpressure_service: CostModelService
    register_budget: float = 64.0

    def advise(self, g: Graph, factors=(1, 2, 4, 8)) -> Dict:
        cands = {f: unroll_graph(g, f) for f in factors}
        lat = self.latency_service.predict_graphs(list(cands.values()))
        reg = self.regpressure_service.predict_graphs(list(cands.values()))
        per_iter = {f: lat[i] / f for i, f in enumerate(cands)}
        feasible = [f for i, f in enumerate(cands)
                    if reg[i] <= self.register_budget]
        best = min(feasible or [1], key=lambda f: per_iter[f])
        return {"best_factor": int(best),
                "per_iter_latency": {f: float(v) for f, v in per_iter.items()},
                "register_pressure": {f: float(reg[i])
                                      for i, f in enumerate(cands)}}


@dataclass
class RecompileAdvisor:
    """Dynamic-runtime decision: with operator shapes changed at runtime,
    is the already-compiled code still good enough, or is recompilation
    (expensive) worth it?"""
    service: CostModelService
    threshold: float = 0.15   # recompile if predicted cost shifts > 15%

    def advise(self, compiled_graph: Graph, new_graph: Graph) -> Dict:
        c_old, c_new = self.service.predict_graphs(
            [compiled_graph, new_graph])
        shift = abs(c_new - c_old) / max(abs(c_old), 1e-9)
        return {"recompile": bool(shift > self.threshold),
                "predicted_old": float(c_old),
                "predicted_new": float(c_new),
                "shift": float(shift)}
