"""The paper's three regressor families, in pure JAX.

1. FC bag-of-tokens        — mean-pooled embeddings -> FC stack (worst RMSE).
2. LSTM                    — lax.scan LSTM over the sequence (middle).
3. Conv1D+MaxPool+FC       — 6 stacked Conv1D (filter sizes per config),
                             MaxPool1D, 3 FC layers (best RMSE; Figs 5/6).

All models share the embedding layer (dim 64 per the paper) and are split
into a shared ``encode(params, ids) -> features`` stage plus regression
heads. Two head layouts exist:

* **single-head** (legacy): the final linear layer predicts one scalar
  target; ``apply(params, ids)`` returns a ``(B,)`` array. This is the
  layout produced by ``*_init(key, cfg)`` with no ``heads`` argument and
  is kept so existing single-target callers keep working.
* **multi-head**: ``*_init(key, cfg, heads=("register_pressure", ...))``
  replaces the final layer with a dict of per-target linear heads over the
  shared features; ``apply(params, ids)`` returns
  ``{target: (B,) array}``. One encoder pass serves every target.

Params are plain dicts with matching ``*_axes`` (which accept the same
``heads`` knob) for the sharded 100M-scale driver.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import _init

# Canonical multi-target head set (every analyzer target, in analyzer order).
DEFAULT_HEADS: Tuple[str, ...] = (
    "register_pressure", "valu_utilization", "latency_us")


# --------------------------------------------------------------- embedding
def embed_init(key, cfg):
    return {"emb": _init(key, (cfg.vocab_size, cfg.embed_dim), scale=0.02)}


def _mask(ids):
    return (ids != 0).astype(jnp.float32)  # PAD id is 0


# ------------------------------------------------------------------- heads
def heads_init(key, feat_dim: int, heads: Sequence[str]) -> Dict[str, Any]:
    """One linear head per target over shared ``feat_dim`` features."""
    ks = jax.random.split(key, max(len(heads), 1))
    return {t: {"w": _init(k, (feat_dim, 1)), "b": jnp.zeros((1,))}
            for t, k in zip(heads, ks)}


def heads_axes(heads: Sequence[str]):
    return {t: {"w": (None, None), "b": (None,)} for t in heads}


def scalar_head(head_p: Dict[str, Any], feats):
    """The one {"w": (F, 1), "b": (1,)} linear-readout contract."""
    return (feats @ head_p["w"] + head_p["b"])[..., 0]


def apply_heads(heads_p: Dict[str, Any], feats) -> Dict[str, Any]:
    return {t: scalar_head(h, feats) for t, h in heads_p.items()}


def model_heads(params) -> Optional[Tuple[str, ...]]:
    """Head names of a multi-head param tree, or None for single-head."""
    if isinstance(params, dict) and "heads" in params:
        return tuple(params["heads"])
    return None


def _finish(p, feats, single_head_fn):
    """Dispatch features to the multi-head dict or the legacy scalar head."""
    if "heads" in p:
        return apply_heads(p["heads"], feats)
    return single_head_fn(feats)


def fc_stack(p, x):
    """Hidden FC layers of an fc/conv param tree -> shared features.

    Multi-head layout: every ``p["fc"]`` layer is hidden (relu'd).
    Single-head layout: the last layer is the scalar head, so it is
    excluded here and applied by :func:`fc_scalar_head`."""
    hidden = p["fc"] if "heads" in p else p["fc"][:-1]
    for layer in hidden:
        x = jax.nn.relu(x @ layer["w"] + layer["b"])
    return x


def fc_scalar_head(p, feats):
    return scalar_head(p["fc"][-1], feats)


def fc_finish(p, x):
    """Pooled features -> fc_stack -> head outputs, for either layout
    (shared by conv_apply and the fused-kernel tower in kernels/ops.py)."""
    return _finish(p, fc_stack(p, x), lambda f: fc_scalar_head(p, f))


# --------------------------------------------------------------- FC (BoT)
def fc_init(key, cfg, heads: Optional[Sequence[str]] = None) -> Dict[str, Any]:
    ks = jax.random.split(key, 5)
    p = {**embed_init(ks[0], cfg)}
    dims = [cfg.embed_dim, *cfg.fc_dims] + ([] if heads else [1])
    p["fc"] = [{"w": _init(ks[1 + i % 3], (dims[i], dims[i + 1])),
                "b": jnp.zeros((dims[i + 1],))}
               for i in range(len(dims) - 1)]
    if heads:
        p["heads"] = heads_init(ks[4], cfg.fc_dims[-1], heads)
    return p


def fc_axes(cfg, heads: Optional[Sequence[str]] = None):
    n_fc = len(cfg.fc_dims) + (0 if heads else 1)
    ax = {"emb": ("vocab", "embed"),
          "fc": [{"w": ("ffn", None) if i else ("embed", "ffn"),
                  "b": (None,)} for i in range(n_fc)]}
    if heads:
        ax["heads"] = heads_axes(heads)
    return ax


def fc_encode(p, ids):
    """Bag-of-tokens pooling + the hidden FC stack -> shared features."""
    m = _mask(ids).astype(p["emb"].dtype)
    x = p["emb"][ids] * m[..., None]
    x = x.sum(1) / jnp.maximum(m.sum(1, keepdims=True), 1.0)  # bag of tokens
    return fc_stack(p, x)


def fc_apply(p, ids):
    return _finish(p, fc_encode(p, ids), lambda f: fc_scalar_head(p, f))


# --------------------------------------------------------------- LSTM
def lstm_init(key, cfg,
              heads: Optional[Sequence[str]] = None) -> Dict[str, Any]:
    ks = jax.random.split(key, 5)
    h = cfg.lstm_hidden
    p = {**embed_init(ks[0], cfg),
         "wx": _init(ks[1], (cfg.embed_dim, 4 * h)),
         "wh": _init(ks[2], (h, 4 * h)),
         "b": jnp.zeros((4 * h,))}
    if heads:
        p["heads"] = heads_init(ks[3], h, heads)
    else:
        p["head"] = {"w": _init(ks[3], (h, 1)), "b": jnp.zeros((1,))}
    return p


def lstm_axes(cfg, heads: Optional[Sequence[str]] = None):
    ax = {"emb": ("vocab", "embed"), "wx": ("embed", "ffn"),
          "wh": (None, "ffn"), "b": (None,)}
    if heads:
        ax["heads"] = heads_axes(heads)
    else:
        ax["head"] = {"w": (None, None), "b": (None,)}
    return ax


def lstm_encode(p, ids):
    """Masked LSTM scan -> final hidden state as shared features.

    Mask and initial state follow the embedding dtype so bf16-cast
    params run a bf16 scan instead of silently promoting back to f32."""
    x = p["emb"][ids]                       # (B, S, E)
    m = _mask(ids).astype(x.dtype)
    B = x.shape[0]
    h_dim = p["wh"].shape[0]
    xw = x @ p["wx"] + p["b"]

    def step(carry, inp):
        h, c = carry
        xt, mt = inp
        gates = xt + h @ p["wh"]
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        i, f, o = jax.nn.sigmoid(i), jax.nn.sigmoid(f + 1.0), jax.nn.sigmoid(o)
        g = jnp.tanh(g)
        c_new = f * c + i * g
        h_new = o * jnp.tanh(c_new)
        keep = mt[:, None]
        return (h_new * keep + h * (1 - keep),
                c_new * keep + c * (1 - keep)), None

    h0 = jnp.zeros((B, h_dim), x.dtype)
    (h, _), _ = jax.lax.scan(step, (h0, h0),
                             (xw.transpose(1, 0, 2), m.T))
    return h


def lstm_apply(p, ids):
    return _finish(p, lstm_encode(p, ids),
                   lambda f: scalar_head(p["head"], f))


# ------------------------------------------------- Conv1D + MaxPool + FC
def conv_init(key, cfg,
              heads: Optional[Sequence[str]] = None) -> Dict[str, Any]:
    ks = jax.random.split(key, 2 + cfg.n_conv + 3)
    p = {**embed_init(ks[0], cfg), "convs": []}
    c_in = cfg.embed_dim
    for i, (fs, c_out) in enumerate(zip(cfg.conv_filters, cfg.conv_channels)):
        p["convs"].append({
            "w": _init(ks[1 + i], (fs, c_in, c_out),
                       scale=1.0 / np.sqrt(fs * c_in)),
            "b": jnp.zeros((c_out,))})
        c_in = c_out
    dims = [c_in, *cfg.fc_dims] + ([] if heads else [1])
    p["fc"] = [{"w": _init(ks[1 + cfg.n_conv + i], (dims[i], dims[i + 1])),
                "b": jnp.zeros((dims[i + 1],))}
               for i in range(len(dims) - 1)]
    if heads:
        p["heads"] = heads_init(ks[-1], cfg.fc_dims[-1], heads)
    return p


def conv_axes(cfg, heads: Optional[Sequence[str]] = None):
    n_fc = len(cfg.fc_dims) + (0 if heads else 1)
    ax = {"emb": ("vocab", "embed"),
          "convs": [{"w": (None, None, "ffn"), "b": ("ffn",)}
                    for _ in range(cfg.n_conv)],
          "fc": [{"w": ("ffn", None), "b": (None,)}
                 for _ in range(n_fc)]}
    if heads:
        ax["heads"] = heads_axes(heads)
    return ax


def conv1d(x, w, b):
    """'same'-padded 1D conv. x: (B, S, Cin); w: (fs, Cin, Cout)."""
    fs = w.shape[0]
    out = jax.lax.conv_general_dilated(
        x, w, window_strides=(1,),
        padding=[((fs - 1) // 2, fs // 2)],
        dimension_numbers=("NWC", "WIO", "NWC"))
    return out + b


def conv_encode(p, ids, *, pooled_only: bool = False):
    """Conv tower + MaxPool (+ hidden FC stack) -> shared features.

    ``pooled_only`` stops after the max-pool (the kernel module's seam).
    The mask follows the embedding dtype (lax.conv is strict about
    matching dtypes), so bf16-cast params run a bf16 tower."""
    x = p["emb"][ids] * _mask(ids).astype(p["emb"].dtype)[..., None]
    for layer in p["convs"]:
        x = jax.nn.relu(conv1d(x, layer["w"], layer["b"]))
    x = x.max(axis=1)                            # MaxPool1D over sequence
    return x if pooled_only else fc_stack(p, x)


def conv_apply(p, ids, *, pooled_feats: bool = False):
    pooled = conv_encode(p, ids, pooled_only=True)
    out = fc_finish(p, pooled)
    return (out, pooled) if pooled_feats else out


# ------------------------------------------------- Transformer (beyond-paper)
# The paper's §6 future work #1: "Use more powerful models like
# Transformers to better the currently achieved accuracy figures".
def xformer_init(key, cfg, n_layers=2, n_heads=4,
                 heads: Optional[Sequence[str]] = None) -> Dict[str, Any]:
    d = cfg.embed_dim
    ks = jax.random.split(key, 2 + 5 * n_layers)
    p = {**embed_init(ks[0], cfg),
         "pos": _init(ks[1], (cfg.max_seq, d), scale=0.02),
         "blocks": []}
    for i in range(n_layers):
        o = 2 + 5 * i
        p["blocks"].append({
            "wqkv": _init(ks[o], (d, 3 * d)),
            "wo": _init(ks[o + 1], (d, d)),
            "ln1": jnp.ones((d,)), "ln2": jnp.ones((d,)),
            "w1": _init(ks[o + 2], (d, 4 * d)),
            "w2": _init(ks[o + 3], (4 * d, d)),
        })
    if heads:
        p["heads"] = heads_init(ks[-1], d, heads)
    else:
        p["head"] = {"w": _init(ks[-1], (d, 1)), "b": jnp.zeros((1,))}
    return p


def xformer_axes(cfg, heads: Optional[Sequence[str]] = None):
    blk = {"wqkv": ("embed", "ffn"), "wo": (None, "embed"),
           "ln1": (None,), "ln2": (None,),
           "w1": ("embed", "ffn"), "w2": ("ffn", "embed")}
    ax = {"emb": ("vocab", "embed"), "pos": (None, "embed"),
          "blocks": [blk, blk]}
    if heads:
        ax["heads"] = heads_axes(heads)
    else:
        ax["head"] = {"w": (None, None), "b": (None,)}
    return ax


def _ln(x, g):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * g


def xformer_encode(p, ids):
    """Masked transformer stack -> mean-pooled features.

    Mask and attention bias follow the embedding dtype (bf16 still
    represents -1e30) so bf16-cast params stay bf16 end to end."""
    m = _mask(ids).astype(p["emb"].dtype)
    B, S = ids.shape
    d = p["emb"].shape[1]
    h = p["emb"][ids] + p["pos"][:S]
    H = 4  # fixed head count for the cost-model transformer
    dh = d // H
    # mask padded keys; cast keeps the bias in the embedding dtype
    # (bf16 represents -1e30) instead of promoting attention to f32
    neg = ((1.0 - m)[:, None, None, :] * -1e30).astype(m.dtype)
    for blk in p["blocks"]:
        x = _ln(h, blk["ln1"])
        qkv = x @ blk["wqkv"]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q = q.reshape(B, S, H, dh).transpose(0, 2, 1, 3)
        k = k.reshape(B, S, H, dh).transpose(0, 2, 1, 3)
        v = v.reshape(B, S, H, dh).transpose(0, 2, 1, 3)
        # python-float scale (weak-typed): an np.float64 scalar would
        # promote a bf16 tower back to f32 here
        a = jnp.einsum("bhqd,bhkd->bhqk", q, k) / float(np.sqrt(dh)) + neg
        w = jax.nn.softmax(a, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", w, v).transpose(0, 2, 1, 3)
        h = h + o.reshape(B, S, d) @ blk["wo"]
        x = _ln(h, blk["ln2"])
        h = h + jax.nn.gelu(x @ blk["w1"]) @ blk["w2"]
    return (h * m[..., None]).sum(1) / jnp.maximum(
        m.sum(1, keepdims=True), 1.0)


def xformer_apply(p, ids):
    return _finish(p, xformer_encode(p, ids),
                   lambda f: scalar_head(p["head"], f))


MODELS = {
    "fc": (fc_init, fc_apply, fc_axes),
    "lstm": (lstm_init, lstm_apply, lstm_axes),
    "conv1d": (conv_init, conv_apply, conv_axes),
    "xformer": (xformer_init, xformer_apply, xformer_axes),
}

ENCODERS = {
    "fc": fc_encode,
    "lstm": lstm_encode,
    "conv1d": conv_encode,
    "xformer": xformer_encode,
}


def get_model(kind: str):
    if kind not in MODELS:
        raise KeyError(f"unknown model {kind!r}; one of {sorted(MODELS)}")
    return MODELS[kind]


def get_encoder(kind: str):
    if kind not in ENCODERS:
        raise KeyError(f"unknown model {kind!r}; one of {sorted(ENCODERS)}")
    return ENCODERS[kind]
