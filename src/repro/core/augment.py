"""Dataset augmentation (paper §3: "we use augmentation to create a larger
training set").

Three semantic-aware transforms; targets are recomputed after each (register
pressure is schedule-dependent, so reordering legitimately changes it —
that's signal, not noise):

* rename_operands — permute SSA result numbering (alpha-renaming). Targets
  invariant; teaches the ops_operands model that %k names are symbolic.
* reorder_ops     — random topological re-schedule.
* jitter_shapes   — scale the graph's leading (batch) dimension by a factor
  from the frequent pool, propagating through all value types.
"""
from __future__ import annotations

import copy
from typing import List

import numpy as np

from repro.ir.graph import Graph, Tensor


def rename_operands(g: Graph, rng: np.random.Generator) -> Graph:
    """Permute the order in which independent ops appear, which permutes SSA
    numbering — equivalent to alpha-renaming %k tokens."""
    return reorder_ops(g, rng)


def reorder_ops(g: Graph, rng: np.random.Generator) -> Graph:
    """Sample a random topological order of the op DAG and renumber SSA."""
    n_ops = len(g.ops)
    deps = {i: set() for i in range(n_ops)}
    producer = {}
    for i, op in enumerate(g.ops):
        producer[op.result] = i
    for i, op in enumerate(g.ops):
        for o in op.operands:
            if o in producer:
                deps[i].add(producer[o])
    ready = [i for i in range(n_ops) if not deps[i]]
    remaining = {i: set(d) for i, d in deps.items()}
    order: List[int] = []
    while ready:
        pick = int(rng.choice(len(ready)))
        cur = ready.pop(pick)
        order.append(cur)
        for j in range(n_ops):
            if cur in remaining.get(j, ()):
                remaining[j].discard(cur)
                if not remaining[j] and j not in order and j not in ready:
                    ready.append(j)
    assert len(order) == n_ops
    # rebuild with new numbering
    new = Graph(name=g.name)
    new.values = [g.values[i] for i in range(g.n_args)]
    new.n_args = g.n_args
    id_map = {i: i for i in range(g.n_args)}
    for old_i in order:
        op = g.ops[old_i]
        new_id = new.add_op(op.opcode,
                            [id_map[o] for o in op.operands],
                            g.values[op.result], **op.attrs)
        id_map[op.result] = new_id
    new.outputs = [id_map[o] for o in g.outputs]
    new.validate()
    return new


def jitter_shapes(g: Graph, rng: np.random.Generator) -> Graph:
    """Scale the batch (leading) dim of every >=3d tensor by 0.5x/2x."""
    factor = float(rng.choice([0.5, 2.0]))
    new = copy.deepcopy(g)

    def scale(t: Tensor) -> Tensor:
        if len(t.shape) < 3:
            return t
        b = max(int(t.shape[0] * factor), 1)
        return Tensor((b,) + t.shape[1:], t.dtype)

    new.values = [scale(t) for t in new.values]
    return new


AUGMENTS = [rename_operands, reorder_ops, jitter_shapes]


def augment(g: Graph, rng: np.random.Generator) -> Graph:
    fn = AUGMENTS[int(rng.integers(len(AUGMENTS)))]
    return fn(g, rng)
