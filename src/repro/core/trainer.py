"""Supervised trainer for the cost models (paper §3-4).

Small configs train single-device; the 100M driver trains data-parallel
under a mesh with optional int8 error-feedback gradient compression
(:mod:`repro.optim.compress`). Metrics match the paper: relative RMSE
("5-7% range") and %-exact for register pressure (Fig. 6: ~75% exact).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import models as CM
from repro.ir import dataset as DS
from repro.optim import adamw


@dataclass
class TrainResult:
    params: Any
    stats: Dict[str, float]
    history: list = field(default_factory=list)
    norm_stats: Dict[str, float] = field(default_factory=dict)


def _batches(rng, n, batch_size):
    perm = rng.permutation(n)
    for i in range(0, n - batch_size + 1, batch_size):
        yield perm[i:i + batch_size]


def make_sgd_step(apply_fn, opt_cfg, grad_transform=None):
    def loss_fn(params, ids, y):
        pred = apply_fn(params, ids)
        return jnp.mean(jnp.square(pred - y))

    def step(params, opt_state, ids, y):
        loss, grads = jax.value_and_grad(loss_fn)(params, ids, y)
        if grad_transform is not None:
            grads = grad_transform(grads)
        params, opt_state, m = adamw.apply_updates(params, grads, opt_state,
                                                   opt_cfg)
        return params, opt_state, loss
    return step


def train_model(kind: str, cfg, train: DS.CostDataset, target: str,
                *, steps: int = 300, batch_size: int = 64,
                lr: float = 1e-3, seed: int = 0,
                jit_step=None, log_every: int = 100,
                verbose: bool = False) -> TrainResult:
    init_fn, apply_fn, _ = CM.get_model(kind)
    key = jax.random.PRNGKey(seed)
    params = init_fn(key, cfg)
    y_raw = train.targets[target]
    y, norm_stats = DS.normalize_targets(y_raw)
    opt_cfg = adamw.AdamWConfig(lr=lr, warmup_steps=min(50, steps // 10),
                                total_steps=steps, weight_decay=0.01)
    step_fn = jit_step or jax.jit(make_sgd_step(apply_fn, opt_cfg))
    opt_state = adamw.init_state(params)
    rng = np.random.default_rng(seed)
    history = []
    it = 0
    t0 = time.time()
    while it < steps:
        for idx in _batches(rng, len(train.ids), batch_size):
            ids = jnp.asarray(train.ids[idx])
            yb = jnp.asarray(y[idx])
            params, opt_state, loss = step_fn(params, opt_state, ids, yb)
            it += 1
            if it % log_every == 0:
                history.append((it, float(loss)))
                if verbose:
                    print(f"  step {it}: mse={float(loss):.4f} "
                          f"({(time.time()-t0):.1f}s)")
            if it >= steps:
                break
    return TrainResult(params=params, stats={}, history=history,
                       norm_stats=norm_stats)


def evaluate(kind: str, cfg, result: TrainResult, test: DS.CostDataset,
             target: str, batch_size: int = 256) -> Dict[str, float]:
    """Paper metrics: relative RMSE (%), normalized RMSE, %-exact (rounded)."""
    _, apply_fn, _ = CM.get_model(kind)
    apply_j = jax.jit(apply_fn)
    preds = []
    for i in range(0, len(test.ids), batch_size):
        ids = jnp.asarray(test.ids[i:i + batch_size])
        preds.append(np.asarray(apply_j(result.params, ids)))
    pred_n = np.concatenate(preds)
    pred = DS.denormalize(pred_n, result.norm_stats)
    true = test.targets[target]
    rel = (pred - true) / np.maximum(np.abs(true), 1e-6)
    # normalized-space RMSE against the train normalization
    true_n = (np.log1p(true) - result.norm_stats["mu"]) / \
        result.norm_stats["sigma"]
    return {
        "rmse_rel_pct": float(np.sqrt(np.mean(np.square(rel))) * 100),
        "mape_pct": float(np.mean(np.abs(rel)) * 100),
        "rmse_norm": float(np.sqrt(np.mean(np.square(pred_n - true_n)))),
        "exact_pct": float(np.mean(np.round(pred) == np.round(true)) * 100),
        "within5_pct": float(np.mean(np.abs(rel) <= 0.05) * 100),
    }
