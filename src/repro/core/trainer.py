"""Supervised trainer for the cost models (paper §3-4).

Small configs train single-device; the 100M driver trains data-parallel
under a mesh with optional int8 error-feedback gradient compression
(:mod:`repro.optim.compress`). Metrics match the paper: relative RMSE
("5-7% range") and %-exact for register pressure (Fig. 6: ~75% exact).

``target`` may be a single name (legacy scalar head) or a sequence of
names, which trains one shared encoder with a per-target head dict under
a joint MSE (mean of per-target MSEs in normalized space). Multi-target
results carry per-target ``norm_stats`` and ``evaluate`` reports metrics
per target.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import models as CM
from repro.ir import dataset as DS
from repro.optim import adamw

TargetSpec = Union[str, Sequence[str]]


@dataclass
class TrainResult:
    params: Any
    stats: Dict[str, float]
    history: list = field(default_factory=list)
    # single-target: {"mu": ..., "sigma": ...}; multi-target: {target: {...}}
    norm_stats: Dict[str, Any] = field(default_factory=dict)
    heads: Optional[Tuple[str, ...]] = None


def _batches(rng, n, batch_size):
    perm = rng.permutation(n)
    for i in range(0, n - batch_size + 1, batch_size):
        yield perm[i:i + batch_size]


def make_loss_fn(apply_fn, heads: Optional[Tuple[str, ...]] = None):
    """MSE loss. With ``heads``, ``y`` is (B, n_heads) column-per-target
    and the loss is the mean of per-target MSEs (joint training)."""
    def loss_fn(params, ids, y):
        pred = apply_fn(params, ids)
        if heads:
            per = [jnp.mean(jnp.square(pred[t] - y[:, i]))
                   for i, t in enumerate(heads)]
            return jnp.mean(jnp.stack(per))
        return jnp.mean(jnp.square(pred - y))
    return loss_fn


def make_sgd_step(apply_fn, opt_cfg, grad_transform=None,
                  heads: Optional[Tuple[str, ...]] = None):
    loss_fn = make_loss_fn(apply_fn, heads)

    def step(params, opt_state, ids, y):
        loss, grads = jax.value_and_grad(loss_fn)(params, ids, y)
        if grad_transform is not None:
            grads = grad_transform(grads)
        params, opt_state, m = adamw.apply_updates(params, grads, opt_state,
                                                   opt_cfg)
        return params, opt_state, loss
    return step


def train_model(kind: str, cfg, train: DS.CostDataset, target: TargetSpec,
                *, steps: int = 300, batch_size: int = 64,
                lr: float = 1e-3, seed: int = 0,
                jit_step=None, log_every: int = 100,
                verbose: bool = False) -> TrainResult:
    heads = None if isinstance(target, str) else tuple(target)
    init_fn, apply_fn, _ = CM.get_model(kind)
    key = jax.random.PRNGKey(seed)
    if heads:
        params = init_fn(key, cfg, heads=heads)
        y, norm_stats = DS.stacked_normalized_targets(train.targets, heads)
    else:
        params = init_fn(key, cfg)
        y, norm_stats = DS.normalize_targets(train.targets[target])
    opt_cfg = adamw.AdamWConfig(lr=lr, warmup_steps=min(50, steps // 10),
                                total_steps=steps, weight_decay=0.01)
    step_fn = jit_step or jax.jit(make_sgd_step(apply_fn, opt_cfg,
                                                heads=heads))
    opt_state = adamw.init_state(params)
    rng = np.random.default_rng(seed)
    history = []
    it = 0
    t0 = time.time()
    while it < steps:
        for idx in _batches(rng, len(train.ids), batch_size):
            ids = jnp.asarray(train.ids[idx])
            yb = jnp.asarray(y[idx])
            params, opt_state, loss = step_fn(params, opt_state, ids, yb)
            it += 1
            if it % log_every == 0:
                history.append((it, float(loss)))
                if verbose:
                    print(f"  step {it}: mse={float(loss):.4f} "
                          f"({(time.time()-t0):.1f}s)")
            if it >= steps:
                break
    return TrainResult(params=params, stats={}, history=history,
                       norm_stats=norm_stats, heads=heads)


def _target_metrics(pred_n: np.ndarray, true: np.ndarray,
                    stats: Dict[str, float]) -> Dict[str, float]:
    """Paper metrics: relative RMSE (%), normalized RMSE, %-exact (rounded)."""
    pred = DS.denormalize(pred_n, stats)
    rel = (pred - true) / np.maximum(np.abs(true), 1e-6)
    # normalized-space RMSE against the train normalization
    true_n = (np.log1p(true) - stats["mu"]) / stats["sigma"]
    return {
        "rmse_rel_pct": float(np.sqrt(np.mean(np.square(rel))) * 100),
        "mape_pct": float(np.mean(np.abs(rel)) * 100),
        "rmse_norm": float(np.sqrt(np.mean(np.square(pred_n - true_n)))),
        "exact_pct": float(np.mean(np.round(pred) == np.round(true)) * 100),
        "within5_pct": float(np.mean(np.abs(rel) <= 0.05) * 100),
    }


def evaluate(kind: str, cfg, result: TrainResult, test: DS.CostDataset,
             target: Optional[TargetSpec] = None, batch_size: int = 256
             ) -> Dict[str, Any]:
    """Evaluate a TrainResult.

    Single-head result + target name -> flat metrics dict (legacy).
    Multi-head result -> {target: metrics} for every requested target
    (default: all heads); passing a single name returns that head's flat
    metrics dict.
    """
    _, apply_fn, _ = CM.get_model(kind)
    apply_j = jax.jit(apply_fn)
    preds = []
    for i in range(0, len(test.ids), batch_size):
        ids = jnp.asarray(test.ids[i:i + batch_size])
        preds.append(jax.device_get(apply_j(result.params, ids)))
    if result.heads:
        pred_n = {t: np.concatenate([np.asarray(p[t]) for p in preds])
                  for t in result.heads}
        if isinstance(target, str):
            return _target_metrics(pred_n[target], test.targets[target],
                                   result.norm_stats[target])
        wanted = tuple(target) if target is not None else result.heads
        return {t: _target_metrics(pred_n[t], test.targets[t],
                                   result.norm_stats[t])
                for t in wanted}
    if not isinstance(target, str):
        raise ValueError("single-head evaluate needs a target name")
    pred_n = np.concatenate([np.asarray(p) for p in preds])
    return _target_metrics(pred_n, test.targets[target], result.norm_stats)
