"""Supervised training for the cost models (paper §3-4) — one engine.

:class:`TrainEngine` owns the repo's ONE training step loop. Every caller
— the quickstart example, the serve/benchmark drivers, the production
``launch/train.py`` CLI, and the :func:`train_model` compatibility wrapper
— builds an engine and calls :meth:`TrainEngine.fit`. The engine wires the
full substrate every time:

* a sharded, prefetching :class:`repro.data.pipeline.Loader` (deterministic,
  resumable cursor), **bucket-aware** by default: batches are grouped by
  power-of-two sequence bucket (the same ladder serving uses, including the
  conv1d pad-slack rule), so each train step jits one program per bucket
  instead of padding every batch to the global ``max_seq``;
* mesh + :class:`~repro.runtime.sharding.ShardingRules` (params are placed
  by the per-family logical axis tables when the mesh has >1 device);
* optional int8 error-feedback gradient compression on the DP axis;
* a :class:`~repro.runtime.fault.TrainSupervisor` step loop: periodic +
  on-preemption atomic checkpoints carrying the loader cursor, and
  automatic resume — or, with ``ckpt_dir=None``, the same loop with
  persistence disabled.

Metrics match the paper: relative RMSE ("5-7% range") and %-exact for
register pressure (Fig. 6: ~75% exact).

``target`` may be a single name (legacy scalar head) or a sequence of
names, which trains one shared encoder with a per-target head dict under
a joint MSE (mean of per-target MSEs in normalized space). Multi-target
results carry per-target ``norm_stats`` and ``evaluate`` reports metrics
per target.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import models as CM
from repro.data import pipeline as PIPE
from repro.ir import dataset as DS
from repro.optim import adamw, compress
from repro.runtime import fault
from repro.runtime.sharding import ShardingRules, tree_shardings

TargetSpec = Union[str, Sequence[str]]


@dataclass
class TrainResult:
    params: Any
    stats: Dict[str, float]
    history: list = field(default_factory=list)
    # single-target: {"mu": ..., "sigma": ...}; multi-target: {target: {...}}
    norm_stats: Dict[str, Any] = field(default_factory=dict)
    heads: Optional[Tuple[str, ...]] = None


def make_loss_fn(apply_fn, heads: Optional[Tuple[str, ...]] = None):
    """MSE loss. With ``heads``, ``y`` is (B, n_heads) column-per-target
    and the loss is the mean of per-target MSEs (joint training)."""
    def loss_fn(params, ids, y):
        pred = apply_fn(params, ids)
        if heads:
            per = [jnp.mean(jnp.square(pred[t] - y[:, i]))
                   for i, t in enumerate(heads)]
            return jnp.mean(jnp.stack(per))
        return jnp.mean(jnp.square(pred - y))
    return loss_fn


def make_sgd_step(apply_fn, opt_cfg, grad_transform=None,
                  heads: Optional[Tuple[str, ...]] = None):
    """Single-step builder for custom/external loops (notebooks, tests).

    The TrainEngine composes the same pieces itself because its step also
    threads the compression error state; this stays the minimal public
    building block."""
    loss_fn = make_loss_fn(apply_fn, heads)

    def step(params, opt_state, ids, y):
        loss, grads = jax.value_and_grad(loss_fn)(params, ids, y)
        if grad_transform is not None:
            grads = grad_transform(grads)
        params, opt_state, _ = adamw.apply_updates(params, grads, opt_state,
                                                   opt_cfg)
        return params, opt_state, loss
    return step


@dataclass(frozen=True)
class EngineConfig:
    """Every knob of the unified step loop (CLI flags map 1:1 onto this)."""
    steps: int = 300
    batch_size: int = 64
    lr: float = 1e-3
    weight_decay: float = 0.01
    seed: int = 0
    log_every: int = 100
    verbose: bool = False
    # batching: per-bucket pad widths (one jitted program per bucket).
    # "batch_max" keeps the global shuffle and is gradient-identical to
    # max_seq padding; "homogeneous" maximizes the step-time win but
    # length-correlates batch composition (see data/pipeline.py).
    bucketed: bool = True
    bucket_mode: str = "batch_max"
    min_bucket: int = 32
    drop_remainder: bool = True
    # mesh / sharding
    mesh_data: int = 1
    mesh_model: int = 1
    # substrate
    compress_grads: bool = False
    ckpt_dir: Optional[str] = None     # None -> loop without persistence
    save_every: int = 100
    keep: int = 3
    check_treedef: bool = True
    install_sigterm: bool = False   # checkpoint + stop on SIGTERM
    shard_index: int = 0
    num_shards: int = 1
    prefetch: int = 2


class TrainEngine:
    """The one way to train a cost model (see module docstring).

    >>> engine = TrainEngine("conv1d", cfg, ("latency_us",), steps=500)
    >>> result = engine.fit(train_ds)
    """

    def __init__(self, kind: str, cfg, target: TargetSpec,
                 engine: Optional[EngineConfig] = None, **overrides):
        self.kind = kind
        self.cfg = cfg
        self.heads = None if isinstance(target, str) else tuple(target)
        self.target = target
        self.ecfg = dataclasses.replace(engine or EngineConfig(),
                                        **overrides)
        self.init_fn, self.apply_fn, self.axes_fn = CM.get_model(kind)

    # ------------------------------------------------------------- pipeline
    def bucket_assignments(self, train: DS.CostDataset
                           ) -> Optional[np.ndarray]:
        """Per-row train bucket length, honoring the serving-side pad-slack
        rule (conv1d needs slack so bucketing is prediction-preserving)."""
        if not self.ecfg.bucketed:
            return None
        from repro.core.service import pad_slack
        # ladder from the DATASET width (the unbucketed path feeds ids at
        # dataset width too); a model whose capacity is narrower than the
        # data (e.g. an xformer pos table) fails loudly either way
        buckets = DS.default_buckets(train.max_seq, self.ecfg.min_bucket)
        return DS.bucket_lengths(train.get_seq_lens(), buckets,
                                 pad_slack(self.kind, self.cfg))

    def make_loader(self, train: DS.CostDataset, y: np.ndarray
                    ) -> PIPE.Loader:
        e = self.ecfg
        if train.ids is not None:
            src = PIPE.ArraySource(ids=train.ids, y=y)
        else:
            # bucket-grouped storage: materialize rows on demand at the
            # widest width a batch could need; the Loader trims per bucket
            width = max(train.bucket_ids) if e.bucketed else train.max_seq
            src = PIPE.FnSource(train.n, lambda idx: {
                "ids": train.row_ids(idx, width), "y": y[idx]})
        return PIPE.Loader(src, e.batch_size, seed=e.seed,
                           shard_index=e.shard_index,
                           num_shards=e.num_shards,
                           drop_remainder=e.drop_remainder,
                           prefetch=e.prefetch,
                           bucket_by=self.bucket_assignments(train),
                           bucket_mode=e.bucket_mode)

    # ------------------------------------------------------------------ fit
    def fit(self, train: DS.CostDataset, *,
            on_step: Optional[Callable] = None) -> TrainResult:
        e = self.ecfg
        key = jax.random.PRNGKey(e.seed)
        if self.heads:
            params = self.init_fn(key, self.cfg, heads=self.heads)
            y, norm_stats = DS.stacked_normalized_targets(train.targets,
                                                          self.heads)
        else:
            params = self.init_fn(key, self.cfg)
            y, norm_stats = DS.normalize_targets(train.targets[self.target])
            y = y.astype(np.float32)
        loader = self.make_loader(train, y)

        mesh = jax.make_mesh((e.mesh_data, e.mesh_model), ("data", "model"))
        if mesh.devices.size > 1:
            rules = ShardingRules(mesh)
            axes = self.axes_fn(self.cfg, heads=self.heads) if self.heads \
                else self.axes_fn(self.cfg)
            shapes = jax.tree.map(lambda x: x.shape, params)
            params = jax.tree.map(jax.device_put, params,
                                  tree_shardings(rules, axes, shapes))

        opt_cfg = adamw.AdamWConfig(lr=e.lr, total_steps=e.steps,
                                    warmup_steps=min(50, e.steps // 10),
                                    weight_decay=e.weight_decay)
        err0 = compress.init_error_state(params) if e.compress_grads \
            else None
        loss_fn = make_loss_fn(self.apply_fn, self.heads)

        @jax.jit
        def train_step(state, ids, yy):
            params, opt_state, err = state
            loss, grads = jax.value_and_grad(loss_fn)(params, ids, yy)
            if err is not None:
                grads, err = compress.compress_grads(grads, err)
            params, opt_state, _ = adamw.apply_updates(
                params, grads, opt_state, opt_cfg)
            return (params, opt_state, err), loss

        sup = fault.TrainSupervisor(e.ckpt_dir, save_every=e.save_every,
                                    keep=e.keep)
        if e.install_sigterm:
            sup.install_signal_handler()
        state = (params, adamw.init_state(params), err0)
        state, start, extra = sup.try_restore(
            state, check_treedef=e.check_treedef)
        if start and "loader" in extra:
            loader.state = PIPE.LoaderState(**extra["loader"])

        it = iter(loader)
        history = []
        last = [jnp.float32(np.nan)]

        def step_fn(state, step):
            batch = next(it)
            state, loss = train_step(state, jnp.asarray(batch["ids"]),
                                     jnp.asarray(batch["y"]))
            last[0] = loss     # device value; sync only at log points
            return state

        def _on_step(step, dt):
            if step % e.log_every == 0 or step == e.steps:
                history.append((step, float(last[0])))
                if e.verbose:
                    print(f"  step {step}: mse={float(last[0]):.4f} "
                          f"({dt * 1e3:.0f} ms)")
            if on_step is not None:
                on_step(step, dt)

        heads_extra = list(self.heads) if self.heads else [self.target]
        t0 = time.perf_counter()
        with mesh:
            state = sup.run(
                state, step_fn, e.steps, start_step=start,
                extra_fn=lambda: {"loader": loader.state.as_dict(),
                                  "norm_stats": norm_stats,
                                  "heads": heads_extra},
                on_step=_on_step)
        wall = time.perf_counter() - t0
        steps_run = max(e.steps - start, 0)
        # a resume that finds the run already complete executes 0 steps:
        # final_loss is then NaN (nothing ran) and steps_per_s 0 by design
        stats = {"final_loss": float(last[0]),
                 "steps": float(steps_run),
                 "wall_time_s": wall,
                 "steps_per_s": steps_run / max(wall, 1e-9)}
        return TrainResult(params=state[0], stats=stats, history=history,
                           norm_stats=norm_stats, heads=self.heads)


def train_model(kind: str, cfg, train: DS.CostDataset, target: TargetSpec,
                *, steps: int = 300, batch_size: int = 64,
                lr: float = 1e-3, seed: int = 0, log_every: int = 100,
                verbose: bool = False, **engine_overrides) -> TrainResult:
    """Compatibility wrapper: a TrainEngine with in-memory defaults."""
    return TrainEngine(kind, cfg, target, steps=steps,
                       batch_size=batch_size, lr=lr, seed=seed,
                       log_every=log_every, verbose=verbose,
                       **engine_overrides).fit(train)


def _target_metrics(pred_n: np.ndarray, true: np.ndarray,
                    stats: Dict[str, float]) -> Dict[str, float]:
    """Paper metrics: relative RMSE (%), normalized RMSE, %-exact (rounded)."""
    pred = DS.denormalize(pred_n, stats)
    rel = (pred - true) / np.maximum(np.abs(true), 1e-6)
    # normalized-space RMSE against the train normalization
    true_n = (np.log1p(true) - stats["mu"]) / stats["sigma"]
    return {
        "rmse_rel_pct": float(np.sqrt(np.mean(np.square(rel))) * 100),
        "mape_pct": float(np.mean(np.abs(rel)) * 100),
        "rmse_norm": float(np.sqrt(np.mean(np.square(pred_n - true_n)))),
        "exact_pct": float(np.mean(np.round(pred) == np.round(true)) * 100),
        "within5_pct": float(np.mean(np.abs(rel) <= 0.05) * 100),
    }


def evaluate(kind: str, cfg, result: TrainResult, test: DS.CostDataset,
             target: Optional[TargetSpec] = None, batch_size: int = 256
             ) -> Dict[str, Any]:
    """Evaluate a TrainResult.

    Single-head result + target name -> flat metrics dict (legacy).
    Multi-head result -> {target: metrics} for every requested target
    (default: all heads); passing a single name returns that head's flat
    metrics dict.
    """
    _, apply_fn, _ = CM.get_model(kind)
    apply_j = jax.jit(apply_fn)
    test_ids = test.dense_ids()
    preds = []
    for i in range(0, len(test_ids), batch_size):
        ids = jnp.asarray(test_ids[i:i + batch_size])
        preds.append(jax.device_get(apply_j(result.params, ids)))
    if result.heads:
        pred_n = {t: np.concatenate([np.asarray(p[t]) for p in preds])
                  for t in result.heads}
        if isinstance(target, str):
            return _target_metrics(pred_n[target], test.targets[target],
                                   result.norm_stats[target])
        wanted = tuple(target) if target is not None else result.heads
        return {t: _target_metrics(pred_n[t], test.targets[t],
                                   result.norm_stats[t])
                for t in wanted}
    if not isinstance(target, str):
        raise ValueError("single-head evaluate needs a target name")
    pred_n = np.concatenate([np.asarray(p) for p in preds])
    return _target_metrics(pred_n, test.targets[target], result.norm_stats)
