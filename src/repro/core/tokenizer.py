"""MLIR tokenization — the paper's two schemes (Fig. 4).

* ``ops``           — opcode sequence + graph input/output tensor shapes;
                      operands dropped; each full shape is ONE token
                      (e.g. ``8x224x224x3xf32``).
* ``ops_operands``  — opcodes AND SSA operand names (``%3``, ``%arg1``) and
                      per-op output shape, in source order (~4x longer).

Unseen shape tokens or ``%k`` names become ``<unk>`` (the paper's OOV
failure mode, reproduced faithfully) — unless the vocab was built or
extended with the OOV machinery below, in which case they degrade
gracefully instead of collapsing onto a single id:

* **hash-bucketed unk shards** (``n_unk_buckets > 0``): an unseen token
  maps to ``<unk#crc32(token) % n>``, so distinct unseen ops/dtypes
  stay distinguishable to the model instead of aliasing onto one
  ``<unk>`` embedding. The shard hash is crc32 over the token's UTF-8
  bytes — deterministic across processes, unlike python ``hash()``, so
  a router-side featurizer and a replica encode identically.
* **byte fallback** (``byte_fallback=True``): short unseen tokens
  (<= :data:`BYTE_FALLBACK_MAX` UTF-8 bytes) expand to per-byte
  ``<0xNN>`` tokens, preserving their spelling end-to-end (the
  SentencePiece byte-fallback idea, applied to MLIR identifiers).

Both default OFF, so existing vocabs behave exactly as before; enable
via :func:`extend_vocab_oov` (post-hoc, on a trained vocab with spare
id capacity) or ``vocab_from_counts(..., n_unk_buckets=, byte_fallback=)``
at fit time. Every added id stays below the embedding-table cap the
caller passes, so a trained model serves extended vocabs unchanged.

The tokenizer also accepts raw MLIR *text* (e.g. StableHLO emitted by
``jax.jit(...).lower().as_text()``) via :func:`tokenize_text` — a
whitespace/punctuation lexer that keeps opcodes, SSA names, and
``NxMxf32`` shapes as single tokens.
"""
from __future__ import annotations

import json
import re
import zlib
from collections import Counter
from dataclasses import dataclass
from typing import Dict, Iterable, List, Sequence

import numpy as np

from repro.ir.graph import Graph

PAD, UNK, BOS, EOS, SEP = "<pad>", "<unk>", "<bos>", "<eos>", "<sep>"
SPECIALS = [PAD, UNK, BOS, EOS, SEP]

# Longest unseen token (in UTF-8 bytes) the byte fallback will expand;
# longer ones (huge attribute blobs) go to an unk shard instead so one
# pathological token can't flood the sequence budget.
BYTE_FALLBACK_MAX = 16


def unk_shard_token(k: int) -> str:
    return f"<unk#{k}>"


def byte_token(b: int) -> str:
    return f"<0x{b:02X}>"


def shard_of(token: str, n_unk_buckets: int) -> int:
    """Deterministic unk-shard index (crc32, stable across processes)."""
    return zlib.crc32(token.encode("utf-8")) % n_unk_buckets

# Bare NxMx<dtype> shape tokens: the dtype alternation must cover every
# MLIR element type the corpus can emit — longer spellings first (``i16``
# before ``i1``, ``f64`` before ``f6``-style prefixes) so the regex never
# matches a prefix and shatters the rest of the shape into fragment
# tokens that become <unk>.
_SHAPE_DTYPES = r"(?:bf16|f64|f32|f16|i64|i32|i16|i8|i1)"
_TEXT_TOKEN_RE = re.compile(
    r"%[A-Za-z0-9_]+|\"[a-z_]+\.[a-z0-9_.]+\"|[a-z_]+\.[a-z0-9_.]+"
    r"|tensor<[^>]*>|\d+x[0-9x]*" + _SHAPE_DTYPES +
    r"|[A-Za-z_][A-Za-z0-9_]*")


def graph_tokens(g: Graph, mode: str = "ops") -> List[str]:
    """Token sequence for a Graph, per the paper's Fig. 4 layout."""
    toks = [BOS]
    # (2) input tensor shapes, each shape a single token
    for i in range(g.n_args):
        toks.append(g.values[i].shape_token())
    toks.append(SEP)
    if mode == "ops":
        # (1) the xpu.op sequence with per-op output shape; operand names
        # (and hence data dependence) dropped — paper's first scheme
        for op in g.ops:
            toks.append(f"xpu.{op.opcode}")
            toks.append(g.values[op.result].shape_token())
    elif mode == "ops_operands":
        for op in g.ops:
            toks.append(g.ssa_name(op.result))
            toks.append(f"xpu.{op.opcode}")
            toks.extend(g.ssa_name(o) for o in op.operands)
            toks.append(g.values[op.result].shape_token())
    else:
        raise ValueError(f"unknown mode {mode!r}")
    toks.append(SEP)
    # (3) output tensor shapes
    for o in g.outputs:
        toks.append(g.values[o].shape_token())
    toks.append(EOS)
    return toks


def tokenize_text(mlir_text: str) -> List[str]:
    """Lex raw MLIR text (StableHLO/affine dialects) into tokens; tensor
    types collapse to single shape tokens per the paper's policy."""
    toks = [BOS]
    for m in _TEXT_TOKEN_RE.finditer(mlir_text):
        t = m.group(0)
        if t.startswith("tensor<"):
            t = t[len("tensor<"):-1].replace("?", "D")
        toks.append(t.strip('"'))
    toks.append(EOS)
    return toks


@dataclass
class Vocab:
    token_to_id: Dict[str, int]
    # OOV machinery (0/False = legacy single-<unk> behavior). The shard
    # and byte tokens themselves live in token_to_id like any other
    # token; these fields just tell encode() how to resolve a miss.
    n_unk_buckets: int = 0
    byte_fallback: bool = False

    @property
    def size(self) -> int:
        return len(self.token_to_id)

    def _oov_ids(self, token: str) -> List[int]:
        """Ids for one out-of-vocabulary token; never raises. Byte
        fallback first (short tokens keep their spelling), then the
        crc32 unk shard, then the bare <unk>."""
        if self.byte_fallback:
            bs = token.encode("utf-8", "replace")
            if 0 < len(bs) <= BYTE_FALLBACK_MAX:
                ids = [self.token_to_id.get(byte_token(b)) for b in bs]
                if all(i is not None for i in ids):
                    return ids          # type: ignore[return-value]
        if self.n_unk_buckets > 0:
            i = self.token_to_id.get(
                unk_shard_token(shard_of(token, self.n_unk_buckets)))
            if i is not None:
                return [i]
        return [self.token_to_id[UNK]]

    @property
    def _oov_active(self) -> bool:
        return self.n_unk_buckets > 0 or self.byte_fallback

    def encode(self, tokens: Sequence[str], max_len: int) -> np.ndarray:
        """Sequences longer than ``max_len`` are silently truncated —
        serving layers that bucket-pad surface a truncation counter
        (see CostModelService.truncations) so drops stay observable.
        With the OOV machinery enabled, an unseen token may expand to
        several byte-fallback ids (before truncation)."""
        t2i = self.token_to_id
        unk = t2i[UNK]
        if not self._oov_active:
            ids = [t2i.get(t, unk) for t in tokens[:max_len]]
        else:
            ids = []
            for t in tokens:
                i = t2i.get(t)
                if i is not None:
                    ids.append(i)
                else:
                    ids.extend(self._oov_ids(t))
                if len(ids) >= max_len:
                    ids = ids[:max_len]
                    break
        out = np.full((max_len,), t2i[PAD], np.int32)
        out[:len(ids)] = ids
        return out

    def _frozen_table(self):
        """Sorted numpy token table for vectorized lookup, built lazily
        and rebuilt if the vocab dict grew (it never does in practice —
        vocabs are frozen after fit)."""
        tab = getattr(self, "_tab", None)
        if tab is None or tab[2] != len(self.token_to_id):
            toks = np.array(list(self.token_to_id.keys()))
            ids = np.fromiter(self.token_to_id.values(), np.int32,
                              len(self.token_to_id))
            order = np.argsort(toks)
            tab = (toks[order], ids[order], len(self.token_to_id))
            self._tab = tab
        return tab[0], tab[1]

    def encode_many(self, token_seqs: Sequence[Sequence[str]],
                    max_len: int) -> np.ndarray:
        """Vectorized batch encode -> (len(token_seqs), max_len) int32.

        One ``np.searchsorted`` over the frozen sorted token table
        replaces per-token ``dict.get`` calls; row-identical to
        :meth:`encode` (same truncation, PAD, and <unk> behavior).
        Rows that are fully in-vocabulary keep the vectorized fast path
        even when the OOV machinery is enabled; only rows containing an
        unseen token fall back to the per-row :meth:`encode` (shard /
        byte-fallback resolution is per-token python anyway)."""
        pad, unk = self.token_to_id[PAD], self.token_to_id[UNK]
        out = np.full((len(token_seqs), max_len), pad, np.int32)
        if not token_seqs:
            return out
        lens = np.fromiter((min(len(s), max_len) for s in token_seqs),
                           np.int64, len(token_seqs))
        flat = [t for s in token_seqs for t in s[:max_len]]
        if not flat:
            return out
        toks, ids_sorted = self._frozen_table()
        arr = np.asarray(flat)
        idx = np.minimum(np.searchsorted(toks, arr), len(toks) - 1)
        found = toks[idx] == arr
        vals = np.where(found, ids_sorted[idx], unk).astype(np.int32)
        rows = np.repeat(np.arange(len(token_seqs)), lens)
        cols = np.arange(int(lens.sum())) - np.repeat(
            np.cumsum(lens) - lens, lens)
        out[rows, cols] = vals
        if self._oov_active and not found.all():
            for r in np.unique(rows[~found]):
                out[r] = self.encode(token_seqs[r], max_len)
        return out

    def oov_rate(self, tokens: Sequence[str]) -> float:
        """Fraction of tokens absent from token_to_id. Shard / byte
        resolution does NOT change this number — it measures vocabulary
        drift on incoming traffic, not encoding failure (see
        :meth:`unk_fraction` for the latter)."""
        if not tokens:
            return 0.0
        return sum(t not in self.token_to_id for t in tokens) / len(tokens)

    def unk_fraction(self, ids: np.ndarray) -> float:
        """Fraction of non-PAD positions that collapsed onto the bare
        ``<unk>`` id. 0.0 on an OOV-extended vocab means every unseen
        token resolved to a shard or byte ids instead."""
        ids = np.asarray(ids)
        live = ids != self.token_to_id[PAD]
        n = int(live.sum())
        if n == 0:
            return 0.0
        return float((ids[live] == self.token_to_id[UNK]).sum()) / n

    def save(self, path: str) -> None:
        payload = {"token_to_id": self.token_to_id,
                   "n_unk_buckets": self.n_unk_buckets,
                   "byte_fallback": self.byte_fallback}
        with open(path, "w") as f:
            json.dump(payload, f)

    @classmethod
    def load(cls, path: str) -> "Vocab":
        with open(path) as f:
            obj = json.load(f)
        if isinstance(obj.get("token_to_id"), dict):
            return cls(obj["token_to_id"],
                       n_unk_buckets=int(obj.get("n_unk_buckets", 0)),
                       byte_fallback=bool(obj.get("byte_fallback", False)))
        return cls(obj)              # legacy format: the plain id dict


def extend_vocab_oov(v: Vocab, n_unk_buckets: int = 32,
                     byte_fallback: bool = True,
                     max_size: int = 0) -> Vocab:
    """Append the OOV machinery tokens to a (trained) vocab.

    Returns a NEW Vocab sharing no dict with ``v``; ids already present
    keep their values, so a model trained on ``v`` serves the extension
    unchanged. ``max_size`` (usually the model's ``cfg.vocab_size``,
    i.e. its embedding-table row count) caps the grown vocab — the
    extension must fit in the trained model's id range or the new ids
    would index past the embedding table."""
    t2i = dict(v.token_to_id)
    want = [unk_shard_token(k) for k in range(n_unk_buckets)]
    if byte_fallback:
        want += [byte_token(b) for b in range(256)]
    new = [t for t in want if t not in t2i]
    if max_size and len(t2i) + len(new) > max_size:
        raise ValueError(
            f"OOV extension needs {len(t2i) + len(new)} ids but the "
            f"embedding table caps at {max_size}; shrink n_unk_buckets "
            f"or refit the vocab with headroom")
    for t in new:
        t2i[t] = len(t2i)
    return Vocab(t2i, n_unk_buckets=n_unk_buckets,
                 byte_fallback=byte_fallback)


def vocab_from_counts(counts: Counter, max_size: int = 8192,
                      min_count: int = 1, n_unk_buckets: int = 0,
                      byte_fallback: bool = False) -> Vocab:
    """Build a Vocab from pre-accumulated token counts (the streaming
    count-then-encode path: pass 1 counts, pass 2 encodes). With
    ``n_unk_buckets`` / ``byte_fallback``, the OOV machinery tokens are
    reserved FIRST so they always fit under ``max_size``."""
    vocab = {t: i for i, t in enumerate(SPECIALS)}
    for k in range(n_unk_buckets):
        vocab[unk_shard_token(k)] = len(vocab)
    if byte_fallback:
        for b in range(256):
            vocab[byte_token(b)] = len(vocab)
    for tok, c in counts.most_common():
        if len(vocab) >= max_size:
            break
        if c >= min_count and tok not in vocab:
            vocab[tok] = len(vocab)
    return Vocab(vocab, n_unk_buckets=n_unk_buckets,
                 byte_fallback=byte_fallback)


def fit_vocab(token_seqs: Iterable[Sequence[str]],
              max_size: int = 8192, min_count: int = 1,
              n_unk_buckets: int = 0,
              byte_fallback: bool = False) -> Vocab:
    counts: Counter = Counter()
    for seq in token_seqs:
        counts.update(seq)
    return vocab_from_counts(counts, max_size=max_size,
                             min_count=min_count,
                             n_unk_buckets=n_unk_buckets,
                             byte_fallback=byte_fallback)
