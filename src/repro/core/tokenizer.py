"""MLIR tokenization — the paper's two schemes (Fig. 4).

* ``ops``           — opcode sequence + graph input/output tensor shapes;
                      operands dropped; each full shape is ONE token
                      (e.g. ``8x224x224x3xf32``).
* ``ops_operands``  — opcodes AND SSA operand names (``%3``, ``%arg1``) and
                      per-op output shape, in source order (~4x longer).

Unseen shape tokens or ``%k`` names become ``<unk>`` (the paper's OOV
failure mode, reproduced faithfully).

The tokenizer also accepts raw MLIR *text* (e.g. StableHLO emitted by
``jax.jit(...).lower().as_text()``) via :func:`tokenize_text` — a
whitespace/punctuation lexer that keeps opcodes, SSA names, and
``NxMxf32`` shapes as single tokens.
"""
from __future__ import annotations

import json
import re
from collections import Counter
from dataclasses import dataclass
from typing import Dict, Iterable, List, Sequence

import numpy as np

from repro.ir.graph import Graph

PAD, UNK, BOS, EOS, SEP = "<pad>", "<unk>", "<bos>", "<eos>", "<sep>"
SPECIALS = [PAD, UNK, BOS, EOS, SEP]

# Bare NxMx<dtype> shape tokens: the dtype alternation must cover every
# MLIR element type the corpus can emit — longer spellings first (``i16``
# before ``i1``, ``f64`` before ``f6``-style prefixes) so the regex never
# matches a prefix and shatters the rest of the shape into fragment
# tokens that become <unk>.
_SHAPE_DTYPES = r"(?:bf16|f64|f32|f16|i64|i32|i16|i8|i1)"
_TEXT_TOKEN_RE = re.compile(
    r"%[A-Za-z0-9_]+|\"[a-z_]+\.[a-z0-9_.]+\"|[a-z_]+\.[a-z0-9_.]+"
    r"|tensor<[^>]*>|\d+x[0-9x]*" + _SHAPE_DTYPES +
    r"|[A-Za-z_][A-Za-z0-9_]*")


def graph_tokens(g: Graph, mode: str = "ops") -> List[str]:
    """Token sequence for a Graph, per the paper's Fig. 4 layout."""
    toks = [BOS]
    # (2) input tensor shapes, each shape a single token
    for i in range(g.n_args):
        toks.append(g.values[i].shape_token())
    toks.append(SEP)
    if mode == "ops":
        # (1) the xpu.op sequence with per-op output shape; operand names
        # (and hence data dependence) dropped — paper's first scheme
        for op in g.ops:
            toks.append(f"xpu.{op.opcode}")
            toks.append(g.values[op.result].shape_token())
    elif mode == "ops_operands":
        for op in g.ops:
            toks.append(g.ssa_name(op.result))
            toks.append(f"xpu.{op.opcode}")
            toks.extend(g.ssa_name(o) for o in op.operands)
            toks.append(g.values[op.result].shape_token())
    else:
        raise ValueError(f"unknown mode {mode!r}")
    toks.append(SEP)
    # (3) output tensor shapes
    for o in g.outputs:
        toks.append(g.values[o].shape_token())
    toks.append(EOS)
    return toks


def tokenize_text(mlir_text: str) -> List[str]:
    """Lex raw MLIR text (StableHLO/affine dialects) into tokens; tensor
    types collapse to single shape tokens per the paper's policy."""
    toks = [BOS]
    for m in _TEXT_TOKEN_RE.finditer(mlir_text):
        t = m.group(0)
        if t.startswith("tensor<"):
            t = t[len("tensor<"):-1].replace("?", "D")
        toks.append(t.strip('"'))
    toks.append(EOS)
    return toks


@dataclass
class Vocab:
    token_to_id: Dict[str, int]

    @property
    def size(self) -> int:
        return len(self.token_to_id)

    def encode(self, tokens: Sequence[str], max_len: int) -> np.ndarray:
        """Sequences longer than ``max_len`` are silently truncated —
        serving layers that bucket-pad surface a truncation counter
        (see CostModelService.truncations) so drops stay observable."""
        unk = self.token_to_id[UNK]
        ids = [self.token_to_id.get(t, unk) for t in tokens[:max_len]]
        out = np.full((max_len,), self.token_to_id[PAD], np.int32)
        out[:len(ids)] = ids
        return out

    def _frozen_table(self):
        """Sorted numpy token table for vectorized lookup, built lazily
        and rebuilt if the vocab dict grew (it never does in practice —
        vocabs are frozen after fit)."""
        tab = getattr(self, "_tab", None)
        if tab is None or tab[2] != len(self.token_to_id):
            toks = np.array(list(self.token_to_id.keys()))
            ids = np.fromiter(self.token_to_id.values(), np.int32,
                              len(self.token_to_id))
            order = np.argsort(toks)
            tab = (toks[order], ids[order], len(self.token_to_id))
            self._tab = tab
        return tab[0], tab[1]

    def encode_many(self, token_seqs: Sequence[Sequence[str]],
                    max_len: int) -> np.ndarray:
        """Vectorized batch encode -> (len(token_seqs), max_len) int32.

        One ``np.searchsorted`` over the frozen sorted token table
        replaces per-token ``dict.get`` calls; row-identical to
        :meth:`encode` (same truncation, PAD, and <unk> behavior)."""
        pad, unk = self.token_to_id[PAD], self.token_to_id[UNK]
        out = np.full((len(token_seqs), max_len), pad, np.int32)
        if not token_seqs:
            return out
        lens = np.fromiter((min(len(s), max_len) for s in token_seqs),
                           np.int64, len(token_seqs))
        flat = [t for s in token_seqs for t in s[:max_len]]
        if not flat:
            return out
        toks, ids_sorted = self._frozen_table()
        arr = np.asarray(flat)
        idx = np.minimum(np.searchsorted(toks, arr), len(toks) - 1)
        found = toks[idx] == arr
        vals = np.where(found, ids_sorted[idx], unk).astype(np.int32)
        rows = np.repeat(np.arange(len(token_seqs)), lens)
        cols = np.arange(int(lens.sum())) - np.repeat(
            np.cumsum(lens) - lens, lens)
        out[rows, cols] = vals
        return out

    def oov_rate(self, tokens: Sequence[str]) -> float:
        if not tokens:
            return 0.0
        return sum(t not in self.token_to_id for t in tokens) / len(tokens)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.token_to_id, f)

    @classmethod
    def load(cls, path: str) -> "Vocab":
        with open(path) as f:
            return cls(json.load(f))


def vocab_from_counts(counts: Counter, max_size: int = 8192,
                      min_count: int = 1) -> Vocab:
    """Build a Vocab from pre-accumulated token counts (the streaming
    count-then-encode path: pass 1 counts, pass 2 encodes)."""
    vocab = {t: i for i, t in enumerate(SPECIALS)}
    for tok, c in counts.most_common():
        if len(vocab) >= max_size:
            break
        if c >= min_count and tok not in vocab:
            vocab[tok] = len(vocab)
    return Vocab(vocab)


def fit_vocab(token_seqs: Iterable[Sequence[str]],
              max_size: int = 8192, min_count: int = 1) -> Vocab:
    counts: Counter = Counter()
    for seq in token_seqs:
        counts.update(seq)
    return vocab_from_counts(counts, max_size=max_size, min_count=min_count)
