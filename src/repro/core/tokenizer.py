"""MLIR tokenization — the paper's two schemes (Fig. 4).

* ``ops``           — opcode sequence + graph input/output tensor shapes;
                      operands dropped; each full shape is ONE token
                      (e.g. ``8x224x224x3xf32``).
* ``ops_operands``  — opcodes AND SSA operand names (``%3``, ``%arg1``) and
                      per-op output shape, in source order (~4x longer).

Unseen shape tokens or ``%k`` names become ``<unk>`` (the paper's OOV
failure mode, reproduced faithfully).

The tokenizer also accepts raw MLIR *text* (e.g. StableHLO emitted by
``jax.jit(...).lower().as_text()``) via :func:`tokenize_text` — a
whitespace/punctuation lexer that keeps opcodes, SSA names, and
``NxMxf32`` shapes as single tokens.
"""
from __future__ import annotations

import json
import re
from collections import Counter
from dataclasses import dataclass
from typing import Dict, Iterable, List, Sequence

import numpy as np

from repro.ir.graph import Graph

PAD, UNK, BOS, EOS, SEP = "<pad>", "<unk>", "<bos>", "<eos>", "<sep>"
SPECIALS = [PAD, UNK, BOS, EOS, SEP]

_TEXT_TOKEN_RE = re.compile(
    r"%[A-Za-z0-9_]+|\"[a-z_]+\.[a-z0-9_.]+\"|[a-z_]+\.[a-z0-9_.]+"
    r"|tensor<[^>]*>|\d+x[0-9x]*(?:f32|bf16|f16|i8|i32)"
    r"|[A-Za-z_][A-Za-z0-9_]*")


def graph_tokens(g: Graph, mode: str = "ops") -> List[str]:
    """Token sequence for a Graph, per the paper's Fig. 4 layout."""
    toks = [BOS]
    # (2) input tensor shapes, each shape a single token
    for i in range(g.n_args):
        toks.append(g.values[i].shape_token())
    toks.append(SEP)
    if mode == "ops":
        # (1) the xpu.op sequence with per-op output shape; operand names
        # (and hence data dependence) dropped — paper's first scheme
        for op in g.ops:
            toks.append(f"xpu.{op.opcode}")
            toks.append(g.values[op.result].shape_token())
    elif mode == "ops_operands":
        for op in g.ops:
            toks.append(g.ssa_name(op.result))
            toks.append(f"xpu.{op.opcode}")
            toks.extend(g.ssa_name(o) for o in op.operands)
            toks.append(g.values[op.result].shape_token())
    else:
        raise ValueError(f"unknown mode {mode!r}")
    toks.append(SEP)
    # (3) output tensor shapes
    for o in g.outputs:
        toks.append(g.values[o].shape_token())
    toks.append(EOS)
    return toks


def tokenize_text(mlir_text: str) -> List[str]:
    """Lex raw MLIR text (StableHLO/affine dialects) into tokens; tensor
    types collapse to single shape tokens per the paper's policy."""
    toks = [BOS]
    for m in _TEXT_TOKEN_RE.finditer(mlir_text):
        t = m.group(0)
        if t.startswith("tensor<"):
            t = t[len("tensor<"):-1].replace("?", "D")
        toks.append(t.strip('"'))
    toks.append(EOS)
    return toks


@dataclass
class Vocab:
    token_to_id: Dict[str, int]

    @property
    def size(self) -> int:
        return len(self.token_to_id)

    def encode(self, tokens: Sequence[str], max_len: int) -> np.ndarray:
        unk = self.token_to_id[UNK]
        ids = [self.token_to_id.get(t, unk) for t in tokens[:max_len]]
        out = np.full((max_len,), self.token_to_id[PAD], np.int32)
        out[:len(ids)] = ids
        return out

    def oov_rate(self, tokens: Sequence[str]) -> float:
        if not tokens:
            return 0.0
        return sum(t not in self.token_to_id for t in tokens) / len(tokens)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.token_to_id, f)

    @classmethod
    def load(cls, path: str) -> "Vocab":
        with open(path) as f:
            return cls(json.load(f))


def vocab_from_counts(counts: Counter, max_size: int = 8192,
                      min_count: int = 1) -> Vocab:
    """Build a Vocab from pre-accumulated token counts (the streaming
    count-then-encode path: pass 1 counts, pass 2 encodes)."""
    vocab = {t: i for i, t in enumerate(SPECIALS)}
    for tok, c in counts.most_common():
        if len(vocab) >= max_size:
            break
        if c >= min_count and tok not in vocab:
            vocab[tok] = len(vocab)
    return Vocab(vocab)


def fit_vocab(token_seqs: Iterable[Sequence[str]],
              max_size: int = 8192, min_count: int = 1) -> Vocab:
    counts: Counter = Counter()
    for seq in token_seqs:
        counts.update(seq)
    return vocab_from_counts(counts, max_size=max_size, min_count=min_count)
