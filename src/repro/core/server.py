"""CostModelServer — async micro-batching gateway over CostModelService.

A DL-compiler doing fusion/unroll/recompile search issues thousands of
concurrent cost queries. The synchronous service answers one caller at a
time: concurrent clients serialize on whole forward passes. This server
turns that into a coalescing pipeline:

* **per-bucket queues** — every request is encoded in the caller's
  thread into a (content-hash, bucket-padded ids) batch entry and routed
  onto the queue for its sequence bucket, so one flush always yields a
  shape-homogeneous batch (one jitted program per bucket).
* **micro-batch flush policy** — a bucket flushes when it holds
  ``max_batch`` entries (full-batch path) or when its oldest entry has
  waited ``flush_us`` microseconds (deadline path, default 2 ms). Both
  paths run the same ``service.forward_entries`` kernel, and the
  service pads batches up to a fixed power-of-two ladder, so results are
  bit-identical to direct per-request ``predict_all`` calls no matter
  how requests were packed.
* **in-flight dedup** — concurrent requests for the same canonical
  ``Graph.struct_key()`` (so also SSA-renumbered / re-scheduled
  spellings of one program, e.g. the same candidate derived through two
  rewrite orders by concurrent ``repro.opt`` searches) coalesce onto one
  compute; the LRU answers repeats for free and cache hits resolve at
  submit time without touching a queue.
* **backpressure** — the total number of outstanding requests (queued
  entries plus waiters coalesced onto in-flight keys) is bounded by
  ``max_queue``; beyond it ``submit`` sheds load by raising
  :class:`ServerOverloadedError` instead of growing memory without
  limit under a compile-search storm.
* **AOT warm-up** — ``start(warmup=True)`` pre-compiles every
  (bucket x ladder-batch) jitted program so no client ever pays
  first-call XLA compile latency.
* **streaming metrics** — queue depth, batch occupancy, request
  latency percentiles (p50/p95/p99), cache hit rate, shed count.

The server duck-types the service's prediction API (``predict_all``,
``predict_graphs``, ``predict``, ``resolve_target``, ``heads``), so the
advisors in :mod:`repro.core.service` drive it unchanged.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import Future
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.service import CostModelService
from repro.ir.graph import Graph


class ServerOverloadedError(RuntimeError):
    """Load shed: the bounded request queue is full. Back off and retry.

    ``retry_after_s`` is the server's backoff hint: roughly the time it
    expects to need to drain the current backlog. Clients (the
    replicated serving tier's router) should sleep at least this long
    before retrying, and shed the request themselves after a bounded
    number of attempts."""

    def __init__(self, msg: str, retry_after_s: float = 0.01):
        super().__init__(msg)
        self.retry_after_s = float(retry_after_s)


@dataclass
class _Request:
    key: str
    ids: np.ndarray
    t_submit: float
    future: "Future[np.ndarray]"
    # optional TraceContext (duck-typed: .trace_id/.span_id) — carried so
    # the worker can emit queue-wait/forward spans retroactively for
    # sampled requests; None for the untraced 1-1/sample_every majority
    trace: Any = None


class ServerMetrics:
    """Streaming counters + a bounded latency reservoir.

    One lock (shared with the server's queue — the server builds its
    queue Condition on ``self._lock``) guards every field, but the
    submit hot path never takes it twice: ``note_request`` is called by
    submit while it already holds the queue lock, while the worker-side
    methods (count, observe_latencies) and snapshot() acquire it
    themselves."""

    def __init__(self, reservoir: int = 8192):
        self._lock = threading.Lock()
        # optional callable returning the wrapped service's phase_stats()
        # dict; snapshot() merges it under ``phase_*`` keys so the
        # hash/encode/forward wall-clock split (and the truncation
        # counter) travels with every metrics payload the benches emit
        self.phase_source = None
        # gauges the server updates out-of-band (adaptive flush deadline)
        self.gauges: Dict[str, float] = {}
        # submit-side (bumped via note_request under the shared lock)
        self.requests = 0
        self.cache_hits = 0       # resolved at submit, no queue/forward
        self.coalesced = 0        # merged onto an identical in-flight key
        self.shed = 0             # rejected by backpressure
        self.max_queue_depth = 0
        # worker-side (guarded by self._lock)
        self.batches = 0          # forward passes flushed
        self.batched_entries = 0  # unique entries across those batches
        self.deadline_flushes = 0
        self.full_flushes = 0
        self.stagnant_flushes = 0  # arrivals stalled; flushed early
        self.pipeline_flushes = 0  # dispatched behind an in-flight batch
        self._lat_us = deque(maxlen=reservoir)

    def observe_latencies(self, us: Sequence[float]) -> None:
        with self._lock:
            self._lat_us.extend(us)

    def count(self, field: str, n: int = 1) -> None:
        with self._lock:
            setattr(self, field, getattr(self, field) + n)

    def note_request(self, cache_hit: bool = False, shed: bool = False,
                     coalesced: bool = False, queue_depth: int = 0) -> None:
        """Submit-side bumps; caller holds the server queue lock."""
        self.requests += 1
        if cache_hit:
            self.cache_hits += 1
        if shed:
            self.shed += 1
        if coalesced:
            self.coalesced += 1
        if queue_depth > self.max_queue_depth:
            self.max_queue_depth = queue_depth

    def snapshot(self, queue_depth: int = 0) -> Dict[str, float]:
        phase = self.phase_source() if self.phase_source else None
        with self._lock:
            hits, total = self.cache_hits, self.requests
            lat = np.asarray(self._lat_us, np.float64)
            occ = (self.batched_entries / self.batches
                   if self.batches else 0.0)
            gauges = dict(self.gauges)
            out = {
                "requests": total,
                "cache_hits": hits,
                "cache_hit_rate": hits / total if total else 0.0,
                "coalesced": self.coalesced,
                "shed": self.shed,
                "batches": self.batches,
                "batch_occupancy": occ,
                "deadline_flushes": self.deadline_flushes,
                "full_flushes": self.full_flushes,
                "stagnant_flushes": self.stagnant_flushes,
                "pipeline_flushes": self.pipeline_flushes,
                "queue_depth": queue_depth,
                "max_queue_depth": self.max_queue_depth,
            }
        for name, q in [("p50", 50), ("p95", 95), ("p99", 99)]:
            out[f"latency_{name}_us"] = (
                float(np.percentile(lat, q)) if lat.size else 0.0)
        out.update(gauges)
        if phase is not None:
            for k, v in phase.items():
                out[f"phase_{k}"] = v
        return out


class CostModelServer:
    """Async gateway: many clients submit, one worker flushes coalesced
    per-bucket batches through the wrapped service.

    ``submit`` returns a Future resolving to the raw (n_heads,)
    normalized row; the blocking facade (``predict_all`` etc.)
    denormalizes through the service, exactly like direct calls.
    """

    def __init__(self, service: CostModelService, *,
                 max_batch: Optional[int] = None,
                 flush_us: float = 2000.0,
                 min_batch: Optional[int] = None,
                 max_queue: int = 4096,
                 metrics_reservoir: int = 8192,
                 adaptive_flush: bool = False,
                 flush_us_min: Optional[float] = None,
                 adaptive_k: float = 8.0,
                 tracer=None):
        self.service = service
        # optional repro.obs.trace.Tracer; every hook is None-guarded so
        # the untraced server keeps zero obs imports and zero overhead
        self.tracer = tracer
        self.max_batch = min(max_batch or service.max_batch,
                             service.max_batch)
        self.flush_us = float(flush_us)
        # Adaptive flush deadline: scale the linger with the observed
        # arrival rate. Lingering only pays while more requests are
        # actually arriving — a fixed deadline makes slow-arrival (cold)
        # traffic wait the full budget for batches that never fill. With
        # adaptive_flush on, the effective deadline is
        #   clamp(adaptive_k * EWMA(inter-arrival), flush_us_min, flush_us)
        # and collapses straight to flush_us_min once arrivals are slower
        # than the budget itself (waiting cannot fill a batch, so flush
        # now). flush_us stays the upper bound / latency budget.
        self.adaptive_flush = bool(adaptive_flush)
        self.flush_us_min = (max(self.flush_us / 16.0, 25.0)
                             if flush_us_min is None else float(flush_us_min))
        self.adaptive_k = float(adaptive_k)
        self._arrival_ewma_us: Optional[float] = None
        self._last_arrival: Optional[float] = None
        # Below min_batch the worker prefers letting a queue build while
        # another batch computes (throughput knob); the flush deadline
        # and the stall detector still bound how long entries can wait,
        # so low-concurrency traffic never stalls on an unfillable gate.
        self.min_batch = (max(1, self.max_batch // 4)
                          if min_batch is None else max(1, min_batch))
        self.max_queue = int(max_queue)
        self.metrics = ServerMetrics(metrics_reservoir)
        self.metrics.phase_source = getattr(service, "phase_stats", None)
        self._queues: Dict[int, deque] = {
            b: deque() for b in service.buckets}
        self._n_queued = 0                      # entries across all queues
        self._n_pending = 0                     # + coalesced dup waiters
        self._inflight: Dict[str, List[_Request]] = {}  # key -> dup waiters
        # one lock for queues AND metrics: note_request piggybacks on the
        # submit path's queue lock, and snapshot() sees consistent counts
        self._lock = self.metrics._lock
        self._work = threading.Condition(self._lock)
        self._running = False
        self._worker: Optional[threading.Thread] = None

    # ------------------------------------------------------------ lifecycle
    def start(self, warmup: bool = True) -> "CostModelServer":
        """Start the flush worker; optionally AOT-compile every
        (bucket x ladder-batch) program first so no request ever blocks
        on XLA compilation."""
        if self._running:
            return self
        if warmup:
            # a full flush of max_batch entries pads UP to the next
            # ladder entry, so warm through that size, not just max_batch
            cap = self.service._ladder_batch(self.max_batch)
            self.service.warmup(
                batch_sizes=[b for b in self.service.batch_ladder
                             if b <= cap])
        self._running = True
        self._worker = threading.Thread(
            target=self._run, name="costmodel-server", daemon=True)
        self._worker.start()
        return self

    def stop(self) -> None:
        with self._work:
            self._running = False
            self._work.notify_all()
        if self._worker is not None:
            self._worker.join()
            self._worker = None
        with self._work:
            for reqs in self._inflight.values():
                for r in reqs:
                    if not r.future.done():
                        r.future.set_exception(
                            RuntimeError("server stopped"))
            self._inflight.clear()
            for q in self._queues.values():
                q.clear()
            self._n_queued = 0
            self._n_pending = 0

    def __enter__(self) -> "CostModelServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # --------------------------------------------------------------- submit
    def submit(self, g: Graph, trace=None) -> "Future[np.ndarray]":
        """Enqueue one graph; resolves to its (n_heads,) normalized row.

        Fast paths: an LRU hit resolves immediately without queueing —
        probed by struct key BEFORE any tokenization, so a hit never
        lexes the graph at all (``fast_encode`` services; the legacy
        path encodes first, as before); a request whose content hash is
        already in flight coalesces onto the pending compute. A full
        queue sheds the request instead."""
        if not self._running:
            raise RuntimeError("server not started (call start())")
        if self.service.fast_encode:
            key = self.service.key_of(g)
            hit = self.service.cache_lookup(key)
            ids = None if hit is not None else self.service.ids_for(g, key)
        else:
            key, ids = self.service.entry(g)
            hit = self.service.cache_lookup(key)
            if hit is not None:
                ids = None
        return self._submit_resolved(key, ids, hit, trace=trace)

    def submit_entry(self, key: str, ids: np.ndarray, *,
                     probe: bool = True, trace=None
                     ) -> "Future[np.ndarray]":
        """Ids-first submit: enqueue an already-featurized ``(struct
        key, bucket-padded ids)`` entry, skipping tokenization entirely.

        This is the replicated serving tier's transport seam: a remote
        router featurizes once client-side and ships (token ids +
        struct key); the replica's key-first LRU probe, in-flight
        dedup, micro-batching and backpressure all behave exactly as
        for graph submits. ``len(ids)`` must be one of the service's
        buckets (routers reuse the service's own featurizer, so it
        always is). ``probe=False`` skips the LRU probe — for callers
        (the replica loop) that already probed this key themselves, so
        the miss isn't double-counted or double-looked-up."""
        if not self._running:
            raise RuntimeError("server not started (call start())")
        hit = self.service.cache_lookup(key) if probe else None
        return self._submit_resolved(key, None if hit is not None else ids,
                                     hit, trace=trace)

    def _submit_resolved(self, key: str, ids: Optional[np.ndarray],
                         hit: Optional[np.ndarray], trace=None
                         ) -> "Future[np.ndarray]":
        now = time.monotonic()
        tr = self.tracer
        if hit is not None:
            with self._work:
                self._note_arrival_locked(now)
                self.metrics.note_request(cache_hit=True)
            if tr is not None and trace is not None:
                tr.emit("server.cache_hit", trace, 0.0)
            fut: "Future[np.ndarray]" = Future()
            fut.set_result(hit)
            return fut
        req = _Request(key, ids, now, Future(), trace)
        with self._work:
            if not self._running:      # lost a race with stop()
                raise RuntimeError("server not started (call start())")
            self._note_arrival_locked(now)
            if self._n_pending >= self.max_queue:
                # bound covers coalesced waiters too: a storm on one hot
                # in-flight key must not grow memory without limit
                self.metrics.note_request(shed=True)
                retry_s = self._overload_retry_s_locked()
                if tr is not None:     # sheds are always-on telemetry
                    tr.error_span("server.shed", trace,
                                  retry_after_s=retry_s,
                                  pending=self._n_pending)
                raise ServerOverloadedError(
                    f"queue full ({self._n_pending}/{self.max_queue} "
                    f"outstanding requests); shedding load",
                    retry_after_s=retry_s)
            self._n_pending += 1
            waiters = self._inflight.get(key)
            if waiters is not None:
                waiters.append(req)
                self.metrics.note_request(coalesced=True,
                                          queue_depth=self._n_queued)
            else:
                self._inflight[key] = [req]
                self._queues[len(ids)].append(req)
                self._n_queued += 1
                self.metrics.note_request(queue_depth=self._n_queued)
                self._work.notify()
        return req.future

    def queue_depth(self) -> int:
        with self._lock:
            return self._n_queued

    def metrics_snapshot(self) -> Dict[str, float]:
        """snapshot() with the live queue depth — the one-call metrics
        payload the benches and the replicated tier's stats RPC emit
        (includes the service's ``phase_*`` split and, when adaptive
        flush is on, the current effective deadline gauge)."""
        return self.metrics.snapshot(self.queue_depth())

    # ------------------------------------------------------ adaptive flush
    def _note_arrival_locked(self, now: float) -> None:
        """EWMA of request inter-arrival time; drives the adaptive
        flush deadline. Caller holds the queue lock."""
        last = self._last_arrival
        self._last_arrival = now
        if last is None:
            return
        gap_us = (now - last) * 1e6
        # clamp single gaps at 8 budgets: one long idle pause must not
        # poison the estimate for minutes of subsequent traffic
        gap_us = min(gap_us, 8 * self.flush_us)
        ewma = self._arrival_ewma_us
        self._arrival_ewma_us = gap_us if ewma is None \
            else 0.8 * ewma + 0.2 * gap_us

    def _effective_flush_us_locked(self) -> float:
        """Deadline actually applied by the flush policy this moment."""
        if not self.adaptive_flush:
            return self.flush_us
        ewma = self._arrival_ewma_us
        if ewma is None:
            eff = self.flush_us
        elif ewma >= self.flush_us:
            # arrivals slower than the whole budget: lingering cannot
            # fill a batch, so flush (nearly) immediately — this is the
            # cold-pass fix: a lone search thread's next candidate burst
            # is milliseconds away, not within the deadline
            eff = self.flush_us_min
        else:
            eff = min(max(self.adaptive_k * ewma, self.flush_us_min),
                      self.flush_us)
        self.metrics.gauges["flush_us_effective"] = eff
        return eff

    def _overload_retry_s_locked(self) -> float:
        """Backoff hint for shed requests: about the time to drain the
        backlog at one max_batch per deadline."""
        batches = max(1.0, self._n_pending / max(1, self.max_batch))
        eff_s = max(self._effective_flush_us_locked(), 100.0) / 1e6
        return min(max(batches * eff_s, 1e-3), 0.25)

    # -------------------------------------------------------------- worker
    def _pick_batch_locked(self) -> Tuple[Optional[List[_Request]],
                                          Optional[float], Optional[str]]:
        """Choose a bucket to flush. Returns (batch, wait_s, path).

        Full path: any single bucket holding max_batch entries flushes
        now; so does the largest bucket whenever the TOTAL backlog
        reaches max_batch — with the worker saturated there is nothing
        to gain by lingering, and draining the deepest queue maximizes
        batch occupancy. Deadline path: once any entry has waited
        flush_us, the deepest *expired* bucket flushes (deepest for
        occupancy; expiry-gated so light-traffic buckets still drain
        within a bounded number of cycles). Otherwise the worker sleeps
        until the nearest deadline."""
        now = time.monotonic()
        deadline_s = self._effective_flush_us_locked() / 1e6
        oldest: Optional[float] = None
        largest: Optional[int] = None
        expired: Optional[int] = None
        for b, q in self._queues.items():
            if len(q) >= self.max_batch:
                return self._drain_locked(b), None, "full"
            if q:
                if largest is None or len(q) > len(self._queues[largest]):
                    largest = b
                if oldest is None or q[0].t_submit < oldest:
                    oldest = q[0].t_submit
                if now >= q[0].t_submit + deadline_s and (
                        expired is None
                        or len(q) > len(self._queues[expired])):
                    expired = b
        if oldest is None:
            return None, None, None          # idle: wait for a submit
        if self._n_queued >= self.max_batch:
            return self._drain_locked(largest), None, "full"
        if expired is not None:
            return self._drain_locked(expired), None, "deadline"
        return None, oldest + deadline_s - now, None

    def _drain_locked(self, bucket: int) -> List[_Request]:
        q = self._queues[bucket]
        batch = [q.popleft() for _ in range(min(len(q), self.max_batch))]
        self._n_queued -= len(batch)
        return batch

    def _largest_locked(self) -> int:
        return max((b for b, q in self._queues.items() if q),
                   key=lambda b: len(self._queues[b]))

    def _pipeline_batch_locked(self) -> Tuple[Optional[List[_Request]],
                                              Optional[str]]:
        """Next batch while another is already computing. Only a queue
        that reached min_batch is worth dispatching early (it rides
        behind the in-flight pass either way; smaller ones keep building
        until the pipeline drains and the deadline logic takes over).
        Any head older than 4x the flush deadline preempts regardless
        (no bucket starves behind a busy one)."""
        stale = time.monotonic() - \
            4 * self._effective_flush_us_locked() / 1e6
        for b, q in self._queues.items():
            if q and q[0].t_submit <= stale:
                return self._drain_locked(b), "deadline"
        if self._n_queued == 0:
            return None, None
        largest = self._largest_locked()
        if len(self._queues[largest]) < self.min_batch:
            return None, None
        return self._drain_locked(largest), "pipeline"

    def _run(self) -> None:
        # Two overlapping phases: dispatch batch k+1 (JAX dispatch is
        # async), then block collecting batch k's results, then resolve
        # k's futures while k+1 computes. The wait for the device and
        # the GIL-bound resolution/submission python run concurrently,
        # and the next batch accumulates for a full compute period —
        # occupancy grows with load, with no tuned linger in the loop.
        #
        # Lingering (only when nothing is in flight): the full deadline
        # only pays off while new requests keep arriving. For a tiny
        # backlog, waiting in sub-deadline quanta lets the worker notice
        # a stalled arrival stream (a lone client, or the tail of a
        # burst) and flush early. Deeper backlogs keep the full linger:
        # under load a short no-arrival window is just GIL scheduling
        # noise, and flushing on it collapses batch occupancy.
        quantum = max(self.flush_us / 8e6, 50e-6)
        stagnant_max = max(1, self.max_batch // 4)
        inflight: Optional[Tuple[List[_Request], Any]] = None
        while True:
            with self._work:
                if not self._running:
                    return               # stop() fails leftover futures
                if inflight is not None:
                    batch, path = self._pipeline_batch_locked()
                else:
                    batch, wait_s, path = self._pick_batch_locked()
                    if batch is None and wait_s is None:
                        self._work.wait()        # idle: no queued work
                        continue
                    if batch is None:
                        depth0 = self._n_queued
                        if depth0 > stagnant_max:
                            self._work.wait(timeout=wait_s)
                            continue
                        self._work.wait(timeout=min(wait_s, quantum))
                        if not self._running:
                            return
                        if self._n_queued == depth0:
                            batch, path = (
                                self._drain_locked(self._largest_locked()),
                                "stagnant")
                        else:
                            continue
            if batch is not None:
                handle = self._dispatch(batch, path)
                prev, inflight = inflight, (batch, handle)
                if prev is not None:
                    self._collect_resolve(prev)
            elif inflight is not None:   # queue empty: drain the pipeline
                self._collect_resolve(inflight)
                inflight = None

    def _dispatch(self, batch: List[_Request], path: str):
        t_disp = time.monotonic()
        entries = [(r.key, r.ids) for r in batch]
        try:
            handle = self.service.forward_entries_dispatch(entries)
        except Exception as e:          # resolve waiters, don't kill worker
            return ("err", e, t_disp, path)
        self.metrics.count(f"{path}_flushes")
        self.metrics.count("batches")
        self.metrics.count("batched_entries", len(batch))
        return ("ok", handle, t_disp, path)

    def _collect_resolve(self, item: Tuple[List[_Request], Any]) -> None:
        batch, (status, payload, t_disp, path) = item
        if status == "ok":
            try:
                rows = self.service.forward_entries_collect(payload)
                err = None
            except Exception as e:
                rows, err = None, e
        else:
            rows, err = None, payload
        with self._work:                # one lock round for the whole batch
            waiters = [self._inflight.pop(r.key, [r]) for r in batch]
            self._n_pending -= sum(len(ws) for ws in waiters)
        now = time.monotonic()
        tr = self.tracer
        lats = []
        for i, ws in enumerate(waiters):
            for j, w in enumerate(ws):
                if tr is not None and w.trace is not None:
                    # retroactive spans: the request's queue wait and the
                    # batch it rode are only known here. Emitted BEFORE
                    # set_result so a callback on the future (the replica
                    # loop shipping spans back) already sees them.
                    tr.emit("server.queue", w.trace,
                            max(t_disp - w.t_submit, 0.0),
                            tags={"coalesced": int(j > 0)})
                    tr.emit("server.forward", w.trace,
                            max(now - t_disp, 0.0),
                            status="ok" if err is None else "err",
                            tags={"batch_size": len(batch), "path": path})
                if err is not None:
                    w.future.set_exception(err)
                else:
                    lats.append((now - w.t_submit) * 1e6)
                    w.future.set_result(rows[i])
        if lats:
            self.metrics.observe_latencies(lats)

    # ----------------------------------------- service-compatible facade
    @property
    def heads(self) -> Tuple[str, ...]:
        return self.service.heads

    def resolve_target(self, target: Optional[str]) -> str:
        return self.service.resolve_target(target)

    def predict_all(self, graphs: Sequence[Graph],
                    timeout: Optional[float] = 60.0
                    ) -> Dict[str, np.ndarray]:
        """Blocking facade over submit(): same contract (and bit-identical
        results) as ``service.predict_all``, but concurrent callers'
        graphs coalesce into shared forward passes."""
        if not graphs:
            return {t: np.zeros((0,), np.float32) for t in self.heads}
        tr = self.tracer
        root = None
        if tr is not None:
            ctx = tr.sample()          # head decision: 1 in sample_every
            root = tr.start("client.predict_all", ctx,
                            tags={"n_graphs": len(graphs)})
        sub = root.ctx if root is not None else None
        try:
            if len(graphs) == 1:       # compiler hot path: one candidate
                raw = self.submit(graphs[0], trace=sub).result(
                    timeout=timeout)[None]
            else:
                futs = [self.submit(g, trace=sub) for g in graphs]
                raw = np.stack([f.result(timeout=timeout) for f in futs])
        except BaseException:
            if tr is not None:
                tr.end(root, status="err")
            raise
        if tr is not None:
            tr.end(root)
        out = self.service.denormalize_rows(raw)
        drift = getattr(self.service, "drift", None)
        if drift is not None:
            drift.observe_batch(graphs, out)
        return out

    def predict_graphs(self, graphs: Sequence[Graph],
                       target: Optional[str] = None) -> np.ndarray:
        return self.predict_all(graphs)[self.resolve_target(target)]

    def predict(self, g: Graph, target: Optional[str] = None) -> float:
        return float(self.predict_graphs([g], target)[0])

    def predict_text(self, text, timeout: Optional[float] = 60.0):
        """Async-gateway twin of ``service.predict_text``: the text is
        featurized in the caller's thread (ingest + encode + OOV
        accounting on the wrapped service), then rides ``submit_entry``
        — key-first LRU probe, in-flight dedup, micro-batching, and
        backpressure all apply. Returns a TextPrediction or a
        structured IngestError; ingestion never raises (server-side
        failures like overload/timeout surface as ``predict``-stage
        errors)."""
        from repro.ir import frontdoor as FD
        ent = self.service.ingest_text(text)
        if isinstance(ent, FD.IngestError):
            return ent
        try:
            row = self.submit_entry(ent.key, ent.ids).result(
                timeout=timeout)
        except Exception as e:
            return FD.IngestError("predict", type(e).__name__,
                                  str(e)[:200])
        preds = self.service.denormalize_rows(row[None])
        return FD.prediction_from(
            ent, {t: float(preds[t][0]) for t in self.heads})
