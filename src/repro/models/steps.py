"""train_step / prefill_step / decode_step builders + input_specs.

``input_specs(arch, shape)`` returns jax.ShapeDtypeStruct stand-ins for every
model input (no allocation) — the dry-run lowers against these.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import model as MODEL
from repro.optim import adamw


def cross_entropy_loss(logits, labels, vocab: int):
    """logits: (B, S, Vpad) (any float dtype); labels int32 with -1 = masked.
    Padded-vocab columns are masked out of the softmax."""
    logits = logits.astype(jnp.float32)
    vpad = logits.shape[-1]
    if vpad > vocab:
        col = jnp.arange(vpad)
        logits = jnp.where(col[None, None, :] < vocab, logits, -1e30)
    mask = labels >= 0
    safe_labels = jnp.where(mask, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe_labels[..., None],
                               axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1)


def fused_unembed_loss(h, table, labels, vocab: int, *, chunk: int = 512,
                       rules=None):
    """Sequence-chunked unembed+cross-entropy: full (B, S, V) logits are
    never materialized — each chunk's logits live only inside the scan body
    (a large activation-memory win at 32k seq / 150k vocab)."""
    B, S, D = h.shape
    chunk = min(chunk, S)
    n_chunks = -(-S // chunk)
    pad = n_chunks * chunk - S
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    hc = h.reshape(B, n_chunks, chunk, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n_chunks, chunk).transpose(1, 0, 2)
    vpad = table.shape[0]
    col = jnp.arange(vpad)

    def one(carry, xs):
        hx, lx = xs
        logits = jnp.einsum("bsd,vd->bsv", hx, table.astype(hx.dtype))
        if rules is not None:
            logits = rules.constrain(logits, "batch", None, "vocab")
        logits = logits.astype(jnp.float32)
        if vpad > vocab:
            logits = jnp.where(col[None, None, :] < vocab, logits, -1e30)
        mask = lx >= 0
        safe = jnp.where(mask, lx, 0)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        nll_sum, cnt = carry
        return (nll_sum + ((logz - gold) * mask).sum(),
                cnt + mask.sum()), None

    (nll, cnt), _ = jax.lax.scan(one, (jnp.zeros(()), jnp.zeros(())),
                                 (hc, lc))
    return nll / jnp.maximum(cnt, 1)


def make_loss_fn(cfg: ArchConfig, rules=None, remat=True):
    def loss_fn(params, batch):
        h, aux = MODEL.forward(params, cfg, batch, rules=rules,
                               remat=remat, unembed=False)
        loss = fused_unembed_loss(h, MODEL.unembed_table(params, cfg),
                                  batch["labels"], cfg.vocab, rules=rules)
        return loss + aux, {"loss": loss, "aux": aux}
    return loss_fn


def make_train_step(cfg: ArchConfig, opt_cfg: adamw.AdamWConfig,
                    rules=None, remat=True, grad_transform=None):
    """Returns train_step(params, opt_state, batch)
    -> (params', state', metrics).

    grad_transform: optional fn(grads) -> grads (e.g. compression hook) applied
    before the optimizer.
    """
    loss_fn = make_loss_fn(cfg, rules=rules, remat=remat)

    def train_step(params, opt_state, batch):
        (loss, inner), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        if grad_transform is not None:
            grads = grad_transform(grads)
        params, opt_state, opt_metrics = adamw.apply_updates(
            params, grads, opt_state, opt_cfg)
        metrics = {"total_loss": loss, **inner, **opt_metrics}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, rules=None):
    """prefill_step(params, batch) -> last-token logits (B, Vpad)."""
    def prefill_step(params, batch):
        logits, _ = MODEL.forward(params, cfg, batch, rules=rules,
                                  remat=False)
        return logits[:, -1]
    return prefill_step


def make_decode_step(cfg: ArchConfig, rules=None):
    """decode_step(params, cache, tokens, index) -> (next_token, new_cache)."""
    def decode_step(params, cache, tokens, index):
        logits, new_cache = MODEL.decode_forward(params, cfg, tokens, cache,
                                                 index, rules=rules)
        vpad = logits.shape[-1]
        if vpad > cfg.vocab:
            col = jnp.arange(vpad)
            logits = jnp.where(col[None, :] < cfg.vocab,
                               logits.astype(jnp.float32), -1e30)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token[:, None], new_cache
    return decode_step


# ------------------------------------------------------------- input specs
def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the step inputs of this (arch, shape)."""
    B = shape.global_batch
    sds = jax.ShapeDtypeStruct
    if shape.kind == "decode":
        return {"tokens": sds((B, 1), jnp.int32)}
    S = shape.seq_len
    specs: Dict[str, Any] = {"tokens": sds((B, S), jnp.int32)}
    if shape.kind == "train":
        specs["labels"] = sds((B, S), jnp.int32)
    if cfg.frontend == "vision":
        specs["patch_embeds"] = sds((B, cfg.vision_patches, cfg.d_model),
                                    jnp.bfloat16)
    if cfg.frontend == "audio":
        specs["frame_embeds"] = sds((B, cfg.encoder_seq, cfg.d_model),
                                    jnp.bfloat16)
    if shape.kind == "prefill" and "labels" not in specs:
        pass
    return specs


def abstract_params(cfg: ArchConfig):
    """Param ShapeDtypeStructs via eval_shape (no allocation)."""
    return jax.eval_shape(
        lambda k: MODEL.init_params(k, cfg), jax.random.PRNGKey(0))


def abstract_opt_state(abs_params):
    return jax.eval_shape(adamw.init_state, abs_params)


def abstract_cache(cfg: ArchConfig, batch: int, max_seq: int,
                   kv_dtype=jnp.bfloat16):
    return jax.eval_shape(functools.partial(
        MODEL.init_cache, cfg, batch, max_seq, kv_dtype=kv_dtype))


def opt_state_axes(param_axes_tree):
    """Optimizer-state logical axes mirror the param axes."""
    return {"m": param_axes_tree, "v": param_axes_tree, "count": ()}
