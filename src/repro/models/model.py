"""Full-model assembly for every assigned architecture family.

``init_params`` / ``param_axes`` / ``forward`` / ``init_cache`` /
``decode_forward`` dispatch on ``cfg.family``:

* dense | moe | vlm : token-embedding decoder LM, scanned uniform layers.
* hybrid (jamba)    : scanned periods of 1 attention + 7 mamba layers,
                      MoE on even layers.
* ssm (xlstm)       : scanned (mLSTM, sLSTM) block pairs.
* audio (whisper)   : enc-dec; encoder over stubbed frame embeddings.

All inits are pure (usable under jax.eval_shape for the no-allocation
dry-run). Layer stacks scan over stacked params (leading "stack" axis).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as M
from repro.models import mamba as MB
from repro.models import xlstm as X

VOCAB_PAD = 128


def padded_vocab(cfg) -> int:
    return ((cfg.vocab + VOCAB_PAD - 1) // VOCAB_PAD) * VOCAB_PAD


def _stack_init(key, n, fn):
    return jax.vmap(fn)(jax.random.split(key, n))


def _stack_axes(n, axes):
    return jax.tree.map(lambda a: ("stack",) + a, axes,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))


# =========================================================== uniform decoder
def _layer_init(key, cfg, gated=True):
    k1, k2 = jax.random.split(key)
    p = {"attn": L.attention_init(k1, cfg),
         "ln1": jnp.ones((cfg.d_model,)), "ln2": jnp.ones((cfg.d_model,))}
    if cfg.moe is not None and cfg.moe.moe_every == 1:
        p["moe"] = M.moe_init(k2, cfg)
    else:
        p["ffn"] = L.ffn_init(k2, cfg.d_model, cfg.d_ff, gated=gated)
    return p


def _layer_axes(cfg, gated=True):
    a = {"attn": L.attention_axes(cfg), "ln1": (None,), "ln2": (None,)}
    if cfg.moe is not None and cfg.moe.moe_every == 1:
        a["moe"] = M.moe_axes(cfg)
    else:
        a["ffn"] = L.ffn_axes(gated=gated)
    return a


def _layer_apply(p, h, cfg, *, positions, rules, cdt, cache=None,
                 cache_index=None):
    attn_in = L.rms_norm(h, p["ln1"], cfg.norm_eps)
    a, new_cache = L.attention_apply(p["attn"], attn_in, cfg,
                                     positions=positions, rules=rules,
                                     cdt=cdt, cache=cache,
                                     cache_index=cache_index)
    h = h + a.astype(h.dtype)
    ffn_in = L.rms_norm(h, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        f, aux = M.moe_apply(p["moe"], ffn_in, cfg, rules=rules, cdt=cdt)
    else:
        f = L.ffn_apply(p["ffn"], ffn_in, rules=rules, cdt=cdt)
        aux = jnp.zeros((), jnp.float32)
    return h + f.astype(h.dtype), new_cache, aux


# =========================================================== hybrid (jamba)
def _period_init(key, cfg):
    hb = cfg.hybrid
    ks = jax.random.split(key, 5)
    n_mamba = hb.period - 1
    n_moe = sum(1 for s in range(hb.period) if s % cfg.moe.moe_every == 0)
    n_dense = hb.period - n_moe
    return {
        "attn": L.attention_init(ks[0], cfg),
        "mamba": _stack_init(ks[1], n_mamba, lambda k: MB.mamba_init(k, cfg)),
        "moe": _stack_init(ks[2], n_moe, lambda k: M.moe_init(k, cfg)),
        "ffn": _stack_init(ks[3], n_dense,
                           lambda k: L.ffn_init(k, cfg.d_model, cfg.d_ff)),
        "ln1": jnp.ones((hb.period, cfg.d_model)),
        "ln2": jnp.ones((hb.period, cfg.d_model)),
    }


def _period_axes(cfg):
    return {
        "attn": L.attention_axes(cfg),
        "mamba": _stack_axes(0, MB.mamba_axes(cfg)),
        "moe": _stack_axes(0, M.moe_axes(cfg)),
        "ffn": _stack_axes(0, L.ffn_axes()),
        "ln1": (None, None), "ln2": (None, None),
    }


def _period_apply(p, h, cfg, *, positions, rules, cdt, caches=None,
                  cache_index=None):
    """One period: slots 0..period-1; attention at hb.attn_index."""
    hb = cfg.hybrid
    mamba_i = moe_i = ffn_i = 0
    new_attn_cache, new_mamba_states = None, []
    aux_total = jnp.zeros((), jnp.float32)
    for slot in range(hb.period):
        mix_in = L.rms_norm(h, p["ln1"][slot], cfg.norm_eps)
        if slot == hb.attn_index:
            cache = caches["attn"] if caches is not None else None
            a, new_attn_cache = L.attention_apply(
                p["attn"], mix_in, cfg, positions=positions, rules=rules,
                cdt=cdt, cache=cache, cache_index=cache_index)
        else:
            mp = jax.tree.map(lambda x: x[mamba_i], p["mamba"])
            st = (jax.tree.map(lambda x: x[mamba_i], caches["mamba"])
                  if caches is not None else None)
            a, new_st = MB.mamba_apply(mp, mix_in, cfg, rules=rules,
                                       cdt=cdt, state=st)
            if caches is not None:
                new_mamba_states.append(new_st)
            mamba_i += 1
        h = h + a.astype(h.dtype)
        ffn_in = L.rms_norm(h, p["ln2"][slot], cfg.norm_eps)
        if slot % cfg.moe.moe_every == 0:
            ep = jax.tree.map(lambda x: x[moe_i], p["moe"])
            f, aux = M.moe_apply(ep, ffn_in, cfg, rules=rules, cdt=cdt)
            aux_total = aux_total + aux
            moe_i += 1
        else:
            fp = jax.tree.map(lambda x: x[ffn_i], p["ffn"])
            f = L.ffn_apply(fp, ffn_in, rules=rules, cdt=cdt)
            ffn_i += 1
        h = h + f.astype(h.dtype)
    new_caches = None
    if caches is not None:
        new_caches = {
            "attn": new_attn_cache,
            "mamba": jax.tree.map(lambda *xs: jnp.stack(xs),
                                  *new_mamba_states),
        }
    return h, new_caches, aux_total


# =========================================================== whisper enc-dec
def _enc_layer_init(key, cfg):
    k1, k2 = jax.random.split(key)
    return {"attn": L.attention_init(k1, cfg),
            "ffn": L.ffn_init(k2, cfg.d_model, cfg.d_ff, gated=False),
            "ln1": jnp.ones((cfg.d_model,)), "ln2": jnp.ones((cfg.d_model,))}


def _enc_layer_apply(p, h, cfg, *, rules, cdt):
    """Bidirectional attention (no causal mask, no rope — learned pos)."""
    x = L.rms_norm(h, p["ln1"], cfg.norm_eps).astype(cdt)
    q = jnp.einsum("bsd,dhk->bshk", x, p["attn"]["wq"].astype(cdt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["attn"]["wk"].astype(cdt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["attn"]["wv"].astype(cdt))
    o = L.flash_attention(q, k, v, causal=False, rules=rules)
    a = jnp.einsum("bshk,hkd->bsd", o, p["attn"]["wo"].astype(cdt))
    h = h + a.astype(h.dtype)
    f = L.ffn_apply(p["ffn"], L.rms_norm(h, p["ln2"], cfg.norm_eps),
                    rules=rules, cdt=cdt, gated=False)
    return h + f.astype(h.dtype)


def _dec_layer_init(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"attn": L.attention_init(k1, cfg),
            "xattn": L.attention_init(k2, cfg),
            "ffn": L.ffn_init(k3, cfg.d_model, cfg.d_ff, gated=False),
            "ln1": jnp.ones((cfg.d_model,)), "lnx": jnp.ones((cfg.d_model,)),
            "ln2": jnp.ones((cfg.d_model,))}


def _cross_attend(p, x, enc_kv, cfg, rules, cdt):
    q = jnp.einsum("bsd,dhk->bshk", x.astype(cdt), p["wq"].astype(cdt))
    o = L.flash_attention(q, enc_kv["k"].astype(cdt),
                          enc_kv["v"].astype(cdt), causal=False, rules=rules)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(cdt))


def _dec_layer_apply(p, h, cfg, *, positions, enc_kv, rules, cdt,
                     cache=None, cache_index=None):
    a_in = L.rms_norm(h, p["ln1"], cfg.norm_eps)
    a, new_cache = L.attention_apply(p["attn"], a_in, cfg,
                                     positions=positions, rules=rules,
                                     cdt=cdt, cache=cache,
                                     cache_index=cache_index)
    h = h + a.astype(h.dtype)
    x_in = L.rms_norm(h, p["lnx"], cfg.norm_eps)
    xa = _cross_attend(p["xattn"], x_in, enc_kv, cfg, rules, cdt)
    h = h + xa.astype(h.dtype)
    f = L.ffn_apply(p["ffn"], L.rms_norm(h, p["ln2"], cfg.norm_eps),
                    rules=rules, cdt=cdt, gated=False)
    return h + f.astype(h.dtype), new_cache


# ================================================================= top level
def init_params(key, cfg) -> Dict[str, Any]:
    ks = jax.random.split(key, 8)
    vp = padded_vocab(cfg)
    p: Dict[str, Any] = {
        "embed": L.embedding_init(ks[0], cfg.vocab, cfg.d_model,
                                  pad_to=VOCAB_PAD),
        "final_norm": jnp.ones((cfg.d_model,)),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = {"table": L._init(ks[1], (vp, cfg.d_model),
                                         scale=0.02)}
    fam = cfg.family
    if fam == "ssm":
        n_pairs = max(cfg.n_layers // 2, 1)
        p["pairs"] = _stack_init(ks[2], n_pairs, lambda k: {
            "mlstm": X.mlstm_init(k, cfg),
            "slstm": X.slstm_init(jax.random.fold_in(k, 1), cfg)})
    elif fam == "hybrid":
        n_periods = cfg.n_layers // cfg.hybrid.period
        p["periods"] = _stack_init(ks[2], n_periods,
                                   lambda k: _period_init(k, cfg))
    elif fam == "audio":
        p["enc_pos"] = L._init(ks[3], (cfg.encoder_seq, cfg.d_model),
                               scale=0.02)
        p["dec_pos"] = L._init(ks[4], (32768, cfg.d_model), scale=0.02)
        p["enc_layers"] = _stack_init(ks[2], cfg.n_encoder_layers,
                                      lambda k: _enc_layer_init(k, cfg))
        p["dec_layers"] = _stack_init(ks[5], cfg.n_layers,
                                      lambda k: _dec_layer_init(k, cfg))
        p["enc_norm"] = jnp.ones((cfg.d_model,))
    else:  # dense | moe | vlm
        gated = True
        p["layers"] = _stack_init(ks[2], cfg.n_layers,
                                  lambda k: _layer_init(k, cfg, gated))
    return p


def param_axes(cfg) -> Dict[str, Any]:
    a: Dict[str, Any] = {
        "embed": L.embedding_axes(),
        "final_norm": (None,),
    }
    if not cfg.tie_embeddings:
        a["unembed"] = {"table": ("vocab", "embed")}
    fam = cfg.family
    if fam == "ssm":
        a["pairs"] = _stack_axes(0, {"mlstm": X.mlstm_axes(cfg),
                                     "slstm": X.slstm_axes(cfg)})
    elif fam == "hybrid":
        a["periods"] = _stack_axes(0, _period_axes(cfg))
    elif fam == "audio":
        a["enc_pos"] = (None, "embed")
        a["dec_pos"] = (None, "embed")
        a["enc_layers"] = _stack_axes(0, {
            "attn": L.attention_axes(cfg), "ffn": L.ffn_axes(gated=False),
            "ln1": (None,), "ln2": (None,)})
        a["dec_layers"] = _stack_axes(0, {
            "attn": L.attention_axes(cfg), "xattn": L.attention_axes(cfg),
            "ffn": L.ffn_axes(gated=False),
            "ln1": (None,), "lnx": (None,), "ln2": (None,)})
        a["enc_norm"] = (None,)
    else:
        a["layers"] = _stack_axes(0, _layer_axes(cfg))
    return a


def _embed_tokens(p, cfg, batch, cdt, rules):
    h = L.embed_apply(p["embed"], batch["tokens"], cdt=cdt)
    if cfg.frontend == "vision" and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(cdt)
        P = pe.shape[1]
        h = jnp.concatenate([pe, h[:, P:]], axis=1)
    if rules is not None:
        h = rules.constrain(h, "batch", "qseq", "embed")
    return h


def _run_encoder(p, cfg, frame_embeds, rules, cdt):
    h = frame_embeds.astype(cdt) + p["enc_pos"].astype(cdt)

    def body(hh, lp):
        return _enc_layer_apply(lp, hh, cfg, rules=rules, cdt=cdt), None

    h, _ = jax.lax.scan(jax.checkpoint(body), h, p["enc_layers"])
    return L.rms_norm(h, p["enc_norm"], cfg.norm_eps)


def _enc_kv(p_layer, enc_out, cfg, cdt):
    G = cfg.n_heads // cfg.n_kv_heads
    k = jnp.einsum("bsd,dhk->bshk", enc_out.astype(cdt),
                   p_layer["xattn"]["wk"].astype(cdt))
    v = jnp.einsum("bsd,dhk->bshk", enc_out.astype(cdt),
                   p_layer["xattn"]["wv"].astype(cdt))
    return {"k": jnp.repeat(k, G, axis=2), "v": jnp.repeat(v, G, axis=2)}


def forward(params, cfg, batch, *, rules=None, cdt=jnp.bfloat16,
            remat=True, unembed=True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Training/prefill forward. Returns (logits, aux_loss) — or, with
    unembed=False, (final hidden states, aux_loss) so the caller can fuse
    the unembedding into a chunked loss (never materializing full logits)."""
    B, S = batch["tokens"].shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    aux = jnp.zeros((), jnp.float32)
    fam = cfg.family

    if fam == "audio":
        enc_out = _run_encoder(p=params, cfg=cfg,
                               frame_embeds=batch["frame_embeds"],
                               rules=rules, cdt=cdt)
        h = L.embed_apply(params["embed"], batch["tokens"], cdt=cdt)
        h = h + params["dec_pos"][:S].astype(cdt)

        def dbody(hh, lp):
            ekv = _enc_kv(lp, enc_out, cfg, cdt)
            out, _ = _dec_layer_apply(lp, hh, cfg, positions=positions,
                                      enc_kv=ekv, rules=rules, cdt=cdt)
            return out, None

        dbody = jax.checkpoint(dbody) if remat else dbody
        h, _ = jax.lax.scan(dbody, h, params["dec_layers"])
    elif fam == "ssm":
        h = _embed_tokens(params, cfg, batch, cdt, rules)

        def pbody(hh, pp):
            hh, _ = X.mlstm_block_apply(pp["mlstm"], hh, cfg, rules=rules,
                                        cdt=cdt)
            hh, _ = X.slstm_block_apply(pp["slstm"], hh, cfg, rules=rules,
                                        cdt=cdt)
            return hh, None

        pbody = jax.checkpoint(pbody) if remat else pbody
        h, _ = jax.lax.scan(pbody, h, params["pairs"])
    elif fam == "hybrid":
        h = _embed_tokens(params, cfg, batch, cdt, rules)

        def hbody(hh, pp):
            out, _, aux_p = _period_apply(pp, hh, cfg, positions=positions,
                                          rules=rules, cdt=cdt)
            return out, aux_p

        hbody = jax.checkpoint(hbody) if remat else hbody
        h, auxs = jax.lax.scan(hbody, h, params["periods"])
        aux = aux + auxs.sum()
    else:
        h = _embed_tokens(params, cfg, batch, cdt, rules)

        def body(hh, lp):
            out, _, aux_l = _layer_apply(lp, hh, cfg, positions=positions,
                                         rules=rules, cdt=cdt)
            return out, aux_l

        body = jax.checkpoint(body) if remat else body
        h, auxs = jax.lax.scan(body, h, params["layers"])
        aux = aux + auxs.sum()

    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    if not unembed:
        return h, aux
    table = params["embed"]["table"] if cfg.tie_embeddings else \
        params["unembed"]["table"]
    logits = jnp.einsum("bsd,vd->bsv", h.astype(cdt), table.astype(cdt))
    if rules is not None:
        logits = rules.constrain(logits, "batch", "qseq", "vocab")
    return logits, aux


def unembed_table(params, cfg):
    return params["embed"]["table"] if cfg.tie_embeddings else \
        params["unembed"]["table"]


# ------------------------------------------------------------------- decode
def init_cache(cfg, batch: int, max_seq: int, *, kv_dtype=jnp.bfloat16):
    """Decode-state pytree (KV caches / recurrent states)."""
    nkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim

    def kvc():
        z = jnp.zeros((batch, nkv, max_seq, hd), kv_dtype)
        return {"k": z, "v": jnp.copy(z)}

    def stack(n, tree):
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n,) + x.shape).copy(), tree)

    fam = cfg.family
    if fam == "ssm":
        n_pairs = max(cfg.n_layers // 2, 1)
        return stack(n_pairs, {"mlstm": X.mlstm_init_state(cfg, batch),
                               "slstm": X.slstm_init_state(cfg, batch)})
    if fam == "hybrid":
        n_periods = cfg.n_layers // cfg.hybrid.period
        one = {"attn": kvc(),
               "mamba": stack(cfg.hybrid.period - 1,
                              MB.mamba_init_state(cfg, batch))}
        return stack(n_periods, one)
    if fam == "audio":
        return {"self": stack(cfg.n_layers, kvc()),
                "enc_out": jnp.zeros((batch, cfg.encoder_seq, cfg.d_model),
                                     jnp.bfloat16)}
    return stack(cfg.n_layers, kvc())


def cache_axes(cfg):
    """Logical sharding axes matching init_cache's pytree."""
    kv_axes = {"k": ("stack", "batch", "kv_heads", "cache_seq", None),
               "v": ("stack", "batch", "kv_heads", "cache_seq", None)}
    fam = cfg.family
    if fam == "ssm":
        return {
            "mlstm": {
                "conv": ("stack", "batch", None, "ffn"),
                "cell": {"C": ("stack", "batch", "heads", None, None),
                         "n": ("stack", "batch", "heads", None),
                         "m": ("stack", "batch", "heads")}},
            "slstm": {k: ("stack", "batch", None)
                      for k in ("c", "n", "h", "m")},
        }
    if fam == "hybrid":
        return {
            "attn": kv_axes,
            "mamba": {"conv": ("stack", "stack2", "batch", None, "ffn"),
                      "ssm": ("stack", "stack2", "batch", "ffn", None)},
        }
    if fam == "audio":
        return {"self": kv_axes, "enc_out": ("batch", None, "embed")}
    return kv_axes


def decode_forward(params, cfg, tokens, cache, index, *, rules=None,
                   cdt=jnp.bfloat16):
    """One decode step. tokens: (B, 1) int32; index: scalar position.
    Returns (logits (B, vocab_padded), new_cache)."""
    B = tokens.shape[0]
    positions = jnp.broadcast_to(index, (B, 1))
    fam = cfg.family
    h = L.embed_apply(params["embed"], tokens, cdt=cdt)

    if fam == "audio":
        h = h + jax.lax.dynamic_slice_in_dim(
            params["dec_pos"], index, 1).astype(cdt)
        enc_out = cache["enc_out"]

        def dbody(hh, xs):
            lp, c = xs
            ekv = _enc_kv(lp, enc_out, cfg, cdt)
            out, nc = _dec_layer_apply(lp, hh, cfg, positions=positions,
                                       enc_kv=ekv, rules=rules, cdt=cdt,
                                       cache=c, cache_index=index)
            return out, nc

        h, new_self = jax.lax.scan(dbody, h, (params["dec_layers"],
                                              cache["self"]))
        new_cache = {"self": new_self, "enc_out": enc_out}
    elif fam == "ssm":
        def pbody(hh, xs):
            pp, st = xs
            hh, s1 = X.mlstm_block_apply(pp["mlstm"], hh, cfg, rules=rules,
                                         cdt=cdt, state=st["mlstm"])
            hh, s2 = X.slstm_block_apply(pp["slstm"], hh, cfg, rules=rules,
                                         cdt=cdt, state=st["slstm"])
            return hh, {"mlstm": s1, "slstm": s2}

        h, new_cache = jax.lax.scan(pbody, h, (params["pairs"], cache))
    elif fam == "hybrid":
        def hbody(hh, xs):
            pp, c = xs
            out, nc, _ = _period_apply(pp, hh, cfg, positions=positions,
                                       rules=rules, cdt=cdt, caches=c,
                                       cache_index=index)
            return out, nc

        h, new_cache = jax.lax.scan(hbody, h, (params["periods"], cache))
    else:
        def body(hh, xs):
            lp, c = xs
            out, nc, _ = _layer_apply(lp, hh, cfg, positions=positions,
                                      rules=rules, cdt=cdt, cache=c,
                                      cache_index=index)
            return out, nc

        h, new_cache = jax.lax.scan(body, h, (params["layers"], cache))

    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    table = params["embed"]["table"] if cfg.tie_embeddings else \
        params["unembed"]["table"]
    logits = jnp.einsum("bsd,vd->bsv", h.astype(cdt), table.astype(cdt))
    if rules is not None:
        logits = rules.constrain(logits, "batch", None, "vocab")
    return logits[:, 0], new_cache
