"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, chunkwise-parallel)
and sLSTM (scalar memory, sequential scan with exponential gating).

TPU adaptation notes (see DESIGN.md):
* mLSTM trains with the stabilized *chunkwise* formulation — quadratic only
  within a chunk, O(d_head^2) carried state across chunks — which maps to
  MXU matmuls instead of a length-S serial scan. Decode is the O(1)
  recurrent update (this is why xlstm runs the long_500k shape).
* sLSTM is inherently sequential (the paper ships CUDA kernels for it); on
  TPU it lowers to a single fused lax.scan over time.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import _init, rms_norm

MLSTM_CHUNK = 256


# ------------------------------------------------------------------- mLSTM
def mlstm_init(key, cfg) -> Dict[str, Any]:
    d = cfg.d_model
    H, dh = cfg.n_heads, cfg.resolved_head_dim
    x = cfg.xlstm
    di = int(x.proj_factor_mlstm * d)
    ks = jax.random.split(key, 8)
    return {
        "norm": jnp.ones((d,)),
        "up_proj": _init(ks[0], (d, 2 * di)),
        "conv_w": _init(ks[1], (x.conv1d_kernel, di), scale=0.5),
        "conv_b": jnp.zeros((di,)),
        "wq": _init(ks[2], (di, H, dh)),
        "wk": _init(ks[3], (di, H, dh)),
        "wv": _init(ks[4], (di, H, dh)),
        "w_if": _init(ks[5], (di, 2 * H), scale=0.02),
        "b_i": jnp.zeros((H,)) - 3.0,
        "b_f": jnp.zeros((H,)) + 3.0,
        "out_norm": jnp.ones((H * dh,)),
        "down_proj": _init(ks[6], (H * dh, d)),
        "skip": jnp.ones((di,)),
    }


def mlstm_axes(cfg):
    return {
        "norm": (None,), "up_proj": ("embed", "ffn"),
        "conv_w": (None, "ffn"), "conv_b": ("ffn",),
        "wq": ("ffn", "heads", None), "wk": ("ffn", "heads", None),
        "wv": ("ffn", "heads", None),
        "w_if": ("ffn", None), "b_i": (None,), "b_f": (None,),
        "out_norm": (None,), "down_proj": (None, "embed"), "skip": ("ffn",),
    }


def _mlstm_cell_chunkwise(q, k, v, li, lf):
    """Stabilized chunkwise mLSTM. q,k,v: (B,H,S,dh); li,lf: (B,H,S) log-gates.
    Returns h: (B,H,S,dh)."""
    B, H, S, dh = q.shape
    L = min(MLSTM_CHUNK, S)
    n_chunks = -(-S // L)
    pad = n_chunks * L - S
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        li = jnp.pad(li, ((0, 0), (0, 0), (0, pad)), constant_values=-1e30)
        lf = jnp.pad(lf, ((0, 0), (0, 0), (0, pad)))
    q = q * (dh ** -0.5)

    def rsh(t, feat):
        newshape = (B, H, n_chunks, L) + ((t.shape[-1],) if feat else ())
        perm = (2, 0, 1, 3, 4) if feat else (2, 0, 1, 3)
        return t.reshape(newshape).transpose(perm)

    qs, ks_, vs = rsh(q, True), rsh(k, True), rsh(v, True)
    lis, lfs = rsh(li, False), rsh(lf, False)

    def step(carry, inp):
        C, n, m = carry          # C: (B,H,dh,dh); n: (B,H,dh); m: (B,H)
        qc, kc, vc, lic, lfc = inp
        b = jnp.cumsum(lfc, axis=-1)                        # B,H,L inclusive
        # intra-chunk log weights: D[i,j] = b_i - b_j + li_j  (j<=i)
        logD = b[..., :, None] - b[..., None, :] + lic[..., None, :]
        tri = jnp.tril(jnp.ones((L, L), bool))
        logD = jnp.where(tri, logD, -1e30)
        inter = b + m[..., None]                            # B,H,L
        m_i = jnp.maximum(inter, logD.max(-1))              # B,H,L
        d_intra = jnp.exp(logD - m_i[..., None])
        w_inter = jnp.exp(inter - m_i)                      # B,H,L
        scores = jnp.einsum("bhid,bhjd->bhij", qc, kc) * d_intra
        h_intra = jnp.einsum("bhij,bhjd->bhid", scores, vc)
        h_inter = w_inter[..., None] * jnp.einsum("bhid,bhde->bhie", qc, C)
        norm_intra = scores.sum(-1)
        norm_inter = w_inter * jnp.einsum("bhid,bhd->bhi", qc, n)
        denom = jnp.maximum(jnp.abs(norm_intra + norm_inter),
                            jnp.exp(-m_i))
        h = (h_intra + h_inter) / denom[..., None]
        # update carried state to end of chunk
        bL = b[..., -1]                                     # B,H
        a = bL[..., None] - b + lic                         # B,H,L
        m_new = jnp.maximum(bL + m, a.max(-1))
        scale_old = jnp.exp(bL + m - m_new)
        wa = jnp.exp(a - m_new[..., None])                  # B,H,L
        C_new = scale_old[..., None, None] * C + \
            jnp.einsum("bhj,bhjd,bhje->bhde", wa, kc, vc)
        n_new = scale_old[..., None] * n + \
            jnp.einsum("bhj,bhjd->bhd", wa, kc)
        return (C_new, n_new, m_new), h

    C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, H, dh), jnp.float32)
    m0 = jnp.full((B, H), -1e30, jnp.float32)
    _, hs = jax.lax.scan(step, (C0, n0, m0),
                         (qs, ks_, vs, lis, lfs))
    h = hs.transpose(1, 2, 0, 3, 4).reshape(B, H, n_chunks * L, dh)
    return h[:, :, :S]


def _mlstm_cell_step(state, q, k, v, li, lf):
    """O(1) decode update. q,k,v: (B,H,dh); li,lf: (B,H)."""
    C, n, m = state["C"], state["n"], state["m"]
    dh = q.shape[-1]
    q = q * (dh ** -0.5)
    m_new = jnp.maximum(lf + m, li)
    f_ = jnp.exp(lf + m - m_new)
    i_ = jnp.exp(li - m_new)
    C_new = f_[..., None, None] * C + \
        i_[..., None, None] * (k[..., :, None] * v[..., None, :])
    n_new = f_[..., None] * n + i_[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, C_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n_new)),
                      jnp.exp(-m_new))
    h = num / den[..., None]
    return {"C": C_new, "n": n_new, "m": m_new}, h


def mlstm_block_apply(p, x, cfg, *, rules=None, cdt=jnp.bfloat16,
                      state: Optional[Dict] = None):
    """x: (B,S,D) -> (out, new_state)."""
    from repro.models.mamba import _causal_conv
    B, S, D = x.shape
    H, dh = cfg.n_heads, cfg.resolved_head_dim
    xi = rms_norm(x, p["norm"], cfg.norm_eps).astype(cdt)
    up = xi @ p["up_proj"].astype(cdt)
    inner, z = jnp.split(up, 2, axis=-1)
    if rules is not None:
        inner = rules.constrain(inner, "batch", None, "ffn")
    conv_state = state["conv"] if state is not None else None
    cx, new_conv = _causal_conv(inner, p["conv_w"].astype(cdt),
                                p["conv_b"].astype(cdt), conv_state)
    cx = jax.nn.silu(cx)
    q = jnp.einsum("bsi,ihd->bshd", cx, p["wq"].astype(cdt))
    k = jnp.einsum("bsi,ihd->bshd", cx, p["wk"].astype(cdt))
    v = jnp.einsum("bsi,ihd->bshd", inner, p["wv"].astype(cdt))
    gates = (cx @ p["w_if"].astype(cdt)).astype(jnp.float32)
    gi, gf = jnp.split(gates, 2, axis=-1)                    # B,S,H
    li = (gi + p["b_i"]).transpose(0, 2, 1)                  # B,H,S
    lf = jax.nn.log_sigmoid(gf + p["b_f"]).transpose(0, 2, 1)
    qT = q.transpose(0, 2, 1, 3).astype(jnp.float32)
    kT = k.transpose(0, 2, 1, 3).astype(jnp.float32)
    vT = v.transpose(0, 2, 1, 3).astype(jnp.float32)
    if state is None:
        h = _mlstm_cell_chunkwise(qT, kT, vT, li, lf)
        new_cell = None
    else:
        new_cell, h1 = _mlstm_cell_step(state["cell"], qT[:, :, 0],
                                        kT[:, :, 0], vT[:, :, 0],
                                        li[:, :, 0], lf[:, :, 0])
        h = h1[:, :, None, :]
    h = h.transpose(0, 2, 1, 3).reshape(B, S, H * dh).astype(cdt)
    h = rms_norm(h, p["out_norm"], cfg.norm_eps)
    h = h + p["skip"].astype(cdt)[:H * dh] * cx[..., :H * dh]
    out = (h * jax.nn.silu(z[..., :H * dh])) @ p["down_proj"].astype(cdt)
    new_state = None
    if state is not None:
        new_state = {"conv": new_conv, "cell": new_cell}
    return x + out.astype(x.dtype), new_state


def mlstm_init_state(cfg, batch):
    x = cfg.xlstm
    H, dh = cfg.n_heads, cfg.resolved_head_dim
    di = int(x.proj_factor_mlstm * cfg.d_model)
    return {
        "conv": jnp.zeros((batch, x.conv1d_kernel - 1, di), jnp.float32),
        "cell": {"C": jnp.zeros((batch, H, dh, dh), jnp.float32),
                 "n": jnp.zeros((batch, H, dh), jnp.float32),
                 "m": jnp.full((batch, H), -1e30, jnp.float32)},
    }


# ------------------------------------------------------------------- sLSTM
def slstm_init(key, cfg) -> Dict[str, Any]:
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    x = cfg.xlstm
    df = int(x.proj_factor_slstm * d)
    ks = jax.random.split(key, 4)
    return {
        "norm": jnp.ones((d,)),
        "w_gates": _init(ks[0], (d, 4 * d)),          # i,f,z,o
        "r_gates": _init(ks[1], (H, dh, 4 * dh),
                         scale=1.0 / np.sqrt(dh)),    # block-diag recurrent
        "b_gates": jnp.concatenate([jnp.zeros((d,)) - 3.0,
                                    jnp.zeros((d,)) + 3.0,
                                    jnp.zeros((2 * d,))]),
        "gn": jnp.ones((d,)),
        "ffn_up": _init(ks[2], (d, 2 * df)),
        "ffn_down": _init(ks[3], (df, d)),
    }


def slstm_axes(cfg):
    return {
        "norm": (None,), "w_gates": ("embed", "ffn"),
        "r_gates": (None, None, None), "b_gates": (None,),
        "gn": (None,),
        "ffn_up": ("embed", "ffn"), "ffn_down": ("ffn", "embed"),
    }


def _slstm_scan(wx, r, state):
    """wx: (B,S,4d) input contributions; r: (H,dh,4dh).
    state: dict(c,n,h,m) each (B,d) except m. Sequential scan over S."""
    B, S, d4 = wx.shape
    d = d4 // 4
    H = r.shape[0]
    dh = d // H

    def step(carry, wxt):
        c, n, h, m = carry
        hh = h.reshape(B, H, dh)
        rec = jnp.einsum("bhd,hde->bhe", hh, r)          # (B, H, 4*dh)
        # reorder per-head (i,f,z,o) blocks into global (i,f,z,o) layout
        rec = rec.reshape(B, H, 4, dh).transpose(0, 2, 1, 3).reshape(B, 4 * d)
        gates = wxt + rec
        gi, gf, gz, go = jnp.split(gates, 4, axis=-1)
        m_new = jnp.maximum(gf + m, gi)
        i_ = jnp.exp(gi - m_new)
        f_ = jnp.exp(gf + m - m_new)
        z = jnp.tanh(gz)
        o = jax.nn.sigmoid(go)
        c_new = f_ * c + i_ * z
        n_new = f_ * n + i_
        h_new = o * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, h_new, m_new), h_new

    carry0 = (state["c"], state["n"], state["h"], state["m"])
    carry, hs = jax.lax.scan(step, carry0, wx.transpose(1, 0, 2))
    new_state = dict(zip(("c", "n", "h", "m"), carry))
    return hs.transpose(1, 0, 2), new_state


def slstm_block_apply(p, x, cfg, *, rules=None, cdt=jnp.bfloat16,
                      state: Optional[Dict] = None):
    B, S, D = x.shape
    H = cfg.n_heads
    xi = rms_norm(x, p["norm"], cfg.norm_eps)
    wx = (xi.astype(cdt) @ p["w_gates"].astype(cdt)).astype(jnp.float32)
    wx = wx + p["b_gates"]
    # recurrent gate layout (B,4d) must split into per-head blocks; reshape
    # w_gates output as (B,S,4,H,dh) -> (B,S,H,4dh)-compatible 4d flat.
    if state is None:
        st = slstm_init_state(cfg, B)
    else:
        st = state
    hs, new_state = _slstm_scan(wx, p["r_gates"], st)
    hs = rms_norm(hs.astype(jnp.float32), p["gn"], cfg.norm_eps).astype(cdt)
    up = hs @ p["ffn_up"].astype(cdt)
    a, b = jnp.split(up, 2, axis=-1)
    out = (jax.nn.gelu(a) * b) @ p["ffn_down"].astype(cdt)
    return x + out.astype(x.dtype), (new_state if state is not None else None)


def slstm_init_state(cfg, batch):
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"c": z, "n": z + 1e-6, "h": z, "m": z - 1e30}


def count_params(cfg) -> int:
    """Analytic param count for the xLSTM LM (embedding tied)."""
    import jax
    k = jax.random.PRNGKey(0)
    n_pairs = max(cfg.n_layers // 2, 1)
    shapes = jax.eval_shape(lambda kk: {
        "m": mlstm_init(kk, cfg), "s": slstm_init(kk, cfg)}, k)
    per_pair = sum(int(np.prod(x.shape))
                   for x in jax.tree.leaves(shapes))
    emb = cfg.vocab * cfg.d_model
    return n_pairs * per_pair + emb + cfg.d_model
