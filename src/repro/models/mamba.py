"""Selective SSM (Mamba) block for the Jamba hybrid architecture.

Training/prefill uses a chunked scan: ``lax.scan`` over sequence chunks with
a ``lax.associative_scan`` inside each chunk, so the (B, S, d_inner, d_state)
tensor never materializes at full sequence length. Decode is the O(1)
recurrent update. d_inner shards over the ``model`` mesh axis ("ffn" logical
axis) — conv/gating are elementwise over d_inner, and the B/C projections
reduce over the sharded dim (GSPMD inserts the small all-reduces).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import _init

MAMBA_CHUNK = 256


def mamba_init(key, cfg) -> Dict[str, Any]:
    h = cfg.hybrid
    d = cfg.d_model
    di = h.expand * d
    ks = jax.random.split(key, 6)
    dt_rank = max(d // 16, 1)
    return {
        "in_proj": _init(ks[0], (d, 2 * di)),
        "conv_w": _init(ks[1], (h.d_conv, di), scale=0.5),
        "conv_b": jnp.zeros((di,)),
        "x_proj": _init(ks[2], (di, dt_rank + 2 * h.d_state)),
        "dt_proj": _init(ks[3], (dt_rank, di), scale=dt_rank ** -0.5),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[4], (di,),
                                       minval=np.log(1e-3),
                                       maxval=np.log(1e-1))))),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, h.d_state + 1,
                                             dtype=jnp.float32), (di, 1))),
        "D": jnp.ones((di,)),
        "out_proj": _init(ks[5], (di, d)),
    }


def mamba_axes(cfg):
    return {
        "in_proj": ("embed", "ffn"),
        "conv_w": (None, "ffn"),
        "conv_b": ("ffn",),
        "x_proj": ("ffn", None),
        "dt_proj": (None, "ffn"),
        "dt_bias": ("ffn",),
        "A_log": ("ffn", None),
        "D": ("ffn",),
        "out_proj": ("ffn", "embed"),
    }


def _causal_conv(x, w, b, state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv. x: (B, S, di); w: (k, di).
    state: (B, k-1, di)."""
    k = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    new_state = xp[:, -(k - 1):] if k > 1 else None
    return out + b, new_state


def _ssm_params(p, x, cfg, cdt):
    """x: (B, S, di) -> dt (B,S,di), B_ (B,S,N), C (B,S,N), A (di,N)."""
    h = cfg.hybrid
    dt_rank = p["dt_proj"].shape[0]
    proj = x @ p["x_proj"].astype(cdt)
    dt_in, Bm, Cm = jnp.split(proj, [dt_rank, dt_rank + h.d_state], axis=-1)
    dt = jax.nn.softplus((dt_in @ p["dt_proj"].astype(cdt)).astype(jnp.float32)
                         + p["dt_bias"])
    A = -jnp.exp(p["A_log"])  # (di, N)
    return dt, Bm.astype(jnp.float32), Cm.astype(jnp.float32), A


def mamba_apply(p, x, cfg, *, rules=None, cdt=jnp.bfloat16,
                state: Optional[Dict] = None):
    """x: (B, S, D). state (decode): {"conv": (B,k-1,di), "ssm": (B,di,N)}.

    Returns (out, new_state)."""
    B, S, D = x.shape
    h = cfg.hybrid
    di = h.expand * D
    xc = x.astype(cdt)
    xz = xc @ p["in_proj"].astype(cdt)
    xin, z = jnp.split(xz, 2, axis=-1)
    if rules is not None:
        xin = rules.constrain(xin, "batch", None, "ffn")
        z = rules.constrain(z, "batch", None, "ffn")

    if state is not None:
        xin, conv_state = _causal_conv(xin, p["conv_w"].astype(cdt),
                                       p["conv_b"].astype(cdt),
                                       state["conv"])
        xin = jax.nn.silu(xin)
        dt, Bm, Cm, A = _ssm_params(p, xin, cfg, cdt)
        # recurrent update: s' = exp(dt*A)*s + dt*B*x
        dA = jnp.exp(dt[:, 0, :, None] * A[None])            # B,di,N
        dBx = dt[:, 0, :, None] * Bm[:, 0, None, :] * \
            xin[:, 0, :, None].astype(jnp.float32)
        s = state["ssm"] * dA + dBx
        y = (s * Cm[:, 0, None, :]).sum(-1)                  # B,di
        y = y + p["D"] * xin[:, 0].astype(jnp.float32)
        y = (y.astype(cdt) * jax.nn.silu(z[:, 0]))[:, None]  # B,1,di
        out = y @ p["out_proj"].astype(cdt)
        return out, {"conv": conv_state, "ssm": s}

    # train/prefill: chunked associative scan
    xin, _ = _causal_conv(xin, p["conv_w"].astype(cdt),
                          p["conv_b"].astype(cdt))
    xin = jax.nn.silu(xin)
    dt, Bm, Cm, A = _ssm_params(p, xin, cfg, cdt)

    chunk = min(MAMBA_CHUNK, S)
    n_chunks = -(-S // chunk)
    pad = n_chunks * chunk - S
    if pad:
        xin = jnp.pad(xin, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))

    def rsh(t):
        return t.reshape(B, n_chunks, chunk, -1).transpose(1, 0, 2, 3)

    xch, dtch, Bch, Cch = (rsh(xin.astype(jnp.float32)), rsh(dt),
                           rsh(Bm), rsh(Cm))

    def chunk_step(s0, inp):
        xc_, dt_, B_, C_ = inp                       # (B, c, di|N)
        dA = jnp.exp(dt_[..., None] * A)             # B,c,di,N
        dBx = dt_[..., None] * B_[:, :, None, :] * xc_[..., None]

        def combine(a, b):
            (a1, b1), (a2, b2) = a, b
            return a1 * a2, b1 * a2 + b2

        aA, aB = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
        s = aA * s0[:, None] + aB                    # B,c,di,N
        y = (s * C_[:, :, None, :]).sum(-1)          # B,c,di
        return s[:, -1], y

    s0 = jnp.zeros((B, di, h.d_state), jnp.float32)
    _, ys = jax.lax.scan(chunk_step, s0, (xch, dtch, Bch, Cch))
    y = ys.transpose(1, 0, 2, 3).reshape(B, n_chunks * chunk, di)
    if pad:
        y = y[:, :S]
    y = y + p["D"] * xin[:, :S].astype(jnp.float32)
    y = y.astype(cdt) * jax.nn.silu(z)
    if rules is not None:
        y = rules.constrain(y, "batch", None, "ffn")
    out = y @ p["out_proj"].astype(cdt)
    return out, None


def mamba_init_state(cfg, batch, dtype=jnp.float32):
    h = cfg.hybrid
    di = h.expand * cfg.d_model
    return {"conv": jnp.zeros((batch, h.d_conv - 1, di), dtype),
            "ssm": jnp.zeros((batch, di, h.d_state), jnp.float32)}
