"""Core transformer layers, pure JAX, with logical sharding axes.

Conventions
-----------
* Params are nested dicts of jnp arrays; every init function has a matching
  ``*_axes`` function returning the same pytree of logical-axis tuples
  (consumed by :mod:`repro.runtime.sharding`).
* Params are stored fp32 (master weights); forward casts to ``cdt``
  (compute dtype, bf16 by default) — mixed-precision training.
* Attention is flash-style (lax.scan over key blocks, online softmax), so no
  S^2 buffer is ever materialized; this is what makes prefill_32k lowerable.
* Layer stacks are scanned (lax.scan over stacked params) for compact HLO
  and fast compiles.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]

DEFAULT_KBLK = 1024   # flash-attention key-block size
DEFAULT_QBLK = 512    # flash-attention query-block size (memory knob)


# ----------------------------------------------------------------- utilities
def _init(key, shape, scale=None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) > 1 else max(shape[0], 1)
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return jax.random.normal(key, shape, dtype) * jnp.asarray(scale, dtype)


def rms_norm(x, gamma, eps):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * gamma.astype(dt)


def rope(x, positions, theta):
    """Rotary embedding. x: (..., S, H, D); positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions.astype(jnp.float32)[..., :, None] * freqs
    # angles: (..., S, half) -> broadcast over heads
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- attention
def attention_init(key, cfg) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": _init(ks[0], (d, nq, hd)),
        "wk": _init(ks[1], (d, nkv, hd)),
        "wv": _init(ks[2], (d, nkv, hd)),
        "wo": _init(ks[3], (nq, hd, d), scale=1.0 / np.sqrt(nq * hd)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nq, hd))
        p["bk"] = jnp.zeros((nkv, hd))
        p["bv"] = jnp.zeros((nkv, hd))
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,))
        p["k_norm"] = jnp.ones((hd,))
    return p


def attention_axes(cfg):
    a = {
        "wq": ("embed", "heads", None),
        "wk": ("embed", "kv_heads", None),
        "wv": ("embed", "kv_heads", None),
        "wo": ("heads", None, "embed"),
    }
    if cfg.qkv_bias:
        a.update(bq=("heads", None), bk=("kv_heads", None),
                 bv=("kv_heads", None))
    if cfg.qk_norm:
        a.update(q_norm=(None,), k_norm=(None,))
    return a


def flash_attention(q, k, v, *, causal: bool, q_offset=0,
                    kblk: int = DEFAULT_KBLK, rules=None,
                    bias_decay: Optional[jnp.ndarray] = None):
    """Online-softmax attention, scanning over key blocks.

    q: (B, Sq, H, D); k, v: (B, Sk, H, D) (kv already repeated to H heads).
    q_offset: global position of q[0] (for causal masking of prefill chunks).
    Never materializes an (Sq, Sk) buffer larger than (Sq, kblk).
    """
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    kblk = min(kblk, Sk)
    n_blk = (Sk + kblk - 1) // kblk
    pad = n_blk * kblk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    scale = 1.0 / np.sqrt(D)
    q_pos = q_offset + jnp.arange(Sq)

    kb = k.reshape(B, n_blk, kblk, H, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, n_blk, kblk, H, D).transpose(1, 0, 2, 3, 4)

    def constrain(x, *axes):
        return rules.constrain(x, *axes) if rules is not None else x

    def step(carry, inputs):
        m, lse, acc, blk_idx = carry
        kc, vc = inputs
        k_pos = blk_idx * kblk + jnp.arange(kblk)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, kc,
                            preferred_element_type=jnp.float32) * scale
        logits = constrain(logits, "batch", "heads", "qseq", None)
        mask = (k_pos[None, :] <= q_pos[:, None]) if causal else \
            (k_pos[None, :] < Sk)
        mask = mask & (k_pos[None, :] < Sk)
        logits = jnp.where(mask[None, None], logits, -1e30)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = lse * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhqk,bkhd->bqhd", p.astype(vc.dtype), vc,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr.transpose(0, 2, 1)[..., None] + pv
        return (m_new, l_new, acc_new, blk_idx + 1), None

    m0 = jnp.full((B, H, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, Sq, H, D), jnp.float32)
    (m, lse, acc, _), _ = jax.lax.scan(step, (m0, l0, a0, jnp.int32(0)),
                                       (kb, vb))
    out = acc / jnp.maximum(lse, 1e-30).transpose(0, 2, 1)[..., None]
    return out.astype(q.dtype)


def attention_apply(p, x, cfg, *, positions, rules=None, cdt=jnp.bfloat16,
                    cache: Optional[Dict] = None, cache_index=None):
    """GQA attention. If cache is given, single-token decode; else full seq.

    cache: {"k": (B, n_kv, S_cache, D), "v": same} sharded on cache_seq.
    Returns (out, new_cache).
    """
    B, S, d = x.shape
    nq, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    G = nq // nkv
    xc = x.astype(cdt)
    q = jnp.einsum("bsd,dhk->bshk", xc, p["wq"].astype(cdt))
    k = jnp.einsum("bsd,dhk->bshk", xc, p["wk"].astype(cdt))
    v = jnp.einsum("bsd,dhk->bshk", xc, p["wv"].astype(cdt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cdt)
        k = k + p["bk"].astype(cdt)
        v = v + p["bv"].astype(cdt)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    if cache is None:
        # train/prefill: repeat kv to full q heads, flash attention.
        # Head (tensor) parallelism when n_heads divides the model axis;
        # with rules.pad_attention_heads, odd head counts are zero-padded up
        # to the next multiple of the model axis (padded heads are sliced
        # away before the output projection — mathematically identity, +14%
        # flops for llava's 56->64, and it converts the expensive per-layer
        # CP<->TP re-sharding into clean head parallelism);
        # otherwise query-sequence context parallelism picks up that axis.
        kf = jnp.repeat(k, G, axis=2)
        vf = jnp.repeat(v, G, axis=2)
        n_eff = nq
        if rules is not None:
            heads_tp = rules.divisible(nq, "model")
            if not heads_tp and getattr(rules, "pad_attention_heads", False):
                m_sz = rules.axis_sizes.get("model", 1)
                n_eff = -(-nq // m_sz) * m_sz
                hp = n_eff - nq
                q = jnp.pad(q, ((0, 0), (0, 0), (0, hp), (0, 0)))
                kf = jnp.pad(kf, ((0, 0), (0, 0), (0, hp), (0, 0)))
                vf = jnp.pad(vf, ((0, 0), (0, 0), (0, hp), (0, 0)))
                heads_tp = True
            qs = None if heads_tp else "qseq"
            q = rules.constrain(q, "batch", qs, "heads", None)
            kf = rules.constrain(kf, "batch", None, "heads", None)
            vf = rules.constrain(vf, "batch", None, "heads", None)
        out = flash_attention(q, kf, vf, causal=True, rules=rules)
        if n_eff != nq:
            out = out[:, :, :nq]
        new_cache = None
    else:
        # decode: update seq-sharded cache at cache_index, grouped attention
        kc = cache["k"]  # (B, nkv, Sc, D)
        vc = cache["v"]
        k1 = k.transpose(0, 2, 1, 3)  # (B, nkv, 1, D)
        v1 = v.transpose(0, 2, 1, 3)
        kc = jax.lax.dynamic_update_slice(kc, k1.astype(kc.dtype),
                                          (0, 0, cache_index, 0))
        vc = jax.lax.dynamic_update_slice(vc, v1.astype(vc.dtype),
                                          (0, 0, cache_index, 0))
        if rules is not None:
            kc = rules.constrain(kc, "batch", "kv_heads", "cache_seq", None)
            vc = rules.constrain(vc, "batch", "kv_heads", "cache_seq", None)
        Sc = kc.shape[2]
        # -> B,nkv,G,S,D
        qg = q.reshape(B, S, nkv, G, hd).transpose(0, 2, 3, 1, 4)
        qg = qg.reshape(B, nkv, G * S, hd)
        logits = jnp.einsum("bhgk,bhsk->bhgs", qg, kc.astype(cdt),
                            preferred_element_type=jnp.float32)
        logits = logits / np.sqrt(hd)
        valid = jnp.arange(Sc) <= cache_index
        logits = jnp.where(valid[None, None, None], logits, -1e30)
        if rules is not None:
            logits = rules.constrain(logits, "batch", "kv_heads", None,
                                     "cache_seq")
        w = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhgs,bhsk->bhgk", w.astype(cdt), vc.astype(cdt))
        out = out.reshape(B, nkv, G, S, hd).transpose(0, 3, 1, 2, 4)
        out = out.reshape(B, S, nq, hd)
        new_cache = {"k": kc, "v": vc}

    y = jnp.einsum("bshk,hkd->bsd", out.astype(cdt), p["wo"].astype(cdt))
    return y, new_cache


# ----------------------------------------------------------------- FFN
def ffn_init(key, d_model, d_ff, gated=True) -> Params:
    ks = jax.random.split(key, 3)
    p = {"w_up": _init(ks[0], (d_model, d_ff)),
         "w_down": _init(ks[1], (d_ff, d_model))}
    if gated:
        p["w_gate"] = _init(ks[2], (d_model, d_ff))
    return p


def ffn_axes(gated=True):
    a = {"w_up": ("embed", "ffn"), "w_down": ("ffn", "embed")}
    if gated:
        a["w_gate"] = ("embed", "ffn")
    return a


def ffn_apply(p, x, *, rules=None, cdt=jnp.bfloat16, gated=True):
    xc = x.astype(cdt)
    up = xc @ p["w_up"].astype(cdt)
    if gated:
        gate = jax.nn.silu(xc @ p["w_gate"].astype(cdt))
        h = gate * up
    else:
        h = jax.nn.gelu(up)
    if rules is not None:
        # ffn (tensor) parallelism owns the model axis here; the sequence
        # dim stays unsharded inside the FFN even under context parallelism
        h = rules.constrain(h, "batch", None, "ffn")
    return h @ p["w_down"].astype(cdt)


# ----------------------------------------------------------------- embedding
def embedding_init(key, vocab, d_model, pad_to=1) -> Params:
    vpad = ((vocab + pad_to - 1) // pad_to) * pad_to
    return {"table": _init(key, (vpad, d_model), scale=0.02)}


def embedding_axes():
    return {"table": ("vocab", "embed")}


def embed_apply(p, ids, cdt=jnp.bfloat16):
    return p["table"].astype(cdt)[ids]


def unembed_apply(p, x, cdt=jnp.bfloat16):
    return jnp.einsum("bsd,vd->bsv", x.astype(cdt), p["table"].astype(cdt))
