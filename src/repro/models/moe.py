"""Token-choice top-k MoE with chunked capacity-based dispatch.

Dispatch/combine are dense one-hot einsums (GSPMD-friendly: no data-dependent
shapes), applied per sequence chunk so the (tokens, experts, capacity)
dispatch tensor stays small even at 32k sequence length. Experts shard over
the ``model`` mesh axis (expert parallelism); the dispatch einsum lowers to
an all-to-all-like collective under GSPMD.

Active-FLOPs accounting: per token, top_k experts * capacity_factor slack,
matching the 6*N_active*D convention used in the roofline.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import _init

MOE_CHUNK = 1024  # sequence chunk for dispatch (memory knob)


def moe_init(key, cfg) -> Dict[str, Any]:
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_ff_expert, m.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": _init(ks[0], (d, e), scale=0.02),
        "w_gate": _init(ks[1], (e, d, f)),
        "w_up": _init(ks[2], (e, d, f)),
        "w_down": _init(ks[3], (e, f, d), scale=1.0 / np.sqrt(f)),
    }


def moe_axes(cfg):
    return {
        "router": ("embed", None),
        "w_gate": ("experts", "embed", "ffn"),
        "w_up": ("experts", "embed", "ffn"),
        "w_down": ("experts", "ffn", "embed"),
    }


def _capacity(chunk_tokens: int, cfg) -> int:
    m = cfg.moe
    cap = int(np.ceil(
        chunk_tokens * m.top_k * m.capacity_factor / m.n_experts))
    return max(cap, m.top_k)


def moe_apply(p, x, cfg, *, rules=None, cdt=jnp.bfloat16):
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar)."""
    B, S, D = x.shape
    m = cfg.moe
    E, K = m.n_experts, m.top_k
    chunk = min(MOE_CHUNK, S)
    n_chunks = S // chunk if S % chunk == 0 else -(-S // chunk)
    pad = n_chunks * chunk - S
    xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0))) if pad else x
    xc = xp.reshape(B, n_chunks, chunk, D).transpose(1, 0, 2, 3)
    cap = _capacity(chunk, cfg)

    def one_chunk(xch):
        # xch: (B, c, D)
        h = xch.astype(cdt)
        logits = (h @ p["router"].astype(cdt)).astype(jnp.float32)  # B,c,E
        probs = jax.nn.softmax(logits, axis=-1)
        topv, topi = jax.lax.top_k(probs, K)                         # B,c,K
        topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
        # slot position of each (token, k) within its expert, via cumsum
        onehot = jax.nn.one_hot(topi, E, dtype=jnp.float32)          # B,c,K,E
        flat = onehot.reshape(B, chunk * K, E)
        pos = jnp.cumsum(flat, axis=1) - flat                        # B,cK,E
        pos = pos.reshape(B, chunk, K, E)
        slot = (pos * onehot).sum(-1)                                # B,c,K
        keep = slot < cap
        slot_oh = jax.nn.one_hot(jnp.where(keep, slot, cap), cap + 1,
                                 dtype=jnp.float32)[..., :cap]  # B,c,K,cap
        disp = jnp.einsum("bcke,bckp->bcep", onehot, slot_oh)  # B,c,E,cap
        comb = jnp.einsum("bcke,bckp,bck->bcep", onehot, slot_oh,
                          topv.astype(jnp.float32))
        # dispatch tokens to expert slots
        xin = jnp.einsum("bcep,bcd->ebpd", disp.astype(cdt), h)  # E,B,cap,D
        if rules is not None:
            xin = rules.constrain(xin, "experts", "batch", None, None)
        gate = jax.nn.silu(jnp.einsum("ebpd,edf->ebpf", xin,
                                      p["w_gate"].astype(cdt)))
        up = jnp.einsum("ebpd,edf->ebpf", xin, p["w_up"].astype(cdt))
        eout = jnp.einsum("ebpf,efd->ebpd", gate * up,
                          p["w_down"].astype(cdt))
        if rules is not None:
            eout = rules.constrain(eout, "experts", "batch", None, None)
        out = jnp.einsum("bcep,ebpd->bcd", comb.astype(cdt), eout)   # B,c,D
        # load-balance aux (Switch-style): mean prob * mean assigned fraction
        me = probs.mean(axis=(0, 1))                                 # E
        ce = onehot.mean(axis=(0, 1, 2)) * K                         # E
        aux = (me * ce).sum() * E
        return out, aux

    outs, auxs = jax.lax.map(one_chunk, xc)
    out = outs.transpose(1, 0, 2, 3).reshape(B, n_chunks * chunk, D)
    if pad:
        out = out[:, :S]
    return out, auxs.mean() * cfg.moe.router_aux_weight
