"""Docs health check, stdlib-only: dead-link scan + tutorial smoke.

1. Every relative markdown link in ``docs/*.md`` and ``README.md``
   must resolve to a real file (anchors and absolute URLs are
   skipped — CI must not depend on network).
2. The first fenced ``python`` block in ``docs/ingestion.md`` — the
   "lower your own JAX function" tutorial — is executed verbatim, so
   the documented front-door API can never silently drift from the
   code. Needs ``PYTHONPATH=src`` (and jax) like the test suite.

    PYTHONPATH=src python docs/check_docs.py
    python docs/check_docs.py --links-only   # no jax needed
"""
from __future__ import annotations

import argparse
import glob
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SNIPPET_RE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def check_links() -> int:
    bad = 0
    files = sorted(glob.glob(os.path.join(ROOT, "docs", "*.md")))
    files.append(os.path.join(ROOT, "README.md"))
    for path in files:
        with open(path, encoding="utf-8") as f:
            text = f.read()
        base = os.path.dirname(path)
        for m in LINK_RE.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue            # no network in CI
            target = target.split("#", 1)[0]
            if not target:
                continue            # pure in-page anchor
            if not os.path.exists(os.path.join(base, target)):
                rel = os.path.relpath(path, ROOT)
                print(f"DEAD LINK {rel}: ({m.group(1)})",
                      file=sys.stderr)
                bad += 1
    n = len(files)
    print(f"link check: {n} files, {bad} dead links")
    return bad


def run_tutorial() -> int:
    path = os.path.join(ROOT, "docs", "ingestion.md")
    with open(path, encoding="utf-8") as f:
        m = SNIPPET_RE.search(f.read())
    if m is None:
        print("TUTORIAL MISSING: no ```python block in ingestion.md",
              file=sys.stderr)
        return 1
    code = m.group(1)
    print(f"running ingestion tutorial ({len(code.splitlines())} "
          f"lines)...")
    try:
        exec(compile(code, "docs/ingestion.md::tutorial", "exec"), {})
    except Exception as e:
        print(f"TUTORIAL FAILED: {type(e).__name__}: {e}",
              file=sys.stderr)
        return 1
    print("tutorial passed")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--links-only", action="store_true",
                    help="skip the tutorial execution (no jax needed)")
    args = ap.parse_args()
    rc = check_links()
    if not args.links_only:
        rc += run_tutorial()
    return 1 if rc else 0


if __name__ == "__main__":
    sys.exit(main())
